//! The hierarchical coordinator (the paper's system design): sharded
//! stores homed on NUMA nodes, a per-thread lock-free queue fabric routing
//! keys to NUMA-local workers, and the leader-driven workload engine.
//!
//! The sharded store exposes the full ordered-map API ([`OrderedKv`]):
//! cross-shard `range` (per-prefix fan-out, concatenated in key order) and
//! routed `insert_batch`/`erase_batch`; [`bulk_load`] drains batch inserts
//! through per-shard queues on pinned workers.

pub mod engine;
pub mod router;
pub mod store;

pub use engine::{bulk_load, run_workload, RunMetrics};
pub use router::RouterFabric;
pub use store::{KvStore, OrderedKv, ShardedStore, StoreKind};
