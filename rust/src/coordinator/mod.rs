//! The hierarchical coordinator (the paper's system design): sharded
//! stores homed on NUMA nodes, a per-thread lock-free queue fabric routing
//! keys to NUMA-local workers, and the leader-driven workload engine.

pub mod engine;
pub mod router;
pub mod store;

pub use engine::{run_workload, RunMetrics};
pub use router::RouterFabric;
pub use store::{KvStore, ShardedStore, StoreKind};
