//! The hierarchical coordinator (the paper's system design): sharded
//! stores homed on NUMA nodes, a per-thread lock-free queue fabric routing
//! work to NUMA-local workers, and the leader-driven workload engine.
//!
//! Three execution modes share the machinery ([`ExecMode`]):
//!
//! - **Direct** — the classic fill-then-drain path: transport words are
//!   routed to threads on each key's home node, and workers apply ops
//!   straight to the sharded store (cross-shard range scans still
//!   dereference remote shards).
//! - **Delegated** — the paper's §VI–VII hierarchical proposal completed:
//!   callers wrap ops in typed [`DelegatedOp`] envelopes, batch them
//!   caller-side, and ship them over the [`OpFabric`] to the owner thread
//!   of each shard; owners execute against their NUMA-local shard only, so
//!   callers never dereference remote shard memory
//!   (`remote_accesses == 0` by construction).
//! - **Replicated** — every NUMA node keeps a lazily-synced local replica
//!   of each shard's index *layers* (`skiplist::replica`) routing into the
//!   single shared terminal list: reads descend node-locally with no
//!   delegation hop (`replica.remote_index_derefs == 0` by construction)
//!   and validate their landing live; writes go to the primary and publish
//!   compact invalidations that replicas absorb on maintenance ticks.
//!
//! The sharded store exposes the full ordered-map API ([`OrderedKv`]):
//! cross-shard `range` (per-prefix fan-out, concatenated in key order) and
//! routed `insert_batch`/`erase_batch`; [`bulk_load`] drains batch inserts
//! through per-shard queues on pinned workers.

pub mod engine;
pub mod router;
pub mod store;

pub use engine::{bulk_load, run_with_mode, run_with_opts, run_workload, ExecMode, RunMetrics, RunOptions};
pub use router::{
    Caller, DelegatedOp, FabricError, FabricStats, OpFabric, OpResult, RouterFabric, SlotTotals,
};
pub use store::{
    keys_sorted, pairs_sorted, KvStore, OrderedKv, ShardedStore, StoreKind, DEFAULT_INTERLEAVE,
};

/// Shard of a key: the top 3 MSBs (the paper's 8 key-space segments) folded
/// onto the shard count. The single source of truth for key→shard routing —
/// the sharded store, the word router and the delegation fabric all call
/// this, so their folded-prefix behaviour can never drift apart (see the
/// cross-check test in `store.rs`).
#[inline]
pub fn shard_of_key(key: u64, nshards: usize) -> usize {
    debug_assert!(nshards > 0);
    ((key >> 61) as usize) % nshards
}

/// Visit every 3-MSB prefix segment intersecting `[lo, hi]` in ascending
/// key order, passing the segment-clamped sub-bounds. The single splitter
/// behind every cross-shard range path — the store's scan, Direct-mode
/// accounting, and the fabric's per-owner sub-ops — so their segment
/// arithmetic can never drift apart. No-op when `lo > hi`.
#[inline]
pub fn for_each_prefix_segment(lo: u64, hi: u64, mut f: impl FnMut(u64, u64)) {
    if lo > hi {
        return;
    }
    for p in (lo >> 61)..=(hi >> 61) {
        let base = p << 61;
        f(lo.max(base), hi.min(base | ((1u64 << 61) - 1)));
    }
}
