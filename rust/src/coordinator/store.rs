//! Sharded store: one data structure per NUMA shard (paper §VI-VIII:
//! "we partitioned the skiplist into one skiplist per NUMA node ... the key
//! space was partitioned across skiplists using 3 MSBs").

use crate::hashtable::{
    ConcurrentMap, FixedHashMap, SpoHashMap, TbbLikeHashMap, TwoLevelHashMap, TwoLevelSpoHashMap,
};
use crate::numa::{LocalityStats, Topology, LATENCY};
use crate::skiplist::{DetSkiplist, FindMode, RandomSkiplist};

/// Unified key-value interface over every structure in the repo.
pub trait KvStore: Send + Sync {
    fn insert(&self, key: u64, value: u64) -> bool;
    fn get(&self, key: u64) -> Option<u64>;
    fn erase(&self, key: u64) -> bool;
    fn len(&self) -> u64;
    fn name(&self) -> &'static str;
}

impl KvStore for DetSkiplist {
    fn insert(&self, key: u64, value: u64) -> bool {
        DetSkiplist::insert(self, key, value)
    }
    fn get(&self, key: u64) -> Option<u64> {
        DetSkiplist::get(self, key)
    }
    fn erase(&self, key: u64) -> bool {
        DetSkiplist::erase(self, key)
    }
    fn len(&self) -> u64 {
        DetSkiplist::len(self)
    }
    fn name(&self) -> &'static str {
        "det-skiplist"
    }
}

impl KvStore for RandomSkiplist {
    fn insert(&self, key: u64, value: u64) -> bool {
        RandomSkiplist::insert(self, key, value)
    }
    fn get(&self, key: u64) -> Option<u64> {
        RandomSkiplist::get(self, key)
    }
    fn erase(&self, key: u64) -> bool {
        RandomSkiplist::erase(self, key)
    }
    fn len(&self) -> u64 {
        RandomSkiplist::len(self)
    }
    fn name(&self) -> &'static str {
        "random-skiplist"
    }
}

macro_rules! kv_for_map {
    ($t:ty) => {
        impl KvStore for $t {
            fn insert(&self, key: u64, value: u64) -> bool {
                ConcurrentMap::insert(self, key, value)
            }
            fn get(&self, key: u64) -> Option<u64> {
                ConcurrentMap::get(self, key)
            }
            fn erase(&self, key: u64) -> bool {
                ConcurrentMap::erase(self, key)
            }
            fn len(&self) -> u64 {
                ConcurrentMap::len(self)
            }
            fn name(&self) -> &'static str {
                ConcurrentMap::name(self)
            }
        }
    };
}

kv_for_map!(FixedHashMap);
kv_for_map!(TwoLevelHashMap);
kv_for_map!(SpoHashMap);
kv_for_map!(TwoLevelSpoHashMap);
kv_for_map!(TbbLikeHashMap);

/// Which structure backs each shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreKind {
    DetSkiplistLf,
    DetSkiplistRwl,
    RandomSkiplist,
    HashFixed,
    HashTwoLevel,
    HashSpo,
    HashTwoLevelSpo,
    HashTbbLike,
}

impl StoreKind {
    pub fn parse(s: &str) -> Option<StoreKind> {
        Some(match s {
            "det" | "det-lf" | "lkfreefind" => StoreKind::DetSkiplistLf,
            "det-rwl" | "rwl" => StoreKind::DetSkiplistRwl,
            "random" | "random-skiplist" => StoreKind::RandomSkiplist,
            "fixed" | "binlist" => StoreKind::HashFixed,
            "twolevel" => StoreKind::HashTwoLevel,
            "spo" | "splitorder" => StoreKind::HashSpo,
            "twolevel-spo" | "spo2" => StoreKind::HashTwoLevelSpo,
            "tbb" | "tbb-like" => StoreKind::HashTbbLike,
            _ => return None,
        })
    }

    fn build(self, capacity: usize) -> Box<dyn KvStore> {
        match self {
            StoreKind::DetSkiplistLf => {
                Box::new(DetSkiplist::with_capacity(FindMode::LockFree, capacity))
            }
            StoreKind::DetSkiplistRwl => {
                Box::new(DetSkiplist::with_capacity(FindMode::ReadLocked, capacity))
            }
            StoreKind::RandomSkiplist => Box::new(RandomSkiplist::with_capacity(capacity)),
            StoreKind::HashFixed => Box::new(FixedHashMap::new(1024)),
            StoreKind::HashTwoLevel => Box::new(TwoLevelHashMap::new(1024, 256)),
            StoreKind::HashSpo => {
                Box::new(SpoHashMap::with_config(1024, 16, 1 << 17, capacity))
            }
            StoreKind::HashTwoLevelSpo => {
                Box::new(TwoLevelSpoHashMap::with_config(32, 64, 16, 1 << 14, capacity / 16))
            }
            StoreKind::HashTbbLike => Box::new(TbbLikeHashMap::with_config(1 << 14, 4)),
        }
    }
}

/// The hierarchical store: one structure per shard, shards homed on
/// (virtual) NUMA nodes by eqs (6)-(7).
pub struct ShardedStore {
    shards: Vec<Box<dyn KvStore>>,
    topology: Topology,
    threads: usize,
    pub locality: LocalityStats,
}

impl ShardedStore {
    /// `nshards` structures (paper: 8 = one per Milan NUMA node).
    pub fn new(kind: StoreKind, nshards: usize, capacity_per_shard: usize, topology: Topology, threads: usize) -> ShardedStore {
        assert!(nshards.is_power_of_two() && nshards <= 8);
        ShardedStore {
            shards: (0..nshards).map(|_| kind.build(capacity_per_shard)).collect(),
            topology,
            threads,
            locality: LocalityStats::new(),
        }
    }

    /// Shard of a key: top 3 MSBs folded onto the shard count.
    #[inline]
    pub fn shard_of(&self, key: u64) -> usize {
        ((key >> 61) as usize) % self.shards.len()
    }

    /// Home NUMA node of a shard under the current thread count (eq. 7).
    #[inline]
    pub fn home_node(&self, shard: usize) -> usize {
        self.topology.shard_home(shard, self.threads)
    }

    /// Account locality of an access from `thread_id` to `key`'s shard and
    /// charge the latency model if the access is remote.
    #[inline]
    pub fn account(&self, thread_id: usize, key: u64) {
        let home = self.home_node(self.shard_of(key));
        let from = self.topology.node_of_cpu(thread_id);
        let local = home == from;
        self.locality.record(local);
        if !local {
            LATENCY.charge_remote();
        }
    }

    #[inline]
    pub fn shard(&self, key: u64) -> &dyn KvStore {
        &*self.shards[self.shard_of(key)]
    }

    pub fn insert(&self, key: u64, value: u64) -> bool {
        self.shard(key).insert(key, value)
    }

    pub fn get(&self, key: u64) -> Option<u64> {
        self.shard(key).get(key)
    }

    pub fn erase(&self, key: u64) -> bool {
        self.shard(key).erase(key)
    }

    pub fn len(&self) -> u64 {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn kind_name(&self) -> &'static str {
        self.shards[0].name()
    }

    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_routing_by_msbs() {
        let s = ShardedStore::new(StoreKind::HashFixed, 8, 1 << 10, Topology::milan_virtual(), 128);
        assert_eq!(s.shard_of(0), 0);
        assert_eq!(s.shard_of(u64::MAX), 7);
        assert_eq!(s.shard_of(1 << 61), 1);
        assert_eq!(s.num_shards(), 8);
    }

    #[test]
    fn insert_routes_and_len_aggregates() {
        let s = ShardedStore::new(StoreKind::DetSkiplistLf, 4, 1 << 12, Topology::milan_virtual(), 64);
        for i in 0..100u64 {
            // spread keys across shards via MSBs
            let key = (i % 4) << 61 | i;
            assert!(s.insert(key, i));
        }
        assert_eq!(s.len(), 100);
        for i in 0..100u64 {
            let key = (i % 4) << 61 | i;
            assert_eq!(s.get(key), Some(i));
        }
    }

    #[test]
    fn all_kinds_build_and_work() {
        for kind in [
            StoreKind::DetSkiplistLf,
            StoreKind::DetSkiplistRwl,
            StoreKind::RandomSkiplist,
            StoreKind::HashFixed,
            StoreKind::HashTwoLevel,
            StoreKind::HashSpo,
            StoreKind::HashTwoLevelSpo,
            StoreKind::HashTbbLike,
        ] {
            let s = ShardedStore::new(kind, 2, 1 << 12, Topology::milan_virtual(), 8);
            assert!(s.insert(42, 1), "{kind:?}");
            assert!(!s.insert(42, 2), "{kind:?}");
            assert_eq!(s.get(42), Some(1), "{kind:?}");
            assert!(s.erase(42), "{kind:?}");
            assert_eq!(s.get(42), None, "{kind:?}");
        }
    }

    #[test]
    fn locality_accounting() {
        let s = ShardedStore::new(StoreKind::HashFixed, 8, 1 << 10, Topology::milan_virtual(), 128);
        // thread 0 is on node 0; shard 0's home with 128 threads is node 0
        s.account(0, 0); // local
        // shard 7 homes on node 7; access from thread 0 is remote
        s.account(0, u64::MAX);
        let (l, r) = s.locality.snapshot();
        assert_eq!((l, r), (1, 1));
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(StoreKind::parse("det"), Some(StoreKind::DetSkiplistLf));
        assert_eq!(StoreKind::parse("rwl"), Some(StoreKind::DetSkiplistRwl));
        assert_eq!(StoreKind::parse("spo2"), Some(StoreKind::HashTwoLevelSpo));
        assert_eq!(StoreKind::parse("nope"), None);
    }
}
