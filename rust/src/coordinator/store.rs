//! Sharded store: one data structure per NUMA shard (paper §VI-VIII:
//! "we partitioned the skiplist into one skiplist per NUMA node ... the key
//! space was partitioned across skiplists using 3 MSBs").
//!
//! Besides the point ops ([`KvStore`]), every structure carries the
//! ordered-map capability ([`OrderedKv`]): `range` plus `insert_batch` /
//! `erase_batch`. The skiplists answer ranges natively off their terminal
//! linked list (the paper's §IX advantage); the hash tables fall back to a
//! sorted snapshot of their contents. Because the shard of a key is its 3
//! MSBs, per-shard range results concatenated in key-prefix order are
//! globally sorted *by construction* — no merge heap is needed (see
//! [`ShardedStore::range`]).

use crate::hashtable::{
    ConcurrentMap, FixedHashMap, SpoHashMap, TbbLikeHashMap, TwoLevelHashMap, TwoLevelSpoHashMap,
};
use crate::mem::{ArenaOptions, PoolStats};
use crate::numa::{LocalityStats, Topology, LATENCY};
use crate::skiplist::{DetSkiplist, FindMode, RandomSkiplist, SkiplistStats};

use super::{for_each_prefix_segment, shard_of_key};

/// Unified key-value interface over every structure in the repo.
pub trait KvStore: Send + Sync {
    fn insert(&self, key: u64, value: u64) -> bool;
    fn get(&self, key: u64) -> Option<u64>;
    fn erase(&self, key: u64) -> bool;
    fn len(&self) -> u64;
    fn name(&self) -> &'static str;

    /// Retry-counter snapshot. Structures without retry loops (the locked
    /// hash tables) report all-zero; the skiplists surface their real
    /// counters so the sharded store can aggregate them end-to-end.
    fn stats(&self) -> SkiplistStats {
        SkiplistStats::default()
    }

    /// §V memory-manager snapshot (allocs/recycled/capacity/locality).
    /// All-zero for structures that do not run on the unified arena (the
    /// BST-backed and chained hash tables).
    fn mem_stats(&self) -> PoolStats {
        PoolStats::default()
    }

    /// Toggle the per-thread search-finger cache (Table XII ablation). A
    /// no-op for structures without fingers; the deterministic skiplist
    /// overrides it.
    fn set_finger_cache(&self, _on: bool) {}
}

/// Ordered-map capability layered on [`KvStore`]: range scans and batch
/// mutations. Implemented natively by both skiplists (terminal-list walk)
/// and via sorted snapshot for the hash tables.
pub trait OrderedKv: KvStore {
    /// All `(key, value)` with `lo <= key <= hi`, sorted by key.
    /// `lo > hi` yields an empty result.
    fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)>;

    /// Insert every pair; returns how many were newly inserted (pairs whose
    /// key already existed are skipped, matching `insert`'s set semantics).
    /// The batch is applied in sorted key order: consecutive skiplist
    /// inserts then land in the same or adjacent terminal segments (the
    /// §IX bulk-load locality argument); for hash tables order is neutral.
    fn insert_batch(&self, items: &[(u64, u64)]) -> u64 {
        let mut sorted = items.to_vec();
        sorted.sort_unstable_by_key(|e| e.0);
        sorted.iter().filter(|&&(k, v)| self.insert(k, v)).count() as u64
    }

    /// Erase every key (sorted, like [`OrderedKv::insert_batch`]); returns
    /// how many were present.
    fn erase_batch(&self, keys: &[u64]) -> u64 {
        let mut sorted = keys.to_vec();
        sorted.sort_unstable();
        sorted.iter().filter(|&&k| self.erase(k)).count() as u64
    }
}

impl KvStore for DetSkiplist {
    fn insert(&self, key: u64, value: u64) -> bool {
        DetSkiplist::insert(self, key, value)
    }
    fn get(&self, key: u64) -> Option<u64> {
        DetSkiplist::get(self, key)
    }
    fn erase(&self, key: u64) -> bool {
        DetSkiplist::erase(self, key)
    }
    fn len(&self) -> u64 {
        DetSkiplist::len(self)
    }
    fn name(&self) -> &'static str {
        "det-skiplist"
    }
    fn stats(&self) -> SkiplistStats {
        DetSkiplist::stats(self)
    }
    fn mem_stats(&self) -> PoolStats {
        DetSkiplist::mem_stats(self)
    }
    fn set_finger_cache(&self, on: bool) {
        DetSkiplist::set_finger_cache(self, on)
    }
}

impl OrderedKv for DetSkiplist {
    fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        if lo > hi {
            return Vec::new();
        }
        DetSkiplist::range(self, lo, hi)
    }
}

impl KvStore for RandomSkiplist {
    fn insert(&self, key: u64, value: u64) -> bool {
        RandomSkiplist::insert(self, key, value)
    }
    fn get(&self, key: u64) -> Option<u64> {
        RandomSkiplist::get(self, key)
    }
    fn erase(&self, key: u64) -> bool {
        RandomSkiplist::erase(self, key)
    }
    fn len(&self) -> u64 {
        RandomSkiplist::len(self)
    }
    fn name(&self) -> &'static str {
        "random-skiplist"
    }
    fn stats(&self) -> SkiplistStats {
        // the randomized skiplist keeps one retry counter, incremented on
        // traversal interference — report it on the find side, along with
        // its Table XII cache-path counters
        SkiplistStats {
            find_retries: self.retry_count(),
            node_derefs: self.deref_count(),
            prefetches: self.prefetch_count(),
            ..SkiplistStats::default()
        }
    }
    fn mem_stats(&self) -> PoolStats {
        RandomSkiplist::mem_stats(self)
    }
}

impl OrderedKv for RandomSkiplist {
    fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        RandomSkiplist::range(self, lo, hi)
    }
}

macro_rules! kv_for_map {
    // plain tables: no unified-arena backing, mem_stats stays all-zero
    ($t:ty) => {
        kv_for_map!(@impl $t, |_s: &$t| PoolStats::default());
    };
    // arena-backed tables: surface the structure's §V accounting
    ($t:ty, arena) => {
        kv_for_map!(@impl $t, <$t>::mem_stats);
    };
    (@impl $t:ty, $mem:expr) => {
        impl KvStore for $t {
            fn insert(&self, key: u64, value: u64) -> bool {
                ConcurrentMap::insert(self, key, value)
            }
            fn get(&self, key: u64) -> Option<u64> {
                ConcurrentMap::get(self, key)
            }
            fn erase(&self, key: u64) -> bool {
                ConcurrentMap::erase(self, key)
            }
            fn len(&self) -> u64 {
                ConcurrentMap::len(self)
            }
            fn name(&self) -> &'static str {
                ConcurrentMap::name(self)
            }
            fn mem_stats(&self) -> PoolStats {
                ($mem)(self)
            }
        }

        impl OrderedKv for $t {
            /// Sorted-snapshot fallback: hash tables have no key order, so
            /// a range is a filtered full snapshot, sorted once at the end.
            fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
                if lo > hi {
                    return Vec::new();
                }
                let mut out = Vec::new();
                ConcurrentMap::for_each(self, &mut |k, v| {
                    if (lo..=hi).contains(&k) {
                        out.push((k, v));
                    }
                });
                out.sort_unstable_by_key(|e| e.0);
                out
            }
        }
    };
}

kv_for_map!(FixedHashMap);
kv_for_map!(TwoLevelHashMap);
kv_for_map!(SpoHashMap, arena);
kv_for_map!(TwoLevelSpoHashMap, arena);
kv_for_map!(TbbLikeHashMap);

/// Which structure backs each shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreKind {
    DetSkiplistLf,
    DetSkiplistRwl,
    RandomSkiplist,
    HashFixed,
    HashTwoLevel,
    HashSpo,
    HashTwoLevelSpo,
    HashTbbLike,
}

impl StoreKind {
    pub fn parse(s: &str) -> Option<StoreKind> {
        Some(match s {
            "det" | "det-lf" | "lkfreefind" => StoreKind::DetSkiplistLf,
            "det-rwl" | "rwl" => StoreKind::DetSkiplistRwl,
            "random" | "random-skiplist" => StoreKind::RandomSkiplist,
            "fixed" | "binlist" => StoreKind::HashFixed,
            "twolevel" => StoreKind::HashTwoLevel,
            "spo" | "splitorder" => StoreKind::HashSpo,
            "twolevel-spo" | "spo2" => StoreKind::HashTwoLevelSpo,
            "tbb" | "tbb-like" => StoreKind::HashTbbLike,
            _ => return None,
        })
    }

    /// Build one shard's structure. Public so tests and tools can exercise
    /// every [`OrderedKv`] implementation behind one constructor.
    pub fn build(self, capacity: usize) -> Box<dyn OrderedKv> {
        self.build_placed(capacity, ArenaOptions::default())
    }

    /// Like [`StoreKind::build`] with explicit arena options: the sharded
    /// store homes each shard's arena(s) on the shard's NUMA node (eq. 7),
    /// so the §V memory managers are placed — and locality-accounted —
    /// per shard. Structures without arenas ignore the options.
    pub fn build_placed(self, capacity: usize, opts: ArenaOptions) -> Box<dyn OrderedKv> {
        match self {
            StoreKind::DetSkiplistLf => {
                Box::new(DetSkiplist::with_capacity_on(FindMode::LockFree, capacity, opts))
            }
            StoreKind::DetSkiplistRwl => {
                Box::new(DetSkiplist::with_capacity_on(FindMode::ReadLocked, capacity, opts))
            }
            StoreKind::RandomSkiplist => Box::new(RandomSkiplist::with_capacity_on(capacity, opts)),
            StoreKind::HashFixed => Box::new(FixedHashMap::new(1024)),
            StoreKind::HashTwoLevel => Box::new(TwoLevelHashMap::new(1024, 256)),
            StoreKind::HashSpo => {
                Box::new(SpoHashMap::with_config_on(1024, 16, 1 << 17, capacity, opts))
            }
            StoreKind::HashTwoLevelSpo => {
                Box::new(TwoLevelSpoHashMap::with_config_on(32, 64, 16, 1 << 14, capacity / 16, opts))
            }
            StoreKind::HashTbbLike => Box::new(TbbLikeHashMap::with_config(1 << 14, 4)),
        }
    }
}

/// Number of key-space prefixes (the paper's 3 MSBs → 8 segments; the
/// per-segment clamp arithmetic lives in [`for_each_prefix_segment`]).
const PREFIXES: u64 = 8;

/// The hierarchical store: one structure per shard, shards homed on
/// (virtual) NUMA nodes by eqs (6)-(7).
pub struct ShardedStore {
    shards: Vec<Box<dyn OrderedKv>>,
    topology: Topology,
    threads: usize,
    pub locality: LocalityStats,
}

impl ShardedStore {
    /// `nshards` structures (paper: 8 = one per Milan NUMA node); each
    /// shard's arena is homed on its eq.-7 NUMA node.
    pub fn new(kind: StoreKind, nshards: usize, capacity_per_shard: usize, topology: Topology, threads: usize) -> ShardedStore {
        assert!(nshards.is_power_of_two() && nshards as u64 <= PREFIXES);
        ShardedStore {
            shards: (0..nshards)
                .map(|i| {
                    let home = topology.shard_home(i, threads);
                    kind.build_placed(capacity_per_shard, ArenaOptions::placed(home, &topology, threads))
                })
                .collect(),
            topology,
            threads,
            locality: LocalityStats::new(),
        }
    }

    /// Shard of a key: top 3 MSBs folded onto the shard count (the shared
    /// [`shard_of_key`] helper, so the store, the word router and the
    /// delegation fabric can never disagree on routing).
    #[inline]
    pub fn shard_of(&self, key: u64) -> usize {
        shard_of_key(key, self.shards.len())
    }

    /// Home NUMA node of a shard under the current thread count (eq. 7).
    #[inline]
    pub fn home_node(&self, shard: usize) -> usize {
        self.topology.shard_home(shard, self.threads)
    }

    /// Account locality of an access from `thread_id` to `key`'s shard and
    /// charge the latency model if the access is remote.
    #[inline]
    pub fn account(&self, thread_id: usize, key: u64) {
        self.account_shard(thread_id, self.shard_of(key));
    }

    /// Account one shard dereference from `thread_id` (the delegation
    /// fabric's per-envelope accounting) and charge the latency model if
    /// the access crosses NUMA nodes.
    #[inline]
    pub fn account_shard(&self, thread_id: usize, shard: usize) {
        let home = self.home_node(shard);
        let from = self.topology.node_of_cpu(thread_id);
        let local = home == from;
        self.locality.record(local);
        if !local {
            LATENCY.charge_remote();
        }
    }

    /// Account every shard a `[lo, hi]` range scan dereferences — one touch
    /// per intersecting 3-MSB prefix, mirroring the per-prefix queries
    /// [`ShardedStore::range`] issues. Direct-mode workers use this: a
    /// cross-shard window makes them reach into remote shards, which is
    /// exactly the access pattern the Delegated mode eliminates.
    pub fn account_range(&self, thread_id: usize, lo: u64, hi: u64) {
        for_each_prefix_segment(lo, hi, |slo, _| {
            self.account_shard(thread_id, shard_of_key(slo, self.shards.len()));
        });
    }

    #[inline]
    pub fn shard(&self, key: u64) -> &dyn OrderedKv {
        &*self.shards[self.shard_of(key)]
    }

    /// Direct access to shard `idx` (bulk-load workers drain one per-shard
    /// queue each through this).
    #[inline]
    pub fn shard_at(&self, idx: usize) -> &dyn OrderedKv {
        &*self.shards[idx]
    }

    pub fn insert(&self, key: u64, value: u64) -> bool {
        self.shard(key).insert(key, value)
    }

    pub fn get(&self, key: u64) -> Option<u64> {
        self.shard(key).get(key)
    }

    pub fn erase(&self, key: u64) -> bool {
        self.shard(key).erase(key)
    }

    /// Cross-shard range scan. The key space is split into 8 prefix
    /// segments by the 3 MSBs; for every prefix intersecting `[lo, hi]` the
    /// owning shard is queried with the prefix-clamped sub-range, and the
    /// per-prefix results are concatenated in prefix order. Prefix order is
    /// key order (the partition preserves global order), so the
    /// concatenation is globally sorted and duplicate-free by construction
    /// — no merge heap. This also holds when `nshards < 8` and several
    /// prefixes fold onto one shard: each fold is queried only for its own
    /// clamped sub-range, still in ascending prefix order. (Trade-off: a
    /// folded hash-table shard re-snapshots once per intersecting prefix —
    /// acceptable because the paper's configuration is 8 shards, where
    /// every prefix maps to a distinct shard and no fold exists.)
    pub fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        for_each_prefix_segment(lo, hi, |slo, shi| {
            out.extend(self.shards[shard_of_key(slo, self.shards.len())].range(slo, shi));
        });
        out
    }

    /// Batch insert: partition the batch into per-shard groups (the "fill
    /// the queues first" step of the paper's methodology), then drain each
    /// group through its shard's native batch path. Returns the number of
    /// pairs newly inserted.
    pub fn insert_batch(&self, items: &[(u64, u64)]) -> u64 {
        let mut per: Vec<Vec<(u64, u64)>> =
            (0..self.shards.len()).map(|_| Vec::new()).collect();
        for &(k, v) in items {
            per[self.shard_of(k)].push((k, v));
        }
        let mut n = 0;
        for (s, batch) in per.into_iter().enumerate() {
            if !batch.is_empty() {
                n += self.shards[s].insert_batch(&batch);
            }
        }
        n
    }

    /// Batch erase, routed per shard like [`ShardedStore::insert_batch`].
    /// Returns how many keys were present.
    pub fn erase_batch(&self, keys: &[u64]) -> u64 {
        let mut per: Vec<Vec<u64>> = (0..self.shards.len()).map(|_| Vec::new()).collect();
        for &k in keys {
            per[self.shard_of(k)].push(k);
        }
        let mut n = 0;
        for (s, batch) in per.into_iter().enumerate() {
            if !batch.is_empty() {
                n += self.shards[s].erase_batch(&batch);
            }
        }
        n
    }

    /// Toggle every shard's search-finger cache (Table XII runs the same
    /// workload with and without fingers; no-op for non-skiplist kinds).
    pub fn set_finger_cache(&self, on: bool) {
        for s in &self.shards {
            s.set_finger_cache(on);
        }
    }

    /// Retry counters summed across every shard (observability: workloads
    /// report e.g. `find_retries` without `write_retries` inflation).
    pub fn stats(&self) -> SkiplistStats {
        let mut out = SkiplistStats::default();
        for s in &self.shards {
            out.merge(&s.stats());
        }
        out
    }

    /// §V memory accounting summed across every shard's arena(s) — the
    /// allocs/recycled/capacity/locality-hit-rate view the engine reports.
    pub fn mem_stats(&self) -> PoolStats {
        let mut out = PoolStats::default();
        for s in &self.shards {
            out.merge(&s.mem_stats());
        }
        out
    }

    pub fn len(&self) -> u64 {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn kind_name(&self) -> &'static str {
        self.shards[0].name()
    }

    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_KINDS: [StoreKind; 8] = [
        StoreKind::DetSkiplistLf,
        StoreKind::DetSkiplistRwl,
        StoreKind::RandomSkiplist,
        StoreKind::HashFixed,
        StoreKind::HashTwoLevel,
        StoreKind::HashSpo,
        StoreKind::HashTwoLevelSpo,
        StoreKind::HashTbbLike,
    ];

    #[test]
    fn shard_routing_by_msbs() {
        let s = ShardedStore::new(StoreKind::HashFixed, 8, 1 << 10, Topology::milan_virtual(), 128);
        assert_eq!(s.shard_of(0), 0);
        assert_eq!(s.shard_of(u64::MAX), 7);
        assert_eq!(s.shard_of(1 << 61), 1);
        assert_eq!(s.num_shards(), 8);
    }

    #[test]
    fn shard_of_matches_shared_helper_for_all_folds() {
        // Satellite cross-check: store routing and the shared helper (used
        // by the word router and the delegation fabric) must agree on every
        // folded-prefix configuration, so a key delegated to an owner lands
        // on the same shard the store itself would pick.
        for nshards in [1usize, 2, 4, 8] {
            let s = ShardedStore::new(
                StoreKind::HashFixed,
                nshards,
                1 << 10,
                Topology::milan_virtual(),
                8,
            );
            for p in 0..8u64 {
                for low in [0u64, 1, 0xFFFF, (1 << 59) - 1, (1 << 61) - 1] {
                    let key = p << 61 | low;
                    assert_eq!(
                        s.shard_of(key),
                        shard_of_key(key, nshards),
                        "nshards={nshards} key={key:#x}"
                    );
                    assert_eq!(
                        s.shard_of(key),
                        (p as usize) % nshards,
                        "folded prefix must be prefix mod nshards"
                    );
                }
            }
        }
    }

    #[test]
    fn insert_routes_and_len_aggregates() {
        let s = ShardedStore::new(StoreKind::DetSkiplistLf, 4, 1 << 12, Topology::milan_virtual(), 64);
        for i in 0..100u64 {
            // spread keys across shards via MSBs
            let key = (i % 4) << 61 | i;
            assert!(s.insert(key, i));
        }
        assert_eq!(s.len(), 100);
        for i in 0..100u64 {
            let key = (i % 4) << 61 | i;
            assert_eq!(s.get(key), Some(i));
        }
    }

    #[test]
    fn all_kinds_build_and_work() {
        for kind in ALL_KINDS {
            let s = ShardedStore::new(kind, 2, 1 << 12, Topology::milan_virtual(), 8);
            assert!(s.insert(42, 1), "{kind:?}");
            assert!(!s.insert(42, 2), "{kind:?}");
            assert_eq!(s.get(42), Some(1), "{kind:?}");
            assert!(s.erase(42), "{kind:?}");
            assert_eq!(s.get(42), None, "{kind:?}");
        }
    }

    #[test]
    fn cross_shard_range_is_globally_sorted() {
        for kind in ALL_KINDS {
            let s = ShardedStore::new(kind, 8, 1 << 12, Topology::milan_virtual(), 8);
            // 40 keys per prefix, all 8 prefixes
            let mut want = Vec::new();
            for p in 0..8u64 {
                for i in 0..40u64 {
                    let k = p << 61 | i * 7;
                    assert!(s.insert(k, k ^ 1), "{kind:?}");
                    want.push((k, k ^ 1));
                }
            }
            want.sort_unstable_by_key(|e| e.0);
            let got = s.range(0, u64::MAX - 2);
            assert_eq!(got, want, "{kind:?}: full cross-shard scan");
            // clamped scan spanning prefixes 2..=5
            let lo = 2u64 << 61;
            let hi = (5u64 << 61) | 100;
            let got = s.range(lo, hi);
            let wantw: Vec<(u64, u64)> =
                want.iter().copied().filter(|&(k, _)| k >= lo && k <= hi).collect();
            assert_eq!(got, wantw, "{kind:?}: prefix-clamped scan");
            assert_eq!(s.range(10, 5), vec![], "{kind:?}: inverted bounds");
        }
    }

    #[test]
    fn folded_prefixes_still_sort_globally() {
        // nshards = 2: prefixes 0,2,4,6 fold onto shard 0 and 1,3,5,7 onto
        // shard 1, so shard-local key sets interleave in global key order.
        // The per-prefix clamped queries must still produce a sorted scan.
        let s = ShardedStore::new(StoreKind::DetSkiplistLf, 2, 1 << 12, Topology::milan_virtual(), 4);
        let mut want = Vec::new();
        for p in 0..8u64 {
            for i in 0..25u64 {
                let k = p << 61 | i;
                assert!(s.insert(k, p));
                want.push((k, p));
            }
        }
        want.sort_unstable_by_key(|e| e.0);
        assert_eq!(s.range(0, u64::MAX - 2), want);
        // a window inside a single folded prefix
        let lo = 4u64 << 61;
        let got = s.range(lo, lo + 10);
        assert_eq!(got.len(), 11);
        assert!(got.iter().all(|&(k, v)| k >> 61 == 4 && v == 4));
    }

    #[test]
    fn batch_ops_route_across_shards() {
        for kind in ALL_KINDS {
            let s = ShardedStore::new(kind, 4, 1 << 12, Topology::milan_virtual(), 8);
            let items: Vec<(u64, u64)> =
                (0..200u64).map(|i| ((i % 8) << 61 | i, i + 1)).collect();
            assert_eq!(s.insert_batch(&items), 200, "{kind:?}");
            assert_eq!(s.insert_batch(&items), 0, "{kind:?}: duplicates");
            assert_eq!(s.len(), 200, "{kind:?}");
            for &(k, v) in &items {
                assert_eq!(s.get(k), Some(v), "{kind:?} key {k}");
            }
            let odd_keys: Vec<u64> =
                items.iter().map(|&(k, _)| k).filter(|&k| k & 1 == 1).collect();
            assert_eq!(s.erase_batch(&odd_keys), odd_keys.len() as u64, "{kind:?}");
            assert_eq!(s.erase_batch(&odd_keys), 0, "{kind:?}");
            assert_eq!(s.len(), 200 - odd_keys.len() as u64, "{kind:?}");
        }
    }

    #[test]
    fn stats_sum_across_shards() {
        let s = ShardedStore::new(StoreKind::DetSkiplistLf, 4, 1 << 14, Topology::milan_virtual(), 8);
        let items: Vec<(u64, u64)> = (0..2_000u64).map(|i| ((i % 4) << 61 | i, i)).collect();
        s.insert_batch(&items);
        let st = s.stats();
        assert!(st.splits > 0, "bulk load must split across shards");
        assert!(st.depth_increases > 0, "per-shard height growth must aggregate");
        // a pure-read phase must not move the write-side counters
        let before = s.stats();
        for i in 0..200u64 {
            let lo = (i % 4) << 61 | i;
            let _ = s.range(lo, lo + 32);
            let _ = s.get(lo);
        }
        let after = s.stats();
        assert_eq!(after.write_retries, before.write_retries, "reads must not inflate write retries");
        assert_eq!(after.splits, before.splits);
    }

    #[test]
    fn mem_stats_aggregate_across_shards_for_arena_kinds() {
        // reset: the test-runner thread may have been pinned by another test
        crate::mem::note_thread_cpu(usize::MAX);
        for kind in [StoreKind::DetSkiplistLf, StoreKind::RandomSkiplist, StoreKind::HashSpo, StoreKind::HashTwoLevelSpo] {
            let s = ShardedStore::new(kind, 4, 1 << 12, Topology::milan_virtual(), 8);
            for i in 0..400u64 {
                let key = (i % 4) << 61 | i;
                assert!(s.insert(key, i), "{kind:?}");
            }
            for i in 0..400u64 {
                let key = (i % 4) << 61 | i;
                assert!(s.erase(key), "{kind:?}");
            }
            let st = s.mem_stats();
            assert!(st.allocs >= 400, "{kind:?}: allocs {}", st.allocs);
            assert_eq!(st.retired, st.recycled + st.free_residue + st.overflow, "{kind:?}: lost nodes");
            assert!(st.arenas >= 4, "{kind:?}: one arena per shard at least");
            assert!(st.capacity > 0, "{kind:?}");
            // unpinned test thread counts as local on every home node
            assert_eq!(st.remote_allocs, 0, "{kind:?}");
        }
        // structures without arenas report all-zero
        let s = ShardedStore::new(StoreKind::HashFixed, 2, 1 << 10, Topology::milan_virtual(), 8);
        s.insert(1, 1);
        assert_eq!(s.mem_stats().allocs, 0);
    }

    #[test]
    fn locality_accounting() {
        let s = ShardedStore::new(StoreKind::HashFixed, 8, 1 << 10, Topology::milan_virtual(), 128);
        // thread 0 is on node 0; shard 0's home with 128 threads is node 0
        s.account(0, 0); // local
        // shard 7 homes on node 7; access from thread 0 is remote
        s.account(0, u64::MAX);
        let (l, r) = s.locality.snapshot();
        assert_eq!((l, r), (1, 1));
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(StoreKind::parse("det"), Some(StoreKind::DetSkiplistLf));
        assert_eq!(StoreKind::parse("rwl"), Some(StoreKind::DetSkiplistRwl));
        assert_eq!(StoreKind::parse("spo2"), Some(StoreKind::HashTwoLevelSpo));
        assert_eq!(StoreKind::parse("nope"), None);
    }
}
