//! Sharded store: one data structure per NUMA shard (paper §VI-VIII:
//! "we partitioned the skiplist into one skiplist per NUMA node ... the key
//! space was partitioned across skiplists using 3 MSBs").
//!
//! Besides the point ops ([`KvStore`]), every structure carries the
//! ordered-map capability ([`OrderedKv`]): `range` plus `insert_batch` /
//! `erase_batch`. The skiplists answer ranges natively off their terminal
//! linked list (the paper's §IX advantage); the hash tables fall back to a
//! sorted snapshot of their contents. Because the shard of a key is its 3
//! MSBs, per-shard range results concatenated in key-prefix order are
//! globally sorted *by construction* — no merge heap is needed (see
//! [`ShardedStore::range`]).

use std::sync::atomic::{AtomicBool, Ordering};

use crate::hashtable::{
    ConcurrentMap, FixedHashMap, SpoHashMap, TbbLikeHashMap, TwoLevelHashMap, TwoLevelSpoHashMap,
};
use crate::mem::{ArenaOptions, PoolStats};
use crate::numa::{LocalityStats, Topology, LATENCY};
use crate::skiplist::{
    is_sorted_run, BatchOp, BatchReply, DetSkiplist, FindMode, RandomSkiplist, ReplicaStats,
    SkiplistStats,
};

use super::{for_each_prefix_segment, shard_of_key};

/// `true` when `items` is already ascending by key — the fast path that
/// lets batch callers with pre-sorted runs skip the clone + re-sort.
#[inline]
pub fn pairs_sorted(items: &[(u64, u64)]) -> bool {
    items.windows(2).all(|w| w[0].0 <= w[1].0)
}

/// `true` when `keys` is already ascending.
#[inline]
pub fn keys_sorted(keys: &[u64]) -> bool {
    keys.windows(2).all(|w| w[0] <= w[1])
}

/// Default interleave width for scattered batches: wide enough to keep
/// several dependent-miss chains in flight, narrow enough that the lane
/// state (one carry + cursor each) stays cache-resident. Callers that know
/// their batch shape (the delegation fabric's adaptive combiner) pick their
/// own width; everything else uses this.
pub const DEFAULT_INTERLEAVE: usize = 8;

/// Flat default for [`KvStore::cluster_gap`]: the clustered-run threshold
/// of a structure with single-key terminals (or no terminal locality at
/// all). Fat-leaf skiplists override the method with a leaf-relative value.
pub const FLAT_CLUSTER_GAP: u64 = 64;

/// Unified key-value interface over every structure in the repo.
pub trait KvStore: Send + Sync {
    fn insert(&self, key: u64, value: u64) -> bool;
    fn get(&self, key: u64) -> Option<u64>;
    fn erase(&self, key: u64) -> bool;
    fn len(&self) -> u64;
    fn name(&self) -> &'static str;

    /// Retry-counter snapshot. Structures without retry loops (the locked
    /// hash tables) report all-zero; the skiplists surface their real
    /// counters so the sharded store can aggregate them end-to-end.
    fn stats(&self) -> SkiplistStats {
        SkiplistStats::default()
    }

    /// §V memory-manager snapshot (allocs/recycled/capacity/locality).
    /// All-zero for structures that do not run on the unified arena (the
    /// BST-backed and chained hash tables).
    fn mem_stats(&self) -> PoolStats {
        PoolStats::default()
    }

    /// Toggle the per-thread search-finger cache (Table XII ablation). A
    /// no-op for structures without fingers; the deterministic skiplist
    /// overrides it.
    fn set_finger_cache(&self, _on: bool) {}

    /// Key-distance threshold below which a sorted run counts as
    /// *clustered* for the combiner's fuse-vs-interleave dispatch: a run
    /// whose median inter-key gap is under this value shares terminal
    /// locality, so the fused single-walk path wins; above it the
    /// interleaved MLP engine wins. Leaf-structured stores scale it with
    /// their terminal width (a fat-leaf chunk of K keys makes runs with
    /// gaps up to ~K× larger still land in shared chunks); the flat
    /// default matches the single-key-terminal behaviour.
    fn cluster_gap(&self) -> u64 {
        FLAT_CLUSTER_GAP
    }

    /// Build NUMA-local index replicas (`ExecMode::Replicated`). A no-op
    /// for structures without a replicable index plane (hash tables answer
    /// point ops in O(1) from their own shard already); the deterministic
    /// skiplist overrides the whole family below.
    fn enable_replicas(&self, _topo: &Topology, _threads: usize) {}

    fn replicas_enabled(&self) -> bool {
        false
    }

    /// Point lookup preferring the calling thread's node-local replica.
    /// Returns `(answer, fell_back)`; the default simply answers from the
    /// primary and reports a fallback, so replication-unaware structures
    /// stay correct (and honestly accounted) under `ExecMode::Replicated`.
    fn get_replicated(&self, key: u64) -> (Option<u64>, bool) {
        (self.get(key), true)
    }

    /// One replica maintenance step for the calling thread's node-local
    /// replica; `true` = clean afterwards (trivially so without replicas).
    fn replica_tick(&self) -> bool {
        true
    }

    /// Force-rebuild every replica (tests / quiescent resync).
    fn replica_rebuild(&self) {}

    /// Merged replica-plane counters (all-zero without replicas).
    fn replica_stats(&self) -> ReplicaStats {
        ReplicaStats::default()
    }
}

/// Ordered-map capability layered on [`KvStore`]: range scans and batch
/// mutations. Implemented natively by both skiplists (terminal-list walk
/// and fused sorted-run descents) and via sorted snapshot / per-key loops
/// for the hash tables.
pub trait OrderedKv: KvStore {
    /// All `(key, value)` with `lo <= key <= hi`, sorted by key.
    /// `lo > hi` yields an empty result.
    fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)>;

    /// Range scan seeded by the calling thread's node-local replica.
    /// Returns `(rows, fell_back)`; defaults to the primary walk.
    fn range_replicated(&self, lo: u64, hi: u64) -> (Vec<(u64, u64)>, bool) {
        (self.range(lo, hi), true)
    }

    /// Apply a key-sorted run of mixed operations, calling `sink(idx,
    /// reply)` exactly once per op in run order. Semantically identical to
    /// the per-key loop over the run (which is the default implementation —
    /// the hash tables have no key order to exploit); both skiplists
    /// override it with a fused descent that amortizes one walk across a
    /// whole group of nearby keys. The sink may be invoked while the
    /// structure holds internal locks: it must not call back into the
    /// structure (counters/aggregation only).
    fn apply_sorted_run(&self, ops: &[BatchOp], sink: &mut dyn FnMut(usize, BatchReply)) {
        debug_assert!(is_sorted_run(ops), "run must be key-sorted");
        for (i, op) in ops.iter().enumerate() {
            let r = match *op {
                BatchOp::Insert(k, v) => BatchReply::Applied(self.insert(k, v)),
                BatchOp::Erase(k) => BatchReply::Applied(self.erase(k)),
                BatchOp::Get(k) => BatchReply::Value(self.get(k)),
            };
            sink(i, r);
        }
    }

    /// Apply a key-sorted run with up to `width` independent descents
    /// advanced round-robin so their dependent-miss chains overlap (the
    /// MLP path for *scattered* runs — fused descents already cover
    /// clustered ones). Same contract as [`OrderedKv::apply_sorted_run`]:
    /// `sink(idx, reply)` fires exactly once per op, in run order per
    /// lane. Hash tables have no pointer chase to pipeline, so the
    /// default simply delegates to the fused/per-key path; both
    /// skiplists override it with their interleaved engines.
    fn apply_interleaved(
        &self,
        ops: &[BatchOp],
        _width: usize,
        sink: &mut dyn FnMut(usize, BatchReply),
    ) {
        self.apply_sorted_run(ops, sink);
    }

    /// Insert every pair; returns how many were newly inserted (pairs whose
    /// key already existed are skipped, matching `insert`'s set semantics).
    /// The batch is applied in sorted key order: consecutive skiplist
    /// inserts then land in the same or adjacent terminal segments (the
    /// §IX bulk-load locality argument); for hash tables order is neutral.
    /// Pre-sorted input takes a zero-copy fast path; unsorted input pays
    /// one clone + sort.
    fn insert_batch(&self, items: &[(u64, u64)]) -> u64 {
        if pairs_sorted(items) {
            return items.iter().filter(|&&(k, v)| self.insert(k, v)).count() as u64;
        }
        let mut sorted = items.to_vec();
        sorted.sort_unstable_by_key(|e| e.0);
        sorted.iter().filter(|&&(k, v)| self.insert(k, v)).count() as u64
    }

    /// Erase every key (sorted, like [`OrderedKv::insert_batch`]); returns
    /// how many were present.
    fn erase_batch(&self, keys: &[u64]) -> u64 {
        if keys_sorted(keys) {
            return keys.iter().filter(|&&k| self.erase(k)).count() as u64;
        }
        let mut sorted = keys.to_vec();
        sorted.sort_unstable();
        sorted.iter().filter(|&&k| self.erase(k)).count() as u64
    }

    /// Look every key up; returns the values in **input order**.
    fn get_batch(&self, keys: &[u64]) -> Vec<Option<u64>> {
        keys.iter().map(|&k| self.get(k)).collect()
    }
}

// ---------------------------------------------------------------------------
// Fused-batch plumbing shared by the skiplist OrderedKv impls: build the
// sorted run (skipping the sort when the input is pre-sorted), apply it
// through the structure's fused descent, fold the replies.
// ---------------------------------------------------------------------------

fn run_insert_batch(
    items: &[(u64, u64)],
    apply: &mut dyn FnMut(&[BatchOp], &mut dyn FnMut(usize, BatchReply)),
) -> u64 {
    let mut run: Vec<BatchOp> = items.iter().map(|&(k, v)| BatchOp::Insert(k, v)).collect();
    if !is_sorted_run(&run) {
        // stable: duplicate input keys keep their order (first wins)
        run.sort_by_key(|o| o.key());
    }
    let mut n = 0u64;
    apply(&run, &mut |_, r| {
        if r == BatchReply::Applied(true) {
            n += 1;
        }
    });
    n
}

fn run_erase_batch(
    keys: &[u64],
    apply: &mut dyn FnMut(&[BatchOp], &mut dyn FnMut(usize, BatchReply)),
) -> u64 {
    let mut run: Vec<BatchOp> = keys.iter().map(|&k| BatchOp::Erase(k)).collect();
    if !is_sorted_run(&run) {
        run.sort_by_key(|o| o.key());
    }
    let mut n = 0u64;
    apply(&run, &mut |_, r| {
        if r == BatchReply::Applied(true) {
            n += 1;
        }
    });
    n
}

/// Sorted input means the caller's keys are genuinely clustered in key
/// space — the fused descent's shared-walk amortization wins. Unsorted
/// input is the scattered case: sorting it groups shard/segment locality
/// but leaves the per-group descents independent, which is exactly what
/// the interleaved engine pipelines (satellite fix: the old path fed both
/// shapes to the fused walk, paying a full dependent-miss chain per
/// scattered group).
fn run_get_batch(
    keys: &[u64],
    fused: &mut dyn FnMut(&[BatchOp], &mut dyn FnMut(usize, BatchReply)),
    interleaved: &mut dyn FnMut(&[BatchOp], &mut dyn FnMut(usize, BatchReply)),
) -> Vec<Option<u64>> {
    let mut out = vec![None; keys.len()];
    if keys_sorted(keys) {
        let run: Vec<BatchOp> = keys.iter().map(|&k| BatchOp::Get(k)).collect();
        fused(&run, &mut |i, r| {
            if let BatchReply::Value(v) = r {
                out[i] = v;
            }
        });
    } else {
        // order-restoring permutation over the sorted view
        let mut order: Vec<u32> = (0..keys.len() as u32).collect();
        order.sort_by_key(|&i| keys[i as usize]);
        let run: Vec<BatchOp> = order.iter().map(|&i| BatchOp::Get(keys[i as usize])).collect();
        interleaved(&run, &mut |i, r| {
            if let BatchReply::Value(v) = r {
                out[order[i] as usize] = v;
            }
        });
    }
    out
}

impl KvStore for DetSkiplist {
    fn insert(&self, key: u64, value: u64) -> bool {
        DetSkiplist::insert(self, key, value)
    }
    fn get(&self, key: u64) -> Option<u64> {
        DetSkiplist::get(self, key)
    }
    fn erase(&self, key: u64) -> bool {
        DetSkiplist::erase(self, key)
    }
    fn len(&self) -> u64 {
        DetSkiplist::len(self)
    }
    fn name(&self) -> &'static str {
        "det-skiplist"
    }
    fn stats(&self) -> SkiplistStats {
        DetSkiplist::stats(self)
    }
    fn mem_stats(&self) -> PoolStats {
        DetSkiplist::mem_stats(self)
    }
    fn set_finger_cache(&self, on: bool) {
        DetSkiplist::set_finger_cache(self, on)
    }
    fn cluster_gap(&self) -> u64 {
        // A chunk holds up to `leaf_cap` keys contiguously, and a fat inner
        // node covers up to `inner_cap` chunks per block probe: runs whose
        // keys land within one routing block's terminal span still amortize
        // one descent, so the clustered threshold scales with both widths
        // (the legacy few-chunks factor of 4 is the floor when routing
        // blocks are narrow or disabled).
        DetSkiplist::leaf_cap(self) as u64 * DetSkiplist::inner_cap(self).max(4) as u64
    }
    fn enable_replicas(&self, topo: &Topology, threads: usize) {
        DetSkiplist::enable_replicas(self, topo, threads)
    }
    fn replicas_enabled(&self) -> bool {
        DetSkiplist::replicas_enabled(self)
    }
    fn get_replicated(&self, key: u64) -> (Option<u64>, bool) {
        DetSkiplist::get_replicated(self, key)
    }
    fn replica_tick(&self) -> bool {
        DetSkiplist::replica_tick(self)
    }
    fn replica_rebuild(&self) {
        DetSkiplist::replica_rebuild_all(self)
    }
    fn replica_stats(&self) -> ReplicaStats {
        DetSkiplist::replica_stats(self)
    }
}

impl OrderedKv for DetSkiplist {
    fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        if lo > hi {
            return Vec::new();
        }
        DetSkiplist::range(self, lo, hi)
    }

    fn range_replicated(&self, lo: u64, hi: u64) -> (Vec<(u64, u64)>, bool) {
        if lo > hi {
            return (Vec::new(), false);
        }
        DetSkiplist::range_replicated(self, lo, hi)
    }

    fn apply_sorted_run(&self, ops: &[BatchOp], sink: &mut dyn FnMut(usize, BatchReply)) {
        DetSkiplist::apply_sorted_run(self, ops, sink)
    }

    fn apply_interleaved(
        &self,
        ops: &[BatchOp],
        width: usize,
        sink: &mut dyn FnMut(usize, BatchReply),
    ) {
        DetSkiplist::apply_interleaved(self, ops, width, sink)
    }

    fn insert_batch(&self, items: &[(u64, u64)]) -> u64 {
        run_insert_batch(items, &mut |ops, sink| DetSkiplist::apply_sorted_run(self, ops, sink))
    }

    fn erase_batch(&self, keys: &[u64]) -> u64 {
        run_erase_batch(keys, &mut |ops, sink| DetSkiplist::apply_sorted_run(self, ops, sink))
    }

    fn get_batch(&self, keys: &[u64]) -> Vec<Option<u64>> {
        run_get_batch(
            keys,
            &mut |ops, sink| DetSkiplist::apply_sorted_run(self, ops, sink),
            &mut |ops, sink| {
                DetSkiplist::apply_interleaved(self, ops, DEFAULT_INTERLEAVE, sink)
            },
        )
    }
}

impl KvStore for RandomSkiplist {
    fn insert(&self, key: u64, value: u64) -> bool {
        RandomSkiplist::insert(self, key, value)
    }
    fn get(&self, key: u64) -> Option<u64> {
        RandomSkiplist::get(self, key)
    }
    fn erase(&self, key: u64) -> bool {
        RandomSkiplist::erase(self, key)
    }
    fn len(&self) -> u64 {
        RandomSkiplist::len(self)
    }
    fn name(&self) -> &'static str {
        "random-skiplist"
    }
    fn stats(&self) -> SkiplistStats {
        // the randomized skiplist keeps one retry counter, incremented on
        // traversal interference — report it on the find side, along with
        // its Table XII cache-path counters
        SkiplistStats {
            find_retries: self.retry_count(),
            node_derefs: self.deref_count(),
            prefetches: self.prefetch_count(),
            ..SkiplistStats::default()
        }
    }
    fn mem_stats(&self) -> PoolStats {
        RandomSkiplist::mem_stats(self)
    }
}

impl OrderedKv for RandomSkiplist {
    fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        RandomSkiplist::range(self, lo, hi)
    }

    fn apply_sorted_run(&self, ops: &[BatchOp], sink: &mut dyn FnMut(usize, BatchReply)) {
        RandomSkiplist::apply_sorted_run(self, ops, sink)
    }

    fn apply_interleaved(
        &self,
        ops: &[BatchOp],
        width: usize,
        sink: &mut dyn FnMut(usize, BatchReply),
    ) {
        RandomSkiplist::apply_interleaved(self, ops, width, sink)
    }

    fn insert_batch(&self, items: &[(u64, u64)]) -> u64 {
        run_insert_batch(items, &mut |ops, sink| RandomSkiplist::apply_sorted_run(self, ops, sink))
    }

    fn erase_batch(&self, keys: &[u64]) -> u64 {
        run_erase_batch(keys, &mut |ops, sink| RandomSkiplist::apply_sorted_run(self, ops, sink))
    }

    fn get_batch(&self, keys: &[u64]) -> Vec<Option<u64>> {
        run_get_batch(
            keys,
            &mut |ops, sink| RandomSkiplist::apply_sorted_run(self, ops, sink),
            &mut |ops, sink| {
                RandomSkiplist::apply_interleaved(self, ops, DEFAULT_INTERLEAVE, sink)
            },
        )
    }
}

macro_rules! kv_for_map {
    // plain tables: no unified-arena backing, mem_stats stays all-zero
    ($t:ty) => {
        kv_for_map!(@impl $t, |_s: &$t| PoolStats::default());
    };
    // arena-backed tables: surface the structure's §V accounting
    ($t:ty, arena) => {
        kv_for_map!(@impl $t, <$t>::mem_stats);
    };
    (@impl $t:ty, $mem:expr) => {
        impl KvStore for $t {
            fn insert(&self, key: u64, value: u64) -> bool {
                ConcurrentMap::insert(self, key, value)
            }
            fn get(&self, key: u64) -> Option<u64> {
                ConcurrentMap::get(self, key)
            }
            fn erase(&self, key: u64) -> bool {
                ConcurrentMap::erase(self, key)
            }
            fn len(&self) -> u64 {
                ConcurrentMap::len(self)
            }
            fn name(&self) -> &'static str {
                ConcurrentMap::name(self)
            }
            fn mem_stats(&self) -> PoolStats {
                ($mem)(self)
            }
        }

        impl OrderedKv for $t {
            /// Sorted-snapshot fallback: hash tables have no key order, so
            /// a range is a filtered full snapshot, sorted once at the end.
            fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
                if lo > hi {
                    return Vec::new();
                }
                let mut out = Vec::new();
                ConcurrentMap::for_each(self, &mut |k, v| {
                    if (lo..=hi).contains(&k) {
                        out.push((k, v));
                    }
                });
                out.sort_unstable_by_key(|e| e.0);
                out
            }
        }
    };
}

kv_for_map!(FixedHashMap);
kv_for_map!(TwoLevelHashMap);
kv_for_map!(SpoHashMap, arena);
kv_for_map!(TwoLevelSpoHashMap, arena);
kv_for_map!(TbbLikeHashMap);

/// Which structure backs each shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreKind {
    DetSkiplistLf,
    DetSkiplistRwl,
    RandomSkiplist,
    HashFixed,
    HashTwoLevel,
    HashSpo,
    HashTwoLevelSpo,
    HashTbbLike,
}

impl StoreKind {
    pub fn parse(s: &str) -> Option<StoreKind> {
        Some(match s {
            "det" | "det-lf" | "lkfreefind" => StoreKind::DetSkiplistLf,
            "det-rwl" | "rwl" => StoreKind::DetSkiplistRwl,
            "random" | "random-skiplist" => StoreKind::RandomSkiplist,
            "fixed" | "binlist" => StoreKind::HashFixed,
            "twolevel" => StoreKind::HashTwoLevel,
            "spo" | "splitorder" => StoreKind::HashSpo,
            "twolevel-spo" | "spo2" => StoreKind::HashTwoLevelSpo,
            "tbb" | "tbb-like" => StoreKind::HashTbbLike,
            _ => return None,
        })
    }

    /// Build one shard's structure. Public so tests and tools can exercise
    /// every [`OrderedKv`] implementation behind one constructor.
    pub fn build(self, capacity: usize) -> Box<dyn OrderedKv> {
        self.build_placed(capacity, ArenaOptions::default())
    }

    /// Like [`StoreKind::build`] with explicit arena options: the sharded
    /// store homes each shard's arena(s) on the shard's NUMA node (eq. 7),
    /// so the §V memory managers are placed — and locality-accounted —
    /// per shard. Structures without arenas ignore the options.
    pub fn build_placed(self, capacity: usize, opts: ArenaOptions) -> Box<dyn OrderedKv> {
        self.build_placed_leaf(capacity, opts, None)
    }

    /// Like [`StoreKind::build_placed`] with an explicit fat-leaf chunk
    /// capacity for the deterministic skiplists (Table XV sweeps K ∈
    /// {1, 8, 16, 32}); `None` means [`crate::skiplist::DEFAULT_LEAF_CAP`].
    /// Structures without a leaf plane ignore it.
    pub fn build_placed_leaf(
        self,
        capacity: usize,
        opts: ArenaOptions,
        leaf_cap: Option<usize>,
    ) -> Box<dyn OrderedKv> {
        self.build_placed_caps(capacity, opts, leaf_cap, None)
    }

    /// Like [`StoreKind::build_placed_leaf`] with an explicit fat-inner
    /// routing-block capacity for the deterministic skiplists (Table XVI
    /// sweeps F ∈ {1, 2, 4, 8, 16}); `None` means
    /// [`crate::skiplist::DEFAULT_INNER_CAP`], `Some(f)` with `f < 2`
    /// disables the blocks (the legacy linked child walk). Structures
    /// without routing blocks ignore it.
    pub fn build_placed_caps(
        self,
        capacity: usize,
        opts: ArenaOptions,
        leaf_cap: Option<usize>,
        inner_cap: Option<usize>,
    ) -> Box<dyn OrderedKv> {
        let k = leaf_cap.unwrap_or(crate::skiplist::DEFAULT_LEAF_CAP);
        let f = inner_cap.unwrap_or(crate::skiplist::DEFAULT_INNER_CAP);
        match self {
            StoreKind::DetSkiplistLf => {
                Box::new(DetSkiplist::with_caps_on(FindMode::LockFree, capacity, opts, k, f))
            }
            StoreKind::DetSkiplistRwl => {
                Box::new(DetSkiplist::with_caps_on(FindMode::ReadLocked, capacity, opts, k, f))
            }
            StoreKind::RandomSkiplist => Box::new(RandomSkiplist::with_capacity_on(capacity, opts)),
            StoreKind::HashFixed => Box::new(FixedHashMap::new(1024)),
            StoreKind::HashTwoLevel => Box::new(TwoLevelHashMap::new(1024, 256)),
            StoreKind::HashSpo => {
                Box::new(SpoHashMap::with_config_on(1024, 16, 1 << 17, capacity, opts))
            }
            StoreKind::HashTwoLevelSpo => {
                Box::new(TwoLevelSpoHashMap::with_config_on(32, 64, 16, 1 << 14, capacity / 16, opts))
            }
            StoreKind::HashTbbLike => Box::new(TbbLikeHashMap::with_config(1 << 14, 4)),
        }
    }
}

/// Number of key-space prefixes (the paper's 3 MSBs → 8 segments; the
/// per-segment clamp arithmetic lives in [`for_each_prefix_segment`]).
const PREFIXES: u64 = 8;

/// The hierarchical store: one structure per shard, shards homed on
/// (virtual) NUMA nodes by eqs (6)-(7).
pub struct ShardedStore {
    shards: Vec<Box<dyn OrderedKv>>,
    topology: Topology,
    threads: usize,
    pub locality: LocalityStats,
    /// `ExecMode::Replicated` engaged (per-shard NUMA index replicas built).
    replicated: AtomicBool,
}

impl ShardedStore {
    /// `nshards` structures (paper: 8 = one per Milan NUMA node); each
    /// shard's arena is homed on its eq.-7 NUMA node.
    pub fn new(kind: StoreKind, nshards: usize, capacity_per_shard: usize, topology: Topology, threads: usize) -> ShardedStore {
        Self::with_leaf_cap(kind, nshards, capacity_per_shard, topology, threads, None)
    }

    /// Like [`ShardedStore::new`] with an explicit fat-leaf chunk capacity
    /// for skiplist shards (the Table XV K sweep); `None` keeps the default.
    pub fn with_leaf_cap(
        kind: StoreKind,
        nshards: usize,
        capacity_per_shard: usize,
        topology: Topology,
        threads: usize,
        leaf_cap: Option<usize>,
    ) -> ShardedStore {
        Self::with_caps(kind, nshards, capacity_per_shard, topology, threads, leaf_cap, None)
    }

    /// Like [`ShardedStore::with_leaf_cap`] with an explicit fat-inner
    /// routing-block capacity for skiplist shards (the Table XVI F sweep);
    /// `None` keeps the default.
    pub fn with_caps(
        kind: StoreKind,
        nshards: usize,
        capacity_per_shard: usize,
        topology: Topology,
        threads: usize,
        leaf_cap: Option<usize>,
        inner_cap: Option<usize>,
    ) -> ShardedStore {
        assert!(nshards.is_power_of_two() && nshards as u64 <= PREFIXES);
        ShardedStore {
            shards: (0..nshards)
                .map(|i| {
                    let home = topology.shard_home(i, threads);
                    kind.build_placed_caps(
                        capacity_per_shard,
                        ArenaOptions::placed(home, &topology, threads),
                        leaf_cap,
                        inner_cap,
                    )
                })
                .collect(),
            topology,
            threads,
            locality: LocalityStats::new(),
            replicated: AtomicBool::new(false),
        }
    }

    /// Shard of a key: top 3 MSBs folded onto the shard count (the shared
    /// [`shard_of_key`] helper, so the store, the word router and the
    /// delegation fabric can never disagree on routing).
    #[inline]
    pub fn shard_of(&self, key: u64) -> usize {
        shard_of_key(key, self.shards.len())
    }

    /// Home NUMA node of a shard under the current thread count (eq. 7).
    #[inline]
    pub fn home_node(&self, shard: usize) -> usize {
        self.topology.shard_home(shard, self.threads)
    }

    /// Account locality of an access from `thread_id` to `key`'s shard and
    /// charge the latency model if the access is remote.
    #[inline]
    pub fn account(&self, thread_id: usize, key: u64) {
        self.account_shard(thread_id, self.shard_of(key));
    }

    /// Account one shard dereference from `thread_id` (the delegation
    /// fabric's per-envelope accounting) and charge the latency model if
    /// the access crosses NUMA nodes.
    #[inline]
    pub fn account_shard(&self, thread_id: usize, shard: usize) {
        let home = self.home_node(shard);
        let from = self.topology.node_of_cpu(thread_id);
        let local = home == from;
        self.locality.record(local);
        if !local {
            LATENCY.charge_remote();
        }
    }

    /// Account every shard a `[lo, hi]` range scan dereferences — one touch
    /// per intersecting 3-MSB prefix, mirroring the per-prefix queries
    /// [`ShardedStore::range`] issues. Direct-mode workers use this: a
    /// cross-shard window makes them reach into remote shards, which is
    /// exactly the access pattern the Delegated mode eliminates.
    pub fn account_range(&self, thread_id: usize, lo: u64, hi: u64) {
        for_each_prefix_segment(lo, hi, |slo, _| {
            self.account_shard(thread_id, shard_of_key(slo, self.shards.len()));
        });
    }

    #[inline]
    pub fn shard(&self, key: u64) -> &dyn OrderedKv {
        &*self.shards[self.shard_of(key)]
    }

    /// Direct access to shard `idx` (bulk-load workers drain one per-shard
    /// queue each through this).
    #[inline]
    pub fn shard_at(&self, idx: usize) -> &dyn OrderedKv {
        &*self.shards[idx]
    }

    pub fn insert(&self, key: u64, value: u64) -> bool {
        self.shard(key).insert(key, value)
    }

    pub fn get(&self, key: u64) -> Option<u64> {
        self.shard(key).get(key)
    }

    pub fn erase(&self, key: u64) -> bool {
        self.shard(key).erase(key)
    }

    /// Cross-shard range scan. The key space is split into 8 prefix
    /// segments by the 3 MSBs; for every prefix intersecting `[lo, hi]` the
    /// owning shard is queried with the prefix-clamped sub-range, and the
    /// per-prefix results are concatenated in prefix order. Prefix order is
    /// key order (the partition preserves global order), so the
    /// concatenation is globally sorted and duplicate-free by construction
    /// — no merge heap. This also holds when `nshards < 8` and several
    /// prefixes fold onto one shard: each fold is queried only for its own
    /// clamped sub-range, still in ascending prefix order. (Trade-off: a
    /// folded hash-table shard re-snapshots once per intersecting prefix —
    /// acceptable because the paper's configuration is 8 shards, where
    /// every prefix maps to a distinct shard and no fold exists.)
    pub fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        for_each_prefix_segment(lo, hi, |slo, shi| {
            out.extend(self.shards[shard_of_key(slo, self.shards.len())].range(slo, shi));
        });
        out
    }

    /// Batch insert: the input is sorted once (skipped when pre-sorted) and
    /// every shard receives its **contiguous slice** of the sorted batch —
    /// the key space partition is by 3-MSB prefix, so the per-prefix
    /// segments of a sorted run are exactly the per-shard groups, found by
    /// binary search instead of a per-key `Vec` push (the old path
    /// allocated one `Vec` per shard on every call). Returns the number of
    /// pairs newly inserted.
    pub fn insert_batch(&self, items: &[(u64, u64)]) -> u64 {
        if items.is_empty() {
            return 0;
        }
        let sorted_buf: Vec<(u64, u64)>;
        let sorted: &[(u64, u64)] = if pairs_sorted(items) {
            items
        } else {
            let mut v = items.to_vec();
            v.sort_unstable_by_key(|e| e.0);
            sorted_buf = v;
            &sorted_buf
        };
        let mut n = 0;
        let mut cur = 0usize;
        for_each_prefix_segment(sorted[0].0, sorted[sorted.len() - 1].0, |slo, shi| {
            let start = cur + sorted[cur..].partition_point(|e| e.0 < slo);
            let end = start + sorted[start..].partition_point(|e| e.0 <= shi);
            cur = end;
            if start < end {
                n += self.shards[shard_of_key(slo, self.shards.len())]
                    .insert_batch(&sorted[start..end]);
            }
        });
        n
    }

    /// Batch erase, segment-routed like [`ShardedStore::insert_batch`].
    /// Returns how many keys were present.
    pub fn erase_batch(&self, keys: &[u64]) -> u64 {
        if keys.is_empty() {
            return 0;
        }
        let sorted_buf: Vec<u64>;
        let sorted: &[u64] = if keys_sorted(keys) {
            keys
        } else {
            let mut v = keys.to_vec();
            v.sort_unstable();
            sorted_buf = v;
            &sorted_buf
        };
        let mut n = 0;
        let mut cur = 0usize;
        for_each_prefix_segment(sorted[0], sorted[sorted.len() - 1], |slo, shi| {
            let start = cur + sorted[cur..].partition_point(|&k| k < slo);
            let end = start + sorted[start..].partition_point(|&k| k <= shi);
            cur = end;
            if start < end {
                n += self.shards[shard_of_key(slo, self.shards.len())]
                    .erase_batch(&sorted[start..end]);
            }
        });
        n
    }

    /// Batch lookup, segment-routed like [`ShardedStore::insert_batch`];
    /// values come back in **input order** (an order-restoring permutation
    /// is built only when the input is unsorted). Pre-sorted input is the
    /// clustered-arrival shape and rides each shard's fused `get_batch`;
    /// unsorted input is scattered arrival, so its (key-sorted) segment
    /// slices go through [`OrderedKv::apply_interleaved`] instead — the
    /// sort cannot turn far-apart probes into a dense run, and pipelining
    /// the independent descents is what hides their miss chains.
    pub fn get_batch(&self, keys: &[u64]) -> Vec<Option<u64>> {
        let mut out = vec![None; keys.len()];
        if keys.is_empty() {
            return out;
        }
        let skeys_buf: Vec<u64>;
        let order: Vec<u32>;
        let (skeys, perm): (&[u64], Option<&[u32]>) = if keys_sorted(keys) {
            (keys, None)
        } else {
            let mut o: Vec<u32> = (0..keys.len() as u32).collect();
            o.sort_by_key(|&i| keys[i as usize]);
            skeys_buf = o.iter().map(|&i| keys[i as usize]).collect();
            order = o;
            (&skeys_buf, Some(&order))
        };
        let mut cur = 0usize;
        for_each_prefix_segment(skeys[0], skeys[skeys.len() - 1], |slo, shi| {
            let start = cur + skeys[cur..].partition_point(|&k| k < slo);
            let end = start + skeys[start..].partition_point(|&k| k <= shi);
            cur = end;
            if start < end {
                let shard = &self.shards[shard_of_key(slo, self.shards.len())];
                match perm {
                    None => {
                        for (j, v) in
                            shard.get_batch(&skeys[start..end]).into_iter().enumerate()
                        {
                            out[start + j] = v;
                        }
                    }
                    Some(p) => {
                        let run: Vec<BatchOp> =
                            skeys[start..end].iter().map(|&k| BatchOp::Get(k)).collect();
                        shard.apply_interleaved(&run, DEFAULT_INTERLEAVE, &mut |j, r| {
                            if let BatchReply::Value(v) = r {
                                out[p[start + j] as usize] = v;
                            }
                        });
                    }
                }
            }
        });
        out
    }

    // ------------------------------------------------------------------
    // NUMA-replicated index layers (ExecMode::Replicated)
    // ------------------------------------------------------------------

    /// Build node-local index replicas on every shard and start routing
    /// replicated reads through them. Idempotent; call at a write-quiet
    /// moment (post-fill) so the initial builds are exact.
    pub fn enable_replication(&self) {
        for s in &self.shards {
            s.enable_replicas(&self.topology, self.threads);
        }
        self.replicated.store(true, Ordering::Release);
    }

    pub fn replication_enabled(&self) -> bool {
        self.replicated.load(Ordering::Acquire)
    }

    /// Point lookup via the calling thread's node-local replica of the
    /// key's shard. Locality accounting is honest: a replica answer is a
    /// node-local access by construction; a fallback is accounted as the
    /// Direct-mode access to the shard's home it actually performs.
    pub fn get_replicated(&self, thread_id: usize, key: u64) -> Option<u64> {
        let (v, fell_back) = self.shard(key).get_replicated(key);
        if fell_back {
            self.account(thread_id, key);
        } else {
            self.locality.record(true);
        }
        v
    }

    /// Cross-shard range scan with replica-seeded per-shard walks (same
    /// prefix-segment concatenation as [`ShardedStore::range`]).
    pub fn range_replicated(&self, thread_id: usize, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        for_each_prefix_segment(lo, hi, |slo, shi| {
            let sh = shard_of_key(slo, self.shards.len());
            let (rows, fell_back) = self.shards[sh].range_replicated(slo, shi);
            if fell_back {
                self.account_shard(thread_id, sh);
            } else {
                self.locality.record(true);
            }
            out.extend(rows);
        });
        out
    }

    /// One maintenance step on the calling thread's node-local replica of
    /// **every** shard (writers run this eagerly; the engine also ticks it
    /// periodically so remote replicas converge).
    pub fn replica_tick(&self) {
        for s in &self.shards {
            s.replica_tick();
        }
    }

    /// Force-rebuild every replica of every shard (tests / quiescence).
    pub fn replica_rebuild(&self) {
        for s in &self.shards {
            s.replica_rebuild();
        }
    }

    /// Replica-plane counters summed across every shard.
    pub fn replica_stats(&self) -> ReplicaStats {
        let mut out = ReplicaStats::default();
        for s in &self.shards {
            out.merge(&s.replica_stats());
        }
        out
    }

    /// Toggle every shard's search-finger cache (Table XII runs the same
    /// workload with and without fingers; no-op for non-skiplist kinds).
    pub fn set_finger_cache(&self, on: bool) {
        for s in &self.shards {
            s.set_finger_cache(on);
        }
    }

    /// Retry counters summed across every shard (observability: workloads
    /// report e.g. `find_retries` without `write_retries` inflation).
    pub fn stats(&self) -> SkiplistStats {
        let mut out = SkiplistStats::default();
        for s in &self.shards {
            out.merge(&s.stats());
        }
        out
    }

    /// §V memory accounting summed across every shard's arena(s) — the
    /// allocs/recycled/capacity/locality-hit-rate view the engine reports.
    pub fn mem_stats(&self) -> PoolStats {
        let mut out = PoolStats::default();
        for s in &self.shards {
            out.merge(&s.mem_stats());
        }
        out
    }

    pub fn len(&self) -> u64 {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn kind_name(&self) -> &'static str {
        self.shards[0].name()
    }

    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_KINDS: [StoreKind; 8] = [
        StoreKind::DetSkiplistLf,
        StoreKind::DetSkiplistRwl,
        StoreKind::RandomSkiplist,
        StoreKind::HashFixed,
        StoreKind::HashTwoLevel,
        StoreKind::HashSpo,
        StoreKind::HashTwoLevelSpo,
        StoreKind::HashTbbLike,
    ];

    #[test]
    fn shard_routing_by_msbs() {
        let s = ShardedStore::new(StoreKind::HashFixed, 8, 1 << 10, Topology::milan_virtual(), 128);
        assert_eq!(s.shard_of(0), 0);
        assert_eq!(s.shard_of(u64::MAX), 7);
        assert_eq!(s.shard_of(1 << 61), 1);
        assert_eq!(s.num_shards(), 8);
    }

    #[test]
    fn shard_of_matches_shared_helper_for_all_folds() {
        // Satellite cross-check: store routing and the shared helper (used
        // by the word router and the delegation fabric) must agree on every
        // folded-prefix configuration, so a key delegated to an owner lands
        // on the same shard the store itself would pick.
        for nshards in [1usize, 2, 4, 8] {
            let s = ShardedStore::new(
                StoreKind::HashFixed,
                nshards,
                1 << 10,
                Topology::milan_virtual(),
                8,
            );
            for p in 0..8u64 {
                for low in [0u64, 1, 0xFFFF, (1 << 59) - 1, (1 << 61) - 1] {
                    let key = p << 61 | low;
                    assert_eq!(
                        s.shard_of(key),
                        shard_of_key(key, nshards),
                        "nshards={nshards} key={key:#x}"
                    );
                    assert_eq!(
                        s.shard_of(key),
                        (p as usize) % nshards,
                        "folded prefix must be prefix mod nshards"
                    );
                }
            }
        }
    }

    #[test]
    fn insert_routes_and_len_aggregates() {
        let s = ShardedStore::new(StoreKind::DetSkiplistLf, 4, 1 << 12, Topology::milan_virtual(), 64);
        for i in 0..100u64 {
            // spread keys across shards via MSBs
            let key = (i % 4) << 61 | i;
            assert!(s.insert(key, i));
        }
        assert_eq!(s.len(), 100);
        for i in 0..100u64 {
            let key = (i % 4) << 61 | i;
            assert_eq!(s.get(key), Some(i));
        }
    }

    #[test]
    fn all_kinds_build_and_work() {
        for kind in ALL_KINDS {
            let s = ShardedStore::new(kind, 2, 1 << 12, Topology::milan_virtual(), 8);
            assert!(s.insert(42, 1), "{kind:?}");
            assert!(!s.insert(42, 2), "{kind:?}");
            assert_eq!(s.get(42), Some(1), "{kind:?}");
            assert!(s.erase(42), "{kind:?}");
            assert_eq!(s.get(42), None, "{kind:?}");
        }
    }

    #[test]
    fn cross_shard_range_is_globally_sorted() {
        for kind in ALL_KINDS {
            let s = ShardedStore::new(kind, 8, 1 << 12, Topology::milan_virtual(), 8);
            // 40 keys per prefix, all 8 prefixes
            let mut want = Vec::new();
            for p in 0..8u64 {
                for i in 0..40u64 {
                    let k = p << 61 | i * 7;
                    assert!(s.insert(k, k ^ 1), "{kind:?}");
                    want.push((k, k ^ 1));
                }
            }
            want.sort_unstable_by_key(|e| e.0);
            let got = s.range(0, u64::MAX - 2);
            assert_eq!(got, want, "{kind:?}: full cross-shard scan");
            // clamped scan spanning prefixes 2..=5
            let lo = 2u64 << 61;
            let hi = (5u64 << 61) | 100;
            let got = s.range(lo, hi);
            let wantw: Vec<(u64, u64)> =
                want.iter().copied().filter(|&(k, _)| k >= lo && k <= hi).collect();
            assert_eq!(got, wantw, "{kind:?}: prefix-clamped scan");
            assert_eq!(s.range(10, 5), vec![], "{kind:?}: inverted bounds");
        }
    }

    #[test]
    fn folded_prefixes_still_sort_globally() {
        // nshards = 2: prefixes 0,2,4,6 fold onto shard 0 and 1,3,5,7 onto
        // shard 1, so shard-local key sets interleave in global key order.
        // The per-prefix clamped queries must still produce a sorted scan.
        let s = ShardedStore::new(StoreKind::DetSkiplistLf, 2, 1 << 12, Topology::milan_virtual(), 4);
        let mut want = Vec::new();
        for p in 0..8u64 {
            for i in 0..25u64 {
                let k = p << 61 | i;
                assert!(s.insert(k, p));
                want.push((k, p));
            }
        }
        want.sort_unstable_by_key(|e| e.0);
        assert_eq!(s.range(0, u64::MAX - 2), want);
        // a window inside a single folded prefix
        let lo = 4u64 << 61;
        let got = s.range(lo, lo + 10);
        assert_eq!(got.len(), 11);
        assert!(got.iter().all(|&(k, v)| k >> 61 == 4 && v == 4));
    }

    #[test]
    fn batch_ops_route_across_shards() {
        for kind in ALL_KINDS {
            let s = ShardedStore::new(kind, 4, 1 << 12, Topology::milan_virtual(), 8);
            let items: Vec<(u64, u64)> =
                (0..200u64).map(|i| ((i % 8) << 61 | i, i + 1)).collect();
            assert_eq!(s.insert_batch(&items), 200, "{kind:?}");
            assert_eq!(s.insert_batch(&items), 0, "{kind:?}: duplicates");
            assert_eq!(s.len(), 200, "{kind:?}");
            for &(k, v) in &items {
                assert_eq!(s.get(k), Some(v), "{kind:?} key {k}");
            }
            let odd_keys: Vec<u64> =
                items.iter().map(|&(k, _)| k).filter(|&k| k & 1 == 1).collect();
            assert_eq!(s.erase_batch(&odd_keys), odd_keys.len() as u64, "{kind:?}");
            assert_eq!(s.erase_batch(&odd_keys), 0, "{kind:?}");
            assert_eq!(s.len(), 200 - odd_keys.len() as u64, "{kind:?}");
        }
    }

    #[test]
    fn get_batch_routes_and_restores_input_order() {
        for kind in ALL_KINDS {
            let s = ShardedStore::new(kind, 4, 1 << 12, Topology::milan_virtual(), 8);
            let items: Vec<(u64, u64)> =
                (0..100u64).map(|i| ((i % 8) << 61 | i, i + 7)).collect();
            assert_eq!(s.insert_batch(&items), 100, "{kind:?}");
            // unsorted query order, some misses, duplicates
            let mut keys: Vec<u64> = items.iter().map(|&(k, _)| k).collect();
            keys.reverse();
            keys.push(12345); // miss
            keys.push(keys[3]); // duplicate
            let got = s.get_batch(&keys);
            assert_eq!(got.len(), keys.len(), "{kind:?}");
            for (i, &k) in keys.iter().enumerate() {
                let want = items.iter().find(|&&(ik, _)| ik == k).map(|&(_, v)| v);
                assert_eq!(got[i], want, "{kind:?}: key {k} at position {i}");
            }
            // pre-sorted input takes the no-permutation fast path
            let mut sk: Vec<u64> = keys.clone();
            sk.sort_unstable();
            let got = s.get_batch(&sk);
            for (i, &k) in sk.iter().enumerate() {
                let want = items.iter().find(|&&(ik, _)| ik == k).map(|&(_, v)| v);
                assert_eq!(got[i], want, "{kind:?}: sorted key {k}");
            }
        }
    }

    #[test]
    fn sorted_and_unsorted_batches_agree() {
        // the pre-sorted fast path and the clone+sort path must produce the
        // same end state, including at shard-boundary keys and folds
        for nshards in [2usize, 8] {
            let a = ShardedStore::new(StoreKind::DetSkiplistLf, nshards, 1 << 12, Topology::milan_virtual(), 8);
            let b = ShardedStore::new(StoreKind::DetSkiplistLf, nshards, 1 << 12, Topology::milan_virtual(), 8);
            // boundary keys: first/near-last of every prefix segment (the
            // last key of prefix 7 would be u64::MAX, which MAX_KEY reserves
            // for the skiplist sentinel spine — stay one below)
            let mut items = Vec::new();
            for p in 0..8u64 {
                items.push((p << 61, p));
                items.push((p << 61 | ((1 << 61) - 2), p));
                for i in 0..20u64 {
                    items.push((p << 61 | i * 31, i));
                }
            }
            let mut sorted = items.clone();
            sorted.sort_unstable_by_key(|e| e.0);
            sorted.dedup_by_key(|e| e.0);
            assert_eq!(a.insert_batch(&sorted), sorted.len() as u64, "pre-sorted path");
            let mut rev = sorted.clone();
            rev.reverse();
            assert_eq!(b.insert_batch(&rev), sorted.len() as u64, "unsorted path");
            assert_eq!(a.range(0, u64::MAX - 2), b.range(0, u64::MAX - 2));
            let keys: Vec<u64> = sorted.iter().map(|&(k, _)| k).collect();
            assert_eq!(a.erase_batch(&keys), keys.len() as u64);
            let mut rkeys = keys.clone();
            rkeys.reverse();
            assert_eq!(b.erase_batch(&rkeys), keys.len() as u64);
            assert_eq!(a.len(), 0);
            assert_eq!(b.len(), 0);
        }
    }

    #[test]
    fn stats_sum_across_shards() {
        let s = ShardedStore::new(StoreKind::DetSkiplistLf, 4, 1 << 14, Topology::milan_virtual(), 8);
        let items: Vec<(u64, u64)> = (0..2_000u64).map(|i| ((i % 4) << 61 | i, i)).collect();
        s.insert_batch(&items);
        let st = s.stats();
        assert!(st.splits > 0, "bulk load must split across shards");
        assert!(st.depth_increases > 0, "per-shard height growth must aggregate");
        // a pure-read phase must not move the write-side counters
        let before = s.stats();
        for i in 0..200u64 {
            let lo = (i % 4) << 61 | i;
            let _ = s.range(lo, lo + 32);
            let _ = s.get(lo);
        }
        let after = s.stats();
        assert_eq!(after.write_retries, before.write_retries, "reads must not inflate write retries");
        assert_eq!(after.splits, before.splits);
    }

    #[test]
    fn mem_stats_aggregate_across_shards_for_arena_kinds() {
        // reset: the test-runner thread may have been pinned by another test
        crate::mem::note_thread_cpu(usize::MAX);
        for kind in [StoreKind::DetSkiplistLf, StoreKind::RandomSkiplist, StoreKind::HashSpo, StoreKind::HashTwoLevelSpo] {
            let s = ShardedStore::new(kind, 4, 1 << 12, Topology::milan_virtual(), 8);
            for i in 0..400u64 {
                let key = (i % 4) << 61 | i;
                assert!(s.insert(key, i), "{kind:?}");
            }
            for i in 0..400u64 {
                let key = (i % 4) << 61 | i;
                assert!(s.erase(key), "{kind:?}");
            }
            let st = s.mem_stats();
            assert!(st.allocs >= 400, "{kind:?}: allocs {}", st.allocs);
            assert_eq!(st.retired, st.recycled + st.free_residue + st.overflow, "{kind:?}: lost nodes");
            assert!(st.arenas >= 4, "{kind:?}: one arena per shard at least");
            assert!(st.capacity > 0, "{kind:?}");
            // unpinned test thread counts as local on every home node
            assert_eq!(st.remote_allocs, 0, "{kind:?}");
        }
        // structures without arenas report all-zero
        let s = ShardedStore::new(StoreKind::HashFixed, 2, 1 << 10, Topology::milan_virtual(), 8);
        s.insert(1, 1);
        assert_eq!(s.mem_stats().allocs, 0);
    }

    #[test]
    fn locality_accounting() {
        let s = ShardedStore::new(StoreKind::HashFixed, 8, 1 << 10, Topology::milan_virtual(), 128);
        // thread 0 is on node 0; shard 0's home with 128 threads is node 0
        s.account(0, 0); // local
        // shard 7 homes on node 7; access from thread 0 is remote
        s.account(0, u64::MAX);
        let (l, r) = s.locality.snapshot();
        assert_eq!((l, r), (1, 1));
    }

    #[test]
    fn cluster_gap_scales_with_leaf_and_inner_caps() {
        // skiplist shards report a clustered threshold scaled by both the
        // terminal width and the routing-block arity (default F = 8) …
        for (k, want) in [(1usize, 8u64), (8, 64), (16, 128), (32, 256)] {
            let s = ShardedStore::with_leaf_cap(
                StoreKind::DetSkiplistLf,
                2,
                1 << 10,
                Topology::milan_virtual(),
                8,
                Some(k),
            );
            assert_eq!(s.shard_at(0).cluster_gap(), want, "K = {k}");
            assert_eq!(s.shard_at(1).cluster_gap(), want, "K = {k}");
        }
        // … narrow or disabled routing blocks fall back to the legacy
        // few-chunks factor of 4
        for f in [1usize, 2, 4] {
            let s = ShardedStore::with_caps(
                StoreKind::DetSkiplistLf,
                2,
                1 << 10,
                Topology::milan_virtual(),
                8,
                Some(16),
                Some(f),
            );
            assert_eq!(s.shard_at(0).cluster_gap(), 64, "F = {f}");
        }
        let s = ShardedStore::with_caps(
            StoreKind::DetSkiplistLf,
            2,
            1 << 10,
            Topology::milan_virtual(),
            8,
            Some(16),
            Some(16),
        );
        assert_eq!(s.shard_at(0).cluster_gap(), 256, "F = 16");
        // … flat structures keep the single-key-terminal default
        let h = StoreKind::HashFixed.build(1 << 10);
        assert_eq!(h.cluster_gap(), 64);
        let d = StoreKind::DetSkiplistLf.build(1 << 10);
        assert_eq!(
            d.cluster_gap(),
            crate::skiplist::DEFAULT_LEAF_CAP as u64 * crate::skiplist::DEFAULT_INNER_CAP as u64
        );
    }

    #[test]
    fn inner_cap_plumbing_reaches_every_shard() {
        // an F-swept store must behave identically to the default store on
        // the full ordered API (same keys, same ranges, same batch replies)
        let base =
            ShardedStore::new(StoreKind::DetSkiplistLf, 4, 1 << 12, Topology::milan_virtual(), 8);
        for f in [1usize, 2, 8, 16] {
            let s = ShardedStore::with_caps(
                StoreKind::DetSkiplistLf,
                4,
                1 << 12,
                Topology::milan_virtual(),
                8,
                None,
                Some(f),
            );
            let items: Vec<(u64, u64)> =
                (0..600u64).map(|i| ((i % 4) << 61 | i * 7, i)).collect();
            assert_eq!(s.insert_batch(&items), items.len() as u64, "F = {f}");
            if f == 1 {
                base.insert_batch(&items);
            }
            assert_eq!(s.range(0, u64::MAX - 2), base.range(0, u64::MAX - 2), "F = {f}");
            let evens: Vec<u64> = items.iter().map(|&(ik, _)| ik).step_by(2).collect();
            assert_eq!(s.erase_batch(&evens), evens.len() as u64, "F = {f}");
            assert_eq!(s.len(), (items.len() - evens.len()) as u64, "F = {f}");
        }
    }

    #[test]
    fn leaf_cap_plumbing_reaches_every_shard() {
        // a K-swept store must behave identically to the default store on
        // the full ordered API (same keys, same ranges, same batch replies)
        let base = ShardedStore::new(StoreKind::DetSkiplistRwl, 4, 1 << 12, Topology::milan_virtual(), 8);
        for k in [1usize, 8, 32] {
            let s = ShardedStore::with_leaf_cap(
                StoreKind::DetSkiplistRwl,
                4,
                1 << 12,
                Topology::milan_virtual(),
                8,
                Some(k),
            );
            let items: Vec<(u64, u64)> = (0..600u64).map(|i| ((i % 4) << 61 | i * 7, i)).collect();
            assert_eq!(s.insert_batch(&items), items.len() as u64, "K = {k}");
            if k == 1 {
                base.insert_batch(&items);
            }
            assert_eq!(s.range(0, u64::MAX - 2), base.range(0, u64::MAX - 2), "K = {k}");
            let evens: Vec<u64> = items.iter().map(|&(ik, _)| ik).step_by(2).collect();
            assert_eq!(s.erase_batch(&evens), evens.len() as u64, "K = {k}");
            assert_eq!(s.len(), (items.len() - evens.len()) as u64, "K = {k}");
        }
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(StoreKind::parse("det"), Some(StoreKind::DetSkiplistLf));
        assert_eq!(StoreKind::parse("rwl"), Some(StoreKind::DetSkiplistRwl));
        assert_eq!(StoreKind::parse("spo2"), Some(StoreKind::HashTwoLevelSpo));
        assert_eq!(StoreKind::parse("nope"), None);
    }
}
