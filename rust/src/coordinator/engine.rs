//! The workload engine: leader fills the router queues (using the AOT
//! routing pipeline when available), workers pinned to (virtual) CPUs drain
//! their NUMA-local queues and apply operations to the sharded store.
//!
//! Matches the paper's methodology: "we filled the queues first before
//! performing operations on the data structures"; reported time is the
//! drain (data-structure) phase.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Instant;

use crate::mem::PoolStats;
use crate::numa::pin_to_cpu;
use crate::runtime::KeyRouter;
use crate::util::rng::Rng;
use crate::workload::{OpKind, WorkloadSpec};

use super::router::RouterFabric;
use super::store::ShardedStore;

/// Aggregated result of one workload run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub fill_seconds: f64,
    pub drain_seconds: f64,
    pub inserts: u64,
    pub finds: u64,
    pub erases: u64,
    pub found: u64,
    /// Range scans executed (mixed point/range workloads).
    pub ranges: u64,
    /// Total rows returned by all range scans.
    pub range_rows: u64,
    pub local_accesses: u64,
    pub remote_accesses: u64,
    pub final_len: u64,
    /// §V memory-manager accounting summed over every shard arena
    /// (allocs/recycled/capacity/magazine hits/locality-hit-rate).
    pub mem: PoolStats,
}

impl RunMetrics {
    pub fn ops(&self) -> u64 {
        self.inserts + self.finds + self.erases + self.ranges
    }

    pub fn throughput_mops(&self) -> f64 {
        if self.drain_seconds == 0.0 {
            0.0
        } else {
            self.ops() as f64 / self.drain_seconds / 1e6
        }
    }
}

/// Run `spec` against `store` with `threads` workers through the queue
/// fabric. `router` generates+routes keys on the leader thread.
pub fn run_workload(
    store: &Arc<ShardedStore>,
    spec: &WorkloadSpec,
    threads: usize,
    key_router: &KeyRouter,
    seed: u64,
) -> RunMetrics {
    let fabric = Arc::new(RouterFabric::new(
        threads,
        store.num_shards(),
        store.topology().clone(),
        // enough blocks for the whole fill phase
        (spec.total_ops as usize / 8192 + 2).next_power_of_two().max(64),
    ));

    // ---- fill phase (leader thread; AOT pipeline) ----
    let t_fill = Instant::now();
    let mut rng = Rng::new(seed);
    let chunk = 65_536usize;
    let mut base = seed.wrapping_mul(0x9E37_79B9);
    let mut remaining = spec.total_ops as usize;
    while remaining > 0 {
        let n = remaining.min(chunk);
        let batch = key_router.route(base, 8192, n);
        for &raw in &batch.keys {
            fabric.route_key(spec.encode(raw), &mut rng);
        }
        base = base.wrapping_add(n as u64);
        remaining -= n;
    }
    let fill_seconds = t_fill.elapsed().as_secs_f64();

    // ---- drain phase (workers) ----
    let barrier = Arc::new(Barrier::new(threads + 1));
    let inserts = Arc::new(AtomicU64::new(0));
    let finds = Arc::new(AtomicU64::new(0));
    let erases = Arc::new(AtomicU64::new(0));
    let found = Arc::new(AtomicU64::new(0));
    let ranges = Arc::new(AtomicU64::new(0));
    let range_rows = Arc::new(AtomicU64::new(0));
    let window = spec.range_window;
    let mut handles = Vec::with_capacity(threads);
    for t in 0..threads {
        let store = store.clone();
        let fabric = fabric.clone();
        let barrier = barrier.clone();
        let (inserts, finds, erases, found) =
            (inserts.clone(), finds.clone(), erases.clone(), found.clone());
        let (ranges, range_rows) = (ranges.clone(), range_rows.clone());
        handles.push(std::thread::spawn(move || {
            pin_to_cpu(t);
            barrier.wait(); // start together
            let (mut li, mut lf, mut le, mut lfound) = (0u64, 0u64, 0u64, 0u64);
            let (mut lr, mut lrows) = (0u64, 0u64);
            while let Some(word) = fabric.pop_local(t) {
                let (op, key) = WorkloadSpec::decode(word);
                store.account(t, key);
                match op {
                    OpKind::Insert => {
                        li += 1;
                        store.insert(key, key ^ 0xDA7A);
                    }
                    OpKind::Find => {
                        lf += 1;
                        if store.get(key).is_some() {
                            lfound += 1;
                        }
                    }
                    OpKind::Erase => {
                        le += 1;
                        store.erase(key);
                    }
                    OpKind::Range => {
                        // windows may span shards; the store concatenates
                        // per-prefix results in key order (see store::range)
                        lr += 1;
                        lrows += store.range(key, key.saturating_add(window)).len() as u64;
                    }
                }
            }
            inserts.fetch_add(li, Ordering::Relaxed);
            finds.fetch_add(lf, Ordering::Relaxed);
            erases.fetch_add(le, Ordering::Relaxed);
            found.fetch_add(lfound, Ordering::Relaxed);
            ranges.fetch_add(lr, Ordering::Relaxed);
            range_rows.fetch_add(lrows, Ordering::Relaxed);
        }));
    }
    // Clock starts BEFORE the barrier release: on an oversubscribed host
    // the leader can be descheduled across the entire drain otherwise,
    // undercounting it to microseconds (EXPERIMENTS.md §Perf notes).
    let t_drain = Instant::now();
    barrier.wait();
    for h in handles {
        h.join().unwrap();
    }
    let drain_seconds = t_drain.elapsed().as_secs_f64();

    let (local, remote) = store.locality.snapshot();
    RunMetrics {
        fill_seconds,
        drain_seconds,
        inserts: inserts.load(Ordering::Relaxed),
        finds: finds.load(Ordering::Relaxed),
        erases: erases.load(Ordering::Relaxed),
        found: found.load(Ordering::Relaxed),
        ranges: ranges.load(Ordering::Relaxed),
        range_rows: range_rows.load(Ordering::Relaxed),
        local_accesses: local,
        remote_accesses: remote,
        final_len: store.len(),
        mem: store.mem_stats(),
    }
}

/// Bulk-load `items` through per-shard staging queues: the leader fills one
/// queue per shard (the paper's "fill the queues first" step, here with
/// `(key, value)` pairs instead of transport words), then up to `threads`
/// workers claim shards and drain each queue through the shard's native
/// batch-insert path. Returns `(drain_seconds, newly_inserted)`.
pub fn bulk_load(store: &Arc<ShardedStore>, items: &[(u64, u64)], threads: usize) -> (f64, u64) {
    use std::sync::atomic::AtomicUsize;

    let nshards = store.num_shards();
    let mut queues: Vec<Vec<(u64, u64)>> = (0..nshards).map(|_| Vec::new()).collect();
    for &(k, v) in items {
        queues[store.shard_of(k)].push((k, v));
    }
    let inserted = AtomicU64::new(0);
    let next_shard = AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..threads.max(1).min(nshards) {
            let queues = &queues;
            let inserted = &inserted;
            let next_shard = &next_shard;
            let store = &**store;
            scope.spawn(move || {
                pin_to_cpu(t);
                loop {
                    let s = next_shard.fetch_add(1, Ordering::Relaxed);
                    if s >= nshards {
                        break;
                    }
                    let n = store.shard_at(s).insert_batch(&queues[s]);
                    inserted.fetch_add(n, Ordering::Relaxed);
                }
            });
        }
    });
    (t0.elapsed().as_secs_f64(), inserted.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::store::StoreKind;
    use crate::numa::Topology;
    use crate::workload::OpMix;

    fn run(kind: StoreKind, threads: usize, ops: u64, mix: OpMix) -> RunMetrics {
        let store = Arc::new(ShardedStore::new(
            kind,
            4,
            1 << 16,
            Topology::virtual_grid(2, 2),
            threads,
        ));
        let spec = WorkloadSpec::new("test", ops, mix, 1 << 16);
        run_workload(&store, &spec, threads, &KeyRouter::Native, 42)
    }

    #[test]
    fn all_ops_execute_exactly_once() {
        let m = run(StoreKind::DetSkiplistLf, 4, 20_000, OpMix::W1);
        assert_eq!(m.ops(), 20_000);
        assert!(m.inserts > 1_000 && m.inserts < 3_000, "inserts {}", m.inserts);
        assert!(m.finds > 16_000, "finds {}", m.finds);
        assert!(m.final_len <= m.inserts);
        assert!(m.drain_seconds > 0.0);
        // the unified arena's accounting reaches the run metrics
        assert!(m.mem.allocs >= m.final_len, "every resident key has a node");
        assert!(m.mem.capacity > 0);
        assert_eq!(m.mem.retired, m.mem.recycled + m.mem.free_residue + m.mem.overflow);
    }

    #[test]
    fn w2_erases_happen() {
        let m = run(StoreKind::RandomSkiplist, 4, 50_000, OpMix::W2);
        assert!(m.erases > 20, "erases {}", m.erases);
        assert_eq!(m.ops(), 50_000);
    }

    #[test]
    fn hash_mix_on_every_table_kind() {
        for kind in [
            StoreKind::HashFixed,
            StoreKind::HashTwoLevel,
            StoreKind::HashSpo,
            StoreKind::HashTwoLevelSpo,
            StoreKind::HashTbbLike,
        ] {
            let m = run(kind, 2, 10_000, OpMix::HASH);
            assert_eq!(m.ops(), 10_000, "{kind:?}");
            assert!(m.inserts > 4_000, "{kind:?} inserts {}", m.inserts);
        }
    }

    #[test]
    fn locality_is_fully_local_by_construction() {
        // Keys are routed to threads on their shard's home node, so every
        // worker access must be local (the paper's design goal).
        let m = run(StoreKind::HashFixed, 4, 10_000, OpMix::HASH);
        assert_eq!(m.remote_accesses, 0, "hierarchical routing must be NUMA-local");
        assert_eq!(m.local_accesses, 10_000);
    }

    #[test]
    fn single_thread_run() {
        let m = run(StoreKind::DetSkiplistLf, 1, 5_000, OpMix::W1);
        assert_eq!(m.ops(), 5_000);
    }

    #[test]
    fn mixed_range_workload_executes_scans() {
        let m = run(StoreKind::DetSkiplistLf, 4, 20_000, OpMix::RANGE);
        assert_eq!(m.ops(), 20_000, "every op drains exactly once");
        assert!(m.ranges > 3_000 && m.ranges < 5_000, "~20% ranges, got {}", m.ranges);
        assert!(m.range_rows > 0, "scans over a bounded key space must hit rows");
        assert!(m.inserts > 1_000, "inserts {}", m.inserts);
    }

    #[test]
    fn bulk_load_drains_per_shard_queues() {
        let store = Arc::new(ShardedStore::new(
            StoreKind::DetSkiplistLf,
            4,
            1 << 16,
            Topology::virtual_grid(2, 2),
            4,
        ));
        let items: Vec<(u64, u64)> =
            (0..10_000u64).map(|i| ((i % 8) << 61 | i, i ^ 3)).collect();
        let (secs, inserted) = super::bulk_load(&store, &items, 4);
        assert!(secs > 0.0);
        assert_eq!(inserted, 10_000);
        assert_eq!(store.len(), 10_000);
        // reloading the same batch inserts nothing
        let (_, again) = super::bulk_load(&store, &items, 2);
        assert_eq!(again, 0);
        // loaded data answers cross-shard ranges
        let rows = store.range(0, u64::MAX - 2);
        assert_eq!(rows.len(), 10_000);
        assert!(rows.windows(2).all(|w| w[0].0 < w[1].0), "sorted, duplicate-free");
    }
}
