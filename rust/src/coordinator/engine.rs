//! The workload engine: leader fills the router queues (using the AOT
//! routing pipeline when available), workers pinned to (virtual) CPUs drain
//! their queues and apply operations to the sharded store.
//!
//! Matches the paper's methodology: "we filled the queues first before
//! performing operations on the data structures"; reported time is the
//! drain (data-structure) phase.
//!
//! Two drain strategies run behind one [`ExecMode`] switch:
//!
//! - [`ExecMode::Direct`] — transport words are routed to a random thread
//!   on each key's home node and workers apply ops straight to the sharded
//!   store. Point ops stay node-local by routing, but cross-shard range
//!   scans dereference every shard they intersect — remote accesses the
//!   locality counters now charge honestly (`account_range`).
//! - [`ExecMode::Delegated`] — words are spread uniformly; each worker is
//!   simultaneously a *caller* (wrapping its words in typed
//!   [`DelegatedOp`] envelopes, batching them per owner, flushing on-N /
//!   on-drain) and an *owner* (draining its own envelope queue and
//!   executing against its NUMA-local shards). Callers never dereference
//!   remote shard memory: `remote_accesses == 0` by construction, the
//!   paper's §VI–VII hierarchical proposal.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use crate::mem::PoolStats;
use crate::numa::pin_to_cpu;
use crate::runtime::KeyRouter;
use crate::skiplist::ReplicaStats;
use crate::sync::Backoff;
use crate::util::rng::Rng;
use crate::workload::{OpKind, WorkloadSpec};

use super::router::{DelegatedOp, FabricStats, OpFabric, RetireOnUnwind, RouterFabric};
use super::store::ShardedStore;

/// How drained operations reach shard memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Workers execute popped ops in place, reaching into whichever shard
    /// owns the key (the pre-delegation path).
    Direct,
    /// Workers delegate typed op envelopes to per-shard owner threads over
    /// the [`OpFabric`]; only owners touch shard memory.
    Delegated,
    /// Workers execute in place like Direct, but reads descend each NUMA
    /// node's local replica of the index layers (shared terminals only at
    /// the bottom) — no delegation hop, no remote index-plane derefs.
    Replicated,
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<ExecMode> {
        Some(match s {
            "direct" => ExecMode::Direct,
            "delegated" | "del" | "hier" => ExecMode::Delegated,
            "replicated" | "repl" | "rep" => ExecMode::Replicated,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Direct => "direct",
            ExecMode::Delegated => "delegated",
            ExecMode::Replicated => "replicated",
        }
    }
}

/// Aggregated result of one workload run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub fill_seconds: f64,
    pub drain_seconds: f64,
    pub inserts: u64,
    pub finds: u64,
    pub erases: u64,
    pub found: u64,
    /// Range scans executed (mixed point/range workloads).
    pub ranges: u64,
    /// Total rows returned by all range scans.
    pub range_rows: u64,
    pub local_accesses: u64,
    pub remote_accesses: u64,
    pub final_len: u64,
    /// §V memory-manager accounting summed over every shard arena
    /// (allocs/recycled/capacity/magazine hits/locality-hit-rate).
    pub mem: PoolStats,
    /// Delegation-fabric metrics (all-zero in Direct mode): queue depth,
    /// batch occupancy, completion latency, backpressure.
    pub fabric: FabricStats,
    /// Replica-plane metrics (all-zero outside [`ExecMode::Replicated`]):
    /// replica derefs and their locality, stale-landing recovery work,
    /// fallbacks, sync traffic.
    pub replica: ReplicaStats,
}

impl RunMetrics {
    pub fn ops(&self) -> u64 {
        self.inserts + self.finds + self.erases + self.ranges
    }

    pub fn throughput_mops(&self) -> f64 {
        if self.drain_seconds == 0.0 {
            0.0
        } else {
            self.ops() as f64 / self.drain_seconds / 1e6
        }
    }
}

/// Run `spec` against `store` with `threads` workers in [`ExecMode::Direct`]
/// (the historical entry point; see [`run_with_mode`]).
pub fn run_workload(
    store: &Arc<ShardedStore>,
    spec: &WorkloadSpec,
    threads: usize,
    key_router: &KeyRouter,
    seed: u64,
) -> RunMetrics {
    run_with_mode(store, spec, threads, key_router, seed, ExecMode::Direct)
}

/// Engine knobs beyond the workload spec (defaults reproduce
/// [`run_with_mode`]'s historical behaviour).
#[derive(Clone, Copy, Debug)]
pub struct RunOptions {
    pub mode: ExecMode,
    /// Seed flush threshold for caller-side envelope batching (delegated
    /// mode; the per-owner threshold adapts in `[batch_n, batch_n*4]`).
    /// Flush-on-64 amortizes the per-op handoff without letting completion
    /// counters lag far behind the op stream.
    pub batch_n: usize,
    /// Owner-side operation combining (drains merge caller batches into
    /// per-shard fused sorted runs). On by default; Table XIII's
    /// per-envelope baseline turns it off.
    pub combining: bool,
    /// Pin the combiner's interleave width for scattered runs (`run
    /// --interleave k`, Table XIV sweep). `0` (the default) leaves the
    /// per-owner width adaptive.
    pub interleave: usize,
    /// Deadline on delegated completion waits (sync-call spin and dispatch
    /// backpressure). `None` (the default) preserves the historical
    /// wait-forever behaviour; `Some(d)` makes a wedged owner surface as
    /// [`super::router::FabricError::Timeout`] after `d` instead of
    /// spinning forever. Also arms heartbeat-based dead-owner detection at
    /// `d / 4` so surviving workers adopt orphaned queues well before
    /// callers give up.
    pub op_timeout: Option<Duration>,
    /// Replicated mode: run one replica maintenance tick every this many
    /// drained ops per worker (writers additionally tick eagerly after
    /// each mutation). `0` disables all ticking — replicas then only
    /// converge via descent-miss repair; the stress tests use this to
    /// force maximal staleness.
    pub replica_tick_every: usize,
}

impl Default for RunOptions {
    fn default() -> RunOptions {
        RunOptions {
            mode: ExecMode::Direct,
            batch_n: 64,
            combining: true,
            interleave: 0,
            op_timeout: None,
            replica_tick_every: 64,
        }
    }
}

impl RunOptions {
    /// Defaults with the given [`ExecMode`].
    pub fn with_mode(mode: ExecMode) -> RunOptions {
        RunOptions { mode, ..RunOptions::default() }
    }
}

/// Per-worker op-kind tallies, merged into the shared metrics at exit.
#[derive(Default)]
struct OpTally {
    inserts: u64,
    finds: u64,
    erases: u64,
    found: u64,
    ranges: u64,
    range_rows: u64,
}

/// Run `spec` against `store` with `threads` workers through the queue
/// fabric in the given [`ExecMode`]. `key_router` generates keys on the
/// leader thread.
pub fn run_with_mode(
    store: &Arc<ShardedStore>,
    spec: &WorkloadSpec,
    threads: usize,
    key_router: &KeyRouter,
    seed: u64,
    mode: ExecMode,
) -> RunMetrics {
    run_with_opts(store, spec, threads, key_router, seed, RunOptions::with_mode(mode))
}

/// [`run_with_mode`] with explicit engine knobs ([`RunOptions`]).
pub fn run_with_opts(
    store: &Arc<ShardedStore>,
    spec: &WorkloadSpec,
    threads: usize,
    key_router: &KeyRouter,
    seed: u64,
    opts: RunOptions,
) -> RunMetrics {
    let mode = opts.mode;
    let words = Arc::new(RouterFabric::new(
        threads,
        store.num_shards(),
        store.topology(),
        // enough blocks for the whole fill phase
        (spec.total_ops as usize / 8192 + 2).next_power_of_two().max(64),
    ));
    let batch_n = opts.batch_n.max(1);
    let fabric = match mode {
        ExecMode::Direct | ExecMode::Replicated => None,
        ExecMode::Delegated => Some(Arc::new(OpFabric::new(
            threads,
            0,
            store.num_shards(),
            store.topology().clone(),
            // worst case every batch lands on one owner: total batches over
            // 256-slot queue blocks, plus slack
            ((spec.total_ops as usize / batch_n) / 256 + 4).next_power_of_two().max(16),
            batch_n,
        ))),
    };
    if let Some(f) = &fabric {
        f.set_combining(opts.combining);
        f.set_interleave_width(opts.interleave);
        f.set_op_timeout(opts.op_timeout);
        // Detect dead owners well inside the caller deadline so takeover
        // (not timeout) is the common recovery path.
        f.set_owner_dead_after(
            opts.op_timeout.map(|d| (d / 4).max(Duration::from_millis(1))),
        );
    }

    // ---- fill phase (leader thread; AOT pipeline) ----
    let t_fill = Instant::now();
    let mut rng = Rng::new(seed);
    let chunk = 65_536usize;
    let mut base = seed.wrapping_mul(0x9E37_79B9);
    let mut remaining = spec.total_ops as usize;
    let mut seq = 0u64; // fill position: drives the hot-window keygen
    while remaining > 0 {
        let n = remaining.min(chunk);
        let batch = key_router.route(base, 8192, n);
        for &raw in &batch.keys {
            let word = spec.encode(raw, seq);
            seq += 1;
            match mode {
                // Direct/Replicated: home-node routing (the paper's word
                // fabric) — replicated workers execute in place too.
                ExecMode::Direct | ExecMode::Replicated => words.route_key(word, &mut rng),
                // Delegated: callers receive arbitrary slices; locality is
                // established at delegation time by the op fabric.
                ExecMode::Delegated => words.route_uniform(word),
            }
        }
        base = base.wrapping_add(n as u64);
        remaining -= n;
    }
    let fill_seconds = t_fill.elapsed().as_secs_f64();

    // Replicated: build the per-node index replicas at the write-quiet
    // fill/drain boundary so the initial builds are exact, and bypass the
    // finger cache — replica descents ARE the locality shortcut, and a
    // finger hit would re-route reads through the shared index.
    if mode == ExecMode::Replicated {
        store.enable_replication();
        store.set_finger_cache(false);
    }
    let tick_every = opts.replica_tick_every;

    // ---- drain phase (workers) ----
    let barrier = Arc::new(Barrier::new(threads + 1));
    let tally = Arc::new(TallyAtomics::default());
    let window = spec.range_window;
    let mut handles = Vec::with_capacity(threads);
    for t in 0..threads {
        let store = store.clone();
        let words = words.clone();
        let fabric = fabric.clone();
        let barrier = barrier.clone();
        let tally = tally.clone();
        handles.push(std::thread::spawn(move || {
            pin_to_cpu(t);
            // Delegated: create the caller handle BEFORE the barrier, so
            // once any worker starts polling all_quiet() the fabric's
            // started-caller count is already final (no early-quiet race).
            let caller = fabric.as_ref().map(|f| f.caller(t, Some(t)));
            barrier.wait(); // start together
            let local = match caller {
                None if mode == ExecMode::Replicated => {
                    drain_replicated(t, &store, &words, window, tick_every)
                }
                None => drain_direct(t, &store, &words, window),
                Some(caller) => {
                    drain_delegated(t, &store, &words, fabric.as_ref().unwrap(), window, caller)
                }
            };
            tally.merge(&local);
        }));
    }
    // Clock starts BEFORE the barrier release: on an oversubscribed host
    // the leader can be descheduled across the entire drain otherwise,
    // undercounting it to microseconds (EXPERIMENTS.md §Perf notes).
    let t_drain = Instant::now();
    barrier.wait();
    for h in handles {
        h.join().unwrap();
    }
    let drain_seconds = t_drain.elapsed().as_secs_f64();

    // Delegated completions live in the fabric's per-caller slots.
    let (mut found, mut range_rows) = (
        tally.found.load(Ordering::Relaxed),
        tally.range_rows.load(Ordering::Relaxed),
    );
    let fabric_stats = match &fabric {
        None => FabricStats::default(),
        Some(f) => {
            // Release-checked: a non-quiesced fabric would silently
            // under-report found/range_rows and every Table XI metric.
            // (A worker panic never reaches here — the joins above
            // propagate it first.)
            assert!(f.all_quiet(), "drain must quiesce the fabric");
            found = (0..f.num_callers()).map(|c| f.slot_totals(c).hits).sum();
            range_rows = (0..f.num_callers()).map(|c| f.slot_totals(c).rows).sum();
            f.stats()
        }
    };

    let (local, remote) = store.locality.snapshot();
    RunMetrics {
        fill_seconds,
        drain_seconds,
        inserts: tally.inserts.load(Ordering::Relaxed),
        finds: tally.finds.load(Ordering::Relaxed),
        erases: tally.erases.load(Ordering::Relaxed),
        found,
        ranges: tally.ranges.load(Ordering::Relaxed),
        range_rows,
        local_accesses: local,
        remote_accesses: remote,
        final_len: store.len(),
        mem: store.mem_stats(),
        fabric: fabric_stats,
        replica: store.replica_stats(),
    }
}

#[derive(Default)]
struct TallyAtomics {
    inserts: AtomicU64,
    finds: AtomicU64,
    erases: AtomicU64,
    found: AtomicU64,
    ranges: AtomicU64,
    range_rows: AtomicU64,
}

impl TallyAtomics {
    fn merge(&self, t: &OpTally) {
        self.inserts.fetch_add(t.inserts, Ordering::Relaxed);
        self.finds.fetch_add(t.finds, Ordering::Relaxed);
        self.erases.fetch_add(t.erases, Ordering::Relaxed);
        self.found.fetch_add(t.found, Ordering::Relaxed);
        self.ranges.fetch_add(t.ranges, Ordering::Relaxed);
        self.range_rows.fetch_add(t.range_rows, Ordering::Relaxed);
    }
}

/// Direct drain: pop words from the thread's home-node queue and execute in
/// place — reaching into remote shards for cross-prefix range windows.
fn drain_direct(
    t: usize,
    store: &ShardedStore,
    words: &RouterFabric,
    window: u64,
) -> OpTally {
    let mut tally = OpTally::default();
    while let Some(word) = words.pop_local(t) {
        let (op, key) = WorkloadSpec::decode(word);
        match op {
            OpKind::Insert => {
                tally.inserts += 1;
                store.account(t, key);
                store.insert(key, key ^ 0xDA7A);
            }
            OpKind::Find => {
                tally.finds += 1;
                store.account(t, key);
                if store.get(key).is_some() {
                    tally.found += 1;
                }
            }
            OpKind::Erase => {
                tally.erases += 1;
                store.account(t, key);
                store.erase(key);
            }
            OpKind::Range => {
                // windows may span shards; the store concatenates
                // per-prefix results in key order (see store::range), and
                // every dereferenced shard is charged (account_range)
                tally.ranges += 1;
                let hi = key.saturating_add(window);
                store.account_range(t, key, hi);
                tally.range_rows += store.range(key, hi).len() as u64;
            }
        }
    }
    tally
}

/// Replicated drain: like Direct, but every read routes through the
/// worker's NUMA-node replica of the owning shard's index layers
/// ([`ShardedStore::get_replicated`] / `range_replicated`), touching only
/// the shared terminal chunk at the bottom. Writes go to the primary and
/// eagerly tick the worker's local replicas so a node's own writes are
/// visible to its replica almost immediately; a periodic tick (every
/// `tick_every` ops) lets each node also absorb remote writers' published
/// invalidations. `tick_every == 0` disables both (forced-staleness runs).
fn drain_replicated(
    t: usize,
    store: &ShardedStore,
    words: &RouterFabric,
    window: u64,
    tick_every: usize,
) -> OpTally {
    let mut tally = OpTally::default();
    let mut since_tick = 0usize;
    while let Some(word) = words.pop_local(t) {
        let (op, key) = WorkloadSpec::decode(word);
        match op {
            OpKind::Insert => {
                tally.inserts += 1;
                store.account(t, key);
                store.insert(key, key ^ 0xDA7A);
                if tick_every != 0 {
                    store.replica_tick();
                }
            }
            OpKind::Find => {
                tally.finds += 1;
                if store.get_replicated(t, key).is_some() {
                    tally.found += 1;
                }
            }
            OpKind::Erase => {
                tally.erases += 1;
                store.account(t, key);
                store.erase(key);
                if tick_every != 0 {
                    store.replica_tick();
                }
            }
            OpKind::Range => {
                tally.ranges += 1;
                let hi = key.saturating_add(window);
                tally.range_rows += store.range_replicated(t, key, hi).len() as u64;
            }
        }
        if tick_every != 0 {
            since_tick += 1;
            if since_tick >= tick_every {
                since_tick = 0;
                store.replica_tick();
            }
        }
    }
    tally
}

/// Delegated drain: the worker is caller and owner at once. As caller it
/// wraps its word slice into typed envelopes, staged per owner with
/// flush-on-N; as owner it drains its envelope queue and executes against
/// its NUMA-local shards. After its words run out it flushes (on-drain),
/// then keeps serving its queue until the whole fabric is quiet. `found`
/// and `range_rows` aggregate through the fabric's completion slots.
fn drain_delegated(
    t: usize,
    store: &ShardedStore,
    words: &RouterFabric,
    fabric: &OpFabric,
    window: u64,
    mut caller: super::router::Caller<'_>,
) -> OpTally {
    // A worker that unwinds out of here can never finish() or drain its
    // queue again. Retiring the owner (instead of poisoning the whole
    // fabric) lets the survivors adopt its queue and quiesce; the join
    // still propagates the panic. Caller-side unwinds that never entered
    // a drain body (e.g. a test assertion) therefore no longer cascade
    // into fabric-wide poison.
    let _guard = RetireOnUnwind { fabric, thread: t };
    let mut tally = OpTally::default();
    let mut since_drain = 0usize;
    while let Some(word) = words.pop_local(t) {
        let (op, key) = WorkloadSpec::decode(word);
        match op {
            OpKind::Insert => {
                tally.inserts += 1;
                caller.delegate(DelegatedOp::Insert { key, value: key ^ 0xDA7A }, store);
            }
            OpKind::Find => {
                tally.finds += 1;
                caller.delegate(DelegatedOp::Find { key }, store);
            }
            OpKind::Erase => {
                tally.erases += 1;
                caller.delegate(DelegatedOp::Erase { key }, store);
            }
            OpKind::Range => {
                tally.ranges += 1;
                caller.delegate_range(key, key.saturating_add(window), store);
            }
        }
        since_drain += 1;
        if since_drain >= 128 {
            // owner role: keep our queue moving while we still have input
            since_drain = 0;
            fabric.drain(t, store, 8);
        }
    }
    caller.finish(store); // on-drain flush + termination bookkeeping
    let mut b = Backoff::new();
    loop {
        if fabric.drain(t, store, 64) > 0 {
            b.reset();
        } else if fabric.all_quiet() {
            break;
        } else if fabric.is_poisoned() {
            // A sibling worker died mid-execution: its queue will never
            // drain and all_quiet can never hold. Bail out so the join
            // surfaces the original panic instead of hanging the run.
            break;
        } else {
            b.wait();
        }
    }
    tally
}

/// Bulk-load `items` through per-shard staging queues: the leader fills one
/// queue per shard (the paper's "fill the queues first" step, here with
/// `(key, value)` pairs instead of transport words), then up to `threads`
/// workers claim shards and drain each queue through the shard's native
/// batch-insert path. Returns `(drain_seconds, newly_inserted)`.
pub fn bulk_load(store: &Arc<ShardedStore>, items: &[(u64, u64)], threads: usize) -> (f64, u64) {
    use std::sync::atomic::AtomicUsize;

    let nshards = store.num_shards();
    let mut queues: Vec<Vec<(u64, u64)>> = (0..nshards).map(|_| Vec::new()).collect();
    for &(k, v) in items {
        queues[store.shard_of(k)].push((k, v));
    }
    let inserted = AtomicU64::new(0);
    let next_shard = AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..threads.max(1).min(nshards) {
            let queues = &queues;
            let inserted = &inserted;
            let next_shard = &next_shard;
            let store = &**store;
            scope.spawn(move || {
                pin_to_cpu(t);
                loop {
                    let s = next_shard.fetch_add(1, Ordering::Relaxed);
                    if s >= nshards {
                        break;
                    }
                    let n = store.shard_at(s).insert_batch(&queues[s]);
                    inserted.fetch_add(n, Ordering::Relaxed);
                }
            });
        }
    });
    (t0.elapsed().as_secs_f64(), inserted.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::store::StoreKind;
    use crate::numa::Topology;
    use crate::workload::OpMix;

    fn run_mode(
        kind: StoreKind,
        threads: usize,
        ops: u64,
        mix: OpMix,
        mode: ExecMode,
    ) -> RunMetrics {
        let store = Arc::new(ShardedStore::new(
            kind,
            4,
            1 << 16,
            Topology::virtual_grid(2, 2),
            threads,
        ));
        let spec = WorkloadSpec::new("test", ops, mix, 1 << 16);
        run_with_mode(&store, &spec, threads, &KeyRouter::Native, 42, mode)
    }

    fn run(kind: StoreKind, threads: usize, ops: u64, mix: OpMix) -> RunMetrics {
        run_mode(kind, threads, ops, mix, ExecMode::Direct)
    }

    #[test]
    fn all_ops_execute_exactly_once() {
        let m = run(StoreKind::DetSkiplistLf, 4, 20_000, OpMix::W1);
        assert_eq!(m.ops(), 20_000);
        assert!(m.inserts > 1_000 && m.inserts < 3_000, "inserts {}", m.inserts);
        assert!(m.finds > 16_000, "finds {}", m.finds);
        assert!(m.final_len <= m.inserts);
        assert!(m.drain_seconds > 0.0);
        // the unified arena's accounting reaches the run metrics
        assert!(m.mem.allocs >= m.final_len, "every resident key has a node");
        assert!(m.mem.capacity > 0);
        assert_eq!(m.mem.retired, m.mem.recycled + m.mem.free_residue + m.mem.overflow);
        // Direct mode never touches the delegation fabric
        assert_eq!(m.fabric.submitted, 0);
    }

    #[test]
    fn w2_erases_happen() {
        let m = run(StoreKind::RandomSkiplist, 4, 50_000, OpMix::W2);
        assert!(m.erases > 20, "erases {}", m.erases);
        assert_eq!(m.ops(), 50_000);
    }

    #[test]
    fn hash_mix_on_every_table_kind() {
        for kind in [
            StoreKind::HashFixed,
            StoreKind::HashTwoLevel,
            StoreKind::HashSpo,
            StoreKind::HashTwoLevelSpo,
            StoreKind::HashTbbLike,
        ] {
            let m = run(kind, 2, 10_000, OpMix::HASH);
            assert_eq!(m.ops(), 10_000, "{kind:?}");
            assert!(m.inserts > 4_000, "{kind:?} inserts {}", m.inserts);
        }
    }

    #[test]
    fn locality_is_fully_local_by_construction() {
        // Keys are routed to threads on their shard's home node, so every
        // worker access must be local (the paper's design goal).
        let m = run(StoreKind::HashFixed, 4, 10_000, OpMix::HASH);
        assert_eq!(m.remote_accesses, 0, "hierarchical routing must be NUMA-local");
        assert_eq!(m.local_accesses, 10_000);
    }

    #[test]
    fn single_thread_run() {
        let m = run(StoreKind::DetSkiplistLf, 1, 5_000, OpMix::W1);
        assert_eq!(m.ops(), 5_000);
    }

    #[test]
    fn mixed_range_workload_executes_scans() {
        let m = run(StoreKind::DetSkiplistLf, 4, 20_000, OpMix::RANGE);
        assert_eq!(m.ops(), 20_000, "every op drains exactly once");
        assert!(m.ranges > 3_000 && m.ranges < 5_000, "~20% ranges, got {}", m.ranges);
        assert!(m.range_rows > 0, "scans over a bounded key space must hit rows");
        assert!(m.inserts > 1_000, "inserts {}", m.inserts);
    }

    #[test]
    fn delegated_all_ops_execute_exactly_once() {
        let m = run_mode(StoreKind::DetSkiplistLf, 4, 20_000, OpMix::W1, ExecMode::Delegated);
        assert_eq!(m.ops(), 20_000);
        assert!(m.inserts > 1_000 && m.inserts < 3_000, "inserts {}", m.inserts);
        assert!(m.found > 0 && m.found <= m.finds, "slot hits aggregate: {}", m.found);
        assert!(m.final_len <= m.inserts);
        let f = &m.fabric;
        assert_eq!(f.submitted, 20_000, "point ops map 1:1 to envelopes");
        assert_eq!(f.executed, f.submitted, "drain quiesces the fabric");
        assert_eq!(f.remote_exec, 0, "owners never execute off their node");
        assert!(f.batches > 0 && f.batch_occupancy() > 1.0, "caller-side batching");
    }

    #[test]
    fn delegated_is_numa_local_even_with_ranges() {
        // The paper's locality assertion, now including cross-shard range
        // windows: every sub-scan executes on its owning shard's node.
        let m = run_mode(StoreKind::DetSkiplistLf, 4, 20_000, OpMix::RANGE, ExecMode::Delegated);
        assert_eq!(m.ops(), 20_000);
        assert!(m.ranges > 3_000, "ranges {}", m.ranges);
        assert!(m.range_rows > 0, "rows aggregate through completion slots");
        assert_eq!(m.remote_accesses, 0, "delegated mode must be fully NUMA-local");
        assert!(m.local_accesses >= 20_000);
    }

    #[test]
    fn direct_ranges_reach_remote_shards_delegated_ones_do_not() {
        // The Table XI contrast in miniature: scans whose window spans a
        // 3-MSB prefix boundary touch two shards. The Direct worker
        // dereferences both itself (one is remote whenever adjacent shards
        // home on different nodes); the delegated caller splits the window
        // and ships each half to its owner, staying at zero remote.
        let run = |mode| {
            let store = Arc::new(ShardedStore::new(
                StoreKind::DetSkiplistLf,
                4,
                1 << 16,
                Topology::virtual_grid(2, 2),
                4,
            ));
            let spec = WorkloadSpec::new("xshard", 10_000, OpMix::RANGE, 1 << 16)
                .with_range_window(1 << 61); // window spans into the next prefix
            run_with_mode(&store, &spec, 4, &KeyRouter::Native, 42, mode)
        };
        let d = run(ExecMode::Direct);
        let g = run(ExecMode::Delegated);
        assert!(
            d.remote_accesses > 0,
            "direct cross-shard scans must be charged as remote (got {})",
            d.remote_accesses
        );
        assert_eq!(g.remote_accesses, 0);
        assert_eq!(d.ops(), g.ops(), "both modes drain the same op stream");
    }

    #[test]
    fn delegated_single_thread_runs_inline() {
        let m = run_mode(StoreKind::DetSkiplistLf, 1, 5_000, OpMix::W1, ExecMode::Delegated);
        assert_eq!(m.ops(), 5_000);
        assert_eq!(m.fabric.executed, 5_000);
        assert_eq!(m.fabric.inline_ops, 5_000, "one thread owns every shard");
        assert_eq!(m.remote_accesses, 0);
    }

    #[test]
    fn delegated_matches_direct_results_on_hash_mix() {
        // Same seed + spec => same op stream => identical end state.
        let d = run_mode(StoreKind::HashFixed, 4, 10_000, OpMix::HASH, ExecMode::Direct);
        let g = run_mode(StoreKind::HashFixed, 4, 10_000, OpMix::HASH, ExecMode::Delegated);
        assert_eq!(d.inserts, g.inserts);
        assert_eq!(d.finds, g.finds);
        assert_eq!(d.final_len, g.final_len, "resident sets agree");
    }

    #[test]
    fn delegated_bulk_mix_combines_under_clustered_runs() {
        let store = Arc::new(ShardedStore::new(
            StoreKind::DetSkiplistLf,
            4,
            1 << 16,
            Topology::virtual_grid(2, 2),
            4,
        ));
        let spec = WorkloadSpec::new("bulk", 20_000, OpMix::BULK, 1 << 14)
            .with_clustered_runs(64, 1);
        let m = run_with_opts(
            &store,
            &spec,
            4,
            &KeyRouter::Native,
            3,
            RunOptions { mode: ExecMode::Delegated, batch_n: 32, ..RunOptions::default() },
        );
        assert_eq!(m.ops(), 20_000);
        assert_eq!(m.fabric.executed, m.fabric.submitted);
        assert_eq!(m.remote_accesses, 0, "combining preserves NUMA locality");
        assert!(m.fabric.combined_drains > 0, "clustered bulk traffic must combine");
        assert!(
            m.fabric.combined_batches >= 2 * m.fabric.combined_drains,
            "a combining drain merges >= 2 caller batches"
        );
        assert!(m.fabric.combined_runs > 0);
    }

    #[test]
    fn combining_on_and_off_agree_on_final_state() {
        // HASH mix (no erases): membership is order-independent, so the
        // combined and per-envelope paths must build the same resident set
        let run = |combining| {
            let store = Arc::new(ShardedStore::new(
                StoreKind::DetSkiplistLf,
                4,
                1 << 16,
                Topology::virtual_grid(2, 2),
                4,
            ));
            let spec = WorkloadSpec::new("cmp", 10_000, OpMix::HASH, 1 << 14)
                .with_clustered_runs(32, 1);
            let m = run_with_opts(
                &store,
                &spec,
                4,
                &KeyRouter::Native,
                11,
                RunOptions { mode: ExecMode::Delegated, batch_n: 16, combining, ..RunOptions::default() },
            );
            (m, store)
        };
        let (a, sa) = run(true);
        let (b, sb) = run(false);
        assert_eq!(a.inserts, b.inserts);
        assert_eq!(a.finds, b.finds);
        assert_eq!(a.final_len, b.final_len, "resident sets agree");
        assert_eq!(sa.range(0, u64::MAX - 2), sb.range(0, u64::MAX - 2));
        assert_eq!(b.fabric.combined_drains, 0, "baseline must not combine");
    }

    #[test]
    fn exec_mode_parses() {
        assert_eq!(ExecMode::parse("direct"), Some(ExecMode::Direct));
        assert_eq!(ExecMode::parse("delegated"), Some(ExecMode::Delegated));
        assert_eq!(ExecMode::parse("hier"), Some(ExecMode::Delegated));
        assert_eq!(ExecMode::parse("nope"), None);
        assert_eq!(ExecMode::Delegated.name(), "delegated");
    }

    #[test]
    fn bulk_load_drains_per_shard_queues() {
        let store = Arc::new(ShardedStore::new(
            StoreKind::DetSkiplistLf,
            4,
            1 << 16,
            Topology::virtual_grid(2, 2),
            4,
        ));
        let items: Vec<(u64, u64)> =
            (0..10_000u64).map(|i| ((i % 8) << 61 | i, i ^ 3)).collect();
        let (secs, inserted) = super::bulk_load(&store, &items, 4);
        assert!(secs > 0.0);
        assert_eq!(inserted, 10_000);
        assert_eq!(store.len(), 10_000);
        // reloading the same batch inserts nothing
        let (_, again) = super::bulk_load(&store, &items, 2);
        assert_eq!(again, 0);
        // loaded data answers cross-shard ranges
        let rows = store.range(0, u64::MAX - 2);
        assert_eq!(rows.len(), 10_000);
        assert!(rows.windows(2).all(|w| w[0].0 < w[1].0), "sorted, duplicate-free");
    }
}
