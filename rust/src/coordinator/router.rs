//! The queue fabrics of the hierarchical coordinator (paper §VI–VII).
//!
//! Two lanes share the generic lock-free queue:
//!
//! - [`RouterFabric`] — the paper's original *word lane*: "We used
//!   lock-free queues, one per thread, for distributing keys. The queues
//!   distributed keys with upper 3-bits equal to S_i to a random thread in
//!   n_{s_i}." Bare `u64` transport words, used by the Direct engine mode.
//! - [`OpFabric`] — the *delegation lane* that completes the paper's
//!   closing proposal ("hierarchical usage of concurrent data structures …
//!   to improve memory latencies by reducing memory accesses from remote
//!   NUMA nodes", §VI–VII): typed [`DelegatedOp`] envelopes batched
//!   caller-side and executed by the owner thread of each shard, so every
//!   shard dereference happens on the shard's home NUMA node.
//!
//! ## Delegation protocol
//!
//! Each shard has exactly one *owner thread*, picked on the shard's eq.-7
//! home node (round-robin across that node's threads when it hosts several
//! shards). Callers stage ops in per-owner buffers and flush a buffer as
//! one [`OpBatch`] when it reaches `batch_n` ops (flush-on-N) or when the
//! caller runs out of input (flush-on-drain) — the batching amortizes the
//! per-op handoff cache misses ("Skiplists with Foresight"). Batches for a
//! caller's *own* shards execute inline (self-delegation needs no queue
//! round-trip and can never self-deadlock on a full queue).
//!
//! Completions come back through padded per-caller [`CompletionSlot`]s:
//! asynchronous ops aggregate counters (acks, find hits, range rows,
//! applied mutations) with relaxed atomics; a synchronous [`Caller::call`]
//! parks on its slot's state word until the owner publishes the full
//! [`OpResult`] (WAITING → CLAIMED → DONE, release/acquire paired).
//!
//! ## Fault tolerance
//!
//! The fabric is self-healing rather than fail-stop. Owner liveness is
//! tracked by per-owner heartbeat epochs beaten at every drain entry; an
//! owner that dies at an op-envelope boundary (an injected
//! [`crate::util::fail::InjectedKill`] caught by [`OpFabric::drain`], or a
//! heartbeat that stops advancing while batches pile up) is marked dead,
//! and a surviving worker *adopts* its work: one CAS claims the orphaned
//! queue (`queue_owner`), per-shard CASes re-home the shard→owner map, and
//! the adopter drains the dead owner's queue and settles every pending
//! completion slot. Boundary kills make this exactly-once: a popped window
//! is always fully executed before a kill site can fire, so every batch
//! still in the queue executes exactly once under its new owner.
//!
//! Sync waits escalate spin → yield → deadline (see
//! [`OpFabric::set_op_timeout`]) and surface a typed [`FabricError`]
//! instead of panicking; a timed-out slot is *abandoned* (the late settler
//! recycles it) so a slow owner can never publish a stale result into a
//! reused slot. A genuine (non-injected) owner panic still poisons the
//! fabric — but its shards are quarantined and served by Direct-mode
//! fallback, pending work is settled as `Err(Poisoned)`, and the original
//! panic propagates for diagnosis.

use std::cell::UnsafeCell;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::numa::Topology;
use crate::queue::{ConcurrentQueue, LfQueue, WordQueue};
use crate::skiplist::{BatchOp, BatchReply};
use crate::sync::Backoff;
use crate::util::fail;
use crate::util::rng::Rng;

use super::store::{ShardedStore, DEFAULT_INTERLEAVE};
use super::{for_each_prefix_segment, shard_of_key};

// ---------------------------------------------------------------------------
// Word lane (Direct mode)
// ---------------------------------------------------------------------------

/// One lock-free queue per worker thread; keys are routed to a random
/// thread pinned to the home NUMA node of their shard.
pub struct RouterFabric {
    queues: Vec<WordQueue>,
    nshards: usize,
    /// Precomputed thread ids per shard's home node (perf: `route_key` was
    /// O(threads) per key with iterator scans — see EXPERIMENTS.md §Perf).
    shard_threads: Vec<Vec<usize>>,
    /// Round-robin cursor for [`RouterFabric::route_uniform`].
    rr: AtomicUsize,
}

impl RouterFabric {
    pub fn new(
        threads: usize,
        nshards: usize,
        topology: &Topology,
        queue_blocks: usize,
    ) -> RouterFabric {
        assert!(threads >= 1 && nshards.is_power_of_two());
        let shard_threads = (0..nshards)
            .map(|shard| {
                let node = topology.shard_home(shard, threads);
                let v: Vec<usize> =
                    (0..threads).filter(|&t| topology.node_of_cpu(t) == node).collect();
                if v.is_empty() {
                    vec![0]
                } else {
                    v
                }
            })
            .collect();
        RouterFabric {
            queues: (0..threads).map(|_| LfQueue::with_config(8192, queue_blocks, true)).collect(),
            nshards,
            shard_threads,
            rr: AtomicUsize::new(0),
        }
    }

    pub fn threads(&self) -> usize {
        self.queues.len()
    }

    /// Route one key to a random thread on its shard's home node.
    #[inline]
    pub fn route_key(&self, key: u64, rng: &mut Rng) {
        let shard = shard_of_key(key, self.nshards);
        let region = &self.shard_threads[shard];
        let t = region[rng.below(region.len() as u64) as usize];
        self.queues[t].push(key);
    }

    /// Route a whole batch (leader-thread fill phase).
    pub fn route_batch(&self, keys: &[u64], rng: &mut Rng) {
        for &k in keys {
            self.route_key(k, rng);
        }
    }

    /// Uniform round-robin distribution, ignoring home nodes: the Delegated
    /// fill phase hands every caller an arbitrary slice of the op stream —
    /// locality is established at delegation time, not at routing time.
    #[inline]
    pub fn route_uniform(&self, key: u64) {
        let t = self.rr.fetch_add(1, Ordering::Relaxed) % self.queues.len();
        self.queues[t].push(key);
    }

    /// Worker-side pop from the thread's own (NUMA-local) queue.
    #[inline]
    pub fn pop_local(&self, thread_id: usize) -> Option<u64> {
        self.queues[thread_id].pop()
    }

    /// Total keys still enqueued (diagnostics). Each queue is snapshotted
    /// with a single `stats()` call that samples `pops` before `pushes`, so
    /// a per-queue term can never underflow. Remaining approximation: the
    /// per-queue snapshots are not taken at one instant, so under churn the
    /// sum can over-count by the pushes that land while later queues are
    /// being sampled — an upper bound within the sampling window, never a
    /// phantom negative.
    pub fn pending(&self) -> u64 {
        self.queues.iter().map(|q| q.stats().depth()).sum()
    }
}

// ---------------------------------------------------------------------------
// Delegation lane (Delegated mode)
// ---------------------------------------------------------------------------

/// A typed operation envelope. `Batch` and `Range` are pre-split by the
/// caller so every envelope targets exactly one shard (and therefore one
/// owner): `Range` bounds are clamped to a single 3-MSB prefix segment,
/// `Batch` items all fold to the same shard.
#[derive(Debug, Clone)]
pub enum DelegatedOp {
    Insert { key: u64, value: u64 },
    Find { key: u64 },
    Erase { key: u64 },
    /// Bulk insert of a single-shard slice (see
    /// [`Caller::delegate_insert_batch`]).
    Batch { items: Vec<(u64, u64)> },
    /// Range scan clamped to one prefix segment (see
    /// [`Caller::delegate_range`]).
    Range { lo: u64, hi: u64 },
}

impl DelegatedOp {
    /// The single shard this envelope touches.
    #[inline]
    pub fn shard(&self, nshards: usize) -> usize {
        let key = match self {
            DelegatedOp::Insert { key, .. }
            | DelegatedOp::Find { key }
            | DelegatedOp::Erase { key } => *key,
            DelegatedOp::Batch { items } => items.first().map(|e| e.0).unwrap_or(0),
            DelegatedOp::Range { lo, .. } => *lo,
        };
        shard_of_key(key, nshards)
    }
}

/// Result of one synchronous delegated op.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OpResult {
    /// Placeholder while the owner has not published yet.
    Pending,
    /// `Find`: the value, if present.
    Value(Option<u64>),
    /// `Insert` / `Erase`: whether the mutation applied.
    Applied(bool),
    /// `Batch`: how many pairs were newly inserted.
    Count(u64),
    /// `Range`: the rows, sorted by key.
    Rows(Vec<(u64, u64)>),
}

/// Typed failure surfaced to synchronous callers instead of a panic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricError {
    /// The configured op deadline elapsed before the op settled (see
    /// [`OpFabric::set_op_timeout`]; without a deadline waits are
    /// unbounded, the pre-fault-tolerance behavior).
    Timeout,
    /// The deadline elapsed *and* the target owner is marked dead — no
    /// survivor has adopted and settled the op yet.
    OwnerDead,
    /// The fabric was poisoned by a genuine (non-injected) owner panic;
    /// pending work is settled with this error by the surviving drains.
    Poisoned,
}

impl std::fmt::Display for FabricError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FabricError::Timeout => write!(f, "delegated op timed out"),
            FabricError::OwnerDead => write!(f, "owner thread died before settling the op"),
            FabricError::Poisoned => write!(f, "delegation fabric poisoned by an owner panic"),
        }
    }
}

impl std::error::Error for FabricError {}

/// One flushed batch of envelopes from one caller to one owner.
pub struct OpBatch {
    caller: u32,
    /// Sync batches carry exactly one op and publish a full [`OpResult`].
    sync: bool,
    /// Flush timestamp — the owner measures handoff latency against it
    /// when it pops the batch from its queue.
    staged_at: Instant,
    ops: Vec<DelegatedOp>,
}

const SLOT_IDLE: u32 = 0;
const SLOT_WAITING: u32 = 1;
/// A settler won the WAITING → CLAIMED race and is writing the result.
const SLOT_CLAIMED: u32 = 2;
const SLOT_DONE: u32 = 3;
/// The caller timed out and walked away; whoever eventually settles the op
/// drops the result and recycles the slot back to IDLE.
const SLOT_ABANDONED: u32 = 4;

/// Per-caller completion slot, padded to its own cache line pair so two
/// callers' completions never false-share.
#[repr(align(128))]
pub struct CompletionSlot {
    /// Sync rendezvous word: IDLE → WAITING (caller) → CLAIMED → DONE
    /// (settler), or WAITING → ABANDONED (caller deadline) → IDLE
    /// (late settler recycles).
    state: AtomicU32,
    /// Sync result cell; written by the settler while `state == CLAIMED`
    /// (the CAS from WAITING grants exclusive write access), read by the
    /// caller after observing DONE (acquire).
    result: UnsafeCell<Result<OpResult, FabricError>>,
    /// Async aggregation: ops completed for this caller.
    acked: AtomicU64,
    /// Async aggregation: finds that hit.
    hits: AtomicU64,
    /// Async aggregation: total rows returned by range scans.
    rows: AtomicU64,
    /// Async aggregation: mutations applied (inserts + erases + batch rows).
    applied: AtomicU64,
    /// Async aggregation: ops settled as errors (poisoned-fabric drain).
    errored: AtomicU64,
}

// The UnsafeCell is guarded by the state-word protocol above.
unsafe impl Sync for CompletionSlot {}

impl CompletionSlot {
    fn new() -> CompletionSlot {
        CompletionSlot {
            state: AtomicU32::new(SLOT_IDLE),
            result: UnsafeCell::new(Ok(OpResult::Pending)),
            acked: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            applied: AtomicU64::new(0),
            errored: AtomicU64::new(0),
        }
    }
}

/// Snapshot of one caller's async completion counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct SlotTotals {
    pub acked: u64,
    pub hits: u64,
    pub rows: u64,
    pub applied: u64,
    /// Ops settled as errors instead of acks (fabric poisoned while they
    /// were in flight). Zero-lost-completions invariant per caller:
    /// `acked + errored == delegated`.
    pub errored: u64,
}

#[derive(Default)]
struct FabricAtomics {
    submitted: AtomicU64,
    executed: AtomicU64,
    batches: AtomicU64,
    queued_batches: AtomicU64,
    inline_ops: AtomicU64,
    sync_calls: AtomicU64,
    backpressure: AtomicU64,
    handoff_ns: AtomicU64,
    peak_depth: AtomicU64,
    remote_exec: AtomicU64,
    combined_drains: AtomicU64,
    combined_batches: AtomicU64,
    combined_runs: AtomicU64,
    fused_runs: AtomicU64,
    interleaved_runs: AtomicU64,
    coalesced_finds: AtomicU64,
    flush_grow: AtomicU64,
    flush_shrink: AtomicU64,
    callers_started: AtomicUsize,
    callers_done: AtomicUsize,
    errored: AtomicU64,
    owner_deaths: AtomicU64,
    shards_adopted: AtomicU64,
    adopted_batches: AtomicU64,
    direct_fallback: AtomicU64,
    sync_timeouts: AtomicU64,
    /// ns-since-epoch0 of the first owner death / first successful queue
    /// takeover; 0 = never (set-once CAS). Their difference is the
    /// recovery latency Table XVII reports.
    first_death_ns: AtomicU64,
    first_takeover_ns: AtomicU64,
}

/// Fabric health metrics (threaded into `RunMetrics` and the CLI).
#[derive(Debug, Clone, Default)]
pub struct FabricStats {
    /// Ops handed to the fabric (queued or executed inline).
    pub submitted: u64,
    /// Ops executed by owners.
    pub executed: u64,
    /// Batches executed (queued + inline).
    pub batches: u64,
    /// Batches that travelled through an owner queue.
    pub queued_batches: u64,
    /// Ops executed via the inline self-delegation shortcut.
    pub inline_ops: u64,
    /// Synchronous calls (completion-slot rendezvous).
    pub sync_calls: u64,
    /// try_push rejections ridden out by the backpressure loop.
    pub backpressure: u64,
    /// Total flush→pop latency over all queued batches, recorded once per
    /// batch at the moment the owner pops it — uniformly across the
    /// combined, single-batch and sync drain branches (the inline
    /// self-delegation shortcut never queues and is deliberately excluded).
    pub handoff_ns: u64,
    /// Deepest owner-queue depth observed (in batches).
    pub peak_depth: u64,
    /// Ops an owner executed against a shard homed on a *different* node —
    /// zero by construction in a healthy fabric; nonzero only after a
    /// fault (an adopter serving a dead owner's shards, or a Direct-mode
    /// fallback). With `owner_deaths == 0` any other value is a routing
    /// bug.
    pub remote_exec: u64,
    /// Drains that merged ≥ 2 caller batches into combined fused runs.
    pub combined_drains: u64,
    /// Caller batches folded into combined runs.
    pub combined_batches: u64,
    /// Per-shard runs executed by combining drains (fused + interleaved).
    pub combined_runs: u64,
    /// Combined runs whose keys were clustered: executed through the fused
    /// shared-walk descent (the sorted-run path).
    pub fused_runs: u64,
    /// Combined runs whose keys were scattered: executed through the
    /// interleaved multi-descent engine (the MLP path).
    pub interleaved_runs: u64,
    /// Duplicate finds answered by a single fused execution.
    pub coalesced_finds: u64,
    /// Adaptive flush-threshold doublings (owner-queue backpressure).
    pub flush_grow: u64,
    /// Adaptive flush-threshold halvings (idle owner queue).
    pub flush_shrink: u64,
    /// Ops settled as errors instead of executing (poisoned-fabric drain).
    /// Quiescence balance: `executed + errored == submitted`.
    pub errored: u64,
    /// Owner threads declared dead (injected kill, heartbeat takeover, or
    /// genuine panic).
    pub owner_deaths: u64,
    /// Shards re-homed to a surviving owner by takeover CAS.
    pub shards_adopted: u64,
    /// Batches drained from adopted (orphaned) queues.
    pub adopted_batches: u64,
    /// Ops executed by Direct-mode fallback on the caller's own thread
    /// (quarantined shard, or a handoff that hit its deadline).
    pub direct_fallback: u64,
    /// Sync calls that abandoned their slot on deadline.
    pub sync_timeouts: u64,
    /// First-death → first-takeover latency in ns (0 when no takeover
    /// happened): the fabric's measured recovery time.
    pub recovery_ns: u64,
}

impl FabricStats {
    /// Average ops per executed batch (the §VII amortization knob).
    pub fn batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.executed as f64 / self.batches as f64
        }
    }

    /// Mean flush→pop handoff latency per queued batch, microseconds.
    pub fn avg_handoff_us(&self) -> f64 {
        if self.queued_batches == 0 {
            0.0
        } else {
            self.handoff_ns as f64 / self.queued_batches as f64 / 1000.0
        }
    }

    /// Average caller batches merged per combining drain (Table XIII's
    /// coalescing metric; ≥ 2 whenever combining fires at all).
    pub fn combined_batches_per_drain(&self) -> f64 {
        if self.combined_drains == 0 {
            0.0
        } else {
            self.combined_batches as f64 / self.combined_drains as f64
        }
    }
}

/// The typed-op delegation fabric: one envelope queue per owner thread,
/// one padded completion slot per caller.
pub struct OpFabric {
    queues: Vec<LfQueue<OpBatch>>,
    slots: Box<[CompletionSlot]>,
    topology: Topology,
    threads: usize,
    nshards: usize,
    /// shard → owner thread (on the shard's eq.-7 home node). Atomic so a
    /// survivor can re-home a dead owner's shards by CAS (takeover).
    owner_of: Vec<AtomicUsize>,
    /// queue index → thread currently responsible for draining it
    /// (initially the identity map; an adopter CASes a dead owner's entry
    /// to itself and drains the orphaned queue on its own cadence).
    queue_owner: Vec<AtomicUsize>,
    /// Per-owner death flags (injected kill, heartbeat takeover, genuine
    /// panic). A dead owner stands down from draining; its new ops route
    /// to the adopter once `owner_of` is re-CASed.
    owner_dead: Vec<AtomicBool>,
    /// Per-shard quarantine flags: set when the shard's owner died to a
    /// *genuine* panic (state cannot be presumed at an op boundary).
    /// Quarantined shards are never adopted; callers serve them by
    /// Direct-mode fallback.
    quarantined: Vec<AtomicBool>,
    /// Cheap gate for the per-op quarantine check on the delegate path.
    any_quarantine: AtomicBool,
    /// Per-owner heartbeat epochs: ns since `epoch0`, beaten at every
    /// drain entry. Staleness (plus a non-empty queue) is the frozen-owner
    /// detector when `owner_dead_after_ns` is set.
    beats: Vec<AtomicU64>,
    /// Time origin for heartbeats and recovery latency.
    epoch0: Instant,
    /// Sync-wait / handoff deadline in ns; 0 = unbounded (default).
    op_timeout_ns: AtomicU64,
    /// Heartbeat staleness threshold in ns; 0 = heartbeat detection off.
    owner_dead_after_ns: AtomicU64,
    batch_n: usize,
    at: FabricAtomics,
    /// Owner-side operation combining (see [`OpFabric::drain`]): on by
    /// default; the Table XIII baseline turns it off to measure the
    /// per-envelope execution path.
    combining: AtomicBool,
    /// Set when an owner dies to a *genuine* panic mid-execution (not an
    /// injected op-boundary kill, which self-heals instead): surviving
    /// drains settle pending work as `Err(Poisoned)` and sync callers get
    /// a typed [`FabricError::Poisoned`] rather than waiting forever.
    poisoned: AtomicBool,
    /// Per-owner adaptive interleave width for scattered combined runs,
    /// adapted like the callers' flush threshold (see
    /// [`OpFabric::pick_interleave`]).
    interleave_w: Vec<AtomicUsize>,
    /// Non-zero pins every owner's interleave width (`run --interleave k`
    /// and the Table XIV width sweep); zero restores adaptation.
    interleave_pin: AtomicUsize,
}

/// One caller's point op waiting in a combining drain's pool.
struct PointEntry {
    op: BatchOp,
    caller: u32,
}

/// How many batches one combining round pops before executing (bounds the
/// pool's memory and the latency of the first completion in the round).
const COMBINE_WINDOW: usize = 32;

/// Runs shorter than this always take the fused path — too few independent
/// descents to fill a pipeline.
const INTERLEAVE_MIN_RUN: usize = 8;

/// Bounds for the per-owner adaptive interleave width. The ceiling matches
/// the skiplists' lane cap; the floor keeps at least two chains in flight
/// once a run qualifies as scattered at all.
const INTERLEAVE_MIN_W: usize = 2;
const INTERLEAVE_MAX_W: usize = 32;

/// `true` when a key-sorted run is dominated by clustered keys: at least
/// half of the adjacent gaps are within `gap` (the target shard's
/// [`crate::coordinator::KvStore::cluster_gap`] — leaf-width × routing-block
/// arity for the fat-node skiplists, so wider terminals *and* wider inner
/// blocks both widen what counts as clustered). The combiner's per-drain
/// dispatch test — clustered windows keep the PR-5 fused path, scattered
/// ones go to the interleaved engine.
fn run_is_clustered(run: &[BatchOp], gap: u64) -> bool {
    if run.len() < INTERLEAVE_MIN_RUN {
        return true;
    }
    let close = run.windows(2).filter(|w| w[1].key() - w[0].key() <= gap).count();
    close * 2 >= run.len() - 1
}

impl OpFabric {
    /// `threads` owner/worker threads (each gets an envelope queue and a
    /// completion slot), plus `extra_callers` slot-only callers that never
    /// own shards (tests and external clients). `queue_blocks` sizes each
    /// owner queue's block directory; `batch_n` is the flush-on-N
    /// threshold handed to [`OpFabric::caller`].
    pub fn new(
        threads: usize,
        extra_callers: usize,
        nshards: usize,
        topology: Topology,
        queue_blocks: usize,
        batch_n: usize,
    ) -> OpFabric {
        assert!(threads >= 1 && nshards.is_power_of_two() && batch_n >= 1);
        let owner_of = (0..nshards)
            .map(|s| {
                let home = topology.shard_home(s, threads);
                let local: Vec<usize> =
                    (0..threads).filter(|&t| topology.node_of_cpu(t) == home).collect();
                let owner = if local.is_empty() {
                    // Unreachable for id-ordered pinning (every engaged node
                    // hosts a thread); kept as a safe fallback.
                    s % threads
                } else {
                    // Shards homed on the same node are s, s + n_u, s + 2·n_u,
                    // …; dividing by n_u round-robins them across the node's
                    // threads so one thread doesn't own every local shard.
                    local[(s / topology.nodes_in_use(threads)) % local.len()]
                };
                AtomicUsize::new(owner)
            })
            .collect();
        OpFabric {
            queues: (0..threads)
                .map(|_| LfQueue::with_config(256, queue_blocks.max(2), true))
                .collect(),
            slots: (0..threads + extra_callers).map(|_| CompletionSlot::new()).collect(),
            topology,
            threads,
            nshards,
            owner_of,
            queue_owner: (0..threads).map(AtomicUsize::new).collect(),
            owner_dead: (0..threads).map(|_| AtomicBool::new(false)).collect(),
            quarantined: (0..nshards).map(|_| AtomicBool::new(false)).collect(),
            any_quarantine: AtomicBool::new(false),
            beats: (0..threads).map(|_| AtomicU64::new(0)).collect(),
            epoch0: Instant::now(),
            op_timeout_ns: AtomicU64::new(0),
            owner_dead_after_ns: AtomicU64::new(0),
            batch_n,
            at: FabricAtomics::default(),
            combining: AtomicBool::new(true),
            poisoned: AtomicBool::new(false),
            interleave_w: (0..threads).map(|_| AtomicUsize::new(DEFAULT_INTERLEAVE)).collect(),
            interleave_pin: AtomicUsize::new(0),
        }
    }

    /// Pin every owner's interleave width to `k` (`run --interleave k` and
    /// the Table XIV sweep); `0` restores per-owner adaptation. Width 1
    /// still routes scattered runs through the interleaved engine — as a
    /// single serialized lane, the Table XIV baseline.
    pub fn set_interleave_width(&self, k: usize) {
        self.interleave_pin.store(k, Ordering::Relaxed);
    }

    /// Toggle owner-side operation combining (on by default).
    pub fn set_combining(&self, on: bool) {
        self.combining.store(on, Ordering::Relaxed);
    }

    pub fn combining_enabled(&self) -> bool {
        self.combining.load(Ordering::Relaxed)
    }

    /// Mark the fabric dead (an owner unwound mid-execution); see the
    /// `poisoned` field.
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
    }

    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// Bound sync waits and handoff backpressure loops: after `d`, a sync
    /// caller abandons its slot with [`FabricError::Timeout`] and a wedged
    /// handoff falls back to Direct-mode execution. `None` (the default)
    /// restores unbounded waits.
    pub fn set_op_timeout(&self, d: Option<Duration>) {
        self.op_timeout_ns
            .store(d.map(|d| d.as_nanos() as u64).unwrap_or(0), Ordering::Relaxed);
    }

    /// Enable heartbeat-based frozen-owner detection: an owner whose beat
    /// is staler than `d` while batches sit in its queue is declared dead
    /// and its work adopted by a survivor. `None` (the default) disables
    /// detection; explicit kills are still detected synchronously.
    pub fn set_owner_dead_after(&self, d: Option<Duration>) {
        self.owner_dead_after_ns
            .store(d.map(|d| d.as_nanos() as u64).unwrap_or(0), Ordering::Relaxed);
    }

    /// Whether owner thread `t` has been declared dead.
    pub fn owner_dead(&self, t: usize) -> bool {
        self.owner_dead[t].load(Ordering::SeqCst)
    }

    /// Whether `shard` is quarantined (owner died to a genuine panic);
    /// quarantined shards are served by Direct-mode fallback.
    pub fn is_quarantined(&self, shard: usize) -> bool {
        self.any_quarantine.load(Ordering::Relaxed) && self.quarantined[shard].load(Ordering::SeqCst)
    }

    #[inline]
    fn now_ns(&self) -> u64 {
        self.epoch0.elapsed().as_nanos() as u64
    }

    #[inline]
    fn beat(&self, t: usize) {
        self.beats[t].store(self.now_ns(), Ordering::Relaxed);
    }

    /// Deadline for one sync wait / handoff attempt, if bounded.
    #[inline]
    fn deadline(&self) -> Option<Instant> {
        let ns = self.op_timeout_ns.load(Ordering::Relaxed);
        (ns > 0).then(|| Instant::now() + Duration::from_nanos(ns))
    }

    /// Declare owner `t` dead. `clean == true` means the death landed at
    /// an op-envelope boundary (injected kill, or a heartbeat presumed
    /// freeze) so its shards are safely adoptable; `clean == false` is a
    /// genuine mid-execution panic — the owner's shards are quarantined
    /// (Direct-mode fallback) and the fabric is poisoned so in-flight
    /// waits fail typed instead of hanging. Idempotent per owner.
    pub fn mark_owner_dead(&self, t: usize, clean: bool) {
        if self.owner_dead[t].swap(true, Ordering::SeqCst) {
            return;
        }
        self.at.owner_deaths.fetch_add(1, Ordering::SeqCst);
        let now = self.now_ns().max(1);
        let _ =
            self.at.first_death_ns.compare_exchange(0, now, Ordering::SeqCst, Ordering::Relaxed);
        if !clean {
            for s in 0..self.nshards {
                if self.owner_of[s].load(Ordering::SeqCst) == t {
                    self.quarantined[s].store(true, Ordering::SeqCst);
                }
            }
            self.any_quarantine.store(true, Ordering::SeqCst);
            self.poison();
        }
    }

    /// Liveness sweep run by worker `me` from its drain and wait loops:
    /// declare frozen owners dead (heartbeat staleness + a non-empty
    /// queue, when [`OpFabric::set_owner_dead_after`] armed the detector)
    /// and adopt any orphaned work. Cheap when nothing is wrong: one
    /// relaxed load each.
    pub fn check_owners(&self, me: usize) {
        if me >= self.threads || self.owner_dead(me) {
            return;
        }
        let hb = self.owner_dead_after_ns.load(Ordering::Relaxed);
        if hb > 0 {
            let now = self.now_ns();
            for t in 0..self.threads {
                if t == me || self.owner_dead(t) {
                    continue;
                }
                let beat = self.beats[t].load(Ordering::Relaxed);
                if now.saturating_sub(beat) > hb && self.queues[t].stats().depth() > 0 {
                    // Batches are piling up behind a heartbeat that stopped
                    // advancing: presume the owner froze at an op boundary.
                    // A false positive (merely-slow owner) is safe — the
                    // queue is MPMC so every batch still pops exactly once;
                    // only NUMA locality is sacrificed.
                    self.mark_owner_dead(t, true);
                }
            }
        }
        if self.at.owner_deaths.load(Ordering::Relaxed) > 0 {
            self.try_adopt(me);
        }
    }

    /// Adopt orphaned work: claim each dead owner's queue with one CAS on
    /// `queue_owner` (exactly one survivor wins and drains it) and re-home
    /// its non-quarantined shards with per-shard CASes on `owner_of` (new
    /// dispatches then route to the adopter's own queue).
    fn try_adopt(&self, me: usize) {
        for q in 0..self.threads {
            let cur = self.queue_owner[q].load(Ordering::SeqCst);
            if cur != me
                && self.owner_dead(cur)
                && self.queue_owner[q]
                    .compare_exchange(cur, me, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
            {
                let now = self.now_ns().max(1);
                let _ = self.at.first_takeover_ns.compare_exchange(
                    0,
                    now,
                    Ordering::SeqCst,
                    Ordering::Relaxed,
                );
            }
        }
        for s in 0..self.nshards {
            let cur = self.owner_of[s].load(Ordering::SeqCst);
            if cur != me
                && self.owner_dead(cur)
                && !self.quarantined[s].load(Ordering::SeqCst)
                && self.owner_of[s]
                    .compare_exchange(cur, me, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
            {
                self.at.shards_adopted.fetch_add(1, Ordering::SeqCst);
            }
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn num_callers(&self) -> usize {
        self.slots.len()
    }

    pub fn num_shards(&self) -> usize {
        self.nshards
    }

    /// Owner thread of a shard (the adopter, after a takeover).
    #[inline]
    pub fn owner_of_shard(&self, shard: usize) -> usize {
        self.owner_of[shard].load(Ordering::Relaxed)
    }

    /// Owner thread of a key.
    #[inline]
    pub fn owner_of_key(&self, key: u64) -> usize {
        self.owner_of_shard(shard_of_key(key, self.nshards))
    }

    /// Home NUMA node of a shard under this fabric's thread count (eq. 7).
    #[inline]
    pub fn home_node(&self, shard: usize) -> usize {
        self.topology.shard_home(shard, self.threads)
    }

    /// Whether `thread` sits on `shard`'s home node.
    #[inline]
    pub fn local_to(&self, thread: usize, shard: usize) -> bool {
        self.topology.node_of_cpu(thread) == self.home_node(shard)
    }

    /// Create the caller handle for completion slot `id`. Worker threads
    /// that also own shards pass their own thread id as `as_owner` so
    /// self-delegated batches execute inline (and so the backpressure loop
    /// can drain their own queue while waiting); slot-only callers pass
    /// `None`. One handle per slot at a time — the sync rendezvous assumes
    /// a single outstanding call per slot. Every handle created MUST
    /// eventually [`Caller::finish`]: [`OpFabric::all_quiet`] waits for all
    /// started handles, so create them *before* any thread can start
    /// polling quiescence (the engine creates one per worker ahead of the
    /// drain barrier).
    pub fn caller(&self, id: usize, as_owner: Option<usize>) -> Caller<'_> {
        assert!(id < self.slots.len());
        if let Some(t) = as_owner {
            assert!(t < self.threads);
        }
        self.at.callers_started.fetch_add(1, Ordering::SeqCst);
        Caller {
            fabric: self,
            id,
            as_owner,
            staged: (0..self.threads).map(|_| Vec::new()).collect(),
            flush_n: (0..self.threads).map(|_| self.batch_n).collect(),
            delegated: 0,
            finished: false,
        }
    }

    /// Owner-side drain: pop and execute up to `max_batches` batches from
    /// `who`'s queue against the local shard(s). Returns ops executed.
    /// Poisons the fabric if execution unwinds, so parked callers fail
    /// fast instead of hanging on a completion that will never come.
    ///
    /// With combining enabled (the default), the drain is a **combiner**:
    /// it pops a window of pending batches, merges their point envelopes
    /// across callers into one key-sorted run per shard, coalesces
    /// duplicate finds, and applies each run through the shard — clustered
    /// runs via the fused
    /// [`crate::coordinator::OrderedKv::apply_sorted_run`] (one descent
    /// per group of nearby keys instead of one per envelope), scattered
    /// runs via the interleaved
    /// [`crate::coordinator::OrderedKv::apply_interleaved`] (k independent
    /// descents overlapped at the owner's adaptive width; see
    /// [`run_is_clustered`] for the dispatch test). Completion
    /// counters still settle per caller (every original op acks its own
    /// caller's slot). Ordering: per-caller per-key order among point ops
    /// survives (batches pop FIFO and the run sort is stable); ordering
    /// *across* keys, and between point ops and `Batch`/`Range` envelopes
    /// within one window, is not preserved — indistinguishable from the
    /// concurrent interleavings async callers already accept. Sync batches
    /// never enter the pool (a parked caller is spinning on the result).
    pub fn drain(&self, who: usize, store: &ShardedStore, max_batches: usize) -> u64 {
        // Injected slow owner: stretches the drain-entry window so the
        // heartbeat detector has something to detect.
        fail::point("fabric.owner.slow");
        if self.owner_dead(who) {
            // Declared dead (injected kill, or a heartbeat takeover while
            // we were frozen): stand down as an owner. Our queue has been
            // (or is being) adopted by a survivor; the thread itself lives
            // on as a plain caller.
            return 0;
        }
        self.beat(who);
        self.check_owners(who);
        if self.is_poisoned() {
            // Fail-stop path (genuine panic elsewhere): settle everything
            // still queued as errors so callers unblock and the quiescence
            // balance `executed + errored == submitted` closes.
            return self.fail_pending(who);
        }
        let mut ops = self.drain_queue(who, who, store, max_batches);
        // Orphaned queues adopted by this thread drain on the same cadence.
        if self.at.owner_deaths.load(Ordering::Relaxed) > 0 {
            for q in 0..self.threads {
                if q != who && self.queue_owner[q].load(Ordering::SeqCst) == who {
                    ops += self.drain_queue(q, who, store, max_batches);
                }
            }
        }
        ops
    }

    /// Drain queue `q` as thread `me`, supervising the execution: an
    /// injected op-boundary kill ([`fail::InjectedKill`]) is caught here —
    /// `me` is declared cleanly dead and stands down, losing no work
    /// (kill sites only fire while no popped batch is in flight). Any
    /// other unwind is a genuine bug: `me`'s shards are quarantined, the
    /// fabric is poisoned, and the panic propagates for diagnosis.
    fn drain_queue(&self, q: usize, me: usize, store: &ShardedStore, max_batches: usize) -> u64 {
        let run = std::panic::catch_unwind(AssertUnwindSafe(|| {
            self.drain_queue_inner(q, me, store, max_batches)
        }));
        match run {
            Ok(n) => n,
            Err(payload) => {
                if payload.downcast_ref::<fail::InjectedKill>().is_some() {
                    self.mark_owner_dead(me, true);
                    0
                } else {
                    self.mark_owner_dead(me, false);
                    std::panic::resume_unwind(payload);
                }
            }
        }
    }

    fn drain_queue_inner(
        &self,
        q: usize,
        me: usize,
        store: &ShardedStore,
        max_batches: usize,
    ) -> u64 {
        let adopted = q != me;
        let queue = &self.queues[q];
        // Depth sample: drain is also called from idle spin loops, so only
        // pay the shared-line RMW when this could actually raise the peak.
        let depth = queue.stats().depth();
        if depth > 0 && depth > self.at.peak_depth.load(Ordering::Relaxed) {
            self.at.peak_depth.fetch_max(depth, Ordering::Relaxed);
        }
        let combine = self.combining.load(Ordering::Relaxed);
        let mut ops = 0;
        let mut left = max_batches;
        loop {
            // Op-envelope boundary: no popped batch is in flight here, so
            // an injected kill can never strand work — everything not yet
            // popped stays in the queue for the adopter; every fully
            // popped window was fully executed.
            fail::point("fabric.owner.kill");
            let window = left.min(COMBINE_WINDOW);
            if window == 0 {
                break;
            }
            let mut popped: Vec<OpBatch> = Vec::new();
            let mut got = 0usize;
            while got < window {
                let Some(batch) = queue.pop() else { break };
                got += 1;
                ops += batch.ops.len() as u64;
                // Handoff latency is recorded here, at pop time, so every
                // queued batch is measured exactly once no matter which
                // execution branch it takes (combined, single-batch or
                // sync) — recording inside the execute paths skewed
                // `fabric:` metrics whenever combining was low.
                self.at
                    .handoff_ns
                    .fetch_add(batch.staged_at.elapsed().as_nanos() as u64, Ordering::Relaxed);
                self.at.queued_batches.fetch_add(1, Ordering::Relaxed);
                if adopted {
                    self.at.adopted_batches.fetch_add(1, Ordering::Relaxed);
                }
                if batch.sync || !combine {
                    // A sync op must observe everything its caller staged
                    // before it (Caller::call's FIFO promise): run the
                    // pooled prefix first, then the sync batch.
                    self.flush_popped(me, &mut popped, store);
                    self.execute_batch(me, batch, store);
                } else {
                    popped.push(batch);
                }
            }
            self.flush_popped(me, &mut popped, store);
            left -= got;
            if got < window {
                break; // queue drained
            }
        }
        ops
    }

    /// Poisoned-fabric drain: pop everything from `who`'s queue (and any
    /// queues it adopted) and settle each op as an error — slots record
    /// `errored` instead of `acked`, parked sync callers get
    /// `Err(Poisoned)`, and the global ledger keeps
    /// `executed + errored == submitted` so termination loops still
    /// quiesce.
    fn fail_pending(&self, who: usize) -> u64 {
        let mut ops = 0;
        for q in 0..self.threads {
            if q != who && self.queue_owner[q].load(Ordering::SeqCst) != who {
                continue;
            }
            while let Some(batch) = self.queues[q].pop() {
                ops += self.fail_batch(batch);
            }
        }
        ops
    }

    fn fail_batch(&self, batch: OpBatch) -> u64 {
        let OpBatch { caller, sync, staged_at: _, ops } = batch;
        let slot = &self.slots[caller as usize];
        let n = ops.len() as u64;
        slot.errored.fetch_add(n, Ordering::Relaxed);
        self.at.errored.fetch_add(n, Ordering::SeqCst);
        if sync {
            self.settle_sync(slot, Err(FabricError::Poisoned));
        }
        n
    }

    /// Execute a pooled window: per-envelope for a single batch (no merge
    /// win), combined for ≥ 2. Leaves `popped` empty.
    fn flush_popped(&self, who: usize, popped: &mut Vec<OpBatch>, store: &ShardedStore) {
        match popped.len() {
            0 => {}
            1 => self.execute_batch(who, popped.pop().unwrap(), store),
            _ => self.execute_combined(who, std::mem::take(popped), store),
        }
    }

    /// Combine ≥ 2 popped batches: pool every point envelope, stable-sort
    /// the pool once by key, and apply each contiguous prefix-segment
    /// slice as one fused sorted run on its shard (the key space is
    /// partitioned by 3-MSB prefix, so sorted order *is* shard order — the
    /// same zero-scatter slicing `ShardedStore::insert_batch` uses; no
    /// per-shard `Vec`s). `Batch` and `Range` envelopes execute
    /// per-envelope in pop order (a `Batch` already *is* a fused
    /// single-shard run downstream).
    fn execute_combined(&self, who: usize, popped: Vec<OpBatch>, store: &ShardedStore) {
        self.at.combined_drains.fetch_add(1, Ordering::Relaxed);
        self.at.combined_batches.fetch_add(popped.len() as u64, Ordering::Relaxed);
        let mut pool: Vec<PointEntry> = Vec::new();
        let mut direct = 0u64; // envelopes executed outside the pool
        for batch in popped {
            let OpBatch { caller, sync: _, staged_at: _, ops } = batch;
            // (handoff_ns / queued_batches were already recorded at pop
            // time in `drain` — uniformly with the sync and single-batch
            // branches)
            self.at.batches.fetch_add(1, Ordering::Relaxed);
            let slot = &self.slots[caller as usize];
            for op in ops {
                match op {
                    DelegatedOp::Insert { key, value } => {
                        pool.push(PointEntry { op: BatchOp::Insert(key, value), caller })
                    }
                    DelegatedOp::Find { key } => {
                        pool.push(PointEntry { op: BatchOp::Get(key), caller })
                    }
                    DelegatedOp::Erase { key } => {
                        pool.push(PointEntry { op: BatchOp::Erase(key), caller })
                    }
                    other => {
                        let shard = other.shard(self.nshards);
                        self.execute_op(who, shard, other, store, slot);
                        slot.acked.fetch_add(1, Ordering::Relaxed);
                        direct += 1;
                    }
                }
            }
        }
        // stable sort by key: batches pop FIFO per owner, so each caller's
        // per-key op order survives the merge
        pool.sort_by_key(|e| e.op.key());
        let mut lo = 0usize;
        while lo < pool.len() {
            // one contiguous prefix segment = one shard's slice (folded
            // prefixes land on the same shard but still apply per segment,
            // mirroring the store's routing)
            let prefix = pool[lo].op.key() >> 61;
            let shard = shard_of_key(pool[lo].op.key(), self.nshards);
            let mut hi = lo + 1;
            while hi < pool.len() && pool[hi].op.key() >> 61 == prefix {
                hi += 1;
            }
            let slice = &pool[lo..hi];
            self.at.combined_runs.fetch_add(1, Ordering::Relaxed);
            if !self.local_to(who, shard) {
                // never for fabric-routed batches; see FabricStats
                self.at.remote_exec.fetch_add(slice.len() as u64, Ordering::Relaxed);
            }
            // build the run, coalescing ADJACENT identical finds (a find
            // separated from its twin by a same-key write must see the
            // write, so only gap-free duplicates share one execution)
            let mut run: Vec<BatchOp> = Vec::with_capacity(slice.len());
            let mut spans: Vec<(u32, u32)> = Vec::with_capacity(slice.len());
            let mut j = 0usize;
            while j < slice.len() {
                let op = slice[j].op;
                let mut len = 1usize;
                if let BatchOp::Get(k) = op {
                    while j + len < slice.len() && slice[j + len].op == BatchOp::Get(k) {
                        len += 1;
                    }
                }
                if len > 1 {
                    self.at.coalesced_finds.fetch_add((len - 1) as u64, Ordering::Relaxed);
                }
                run.push(op);
                spans.push((j as u32, len as u32));
                j += len;
            }
            // one application on the owner's NUMA-local shard; every
            // original op settles its own caller's completion slot
            let spans_ref = &spans;
            let mut settle = |ri: usize, reply: BatchReply| {
                // one shard dereference per *executed* run op: an N-way
                // coalesced find reads the shard once, so locality (and
                // the remote-latency model) is charged once — charging
                // inside the per-entry loop below over-counted it N times
                let (start, len) = spans_ref[ri];
                store.account_shard(who, shard);
                for e in &slice[start as usize..(start as usize + len as usize)] {
                    let slot = &self.slots[e.caller as usize];
                    match reply {
                        BatchReply::Applied(ok) => {
                            slot.applied.fetch_add(ok as u64, Ordering::Relaxed);
                        }
                        BatchReply::Value(v) => {
                            slot.hits.fetch_add(v.is_some() as u64, Ordering::Relaxed);
                        }
                    }
                    slot.acked.fetch_add(1, Ordering::Relaxed);
                }
            };
            // per-drain dispatch: clustered windows keep the PR-5 fused
            // shared-walk descent; scattered ones overlap their independent
            // miss chains through the interleaved engine at the owner's
            // adaptive width
            if run_is_clustered(&run, store.shard_at(shard).cluster_gap()) {
                self.at.fused_runs.fetch_add(1, Ordering::Relaxed);
                store.shard_at(shard).apply_sorted_run(&run, &mut settle);
            } else {
                let width = self.pick_interleave(who, run.len());
                self.at.interleaved_runs.fetch_add(1, Ordering::Relaxed);
                store.shard_at(shard).apply_interleaved(&run, width, &mut settle);
            }
            lo = hi;
        }
        self.at.executed.fetch_add(direct + pool.len() as u64, Ordering::SeqCst);
    }

    /// Interleave width for a scattered run on `who`'s shard, adapted like
    /// the callers' flush threshold: a run at least twice the current width
    /// doubles it for the next drain (more independent chains available to
    /// overlap than lanes to hold them), a run below the current width
    /// halves it (lanes would sit empty). The *current* width is used for
    /// this run; adaptation only steers future drains. A non-zero
    /// [`OpFabric::set_interleave_width`] pin short-circuits all of it.
    fn pick_interleave(&self, who: usize, run_len: usize) -> usize {
        let pin = self.interleave_pin.load(Ordering::Relaxed);
        if pin > 0 {
            return pin;
        }
        let w = &self.interleave_w[who];
        let cur = w.load(Ordering::Relaxed);
        if run_len >= cur * 2 && cur < INTERLEAVE_MAX_W {
            w.store((cur * 2).min(INTERLEAVE_MAX_W), Ordering::Relaxed);
        } else if run_len < cur && cur > INTERLEAVE_MIN_W {
            w.store((cur / 2).max(INTERLEAVE_MIN_W), Ordering::Relaxed);
        }
        cur
    }

    /// Batches currently enqueued across all owner queues (single-snapshot
    /// per queue; see [`RouterFabric::pending`] for the approximation).
    pub fn pending_batches(&self) -> u64 {
        self.queues.iter().map(|q| q.stats().depth()).sum()
    }

    /// True once every *started* caller handle has [`Caller::finish`]ed and
    /// every submitted op has settled — executed, or errored out by the
    /// poisoned-fabric drain (`executed + errored == submitted`): no work
    /// is queued or in flight anywhere, so owner loops can exit. Callers
    /// that will participate must be created before quiescence polling
    /// starts (see [`OpFabric::caller`]); unused completion slots don't
    /// count.
    pub fn all_quiet(&self) -> bool {
        // `started` is loaded first: a handle created after this load can
        // only push `done` past the snapshot, which fails the equality —
        // conservative, never a false "quiet".
        let started = self.at.callers_started.load(Ordering::SeqCst);
        started > 0
            && self.at.callers_done.load(Ordering::SeqCst) == started
            && self.at.executed.load(Ordering::SeqCst) + self.at.errored.load(Ordering::SeqCst)
                == self.at.submitted.load(Ordering::SeqCst)
    }

    /// Async completion counters for caller `id`.
    pub fn slot_totals(&self, id: usize) -> SlotTotals {
        let s = &self.slots[id];
        SlotTotals {
            acked: s.acked.load(Ordering::Relaxed),
            hits: s.hits.load(Ordering::Relaxed),
            rows: s.rows.load(Ordering::Relaxed),
            applied: s.applied.load(Ordering::Relaxed),
            errored: s.errored.load(Ordering::Relaxed),
        }
    }

    pub fn stats(&self) -> FabricStats {
        FabricStats {
            submitted: self.at.submitted.load(Ordering::SeqCst),
            executed: self.at.executed.load(Ordering::SeqCst),
            batches: self.at.batches.load(Ordering::Relaxed),
            queued_batches: self.at.queued_batches.load(Ordering::Relaxed),
            inline_ops: self.at.inline_ops.load(Ordering::Relaxed),
            sync_calls: self.at.sync_calls.load(Ordering::Relaxed),
            backpressure: self.at.backpressure.load(Ordering::Relaxed),
            handoff_ns: self.at.handoff_ns.load(Ordering::Relaxed),
            peak_depth: self.at.peak_depth.load(Ordering::Relaxed),
            remote_exec: self.at.remote_exec.load(Ordering::Relaxed),
            combined_drains: self.at.combined_drains.load(Ordering::Relaxed),
            combined_batches: self.at.combined_batches.load(Ordering::Relaxed),
            combined_runs: self.at.combined_runs.load(Ordering::Relaxed),
            fused_runs: self.at.fused_runs.load(Ordering::Relaxed),
            interleaved_runs: self.at.interleaved_runs.load(Ordering::Relaxed),
            coalesced_finds: self.at.coalesced_finds.load(Ordering::Relaxed),
            flush_grow: self.at.flush_grow.load(Ordering::Relaxed),
            flush_shrink: self.at.flush_shrink.load(Ordering::Relaxed),
            errored: self.at.errored.load(Ordering::SeqCst),
            owner_deaths: self.at.owner_deaths.load(Ordering::SeqCst),
            shards_adopted: self.at.shards_adopted.load(Ordering::SeqCst),
            adopted_batches: self.at.adopted_batches.load(Ordering::Relaxed),
            direct_fallback: self.at.direct_fallback.load(Ordering::Relaxed),
            sync_timeouts: self.at.sync_timeouts.load(Ordering::Relaxed),
            recovery_ns: {
                let death = self.at.first_death_ns.load(Ordering::SeqCst);
                let takeover = self.at.first_takeover_ns.load(Ordering::SeqCst);
                if death > 0 && takeover > death {
                    takeover - death
                } else {
                    0
                }
            },
        }
    }

    /// Hand one sealed batch to `owner`: inline if the dispatching thread
    /// *is* the owner (no queue round-trip, no self-deadlock on a full
    /// queue), otherwise queued with a backpressure loop that keeps the
    /// helper's own queue draining while it waits. `Ok(pushed_back)`
    /// reports whether the push hit backpressure (the caller's adaptive
    /// flush threshold grows on it); `Err(batch)` hands the batch back
    /// when the handoff gave up — fabric poisoned, or the configured op
    /// deadline elapsed — so the caller can fall back to Direct-mode
    /// execution (`submitted` is already counted; the fallback's
    /// `execute_batch` keeps the ledger balanced).
    fn dispatch(
        &self,
        owner: usize,
        batch: OpBatch,
        helper: Option<usize>,
        store: &ShardedStore,
    ) -> Result<bool, OpBatch> {
        self.at.submitted.fetch_add(batch.ops.len() as u64, Ordering::SeqCst);
        if helper == Some(owner) {
            self.at.inline_ops.fetch_add(batch.ops.len() as u64, Ordering::Relaxed);
            self.execute_batch(owner, batch, store);
            return Ok(false);
        }
        let deadline = self.deadline();
        let mut b = Backoff::new();
        let mut batch = batch;
        let mut pushed_back = false;
        loop {
            if self.is_poisoned() {
                return Err(batch);
            }
            match self.queues[owner].try_push(batch) {
                Ok(()) => return Ok(pushed_back),
                Err(back) => {
                    batch = back;
                    pushed_back = true;
                    self.at.backpressure.fetch_add(1, Ordering::Relaxed);
                    if let Some(h) = helper {
                        // Make progress on our own queue instead of spinning:
                        // breaks caller↔owner full-queue cycles.
                        self.drain(h, store, 4);
                    } else {
                        // Slot-only callers can't adopt, but a full queue
                        // with a dead owner needs *someone* to notice.
                        self.check_owners(owner);
                    }
                    if let Some(d) = deadline {
                        if Instant::now() >= d {
                            return Err(batch);
                        }
                    }
                    b.wait();
                }
            }
        }
    }

    /// Execute one batch on thread `who` (the owner, or a caller running
    /// the inline shortcut — in which case `who == owner` by construction).
    /// Handoff accounting is not done here: queued batches are measured at
    /// pop time in [`OpFabric::drain`], inline batches never queue.
    fn execute_batch(&self, who: usize, batch: OpBatch, store: &ShardedStore) {
        let OpBatch { caller, sync, staged_at: _, ops } = batch;
        self.at.batches.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[caller as usize];
        let n = ops.len() as u64;
        debug_assert!(!sync || n == 1, "sync batches carry exactly one op");
        for op in ops {
            let shard = op.shard(self.nshards);
            let result = self.execute_op(who, shard, op, store, slot);
            slot.acked.fetch_add(1, Ordering::Relaxed);
            if sync {
                self.settle_sync(slot, Ok(result));
            }
        }
        self.at.executed.fetch_add(n, Ordering::SeqCst);
    }

    /// Publish a sync result (or error) into `slot`. The WAITING → CLAIMED
    /// CAS grants exclusive write access; losing it means the caller
    /// abandoned the slot on deadline — the result is dropped and the slot
    /// recycled to IDLE so the caller can arm it again. A late settle can
    /// therefore never publish a stale result into a *reused* slot.
    fn settle_sync(&self, slot: &CompletionSlot, result: Result<OpResult, FabricError>) {
        // Injected delayed ack: stretches the settle window (Delay only —
        // a kill here would strand the already-executed op's accounting).
        fail::point("fabric.settle");
        match slot.state.compare_exchange(
            SLOT_WAITING,
            SLOT_CLAIMED,
            Ordering::Acquire,
            Ordering::Relaxed,
        ) {
            Ok(_) => {
                unsafe { *slot.result.get() = result };
                slot.state.store(SLOT_DONE, Ordering::Release);
            }
            Err(_) => {
                // Caller walked away (ABANDONED): nobody will read the
                // result; hand the slot back for reuse.
                let _ = slot.state.compare_exchange(
                    SLOT_ABANDONED,
                    SLOT_IDLE,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                );
            }
        }
    }

    /// Execute one envelope against `shard` (accounting + slot counters;
    /// `acked` and `executed` are the caller's responsibility). Shared by
    /// the per-envelope path and the combiner's `Batch`/`Range` lane.
    fn execute_op(
        &self,
        who: usize,
        shard: usize,
        op: DelegatedOp,
        store: &ShardedStore,
        slot: &CompletionSlot,
    ) -> OpResult {
        if !self.local_to(who, shard) {
            // Never happens for fabric-routed batches; the counter
            // surfaces any future routing regression in `stats()`.
            self.at.remote_exec.fetch_add(1, Ordering::Relaxed);
        }
        store.account_shard(who, shard);
        match op {
            DelegatedOp::Insert { key, value } => {
                let ok = store.shard_at(shard).insert(key, value);
                slot.applied.fetch_add(ok as u64, Ordering::Relaxed);
                OpResult::Applied(ok)
            }
            DelegatedOp::Find { key } => {
                let v = store.shard_at(shard).get(key);
                slot.hits.fetch_add(v.is_some() as u64, Ordering::Relaxed);
                OpResult::Value(v)
            }
            DelegatedOp::Erase { key } => {
                let ok = store.shard_at(shard).erase(key);
                slot.applied.fetch_add(ok as u64, Ordering::Relaxed);
                OpResult::Applied(ok)
            }
            DelegatedOp::Batch { items } => {
                // Release-checked: a mis-split batch would insert keys
                // into a shard that routed lookups never visit — a
                // silent wrong-answer, so fail loudly instead.
                assert!(
                    items.iter().all(|&(k, _)| shard_of_key(k, self.nshards) == shard),
                    "Batch envelope must be pre-split to one shard \
                     (use Caller::delegate_insert_batch)"
                );
                let c = store.shard_at(shard).insert_batch(&items);
                slot.applied.fetch_add(c, Ordering::Relaxed);
                OpResult::Count(c)
            }
            DelegatedOp::Range { lo, hi } => {
                // Release-checked like Batch: an unclamped window would
                // silently drop every row outside the first segment.
                assert_eq!(
                    lo >> 61,
                    hi >> 61,
                    "Range envelope must be pre-clamped to one prefix segment \
                     (use Caller::delegate_range)"
                );
                let rows = store.shard_at(shard).range(lo, hi);
                slot.rows.fetch_add(rows.len() as u64, Ordering::Relaxed);
                OpResult::Rows(rows)
            }
        }
    }

    fn note_caller_done(&self) {
        self.at.callers_done.fetch_add(1, Ordering::SeqCst);
    }
}

/// Caller-side handle: per-owner staging buffers with flush-on-N, plus the
/// synchronous rendezvous path. Obtain via [`OpFabric::caller`].
pub struct Caller<'f> {
    fabric: &'f OpFabric,
    id: usize,
    as_owner: Option<usize>,
    staged: Vec<Vec<DelegatedOp>>,
    /// Per-owner adaptive flush threshold: doubled when the owner's queue
    /// pushes back (a congested handoff wants fewer, deeper batches — and
    /// hands the combiner more to merge per drain), halved back toward the
    /// fabric's `batch_n` when a flush finds the owner's queue empty
    /// (caught-up owner: no reason to hold completions back). Clamped to
    /// `[batch_n, batch_n*4]` — `batch_n` is the floor, so occupancy never
    /// degrades below the configured amortization.
    flush_n: Vec<usize>,
    delegated: u64,
    finished: bool,
}

impl Caller<'_> {
    /// Completion-slot id of this caller.
    pub fn id(&self) -> usize {
        self.id
    }

    /// Ops delegated through this handle so far.
    pub fn delegated(&self) -> u64 {
        self.delegated
    }

    /// Stage one envelope toward its shard's owner; flushes that owner's
    /// buffer when it reaches the adaptive threshold (seeded at the
    /// fabric's `batch_n`; see [`Caller::flush_n`]). Quarantined shards
    /// (owner died to a genuine panic) bypass the fabric entirely and
    /// execute Direct-mode on this thread.
    pub fn delegate(&mut self, op: DelegatedOp, store: &ShardedStore) {
        let shard = op.shard(self.fabric.nshards);
        if self.fabric.is_quarantined(shard) {
            self.delegated += 1;
            let _ = self.direct_exec(op, store);
            return;
        }
        let owner = self.fabric.owner_of_shard(shard);
        self.staged[owner].push(op);
        self.delegated += 1;
        if self.staged[owner].len() >= self.flush_n[owner] {
            self.flush_owner(owner, store);
        }
    }

    /// Direct-mode fallback: execute one envelope on this thread, settling
    /// this caller's own slot counters and keeping the fabric ledger
    /// balanced. Used for quarantined shards and timed-out sync handoffs —
    /// correctness holds because the data plane is thread-safe everywhere;
    /// only NUMA locality is sacrificed (and accounted via `remote_exec`).
    fn direct_exec(&self, op: DelegatedOp, store: &ShardedStore) -> OpResult {
        let f = self.fabric;
        let shard = op.shard(f.nshards);
        let who = self.as_owner.unwrap_or_else(|| f.owner_of_shard(shard));
        f.at.submitted.fetch_add(1, Ordering::SeqCst);
        f.at.direct_fallback.fetch_add(1, Ordering::Relaxed);
        let slot = &f.slots[self.id];
        let r = f.execute_op(who, shard, op, store, slot);
        slot.acked.fetch_add(1, Ordering::Relaxed);
        f.at.executed.fetch_add(1, Ordering::SeqCst);
        r
    }

    /// Split a `[lo, hi]` range scan into per-prefix sub-scans and delegate
    /// each to its owning shard's thread — the cross-shard case the Direct
    /// path resolves by dereferencing remote shards. Returns the number of
    /// sub-ops staged; their row counts aggregate into this caller's slot.
    pub fn delegate_range(&mut self, lo: u64, hi: u64, store: &ShardedStore) -> u64 {
        let mut n = 0;
        for_each_prefix_segment(lo, hi, |slo, shi| {
            self.delegate(DelegatedOp::Range { lo: slo, hi: shi }, store);
            n += 1;
        });
        n
    }

    /// Split a bulk insert into per-shard slices and delegate each as one
    /// [`DelegatedOp::Batch`] envelope. Returns the envelopes staged.
    pub fn delegate_insert_batch(&mut self, items: &[(u64, u64)], store: &ShardedStore) -> u64 {
        let mut per: Vec<Vec<(u64, u64)>> =
            (0..self.fabric.nshards).map(|_| Vec::new()).collect();
        for &(k, v) in items {
            per[shard_of_key(k, self.fabric.nshards)].push((k, v));
        }
        let mut n = 0;
        for items in per {
            if !items.is_empty() {
                self.delegate(DelegatedOp::Batch { items }, store);
                n += 1;
            }
        }
        n
    }

    /// Flush every staged buffer (the on-drain flush).
    pub fn flush(&mut self, store: &ShardedStore) {
        for owner in 0..self.staged.len() {
            self.flush_owner(owner, store);
        }
    }

    fn flush_owner(&mut self, owner: usize, store: &ShardedStore) {
        if self.staged[owner].is_empty() {
            return;
        }
        let lo = self.fabric.batch_n;
        let hi = self.fabric.batch_n.saturating_mul(4);
        // Adapt down toward the configured floor: an empty owner queue
        // means the owner caught up — no reason to hold completions back.
        // (Skipped for the inline self-delegation lane, which never queues.)
        if Some(owner) != self.as_owner
            && self.flush_n[owner] > lo
            && self.fabric.queues[owner].stats().depth() == 0
        {
            self.flush_n[owner] = (self.flush_n[owner] / 2).max(lo);
            self.fabric.at.flush_shrink.fetch_add(1, Ordering::Relaxed);
        }
        // Keep a threshold-capacity buffer behind: flush-on-N would
        // otherwise pay the 1→2→…→N growth reallocations on every batch.
        let ops = std::mem::replace(
            &mut self.staged[owner],
            Vec::with_capacity(self.flush_n[owner]),
        );
        let batch =
            OpBatch { caller: self.id as u32, sync: false, staged_at: Instant::now(), ops };
        // Adapt up on backpressure: a full owner queue wants fewer, deeper
        // batches (which also hands the combiner more to merge per drain).
        match self.fabric.dispatch(owner, batch, self.as_owner, store) {
            Ok(pushed_back) => {
                if pushed_back && self.flush_n[owner] < hi {
                    self.flush_n[owner] = (self.flush_n[owner] * 2).min(hi);
                    self.fabric.at.flush_grow.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(batch) => {
                // Handoff gave up (deadline elapsed or fabric poisoned):
                // Direct-mode fallback keeps the ops moving and the ledger
                // balanced — `submitted` was counted by dispatch, and
                // execute_batch counts `executed`.
                let me = self.as_owner.unwrap_or(owner);
                self.fabric.at.direct_fallback.fetch_add(batch.ops.len() as u64, Ordering::Relaxed);
                self.fabric.execute_batch(me, batch, store);
            }
        }
    }

    /// Synchronous delegation: flush (preserving per-owner FIFO order with
    /// everything staged so far), ship the op, park on this caller's
    /// completion slot until a settler publishes the result. Owners must
    /// be draining concurrently unless the op targets this caller's own
    /// shard (then it executes inline). The wait escalates spin → yield →
    /// deadline ([`Backoff`] phases + [`OpFabric::set_op_timeout`]); on
    /// deadline the slot is abandoned and the caller gets
    /// `Err(Timeout)` — or `Err(OwnerDead)` when the target owner is
    /// marked dead and nobody has adopted the op yet. A poisoned fabric
    /// yields `Err(Poisoned)` instead of the old panic.
    pub fn call(&mut self, op: DelegatedOp, store: &ShardedStore) -> Result<OpResult, FabricError> {
        self.flush(store);
        self.delegated += 1;
        self.fabric.at.sync_calls.fetch_add(1, Ordering::Relaxed);
        let shard = op.shard(self.fabric.nshards);
        if self.fabric.is_quarantined(shard) {
            // The shard's owner died un-cleanly: serve Direct-mode.
            return Ok(self.direct_exec(op, store));
        }
        let owner = self.fabric.owner_of_shard(shard);
        let slot = &self.fabric.slots[self.id];
        let deadline = self.fabric.deadline();
        // The slot may still be burned by a previously abandoned call whose
        // settler hasn't recycled it yet: wait for IDLE (bounded by the
        // same deadline) before arming it again — re-arming early would let
        // the late settler publish the *old* op's result into this call.
        let mut b = Backoff::new();
        while slot.state.load(Ordering::Acquire) != SLOT_IDLE {
            if self.fabric.is_poisoned() {
                return Err(FabricError::Poisoned);
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return Err(FabricError::Timeout);
                }
            }
            if let Some(h) = self.as_owner {
                self.fabric.drain(h, store, 4);
            }
            b.wait();
        }
        slot.state.store(SLOT_WAITING, Ordering::Release);
        let batch =
            OpBatch { caller: self.id as u32, sync: true, staged_at: Instant::now(), ops: vec![op] };
        match self.fabric.dispatch(owner, batch, self.as_owner, store) {
            Ok(_) => {}
            Err(batch) => {
                // Handoff gave up: Direct-mode fallback still settles our
                // own slot (execute_batch runs the sync settle protocol),
                // so the wait below completes immediately.
                let me = self.as_owner.unwrap_or(owner);
                self.fabric.at.direct_fallback.fetch_add(1, Ordering::Relaxed);
                self.fabric.execute_batch(me, batch, store);
            }
        }
        let mut b = Backoff::new();
        loop {
            let st = slot.state.load(Ordering::Acquire);
            if st == SLOT_DONE {
                break;
            }
            if st == SLOT_WAITING && self.fabric.is_poisoned() {
                // The poisoned-fabric drain will error-settle us, but may
                // itself be gone: abandon the slot (the CAS keeps the
                // settle race safe) and fail typed.
                if slot
                    .state
                    .compare_exchange(
                        SLOT_WAITING,
                        SLOT_ABANDONED,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    return Err(FabricError::Poisoned);
                }
                continue; // a settler claimed it first — take the result
            }
            if let Some(d) = deadline {
                if st == SLOT_WAITING && Instant::now() >= d {
                    if slot
                        .state
                        .compare_exchange(
                            SLOT_WAITING,
                            SLOT_ABANDONED,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
                    {
                        self.fabric.at.sync_timeouts.fetch_add(1, Ordering::Relaxed);
                        return Err(if self.fabric.owner_dead(owner) {
                            FabricError::OwnerDead
                        } else {
                            FabricError::Timeout
                        });
                    }
                    continue; // settler won the race — take the result
                }
            }
            if let Some(h) = self.as_owner {
                // An owner-caller parked on a remote sync op keeps its own
                // queue moving (other callers may be parked on *us*) and
                // sweeps for dead owners whose work may include our op.
                self.fabric.drain(h, store, 4);
            }
            b.wait();
        }
        let result =
            unsafe { std::mem::replace(&mut *slot.result.get(), Ok(OpResult::Pending)) };
        slot.state.store(SLOT_IDLE, Ordering::Release);
        result
    }

    /// Final flush + publish "this caller is done" for
    /// [`OpFabric::all_quiet`] termination detection.
    pub fn finish(&mut self, store: &ShardedStore) {
        self.flush(store);
        if !self.finished {
            self.finished = true;
            self.fabric.note_caller_done();
        }
    }
}

impl Drop for Caller<'_> {
    fn drop(&mut self) {
        // Skipped while unwinding: asserting here would double-panic into
        // an abort and defeat the fabric's propagate path.
        debug_assert!(
            std::thread::panicking() || self.staged.iter().all(|s| s.is_empty()),
            "Caller dropped with staged ops — call flush()/finish() first"
        );
        // A caller dying mid-unwind (worker panic, test assertion) can
        // never finish(): publish its done-mark anyway so quiescence
        // detection still closes for the survivors. Its un-flushed staged
        // ops were never submitted, so the op ledger stays balanced.
        if std::thread::panicking() && !self.finished {
            self.finished = true;
            self.fabric.note_caller_done();
        }
    }
}

/// RAII guard for the engine's delegated worker bodies: if the worker
/// unwinds (a genuine bug or a caller-side assertion in the workload), the
/// thread is declared a *clean* owner death so survivors adopt its queue
/// and shards and the run completes — the panic itself still propagates to
/// `join` for diagnosis. Deliberately NOT a fabric-wide poison: execution
/// panics inside [`OpFabric::drain`] are supervised there (quarantine +
/// poison), so an unwind seen only here happened *outside* shard
/// execution, where shard state is untouched and peers must not be
/// poisoned over it.
pub(crate) struct RetireOnUnwind<'f> {
    pub(crate) fabric: &'f OpFabric,
    pub(crate) thread: usize,
}

impl Drop for RetireOnUnwind<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.fabric.mark_owner_dead(self.thread, true);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::store::StoreKind;
    use std::sync::Arc;

    #[test]
    fn keys_land_on_home_node_threads() {
        let topo = Topology::virtual_grid(2, 2); // 2 nodes x 2 cpus
        let fabric = RouterFabric::new(4, 8, &topo, 64);
        let mut rng = Rng::new(1);
        // shard 0 (MSBs 000) homes on node 0 -> threads 0,1
        // shard 1 (MSBs 001) homes on node 1 -> threads 2,3
        for i in 0..100u64 {
            fabric.route_key(i, &mut rng); // shard 0
            fabric.route_key(1 << 61 | i, &mut rng); // shard 1
        }
        let n0: u64 = (0..2).map(|t| fabric.queues[t].stats().pushes).sum();
        let n1: u64 = (2..4).map(|t| fabric.queues[t].stats().pushes).sum();
        assert_eq!(n0, 100, "shard-0 keys must stay on node 0");
        assert_eq!(n1, 100, "shard-1 keys must stay on node 1");
    }

    #[test]
    fn pop_local_drains() {
        let topo = Topology::virtual_grid(1, 2);
        let fabric = RouterFabric::new(2, 8, &topo, 64);
        let mut rng = Rng::new(2);
        for i in 0..50u64 {
            fabric.route_key(i, &mut rng);
        }
        let mut got = 0;
        for t in 0..2 {
            while fabric.pop_local(t).is_some() {
                got += 1;
            }
        }
        assert_eq!(got, 50);
        assert_eq!(fabric.pending(), 0);
    }

    #[test]
    fn single_thread_fabric() {
        let fabric = RouterFabric::new(1, 8, &Topology::milan_virtual(), 64);
        let mut rng = Rng::new(3);
        for i in 0..20u64 {
            fabric.route_key(i << 61 | i, &mut rng); // all shards
        }
        let mut got = 0;
        while fabric.pop_local(0).is_some() {
            got += 1;
        }
        assert_eq!(got, 20);
    }

    #[test]
    fn route_uniform_spreads_round_robin() {
        let topo = Topology::virtual_grid(2, 2);
        let fabric = RouterFabric::new(4, 8, &topo, 64);
        for i in 0..40u64 {
            fabric.route_uniform(i); // all shard-0 keys, spread anyway
        }
        for t in 0..4 {
            assert_eq!(fabric.queues[t].stats().pushes, 10, "thread {t}");
        }
    }

    #[test]
    fn owners_sit_on_home_nodes() {
        let topo = Topology::milan_virtual();
        for threads in [1usize, 4, 16, 17, 32, 128] {
            let fabric = OpFabric::new(threads, 0, 8, topo.clone(), 8, 16);
            for s in 0..8 {
                let owner = fabric.owner_of_shard(s);
                assert!(owner < threads);
                assert!(
                    fabric.local_to(owner, s),
                    "threads={threads} shard={s}: owner {owner} must sit on the home node"
                );
            }
        }
    }

    #[test]
    fn owners_round_robin_within_a_node() {
        // 2 nodes x 4 cpus, 8 threads, 8 shards: 4 shards per node must
        // spread over that node's 4 threads instead of piling on one.
        let fabric = OpFabric::new(8, 0, 8, Topology::virtual_grid(2, 4), 8, 16);
        for node in 0..2 {
            let owners: std::collections::HashSet<usize> = (0..8usize)
                .filter(|s| s % 2 == node)
                .map(|s| fabric.owner_of_shard(s))
                .collect();
            assert_eq!(owners.len(), 4, "node {node}: distinct owner per shard");
        }
    }

    #[test]
    fn delegated_ops_execute_on_owners_and_complete() {
        let topo = Topology::virtual_grid(2, 2);
        let threads = 4;
        let store =
            Arc::new(ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 12, topo.clone(), threads));
        let fabric = OpFabric::new(threads, 1, 8, topo, 16, 4);
        let caller_id = threads; // the extra, slot-only caller
        let mut caller = fabric.caller(caller_id, None);
        // stage async inserts across all shards, then drain as each owner
        for i in 0..64u64 {
            let key = (i % 8) << 61 | i;
            caller.delegate(DelegatedOp::Insert { key, value: i }, &store);
        }
        caller.finish(&store);
        for t in 0..threads {
            while fabric.drain(t, &store, usize::MAX) > 0 {}
        }
        assert!(fabric.all_quiet());
        assert_eq!(store.len(), 64);
        let st = fabric.stats();
        assert_eq!(st.submitted, 64);
        assert_eq!(st.executed, 64);
        assert_eq!(st.remote_exec, 0, "owners only touch home-node shards");
        assert!(st.batch_occupancy() >= 2.0, "flush-on-4 batches multiple ops");
        let totals = fabric.slot_totals(caller_id);
        assert_eq!(totals.acked, 64);
        assert_eq!(totals.applied, 64);
        // locality: every executed op was accounted local
        let (local, remote) = store.locality.snapshot();
        assert_eq!(remote, 0);
        assert_eq!(local, 64);
    }

    #[test]
    fn inline_self_delegation_needs_no_queue() {
        // Single thread owns every shard: all ops take the inline shortcut.
        let topo = Topology::milan_virtual();
        let store =
            Arc::new(ShardedStore::new(StoreKind::HashFixed, 8, 1 << 10, topo.clone(), 1));
        let fabric = OpFabric::new(1, 0, 8, topo, 4, 8);
        let mut caller = fabric.caller(0, Some(0));
        for i in 0..32u64 {
            caller.delegate(DelegatedOp::Insert { key: (i % 8) << 61 | i, value: i }, &store);
        }
        // sync through the same path — executes inline, no owner thread
        let r = caller.call(DelegatedOp::Find { key: 0 }, &store).unwrap();
        assert_eq!(r, OpResult::Value(Some(0)));
        caller.finish(&store);
        assert!(fabric.all_quiet());
        let st = fabric.stats();
        assert_eq!(st.executed, 33);
        assert_eq!(st.inline_ops, 33);
        assert_eq!(st.queued_batches, 0, "nothing travels a queue with one thread");
    }

    #[test]
    fn combining_drain_merges_batches_and_coalesces_finds() {
        let topo = Topology::virtual_grid(2, 2);
        let threads = 4;
        let store =
            Arc::new(ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 12, topo.clone(), threads));
        let fabric = OpFabric::new(threads, 2, 8, topo, 16, 4);
        assert!(fabric.combining_enabled(), "combining is the default");
        // two callers stage overlapping shard-0 work: duplicate inserts
        // (second caller must lose) and duplicate finds (must coalesce)
        let mut c1 = fabric.caller(threads, None);
        let mut c2 = fabric.caller(threads + 1, None);
        for i in 0..32u64 {
            c1.delegate(DelegatedOp::Insert { key: i, value: i }, &store);
            c2.delegate(DelegatedOp::Insert { key: i, value: 100 + i }, &store);
        }
        for i in 0..32u64 {
            c1.delegate(DelegatedOp::Find { key: i }, &store);
            c2.delegate(DelegatedOp::Find { key: i }, &store);
        }
        c1.finish(&store);
        c2.finish(&store);
        for t in 0..threads {
            while fabric.drain(t, &store, usize::MAX) > 0 {}
        }
        assert!(fabric.all_quiet());
        let st = fabric.stats();
        assert_eq!(st.executed, st.submitted);
        assert_eq!(store.len(), 32, "duplicate inserts must not double-insert");
        assert!(st.combined_drains > 0, "two callers' batches must combine");
        assert!(
            st.combined_batches >= 2 * st.combined_drains,
            "a combining drain merges >= 2 batches ({} over {})",
            st.combined_batches,
            st.combined_drains
        );
        assert!(st.combined_runs > 0);
        assert!(st.coalesced_finds > 0, "cross-caller duplicate finds must coalesce");
        // per-caller settlement survives the merge
        let t1 = fabric.slot_totals(threads);
        let t2 = fabric.slot_totals(threads + 1);
        assert_eq!(t1.acked, 64);
        assert_eq!(t2.acked, 64);
        assert_eq!(t1.applied, 32, "caller 1 wins every duplicate insert (FIFO pop order)");
        assert_eq!(t2.applied, 0);
        assert_eq!(t1.hits, 32, "finds run after the same-key inserts of this drain");
        assert_eq!(t2.hits, 32);
        // values must be caller 1's (first in per-key order)
        for i in 0..32u64 {
            assert_eq!(store.get(i), Some(i));
        }
    }

    #[test]
    fn stats_balance_to_quiescence_with_coalescing_and_sync() {
        // The FabricStats ledger must balance at quiescence no matter how
        // coalesced windows and sync batches interleave: every submitted
        // op executes exactly once (`executed == submitted`) and settles
        // exactly one ack on its own caller's slot — an N-way coalesced
        // find executes once but still acks N slots, and a sync batch
        // popped mid-window must not double-run the pooled prefix.
        let topo = Topology::virtual_grid(2, 2);
        let threads = 4;
        let store = Arc::new(ShardedStore::new(
            StoreKind::DetSkiplistLf,
            8,
            1 << 12,
            topo.clone(),
            threads,
        ));
        let fabric = OpFabric::new(threads, 3, 8, topo, 16, 4);
        let mut a = fabric.caller(threads, None);
        let mut b = fabric.caller(threads + 1, None);
        let mut c = fabric.caller(threads + 2, None);
        // stage everything *before* owners start draining so the combiner
        // sees deep queues: a's inserts+finds first, then b's duplicate
        // finds — per-key pop order [Insert_a, Find_a, Find_b, Find_b]
        // guarantees an adjacent duplicate-find pair to coalesce
        for i in 0..48u64 {
            let key = (i % 8) << 61 | i;
            a.delegate(DelegatedOp::Insert { key, value: i }, &store);
            a.delegate(DelegatedOp::Find { key }, &store);
        }
        for i in 0..48u64 {
            let key = (i % 8) << 61 | i;
            b.delegate(DelegatedOp::Find { key }, &store);
            b.delegate(DelegatedOp::Find { key }, &store);
        }
        std::thread::scope(|s| {
            for t in 0..threads {
                let fabric = &fabric;
                let store = &store;
                s.spawn(move || {
                    while !fabric.all_quiet() {
                        fabric.drain(t, store, 64);
                        std::hint::spin_loop();
                    }
                });
            }
            // sync calls land between the owners' combining windows
            for i in 0..6u64 {
                let key = (i % 8) << 61 | i;
                let r = c.call(DelegatedOp::Find { key }, &store).unwrap();
                assert!(matches!(r, OpResult::Value(_)));
            }
            a.finish(&store);
            b.finish(&store);
            c.finish(&store);
        });
        let st = fabric.stats();
        assert_eq!(st.executed, st.submitted, "quiescence balance");
        assert_eq!(st.submitted, 96 + 96 + 6);
        assert!(st.coalesced_finds > 0, "duplicate finds must have coalesced");
        assert_eq!(st.sync_calls, 6);
        assert_eq!(
            st.fused_runs + st.interleaved_runs,
            st.combined_runs,
            "every combined run is dispatched exactly one way"
        );
        assert!(st.queued_batches > 0);
        assert!(st.handoff_ns > 0, "pop-time handoff must cover sync + combined batches");
        // slot acks == ops per caller: coalescing settles every twin
        assert_eq!(fabric.slot_totals(threads).acked, 96);
        assert_eq!(fabric.slot_totals(threads + 1).acked, 96);
        assert_eq!(fabric.slot_totals(threads + 2).acked, 6);
        assert_eq!((a.delegated(), b.delegated(), c.delegated()), (96, 96, 6));
    }

    #[test]
    fn scattered_combined_runs_take_the_interleaved_path() {
        // One owner, deep queue of far-apart keys: the combiner's dispatch
        // test must classify the merged runs as scattered and route them
        // through apply_interleaved (counter proof), with results intact.
        let topo = Topology::milan_virtual();
        let store =
            Arc::new(ShardedStore::new(StoreKind::DetSkiplistLf, 1, 1 << 14, topo.clone(), 1));
        let fabric = OpFabric::new(1, 2, 1, topo, 16, 4);
        // seed values through the store directly
        let mut keys = Vec::new();
        for i in 0..256u64 {
            // stride far beyond the shard's cluster_gap, everything in prefix 0
            let key = i * 8192 + 17;
            store.insert(key, i);
            keys.push(key);
        }
        let mut c1 = fabric.caller(1, None);
        let mut c2 = fabric.caller(2, None);
        // scatter the delegation order so per-batch keys are unsorted too
        for (j, &key) in keys.iter().enumerate() {
            if j % 2 == 0 {
                c1.delegate(DelegatedOp::Find { key }, &store);
            } else {
                c2.delegate(DelegatedOp::Find { key }, &store);
            }
        }
        c1.finish(&store);
        c2.finish(&store);
        while fabric.drain(0, &store, usize::MAX) > 0 {}
        assert!(fabric.all_quiet());
        let st = fabric.stats();
        assert_eq!(st.executed, st.submitted);
        assert!(st.interleaved_runs > 0, "scattered windows must interleave");
        let t1 = fabric.slot_totals(1);
        let t2 = fabric.slot_totals(2);
        assert_eq!(t1.acked + t2.acked, 256);
        assert_eq!(t1.hits + t2.hits, 256, "every find hits its seeded key");
    }

    #[test]
    fn cluster_dispatch_is_gap_relative() {
        // same run, different thresholds: a stride-100 run is scattered
        // under the flat default but clustered once the gap widens past the
        // stride (what a fat-node shard with a bigger leaf_cap or a wider
        // routing block reports)
        use crate::coordinator::store::{KvStore, FLAT_CLUSTER_GAP};
        let run: Vec<BatchOp> = (0..64u64).map(|i| BatchOp::Get(i * 100)).collect();
        assert!(!run_is_clustered(&run, FLAT_CLUSTER_GAP));
        assert!(run_is_clustered(&run, 128));
        // the default det shard's gap (leaf 16 × inner 8 = 128) classifies
        // the stride-100 run as clustered where the flat default did not —
        // the recalibration that keeps block-spanning runs on the fused path
        let det = StoreKind::DetSkiplistLf.build(1 << 10);
        assert_eq!(det.cluster_gap(), 128);
        assert!(run_is_clustered(&run, det.cluster_gap()));
        // short runs always fuse regardless of gap
        let short: Vec<BatchOp> = (0..4u64).map(|i| BatchOp::Get(i << 20)).collect();
        assert!(run_is_clustered(&short, 1));
        // majority rule: half the gaps tight, half huge — clustered at the
        // default, still clustered when the gap shrinks below the tight half
        let mixed: Vec<BatchOp> =
            (0..32u64).map(|i| BatchOp::Get(i / 2 * 100_000 + (i % 2) * 8)).collect();
        assert!(run_is_clustered(&mixed, FLAT_CLUSTER_GAP));
        assert!(!run_is_clustered(&mixed, 4));
    }

    #[test]
    fn combining_off_restores_per_envelope_execution() {
        let topo = Topology::virtual_grid(2, 2);
        let store =
            Arc::new(ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 12, topo.clone(), 4));
        let fabric = OpFabric::new(4, 1, 8, topo, 16, 4);
        fabric.set_combining(false);
        let mut c = fabric.caller(4, None);
        for i in 0..64u64 {
            c.delegate(DelegatedOp::Insert { key: (i % 8) << 61 | i, value: i }, &store);
        }
        c.finish(&store);
        for t in 0..4 {
            while fabric.drain(t, &store, usize::MAX) > 0 {}
        }
        assert!(fabric.all_quiet());
        let st = fabric.stats();
        assert_eq!(st.executed, 64);
        assert_eq!(st.combined_drains, 0, "no combining when disabled");
        assert_eq!(st.combined_batches, 0);
        assert_eq!(store.len(), 64);
    }

    #[test]
    fn range_splits_per_prefix_and_counts_rows() {
        let topo = Topology::virtual_grid(2, 2);
        let store =
            Arc::new(ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 12, topo.clone(), 4));
        for p in 0..8u64 {
            for i in 0..10u64 {
                store.insert(p << 61 | i, p);
            }
        }
        let fabric = OpFabric::new(4, 1, 8, topo, 16, 64);
        let mut caller = fabric.caller(4, None);
        // full-space scan = 8 sub-ops
        let subs = caller.delegate_range(0, u64::MAX, &store);
        assert_eq!(subs, 8);
        caller.finish(&store);
        for t in 0..4 {
            while fabric.drain(t, &store, usize::MAX) > 0 {}
        }
        assert_eq!(fabric.slot_totals(4).rows, 80, "all rows aggregate to the caller");
        assert_eq!(caller.delegate_range(10, 5, &store), 0, "inverted bounds");
    }

    #[test]
    fn killed_owner_work_is_adopted_and_completes() {
        // No failpoints needed: mark_owner_dead(t, clean) simulates a
        // clean op-boundary death. Survivors must adopt the orphaned queue
        // and shards and finish every queued op — zero lost completions.
        let topo = Topology::virtual_grid(2, 2);
        let threads = 4;
        let store =
            Arc::new(ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 12, topo.clone(), threads));
        let fabric = OpFabric::new(threads, 1, 8, topo, 16, 4);
        let mut caller = fabric.caller(threads, None);
        for i in 0..64u64 {
            let key = (i % 8) << 61 | i;
            caller.delegate(DelegatedOp::Insert { key, value: i }, &store);
        }
        caller.finish(&store);
        // Kill owner 0 before anyone drains: its queued batches orphan.
        fabric.mark_owner_dead(0, true);
        assert!(fabric.owner_dead(0));
        assert_eq!(fabric.drain(0, &store, usize::MAX), 0, "dead owners stand down");
        for t in 1..threads {
            while fabric.drain(t, &store, usize::MAX) > 0 {}
        }
        assert!(fabric.all_quiet(), "adoption must drain the dead owner's queue");
        assert_eq!(store.len(), 64);
        let st = fabric.stats();
        assert_eq!(st.executed, 64);
        assert_eq!(st.errored, 0, "clean kills lose nothing");
        assert_eq!(st.owner_deaths, 1);
        assert!(st.shards_adopted > 0, "the dead owner's shards re-home by CAS");
        assert!(st.adopted_batches > 0, "orphaned batches drain under the adopter");
        assert!(st.recovery_ns > 0, "death -> takeover latency is measured");
        let totals = fabric.slot_totals(threads);
        assert_eq!(totals.acked + totals.errored, 64, "zero lost acks");
        // Post-recovery routing: every shard's owner is alive again.
        for s in 0..8 {
            assert!(!fabric.owner_dead(fabric.owner_of_shard(s)));
        }
    }

    #[test]
    fn sync_call_times_out_typed_and_slot_recovers() {
        let topo = Topology::virtual_grid(2, 2);
        let threads = 4;
        let store =
            Arc::new(ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 12, topo.clone(), threads));
        let fabric = OpFabric::new(threads, 1, 8, topo, 16, 4);
        fabric.set_op_timeout(Some(Duration::from_millis(30)));
        let mut c = fabric.caller(threads, None);
        // Nobody drains the owner: the sync wait must hit its deadline and
        // surface a typed error instead of spinning forever.
        let r = c.call(DelegatedOp::Find { key: 1 << 61 }, &store);
        assert_eq!(r, Err(FabricError::Timeout));
        // The late owner settles the abandoned batch: the slot must be
        // recycled (ABANDONED -> IDLE), never delivered into a new call.
        let owner = fabric.owner_of_key(1 << 61);
        while fabric.drain(owner, &store, usize::MAX) > 0 {}
        // A fresh call on the same slot completes once owners drain.
        fabric.set_op_timeout(None);
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            let fabric = &fabric;
            let store = &store;
            let stop = &stop;
            s.spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    for t in 0..threads {
                        fabric.drain(t, store, 8);
                    }
                }
            });
            let r2 = c.call(DelegatedOp::Find { key: 1 << 61 }, store);
            assert_eq!(r2, Ok(OpResult::Value(None)));
            stop.store(true, Ordering::Relaxed);
        });
        c.finish(&store);
        let st = fabric.stats();
        assert_eq!(st.sync_timeouts, 1);
        assert_eq!(st.executed, st.submitted, "the timed-out op still executed exactly once");
        assert!(fabric.all_quiet());
    }

    #[test]
    fn poisoned_fabric_errors_pending_work_and_balances() {
        let topo = Topology::virtual_grid(2, 2);
        let threads = 4;
        let store =
            Arc::new(ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 12, topo.clone(), threads));
        let fabric = OpFabric::new(threads, 1, 8, topo, 16, 4);
        let mut caller = fabric.caller(threads, None);
        for i in 0..64u64 {
            let key = (i % 8) << 61 | i;
            caller.delegate(DelegatedOp::Insert { key, value: i }, &store);
        }
        caller.finish(&store);
        fabric.poison();
        for t in 0..threads {
            while fabric.drain(t, &store, usize::MAX) > 0 {}
        }
        // Every queued op settled as an error: nothing executed, nothing
        // lost, and the ledger still closes for the termination loops.
        assert!(fabric.all_quiet(), "errored ops must still quiesce the fabric");
        let st = fabric.stats();
        assert_eq!(st.executed + st.errored, st.submitted, "quiescence balance");
        assert_eq!(st.errored, 64);
        let totals = fabric.slot_totals(threads);
        assert_eq!(totals.acked + totals.errored, 64, "zero lost completions");
        assert_eq!(totals.errored, 64);
    }
}
