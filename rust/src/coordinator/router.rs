//! The per-thread queue fabric (paper §VI-VII): "We used lock-free queues,
//! one per thread, for distributing keys. The queues distributed keys with
//! upper 3-bits equal to S_i to a random thread in n_{s_i}."

use crate::numa::Topology;
use crate::queue::{ConcurrentQueue, LfQueue};
use crate::util::rng::Rng;

/// One lock-free queue per worker thread; keys are routed to a random
/// thread pinned to the home NUMA node of their shard.
pub struct RouterFabric {
    queues: Vec<LfQueue>,
    #[allow(dead_code)]
    topology: Topology,
    nshards: usize,
    /// Precomputed thread ids per shard's home node (perf: `route_key` was
    /// O(threads) per key with iterator scans — see EXPERIMENTS.md §Perf).
    shard_threads: Vec<Vec<usize>>,
}

impl RouterFabric {
    pub fn new(threads: usize, nshards: usize, topology: Topology, queue_blocks: usize) -> RouterFabric {
        assert!(threads >= 1 && nshards.is_power_of_two());
        let shard_threads = (0..nshards)
            .map(|shard| {
                let node = topology.shard_home(shard, threads);
                let v: Vec<usize> =
                    (0..threads).filter(|&t| topology.node_of_cpu(t) == node).collect();
                if v.is_empty() {
                    vec![0]
                } else {
                    v
                }
            })
            .collect();
        RouterFabric {
            queues: (0..threads).map(|_| LfQueue::with_config(8192, queue_blocks, true)).collect(),
            topology,
            nshards,
            shard_threads,
        }
    }

    pub fn threads(&self) -> usize {
        self.queues.len()
    }

    /// Route one key to a random thread on its shard's home node.
    #[inline]
    pub fn route_key(&self, key: u64, rng: &mut Rng) {
        let shard = ((key >> 61) as usize) % self.nshards;
        let region = &self.shard_threads[shard];
        let t = region[rng.below(region.len() as u64) as usize];
        self.queues[t].push(key);
    }

    /// Route a whole batch (leader-thread fill phase).
    pub fn route_batch(&self, keys: &[u64], rng: &mut Rng) {
        for &k in keys {
            self.route_key(k, rng);
        }
    }

    /// Worker-side pop from the thread's own (NUMA-local) queue.
    #[inline]
    pub fn pop_local(&self, thread_id: usize) -> Option<u64> {
        self.queues[thread_id].pop()
    }

    /// Total keys still enqueued (diagnostics; approximate under churn).
    pub fn pending(&self) -> u64 {
        self.queues
            .iter()
            .map(|q| {
                let s = q.stats();
                s.pushes.saturating_sub(s.pops)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_land_on_home_node_threads() {
        let topo = Topology::virtual_grid(2, 2); // 2 nodes x 2 cpus
        let fabric = RouterFabric::new(4, 8, topo.clone(), 64);
        let mut rng = Rng::new(1);
        // shard 0 (MSBs 000) homes on node 0 -> threads 0,1
        // shard 1 (MSBs 001) homes on node 1 -> threads 2,3
        for i in 0..100u64 {
            fabric.route_key(i, &mut rng); // shard 0
            fabric.route_key(1 << 61 | i, &mut rng); // shard 1
        }
        let n0: u64 = (0..2).map(|t| fabric.queues[t].stats().pushes).sum();
        let n1: u64 = (2..4).map(|t| fabric.queues[t].stats().pushes).sum();
        assert_eq!(n0, 100, "shard-0 keys must stay on node 0");
        assert_eq!(n1, 100, "shard-1 keys must stay on node 1");
    }

    #[test]
    fn pop_local_drains() {
        let topo = Topology::virtual_grid(1, 2);
        let fabric = RouterFabric::new(2, 8, topo, 64);
        let mut rng = Rng::new(2);
        for i in 0..50u64 {
            fabric.route_key(i, &mut rng);
        }
        let mut got = 0;
        for t in 0..2 {
            while fabric.pop_local(t).is_some() {
                got += 1;
            }
        }
        assert_eq!(got, 50);
        assert_eq!(fabric.pending(), 0);
    }

    #[test]
    fn single_thread_fabric() {
        let fabric = RouterFabric::new(1, 8, Topology::milan_virtual(), 64);
        let mut rng = Rng::new(3);
        for i in 0..20u64 {
            fabric.route_key(i << 61 | i, &mut rng); // all shards
        }
        let mut got = 0;
        while fabric.pop_local(0).is_some() {
            got += 1;
        }
        assert_eq!(got, 20);
    }
}
