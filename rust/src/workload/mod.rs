//! Workload specifications (the paper's experiment mixes) and deterministic
//! op assignment.
//!
//! Keys are a splitmix64 counter stream (L1 `keygen` kernel or the native
//! fallback). The *operation* for a key is derived from the key itself
//! (`op_of`), so a key routed through the queue fabric as a bare `u64`
//! carries its op implicitly — producer and consumer agree without extra
//! payload bits, keeping the queue element exactly the paper's "integer".

use crate::util::rng::mix64;

/// Operation kinds in the paper's workloads, plus the §IX range scan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Insert,
    Find,
    Erase,
    /// Range scan of `[key, key + range_window]` (see [`WorkloadSpec`]).
    Range,
}

/// An operation mix in per-mille (supports the paper's 0.2% erase).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpMix {
    pub insert_pm: u32,
    pub find_pm: u32,
    pub erase_pm: u32,
    pub range_pm: u32,
}

impl OpMix {
    /// Point-op mix (no range scans).
    pub const fn new(insert_pm: u32, find_pm: u32, erase_pm: u32) -> OpMix {
        assert!(insert_pm + find_pm + erase_pm == 1000);
        OpMix { insert_pm, find_pm, erase_pm, range_pm: 0 }
    }

    /// Mixed point/range mix: the range-op ratio is `range_pm` per mille;
    /// each range op scans a window of [`WorkloadSpec::range_window`] keys.
    pub const fn with_range(insert_pm: u32, find_pm: u32, erase_pm: u32, range_pm: u32) -> OpMix {
        assert!(insert_pm + find_pm + erase_pm + range_pm == 1000);
        OpMix { insert_pm, find_pm, erase_pm, range_pm }
    }

    /// Paper workload 1 (§VI): 10% insert, 90% find.
    pub const W1: OpMix = OpMix::new(100, 900, 0);
    /// Paper workload 2 (§VI): 10% insert, 89.8% find, 0.2% erase.
    pub const W2: OpMix = OpMix::new(100, 898, 2);
    /// Hash-table workload (§VIII): 50% insert, 50% find.
    pub const HASH: OpMix = OpMix::new(500, 500, 0);
    /// Mixed point/range workload (§IX terminal-list advantage): 10%
    /// insert, 70% find, 20% range scans.
    pub const RANGE: OpMix = OpMix::with_range(100, 700, 0, 200);
    /// Hierarchical-delegation workload (Table XI): all four op kinds —
    /// 20% insert, 64% find, 6% erase, 10% range scans — so the Direct vs
    /// Delegated comparison exercises every envelope type, including the
    /// cross-shard scans that make Direct reach into remote shards (pair
    /// with a prefix-spanning `range_window`).
    pub const HIER: OpMix = OpMix::with_range(200, 640, 60, 100);
    /// Bulk-batch workload (Table XIII): 40% insert, 40% find, 20% erase —
    /// point ops only, mutation-heavy so the fused sorted-run descents have
    /// writes to amortize. Pair with
    /// [`WorkloadSpec::with_clustered_runs`] for the sorted-arrival shape
    /// the §VII batching proposal assumes.
    pub const BULK: OpMix = OpMix::new(400, 400, 200);

    /// Table XVIII read-heavy mix: 95% find, 2.5% insert, 2.5% erase —
    /// the replicated-index sweet spot (reads never leave their node).
    pub const READ95: OpMix = OpMix::new(25, 950, 25);
    /// Table XVIII mixed mix: 70% find, 15% insert, 15% erase.
    pub const READ70: OpMix = OpMix::new(150, 700, 150);
    /// Table XVIII write-heavy mix: 50% find, 25% insert, 25% erase —
    /// stresses the invalidation log and replica maintenance.
    pub const READ50: OpMix = OpMix::new(250, 500, 250);

    /// Deterministic op for a key: both the router (producer) and the
    /// worker (consumer) compute the same answer from the key alone.
    #[inline]
    pub fn op_of(&self, key: u64) -> OpKind {
        // decorrelate from the key's own hash uses
        let roll = (mix64(key ^ 0xC0FF_EE00_D15E_A5E5) % 1000) as u32;
        if roll < self.insert_pm {
            OpKind::Insert
        } else if roll < self.insert_pm + self.find_pm {
            OpKind::Find
        } else if roll < self.insert_pm + self.find_pm + self.erase_pm {
            OpKind::Erase
        } else {
            OpKind::Range
        }
    }
}

/// A complete experiment workload description.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub name: &'static str,
    pub total_ops: u64,
    pub mix: OpMix,
    /// Keys are folded into this many distinct values (0 = full u64 space).
    /// A bounded key space makes finds/erases hit earlier inserts.
    pub key_space: u64,
    /// Window width of one `Range` op: the worker scans
    /// `[key, key + range_window]`. Only meaningful when `mix.range_pm > 0`.
    pub range_window: u64,
    /// Temporal-locality window (0 = uniform keys). When set, consecutive
    /// operations draw their low key bits from a `hot_span`-wide window
    /// whose base moves every `hot_phase` ops — the repeated-nearby-key
    /// access pattern (zipf-ish working set) that the Table XII search
    /// fingers exploit. Shard MSBs stay uniform so routing is unaffected.
    pub hot_span: u64,
    /// Ops per hot window before the base jumps (only with `hot_span > 0`).
    pub hot_phase: u64,
    /// Clustered-run length (0 = off). When set, consecutive operations
    /// form ascending key runs: `run_len` ops per run, consecutive keys
    /// `run_stride` apart, and the 3 shard MSBs drawn once *per run* so a
    /// whole run lands on one shard — the sorted, shard-local arrival
    /// shape the paper's §VII batching proposal assumes (Table XIII's
    /// clustering axis). Mutually exclusive with `hot_span`.
    pub run_len: u64,
    /// Key distance between consecutive ops of a run (with `run_len > 0`).
    pub run_stride: u64,
    /// Salt mixed into every run's base/shard draw. The clustered keys are
    /// a function of fill position alone (the whole run must share one
    /// base), so without a salt every seed would replay the same key
    /// stream — reps would not be independent samples. Set it from the
    /// run's seed ([`WorkloadSpec::with_run_salt`]).
    pub run_salt: u64,
}

impl WorkloadSpec {
    pub fn new(name: &'static str, total_ops: u64, mix: OpMix, key_space: u64) -> WorkloadSpec {
        WorkloadSpec {
            name,
            total_ops,
            mix,
            key_space,
            range_window: 64,
            hot_span: 0,
            hot_phase: 4096,
            run_len: 0,
            run_stride: 1,
            run_salt: 0,
        }
    }

    /// Override the range-scan window width (builder style).
    pub fn with_range_window(mut self, window: u64) -> WorkloadSpec {
        self.range_window = window;
        self
    }

    /// Confine consecutive ops to a moving `span`-wide key window that
    /// jumps every `phase` ops (builder style; see [`WorkloadSpec::hot_span`]).
    pub fn with_hot_span(mut self, span: u64, phase: u64) -> WorkloadSpec {
        assert!(span > 0 && phase > 0, "hot window needs a non-empty span and phase");
        assert!(
            self.key_space == 0 || span <= self.key_space,
            "hot span {span} cannot exceed the key space {} — keys would \
             silently escape the documented bound",
            self.key_space
        );
        assert!(self.run_len == 0, "hot windows and clustered runs are mutually exclusive");
        self.hot_span = span;
        self.hot_phase = phase;
        self
    }

    /// Make consecutive ops arrive as ascending same-shard key runs
    /// (builder style; see [`WorkloadSpec::run_len`]): `run_len` ops per
    /// run, consecutive keys `stride` apart.
    pub fn with_clustered_runs(mut self, run_len: u64, stride: u64) -> WorkloadSpec {
        assert!(run_len > 0 && stride > 0, "clustered runs need a length and a stride");
        assert!(self.hot_span == 0, "hot windows and clustered runs are mutually exclusive");
        let width = run_len * stride;
        assert!(
            (self.key_space == 0 || width <= self.key_space) && width <= (1 << 59),
            "run width {width} cannot exceed the key space {}",
            self.key_space
        );
        self.run_len = run_len;
        self.run_stride = stride;
        self
    }

    /// Decorrelate clustered runs across seeds/reps (builder style; see
    /// [`WorkloadSpec::run_salt`]).
    pub fn with_run_salt(mut self, salt: u64) -> WorkloadSpec {
        self.run_salt = salt;
        self
    }

    /// Map a raw generated key into the bounded key space while keeping the
    /// top shard bits intact (NUMA routing uses MSBs; we bound the LOW bits).
    #[inline]
    pub fn fold_key(&self, raw: u64) -> u64 {
        if self.key_space == 0 {
            raw & !(0b11 << OP_SHIFT) // reserve the transport op bits
        } else {
            // keep the 3 shard MSBs, bound the rest
            let shard = raw & (0b111 << 61);
            shard | (raw % self.key_space.min(1 << 59))
        }
    }

    /// Map a raw key into the hot window active at fill position `seq`:
    /// the window base is a deterministic function of `seq / hot_phase`, so
    /// ~`hot_phase` consecutive ops share one `hot_span`-wide neighbourhood
    /// (per shard — the 3 shard MSBs stay uniform). Workers drain their
    /// queues in fill order, so the temporal locality survives transport.
    #[inline]
    fn fold_key_at(&self, raw: u64, seq: u64) -> u64 {
        if self.run_len > 0 {
            // clustered run: base, shard and stride walk are all functions
            // of the run id / position, so every op of a run targets one
            // shard with strictly ascending keys
            let rid = seq / self.run_len;
            let h = mix64(rid ^ mix64(self.run_salt ^ 0xB1_7C5E_D0_1234));
            let shard = h & (0b111 << 61);
            let space = if self.key_space == 0 { 1 << 59 } else { self.key_space.min(1 << 59) };
            // width <= space is asserted in with_clustered_runs
            let width = self.run_len * self.run_stride;
            let base = if space > width { (h >> 3) % (space - width + 1) } else { 0 };
            return shard | (base + (seq % self.run_len) * self.run_stride);
        }
        if self.hot_span == 0 {
            return self.fold_key(raw);
        }
        let shard = raw & (0b111 << 61);
        // span <= key_space is asserted in with_hot_span; key_space 0 means
        // the full (sub-shard-bit) space
        let space = if self.key_space == 0 {
            1 << 59
        } else {
            self.key_space.min(1 << 59)
        };
        let base = if space > self.hot_span {
            mix64(seq / self.hot_phase) % (space - self.hot_span + 1)
        } else {
            0
        };
        shard | (base + raw % self.hot_span)
    }

    /// Encode one transport word for the queue fabric: the folded key plus
    /// the operation in bits 60:59. The op is drawn from the *raw* stream
    /// (so mix fractions are exact and find/erase keys hit the same
    /// population inserts populate), and travels with the key because the
    /// same folded key must be insertable by one queue element and findable
    /// by another. `seq` is the op's position in the fill stream; it only
    /// matters when a hot window is configured ([`WorkloadSpec::hot_span`]).
    #[inline]
    pub fn encode(&self, raw: u64, seq: u64) -> u64 {
        let op = match self.mix.op_of(raw) {
            OpKind::Insert => 0u64,
            OpKind::Find => 1,
            OpKind::Erase => 2,
            OpKind::Range => 3,
        };
        self.fold_key_at(raw, seq) | (op << OP_SHIFT)
    }

    /// Decode a transport word back into (op, key).
    #[inline]
    pub fn decode(word: u64) -> (OpKind, u64) {
        let op = match (word >> OP_SHIFT) & 0b11 {
            0 => OpKind::Insert,
            1 => OpKind::Find,
            2 => OpKind::Erase,
            _ => OpKind::Range,
        };
        (op, word & !(0b11 << OP_SHIFT))
    }
}

/// Transport bits 60:59 carry the op (below the 3 shard MSBs, above any
/// realistic key space).
pub const OP_SHIFT: u32 = 59;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_fractions_are_respected() {
        let mix = OpMix::W2;
        let (mut i, mut f, mut e) = (0u64, 0u64, 0u64);
        let n = 200_000u64;
        for c in 0..n {
            match mix.op_of(mix64(c)) {
                OpKind::Insert => i += 1,
                OpKind::Find => f += 1,
                OpKind::Erase => e += 1,
                OpKind::Range => unreachable!("W2 has no range ops"),
            }
        }
        let pct = |x: u64| x as f64 / n as f64 * 1000.0;
        assert!((pct(i) - 100.0).abs() < 10.0, "insert {:.1}pm", pct(i));
        assert!((pct(f) - 898.0).abs() < 10.0, "find {:.1}pm", pct(f));
        assert!((pct(e) - 2.0).abs() < 1.0, "erase {:.1}pm", pct(e));
    }

    #[test]
    fn op_is_deterministic_per_key() {
        let mix = OpMix::W1;
        for k in 0..1000u64 {
            assert_eq!(mix.op_of(k), mix.op_of(k));
        }
    }

    #[test]
    fn fold_preserves_shard_bits() {
        let spec = WorkloadSpec::new("t", 100, OpMix::W1, 1 << 20);
        for raw in [0u64, u64::MAX - 7, 0x7FFF_FFFF_FFFF_FFFF, 1 << 61] {
            let folded = spec.fold_key(raw);
            assert_eq!(folded >> 61, raw >> 61, "shard bits must survive");
            assert!(folded & !(0b111 << 61) < (1 << 20));
        }
    }

    #[test]
    #[should_panic]
    fn mix_must_sum_to_1000() {
        let _ = OpMix::new(500, 400, 0);
    }

    #[test]
    fn hot_span_confines_consecutive_keys_and_moves() {
        let spec = WorkloadSpec::new("hot", 0, OpMix::W1, 4096).with_hot_span(64, 256);
        // within one phase, all low keys live in one 64-wide window
        let phase_keys: Vec<u64> = (0..256u64)
            .map(|c| {
                let (_, key) = WorkloadSpec::decode(spec.encode(mix64(c), c));
                key & !(0b111 << 61)
            })
            .collect();
        let lo = *phase_keys.iter().min().unwrap();
        let hi = *phase_keys.iter().max().unwrap();
        assert!(hi - lo < 64, "phase keys span {lo}..{hi}, want < 64 wide");
        assert!(hi < 4096, "window stays inside the key space");
        // a later phase draws from a different (still bounded) window
        // (8960 = 35 * 256: the range stays inside one phase)
        let later_keys: Vec<u64> = (8_960..9_216u64)
            .map(|c| {
                let (_, key) = WorkloadSpec::decode(spec.encode(mix64(c), c));
                key & !(0b111 << 61)
            })
            .collect();
        let llo = *later_keys.iter().min().unwrap();
        let lhi = *later_keys.iter().max().unwrap();
        assert!(lhi - llo < 64 && lhi < 4096);
        assert_ne!(llo / 64, lo / 64, "the window must move between phases");
        // shard MSBs still come from the raw stream
        let raw = 0b101u64 << 61 | 12345;
        let (_, key) = WorkloadSpec::decode(spec.encode(raw, 0));
        assert_eq!(key >> 61, 0b101, "shard bits survive the hot fold");
    }

    #[test]
    fn clustered_runs_are_ascending_and_shard_local() {
        let spec = WorkloadSpec::new("bulk", 0, OpMix::BULK, 1 << 14).with_clustered_runs(64, 3);
        for rid in [0u64, 7, 99] {
            let keys: Vec<u64> = (rid * 64..(rid + 1) * 64)
                .map(|c| {
                    let (_, key) = WorkloadSpec::decode(spec.encode(mix64(c), c));
                    key
                })
                .collect();
            // one shard per run
            let shard = keys[0] >> 61;
            assert!(keys.iter().all(|&k| k >> 61 == shard), "run {rid} crosses shards");
            // strictly ascending with the configured stride
            for w in keys.windows(2) {
                assert_eq!(w[1] - w[0], 3, "run {rid} must step by the stride");
            }
            // inside the key space
            assert!(keys.iter().all(|&k| k & !(0b111 << 61) < (1 << 14)), "run {rid}");
        }
        // different runs draw different bases (clustering moves around)
        let k0 = WorkloadSpec::decode(spec.encode(mix64(0), 0)).1 & !(0b111 << 61);
        let k9 = WorkloadSpec::decode(spec.encode(mix64(9 * 64), 9 * 64)).1 & !(0b111 << 61);
        assert_ne!(k0, k9, "bases must vary across runs");
        // and different salts (seeds) draw different streams entirely
        let salted = spec.clone().with_run_salt(42);
        let ks = WorkloadSpec::decode(salted.encode(mix64(0), 0)).1;
        assert_ne!(
            ks,
            WorkloadSpec::decode(spec.encode(mix64(0), 0)).1,
            "the run salt must decorrelate reps"
        );
    }

    #[test]
    #[should_panic]
    fn clustered_runs_exclude_hot_windows() {
        let _ = WorkloadSpec::new("x", 0, OpMix::BULK, 1 << 14)
            .with_hot_span(64, 256)
            .with_clustered_runs(64, 1);
    }

    #[test]
    fn range_mix_fraction_and_transport_roundtrip() {
        let spec = WorkloadSpec::new("r", 0, OpMix::RANGE, 1 << 20).with_range_window(32);
        assert_eq!(spec.range_window, 32);
        let n = 100_000u64;
        let mut r = 0u64;
        for c in 0..n {
            let raw = mix64(c);
            let word = spec.encode(raw, c);
            let (op, key) = WorkloadSpec::decode(word);
            assert_eq!(key, spec.fold_key(raw), "key survives transport");
            if op == OpKind::Range {
                assert_eq!(spec.mix.op_of(raw), OpKind::Range, "op survives transport");
                r += 1;
            }
        }
        let pm = r as f64 / n as f64 * 1000.0;
        assert!((pm - 200.0).abs() < 15.0, "range ratio {pm:.1}pm, want ~200pm");
    }
}
