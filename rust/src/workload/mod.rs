//! Workload specifications (the paper's experiment mixes) and deterministic
//! op assignment.
//!
//! Keys are a splitmix64 counter stream (L1 `keygen` kernel or the native
//! fallback). The *operation* for a key is derived from the key itself
//! (`op_of`), so a key routed through the queue fabric as a bare `u64`
//! carries its op implicitly — producer and consumer agree without extra
//! payload bits, keeping the queue element exactly the paper's "integer".

use crate::util::rng::mix64;

/// Operation kinds in the paper's workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Insert,
    Find,
    Erase,
}

/// An operation mix in per-mille (supports the paper's 0.2% erase).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpMix {
    pub insert_pm: u32,
    pub find_pm: u32,
    pub erase_pm: u32,
}

impl OpMix {
    pub const fn new(insert_pm: u32, find_pm: u32, erase_pm: u32) -> OpMix {
        assert!(insert_pm + find_pm + erase_pm == 1000);
        OpMix { insert_pm, find_pm, erase_pm }
    }

    /// Paper workload 1 (§VI): 10% insert, 90% find.
    pub const W1: OpMix = OpMix::new(100, 900, 0);
    /// Paper workload 2 (§VI): 10% insert, 89.8% find, 0.2% erase.
    pub const W2: OpMix = OpMix::new(100, 898, 2);
    /// Hash-table workload (§VIII): 50% insert, 50% find.
    pub const HASH: OpMix = OpMix::new(500, 500, 0);

    /// Deterministic op for a key: both the router (producer) and the
    /// worker (consumer) compute the same answer from the key alone.
    #[inline]
    pub fn op_of(&self, key: u64) -> OpKind {
        // decorrelate from the key's own hash uses
        let roll = (mix64(key ^ 0xC0FF_EE00_D15E_A5E5) % 1000) as u32;
        if roll < self.insert_pm {
            OpKind::Insert
        } else if roll < self.insert_pm + self.find_pm {
            OpKind::Find
        } else {
            OpKind::Erase
        }
    }
}

/// A complete experiment workload description.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub name: &'static str,
    pub total_ops: u64,
    pub mix: OpMix,
    /// Keys are folded into this many distinct values (0 = full u64 space).
    /// A bounded key space makes finds/erases hit earlier inserts.
    pub key_space: u64,
}

impl WorkloadSpec {
    pub fn new(name: &'static str, total_ops: u64, mix: OpMix, key_space: u64) -> WorkloadSpec {
        WorkloadSpec { name, total_ops, mix, key_space }
    }

    /// Map a raw generated key into the bounded key space while keeping the
    /// top shard bits intact (NUMA routing uses MSBs; we bound the LOW bits).
    #[inline]
    pub fn fold_key(&self, raw: u64) -> u64 {
        if self.key_space == 0 {
            raw & !(0b11 << OP_SHIFT) // reserve the transport op bits
        } else {
            // keep the 3 shard MSBs, bound the rest
            let shard = raw & (0b111 << 61);
            shard | (raw % self.key_space.min(1 << 59))
        }
    }

    /// Encode one transport word for the queue fabric: the folded key plus
    /// the operation in bits 60:59. The op is drawn from the *raw* stream
    /// (so mix fractions are exact and find/erase keys hit the same
    /// population inserts populate), and travels with the key because the
    /// same folded key must be insertable by one queue element and findable
    /// by another.
    #[inline]
    pub fn encode(&self, raw: u64) -> u64 {
        let op = match self.mix.op_of(raw) {
            OpKind::Insert => 0u64,
            OpKind::Find => 1,
            OpKind::Erase => 2,
        };
        self.fold_key(raw) | (op << OP_SHIFT)
    }

    /// Decode a transport word back into (op, key).
    #[inline]
    pub fn decode(word: u64) -> (OpKind, u64) {
        let op = match (word >> OP_SHIFT) & 0b11 {
            0 => OpKind::Insert,
            1 => OpKind::Find,
            _ => OpKind::Erase,
        };
        (op, word & !(0b11 << OP_SHIFT))
    }
}

/// Transport bits 60:59 carry the op (below the 3 shard MSBs, above any
/// realistic key space).
pub const OP_SHIFT: u32 = 59;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_fractions_are_respected() {
        let mix = OpMix::W2;
        let (mut i, mut f, mut e) = (0u64, 0u64, 0u64);
        let n = 200_000u64;
        for c in 0..n {
            match mix.op_of(mix64(c)) {
                OpKind::Insert => i += 1,
                OpKind::Find => f += 1,
                OpKind::Erase => e += 1,
            }
        }
        let pct = |x: u64| x as f64 / n as f64 * 1000.0;
        assert!((pct(i) - 100.0).abs() < 10.0, "insert {:.1}pm", pct(i));
        assert!((pct(f) - 898.0).abs() < 10.0, "find {:.1}pm", pct(f));
        assert!((pct(e) - 2.0).abs() < 1.0, "erase {:.1}pm", pct(e));
    }

    #[test]
    fn op_is_deterministic_per_key() {
        let mix = OpMix::W1;
        for k in 0..1000u64 {
            assert_eq!(mix.op_of(k), mix.op_of(k));
        }
    }

    #[test]
    fn fold_preserves_shard_bits() {
        let spec = WorkloadSpec::new("t", 100, OpMix::W1, 1 << 20);
        for raw in [0u64, u64::MAX - 7, 0x7FFF_FFFF_FFFF_FFFF, 1 << 61] {
            let folded = spec.fold_key(raw);
            assert_eq!(folded >> 61, raw >> 61, "shard bits must survive");
            assert!(folded & !(0b111 << 61) < (1 << 20));
        }
    }

    #[test]
    #[should_panic]
    fn mix_must_sum_to_1000() {
        let _ = OpMix::new(500, 400, 0);
    }
}
