//! `cdskl` — CLI launcher for the reproduction.
//!
//! ```text
//! cdskl info                           topology, artifacts, self-check
//! cdskl exp <t1|t2|t3|t4|t5|t6|t78|t9|t10|t11|t12|t13|t14|t15|t16|t17|t18|all> [--threads 4,8]
//!           [--reps N] [--scale N] [--out FILE]   regenerate paper tables
//! cdskl run [--store det|rwl|random|fixed|twolevel|spo|spo2|tbb]
//!           [--ops N] [--threads N] [--mix w1|w2|hash|range|hier|bulk|r95|r70|r50]
//!           [--exec direct|delegated|replicated] [--range-window W] [--batch-n N]
//!           [--combine true|false] [--run-len N] [--interleave K]
//!           [--inject-latency NS] [--fingers true|false]
//!           [--leaf-cap K] [--inner-cap F] [--op-timeout-ms MS]
//!           [--replica-tick N]
//!                                      one workload run with metrics
//! cdskl selfcheck                      AOT artifacts vs native mixer
//! ```

use std::sync::Arc;

use cdskl::coordinator::{run_with_opts, ExecMode, RunOptions, ShardedStore, StoreKind};
use cdskl::experiments::{self, ExpConfig};
use cdskl::numa::{Topology, LATENCY};
use cdskl::runtime::{KeyRouter, RouteEngine};
use cdskl::util::cli::Args;
use cdskl::workload::{OpMix, WorkloadSpec};

fn artifacts_dir() -> String {
    std::env::var("CDSKL_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("info") => info(),
        Some("selfcheck") => selfcheck(),
        Some("exp") => exp(&args),
        Some("run") => run(&args),
        _ => {
            eprintln!(
                "usage: cdskl <info|selfcheck|exp|run> [flags]\n\
                 see `cdskl exp all --scale 1000 --reps 1` for a quick sweep"
            );
            std::process::exit(2);
        }
    }
}

fn info() {
    let topo = Topology::detect();
    println!(
        "topology: {} NUMA nodes x {} CPUs (detected={})",
        topo.numa_nodes, topo.cpus_per_node, topo.detected
    );
    println!(
        "host parallelism: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    match RouteEngine::load(&artifacts_dir()) {
        Ok(e) => println!("AOT artifacts: OK (batch sizes {:?}, self-check passed)", e.batch_sizes()),
        Err(err) => println!("AOT artifacts: unavailable ({err:#}) — run `make artifacts`"),
    }
}

fn selfcheck() {
    match RouteEngine::load(&artifacts_dir()) {
        Ok(e) => {
            e.self_check().expect("self-check");
            println!("selfcheck OK: AOT route == native splitmix64 routing");
        }
        Err(err) => {
            eprintln!("selfcheck FAILED: {err:#}");
            std::process::exit(1);
        }
    }
}

fn exp_config(args: &Args) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.threads = args.u64_list_or("threads", &cfg.threads);
    cfg.reps = args.usize_or("reps", cfg.reps);
    cfg.scale = args.u64_or("scale", cfg.scale);
    cfg.seed = args.u64_or("seed", cfg.seed);
    let nodes = args.usize_or("numa-nodes", cfg.topology.numa_nodes);
    let cpus = args.usize_or("cpus-per-node", cfg.topology.cpus_per_node);
    cfg.topology = Topology::virtual_grid(nodes, cpus);
    cfg
}

fn exp(args: &Args) {
    let cfg = exp_config(args);
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let router = KeyRouter::auto(&artifacts_dir());
    println!(
        "# cdskl experiments — {} | threads {:?} | reps {} | scale 1/{} | router {}\n",
        which,
        cfg.threads,
        cfg.reps,
        cfg.scale,
        if router.is_aot() { "AOT" } else { "native" }
    );
    let mut tables = Vec::new();
    let all = which == "all";
    if all || which == "t1" {
        tables.extend(experiments::t1_queues(&cfg));
    }
    if all || which == "t2" {
        tables.push(experiments::t2_skiplist_w1(&cfg, &router));
    }
    if all || which == "t3" {
        tables.push(experiments::t3_skiplist_w2(&cfg, &router));
    }
    if all || which == "t4" {
        tables.push(experiments::t4_random_vs_det(&cfg, &router));
    }
    if all || which == "t5" {
        tables.push(experiments::t5_hash_fixed_twolevel(&cfg, &router));
    }
    if all || which == "t6" {
        tables.push(experiments::t6_spo_cache(&cfg));
    }
    if all || which == "t78" {
        tables.extend(experiments::t78_hash_compare(&cfg, &router));
    }
    if all || which == "t9" || which == "range" {
        tables.push(experiments::t9_range(&cfg, &router));
    }
    if all || which == "t10" || which == "mem" {
        tables.extend(experiments::t10_mem(&cfg));
    }
    if all || which == "t11" || which == "hier" {
        tables.push(experiments::t11_hier(&cfg, &router));
    }
    if all || which == "t12" || which == "cache" {
        tables.push(experiments::t12_cache(&cfg, &router));
    }
    if all || which == "t13" || which == "batch" {
        tables.push(experiments::t13_batch(&cfg, &router));
    }
    if all || which == "t14" || which == "mlp" {
        tables.push(experiments::t14_mlp(&cfg, &router));
    }
    if all || which == "t15" || which == "fatleaf" {
        tables.push(experiments::t15_fatleaf(&cfg, &router));
    }
    if all || which == "t16" || which == "fatinner" {
        tables.push(experiments::t16_fatinner(&cfg, &router));
    }
    if all || which == "t17" || which == "chaos" {
        tables.push(experiments::t17_chaos(&cfg, &router));
    }
    if all || which == "t18" || which == "replica" {
        tables.push(experiments::t18_replica(&cfg, &router));
    }
    if tables.is_empty() {
        eprintln!("unknown experiment '{which}' (t1 t2 t3 t4 t5 t6 t78 t9 t10 t11 t12 t13 t14 t15 t16 t17 t18 all)");
        std::process::exit(2);
    }
    let mut out = String::new();
    for t in &tables {
        t.print();
        out.push_str(&t.to_markdown());
        out.push('\n');
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, out).expect("write --out file");
        println!("(written to {path})");
    }
}

fn run(args: &Args) {
    let kind = StoreKind::parse(&args.str_or("store", "det")).unwrap_or_else(|| {
        eprintln!("unknown --store (det rwl random fixed twolevel spo spo2 tbb)");
        std::process::exit(2);
    });
    let ops = args.u64_or("ops", 1_000_000);
    let threads = args.usize_or("threads", 8);
    let mix = match args.str_or("mix", "w1").as_str() {
        "w1" => OpMix::W1,
        "w2" => OpMix::W2,
        "hash" => OpMix::HASH,
        "range" => OpMix::RANGE,
        "hier" => OpMix::HIER,
        "bulk" => OpMix::BULK,
        "r95" => OpMix::READ95,
        "r70" => OpMix::READ70,
        "r50" => OpMix::READ50,
        other => {
            eprintln!("unknown --mix '{other}' (w1 w2 hash range hier bulk r95 r70 r50)");
            std::process::exit(2);
        }
    };
    let mode = ExecMode::parse(&args.str_or("exec", "direct")).unwrap_or_else(|| {
        eprintln!("unknown --exec (direct delegated replicated)");
        std::process::exit(2);
    });
    if let Some(ns) = args.get("inject-latency") {
        LATENCY.enable(ns.parse().expect("--inject-latency NS"));
    }
    let topo = Topology::virtual_grid(
        args.usize_or("numa-nodes", 8),
        args.usize_or("cpus-per-node", 16),
    );
    let router = KeyRouter::auto(&artifacts_dir());
    // --leaf-cap K / --inner-cap F override the terminal-chunk width and
    // the routing-block arity (F < 2 disables the fat inner blocks)
    let leaf_cap = args.get("leaf-cap").map(|s| s.parse().expect("--leaf-cap K"));
    let inner_cap = args.get("inner-cap").map(|s| s.parse().expect("--inner-cap F"));
    let store = Arc::new(ShardedStore::with_caps(
        kind,
        8,
        (ops as usize / 4).max(1 << 16),
        topo,
        threads,
        leaf_cap,
        inner_cap,
    ));
    store.set_finger_cache(args.bool_or("fingers", true));
    let mut spec = WorkloadSpec::new("run", ops, mix, args.u64_or("key-space", (ops / 2).max(1 << 16)))
        .with_range_window(args.u64_or("range-window", 64));
    let seed = args.u64_or("seed", 7);
    let run_len = args.u64_or("run-len", 0);
    if run_len > 0 {
        spec = spec
            .with_clustered_runs(run_len, args.u64_or("run-stride", 1))
            .with_run_salt(seed);
    }
    let opts = RunOptions {
        mode,
        batch_n: args.usize_or("batch-n", 64),
        combining: args.bool_or("combine", true),
        interleave: args.usize_or("interleave", 0),
        // 0 = unbounded waits (the historical default); >0 bounds sync
        // waits/handoffs and arms heartbeat takeover at a quarter of it.
        op_timeout: match args.u64_or("op-timeout-ms", 0) {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
        // replicated mode: maintenance tick cadence (ops between local
        // replica ticks per worker; 0 leaves replicas entirely stale)
        replica_tick_every: args.usize_or("replica-tick", 64),
    };
    let m = run_with_opts(&store, &spec, threads, &router, seed, opts);
    println!(
        "store: {} x{} shards | threads {threads} | ops {ops} | exec {}",
        store.kind_name(),
        store.num_shards(),
        mode.name()
    );
    println!(
        "fill   : {:.4}s (router={})",
        m.fill_seconds,
        if router.is_aot() { "AOT" } else { "native" }
    );
    println!("drain  : {:.4}s  ({:.3} Mops/s)", m.drain_seconds, m.throughput_mops());
    println!(
        "ops    : {} inserts, {} finds ({} hit), {} erases",
        m.inserts, m.finds, m.found, m.erases
    );
    if m.ranges > 0 {
        println!(
            "ranges : {} scans, {} rows ({:.1} rows/scan, window {})",
            m.ranges,
            m.range_rows,
            m.range_rows as f64 / m.ranges as f64,
            spec.range_window
        );
    }
    println!("numa   : {} local, {} remote accesses", m.local_accesses, m.remote_accesses);
    if m.fabric.submitted > 0 {
        println!(
            "fabric : {} ops in {} batches (occupancy {:.1}, {} inline), handoff {:.1}us avg, \
             peak depth {}, backpressure {}, remote-exec {}",
            m.fabric.submitted,
            m.fabric.batches,
            m.fabric.batch_occupancy(),
            m.fabric.inline_ops,
            m.fabric.avg_handoff_us(),
            m.fabric.peak_depth,
            m.fabric.backpressure,
            m.fabric.remote_exec,
        );
        if m.fabric.owner_deaths > 0 || m.fabric.direct_fallback > 0 || m.fabric.errored > 0 {
            println!(
                "faults : {} owner deaths, {} shards adopted, {} adopted batches, \
                 recovery {:.1}us, {} direct-fallback ops, {} errored, {} sync timeouts",
                m.fabric.owner_deaths,
                m.fabric.shards_adopted,
                m.fabric.adopted_batches,
                m.fabric.recovery_ns as f64 / 1000.0,
                m.fabric.direct_fallback,
                m.fabric.errored,
                m.fabric.sync_timeouts,
            );
        }
        if m.fabric.combined_drains > 0 {
            println!(
                "combine: {} drains merged {} batches ({:.1}/drain) into {} runs \
                 ({} fused, {} interleaved), {} finds coalesced, flush adapt {}^ {}v",
                m.fabric.combined_drains,
                m.fabric.combined_batches,
                m.fabric.combined_batches_per_drain(),
                m.fabric.combined_runs,
                m.fabric.fused_runs,
                m.fabric.interleaved_runs,
                m.fabric.coalesced_finds,
                m.fabric.flush_grow,
                m.fabric.flush_shrink,
            );
        }
    }
    let sl = store.stats();
    if sl.node_derefs > 0 {
        let ops_done = m.ops().max(1);
        println!(
            "cache  : {:.1} node derefs/op, {:.1} prefetches/op, finger hit {:.1}% ({} of {} consults)",
            sl.node_derefs as f64 / ops_done as f64,
            sl.prefetches as f64 / ops_done as f64,
            100.0 * sl.finger_hit_rate(),
            sl.finger_hits,
            sl.finger_attempts,
        );
    }
    if m.replica.lookups > 0 || m.replica.rebuilds > 0 {
        let r = &m.replica;
        println!(
            "replica: {:.1} index derefs/read ({} remote), fallback {:.1}% ({} of {} lookups), \
             {} walk hops, {} left steps, {} records ({} consumed), {} patches, {} rebuilds, {} ticks",
            r.derefs_per_read(),
            r.remote_index_derefs,
            100.0 * r.fallback_rate(),
            r.fallbacks,
            r.lookups,
            r.walk_hops,
            r.left_steps,
            r.records_published,
            r.records_consumed,
            r.patches,
            r.rebuilds,
            r.ticks,
        );
    }
    if m.mem.allocs > 0 {
        println!(
            "mem    : {} allocs ({:.1}% recycled, {:.1}% magazine), {} nodes in {} blocks / {} arenas, locality hit {:.1}%",
            m.mem.allocs,
            100.0 * m.mem.recycle_rate(),
            100.0 * m.mem.magazine_hit_rate(),
            m.mem.capacity,
            m.mem.blocks,
            m.mem.arenas,
            100.0 * m.mem.locality_hit_rate(),
        );
    }
    println!("final  : {} keys resident", m.final_len);
}
