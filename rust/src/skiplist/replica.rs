//! NUMA-replicated index layers (the third execution mode's backbone).
//!
//! Each engaged NUMA node owns an [`IndexReplica`]: a private, node-locally
//! allocated copy of the level >= 1 routing structure — fat separator
//! blocks, built with the same `node.rs` block machinery as the shared
//! index — whose bottom level routes straight into the **single shared
//! terminal fat-leaf list**. A replicated read descends entirely inside its
//! node's replica (zero remote index-plane derefs by construction) and only
//! then touches the shared terminal chunk, where the landing is validated
//! live exactly like the shared lock-free descent: seqlock window probe,
//! post-window generation + mark re-check, and a key-coverage proof.
//!
//! ## Safe-stale (the finger/carry argument, applied to a whole index)
//!
//! Replicas are *lazily* synced, so a descent may land on a stale terminal
//! position. Staleness is recoverable because terminal membership changes
//! are themselves safe to race with:
//!
//! - **Landed too far left** (chunk's live max < key — appends, splits):
//!   walk right through *live* `next` links, re-probing each chunk. A chunk
//!   whose probe proves `lo <= key <= max` answers definitively (global
//!   sortedness makes live chunk ranges disjoint); walking off the right
//!   end proves absence, exactly as in `find_lockfree_from`.
//! - **Landed too far right** (chunk's live lo > key — merges publish
//!   through the left sibling, delete-by-copy raises `lo`): retry the next
//!   entry to the left inside the replica's leaf block, then one step into
//!   the parent's previous child; every leftward retry is followed by the
//!   same walk-right protocol, which crosses the moved region through live
//!   links.
//! - **Landed on a dead chunk** (generation bumped or marked): treated as
//!   "too far right" — step left and walk forward through live links.
//!
//! A descent that exhausts its (bounded) retries returns a **miss** and the
//! caller falls back to the shared index — slower, never wrong. Misses also
//! mark the replica dirty so the next maintenance tick rebuilds it.
//!
//! ## Sync protocol
//!
//! Writers publish a compact record (the affected boundary key) into a
//! fixed [`ReplicaLog`] ring at every terminal membership change (first
//! chunk, split, unlink, delete-by-copy, merge/borrow, max movement).
//! Each replica consumes the log from its own cursor: in-budget lag is
//! repaired by **patching** (re-deriving one leaf block's entries from a
//! live terminal walk, rewritten under the block's seqlock), while a lapped
//! cursor or a dirty flag triggers a **full rebuild** (fresh tree from a
//! terminal walk, atomic root swap, old blocks marked + retired so stale
//! readers fail generation checks into the miss path). Writers drain their
//! own node's log eagerly after each write; remote replicas catch up on the
//! maintenance tick or on descent-miss repair. Replica correctness never
//! depends on sync — patches and rebuilds are pure performance.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::mem::{thread_cpu, ArenaOptions, PoolStats};
use crate::numa::Topology;
use crate::util::simd;

use super::det::DetSkiplist;
use super::node::{NodeArena, NodeRef, MAX_INNER_CAP, SENTINEL};

/// Invalidation-ring slots per skiplist (shard). A writer burst larger than
/// this between two ticks laps the consumer, which then rebuilds instead of
/// patching — correctness is unaffected either way.
const LOG_RING: usize = 1024;

/// Replica branching factor: separators per replica block. The widest the
/// shared plane supports — replicas are read-mostly, so denser is better.
const REPLICA_BF: usize = MAX_INNER_CAP;

/// Rightward live-link hops a stale landing may take before giving up.
const WALK_HOP_CAP: usize = 64;

/// Records one maintenance tick consumes before yielding (bounds tick
/// latency on the write path; the rest stay queued for the next tick).
const PATCH_BUDGET: u64 = 128;

/// Snapshot of replica-plane counters (merged across a store's replicas).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplicaStats {
    /// Point reads attempted through a replica.
    pub lookups: u64,
    /// Range seeks attempted through a replica.
    pub seeks: u64,
    /// Replica block dereferences (the node-local index plane).
    pub index_derefs: u64,
    /// Replica block dereferences issued from a thread pinned to a
    /// *different* NUMA node — zero by construction in Replicated runs.
    pub remote_index_derefs: u64,
    /// Shared terminal-chunk probes issued by replica descents.
    pub terminal_probes: u64,
    /// Rightward terminal hops taken to recover stale landings.
    pub walk_hops: u64,
    /// Leftward in-block entry retries after dead / too-far-right landings.
    pub left_steps: u64,
    /// Parent-level previous-child retries (one per descent at most).
    pub parent_retries: u64,
    /// Descents that gave up and fell back to the shared index.
    pub fallbacks: u64,
    /// Invalidation records published by writers.
    pub records_published: u64,
    /// Invalidation records consumed by maintenance.
    pub records_consumed: u64,
    /// Leaf blocks rewritten in place from a live terminal walk.
    pub patches: u64,
    /// Full replica rebuilds (initial build included).
    pub rebuilds: u64,
    /// Maintenance ticks that did work (fast-path clean ticks excluded).
    pub ticks: u64,
}

impl ReplicaStats {
    /// Accumulate `other` (per-replica / per-shard aggregation).
    pub fn merge(&mut self, other: &ReplicaStats) {
        self.lookups += other.lookups;
        self.seeks += other.seeks;
        self.index_derefs += other.index_derefs;
        self.remote_index_derefs += other.remote_index_derefs;
        self.terminal_probes += other.terminal_probes;
        self.walk_hops += other.walk_hops;
        self.left_steps += other.left_steps;
        self.parent_retries += other.parent_retries;
        self.fallbacks += other.fallbacks;
        self.records_published += other.records_published;
        self.records_consumed += other.records_consumed;
        self.patches += other.patches;
        self.rebuilds += other.rebuilds;
        self.ticks += other.ticks;
    }

    /// Replica-plane derefs per lookup-class op (index + shared terminal).
    pub fn derefs_per_read(&self) -> f64 {
        let reads = (self.lookups + self.seeks).max(1);
        (self.index_derefs + self.terminal_probes + self.walk_hops) as f64 / reads as f64
    }

    /// Fraction of replica reads that fell back to the shared index.
    pub fn fallback_rate(&self) -> f64 {
        let reads = (self.lookups + self.seeks).max(1);
        self.fallbacks as f64 / reads as f64
    }
}

/// Per-replica counter block (relaxed; snapshotted into [`ReplicaStats`]).
#[derive(Default)]
struct Counters {
    lookups: AtomicU64,
    seeks: AtomicU64,
    index_derefs: AtomicU64,
    remote_index_derefs: AtomicU64,
    terminal_probes: AtomicU64,
    walk_hops: AtomicU64,
    left_steps: AtomicU64,
    parent_retries: AtomicU64,
    fallbacks: AtomicU64,
    records_consumed: AtomicU64,
    patches: AtomicU64,
    rebuilds: AtomicU64,
    ticks: AtomicU64,
}

impl Counters {
    #[inline]
    fn bump(c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> ReplicaStats {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        ReplicaStats {
            lookups: g(&self.lookups),
            seeks: g(&self.seeks),
            index_derefs: g(&self.index_derefs),
            remote_index_derefs: g(&self.remote_index_derefs),
            terminal_probes: g(&self.terminal_probes),
            walk_hops: g(&self.walk_hops),
            left_steps: g(&self.left_steps),
            parent_retries: g(&self.parent_retries),
            fallbacks: g(&self.fallbacks),
            records_published: 0, // set-level counter, merged by the owner
            records_consumed: g(&self.records_consumed),
            patches: g(&self.patches),
            rebuilds: g(&self.rebuilds),
            ticks: g(&self.ticks),
        }
    }
}

/// Fixed ring of boundary keys published by terminal-membership writers.
/// Monotonic write cursor; per-replica read cursors. Lapped readers detect
/// the overrun (`pos - cursor > LOG_RING`) and rebuild instead of trusting
/// possibly-overwritten slots — a stale slot read is at worst a patch of
/// the wrong (still valid) block, never a wrong answer.
pub(crate) struct ReplicaLog {
    ring: Vec<AtomicU64>,
    pos: AtomicU64,
}

impl ReplicaLog {
    fn new() -> ReplicaLog {
        ReplicaLog { ring: (0..LOG_RING).map(|_| AtomicU64::new(0)).collect(), pos: AtomicU64::new(0) }
    }

    #[inline]
    fn publish(&self, key: u64) {
        let i = self.pos.fetch_add(1, Ordering::AcqRel) as usize;
        self.ring[i % LOG_RING].store(key, Ordering::Release);
    }

    #[inline]
    fn position(&self) -> u64 {
        self.pos.load(Ordering::Acquire)
    }

    #[inline]
    fn read(&self, i: u64) -> u64 {
        self.ring[(i as usize) % LOG_RING].load(Ordering::Acquire)
    }
}

/// Outcome of a replica read attempt.
pub(crate) enum ReplicaRead {
    /// Definitive, live-validated answer (`None` = key proven absent).
    Value(Option<u64>),
    /// Descent gave up; caller must use the shared index.
    Miss,
}

/// Outcome of one terminal-landing attempt inside a leaf block.
enum Landing {
    Answer(Option<u64>),
    /// For seeks: the validated chunk the range walk starts from
    /// (`SENTINEL` = walked off the right end, empty result).
    Start(NodeRef),
    /// Block exhausted leftward: the covering chunk lies left of it.
    Left,
    Miss,
}

/// What a landing should produce.
#[derive(Clone, Copy, PartialEq)]
enum Want {
    /// Point lookup: the value (or proven absence).
    Point,
    /// Range seek: the first chunk whose live max >= key.
    Seek,
}

/// One NUMA node's private copy of the level >= 1 index: fat separator
/// blocks in a node-local arena, leaf blocks holding `(separator, shared
/// terminal chunk ref)` entries. Blocks at each level are `next`-linked;
/// block node keys (the last separator at build time) are fixed for the
/// tree's lifetime — live coverage may outgrow them, which descents repair
/// with rightward walks (stale-high parents are safe, as in the shared
/// index).
pub(crate) struct IndexReplica {
    /// Home NUMA node (arena placement + deref locality accounting).
    home: usize,
    /// Engaged-node count (`topo.nodes_in_use(threads)`): the same fold
    /// [`ReplicaSet::local`] selects replicas with, so the remote-deref
    /// charge detects genuine cross-node routing rather than real CPU ids
    /// beyond the virtually-pinned engaged set.
    engaged: usize,
    cpus_per_node: usize,
    /// Node-local block arena (chunk role unused; `inner_cap` = BF).
    arena: NodeArena,
    /// Current tree root (`SENTINEL` = empty / unbuilt: every read misses).
    root: AtomicU64,
    /// All blocks of the current tree (maintainer-owned; retired on swap).
    blocks: Mutex<Vec<NodeRef>>,
    /// Consume position into the shared [`ReplicaLog`].
    cursor: AtomicU64,
    /// Patch failed / log lapped / descent missed: rebuild on next tick.
    dirty: AtomicBool,
    /// Exactly mirrors the terminal list: set by a rebuild that raced no
    /// writer, cleared by every published record. Gates the strong
    /// `check_invariants` agreement assertion.
    exact: AtomicBool,
    /// Maintainer try-lock: one patcher/rebuilder at a time per replica.
    maint: AtomicBool,
    stats: Counters,
}

impl IndexReplica {
    fn new(node: usize, topo: &Topology, threads: usize, block_capacity: usize) -> IndexReplica {
        IndexReplica {
            home: node,
            engaged: topo.nodes_in_use(threads).max(1),
            cpus_per_node: topo.cpus_per_node.max(1),
            arena: NodeArena::for_capacity_caps(
                block_capacity,
                ArenaOptions::placed(node, topo, threads),
                1,
                REPLICA_BF,
            ),
            root: AtomicU64::new(SENTINEL),
            blocks: Mutex::new(Vec::new()),
            cursor: AtomicU64::new(0),
            dirty: AtomicBool::new(false),
            exact: AtomicBool::new(false),
            maint: AtomicBool::new(false),
            stats: Counters::default(),
        }
    }

    /// Count one replica-block deref, charged remote when the calling
    /// thread's engaged-set node differs from `home` — i.e. when routing
    /// handed the thread a replica that is not its node-local one.
    #[inline]
    fn deref(&self) {
        Counters::bump(&self.stats.index_derefs);
        let cpu = thread_cpu();
        if cpu != usize::MAX && (cpu / self.cpus_per_node) % self.engaged != self.home {
            Counters::bump(&self.stats.remote_index_derefs);
        }
    }

    #[inline]
    fn note_miss(&self) {
        Counters::bump(&self.stats.fallbacks);
        self.dirty.store(true, Ordering::Release);
    }

    // ------------------------------------------------------------------
    // Read path
    // ------------------------------------------------------------------

    /// Point lookup through this replica. `Value` answers carry the full
    /// shared-index validation (coverage proof + post-window mark/gen
    /// re-check on the answering chunk); `Miss` means fall back.
    pub(crate) fn lookup(&self, det: &DetSkiplist, key: u64) -> ReplicaRead {
        Counters::bump(&self.stats.lookups);
        match self.landing(det, key, Want::Point) {
            Landing::Answer(v) => ReplicaRead::Value(v),
            _ => {
                self.note_miss();
                ReplicaRead::Miss
            }
        }
    }

    /// Range seek: the shared terminal chunk a walk for keys `>= lo`
    /// starts at (`Some(SENTINEL)` = proven past the end). `None` = miss.
    pub(crate) fn seek(&self, det: &DetSkiplist, lo: u64) -> Option<NodeRef> {
        Counters::bump(&self.stats.seeks);
        match self.landing(det, lo, Want::Seek) {
            Landing::Start(r) => Some(r),
            _ => {
                self.note_miss();
                None
            }
        }
    }

    /// Descend this replica for `key` and run the terminal protocol.
    fn landing(&self, det: &DetSkiplist, key: u64, want: Want) -> Landing {
        let mut cur = self.root.load(Ordering::Acquire);
        if cur == SENTINEL {
            return Landing::Miss;
        }
        let mut seps = [0u64; MAX_INNER_CAP];
        let mut childs = [SENTINEL; MAX_INNER_CAP];
        // The parent's previous child (a leaf block), for one left retry.
        let mut parent_left: Option<NodeRef> = None;
        // Bounded: tree height + a few lateral moves.
        for _ in 0..48 {
            self.deref();
            let Some(n) = self.arena.resolve(cur) else { return Landing::Miss };
            if n.is_marked() {
                return Landing::Miss; // tree retired under us (root swap)
            }
            let level = n.hot.level.load(Ordering::Relaxed);
            let Some((count, _bkey, bnext)) = self.arena.block_snapshot(cur, &mut seps, &mut childs)
            else {
                return Landing::Miss;
            };
            let rank = simd::rank(&seps[..count], key);
            if level >= 2 {
                if rank == count {
                    if bnext != SENTINEL {
                        // Block range ended below key (stale-high parent
                        // separator): lateral move, like the shared index.
                        cur = bnext;
                        continue;
                    }
                    // Rightmost spine: clamp into the last subtree — the
                    // terminal walk-right recovers any growth past it.
                    parent_left = if count >= 2 { Some(childs[count - 2]) } else { None };
                    cur = childs[count - 1];
                    continue;
                }
                parent_left = if rank > 0 { Some(childs[rank - 1]) } else { None };
                cur = childs[rank];
                continue;
            }
            // Leaf block: entries are shared terminal chunks. Clamp
            // past-the-end ranks to the last entry — rightward recovery
            // through live terminal links beats block hopping.
            let r0 = rank.min(count - 1);
            match self.terminal(det, &childs[..count], r0, key, want) {
                Landing::Left => match parent_left.take() {
                    None => return Landing::Miss,
                    Some(lb) => {
                        // One parent-level retry: land on the previous leaf
                        // block's last entry and re-run the protocol.
                        Counters::bump(&self.stats.parent_retries);
                        self.deref();
                        if self.arena.resolve(lb).is_none() {
                            return Landing::Miss;
                        }
                        let Some((c2, _, _)) = self.arena.block_snapshot(lb, &mut seps, &mut childs)
                        else {
                            return Landing::Miss;
                        };
                        return match self.terminal(det, &childs[..c2], c2 - 1, key, want) {
                            Landing::Left => Landing::Miss,
                            other => other,
                        };
                    }
                },
                other => return other,
            }
        }
        Landing::Miss
    }

    /// The terminal protocol: probe entry `r` of a leaf block's `childs`,
    /// retrying leftward on dead / too-far-right landings and walking
    /// right through live links on too-far-left ones.
    fn terminal(
        &self,
        det: &DetSkiplist,
        childs: &[NodeRef],
        mut r: usize,
        key: u64,
        want: Want,
    ) -> Landing {
        loop {
            Counters::bump(&self.stats.terminal_probes);
            if let Some(p) = det.arena().chunk_probe(childs[r], key) {
                if key > p.max {
                    // Too far left (or just left of the target): recover
                    // rightward through live links — sound regardless of
                    // how stale the landing was, because a live chunk with
                    // max < key proves the covering position is right of it.
                    return self.walk_right(det, p.next, key, want);
                }
                if key >= p.lo {
                    // Coverage proven inside the probe window; the same
                    // post-window re-check as `find_lockfree_from` pins the
                    // chunk live at the linearization point.
                    let live =
                        det.arena().resolve(childs[r]).map(|n| !n.is_marked()).unwrap_or(false);
                    if live {
                        return match want {
                            Want::Point => Landing::Answer(p.hit),
                            Want::Seek => Landing::Start(childs[r]),
                        };
                    }
                }
                // key < p.lo (chunk's live range moved right — merge /
                // delete-by-copy) or the chunk died post-window: go left.
            }
            if r == 0 {
                return Landing::Left;
            }
            r -= 1;
            Counters::bump(&self.stats.left_steps);
        }
    }

    /// Walk live terminal `next` links until a chunk covers `key` (answer /
    /// range start) or the list ends (proven absence — mirrors the shared
    /// descent returning `Ok(None)` off the right end).
    fn walk_right(&self, det: &DetSkiplist, mut cur: NodeRef, key: u64, want: Want) -> Landing {
        for _ in 0..WALK_HOP_CAP {
            if cur == SENTINEL {
                return match want {
                    Want::Point => Landing::Answer(None),
                    Want::Seek => Landing::Start(SENTINEL),
                };
            }
            Counters::bump(&self.stats.walk_hops);
            let Some(p) = det.arena().chunk_probe(cur, key) else { return Landing::Miss };
            if key <= p.max {
                let live = det.arena().resolve(cur).map(|n| !n.is_marked()).unwrap_or(false);
                if !live {
                    return Landing::Miss;
                }
                return match want {
                    Want::Point => Landing::Answer(p.hit),
                    Want::Seek => Landing::Start(cur),
                };
            }
            cur = p.next;
        }
        Landing::Miss
    }

    // ------------------------------------------------------------------
    // Maintenance (single maintainer per replica via `maint` try-lock)
    // ------------------------------------------------------------------

    /// Consume pending log records (patching), or rebuild when dirty /
    /// lapped / forced. Returns `true` when the replica is clean after the
    /// call. Cheap when there is nothing to do (one fast-path check).
    pub(crate) fn maintain(&self, det: &DetSkiplist, log: &ReplicaLog, force: bool) -> bool {
        if !force
            && !self.dirty.load(Ordering::Acquire)
            && self.cursor.load(Ordering::Acquire) == log.position()
            && self.root.load(Ordering::Acquire) != SENTINEL
        {
            return true;
        }
        if self.maint.swap(true, Ordering::AcqRel) {
            return false; // another maintainer is on it
        }
        let clean = self.maintain_locked(det, log, force);
        self.maint.store(false, Ordering::Release);
        clean
    }

    fn maintain_locked(&self, det: &DetSkiplist, log: &ReplicaLog, force: bool) -> bool {
        Counters::bump(&self.stats.ticks);
        let cur = self.cursor.load(Ordering::Relaxed);
        let pre = log.position();
        let lag = pre.saturating_sub(cur);
        if force
            || self.dirty.load(Ordering::Acquire)
            || lag > LOG_RING as u64
            || self.root.load(Ordering::Acquire) == SENTINEL
        {
            if self.rebuild(det) {
                self.cursor.store(pre, Ordering::Release);
                self.dirty.store(false, Ordering::Release);
                // Exact only when no writer published during the walk.
                self.exact.store(log.position() == pre, Ordering::Release);
                return log.position() == pre;
            }
            // Terminal walk tore under concurrent writers: stay dirty, the
            // old tree keeps serving (safe-stale) until the next tick.
            self.dirty.store(true, Ordering::Release);
            return false;
        }
        let take = lag.min(PATCH_BUDGET);
        for i in cur..cur + take {
            Counters::bump(&self.stats.records_consumed);
            if !self.patch(det, log.read(i)) {
                self.dirty.store(true, Ordering::Release);
                break;
            }
        }
        // Writers lapped us mid-consume: some slots we read were reused.
        if log.position().saturating_sub(cur) > LOG_RING as u64 {
            self.dirty.store(true, Ordering::Release);
        }
        self.cursor.store(cur + take, Ordering::Release);
        !self.dirty.load(Ordering::Acquire) && log.position() == cur + take
    }

    /// Re-derive the leaf block covering `k` from a live terminal walk and
    /// rewrite it under its seqlock. The block's node key is immutable —
    /// collected separators may exceed it (raised maxes), which descents
    /// tolerate; a span that outgrew the block fails the patch (rebuild).
    fn patch(&self, det: &DetSkiplist, k: u64) -> bool {
        let mut cur = self.root.load(Ordering::Acquire);
        if cur == SENTINEL {
            return false;
        }
        // Writer-side descent: the maintainer lock makes our tree stable.
        for _ in 0..32 {
            let Some(n) = self.arena.resolve(cur) else { return false };
            let level = n.hot.level.load(Ordering::Relaxed);
            let Some(cnt) = self.arena.block_len(cur) else { return false };
            let mut rank = cnt - 1;
            for i in 0..cnt {
                if self.arena.block_sep(cur, i) >= k {
                    rank = i;
                    break;
                }
            }
            if level == 1 {
                break;
            }
            cur = self.arena.block_child(cur, rank);
        }
        let header = self.arena.node(cur).key_next().0;
        let Some(cnt) = self.arena.block_len(cur) else { return false };
        // First live entry anchors the walk; a fully dead block rebuilds.
        let mut c = SENTINEL;
        for i in 0..cnt {
            let e = self.arena.block_child(cur, i);
            if det.arena().resolve(e).map(|n| !n.is_marked()).unwrap_or(false) {
                c = e;
                break;
            }
        }
        if c == SENTINEL {
            return false;
        }
        let mut seps = [0u64; MAX_INNER_CAP];
        let mut childs = [SENTINEL; MAX_INNER_CAP];
        let mut n = 0usize;
        loop {
            let Some((ck, cnext)) = det.arena().read_key_next(c) else { return false };
            if n == REPLICA_BF {
                return false; // span outgrew the block
            }
            seps[n] = ck;
            childs[n] = c;
            n += 1;
            if ck >= header || cnext == SENTINEL {
                break;
            }
            c = cnext;
        }
        Counters::bump(&self.stats.patches);
        let w = self.arena.block_write(cur);
        for i in 0..n {
            w.set_key(i, seps[i]);
            w.set_child(i, childs[i]);
        }
        w.set_count(n);
        true
    }

    /// Build a fresh tree from a live terminal walk, swap it in, and mark +
    /// retire the old blocks (stale readers then fail generation checks
    /// into the miss path). Returns `false` when the walk tore.
    fn rebuild(&self, det: &DetSkiplist) -> bool {
        let mut entries: Vec<(u64, NodeRef)> = Vec::new();
        if !collect_terminals(det, &mut entries) {
            return false;
        }
        let mut new_blocks = Vec::new();
        let root = if entries.is_empty() {
            SENTINEL
        } else {
            let mut level_refs = entries;
            let mut level = 1u32;
            loop {
                // Right-to-left per level so `next` links are known at
                // alloc time; `block_init`'s release fence orders content
                // before the root's release publish below.
                let groups: Vec<&[(u64, NodeRef)]> = level_refs.chunks(REPLICA_BF).collect();
                let mut next_level: Vec<(u64, NodeRef)> = Vec::with_capacity(groups.len());
                let mut next = SENTINEL;
                for g in groups.iter().rev() {
                    let seps: Vec<u64> = g.iter().map(|e| e.0).collect();
                    let childs: Vec<NodeRef> = g.iter().map(|e| e.1).collect();
                    let last = *seps.last().unwrap();
                    let r = self.arena.alloc(last, next, childs[0], 0, level);
                    self.arena.block_init(r, &seps, &childs);
                    new_blocks.push(r);
                    next_level.push((last, r));
                    next = r;
                }
                next_level.reverse();
                if next_level.len() == 1 {
                    break next_level[0].1;
                }
                level_refs = next_level;
                level += 1;
            }
        };
        self.root.store(root, Ordering::Release);
        let old = {
            let mut blocks = self.blocks.lock().unwrap();
            std::mem::replace(&mut *blocks, new_blocks)
        };
        for r in old {
            if let Some(n) = self.arena.resolve(r) {
                n.cold.mark.store(true, Ordering::Release);
                self.arena.retire(r);
            }
        }
        Counters::bump(&self.stats.rebuilds);
        true
    }

    /// Whether the replica exactly mirrors the terminal list (rebuilt at
    /// quiescence, nothing published since). Gates the strong agreement
    /// assertion in `check_invariants`.
    pub(crate) fn is_exact(&self) -> bool {
        self.exact.load(Ordering::Acquire)
    }

    /// Left-to-right `(separator, shared chunk ref)` entries of the leaf
    /// blocks (quiescent use only: `check_invariants` / tests).
    pub(crate) fn leaf_entries(&self) -> Vec<(u64, NodeRef)> {
        let mut out = Vec::new();
        let mut cur = self.root.load(Ordering::Acquire);
        if cur == SENTINEL {
            return out;
        }
        // descend leftmost spine to level 1
        for _ in 0..32 {
            let Some(n) = self.arena.resolve(cur) else { return out };
            if n.hot.level.load(Ordering::Relaxed) == 1 {
                break;
            }
            match self.arena.block_len(cur) {
                Some(_) => cur = self.arena.block_child(cur, 0),
                None => return out,
            }
        }
        while cur != SENTINEL {
            let Some(n) = self.arena.resolve(cur) else { break };
            let Some(cnt) = self.arena.block_len(cur) else { break };
            for i in 0..cnt {
                out.push((self.arena.block_sep(cur, i), self.arena.block_child(cur, i)));
            }
            cur = n.next();
        }
        out
    }

    fn stats_snapshot(&self) -> ReplicaStats {
        self.stats.snapshot()
    }

    fn mem_stats(&self) -> PoolStats {
        self.arena.stats()
    }
}

/// Walk the live terminal list into `(chunk key, chunk ref)` entries.
/// Retries a bounded number of times on torn reads; `false` = give up
/// (caller keeps the old tree and stays dirty).
fn collect_terminals(det: &DetSkiplist, out: &mut Vec<(u64, NodeRef)>) -> bool {
    'retry: for _ in 0..8 {
        out.clear();
        let Some(start) = det.first_terminal() else { continue 'retry };
        let mut cur = start;
        while cur != SENTINEL {
            let Some((k, nx)) = det.arena().read_key_next(cur) else { continue 'retry };
            out.push((k, cur));
            cur = nx;
        }
        return true;
    }
    false
}

/// The per-skiplist replica family: one [`IndexReplica`] per engaged NUMA
/// node plus the shared invalidation log. Lives inside [`DetSkiplist`]
/// behind a `OnceLock` — `None` until `enable_replicas`, so non-replicated
/// runs pay one atomic load per write-path publication check.
pub(crate) struct ReplicaSet {
    log: ReplicaLog,
    replicas: Vec<IndexReplica>,
    cpus_per_node: usize,
    published: AtomicU64,
}

impl ReplicaSet {
    /// Build one replica per engaged node (`topo.nodes_in_use(threads)`),
    /// each node-locally placed, and populate them from the current
    /// terminal list.
    pub(crate) fn new(det: &DetSkiplist, topo: &Topology, threads: usize) -> ReplicaSet {
        let nodes = topo.nodes_in_use(threads);
        // Generous block budget: ~chunks/(BF-1) blocks live per replica,
        // doubled for rebuild overlap (retired blocks recycle afterwards).
        let chunks = (det.arena().capacity() as usize / det.leaf_cap().max(1)).max(64);
        let block_capacity = (chunks / 4).max(1024);
        let set = ReplicaSet {
            log: ReplicaLog::new(),
            replicas: (0..nodes)
                .map(|n| IndexReplica::new(n, topo, threads, block_capacity))
                .collect(),
            cpus_per_node: topo.cpus_per_node.max(1),
            published: AtomicU64::new(0),
        };
        for r in &set.replicas {
            r.maintain(det, &set.log, true);
        }
        set
    }

    /// Publish a terminal-membership change (writer hook).
    #[inline]
    pub(crate) fn note(&self, key: u64) {
        self.published.fetch_add(1, Ordering::Relaxed);
        self.log.publish(key);
        for r in &self.replicas {
            r.exact.store(false, Ordering::Release);
        }
    }

    /// The calling thread's node-local replica (unpinned threads map to
    /// node 0; nodes beyond the engaged set wrap around).
    #[inline]
    pub(crate) fn local(&self) -> &IndexReplica {
        let cpu = thread_cpu();
        let node = if cpu == usize::MAX { 0 } else { cpu / self.cpus_per_node };
        &self.replicas[node % self.replicas.len()]
    }

    pub(crate) fn log(&self) -> &ReplicaLog {
        &self.log
    }

    pub(crate) fn replicas(&self) -> &[IndexReplica] {
        &self.replicas
    }

    /// Merged counters across this set's replicas.
    pub(crate) fn stats(&self) -> ReplicaStats {
        let mut out = ReplicaStats::default();
        for r in &self.replicas {
            out.merge(&r.stats_snapshot());
        }
        out.records_published = self.published.load(Ordering::Relaxed);
        out
    }

    /// Merged arena accounting across this set's replicas.
    pub(crate) fn mem_stats(&self) -> PoolStats {
        let mut out = PoolStats::default();
        for r in &self.replicas {
            out.merge(&r.mem_stats());
        }
        out
    }
}
