//! Skiplist nodes and the generation-tagged node arena.
//!
//! A node link (`NodeRef`) is not a raw pointer but a packed
//! `(generation << 32) | index` word.  The arena keeps node memory alive for
//! its whole lifetime (block allocation, §V) and bumps a node's generation
//! when it is retired — the paper's "reference counters incremented during
//! every recycling operation" ABA defense.  Any traversal that resolves a
//! stale link observes a generation mismatch and retries; recycled memory
//! can never masquerade as the node a link meant.
//!
//! The allocator body lives in the unified [`crate::mem::BlockArena`]
//! (block directory, per-thread magazines, capacity-sized free list);
//! [`NodeArena`] only adds the skiplist-specific parts: the packed link
//! format, the slot-0 sentinel, and `(key, next)` snapshot validation.
//!
//! The `(key, next)` pair lives in one [`AtomicU128`] (key in bits 127:64,
//! next link in bits 63:0, exactly the paper's wide-integer layout), so the
//! lock-free `Find` reads a consistent view with a single atomic load and
//! rebalancing publishes `(key, next)` changes atomically.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};

use crate::mem::{ArenaNode, ArenaOptions, BlockArena, PoolStats};
use crate::sync::{hi64, lo64, pack, AtomicU128, RwSpinLock};

/// Packed node link: `(gen << 32) | idx`. `SENTINEL` (0) is the shared
/// self-referential tail/bottom sentinel of every list level.
pub type NodeRef = u64;

/// The sentinel link: index 0, generation 0 (never retired).
pub const SENTINEL: NodeRef = 0;

#[inline(always)]
pub fn ref_idx(r: NodeRef) -> u32 {
    r as u32
}

#[inline(always)]
pub fn ref_gen(r: NodeRef) -> u32 {
    (r >> 32) as u32
}

#[inline(always)]
pub fn make_ref(gen: u32, idx: u32) -> NodeRef {
    (gen as u64) << 32 | idx as u64
}

/// A skiplist node (terminal and non-terminal share the layout).
pub struct Node {
    /// `(key << 64) | next` — read/written as one atomic word.
    pub kn: AtomicU128,
    /// Link to the first child (non-terminal) or `SENTINEL` (terminal).
    pub bottom: AtomicU64,
    /// Payload (terminal nodes only).
    pub value: AtomicU64,
    /// Per-node reader-writer lock (writers: L/LL acquisition; readers:
    /// only in the RWL find baseline).
    pub lock: RwSpinLock,
    /// Set when the node has been removed from its list.
    pub mark: AtomicBool,
    /// Recycle generation; bumped at retire. Links carry the expected value.
    pub gen: AtomicU32,
    /// Height: 0 = terminal, 1 = leaf, increasing upward.
    pub level: AtomicU32,
}

impl Node {
    #[inline]
    pub fn key(&self) -> u64 {
        hi64(self.kn.load())
    }

    #[inline]
    pub fn next(&self) -> NodeRef {
        lo64(self.kn.load())
    }

    /// Atomic `(key, next)` snapshot.
    #[inline]
    pub fn key_next(&self) -> (u64, NodeRef) {
        let kn = self.kn.load();
        (hi64(kn), lo64(kn))
    }

    #[inline]
    pub fn set_key_next(&self, key: u64, next: NodeRef) {
        self.kn.store(pack(key, next));
    }

    #[inline]
    pub fn is_marked(&self) -> bool {
        self.mark.load(Ordering::Acquire)
    }
}

impl ArenaNode for Node {
    fn vacant() -> Node {
        Node {
            kn: AtomicU128::new(0),
            bottom: AtomicU64::new(SENTINEL),
            value: AtomicU64::new(0),
            lock: RwSpinLock::new(),
            mark: AtomicBool::new(false),
            gen: AtomicU32::new(0),
            level: AtomicU32::new(0),
        }
    }

    fn generation(&self) -> &AtomicU32 {
        &self.gen
    }
}

/// Index-addressed arena of [`Node`]s with lock-free recycling — a typed
/// façade over the unified [`BlockArena`].
pub struct NodeArena {
    arena: BlockArena<Node>,
}

impl NodeArena {
    /// Arena with `block_size` nodes per block, at most `max_blocks` blocks.
    /// Index 0 is pre-allocated as the self-referential sentinel.
    pub fn new(block_size: usize, max_blocks: usize) -> NodeArena {
        Self::with_options(block_size, max_blocks, ArenaOptions::default())
    }

    /// Like [`NodeArena::new`] with explicit placement/magazine options
    /// (per-shard arenas are homed on their shard's NUMA node).
    pub fn with_options(block_size: usize, max_blocks: usize, opts: ArenaOptions) -> NodeArena {
        Self::finish(BlockArena::with_options(block_size, max_blocks, opts))
    }

    /// Arena sized by the shared §V capacity policy
    /// ([`BlockArena::for_capacity`]) for up to `capacity` live nodes.
    pub fn for_capacity(capacity: usize, opts: ArenaOptions) -> NodeArena {
        Self::finish(BlockArena::for_capacity(capacity, opts))
    }

    fn finish(arena: BlockArena<Node>) -> NodeArena {
        let a = NodeArena { arena };
        // slot 0: the sentinel — key MAX, next/bottom self, never retired.
        let s = a.alloc(u64::MAX, SENTINEL, SENTINEL, 0, 0);
        debug_assert_eq!(s, SENTINEL);
        a
    }

    /// Resolve a link; `None` if the node has been retired/recycled since
    /// the link was created (generation mismatch).
    #[inline]
    pub fn resolve(&self, r: NodeRef) -> Option<&Node> {
        let n = self.arena.raw(ref_idx(r));
        if n.gen.load(Ordering::Acquire) == ref_gen(r) {
            Some(n)
        } else {
            None
        }
    }

    /// Resolve without the generation check (sentinel / owned refs).
    #[inline]
    pub fn node(&self, r: NodeRef) -> &Node {
        self.arena.raw(ref_idx(r))
    }

    /// Read a validated `(key, next)` snapshot of `r`: the generation is
    /// re-checked *after* the read, so the returned pair was published while
    /// the node was live under this link.
    #[inline]
    pub fn read_key_next(&self, r: NodeRef) -> Option<(u64, NodeRef)> {
        let n = self.arena.raw(ref_idx(r));
        if n.gen.load(Ordering::Acquire) != ref_gen(r) {
            return None;
        }
        let (k, nx) = n.key_next();
        if n.gen.load(Ordering::Acquire) != ref_gen(r) {
            return None;
        }
        Some((k, nx))
    }

    /// Allocate a node (recycled or fresh) and initialize it. The lock word
    /// and generation are deliberately *not* reset (stragglers may still be
    /// spinning on them; they re-validate after acquiring).
    pub fn alloc(&self, key: u64, next: NodeRef, bottom: NodeRef, value: u64, level: u32) -> NodeRef {
        let idx = self.arena.alloc_slot();
        let n = self.arena.raw(idx);
        n.bottom.store(bottom, Ordering::Relaxed);
        n.value.store(value, Ordering::Relaxed);
        n.mark.store(false, Ordering::Relaxed);
        n.level.store(level, Ordering::Relaxed);
        // publish (key,next) last
        n.set_key_next(key, next);
        make_ref(n.gen.load(Ordering::Acquire), idx)
    }

    /// Retire a node: bump its generation (invalidating every existing link
    /// to it) and return it to the magazine/free pool.
    pub fn retire(&self, r: NodeRef) {
        debug_assert_ne!(r, SENTINEL, "cannot retire the sentinel");
        debug_assert!(self.arena.raw(ref_idx(r)).is_marked(), "retiring an unmarked node");
        self.arena.retire_slot(ref_idx(r));
    }

    /// Nodes currently materialized (capacity in nodes).
    pub fn capacity(&self) -> u64 {
        self.arena.capacity()
    }

    /// §V accounting snapshot (allocs/recycled/capacity/locality). Not a
    /// cheap counter read: it locks every (thread-private, uncontended)
    /// magazine once — take one snapshot and read the fields you need.
    pub fn stats(&self) -> PoolStats {
        self.arena.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentinel_is_self_referential() {
        let a = NodeArena::new(16, 16);
        let s = a.node(SENTINEL);
        assert_eq!(s.key(), u64::MAX);
        assert_eq!(s.next(), SENTINEL);
        assert_eq!(s.bottom.load(Ordering::Relaxed), SENTINEL);
    }

    #[test]
    fn alloc_and_resolve() {
        let a = NodeArena::new(16, 16);
        let r = a.alloc(42, SENTINEL, SENTINEL, 7, 0);
        let n = a.resolve(r).unwrap();
        assert_eq!(n.key(), 42);
        assert_eq!(n.value.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn retire_invalidates_links() {
        let a = NodeArena::new(16, 16);
        let r = a.alloc(1, SENTINEL, SENTINEL, 0, 0);
        a.node(r).mark.store(true, Ordering::Release);
        a.retire(r);
        assert!(a.resolve(r).is_none());
        assert!(a.read_key_next(r).is_none());
    }

    #[test]
    fn recycled_slot_gets_new_generation() {
        let a = NodeArena::new(16, 16);
        let r1 = a.alloc(1, SENTINEL, SENTINEL, 0, 0);
        a.node(r1).mark.store(true, Ordering::Release);
        a.retire(r1);
        let r2 = a.alloc(2, SENTINEL, SENTINEL, 0, 0);
        assert_eq!(ref_idx(r1), ref_idx(r2), "slot reused");
        assert_ne!(ref_gen(r1), ref_gen(r2), "generation bumped");
        assert!(a.resolve(r1).is_none());
        assert_eq!(a.resolve(r2).unwrap().key(), 2);
    }

    #[test]
    fn stats_flow_through_the_unified_arena() {
        let a = NodeArena::new(16, 16);
        let r = a.alloc(1, SENTINEL, SENTINEL, 0, 0);
        a.node(r).mark.store(true, Ordering::Release);
        a.retire(r);
        let _ = a.alloc(2, SENTINEL, SENTINEL, 0, 0);
        let st = a.stats();
        assert_eq!(st.allocs, 3, "sentinel + two allocs");
        assert_eq!(st.recycled, 1);
        assert_eq!(st.retired, 1);
        assert_eq!(st.arenas, 1);
        assert_eq!(st.capacity, a.capacity());
    }

    #[test]
    fn ref_packing() {
        let r = make_ref(0xABCD, 0x1234);
        assert_eq!(ref_gen(r), 0xABCD);
        assert_eq!(ref_idx(r), 0x1234);
    }
}
