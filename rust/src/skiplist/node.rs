//! Skiplist nodes and the generation-tagged node arena.
//!
//! A node link (`NodeRef`) is not a raw pointer but a packed
//! `(generation << 32) | index` word.  The arena keeps node memory alive for
//! its whole lifetime (block allocation, §V) and bumps a node's generation
//! when it is retired — the paper's "reference counters incremented during
//! every recycling operation" ABA defense.  Any traversal that resolves a
//! stale link observes a generation mismatch and retries; recycled memory
//! can never masquerade as the node a link meant.
//!
//! **Hot/cold split.** The node is stored as two parallel plane slots in
//! the unified [`crate::mem::BlockArena`]:
//!
//! - [`NodeHot`] — the descent line: the packed `(key, next)` word,
//!   `bottom` and `level`, `#[repr(align(64))]` and statically asserted to
//!   fit one 64-byte cache line. A lock-free `Find` touches *only* hot
//!   lines until it reaches its terminal node.
//! - [`NodeCold`] — control state: the per-node RW lock, the removal mark,
//!   the recycle generation and the value. Writers and validation touch it;
//!   the descent stream does not, so lock ping-pong between writers never
//!   evicts the hot lines readers are traversing.
//!
//! [`NodeView`] pairs the two plane references back into one "node" for
//! call sites.
//!
//! The allocator body lives in the unified [`crate::mem::BlockArena`]
//! (block directory, per-thread magazines, capacity-sized free list);
//! [`NodeArena`] only adds the skiplist-specific parts: the packed link
//! format, the slot-0 sentinel, `(key, next)` snapshot validation and the
//! descent prefetch helper.
//!
//! The `(key, next)` pair lives in one [`AtomicU128`] (key in bits 127:64,
//! next link in bits 63:0, exactly the paper's wide-integer layout), so the
//! lock-free `Find` reads a consistent view with a single atomic load and
//! rebalancing publishes `(key, next)` changes atomically.
//!
//! **Publication ordering.** `NodeArena::alloc` initializes `bottom`,
//! `value`, `mark` and `level` with relaxed stores and only then publishes
//! the node by storing `(key, next)`. A release fence sits between the two
//! phases: any thread that observes the published `(key, next)` word (the
//! `AtomicU128` load synchronizes — x86 `lock cmpxchg16b` or the seqlock's
//! acquire/release pair) therefore also observes every field initialized
//! before the fence, even through relaxed loads. This is the happens-before
//! edge the lock-free `Find` relies on when it reads `bottom`/`value` of a
//! node it discovered through a freshly published link (see the
//! `alloc_publication_is_release_ordered` stress test).

use std::sync::atomic::{fence, AtomicBool, AtomicU32, AtomicU64, Ordering};

use crate::mem::{ArenaNode, ArenaOptions, BlockArena, PoolStats};
use crate::sync::{hi64, lo64, pack, AtomicU128, RwSpinLock};
use crate::util::simd;

/// Packed node link: `(gen << 32) | idx`. `SENTINEL` (0) is the shared
/// self-referential tail/bottom sentinel of every list level.
pub type NodeRef = u64;

/// Hard upper bound on keys per terminal chunk (the fat-leaf plane's key
/// and value arrays are sized/copied against this at compile time).
pub const MAX_LEAF_CAP: usize = 32;

/// Default terminal-chunk capacity: 16 keys = two 64-byte lines of keys
/// (plus two of values), the sweet spot Table XV sweeps around.
pub const DEFAULT_LEAF_CAP: usize = 16;

/// Hard upper bound on separator keys per fat inner routing block (one
/// 128-byte separator array + one 128-byte child array at the max).
pub const MAX_INNER_CAP: usize = 16;

/// Default inner-block capacity: 8 separators = one 64-byte line of keys
/// plus one of child links, the sweet spot Table XVI sweeps around.
pub const DEFAULT_INNER_CAP: usize = 8;

/// Count-word sentinel marking an inner block *overflowed*: the node
/// transiently has more children than `inner_cap` (rebalance windows allow
/// brief excursions past `F`), so readers must fall back to the linked
/// child walk. Any value `> inner_cap` routes to the fallback; `u64::MAX`
/// makes the intent unmistakable in a debugger.
const BLOCK_OVERFLOW: u64 = u64::MAX;

/// Chunk/block-plane slot layout (all `AtomicU64` words): `[0]` seqlock
/// version, `[1]` live key count, `[2 .. 2+P]` sorted keys, `[2+P .. 2+2P]`
/// the parallel second array, where `P` is the plane capacity
/// (`max(leaf_cap, inner_cap)` — terminal chunks and inner routing blocks
/// share the plane, so both arrays sit at the same offsets for either
/// role). For a terminal chunk the second array holds values; for a
/// level ≥ 1 routing block it holds child `NodeRef`s. The node's packed
/// `(key, next)` word doubles as the header — one atomic snapshot still
/// routes the descent, and in-slot state is versioned by the seqlock word.
const LEAF_VERSION: usize = 0;
const LEAF_COUNT: usize = 1;
const LEAF_KEYS: usize = 2;

/// Words per chunk/block-plane slot for a `plane_cap`-key slot.
#[inline]
pub fn leaf_words_for(plane_cap: usize) -> usize {
    LEAF_KEYS + 2 * plane_cap
}

/// A lock-free, seqlock-consistent probe of one terminal chunk: the fields
/// a descent needs to either answer for `key` or keep walking right. All
/// fields were read inside one version-stable window and generation-checked
/// after it, so they describe a single moment of a live chunk.
#[derive(Clone, Copy, Debug)]
pub struct ChunkProbe {
    /// Chunk coverage upper bound (== the node's packed key).
    pub max: u64,
    /// Next terminal chunk (the node's packed next).
    pub next: NodeRef,
    /// Smallest key in the chunk (`max` when the chunk is empty).
    pub lo: u64,
    /// Live keys in the chunk.
    pub count: usize,
    /// Value for `key` if the chunk holds it.
    pub hit: Option<u64>,
}

/// One routing decision computed from a fat inner node's separator block,
/// read under the same seqlock + generation protocol as [`ChunkProbe`].
/// The packed `(key, next)` header is read *inside* the version-stable
/// window, so the header and the block describe one consistent moment —
/// without that pairing a reader could combine a pre-split high key with a
/// post-split half-block and route right past the new sibling.
#[derive(Clone, Copy, Debug)]
pub enum BlockRoute {
    /// No usable block (unbuilt, or overflowed past `inner_cap` during a
    /// rebalance excursion): walk the linked child list from `bottom`.
    Fallback {
        /// Node key at the probe instant.
        nkey: u64,
        /// Node next at the probe instant.
        next: NodeRef,
    },
    /// The node's whole range is below the target: continue right.
    Right { nkey: u64, next: NodeRef },
    /// Descend directly into `child` — the first child whose stored
    /// separator is `>= target`.
    Descend {
        nkey: u64,
        next: NodeRef,
        child: NodeRef,
        /// Stored separator of the *previous* child (`None` when `child`
        /// is the first): `target > sep_lo` and separators are never
        /// stale-low, so `child`'s segment starts at or below
        /// `sep_lo + 1`. Fingers use this as a conservative lower bound.
        sep_lo: Option<u64>,
    },
}

/// Writer-side seqlock window over one chunk/block-plane slot. Opened only
/// while holding the owning node's (parent-serialized) write lock; data
/// stores inside the window are relaxed, and dropping the guard publishes
/// them with a release store of the even version. Lock-free readers that
/// overlapped the window observe an odd or changed version and retry.
/// The same guard serves terminal chunks (second array = values) and inner
/// routing blocks (second array = child links).
pub struct ChunkWrite<'a> {
    leaf: &'a [AtomicU64],
    cap: usize,
    v: u64,
}

impl ChunkWrite<'_> {
    #[inline]
    pub fn set_count(&self, count: usize) {
        debug_assert!(count <= self.cap);
        self.leaf[LEAF_COUNT].store(count as u64, Ordering::Relaxed);
    }

    #[inline]
    pub fn set_key(&self, i: usize, key: u64) {
        debug_assert!(i < self.cap);
        self.leaf[LEAF_KEYS + i].store(key, Ordering::Relaxed);
    }

    #[inline]
    pub fn set_val(&self, i: usize, val: u64) {
        debug_assert!(i < self.cap);
        self.leaf[LEAF_KEYS + self.cap + i].store(val, Ordering::Relaxed);
    }

    #[inline]
    pub fn key(&self, i: usize) -> u64 {
        self.leaf[LEAF_KEYS + i].load(Ordering::Relaxed)
    }

    #[inline]
    pub fn val(&self, i: usize) -> u64 {
        self.leaf[LEAF_KEYS + self.cap + i].load(Ordering::Relaxed)
    }

    /// Block-role alias: child link `i` (the second array).
    #[inline]
    pub fn set_child(&self, i: usize, child: NodeRef) {
        self.set_val(i, child);
    }

    /// Mark the block overflowed: readers fall back to the linked child
    /// walk until a later rebuild publishes a real count.
    #[inline]
    pub fn set_overflow(&self) {
        self.leaf[LEAF_COUNT].store(BLOCK_OVERFLOW, Ordering::Relaxed);
    }
}

impl Drop for ChunkWrite<'_> {
    fn drop(&mut self) {
        // Release: orders every relaxed data store in the window before the
        // even version becomes visible.
        self.leaf[LEAF_VERSION].store(self.v.wrapping_add(2), Ordering::Release);
    }
}

/// The sentinel link: index 0, generation 0 (never retired).
pub const SENTINEL: NodeRef = 0;

#[inline(always)]
pub fn ref_idx(r: NodeRef) -> u32 {
    r as u32
}

#[inline(always)]
pub fn ref_gen(r: NodeRef) -> u32 {
    (r >> 32) as u32
}

#[inline(always)]
pub fn make_ref(gen: u32, idx: u32) -> NodeRef {
    (gen as u64) << 32 | idx as u64
}

/// Hot plane of a skiplist node: exactly what a descent dereferences,
/// packed into (at most) one 64-byte line. Terminal and non-terminal nodes
/// share the layout.
#[repr(align(64))]
pub struct NodeHot {
    /// `(key << 64) | next` — read/written as one atomic word.
    pub kn: AtomicU128,
    /// Link to the first child (non-terminal) or `SENTINEL` (terminal).
    pub bottom: AtomicU64,
    /// Height: 0 = terminal, 1 = leaf, increasing upward.
    pub level: AtomicU32,
}

// The whole point of the split: the descent line must be one cache line,
// aligned so it never straddles two. Checked at compile time on every
// target (the non-x86 AtomicU128 carries a seqlock word and still fits).
const _: () = {
    assert!(std::mem::size_of::<NodeHot>() == 64, "hot node plane must be exactly one cache line");
    assert!(std::mem::align_of::<NodeHot>() == 64, "hot node plane must be line-aligned");
};

/// Cold plane of a skiplist node: writer/validation control words.
pub struct NodeCold {
    /// Per-node reader-writer lock (writers: L/LL acquisition; readers:
    /// only in the RWL find baseline).
    pub lock: RwSpinLock,
    /// Set when the node has been removed from its list.
    pub mark: AtomicBool,
    /// Recycle generation; bumped at retire. Links carry the expected value.
    pub gen: AtomicU32,
    /// Payload (terminal nodes only).
    pub value: AtomicU64,
}

/// Tag type naming the skiplist node's hot/cold split (never instantiated).
pub struct Node;

impl ArenaNode for Node {
    type Hot = NodeHot;
    type Cold = NodeCold;

    fn vacant_hot() -> NodeHot {
        NodeHot {
            kn: AtomicU128::new(0),
            bottom: AtomicU64::new(SENTINEL),
            level: AtomicU32::new(0),
        }
    }

    fn vacant_cold() -> NodeCold {
        NodeCold {
            lock: RwSpinLock::new(),
            mark: AtomicBool::new(false),
            gen: AtomicU32::new(0),
            value: AtomicU64::new(0),
        }
    }

    fn generation(cold: &NodeCold) -> &AtomicU32 {
        &cold.gen
    }
}

/// Both planes of one node, paired back together for call sites. Copyable
/// reference pair — methods cover the common composite reads/writes, and
/// the `hot`/`cold` fields are public for direct plane access (which makes
/// the hot/cold cost of every touch visible at the call site).
#[derive(Clone, Copy)]
pub struct NodeView<'a> {
    pub hot: &'a NodeHot,
    pub cold: &'a NodeCold,
}

impl<'a> NodeView<'a> {
    #[inline]
    pub fn key(&self) -> u64 {
        hi64(self.hot.kn.load())
    }

    #[inline]
    pub fn next(&self) -> NodeRef {
        lo64(self.hot.kn.load())
    }

    /// Atomic `(key, next)` snapshot.
    #[inline]
    pub fn key_next(&self) -> (u64, NodeRef) {
        let kn = self.hot.kn.load();
        (hi64(kn), lo64(kn))
    }

    #[inline]
    pub fn set_key_next(&self, key: u64, next: NodeRef) {
        self.hot.kn.store(pack(key, next));
    }

    #[inline]
    pub fn is_marked(&self) -> bool {
        self.cold.mark.load(Ordering::Acquire)
    }
}

/// Index-addressed arena of skiplist nodes with lock-free recycling — a
/// typed façade over the unified [`BlockArena`].
pub struct NodeArena {
    arena: BlockArena<Node>,
    /// Keys per terminal chunk; 0 = no chunk/block plane (non-chunked
    /// users: the split-order table shares this arena type).
    leaf_cap: usize,
    /// Separators per fat inner routing block; `< 2` = inner blocks
    /// disabled (level ≥ 1 descents use the legacy linked child walk).
    inner_cap: usize,
    /// Plane slot width driver: `max(leaf_cap, inner_cap when enabled)`.
    /// Both plane roles index their second array at `LEAF_KEYS + plane_cap`.
    plane_cap: usize,
}

impl NodeArena {
    /// Arena with `block_size` nodes per block, at most `max_blocks` blocks.
    /// Index 0 is pre-allocated as the self-referential sentinel.
    pub fn new(block_size: usize, max_blocks: usize) -> NodeArena {
        Self::with_options(block_size, max_blocks, ArenaOptions::default())
    }

    /// Like [`NodeArena::new`] with explicit placement/magazine options
    /// (per-shard arenas are homed on their shard's NUMA node).
    pub fn with_options(block_size: usize, max_blocks: usize, opts: ArenaOptions) -> NodeArena {
        let leaf_cap = if opts.leaf_words == 0 { 0 } else { (opts.leaf_words - LEAF_KEYS) / 2 };
        Self::finish(BlockArena::with_options(block_size, max_blocks, opts), leaf_cap, 1)
    }

    /// Arena sized by the shared §V capacity policy
    /// ([`BlockArena::for_capacity`]) for up to `capacity` live nodes.
    pub fn for_capacity(capacity: usize, opts: ArenaOptions) -> NodeArena {
        Self::finish(BlockArena::for_capacity(capacity, opts), 0, 1)
    }

    /// Capacity-sized arena with a fat-leaf plane: every slot additionally
    /// carries a `leaf_words_for(leaf_cap)`-word chunk (version, count,
    /// keys, values) in the [`BlockArena`]'s third plane. Inner routing
    /// blocks stay disabled (the legacy linked-walk index).
    pub fn for_capacity_chunks(capacity: usize, opts: ArenaOptions, leaf_cap: usize) -> NodeArena {
        Self::for_capacity_caps(capacity, opts, leaf_cap, 1)
    }

    /// Capacity-sized arena with both fat-plane roles: terminal chunks of
    /// up to `leaf_cap` keys *and* (when `inner_cap >= 2`) level ≥ 1
    /// routing blocks of up to `inner_cap` separators + child links. The
    /// two roles live in one shared plane sized by the wider of the caps,
    /// since any given node is exactly one of terminal/inner.
    pub fn for_capacity_caps(
        capacity: usize,
        opts: ArenaOptions,
        leaf_cap: usize,
        inner_cap: usize,
    ) -> NodeArena {
        assert!(
            (1..=MAX_LEAF_CAP).contains(&leaf_cap),
            "leaf_cap {leaf_cap} outside 1..={MAX_LEAF_CAP}"
        );
        assert!(
            (1..=MAX_INNER_CAP).contains(&inner_cap),
            "inner_cap {inner_cap} outside 1..={MAX_INNER_CAP}"
        );
        let plane_cap = leaf_cap.max(if inner_cap >= 2 { inner_cap } else { 0 });
        let opts = opts.with_leaf_words(leaf_words_for(plane_cap));
        Self::finish(BlockArena::for_capacity(capacity, opts), leaf_cap, inner_cap)
    }

    fn finish(arena: BlockArena<Node>, leaf_cap: usize, inner_cap: usize) -> NodeArena {
        let plane_cap = leaf_cap.max(if inner_cap >= 2 { inner_cap } else { 0 });
        let a = NodeArena { arena, leaf_cap, inner_cap, plane_cap };
        // slot 0: the sentinel — key MAX, next/bottom self, never retired.
        // A non-zero slot here would silently corrupt every SENTINEL link,
        // so this is a hard assert even in release builds.
        let s = a.alloc(u64::MAX, SENTINEL, SENTINEL, 0, 0);
        assert_eq!(s, SENTINEL, "sentinel must land in slot 0, generation 0");
        a
    }

    /// Keys per terminal chunk (0 when the arena has no leaf plane).
    #[inline]
    pub fn leaf_cap(&self) -> usize {
        self.leaf_cap
    }

    /// Separators per fat inner routing block (`< 2` = blocks disabled).
    #[inline]
    pub fn inner_cap(&self) -> usize {
        self.inner_cap
    }

    /// Whether level ≥ 1 nodes carry routing blocks at all.
    #[inline]
    pub fn inner_blocks(&self) -> bool {
        self.inner_cap >= 2
    }

    /// Resolve a link; `None` if the node has been retired/recycled since
    /// the link was created (generation mismatch).
    #[inline]
    pub fn resolve(&self, r: NodeRef) -> Option<NodeView<'_>> {
        let n = self.node(r);
        if n.cold.gen.load(Ordering::Acquire) == ref_gen(r) {
            Some(n)
        } else {
            None
        }
    }

    /// Resolve without the generation check (sentinel / owned refs).
    #[inline]
    pub fn node(&self, r: NodeRef) -> NodeView<'_> {
        let idx = ref_idx(r);
        NodeView { hot: self.arena.hot(idx), cold: self.arena.cold(idx) }
    }

    /// Hint the cache hierarchy to pull `r`'s hot descent line. Safe for
    /// any link value (bounds-guarded; a prefetch never faults) — issue it
    /// for the *next* hop while the current node is still being examined so
    /// the dependent misses overlap ("Skiplists with Foresight"). The
    /// sentinel's line is never worth a prefetch slot; returns whether a
    /// prefetch was issued so callers keep honest per-op counts.
    #[inline]
    pub fn prefetch(&self, r: NodeRef) -> bool {
        r != SENTINEL && self.arena.prefetch_hot(ref_idx(r))
    }

    /// Paired prefetch for `r`'s chunk/block-plane row — the keys the SIMD
    /// rank is about to scan. Issue it alongside [`NodeArena::prefetch`] so
    /// the plane line doesn't cold-miss right after the hot word told us to
    /// read it (leaf chunk on terminal approach, inner block on level ≥ 1
    /// hops). Bounds-guarded like the hot prefetch; returns whether issued.
    #[inline]
    pub fn prefetch_plane(&self, r: NodeRef) -> bool {
        r != SENTINEL && self.arena.prefetch_leaf(ref_idx(r))
    }

    /// Batched [`NodeArena::prefetch`]: one prefetch per ref, issued back to
    /// back so the set's misses overlap before any line is needed (sentinel
    /// refs skipped). Returns how many were issued.
    pub fn prefetch_many(&self, refs: &[NodeRef]) -> u64 {
        let mut issued = 0u64;
        for &r in refs {
            issued += self.prefetch(r) as u64;
        }
        issued
    }

    /// Read a validated `(key, next)` snapshot of `r`: the generation is
    /// re-checked *after* the read, so the returned pair was published while
    /// the node was live under this link.
    #[inline]
    pub fn read_key_next(&self, r: NodeRef) -> Option<(u64, NodeRef)> {
        let idx = ref_idx(r);
        let cold = self.arena.cold(idx);
        if cold.gen.load(Ordering::Acquire) != ref_gen(r) {
            return None;
        }
        let kn = self.arena.hot(idx).kn.load();
        if cold.gen.load(Ordering::Acquire) != ref_gen(r) {
            return None;
        }
        Some((hi64(kn), lo64(kn)))
    }

    /// Allocate a node (recycled or fresh) and initialize it. The lock word
    /// and generation are deliberately *not* reset (stragglers may still be
    /// spinning on them; they re-validate after acquiring).
    ///
    /// Field stores are relaxed; the release fence below orders them before
    /// the `(key, next)` publish, giving readers that discover the node
    /// through the published word a happens-before edge to every field (see
    /// the module docs — this is load-bearing for the lock-free `Find`).
    pub fn alloc(&self, key: u64, next: NodeRef, bottom: NodeRef, value: u64, level: u32) -> NodeRef {
        let idx = self.arena.alloc_slot();
        let hot = self.arena.hot(idx);
        let cold = self.arena.cold(idx);
        hot.bottom.store(bottom, Ordering::Relaxed);
        cold.value.store(value, Ordering::Relaxed);
        cold.mark.store(false, Ordering::Relaxed);
        hot.level.store(level, Ordering::Relaxed);
        // publish (key,next) last, release-ordered after the field stores
        fence(Ordering::Release);
        hot.kn.store(pack(key, next));
        make_ref(cold.gen.load(Ordering::Acquire), idx)
    }

    /// Retire a node: bump its generation (invalidating every existing link
    /// to it) and return it to the magazine/free pool.
    pub fn retire(&self, r: NodeRef) {
        debug_assert_ne!(r, SENTINEL, "cannot retire the sentinel");
        debug_assert!(self.node(r).is_marked(), "retiring an unmarked node");
        self.arena.retire_slot(ref_idx(r));
    }

    // ------------------------------------------------------------------
    // Fat-leaf terminal chunks (leaf plane; `leaf_cap > 0` arenas only)
    // ------------------------------------------------------------------

    #[inline]
    fn leaf(&self, r: NodeRef) -> &[AtomicU64] {
        debug_assert!(self.leaf_cap > 0, "arena has no leaf plane");
        self.arena.leaf(ref_idx(r))
    }

    /// Initialize a *pre-publication* chunk slot (count + sorted keys +
    /// values), ending with a release fence so the subsequent link store
    /// that publishes the chunk carries a happens-before edge to every
    /// word written here (same discipline as [`NodeArena::alloc`]).
    ///
    /// No seqlock window: the chunk is unreachable until linked, and a
    /// stale reader still probing this recycled slot discards its result on
    /// the post-window generation re-check.
    pub fn chunk_init(&self, r: NodeRef, keys: &[u64], vals: &[u64]) {
        debug_assert_eq!(keys.len(), vals.len());
        debug_assert!(keys.len() <= self.leaf_cap);
        let leaf = self.leaf(r);
        leaf[LEAF_COUNT].store(keys.len() as u64, Ordering::Relaxed);
        for (i, &k) in keys.iter().enumerate() {
            leaf[LEAF_KEYS + i].store(k, Ordering::Relaxed);
        }
        for (i, &v) in vals.iter().enumerate() {
            leaf[LEAF_KEYS + self.plane_cap + i].store(v, Ordering::Relaxed);
        }
        fence(Ordering::Release);
    }

    /// Allocate and initialize a fresh terminal chunk holding `keys`/`vals`
    /// (sorted, non-empty), with `(max_key, next)` as its packed header.
    /// The caller publishes it by linking (predecessor `(key, next)` store
    /// or parent `bottom` store).
    pub fn alloc_chunk(&self, keys: &[u64], vals: &[u64], next: NodeRef) -> NodeRef {
        debug_assert!(!keys.is_empty());
        let max = *keys.last().unwrap();
        let r = self.alloc(max, next, SENTINEL, 0, 0);
        self.chunk_init(r, keys, vals);
        r
    }

    /// Open a writer-side seqlock window on `r`'s chunk. Caller must hold
    /// the chunk's write lock (all terminal locks are taken under the
    /// parent leaf's lock, so windows never nest or race each other).
    /// Mutations — including the node's own `(key, next)` header when the
    /// chunk max changes — go inside the window; dropping the guard
    /// publishes them.
    pub fn chunk_write(&self, r: NodeRef) -> ChunkWrite<'_> {
        let leaf = self.leaf(r);
        let v = leaf[LEAF_VERSION].load(Ordering::Relaxed);
        debug_assert_eq!(v & 1, 0, "chunk write window already open");
        leaf[LEAF_VERSION].store(v.wrapping_add(1), Ordering::Relaxed);
        // Readers that observe any data store below must also observe the
        // odd version: the release fence pairs with the reader's acquire
        // fence (crossbeam-style seqlock argument).
        fence(Ordering::Release);
        ChunkWrite { leaf, cap: self.plane_cap, v }
    }

    /// Writer-side chunk key count (caller holds the chunk's lock).
    #[inline]
    pub fn chunk_count(&self, r: NodeRef) -> usize {
        self.leaf(r)[LEAF_COUNT].load(Ordering::Relaxed) as usize
    }

    /// Writer-side key read (caller holds the chunk's lock).
    #[inline]
    pub fn chunk_key(&self, r: NodeRef, i: usize) -> u64 {
        self.leaf(r)[LEAF_KEYS + i].load(Ordering::Relaxed)
    }

    /// Writer-side value read (caller holds the chunk's lock).
    #[inline]
    pub fn chunk_val(&self, r: NodeRef, i: usize) -> u64 {
        self.leaf(r)[LEAF_KEYS + self.plane_cap + i].load(Ordering::Relaxed)
    }

    /// Writer-side copy of the chunk's live keys into `buf`; returns the
    /// count. The copy feeds the SIMD rank ([`crate::util::simd::rank`]).
    pub fn chunk_keys_into(&self, r: NodeRef, buf: &mut [u64; MAX_LEAF_CAP]) -> usize {
        let leaf = self.leaf(r);
        let count = (leaf[LEAF_COUNT].load(Ordering::Relaxed) as usize).min(self.leaf_cap);
        for (i, slot) in buf.iter_mut().enumerate().take(count) {
            *slot = leaf[LEAF_KEYS + i].load(Ordering::Relaxed);
        }
        count
    }

    /// Lock-free consistent probe of chunk `r` for `key`: retries the
    /// seqlock until a version-stable window is read, then re-checks the
    /// generation so a retire/recycle that slipped under the read (the
    /// version word alone cannot rule reuse out) voids the result.
    ///
    /// `None` means the chunk is gone (stale link) or a writer interfered
    /// persistently — the caller restarts its descent, exactly like a
    /// failed `resolve`.
    pub fn chunk_probe(&self, r: NodeRef, key: u64) -> Option<ChunkProbe> {
        let idx = ref_idx(r);
        let cold = self.arena.cold(idx);
        if cold.gen.load(Ordering::Acquire) != ref_gen(r) {
            return None;
        }
        let leaf = self.leaf(r);
        let hot = self.arena.hot(idx);
        let mut keys = [0u64; MAX_LEAF_CAP];
        for _ in 0..64 {
            let v1 = leaf[LEAF_VERSION].load(Ordering::Acquire);
            if v1 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            // Everything the decision needs is read inside the window: the
            // packed (max, next) header AND the array words, so the routing
            // decision and the in-chunk answer come from one version.
            let kn = hot.kn.load();
            let count = leaf[LEAF_COUNT].load(Ordering::Relaxed) as usize;
            if count > self.leaf_cap {
                // torn count (window already invalid); never index with it
                std::hint::spin_loop();
                continue;
            }
            for (i, slot) in keys.iter_mut().enumerate().take(count) {
                *slot = leaf[LEAF_KEYS + i].load(Ordering::Relaxed);
            }
            let rank = simd::rank(&keys[..count], key);
            let hit = if rank < count && keys[rank] == key {
                Some(leaf[LEAF_KEYS + self.plane_cap + rank].load(Ordering::Relaxed))
            } else {
                None
            };
            fence(Ordering::Acquire);
            if leaf[LEAF_VERSION].load(Ordering::Relaxed) != v1 {
                continue;
            }
            // Version-stable, but the slot may have been retired and reused
            // wholesale since `r` was minted: the generation is the ABA
            // authority (retire bumps it before any reuse).
            if cold.gen.load(Ordering::Acquire) != ref_gen(r) {
                return None;
            }
            let max = hi64(kn);
            let lo = if count > 0 { keys[0] } else { max };
            return Some(ChunkProbe { max, next: lo64(kn), lo, count, hit });
        }
        None
    }

    /// Lock-free consistent snapshot of chunk `r`'s full contents (for
    /// range scans): `(count, max, next)` plus `keys`/`vals` filled in.
    /// Same validation protocol as [`NodeArena::chunk_probe`].
    pub fn chunk_snapshot(
        &self,
        r: NodeRef,
        keys: &mut [u64; MAX_LEAF_CAP],
        vals: &mut [u64; MAX_LEAF_CAP],
    ) -> Option<(usize, u64, NodeRef)> {
        let idx = ref_idx(r);
        let cold = self.arena.cold(idx);
        if cold.gen.load(Ordering::Acquire) != ref_gen(r) {
            return None;
        }
        let leaf = self.leaf(r);
        let hot = self.arena.hot(idx);
        for _ in 0..64 {
            let v1 = leaf[LEAF_VERSION].load(Ordering::Acquire);
            if v1 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let kn = hot.kn.load();
            let count = leaf[LEAF_COUNT].load(Ordering::Relaxed) as usize;
            if count > self.leaf_cap {
                std::hint::spin_loop();
                continue;
            }
            for i in 0..count {
                keys[i] = leaf[LEAF_KEYS + i].load(Ordering::Relaxed);
                vals[i] = leaf[LEAF_KEYS + self.plane_cap + i].load(Ordering::Relaxed);
            }
            fence(Ordering::Acquire);
            if leaf[LEAF_VERSION].load(Ordering::Relaxed) != v1 {
                continue;
            }
            if cold.gen.load(Ordering::Acquire) != ref_gen(r) {
                return None;
            }
            return Some((count, hi64(kn), lo64(kn)));
        }
        None
    }

    // ------------------------------------------------------------------
    // Fat inner routing blocks (level ≥ 1 nodes; `inner_cap >= 2` arenas)
    // ------------------------------------------------------------------

    /// Initialize a *pre-publication* routing block as unbuilt (count 0):
    /// readers fall back to the linked child walk until the first rebuild
    /// publishes real content. Mandatory for every level ≥ 1 alloc in a
    /// blocks-enabled arena — the recycled plane slot may hold a stale
    /// chunk/block image that would otherwise be misread as this node's.
    pub fn block_init_unbuilt(&self, r: NodeRef) {
        debug_assert!(self.inner_blocks());
        self.leaf(r)[LEAF_COUNT].store(0, Ordering::Relaxed);
        fence(Ordering::Release);
    }

    /// Initialize a *pre-publication* routing block with `seps`/`childs`
    /// (overflow-marked when more children than `inner_cap`), ending with
    /// a release fence — same discipline as [`NodeArena::chunk_init`]: the
    /// link store that publishes the node orders every word written here.
    pub fn block_init(&self, r: NodeRef, seps: &[u64], childs: &[NodeRef]) {
        debug_assert!(self.inner_blocks());
        debug_assert_eq!(seps.len(), childs.len());
        let leaf = self.leaf(r);
        if seps.is_empty() {
            leaf[LEAF_COUNT].store(0, Ordering::Relaxed);
        } else if seps.len() > self.inner_cap {
            leaf[LEAF_COUNT].store(BLOCK_OVERFLOW, Ordering::Relaxed);
        } else {
            for (i, (&s, &c)) in seps.iter().zip(childs.iter()).enumerate() {
                leaf[LEAF_KEYS + i].store(s, Ordering::Relaxed);
                leaf[LEAF_KEYS + self.plane_cap + i].store(c, Ordering::Relaxed);
            }
            leaf[LEAF_COUNT].store(seps.len() as u64, Ordering::Relaxed);
        }
        fence(Ordering::Release);
    }

    /// Open a writer-side seqlock window on `r`'s routing block (caller
    /// holds `r`'s write lock). Identical guard to [`NodeArena::chunk_write`]
    /// — the plane slot is shared — named separately so call sites state
    /// which role they are mutating. Every `(key, next)` store on a
    /// published level ≥ 1 node must happen inside this window, so readers
    /// pair the header and the block from one consistent moment.
    #[inline]
    pub fn block_write(&self, r: NodeRef) -> ChunkWrite<'_> {
        self.chunk_write(r)
    }

    /// Writer-side block occupancy: `Some(count)` for a built in-range
    /// block, `None` when unbuilt or overflow-marked (caller holds the
    /// node's lock).
    #[inline]
    pub fn block_len(&self, r: NodeRef) -> Option<usize> {
        let c = self.leaf(r)[LEAF_COUNT].load(Ordering::Relaxed);
        if c == 0 || c > self.inner_cap as u64 {
            None
        } else {
            Some(c as usize)
        }
    }

    /// Writer-side separator read (caller holds the node's lock).
    #[inline]
    pub fn block_sep(&self, r: NodeRef, i: usize) -> u64 {
        self.chunk_key(r, i)
    }

    /// Writer-side child-link read (caller holds the node's lock).
    #[inline]
    pub fn block_child(&self, r: NodeRef, i: usize) -> NodeRef {
        self.chunk_val(r, i)
    }

    /// Lock-free consistent routing probe of `r`'s separator block for
    /// `key`: one seqlock window yields the packed `(key, next)` header
    /// *and* the block, one [`crate::util::simd::rank`] call replaces the
    /// per-child linked walk. Validation protocol (version retry + post-
    /// window generation re-check) is [`NodeArena::chunk_probe`]'s.
    ///
    /// `None` means the node is gone (stale link) or a writer interfered
    /// persistently — the caller restarts its descent, exactly like a
    /// failed `resolve`.
    pub fn block_route(&self, r: NodeRef, key: u64) -> Option<BlockRoute> {
        debug_assert!(self.inner_blocks());
        let idx = ref_idx(r);
        let cold = self.arena.cold(idx);
        if cold.gen.load(Ordering::Acquire) != ref_gen(r) {
            return None;
        }
        let leaf = self.leaf(r);
        let hot = self.arena.hot(idx);
        let mut seps = [0u64; MAX_INNER_CAP];
        let mut childs = [SENTINEL; MAX_INNER_CAP];
        for _ in 0..64 {
            let v1 = leaf[LEAF_VERSION].load(Ordering::Acquire);
            if v1 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let kn = hot.kn.load();
            let raw = leaf[LEAF_COUNT].load(Ordering::Relaxed);
            if raw == 0 || raw > self.inner_cap as u64 {
                // Unbuilt or overflowed: the header is one atomic load and
                // needs no window validation, but the generation must still
                // vouch this is the node the link meant.
                if cold.gen.load(Ordering::Acquire) != ref_gen(r) {
                    return None;
                }
                return Some(BlockRoute::Fallback { nkey: hi64(kn), next: lo64(kn) });
            }
            let count = raw as usize;
            for i in 0..count {
                seps[i] = leaf[LEAF_KEYS + i].load(Ordering::Relaxed);
                childs[i] = leaf[LEAF_KEYS + self.plane_cap + i].load(Ordering::Relaxed);
            }
            fence(Ordering::Acquire);
            if leaf[LEAF_VERSION].load(Ordering::Relaxed) != v1 {
                continue;
            }
            if cold.gen.load(Ordering::Acquire) != ref_gen(r) {
                return None;
            }
            let (nkey, next) = (hi64(kn), lo64(kn));
            if nkey < key {
                return Some(BlockRoute::Right { nkey, next });
            }
            let rank = simd::rank(&seps[..count], key);
            if rank < count {
                let sep_lo = if rank == 0 { None } else { Some(seps[rank - 1]) };
                return Some(BlockRoute::Descend { nkey, next, child: childs[rank], sep_lo });
            }
            // All stored separators < key while nkey >= key: the node's
            // header is stale-high (its real range ended below `key`) —
            // separators are never stale-low, so no child covers `key`.
            return Some(BlockRoute::Right { nkey, next });
        }
        None
    }

    /// Lock-free consistent snapshot of `r`'s full routing block (for the
    /// NUMA-replica descent, which needs every separator at once so it can
    /// clamp past-the-end ranks to the last child and retry leftward ranks
    /// after a stale terminal landing): `(count, node_key, next)` plus
    /// `seps`/`childs` filled in. Validation protocol (version retry +
    /// post-window generation re-check) is [`NodeArena::chunk_snapshot`]'s.
    ///
    /// `None` means the block is gone (stale link), unbuilt/overflowed, or
    /// a writer interfered persistently — replica callers treat all of
    /// those as a descent miss and fall back to the shared index.
    pub fn block_snapshot(
        &self,
        r: NodeRef,
        seps: &mut [u64; MAX_INNER_CAP],
        childs: &mut [NodeRef; MAX_INNER_CAP],
    ) -> Option<(usize, u64, NodeRef)> {
        debug_assert!(self.inner_blocks());
        let idx = ref_idx(r);
        let cold = self.arena.cold(idx);
        if cold.gen.load(Ordering::Acquire) != ref_gen(r) {
            return None;
        }
        let leaf = self.leaf(r);
        let hot = self.arena.hot(idx);
        for _ in 0..64 {
            let v1 = leaf[LEAF_VERSION].load(Ordering::Acquire);
            if v1 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let kn = hot.kn.load();
            let raw = leaf[LEAF_COUNT].load(Ordering::Relaxed);
            if raw == 0 || raw > self.inner_cap as u64 {
                // Unbuilt or overflowed: no consistent block to copy.
                return None;
            }
            let count = raw as usize;
            for i in 0..count {
                seps[i] = leaf[LEAF_KEYS + i].load(Ordering::Relaxed);
                childs[i] = leaf[LEAF_KEYS + self.plane_cap + i].load(Ordering::Relaxed);
            }
            fence(Ordering::Acquire);
            if leaf[LEAF_VERSION].load(Ordering::Relaxed) != v1 {
                continue;
            }
            if cold.gen.load(Ordering::Acquire) != ref_gen(r) {
                return None;
            }
            return Some((count, hi64(kn), lo64(kn)));
        }
        None
    }

    /// Nodes currently materialized (capacity in nodes).
    pub fn capacity(&self) -> u64 {
        self.arena.capacity()
    }

    /// §V accounting snapshot (allocs/recycled/capacity/locality). Not a
    /// cheap counter read: it locks every (thread-private, uncontended)
    /// magazine once — take one snapshot and read the fields you need.
    pub fn stats(&self) -> PoolStats {
        self.arena.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sentinel_is_self_referential() {
        let a = NodeArena::new(16, 16);
        let s = a.node(SENTINEL);
        assert_eq!(s.key(), u64::MAX);
        assert_eq!(s.next(), SENTINEL);
        assert_eq!(s.hot.bottom.load(Ordering::Relaxed), SENTINEL);
    }

    #[test]
    fn hot_plane_is_one_aligned_cache_line() {
        // compile-time assert made observable, plus the runtime layout of
        // actual slots: each plane packs densely at its *own* width — the
        // hot plane at exactly one aligned 64-byte line per slot, the leaf
        // plane (when present) at its configured multi-line word stride.
        assert_eq!(std::mem::size_of::<NodeHot>(), 64);
        assert_eq!(std::mem::align_of::<NodeHot>(), 64);
        let hot_stride = std::mem::size_of::<NodeHot>();
        let a = NodeArena::for_capacity_chunks(256, ArenaOptions::default(), DEFAULT_LEAF_CAP);
        let r1 = a.alloc(1, SENTINEL, SENTINEL, 0, 0);
        let r2 = a.alloc(2, SENTINEL, SENTINEL, 0, 0);
        let p1 = a.node(r1).hot as *const NodeHot as usize;
        let p2 = a.node(r2).hot as *const NodeHot as usize;
        assert_eq!(p1 % 64, 0, "hot slots are line-aligned");
        assert_eq!(p2 - p1, hot_stride, "hot slots are densely packed at the hot width");
        // leaf plane: stride = leaf_words_for(K) words = (2 + 2K) * 8 bytes
        // (multi-cache-line at the default K — the whole point of fat leaves)
        let leaf_stride = leaf_words_for(a.leaf_cap()) * 8;
        assert!(leaf_stride > 64, "default-K leaf slots span multiple lines");
        let l1 = a.leaf(r1).as_ptr() as usize;
        let l2 = a.leaf(r2).as_ptr() as usize;
        assert_eq!(l2 - l1, leaf_stride, "leaf slots are densely packed at the leaf width");
        assert_eq!(a.leaf(r1).len(), leaf_words_for(a.leaf_cap()));
        // arenas without a leaf plane still pack the hot plane identically
        let b = NodeArena::new(16, 16);
        let q1 = b.alloc(1, SENTINEL, SENTINEL, 0, 0);
        let q2 = b.alloc(2, SENTINEL, SENTINEL, 0, 0);
        let h1 = b.node(q1).hot as *const NodeHot as usize;
        assert_eq!((b.node(q2).hot as *const NodeHot as usize) - h1, hot_stride);
    }

    #[test]
    fn chunk_init_probe_and_snapshot_roundtrip() {
        let a = NodeArena::for_capacity_chunks(256, ArenaOptions::default(), 8);
        assert_eq!(a.leaf_cap(), 8);
        let keys = [10u64, 20, 30, 40, 50];
        let vals = [1u64, 2, 3, 4, 5];
        let r = a.alloc_chunk(&keys, &vals, SENTINEL);
        let n = a.node(r);
        assert_eq!(n.key(), 50, "chunk header key = max key");
        assert_eq!(a.chunk_count(r), 5);
        assert_eq!(a.chunk_key(r, 2), 30);
        assert_eq!(a.chunk_val(r, 2), 3);
        // probe: hit, miss-below, miss-between, miss-above
        let p = a.chunk_probe(r, 30).unwrap();
        assert_eq!((p.hit, p.lo, p.max, p.count), (Some(3), 10, 50, 5));
        assert_eq!(a.chunk_probe(r, 5).unwrap().hit, None);
        assert_eq!(a.chunk_probe(r, 35).unwrap().hit, None);
        assert_eq!(a.chunk_probe(r, 60).unwrap().hit, None);
        let mut ks = [0u64; MAX_LEAF_CAP];
        let mut vs = [0u64; MAX_LEAF_CAP];
        let (count, max, next) = a.chunk_snapshot(r, &mut ks, &mut vs).unwrap();
        assert_eq!((count, max, next), (5, 50, SENTINEL));
        assert_eq!(&ks[..5], &keys);
        assert_eq!(&vs[..5], &vals);
    }

    #[test]
    fn chunk_write_window_blocks_readers_until_closed() {
        let a = NodeArena::for_capacity_chunks(256, ArenaOptions::default(), 4);
        let r = a.alloc_chunk(&[1, 2], &[10, 20], SENTINEL);
        {
            let w = a.chunk_write(r);
            // window open (odd version): a lock-free probe must refuse to
            // return rather than expose the half-written state
            w.set_key(2, 3);
            w.set_val(2, 30);
            w.set_count(3);
            assert!(a.chunk_probe(r, 2).is_none(), "open window must not leak");
        }
        let p = a.chunk_probe(r, 3).unwrap();
        assert_eq!(p.hit, Some(30));
        assert_eq!(p.count, 3);
    }

    #[test]
    fn chunk_probe_rejects_retired_generation() {
        let a = NodeArena::for_capacity_chunks(256, ArenaOptions::default(), 4);
        let r = a.alloc_chunk(&[7], &[70], SENTINEL);
        a.node(r).cold.mark.store(true, Ordering::Release);
        a.retire(r);
        assert!(a.chunk_probe(r, 7).is_none());
        assert!(a.chunk_snapshot(r, &mut [0; MAX_LEAF_CAP], &mut [0; MAX_LEAF_CAP]).is_none());
        // the recycled slot serves a fresh chunk under a new generation
        let r2 = a.alloc_chunk(&[9], &[90], SENTINEL);
        assert_eq!(ref_idx(r), ref_idx(r2));
        assert!(a.chunk_probe(r, 7).is_none(), "old link stays dead");
        assert_eq!(a.chunk_probe(r2, 9).unwrap().hit, Some(90));
    }

    #[test]
    fn block_init_route_overflow_and_shared_plane() {
        // leaf_cap 4, inner_cap 8: plane sized by the wider role, both
        // roles' second arrays at the same offset
        let a = NodeArena::for_capacity_caps(256, ArenaOptions::default(), 4, 8);
        assert_eq!(a.leaf_cap(), 4);
        assert_eq!(a.inner_cap(), 8);
        assert!(a.inner_blocks());
        // terminal chunk still round-trips on the widened plane
        let c = a.alloc_chunk(&[10, 20], &[1, 2], SENTINEL);
        assert_eq!(a.chunk_probe(c, 20).unwrap().hit, Some(2));
        // inner node with a 3-child block
        let k1 = a.alloc_chunk(&[5], &[50], SENTINEL);
        let n = a.alloc(300, SENTINEL, k1, 0, 1);
        a.block_init(n, &[100, 200, 300], &[k1, c, k1]);
        assert_eq!(a.block_len(n), Some(3));
        assert_eq!(a.block_sep(n, 1), 200);
        assert_eq!(a.block_child(n, 1), c);
        // routing: first sep >= target wins; sep_lo = previous stored sep
        match a.block_route(n, 150).unwrap() {
            BlockRoute::Descend { child, sep_lo, nkey, .. } => {
                assert_eq!(child, c);
                assert_eq!(sep_lo, Some(100));
                assert_eq!(nkey, 300);
            }
            other => panic!("expected Descend, got {other:?}"),
        }
        match a.block_route(n, 100).unwrap() {
            BlockRoute::Descend { child, sep_lo, .. } => {
                assert_eq!(child, k1);
                assert_eq!(sep_lo, None, "first child has no lower separator");
            }
            other => panic!("expected Descend, got {other:?}"),
        }
        // target above the node's key: continue right
        assert!(matches!(a.block_route(n, 301).unwrap(), BlockRoute::Right { nkey: 300, .. }));
        // unbuilt and overflowed blocks both route to the fallback walk
        let u = a.alloc(400, SENTINEL, k1, 0, 1);
        a.block_init_unbuilt(u);
        assert!(matches!(a.block_route(u, 7).unwrap(), BlockRoute::Fallback { nkey: 400, .. }));
        let refs = [k1; 9];
        a.block_init(u, &[1, 2, 3, 4, 5, 6, 7, 8, 9], &refs);
        assert!(matches!(a.block_route(u, 7).unwrap(), BlockRoute::Fallback { .. }));
        // an open write window blocks routing until closed; set_overflow
        // inside a window republishes the fallback marker
        {
            let w = a.block_write(n);
            assert!(a.block_route(n, 150).is_none(), "open window must not leak");
            w.set_overflow();
        }
        assert!(matches!(a.block_route(n, 150).unwrap(), BlockRoute::Fallback { .. }));
        // retired generation voids the probe entirely
        a.node(n).cold.mark.store(true, Ordering::Release);
        a.retire(n);
        assert!(a.block_route(n, 150).is_none());
    }

    #[test]
    fn block_header_and_block_read_in_one_window() {
        // The route must pair (key,next) with the block from one seqlock
        // moment: a header rewrite inside the window is invisible until
        // the window closes, together with the new block content.
        let a = NodeArena::for_capacity_caps(256, ArenaOptions::default(), 1, 4);
        let k1 = a.alloc_chunk(&[5], &[50], SENTINEL);
        let n = a.alloc(100, SENTINEL, k1, 0, 1);
        a.block_init(n, &[100], &[k1]);
        {
            let w = a.block_write(n);
            a.node(n).set_key_next(50, SENTINEL);
            w.set_key(0, 50);
            assert!(a.block_route(n, 80).is_none(), "mid-rewrite state must not leak");
        }
        assert!(matches!(a.block_route(n, 80).unwrap(), BlockRoute::Right { nkey: 50, .. }));
    }

    #[test]
    fn alloc_and_resolve() {
        let a = NodeArena::new(16, 16);
        let r = a.alloc(42, SENTINEL, SENTINEL, 7, 0);
        let n = a.resolve(r).unwrap();
        assert_eq!(n.key(), 42);
        assert_eq!(n.cold.value.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn retire_invalidates_links() {
        let a = NodeArena::new(16, 16);
        let r = a.alloc(1, SENTINEL, SENTINEL, 0, 0);
        a.node(r).cold.mark.store(true, Ordering::Release);
        a.retire(r);
        assert!(a.resolve(r).is_none());
        assert!(a.read_key_next(r).is_none());
    }

    #[test]
    fn recycled_slot_gets_new_generation() {
        let a = NodeArena::new(16, 16);
        let r1 = a.alloc(1, SENTINEL, SENTINEL, 0, 0);
        a.node(r1).cold.mark.store(true, Ordering::Release);
        a.retire(r1);
        let r2 = a.alloc(2, SENTINEL, SENTINEL, 0, 0);
        assert_eq!(ref_idx(r1), ref_idx(r2), "slot reused");
        assert_ne!(ref_gen(r1), ref_gen(r2), "generation bumped");
        assert!(a.resolve(r1).is_none());
        assert_eq!(a.resolve(r2).unwrap().key(), 2);
    }

    #[test]
    fn stats_flow_through_the_unified_arena() {
        let a = NodeArena::new(16, 16);
        let r = a.alloc(1, SENTINEL, SENTINEL, 0, 0);
        a.node(r).cold.mark.store(true, Ordering::Release);
        a.retire(r);
        let _ = a.alloc(2, SENTINEL, SENTINEL, 0, 0);
        let st = a.stats();
        assert_eq!(st.allocs, 3, "sentinel + two allocs");
        assert_eq!(st.recycled, 1);
        assert_eq!(st.retired, 1);
        assert_eq!(st.arenas, 1);
        assert_eq!(st.capacity, a.capacity());
    }

    #[test]
    fn ref_packing() {
        let r = make_ref(0xABCD, 0x1234);
        assert_eq!(ref_gen(r), 0xABCD);
        assert_eq!(ref_idx(r), 0x1234);
    }

    /// Satellite regression (publication ordering): a node's relaxed field
    /// stores must be visible to any thread that observed the node through
    /// its published `(key, next)` word. An allocator thread churns
    /// alloc/publish/retire cycles with value/level derived from the key;
    /// reader threads chase the freshly published refs through a mailbox
    /// and assert they never observe a stale field behind a valid link+key.
    #[test]
    fn alloc_publication_is_release_ordered() {
        // 30k allocs with ~1/4 recycled: stays well inside 8192*8 slots
        let a = Arc::new(NodeArena::new(8192, 8));
        let mailbox = Arc::new(AtomicU64::new(SENTINEL));
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let a = a.clone();
            let mailbox = mailbox.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                let mut checked = 0u64;
                while !stop.load(Ordering::Acquire) {
                    let r = mailbox.load(Ordering::Acquire);
                    if r == SENTINEL {
                        std::hint::spin_loop();
                        continue;
                    }
                    // read (key,next) through the validated snapshot, then
                    // the relaxed-initialized fields; re-validate afterwards
                    // so a recycled node can't fake a violation.
                    let Some((k, _)) = a.read_key_next(r) else { continue };
                    let n = a.node(r);
                    let v = n.cold.value.load(Ordering::Relaxed);
                    let lvl = n.hot.level.load(Ordering::Relaxed);
                    let b = n.hot.bottom.load(Ordering::Relaxed);
                    if a.resolve(r).is_none() {
                        continue; // recycled under us: snapshot void
                    }
                    assert_eq!(v, k.wrapping_mul(7) ^ 1, "value published after (key,next)");
                    assert_eq!(lvl, (k % 5) as u32, "level published after (key,next)");
                    assert_eq!(b, SENTINEL);
                    checked += 1;
                }
                checked
            }));
        }
        for k in 1..30_000u64 {
            let r = a.alloc(k, SENTINEL, SENTINEL, k.wrapping_mul(7) ^ 1, (k % 5) as u32);
            mailbox.store(r, Ordering::Release);
            // leave the node visible briefly, then recycle it
            if k % 4 == 0 {
                mailbox.store(SENTINEL, Ordering::Release);
                a.node(r).cold.mark.store(true, Ordering::Release);
                a.retire(r);
            }
        }
        stop.store(true, Ordering::Release);
        let checked: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(checked > 0, "readers must have validated at least one publication");
    }
}
