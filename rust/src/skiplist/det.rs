//! Concurrent deterministic 1-2-3-4 skiplist (paper §II, algorithms 1–6).
//!
//! Structure: a hierarchy of linked lists. Level 0 is the *terminal* list
//! holding `(key, value)`; level 1 nodes ("leaves") point into it; higher
//! levels shortcut further. Every non-terminal node covers the child segment
//! `(<prev sibling key>, node.key]`; the rightmost node of every level (and
//! the head) carries key `u64::MAX` ("the key of the root node is the
//! maximum key"). All lists end at the shared self-referential sentinel.
//!
//! Concurrency design, faithful to the paper:
//! - `(key, next)` lives in one 128-bit atomic word; **`Find` is lock-free**
//!   (algorithm 4) and validates node generations against recycling (the
//!   paper's per-node reference counters).
//! - `Addition` (algs 1–2) locks a node plus its children (L shape, ≤ 6
//!   locks) and splits 5-child nodes proactively on the way down.
//! - `Deletion` locks the node plus an adjacent child *pair* (LL shape),
//!   boosts 2-child path nodes via `MergeBorrow` (alg 5), and removes the
//!   terminal key with in-segment unlink or delete-by-copy so a segment's
//!   first node is never unlinked (which would dangle the left neighbour's
//!   `next`). `merge` removes the node with the *higher* key for the same
//!   reason.
//! - Height changes only at the head (algs 3/6); any operation seeing
//!   `head.next != sentinel` retries after helping (`IncreaseDepth`).
//! - Stale-high keys left by lazy ancestor updates are repaired eagerly by
//!   `CheckNodeKey` whenever a writer passes through a node.
//!
//! Deadlock freedom: every writer acquires locks parent-before-child and
//! left-before-right, and releases before recursing; the order is acyclic.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::mem::{ArenaOptions, PoolStats};
use crate::sync::Backoff;

use super::node::{NodeArena, NodeRef, SENTINEL};

/// How `find` traverses: the paper's lock-free algorithm 4, or the RWL
/// baseline (hand-over-hand shared locks, "RWL" in tables II/III).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FindMode {
    LockFree,
    ReadLocked,
}

/// Tri-state internal result (paper's TRUE/FALSE/RETRY).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Tri {
    True,
    False,
    Retry,
}

/// Operation counters (used by tests, ablations and EXPERIMENTS.md).
#[derive(Debug, Default, Clone)]
pub struct SkiplistStats {
    pub splits: u64,
    pub merges: u64,
    pub borrows: u64,
    pub depth_increases: u64,
    pub depth_decreases: u64,
    pub find_retries: u64,
    pub write_retries: u64,
}

impl SkiplistStats {
    /// Accumulate `other` into `self` (per-shard aggregation: the sharded
    /// store sums every shard's counters into one observable snapshot).
    pub fn merge(&mut self, other: &SkiplistStats) {
        self.splits += other.splits;
        self.merges += other.merges;
        self.borrows += other.borrows;
        self.depth_increases += other.depth_increases;
        self.depth_decreases += other.depth_decreases;
        self.find_retries += other.find_retries;
        self.write_retries += other.write_retries;
    }
}

#[derive(Default)]
struct AtomicSkiplistStats {
    splits: AtomicU64,
    merges: AtomicU64,
    borrows: AtomicU64,
    depth_increases: AtomicU64,
    depth_decreases: AtomicU64,
    find_retries: AtomicU64,
    write_retries: AtomicU64,
}


/// Fixed-capacity child list (arity is bounded by ~7 plus the boundary
/// node): avoids a heap allocation per visited node on the write path —
/// see EXPERIMENTS.md §Perf.
pub(crate) struct ChildVec {
    buf: [NodeRef; 12],
    len: usize,
}

impl ChildVec {
    #[inline]
    fn new() -> ChildVec {
        ChildVec { buf: [SENTINEL; 12], len: 0 }
    }

    /// Append a child; `false` when the fixed arity bound would be
    /// exceeded (the structure is transiently wider than any legal arity).
    /// Callers must surface that as a RETRY — silently clamping would make
    /// split/merge reason about a truncated child list and (in release
    /// builds, where the old debug assert vanished) corrupt the segment.
    #[inline]
    #[must_use]
    fn push(&mut self, r: NodeRef) -> bool {
        if self.len < self.buf.len() {
            self.buf[self.len] = r;
            self.len += 1;
            true
        } else {
            false
        }
    }
}

impl std::ops::Deref for ChildVec {
    type Target = [NodeRef];
    #[inline]
    fn deref(&self) -> &[NodeRef] {
        &self.buf[..self.len]
    }
}

/// The concurrent deterministic 1-2-3-4 skiplist.
pub struct DetSkiplist {
    arena: NodeArena,
    head: NodeRef,
    mode: FindMode,
    len: AtomicU64,
    stats: AtomicSkiplistStats,
}

/// Keys must stay below `u64::MAX` (reserved for the head/sentinel spine).
pub const MAX_KEY: u64 = u64::MAX - 1;

impl DetSkiplist {
    /// Skiplist with default arena sizing (grow-on-demand blocks).
    pub fn new(mode: FindMode) -> DetSkiplist {
        Self::with_capacity(mode, 1 << 20)
    }

    /// `capacity` bounds the number of live nodes (terminal + index).
    pub fn with_capacity(mode: FindMode, capacity: usize) -> DetSkiplist {
        Self::with_capacity_on(mode, capacity, ArenaOptions::default())
    }

    /// Like [`DetSkiplist::with_capacity`] with explicit arena placement
    /// (per-shard skiplists home their arena on the shard's NUMA node).
    pub fn with_capacity_on(mode: FindMode, capacity: usize, opts: ArenaOptions) -> DetSkiplist {
        let arena = NodeArena::for_capacity(capacity, opts);
        // head: level-1 leaf, key MAX, no children yet.
        let head = arena.alloc(u64::MAX, SENTINEL, SENTINEL, 0, 1);
        DetSkiplist {
            arena,
            head,
            mode,
            len: AtomicU64::new(0),
            stats: AtomicSkiplistStats::default(),
        }
    }

    #[inline]
    fn is_head(&self, r: NodeRef) -> bool {
        r == self.head
    }

    /// Number of keys currently stored.
    pub fn len(&self) -> u64 {
        self.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> SkiplistStats {
        SkiplistStats {
            splits: self.stats.splits.load(Ordering::Relaxed),
            merges: self.stats.merges.load(Ordering::Relaxed),
            borrows: self.stats.borrows.load(Ordering::Relaxed),
            depth_increases: self.stats.depth_increases.load(Ordering::Relaxed),
            depth_decreases: self.stats.depth_decreases.load(Ordering::Relaxed),
            find_retries: self.stats.find_retries.load(Ordering::Relaxed),
            write_retries: self.stats.write_retries.load(Ordering::Relaxed),
        }
    }

    pub fn arena(&self) -> &NodeArena {
        &self.arena
    }

    /// §V arena accounting (allocs/recycled/capacity/locality).
    pub fn mem_stats(&self) -> PoolStats {
        self.arena.stats()
    }

    // ------------------------------------------------------------------
    // Height management (algorithms 3 and 6)
    // ------------------------------------------------------------------

    /// Algorithm 3: push the head's level down one if it gained a sibling.
    fn increase_depth(&self) {
        let head = self.arena.node(self.head);
        head.lock.lock();
        let (hkey, hnext) = head.key_next();
        if hnext == SENTINEL {
            head.lock.unlock();
            return;
        }
        let level = head.level.load(Ordering::Relaxed);
        let hbot = head.bottom.load(Ordering::Acquire);
        // d inherits the head's current (key, next, bottom) at the old level.
        let d = self.arena.alloc(hkey, hnext, hbot, 0, level);
        head.bottom.store(d, Ordering::Release);
        head.level.store(level + 1, Ordering::Relaxed);
        head.set_key_next(u64::MAX, SENTINEL);
        head.lock.unlock();
        self.stats.depth_increases.fetch_add(1, Ordering::Relaxed);
    }

    /// Algorithm 6: collapse a root whose single child spans everything.
    fn decrease_depth(&self) {
        let head = self.arena.node(self.head);
        head.lock.lock();
        let (hkey, hnext) = head.key_next();
        let level = head.level.load(Ordering::Relaxed);
        if hnext != SENTINEL || level <= 1 {
            head.lock.unlock();
            return;
        }
        let b = head.bottom.load(Ordering::Acquire);
        if b == SENTINEL {
            head.lock.unlock();
            return;
        }
        let bn = self.arena.node(b);
        bn.lock.lock();
        let (bkey, bnext) = bn.key_next();
        let bb = bn.bottom.load(Ordering::Acquire);
        // Collapse only when b is the sole child (key MAX), not terminal.
        if bkey == hkey && bnext == SENTINEL && bb != SENTINEL {
            head.bottom.store(bb, Ordering::Release);
            head.level.store(level - 1, Ordering::Relaxed);
            bn.mark.store(true, Ordering::Release);
            bn.lock.unlock();
            self.arena.retire(b);
            self.stats.depth_decreases.fetch_add(1, Ordering::Relaxed);
        } else {
            bn.lock.unlock();
        }
        head.lock.unlock();
    }

    // ------------------------------------------------------------------
    // Shared helpers for writers (node + children locked)
    // ------------------------------------------------------------------

    /// Lock and collect the children of locked node `p` (the paper's
    /// `AcquireChildren`): the segment from `p.bottom` up to and including
    /// the first child with key >= p.key. Children cannot be retired while
    /// `p` is locked, so links resolve unconditionally.
    ///
    /// `Err` carries the already-locked prefix when the arity bound
    /// overflows (transiently over-wide segment): the caller must release
    /// those locks and retry the operation.
    fn acquire_children(&self, pkey: u64, pbottom: NodeRef) -> Result<ChildVec, ChildVec> {
        let mut out = ChildVec::new();
        let mut d = pbottom;
        while d != SENTINEL {
            let dn = self.arena.node(d);
            dn.lock.lock();
            let (dk, dnext) = dn.key_next();
            if dk > pkey {
                // Foreign boundary: this node already belongs to the next
                // parent (we are stale-high). Exclude it — CheckNodeKey will
                // lower our key and the operation moves right.
                dn.lock.unlock();
                break;
            }
            if !out.push(d) {
                dn.lock.unlock();
                return Err(out);
            }
            if dk == pkey {
                break;
            }
            d = dnext;
        }
        Ok(out)
    }

    fn release_children(&self, children: &[NodeRef]) {
        for &c in children {
            self.arena.node(c).lock.unlock();
        }
    }

    /// Release children, retiring any that this operation marked (merge /
    /// drop-key victims). Children cannot be marked by other threads while
    /// their parent is locked, so every marked child here is ours.
    fn release_children_retiring(&self, children: &[NodeRef]) {
        for &c in children {
            let n = self.arena.node(c);
            let marked = n.is_marked();
            n.lock.unlock();
            if marked {
                self.arena.retire(c);
            }
        }
    }

    /// Paper's `CheckNodeKey`: lower `p.key` to its last child's key if the
    /// child with the highest key was removed. `p` and children are locked.
    fn check_node_key(&self, p: NodeRef, children: &[NodeRef]) {
        if self.is_head(p) || children.is_empty() {
            return;
        }
        let pn = self.arena.node(p);
        let (pkey, pnext) = pn.key_next();
        if pkey == u64::MAX {
            return; // MAX-spine nodes cover (prev, MAX] by construction
        }
        let last = self.arena.node(*children.last().unwrap());
        let lk = last.key();
        if lk < pkey {
            pn.set_key_next(lk, pnext);
        }
    }

    /// Algorithm 2 (`AdditionRebalance`): split `p` if it has >= 5 children.
    /// `p` and `children` are locked. The new sibling takes `p`'s old
    /// `(key, next)` and the children from index 2 on; `p` keeps the first
    /// two and the second child's key.
    fn addition_rebalance(&self, p: NodeRef, children: &[NodeRef]) {
        if children.len() < 5 {
            return;
        }
        let pn = self.arena.node(p);
        let (pkey, pnext) = pn.key_next();
        let level = pn.level.load(Ordering::Relaxed);
        let nn = self.arena.alloc(pkey, pnext, children[2], 0, level);
        let c1key = self.arena.node(children[1]).key();
        pn.set_key_next(c1key, nn);
        self.stats.splits.fetch_add(1, Ordering::Relaxed);
    }

    // ------------------------------------------------------------------
    // Addition (algorithm 1)
    // ------------------------------------------------------------------

    /// Insert `key -> value`. Returns `false` if the key already exists.
    pub fn insert(&self, key: u64, value: u64) -> bool {
        assert!(key <= MAX_KEY, "key {key} reserved for sentinels");
        let mut b = Backoff::new();
        loop {
            match self.addition(self.head, key, value) {
                Tri::True => {
                    self.len.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
                Tri::False => return false,
                Tri::Retry => {
                    self.stats.write_retries.fetch_add(1, Ordering::Relaxed);
                    self.increase_depth();
                    b.wait();
                }
            }
        }
    }

    fn addition(&self, nref: NodeRef, key: u64, value: u64) -> Tri {
        if nref == SENTINEL {
            return Tri::Retry; // fell off the structure; restart
        }
        let Some(n) = self.arena.resolve(nref) else {
            return Tri::Retry;
        };
        n.lock.lock();
        if n.is_marked() || self.arena.resolve(nref).is_none() {
            n.lock.unlock();
            return Tri::Retry;
        }
        let (nkey, nnext) = n.key_next();
        if self.is_head(nref) && nnext != SENTINEL {
            n.lock.unlock();
            return Tri::Retry; // height increase pending (alg 3)
        }
        let nbottom = n.bottom.load(Ordering::Acquire);
        let children = match self.acquire_children(nkey, nbottom) {
            Ok(c) => c,
            Err(partial) => {
                self.release_children(&partial);
                n.lock.unlock();
                return Tri::Retry; // over-wide segment: retry after help
            }
        };
        self.check_node_key(nref, &children);
        let (nkey, nnext) = n.key_next(); // may have been lowered

        if nkey < key {
            // Move right.
            self.release_children(&children);
            n.lock.unlock();
            return self.addition(nnext, key, value);
        }

        self.addition_rebalance(nref, &children);
        let level = n.level.load(Ordering::Relaxed);

        if level == 1 {
            // Leaf: insert into the terminal segment (paper's AddNode).
            let r = self.add_terminal(nref, &children, key, value);
            self.release_children(&children);
            n.lock.unlock();
            return r;
        }

        // Descend into the first child whose key covers `key`.
        let mut target = None;
        for &c in children.iter() {
            if key <= self.arena.node(c).key() {
                target = Some(c);
                break;
            }
        }
        self.release_children(&children);
        n.lock.unlock();
        match target {
            Some(c) => self.addition(c, key, value),
            // Can only happen transiently (concurrent restructure): retry.
            None => Tri::Retry,
        }
    }

    /// Insert a terminal node for `key` into locked leaf `p` whose terminal
    /// children (also locked) are `children`. Insert-before is done by
    /// duplicating the successor and atomically overwriting its `(key,next)`
    /// so no predecessor pointer is ever needed.
    fn add_terminal(&self, p: NodeRef, children: &[NodeRef], key: u64, value: u64) -> Tri {
        let pn = self.arena.node(p);
        // children here are terminal nodes; find insert position.
        let mut pred: Option<NodeRef> = None;
        let mut cand: Option<NodeRef> = None;
        for &c in children {
            let ck = self.arena.node(c).key();
            if ck < key {
                pred = Some(c);
            } else {
                cand = Some(c);
                break;
            }
        }
        if let Some(c) = cand {
            let cn = self.arena.node(c);
            let (ck, cnext) = cn.key_next();
            if ck == key {
                return Tri::False; // duplicate
            }
            // insert-before-c: nn duplicates c; c becomes the new key.
            let cval = cn.value.load(Ordering::Relaxed);
            let nn = self.arena.alloc(ck, cnext, SENTINEL, cval, 0);
            cn.value.store(value, Ordering::Relaxed);
            cn.set_key_next(key, nn);
            return Tri::True;
        }
        // key is larger than every child but <= p.key: append after pred,
        // or become the first terminal node of an empty (head) leaf.
        let t = match pred {
            Some(pr) => {
                let prn = self.arena.node(pr);
                let (prk, prnext) = prn.key_next();
                let t = self.arena.alloc(key, prnext, SENTINEL, value, 0);
                prn.set_key_next(prk, t);
                t
            }
            None => {
                let t = self.arena.alloc(key, SENTINEL, SENTINEL, value, 0);
                pn.bottom.store(t, Ordering::Release);
                t
            }
        };
        let _ = t;
        Tri::True
    }

    // ------------------------------------------------------------------
    // Find (algorithm 4)
    // ------------------------------------------------------------------

    /// Lookup: returns the value if present.
    pub fn get(&self, key: u64) -> Option<u64> {
        let mut b = Backoff::new();
        loop {
            let r = match self.mode {
                FindMode::LockFree => self.find_lockfree(key),
                FindMode::ReadLocked => self.find_readlocked(key),
            };
            match r {
                Ok(v) => return v,
                Err(()) => {
                    self.stats.find_retries.fetch_add(1, Ordering::Relaxed);
                    // help pending height changes, then retry
                    if self.arena.node(self.head).next() != SENTINEL {
                        self.increase_depth();
                    }
                    b.wait();
                }
            }
        }
    }

    pub fn contains(&self, key: u64) -> bool {
        self.get(key).is_some()
    }

    /// One lock-free traversal attempt. `Err(())` = RETRY.
    fn find_lockfree(&self, key: u64) -> Result<Option<u64>, ()> {
        let mut cur = self.head;
        loop {
            if cur == SENTINEL {
                return Ok(None);
            }
            let Some(n) = self.arena.resolve(cur) else {
                return Err(());
            };
            if n.is_marked() {
                return Err(());
            }
            let (nkey, nnext) = n.key_next();
            let bottom = n.bottom.load(Ordering::Acquire);
            // validate the snapshot was taken while `cur` was live
            if self.arena.resolve(cur).is_none() {
                return Err(());
            }
            if self.is_head(cur) && nnext != SENTINEL {
                return Err(()); // height change pending
            }
            if bottom == SENTINEL && !self.is_head(cur) {
                // terminal node
                if nkey == key {
                    let v = n.value.load(Ordering::Relaxed);
                    if n.is_marked() || self.arena.resolve(cur).is_none() {
                        return Err(());
                    }
                    return Ok(Some(v));
                }
                if nkey > key {
                    return Ok(None);
                }
                cur = nnext;
                continue;
            }
            if self.is_head(cur) && bottom == SENTINEL {
                return Ok(None); // empty structure
            }
            if nkey < key {
                cur = nnext;
                continue;
            }
            // collect children lock-free; stop at first covering child
            let mut d = bottom;
            let mut target = None;
            loop {
                if d == SENTINEL {
                    break;
                }
                let Some((dk, dn)) = self.arena.read_key_next(d) else {
                    return Err(());
                };
                let dnode = self.arena.node(d);
                if dnode.is_marked() || n.is_marked() {
                    return Err(());
                }
                if key <= dk {
                    target = Some(d);
                    break;
                }
                if dk >= nkey {
                    break; // boundary child passed without covering `key`
                }
                d = dn;
            }
            match target {
                // Descending into a foreign boundary child (key > nkey,
                // stale-high parent) is correct: the gap (last child, nkey]
                // belongs to the next parent's first subtree.
                Some(t) => cur = t,
                // No cover: every child key < key, so this subtree's max is
                // below `key` — continue right (paper: "the search can
                // continue to the right").
                None => cur = nnext,
            }
        }
    }

    /// RWL baseline: hand-over-hand shared locks.
    fn find_readlocked(&self, key: u64) -> Result<Option<u64>, ()> {
        let mut cur = self.head;
        let mut held: Option<NodeRef> = None;
        let r = self.find_readlocked_inner(&mut cur, &mut held, key);
        if let Some(h) = held {
            self.arena.node(h).lock.unlock_shared();
        }
        r
    }

    fn find_readlocked_inner(
        &self,
        cur: &mut NodeRef,
        held: &mut Option<NodeRef>,
        key: u64,
    ) -> Result<Option<u64>, ()> {
        // lock the starting node
        let n0 = self.arena.node(*cur);
        n0.lock.lock_shared();
        *held = Some(*cur);
        loop {
            let curref = (*held).unwrap();
            let n = self.arena.node(curref);
            if n.is_marked() || self.arena.resolve(curref).is_none() {
                return Err(());
            }
            let (nkey, nnext) = n.key_next();
            if self.is_head(curref) && nnext != SENTINEL {
                return Err(());
            }
            let bottom = n.bottom.load(Ordering::Acquire);
            if bottom == SENTINEL && !self.is_head(curref) {
                // terminal
                if nkey == key {
                    return Ok(Some(n.value.load(Ordering::Relaxed)));
                }
                if nkey > key {
                    return Ok(None);
                }
                if !self.step_read(held, nnext)? {
                    return Ok(None);
                }
                continue;
            }
            if self.is_head(curref) && bottom == SENTINEL {
                return Ok(None);
            }
            if nkey < key {
                if !self.step_read(held, nnext)? {
                    return Ok(None);
                }
                continue;
            }
            // walk children under the parent's read lock (children cannot be
            // restructured without the parent's write lock for terminals, and
            // child-level writers lock the child itself — take its read lock
            // before stepping down).
            let mut d = bottom;
            let mut target = None;
            while d != SENTINEL {
                let dn = self.arena.node(d);
                let (dk, dnext) = dn.key_next();
                if key <= dk {
                    target = Some(d);
                    break;
                }
                if dk >= nkey {
                    break;
                }
                d = dnext;
            }
            match target {
                Some(t) => {
                    if !self.step_read(held, t)? {
                        return Ok(None);
                    }
                }
                // no cover: subtree max < key — continue right
                None => {
                    if !self.step_read(held, nnext)? {
                        return Ok(None);
                    }
                }
            }
        }
    }

    /// Move the single shared lock from `held` to `to` (hand-over-hand).
    fn step_read(&self, held: &mut Option<NodeRef>, to: NodeRef) -> Result<bool, ()> {
        if to == SENTINEL {
            if let Some(h) = held.take() {
                self.arena.node(h).lock.unlock_shared();
            }
            return Ok(false);
        }
        let tn = self.arena.node(to);
        tn.lock.lock_shared();
        if let Some(h) = held.take() {
            self.arena.node(h).lock.unlock_shared();
        }
        *held = Some(to);
        if self.arena.resolve(to).is_none() || tn.is_marked() {
            return Err(());
        }
        Ok(true)
    }

    // ------------------------------------------------------------------
    // Deletion (algorithm 5 + the paper's prose)
    // ------------------------------------------------------------------

    /// Remove `key`. Returns `false` if it was not present.
    pub fn erase(&self, key: u64) -> bool {
        let mut b = Backoff::new();
        loop {
            match self.deletion(self.head, key) {
                Tri::True => {
                    self.len.fetch_sub(1, Ordering::Relaxed);
                    // opportunistic height collapse (cheap check first)
                    self.maybe_decrease_depth();
                    return true;
                }
                Tri::False => return false,
                Tri::Retry => {
                    self.stats.write_retries.fetch_add(1, Ordering::Relaxed);
                    self.increase_depth();
                    self.maybe_decrease_depth();
                    b.wait();
                }
            }
        }
    }

    fn maybe_decrease_depth(&self) {
        let head = self.arena.node(self.head);
        if head.level.load(Ordering::Relaxed) <= 1 {
            return;
        }
        let b = head.bottom.load(Ordering::Acquire);
        if b == SENTINEL {
            return;
        }
        if let Some((bk, bn)) = self.arena.read_key_next(b) {
            if bk == u64::MAX && bn == SENTINEL {
                self.decrease_depth();
            }
        }
    }

    fn deletion(&self, nref: NodeRef, key: u64) -> Tri {
        if nref == SENTINEL {
            return Tri::Retry;
        }
        let Some(n) = self.arena.resolve(nref) else {
            return Tri::Retry;
        };
        n.lock.lock();
        if n.is_marked() || self.arena.resolve(nref).is_none() {
            n.lock.unlock();
            return Tri::Retry;
        }
        let (nkey, nnext) = n.key_next();
        if self.is_head(nref) && nnext != SENTINEL {
            n.lock.unlock();
            return Tri::Retry;
        }
        let nbottom = n.bottom.load(Ordering::Acquire);
        let children = match self.acquire_children(nkey, nbottom) {
            Ok(c) => c,
            Err(partial) => {
                self.release_children(&partial);
                n.lock.unlock();
                return Tri::Retry; // over-wide segment: retry after help
            }
        };
        self.check_node_key(nref, &children);
        let (nkey, nnext) = n.key_next();

        if nkey < key {
            self.release_children(&children);
            n.lock.unlock();
            return self.deletion(nnext, key);
        }

        let level = n.level.load(Ordering::Relaxed);
        if level == 1 {
            let r = self.drop_key(nref, &children, key);
            self.release_children_retiring(&children);
            n.lock.unlock();
            return r;
        }

        // Choose the covering child and (if it needs boosting) a partner.
        let mut idx = None;
        for (i, &c) in children.iter().enumerate() {
            if key <= self.arena.node(c).key() {
                idx = Some(i);
                break;
            }
        }
        let Some(i) = idx else {
            self.release_children(&children);
            n.lock.unlock();
            return Tri::False; // key beyond every child: not present
        };

        let target = children[i];
        let Some(tchildren) = self.count_children(target) else {
            // arity overflow while counting: retry the whole operation
            self.release_children(&children);
            n.lock.unlock();
            return Tri::Retry;
        };
        let mut descend = target;

        if tchildren == 0 {
            // transient/corrupt view; retry
            self.release_children(&children);
            n.lock.unlock();
            return Tri::Retry;
        }
        if tchildren <= 2 && children.len() >= 2 {
            // Boost via merge/borrow with a sibling (alg 5). Pair is always
            // (left, right) = adjacent children of n; merge removes the
            // RIGHT node so the parent's bottom link never dangles.
            let (li, ri) = if i > 0 { (i - 1, i) } else { (i, i + 1) };
            if ri < children.len() {
                let merged = self.merge_borrow(children[li], children[ri], key);
                descend = merged;
            }
        }

        self.release_children_retiring(&children);
        n.lock.unlock();
        self.deletion(descend, key)
    }

    /// Count the children of locked node `c` (no locks needed: mutating
    /// `c`'s child list requires `c`'s lock, which we hold). `None` on
    /// arity overflow (caller retries).
    fn count_children(&self, c: NodeRef) -> Option<usize> {
        self.collect_children(c).map(|v| v.len())
    }

    /// Algorithm 5: merge the pair `(n1, n2)` (both locked children of the
    /// current node; `n2 = n1.next`) and optionally re-split ("borrow") if
    /// the donor side had more than 2 children. Returns the node now
    /// covering `key`.
    fn merge_borrow(&self, n1: NodeRef, n2: NodeRef, key: u64) -> NodeRef {
        let n1n = self.arena.node(n1);
        let n2n = self.arena.node(n2);
        let (n1key, n1next) = n1n.key_next();
        debug_assert_eq!(n1next, n2, "pair must be adjacent");
        let (c1, c2) = match (self.collect_children(n1), self.collect_children(n2)) {
            (Some(a), Some(b)) => (a, b),
            // Transiently over-wide sibling: skip the boost. The deletion
            // still descends into the covering child; the next writer pass
            // through this segment rebalances it.
            _ => return if key <= n1key { n1 } else { n2 },
        };
        let target_left = key <= n1key;
        let need = (target_left && c1.len() <= 2) || (!target_left && c2.len() <= 2);
        if !need {
            return if target_left { n1 } else { n2 };
        }

        // merge: n1 absorbs n2 (atomic (key,next) takeover), n2 retires.
        let (n2key, n2next) = n2n.key_next();
        let level = n1n.level.load(Ordering::Relaxed);
        n1n.set_key_next(n2key, n2next);
        n2n.mark.store(true, Ordering::Release);
        self.stats.merges.fetch_add(1, Ordering::Relaxed);

        let merged_len = c1.len() + c2.len();
        let mut result = n1;
        if merged_len > 4 {
            // borrow: re-split so the target side keeps >= 3 children.
            self.stats.borrows.fetch_add(1, Ordering::Relaxed);
            if target_left {
                // target was n1 (2 children); give it c2[0], new node nn
                // takes c2[1..].
                let nn = self.arena.alloc(n2key, n2next, c2[1], 0, level);
                let bk = self.arena.node(c2[0]).key();
                n1n.set_key_next(bk, nn);
                result = if key <= bk { n1 } else { nn };
            } else {
                // target was n2 (2 children); nn takes n1's last child plus
                // n2's children.
                let p = c1.len();
                let nn = self.arena.alloc(n2key, n2next, c1[p - 1], 0, level);
                let bk = self.arena.node(c1[p - 2]).key();
                n1n.set_key_next(bk, nn);
                result = if key <= bk { n1 } else { nn };
            }
        }
        // n2 stays locked and marked; the caller's release loop unlocks and
        // retires it (release_children_retiring).
        result
    }

    /// Child refs of locked node `c`, without locking them (mutating `c`'s
    /// child list requires `c`'s lock, which the caller holds). Foreign
    /// boundary nodes (key > c.key) are excluded — see `acquire_children`.
    /// `None` on arity overflow (caller retries or skips the rebalance).
    fn collect_children(&self, c: NodeRef) -> Option<ChildVec> {
        let cn = self.arena.node(c);
        let ckey = cn.key();
        let mut out = ChildVec::new();
        let mut d = cn.bottom.load(Ordering::Acquire);
        while d != SENTINEL {
            let (dk, dn) = self.arena.node(d).key_next();
            if dk > ckey {
                break;
            }
            if !out.push(d) {
                return None;
            }
            if dk == ckey {
                break;
            }
            d = dn;
        }
        Some(out)
    }

    /// Remove `key` from the terminal segment of locked leaf `p` (children
    /// locked). In-segment unlink via predecessor, or delete-by-copy when
    /// the target is the segment's first node.
    fn drop_key(&self, p: NodeRef, children: &[NodeRef], key: u64) -> Tri {
        let pn = self.arena.node(p);
        let mut pred: Option<NodeRef> = None;
        let mut target: Option<(usize, NodeRef)> = None;
        for (i, &c) in children.iter().enumerate() {
            let ck = self.arena.node(c).key();
            if ck == key {
                target = Some((i, c));
                break;
            }
            if ck < key {
                pred = Some(c);
            } else {
                break;
            }
        }
        let Some((ti, t)) = target else {
            return Tri::False;
        };
        let tn = self.arena.node(t);
        let (tkey, tnext) = tn.key_next();
        debug_assert_eq!(tkey, key);

        if let Some(pr) = pred {
            // unlink via in-segment predecessor
            let prn = self.arena.node(pr);
            let (prk, _) = prn.key_next();
            prn.set_key_next(prk, tnext);
            tn.mark.store(true, Ordering::Release);
            // keep p.key in sync if we removed the last child
            if ti == children.len() - 1 {
                let (pk, pnx) = pn.key_next();
                if pk == key && !self.is_head(p) {
                    pn.set_key_next(prk, pnx);
                }
            }
        } else if ti + 1 < children.len() {
            // first child: delete-by-copy from the in-segment successor
            let s = children[ti + 1];
            let sn = self.arena.node(s);
            let (sk, snext) = sn.key_next();
            let sval = sn.value.load(Ordering::Relaxed);
            tn.value.store(sval, Ordering::Relaxed);
            tn.set_key_next(sk, snext);
            sn.mark.store(true, Ordering::Release);
        } else {
            // only child (possible only at the head leaf)
            pn.bottom.store(tnext, Ordering::Release);
            tn.mark.store(true, Ordering::Release);
        }
        Tri::True
    }


    // ------------------------------------------------------------------
    // Range search (the paper's motivating skiplist advantage, §IX)
    // ------------------------------------------------------------------

    /// Collect all `(key, value)` with `lo <= key <= hi` (lock-free walk of
    /// the terminal list; retries on interference).
    pub fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        let mut b = Backoff::new();
        'retry: loop {
            let Some(start) = self.seek_terminal(lo) else {
                self.stats.find_retries.fetch_add(1, Ordering::Relaxed);
                b.wait();
                continue 'retry;
            };
            let mut out = Vec::new();
            let mut cur = start;
            loop {
                if cur == SENTINEL {
                    return out;
                }
                let Some((k, nx)) = self.arena.read_key_next(cur) else {
                    self.stats.find_retries.fetch_add(1, Ordering::Relaxed);
                    b.wait();
                    continue 'retry;
                };
                if k > hi {
                    return out;
                }
                if k >= lo {
                    let v = self.arena.node(cur).value.load(Ordering::Relaxed);
                    if self.arena.resolve(cur).is_none() {
                        b.wait();
                        continue 'retry;
                    }
                    out.push((k, v));
                }
                cur = nx;
            }
        }
    }

    /// Find the first terminal node with key >= lo (None = retry).
    fn seek_terminal(&self, lo: u64) -> Option<NodeRef> {
        let mut cur = self.head;
        loop {
            if cur == SENTINEL {
                return Some(SENTINEL);
            }
            let n = self.arena.resolve(cur)?;
            if n.is_marked() {
                return None;
            }
            let (nkey, nnext) = n.key_next();
            let bottom = n.bottom.load(Ordering::Acquire);
            if self.arena.resolve(cur).is_none() {
                return None;
            }
            if self.is_head(cur) && nnext != SENTINEL {
                return None;
            }
            if bottom == SENTINEL && !self.is_head(cur) {
                // terminal node
                if nkey >= lo {
                    return Some(cur);
                }
                cur = nnext;
                continue;
            }
            if self.is_head(cur) && bottom == SENTINEL {
                return Some(SENTINEL);
            }
            if nkey < lo {
                cur = nnext;
                continue;
            }
            // descend into covering child
            let mut d = bottom;
            let mut target = None;
            while d != SENTINEL {
                let (dk, dn) = self.arena.read_key_next(d)?;
                if lo <= dk {
                    target = Some(d);
                    break;
                }
                if dk >= nkey {
                    break;
                }
                d = dn;
            }
            match target {
                Some(t) => cur = t,
                None => {
                    // lo beyond this subtree: continue right at this level
                    cur = nnext;
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Invariant checking (tests; quiescent only)
    // ------------------------------------------------------------------

    /// Verify structural invariants (call only when no writers are active):
    /// per-level sorted keys, parent keys >= child keys, segment partition,
    /// arity bounds, terminal key set. Returns the sorted terminal keys.
    pub fn check_invariants(&self) -> Result<Vec<u64>, String> {
        let head = self.arena.node(self.head);
        if head.next() != SENTINEL {
            return Err("head has a sibling (pending IncreaseDepth)".into());
        }
        // walk down the leftmost spine collecting level heads
        let mut level_heads = vec![self.head];
        let mut cur = self.head;
        loop {
            let b = self.arena.node(cur).bottom.load(Ordering::Acquire);
            if b == SENTINEL {
                break;
            }
            level_heads.push(b);
            cur = b;
        }
        if level_heads.len() < 2 {
            // empty structure
            return Ok(Vec::new());
        }
        // check each non-terminal level
        for w in 0..level_heads.len() - 1 {
            let mut node = level_heads[w];
            let mut child = level_heads[w + 1];
            let mut prev_key: Option<u64> = None;
            while node != SENTINEL {
                let nn = self.arena.node(node);
                if nn.is_marked() {
                    return Err(format!("marked node reachable at level walk {w}"));
                }
                let (nkey, nnext) = nn.key_next();
                if let Some(pk) = prev_key {
                    if nkey <= pk {
                        return Err(format!("level {w}: keys not increasing ({pk} -> {nkey})"));
                    }
                }
                prev_key = Some(nkey);
                // node's children = segment of the lower level from `child`
                if nn.bottom.load(Ordering::Acquire) != child {
                    return Err(format!("level {w}: segment partition broken at key {nkey}"));
                }
                let mut arity = 0;
                loop {
                    if child == SENTINEL {
                        break;
                    }
                    let (ck, cn) = self.arena.node(child).key_next();
                    if ck > nkey {
                        // stale-high parent (lazy CheckNodeKey): the next
                        // parent owns this child — legal quiescent state.
                        break;
                    }
                    arity += 1;
                    child = cn;
                    if ck == nkey {
                        break;
                    }
                }
                if arity > 7 {
                    return Err(format!("level {w}: node arity {arity} > 7"));
                }
                let is_root_or_spine = node == self.head || nkey == u64::MAX;
                if arity < 2 && !is_root_or_spine && self.len() > 4 {
                    return Err(format!("level {w}: node key {nkey} arity {arity} < 2"));
                }
                node = nnext;
            }
            if child != SENTINEL {
                return Err(format!("level {w}: lower level has unreachable tail"));
            }
        }
        // collect terminal keys
        let mut keys = Vec::new();
        let mut t = *level_heads.last().unwrap();
        let mut prev: Option<u64> = None;
        while t != SENTINEL {
            let (k, nx) = self.arena.node(t).key_next();
            if let Some(p) = prev {
                if k <= p {
                    return Err(format!("terminal keys not increasing ({p} -> {k})"));
                }
            }
            prev = Some(k);
            keys.push(k);
            t = nx;
        }
        if keys.len() as u64 != self.len() {
            return Err(format!("len {} != terminal count {}", self.len(), keys.len()));
        }
        Ok(keys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeSet;
    use std::sync::Arc;

    fn new_lf() -> DetSkiplist {
        DetSkiplist::with_capacity(FindMode::LockFree, 1 << 14)
    }

    #[test]
    fn empty_structure() {
        let s = new_lf();
        assert_eq!(s.get(1), None);
        assert!(!s.erase(1));
        assert!(s.is_empty());
        assert_eq!(s.check_invariants().unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn single_insert_find() {
        let s = new_lf();
        assert!(s.insert(42, 420));
        assert_eq!(s.get(42), Some(420));
        assert_eq!(s.get(41), None);
        assert_eq!(s.get(43), None);
        assert!(!s.insert(42, 421), "duplicate rejected");
        assert_eq!(s.get(42), Some(420), "duplicate does not overwrite");
        assert_eq!(s.check_invariants().unwrap(), vec![42]);
    }

    #[test]
    fn sorted_bulk_insert_builds_levels() {
        let s = new_lf();
        for k in 0..200u64 {
            assert!(s.insert(k, k * 10));
        }
        for k in 0..200u64 {
            assert_eq!(s.get(k), Some(k * 10), "key {k}");
        }
        assert_eq!(s.get(200), None);
        let st = s.stats();
        assert!(st.splits > 0, "splits must have happened");
        assert!(st.depth_increases > 0, "height must have grown");
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, (0..200).collect::<Vec<_>>());
    }

    #[test]
    fn reverse_and_shuffled_inserts() {
        for seed in 0..3 {
            let s = new_lf();
            let mut keys: Vec<u64> = (0..300).map(|i| i * 7 + 1).collect();
            if seed == 0 {
                keys.reverse();
            } else {
                Rng::new(seed).shuffle(&mut keys);
            }
            for &k in &keys {
                assert!(s.insert(k, k));
            }
            for &k in &keys {
                assert_eq!(s.get(k), Some(k));
            }
            let mut sorted = keys.clone();
            sorted.sort_unstable();
            assert_eq!(s.check_invariants().unwrap(), sorted);
        }
    }

    #[test]
    fn erase_sequential() {
        let s = new_lf();
        for k in 0..100u64 {
            s.insert(k, k);
        }
        // erase evens
        for k in (0..100u64).step_by(2) {
            assert!(s.erase(k), "erase {k}");
        }
        for k in 0..100u64 {
            assert_eq!(s.contains(k), k % 2 == 1, "key {k}");
        }
        assert!(!s.erase(2), "double erase");
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, (0..100).filter(|k| k % 2 == 1).collect::<Vec<_>>());
    }

    #[test]
    fn erase_everything_and_reuse() {
        let s = new_lf();
        for round in 0..3 {
            for k in 0..150u64 {
                assert!(s.insert(k, k + round), "round {round} insert {k}");
            }
            for k in 0..150u64 {
                assert!(s.erase(k), "round {round} erase {k}");
            }
            assert!(s.is_empty(), "round {round}");
            assert_eq!(s.check_invariants().unwrap(), Vec::<u64>::new());
        }
        assert!(s.mem_stats().recycled > 0, "nodes must recycle");
    }

    #[test]
    fn matches_btreeset_oracle_sequential() {
        let s = new_lf();
        let mut oracle = BTreeSet::new();
        let mut rng = Rng::new(7);
        for i in 0..10_000 {
            let k = rng.below(400);
            match rng.below(10) {
                0..=3 => assert_eq!(s.insert(k, k), oracle.insert(k), "op {i} insert {k}"),
                4..=5 => assert_eq!(s.erase(k), oracle.remove(&k), "op {i} erase {k}"),
                _ => assert_eq!(s.contains(k), oracle.contains(&k), "op {i} find {k}"),
            }
        }
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, oracle.into_iter().collect::<Vec<_>>());
    }

    #[test]
    fn range_search() {
        let s = new_lf();
        for k in (0..100u64).step_by(5) {
            s.insert(k, k * 2);
        }
        let r = s.range(10, 30);
        assert_eq!(r, vec![(10, 20), (15, 30), (20, 40), (25, 50), (30, 60)]);
        assert_eq!(s.range(101, 200), vec![]);
        assert_eq!(s.range(0, 0), vec![(0, 0)]);
        // range on boundaries not present
        let r = s.range(11, 14);
        assert_eq!(r, vec![]);
    }

    #[test]
    fn childvec_push_signals_overflow() {
        let mut cv = ChildVec::new();
        for i in 0..12u64 {
            assert!(cv.push(i + 1), "push {i} within bound");
        }
        assert_eq!(cv.len(), 12);
        assert!(!cv.push(99), "13th child must signal overflow");
        assert_eq!(cv.len(), 12, "overflowing push must not clobber");
        assert_eq!(cv[11], 12, "contents intact after rejected push");
    }

    #[test]
    fn insert_and_erase_batches() {
        // batch ops come from the OrderedKv capability (sorted default over
        // the native insert/erase)
        use crate::coordinator::OrderedKv;
        let s = new_lf();
        let items: Vec<(u64, u64)> = (0..300u64).rev().map(|k| (k * 2, k)).collect();
        assert_eq!(s.insert_batch(&items), 300);
        assert_eq!(s.insert_batch(&items), 0, "all duplicates");
        assert_eq!(s.len(), 300);
        assert_eq!(s.range(0, 10), vec![(0, 0), (2, 1), (4, 2), (6, 3), (8, 4), (10, 5)]);
        let evens: Vec<u64> = (0..300u64).map(|k| k * 2).collect();
        assert_eq!(s.erase_batch(&evens), 300);
        assert_eq!(s.erase_batch(&evens), 0);
        assert!(s.is_empty());
        s.check_invariants().unwrap();
    }

    #[test]
    fn rwl_mode_basics() {
        let s = DetSkiplist::with_capacity(FindMode::ReadLocked, 1 << 14);
        let mut oracle = BTreeSet::new();
        let mut rng = Rng::new(11);
        for _ in 0..3_000 {
            let k = rng.below(200);
            match rng.below(4) {
                0 => assert_eq!(s.insert(k, k), oracle.insert(k)),
                1 => assert_eq!(s.erase(k), oracle.remove(&k)),
                _ => assert_eq!(s.contains(k), oracle.contains(&k)),
            }
        }
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, oracle.into_iter().collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_disjoint_inserts() {
        let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..2_000u64 {
                    assert!(s.insert(t * 100_000 + i, i));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 8_000);
        for t in 0..4u64 {
            for i in (0..2_000u64).step_by(97) {
                assert_eq!(s.get(t * 100_000 + i), Some(i));
            }
        }
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys.len(), 8_000);
    }

    #[test]
    fn concurrent_interleaved_key_space() {
        // threads insert interleaved (mod-4) keys: heavy same-segment contention
        let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1_500u64 {
                    assert!(s.insert(i * 4 + t, t));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 6_000);
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, (0..6_000).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_readers_and_writers() {
        let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
        for k in 0..1_000u64 {
            s.insert(k * 2, k); // evens pre-inserted
        }
        let mut handles = Vec::new();
        // writers insert odds
        for t in 0..2u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1_000u64 {
                    if i % 2 == t {
                        s.insert(i * 2 + 1, i);
                    }
                }
            }));
        }
        // readers: evens must always be present
        for _ in 0..2 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(3);
                for _ in 0..5_000 {
                    let k = rng.below(1_000) * 2;
                    assert!(s.contains(k), "pre-inserted key {k} lost");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 2_000);
        s.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_erase_and_find() {
        let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
        for k in 0..4_000u64 {
            s.insert(k, k);
        }
        let mut handles = Vec::new();
        // erasers: each removes a disjoint quarter
        for t in 0..2u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for k in 0..4_000u64 {
                    if k % 4 == t {
                        assert!(s.erase(k), "erase {k}");
                    }
                }
            }));
        }
        // readers: keys == 3 (mod 4) never erased
        for _ in 0..2 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(5);
                for _ in 0..4_000 {
                    let k = rng.below(1_000) * 4 + 3;
                    assert!(s.contains(k), "stable key {k} lost");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 2_000);
        let keys = s.check_invariants().unwrap();
        assert!(keys.iter().all(|k| k % 4 >= 2));
    }

    #[test]
    fn concurrent_mixed_workload_then_invariants() {
        let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..2_500 {
                    let k = rng.below(256);
                    match rng.below(10) {
                        0..=4 => {
                            s.insert(k, k * 3);
                        }
                        5..=6 => {
                            s.erase(k);
                        }
                        _ => {
                            if let Some(v) = s.get(k) {
                                assert_eq!(v, k * 3, "value corruption at {k}");
                            }
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let keys = s.check_invariants().unwrap();
        for k in keys {
            assert_eq!(s.get(k), Some(k * 3));
        }
    }

    #[test]
    fn height_decreases_after_mass_erase() {
        let s = new_lf();
        for k in 0..500u64 {
            s.insert(k, k);
        }
        for k in 0..495u64 {
            s.erase(k);
        }
        // trigger lazy collapses via traffic
        for _ in 0..20 {
            s.get(499);
            s.erase(496);
            s.insert(496, 0);
        }
        assert!(s.stats().depth_decreases > 0, "height should shrink");
        s.check_invariants().unwrap();
    }
}
