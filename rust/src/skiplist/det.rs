//! Concurrent deterministic 1-2-3-4 skiplist (paper §II, algorithms 1–6).
//!
//! Structure: a hierarchy of linked lists. Level 0 is the *terminal* list
//! holding `(key, value)`; level 1 nodes ("leaves") point into it; higher
//! levels shortcut further. Every non-terminal node covers the child segment
//! `(<prev sibling key>, node.key]`; the rightmost node of every level (and
//! the head) carries key `u64::MAX` ("the key of the root node is the
//! maximum key"). All lists end at the shared self-referential sentinel.
//!
//! Concurrency design, faithful to the paper:
//! - `(key, next)` lives in one 128-bit atomic word; **`Find` is lock-free**
//!   (algorithm 4) and validates node generations against recycling (the
//!   paper's per-node reference counters).
//! - `Addition` (algs 1–2) locks a node plus its children (L shape, ≤ 6
//!   locks) and splits 5-child nodes proactively on the way down.
//! - `Deletion` locks the node plus an adjacent child *pair* (LL shape),
//!   boosts 2-child path nodes via `MergeBorrow` (alg 5), and removes the
//!   terminal key with in-segment unlink or delete-by-copy so a segment's
//!   first node is never unlinked (which would dangle the left neighbour's
//!   `next`). `merge` removes the node with the *higher* key for the same
//!   reason.
//! - Height changes only at the head (algs 3/6); any operation seeing
//!   `head.next != sentinel` retries after helping (`IncreaseDepth`).
//! - Stale-high keys left by lazy ancestor updates are repaired eagerly by
//!   `CheckNodeKey` whenever a writer passes through a node.
//!
//! Deadlock freedom: every writer acquires locks parent-before-child and
//! left-before-right, and releases before recursing; the order is acyclic.
//! The finger fast path (below) locks a leaf without holding its parent,
//! which preserves acyclicity: terminal locks are only ever taken by the
//! holder of their leaf's lock, and no finger path ever waits on a lock
//! while holding a lock above it.
//!
//! # Cache-conscious search path
//!
//! Three mechanisms cut the descent's memory cost (measured by
//! `experiments::t12_cache` / Table XII):
//!
//! - **Hot/cold node split** — descents touch only the 64-byte
//!   [`super::node::NodeHot`] lines (see `node.rs`).
//! - **Descent prefetching** — while a node is being examined, its `next`
//!   and `bottom` hot lines are software-prefetched so the two dependent
//!   misses overlap instead of serializing (`util::prefetch`).
//! - **Per-thread search fingers** — a padded per-thread cache of the last
//!   descent's per-level predecessors (one finger array per skiplist, so
//!   per *shard* in the sharded store). A finger entry is only a *hint*:
//!   before use it is validated live — generation match, unmarked, and
//!   `first_child.key <= key <= node.key`, which proves the key lies in the
//!   node's segment at validation time (the segment's lower bound is
//!   strictly below its first child's key). A stale finger therefore fails
//!   validation and falls back to a full top-down descent; it can make a
//!   search slower, never wrong. Reads may start mid-structure at any
//!   validated level; writes use only the *leaf* finger and additionally
//!   require an arity window (≤ 4 children for insert, ≥ 3 for erase) so
//!   the fast path can never split or underflow a segment — rebalancing
//!   work always happens on full descents, preserving the 1-2-3-4
//!   discipline's "rebalance on the way down" invariant.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::mem::arena::{magazine_count, thread_slot, ThreadTallies};
use crate::mem::{ArenaOptions, PoolStats};
use crate::numa::Topology;
use crate::sync::Backoff;
use crate::util::simd;

use super::replica::{ReplicaRead, ReplicaSet, ReplicaStats};

use super::node::{
    BlockRoute, NodeArena, NodeRef, NodeView, DEFAULT_INNER_CAP, DEFAULT_LEAF_CAP, MAX_INNER_CAP,
    MAX_LEAF_CAP, SENTINEL,
};
use super::{BatchOp, BatchReply};

/// The 1-2-3-4 discipline's arity windows, shared by the rebalancers, the
/// fast-path gates and [`DetSkiplist::check_invariants`] so a drifted
/// constant cannot silently open a window the validator no longer checks
/// (see `arity_windows_are_mutually_consistent`).
///
/// A segment legally holds 1–4 children between descents; a split leaves a
/// ≤ 5-wide transient that the next descent repairs, and lazy boundary
/// repairs (`CheckNodeKey`) can briefly stack to ~7 — the validator's hard
/// ceiling.
pub(crate) const MAX_ARITY: usize = 7;
/// A fast-path insert requires ≤ `INSERT_WINDOW` children: after the op the
/// node holds at most `SPLIT_THRESHOLD`, the same transient a full descent
/// leaves behind.
pub(crate) const INSERT_WINDOW: usize = 4;
/// A fast-path erase (or any leaf-arity shrink outside a full descent)
/// requires ≥ `ERASE_WINDOW` children: after the op at least 2 remain, so
/// no merge/borrow boost is ever needed off the descent path.
pub(crate) const ERASE_WINDOW: usize = 3;
/// Descents split any node at or above this width on the way down
/// (algorithm 2): the over-full transient an in-window insert may create.
pub(crate) const SPLIT_THRESHOLD: usize = INSERT_WINDOW + 1;

/// How `find` traverses: the paper's lock-free algorithm 4, or the RWL
/// baseline (hand-over-hand shared locks, "RWL" in tables II/III).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FindMode {
    LockFree,
    ReadLocked,
}

/// Tri-state internal result (paper's TRUE/FALSE/RETRY).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Tri {
    True,
    False,
    Retry,
}

/// Operation counters (used by tests, ablations and EXPERIMENTS.md).
#[derive(Debug, Default, Clone)]
pub struct SkiplistStats {
    pub splits: u64,
    pub merges: u64,
    pub borrows: u64,
    pub depth_increases: u64,
    pub depth_decreases: u64,
    pub find_retries: u64,
    pub write_retries: u64,
    /// Node (hot-line) dereferences across all operations — the cache-cost
    /// proxy Table XII tracks per op.
    pub node_derefs: u64,
    /// Operations that consulted the per-thread finger cache.
    pub finger_attempts: u64,
    /// Consultations that validated and skipped the full top-down descent.
    pub finger_hits: u64,
    /// Validated finger starts whose traversal then raced a restructure and
    /// fell back to a full descent. Kept separate from `find_retries` so
    /// the pre-finger meaning of that counter (lock-free traversal
    /// interference) stays intact.
    pub finger_fallbacks: u64,
    /// Software prefetches issued on the search path.
    pub prefetches: u64,
    /// Dereferences the interleaved engine performed with no other descent
    /// in flight to overlap their misses with (width-1 pipelines and drain
    /// tails) — the MLP-exposure proxy Table XIV tracks per op. Point and
    /// fused operations leave this at zero.
    pub stalled_derefs: u64,
}

impl SkiplistStats {
    /// Accumulate `other` into `self` (per-shard aggregation: the sharded
    /// store sums every shard's counters into one observable snapshot).
    pub fn merge(&mut self, other: &SkiplistStats) {
        self.splits += other.splits;
        self.merges += other.merges;
        self.borrows += other.borrows;
        self.depth_increases += other.depth_increases;
        self.depth_decreases += other.depth_decreases;
        self.find_retries += other.find_retries;
        self.write_retries += other.write_retries;
        self.node_derefs += other.node_derefs;
        self.finger_attempts += other.finger_attempts;
        self.finger_hits += other.finger_hits;
        self.finger_fallbacks += other.finger_fallbacks;
        self.prefetches += other.prefetches;
        self.stalled_derefs += other.stalled_derefs;
    }

    /// Fraction of finger consultations that skipped the full descent.
    pub fn finger_hit_rate(&self) -> f64 {
        if self.finger_attempts == 0 {
            0.0
        } else {
            self.finger_hits as f64 / self.finger_attempts as f64
        }
    }
}

/// Shared counters for *rare* events only (restructures and retries). The
/// per-op hot counters live in the padded per-thread
/// [`ThreadTallies`] array — a find must not bounce a shared stats line on
/// every operation, or the instrumentation itself would suppress the read
/// scalability Table XII exists to measure.
#[derive(Default)]
struct AtomicSkiplistStats {
    splits: AtomicU64,
    merges: AtomicU64,
    borrows: AtomicU64,
    depth_increases: AtomicU64,
    depth_decreases: AtomicU64,
    find_retries: AtomicU64,
    write_retries: AtomicU64,
    finger_fallbacks: AtomicU64,
}

// Counter indices in the per-thread tally slots.
const TALLY_DEREFS: usize = 0;
const TALLY_PREFETCHES: usize = 1;
const TALLY_ATTEMPTS: usize = 2;
const TALLY_HITS: usize = 3;
const TALLY_STALLED: usize = 4;
const TALLY_WIDTH: usize = 5;

/// Per-operation cost tally, accumulated in registers on the hot path and
/// flushed to this thread's padded tally line once per public operation
/// (a single slot lookup and at most four thread-private `fetch_add`s per
/// op, instead of shared-atomic traffic per node).
#[derive(Default)]
struct PathCost {
    derefs: u64,
    prefetches: u64,
    finger_attempts: u64,
    finger_hits: u64,
    stalled: u64,
}

/// Levels of the descent path a finger slot remembers (leaf = index 0).
const FINGER_LEVELS: usize = 8;

/// One thread's finger: the last descent's per-level predecessors plus the
/// key bounds each covered when recorded. Padded so hashed-slot neighbours
/// never false-share. The stored bounds are a *predictor* only (torn or
/// stale values at worst cause a failed validation); correctness comes from
/// the live generation + key-bounds check in `finger_start`.
#[repr(align(128))]
struct FingerSlot {
    refs: [AtomicU64; FINGER_LEVELS],
    lo: [AtomicU64; FINGER_LEVELS],
    hi: [AtomicU64; FINGER_LEVELS],
}

impl FingerSlot {
    fn new() -> FingerSlot {
        FingerSlot {
            refs: std::array::from_fn(|_| AtomicU64::new(SENTINEL)),
            lo: std::array::from_fn(|_| AtomicU64::new(0)),
            hi: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Fixed-capacity child list (arity is bounded by `max_arity()` ≤ F + 2 =
/// 18 at the widest `inner_cap`, plus the boundary node): avoids a heap
/// allocation per visited node on the write path — see EXPERIMENTS.md
/// §Perf.
pub(crate) struct ChildVec {
    buf: [NodeRef; 24],
    len: usize,
}

impl ChildVec {
    #[inline]
    fn new() -> ChildVec {
        ChildVec { buf: [SENTINEL; 24], len: 0 }
    }

    /// Append a child; `false` when the fixed arity bound would be
    /// exceeded (the structure is transiently wider than any legal arity).
    /// Callers must surface that as a RETRY — silently clamping would make
    /// split/merge reason about a truncated child list and (in release
    /// builds, where the old debug assert vanished) corrupt the segment.
    #[inline]
    #[must_use]
    fn push(&mut self, r: NodeRef) -> bool {
        if self.len < self.buf.len() {
            self.buf[self.len] = r;
            self.len += 1;
            true
        } else {
            false
        }
    }
}

impl std::ops::Deref for ChildVec {
    type Target = [NodeRef];
    #[inline]
    fn deref(&self) -> &[NodeRef] {
        &self.buf[..self.len]
    }
}

/// Which terminal mutation a finger fast path is attempting.
enum FingerOp {
    Insert(u64),
    Erase,
}

/// Outcome of one fused-run descent ([`DetSkiplist::apply_sorted_run`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RunStep {
    /// The descent reached a leaf and ended the group (≥ 0 ops applied).
    Done,
    /// The carried start failed live validation — retry from a shallower
    /// carried level (or the head). Only produced for carried starts.
    Stale,
    /// Structural interference: restart the group from the head.
    Retry,
}

/// The carried descent path of a fused run: the last descent's entry node
/// per level (leaf = index 0) with its coverage key at record time. A
/// run-local, single-owner analogue of the finger cache — entries are
/// hints only, validated live before reuse (lock + generation + the
/// children lower-bound proof), so a stale entry costs a retry from a
/// shallower level, never a wrong placement.
struct RunCarry {
    refs: [NodeRef; FINGER_LEVELS],
    hi: [u64; FINGER_LEVELS],
}

impl RunCarry {
    fn new() -> RunCarry {
        RunCarry { refs: [SENTINEL; FINGER_LEVELS], hi: [0; FINGER_LEVELS] }
    }

    fn clear(&mut self) {
        self.refs = [SENTINEL; FINGER_LEVELS];
    }

    /// Remember node `r` (level >= 1) as the run's entry at its level,
    /// covering keys up to `hi` when recorded.
    fn record(&mut self, level: u32, r: NodeRef, hi: u64) {
        if level >= 1 && level <= FINGER_LEVELS as u32 {
            self.refs[(level - 1) as usize] = r;
            self.hi[(level - 1) as usize] = hi;
        }
    }

    /// Deepest entry predicted to cover `key` (level index, ref). Keys only
    /// ascend within a run, so an entry whose recorded coverage fell behind
    /// is skipped without touching the node.
    fn start_for(&self, key: u64) -> Option<(usize, NodeRef)> {
        (0..FINGER_LEVELS)
            .find(|&l| self.refs[l] != SENTINEL && key <= self.hi[l])
            .map(|l| (l, self.refs[l]))
    }

    /// Drop every entry at or below level index `l` (they failed or are
    /// shadowed by a failed validation).
    fn invalidate_up_to(&mut self, l: usize) {
        for k in 0..=l.min(FINGER_LEVELS - 1) {
            self.refs[k] = SENTINEL;
        }
    }
}

/// Upper bound on the interleaved engine's pipeline width: beyond ~32
/// in-flight descents the lane states themselves outgrow L1 and the
/// pipeline starts thrashing the very cache it is trying to hide.
const MAX_INTERLEAVE: usize = 32;

/// Automaton restarts per op before the interleaved engine resolves the op
/// synchronously (guaranteed progress under adversarial churn).
const LANE_RETRY_LIMIT: u32 = 8;

/// One in-flight descent of the interleaved engine
/// ([`DetSkiplist::apply_interleaved`]): the lane's contiguous slice of the
/// run, its current automaton position, and its private carried path (keys
/// only ascend within a lane, so the carry is reused exactly like the fused
/// path's).
struct Lane {
    /// Next op index (into the whole run) this lane resolves.
    i: usize,
    /// Exclusive end of the lane's chunk.
    end: usize,
    /// Current node of the in-flight descent (valid when `started`).
    cur: NodeRef,
    started: bool,
    /// Automaton restarts for the current op (see [`LANE_RETRY_LIMIT`]).
    retries: u32,
    carry: RunCarry,
}

/// Capacity of the leaf-group segment mirror: the acquired child list is at
/// most the F-relative split window wide (`split_threshold() ≤ 16`) and
/// only the group's licensed first insert can land on a transiently
/// over-wide segment, so 24 never overflows.
const SEG_CAP: usize = 24;

/// Live mirror of one leaf's terminal segment during a fused group: every
/// ref in it is locked by this thread. Kept key-sorted by construction.
struct Seg {
    buf: [NodeRef; SEG_CAP],
    len: usize,
}

impl Seg {
    fn new() -> Seg {
        Seg { buf: [SENTINEL; SEG_CAP], len: 0 }
    }

    #[inline]
    fn len(&self) -> usize {
        self.len
    }

    #[inline]
    fn get(&self, i: usize) -> NodeRef {
        debug_assert!(i < self.len);
        self.buf[i]
    }

    #[inline]
    fn push(&mut self, r: NodeRef) {
        debug_assert!(self.len < SEG_CAP);
        self.buf[self.len] = r;
        self.len += 1;
    }

    /// Insert `r` at position `i`, shifting the tail right (caller keeps
    /// within capacity — guarded at the call site).
    fn insert_at(&mut self, i: usize, r: NodeRef) {
        debug_assert!(self.len < SEG_CAP && i <= self.len);
        let mut j = self.len;
        while j > i {
            self.buf[j] = self.buf[j - 1];
            j -= 1;
        }
        self.buf[i] = r;
        self.len += 1;
    }

    /// Remove the ref at position `i`, shifting the tail left.
    fn remove_at(&mut self, i: usize) {
        debug_assert!(i < self.len);
        for j in i..self.len - 1 {
            self.buf[j] = self.buf[j + 1];
        }
        self.len -= 1;
    }
}

/// The concurrent deterministic 1-2-3-4 skiplist.
pub struct DetSkiplist {
    arena: NodeArena,
    head: NodeRef,
    mode: FindMode,
    len: AtomicU64,
    stats: AtomicSkiplistStats,
    /// Hashed per-thread finger slots (same sizing policy as the arena's
    /// magazines; collisions only degrade the hint, never correctness).
    fingers: Box<[FingerSlot]>,
    /// Hashed per-thread hot-path counter lines (see [`ThreadTallies`]).
    tallies: ThreadTallies<TALLY_WIDTH>,
    fingers_on: AtomicBool,
    /// NUMA-replicated index layers (`ExecMode::Replicated`); unset until
    /// [`DetSkiplist::enable_replicas`], so the write-path publication hook
    /// costs one `OnceLock` load in non-replicated runs.
    replicas: OnceLock<ReplicaSet>,
}

/// Keys must stay below `u64::MAX` (reserved for the head/sentinel spine).
pub const MAX_KEY: u64 = u64::MAX - 1;

impl DetSkiplist {
    /// Skiplist with default arena sizing (grow-on-demand blocks).
    pub fn new(mode: FindMode) -> DetSkiplist {
        Self::with_capacity(mode, 1 << 20)
    }

    /// `capacity` bounds the number of live nodes (terminal + index).
    pub fn with_capacity(mode: FindMode, capacity: usize) -> DetSkiplist {
        Self::with_capacity_on(mode, capacity, ArenaOptions::default())
    }

    /// Like [`DetSkiplist::with_capacity`] with explicit arena placement
    /// (per-shard skiplists home their arena on the shard's NUMA node).
    /// `opts.threads_hint` also sizes the per-thread finger array.
    pub fn with_capacity_on(mode: FindMode, capacity: usize, opts: ArenaOptions) -> DetSkiplist {
        Self::with_leaf_cap_on(mode, capacity, opts, DEFAULT_LEAF_CAP)
    }

    /// Like [`DetSkiplist::with_capacity_on`] with an explicit terminal
    /// chunk capacity `leaf_cap` ∈ 1..=[`MAX_LEAF_CAP`] (Table XV sweeps
    /// this; `leaf_cap = 1` degenerates to the paper's one-key terminals).
    pub fn with_leaf_cap_on(
        mode: FindMode,
        capacity: usize,
        opts: ArenaOptions,
        leaf_cap: usize,
    ) -> DetSkiplist {
        Self::with_caps_on(mode, capacity, opts, leaf_cap, DEFAULT_INNER_CAP)
    }

    /// Fully explicit construction: terminal chunk capacity `leaf_cap`
    /// *and* fat-inner routing-block capacity `inner_cap` ∈
    /// 1..=[`MAX_INNER_CAP`] (Table XVI sweeps this; `inner_cap = 1`
    /// degenerates to the paper's linked per-level child walk with the
    /// legacy 1-2-3-4 arity windows).
    pub fn with_caps_on(
        mode: FindMode,
        capacity: usize,
        opts: ArenaOptions,
        leaf_cap: usize,
        inner_cap: usize,
    ) -> DetSkiplist {
        let arena = NodeArena::for_capacity_caps(capacity, opts, leaf_cap, inner_cap);
        // head: level-1 leaf, key MAX, no children yet.
        let head = arena.alloc(u64::MAX, SENTINEL, SENTINEL, 0, 1);
        if arena.inner_blocks() {
            arena.block_init_unbuilt(head);
        }
        DetSkiplist {
            arena,
            head,
            mode,
            len: AtomicU64::new(0),
            stats: AtomicSkiplistStats::default(),
            fingers: (0..magazine_count(opts.threads_hint)).map(|_| FingerSlot::new()).collect(),
            tallies: ThreadTallies::new(opts.threads_hint),
            fingers_on: AtomicBool::new(true),
            replicas: OnceLock::new(),
        }
    }

    #[inline]
    fn is_head(&self, r: NodeRef) -> bool {
        r == self.head
    }

    /// Keys per terminal chunk (the fat-leaf K).
    #[inline]
    pub fn leaf_cap(&self) -> usize {
        self.arena.leaf_cap()
    }

    /// Minimum chunk occupancy the merge/borrow discipline maintains
    /// (`max(1, K/4)`; a leaf's only chunk is exempt, like the spine).
    #[inline]
    fn min_chunk_occupancy(&self) -> usize {
        (self.arena.leaf_cap() / 4).max(1)
    }

    /// Separators per fat inner routing block (the F of Table XVI;
    /// `< 2` = blocks disabled, legacy linked child walk).
    #[inline]
    pub fn inner_cap(&self) -> usize {
        self.arena.inner_cap()
    }

    #[inline]
    fn inner_blocks(&self) -> bool {
        self.arena.inner_blocks()
    }

    // ------------------------------------------------------------------
    // Arity windows — F-relative generalization of the 1-2-3-4 discipline
    // ------------------------------------------------------------------
    //
    // With inner blocks disabled (F = 1) these reproduce the legacy
    // constants exactly: split at 5, insert window 4, erase window 3,
    // boost at <= 2, validator ceiling 7. With blocks of capacity F >= 2
    // the same relations are re-anchored on F: a descent splits any node
    // at F (so resting arity fits the block), the merge/borrow floor is
    // max(1, F/4) (the B-tree quarter-occupancy rule the terminal chunks
    // already use), and the fast-path windows keep their "never force a
    // rebalance off the descent path" meaning relative to those bounds.
    // `check_invariants` + `arity_windows_are_mutually_consistent` pin the
    // relations so a drifted window cannot silently escape validation.

    /// Descents split any node at or above this width on the way down
    /// (algorithm 2 generalized): legacy 5, else the block capacity F.
    #[inline]
    pub(crate) fn split_threshold(&self) -> usize {
        if self.inner_blocks() {
            self.inner_cap()
        } else {
            SPLIT_THRESHOLD
        }
    }

    /// A fast-path insert requires `<= insert_window` children: after the
    /// op the node holds at most `split_threshold`, the same transient a
    /// full descent leaves behind.
    #[inline]
    pub(crate) fn insert_window(&self) -> usize {
        if self.inner_blocks() {
            self.split_threshold() - 1
        } else {
            INSERT_WINDOW
        }
    }

    /// Minimum resting arity of a non-spine node between descents: legacy
    /// 2, else `max(1, F/4)`. Deletion boosts any path node at or below
    /// this so the terminal removal can never underflow a segment.
    #[inline]
    pub(crate) fn min_inner(&self) -> usize {
        if self.inner_blocks() {
            (self.inner_cap() / 4).max(1)
        } else {
            2
        }
    }

    /// A fast-path erase (or any leaf-arity shrink outside a full descent)
    /// requires `>= erase_window` children: after the op at least
    /// `min_inner` remain, so no merge/borrow boost is ever needed off the
    /// descent path. Legacy 3.
    #[inline]
    pub(crate) fn erase_window(&self) -> usize {
        if self.inner_blocks() {
            self.min_inner() + 1
        } else {
            ERASE_WINDOW
        }
    }

    /// Validator hard ceiling: a split transient (`split_threshold`) plus
    /// the ~2 nodes lazy boundary repairs can briefly stack. Legacy 7.
    #[inline]
    pub(crate) fn max_arity(&self) -> usize {
        if self.inner_blocks() {
            self.split_threshold() + 2
        } else {
            MAX_ARITY
        }
    }

    /// Number of keys currently stored.
    pub fn len(&self) -> u64 {
        self.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> SkiplistStats {
        let mut out = SkiplistStats {
            splits: self.stats.splits.load(Ordering::Relaxed),
            merges: self.stats.merges.load(Ordering::Relaxed),
            borrows: self.stats.borrows.load(Ordering::Relaxed),
            depth_increases: self.stats.depth_increases.load(Ordering::Relaxed),
            depth_decreases: self.stats.depth_decreases.load(Ordering::Relaxed),
            find_retries: self.stats.find_retries.load(Ordering::Relaxed),
            write_retries: self.stats.write_retries.load(Ordering::Relaxed),
            finger_fallbacks: self.stats.finger_fallbacks.load(Ordering::Relaxed),
            ..SkiplistStats::default()
        };
        out.node_derefs = self.tallies.sum(TALLY_DEREFS);
        out.prefetches = self.tallies.sum(TALLY_PREFETCHES);
        out.finger_attempts = self.tallies.sum(TALLY_ATTEMPTS);
        out.finger_hits = self.tallies.sum(TALLY_HITS);
        out.stalled_derefs = self.tallies.sum(TALLY_STALLED);
        out
    }

    pub fn arena(&self) -> &NodeArena {
        &self.arena
    }

    /// §V arena accounting (allocs/recycled/capacity/locality), replica
    /// block arenas included once replication is enabled.
    pub fn mem_stats(&self) -> PoolStats {
        let mut out = self.arena.stats();
        if let Some(set) = self.replicas.get() {
            out.merge(&set.mem_stats());
        }
        out
    }

    // ------------------------------------------------------------------
    // NUMA-replicated index layers (ExecMode::Replicated)
    // ------------------------------------------------------------------

    /// Build one node-local index replica per engaged NUMA node and start
    /// routing replicated reads through them. Idempotent; best enabled at
    /// a write-quiet moment (e.g. after the fill phase) so the initial
    /// builds are exact.
    pub fn enable_replicas(&self, topo: &Topology, threads: usize) {
        self.replicas.get_or_init(|| ReplicaSet::new(self, topo, threads));
    }

    pub fn replicas_enabled(&self) -> bool {
        self.replicas.get().is_some()
    }

    /// Point lookup through the calling thread's node-local replica.
    /// Returns `(answer, fell_back)`: `fell_back` is `true` when the
    /// replica missed (or replication is off) and the shared index
    /// answered instead — the answer itself is always live-validated.
    pub fn get_replicated(&self, key: u64) -> (Option<u64>, bool) {
        let Some(set) = self.replicas.get() else {
            return (self.get(key), true);
        };
        match set.local().lookup(self, key) {
            ReplicaRead::Value(v) => (v, false),
            ReplicaRead::Miss => (self.get(key), true),
        }
    }

    /// Range scan seeded by the calling thread's node-local replica: the
    /// replica seeks the starting terminal chunk, the walk itself reads
    /// the shared terminal list (chunks are not replicated). Torn walks
    /// retry the replica seek a few times before falling back.
    pub fn range_replicated(&self, lo: u64, hi: u64) -> (Vec<(u64, u64)>, bool) {
        let Some(set) = self.replicas.get() else {
            return (self.range(lo, hi), true);
        };
        let rep = set.local();
        let mut cost = PathCost::default();
        for _ in 0..4 {
            let Some(start) = rep.seek(self, lo) else { break };
            if let Some(out) = self.range_walk(start, lo, hi, &mut cost) {
                self.flush_cost(&cost);
                return (out, false);
            }
        }
        self.flush_cost(&cost);
        (self.range(lo, hi), true)
    }

    /// One maintenance step on the calling thread's node-local replica
    /// (consume pending invalidations / rebuild if dirty). Returns `true`
    /// when that replica is clean afterwards. No-op without replication.
    pub fn replica_tick(&self) -> bool {
        match self.replicas.get() {
            Some(set) => set.local().maintain(self, set.log(), false),
            None => true,
        }
    }

    /// Force a full rebuild of **every** replica (tests / quiescent
    /// resync after deliberately starving the tick).
    pub fn replica_rebuild_all(&self) {
        if let Some(set) = self.replicas.get() {
            for r in set.replicas() {
                r.maintain(self, set.log(), true);
            }
        }
    }

    /// Merged replica-plane counters (zeroes when replication is off).
    pub fn replica_stats(&self) -> ReplicaStats {
        self.replicas.get().map(|s| s.stats()).unwrap_or_default()
    }

    /// Writer-side publication hook: every terminal-membership or boundary
    /// change notes the affected key so replicas can invalidate lazily.
    #[inline]
    fn replica_note(&self, key: u64) {
        if let Some(set) = self.replicas.get() {
            set.note(key);
        }
    }

    /// First terminal chunk of the live list (`Some(SENTINEL)` = empty,
    /// `None` = torn — retry). Replica rebuilds walk from here.
    pub(crate) fn first_terminal(&self) -> Option<NodeRef> {
        let mut cost = PathCost::default();
        let out = self.seek_terminal(0, &mut cost);
        self.flush_cost(&cost);
        out
    }

    /// Enable/disable the per-thread finger cache (enabled by default).
    /// Disabling it restores the pure top-down descent — the Table XII
    /// baseline.
    pub fn set_finger_cache(&self, on: bool) {
        self.fingers_on.store(on, Ordering::Relaxed);
    }

    pub fn finger_cache_enabled(&self) -> bool {
        self.fingers_on.load(Ordering::Relaxed)
    }

    /// Flush a per-op cost tally into this thread's padded counter line
    /// (one slot lookup per op; zero-count fields skip their `fetch_add`).
    #[inline]
    fn flush_cost(&self, cost: &PathCost) {
        let t = self.tallies.slot();
        t.0[TALLY_DEREFS].fetch_add(cost.derefs, Ordering::Relaxed);
        if cost.prefetches > 0 {
            t.0[TALLY_PREFETCHES].fetch_add(cost.prefetches, Ordering::Relaxed);
        }
        if cost.finger_attempts > 0 {
            t.0[TALLY_ATTEMPTS].fetch_add(cost.finger_attempts, Ordering::Relaxed);
        }
        if cost.finger_hits > 0 {
            t.0[TALLY_HITS].fetch_add(cost.finger_hits, Ordering::Relaxed);
        }
        if cost.stalled > 0 {
            t.0[TALLY_STALLED].fetch_add(cost.stalled, Ordering::Relaxed);
        }
    }

    // ------------------------------------------------------------------
    // Finger cache (per-thread, per-shard search fingers)
    // ------------------------------------------------------------------

    #[inline]
    fn finger_slot(&self) -> &FingerSlot {
        &self.fingers[thread_slot() & (self.fingers.len() - 1)]
    }

    /// Remember node `r` (level >= 1) as the descent's entry at its level,
    /// covering (predicted) inclusive key bounds `[lo, hi]`.
    #[inline]
    fn finger_record(&self, level: u32, r: NodeRef, lo: u64, hi: u64) {
        if level == 0 || level > FINGER_LEVELS as u32 || !self.fingers_on.load(Ordering::Relaxed) {
            return;
        }
        let s = self.finger_slot();
        let i = (level - 1) as usize;
        s.refs[i].store(r, Ordering::Relaxed);
        s.lo[i].store(lo, Ordering::Relaxed);
        s.hi[i].store(hi, Ordering::Relaxed);
    }

    /// Validate a finger entry as a safe descent start for `key`. Returns
    /// `(start, seg_lo)` where `seg_lo` is the proven inclusive lower bound
    /// (the first child's key).
    ///
    /// Safety argument: at the instant the second generation check passes,
    /// the node is live and unmarked, its key is `>= key`, and its first
    /// child's key is `<= key`. Since a node's segment covers
    /// `(prev sibling key, node.key]` and its first child's key is strictly
    /// greater than that lower bound, `key` provably lies inside the
    /// node's segment *at that instant* — so starting the lock-free find
    /// here is indistinguishable from a full descent that reached this node
    /// at that moment. Any interference afterwards is caught by the find
    /// loop's own generation/mark checks (RETRY → full descent), making a
    /// stale finger safe, never just slow-and-wrong.
    fn finger_start(&self, key: u64, cost: &mut PathCost) -> Option<(NodeRef, u64)> {
        let slot = self.finger_slot();
        let mut tried = 0;
        // deepest predicted-covering entry first: the deeper the start, the
        // more of the descent it skips
        for i in 0..FINGER_LEVELS {
            let r = slot.refs[i].load(Ordering::Relaxed);
            if r == SENTINEL || r == self.head {
                continue;
            }
            if !(slot.lo[i].load(Ordering::Relaxed) <= key
                && key <= slot.hi[i].load(Ordering::Relaxed))
            {
                continue;
            }
            tried += 1;
            cost.derefs += 2;
            if let Some(n) = self.arena.resolve(r) {
                if !n.is_marked() {
                    let (nkey, _) = n.key_next();
                    let bottom = n.hot.bottom.load(Ordering::Acquire);
                    let level = n.hot.level.load(Ordering::Relaxed);
                    if key <= nkey && bottom != SENTINEL {
                        // the proven lower bound: the first child's key — at
                        // a leaf, the first chunk's *min* key (`min_key <=
                        // key <= max_key` over the chunked segment)
                        let blo = if level == 1 {
                            self.arena.chunk_probe(bottom, key).map(|p| p.lo)
                        } else {
                            self.arena.read_key_next(bottom).map(|(bk, _)| bk)
                        };
                        if let Some(blo) = blo {
                            if blo <= key && !n.is_marked() && self.arena.resolve(r).is_some() {
                                return Some((r, blo));
                            }
                        }
                    }
                }
            }
            if tried >= 2 {
                break; // bound the validation cost of a cold/stale slot
            }
        }
        None
    }

    // ------------------------------------------------------------------
    // Height management (algorithms 3 and 6)
    // ------------------------------------------------------------------

    /// Algorithm 3: push the head's level down one if it gained a sibling.
    fn increase_depth(&self) {
        let head = self.arena.node(self.head);
        head.cold.lock.lock();
        let (hkey, hnext) = head.key_next();
        if hnext == SENTINEL {
            head.cold.lock.unlock();
            return;
        }
        let level = head.hot.level.load(Ordering::Relaxed);
        let hbot = head.hot.bottom.load(Ordering::Acquire);
        // d inherits the head's current (key, next, bottom) at the old level
        // — and therefore the head's routing block verbatim (both describe
        // the same child list, stable under the head's lock).
        let d = self.arena.alloc(hkey, hnext, hbot, 0, level);
        self.block_clone_into(d, self.head);
        head.hot.bottom.store(d, Ordering::Release);
        head.hot.level.store(level + 1, Ordering::Relaxed);
        if self.inner_blocks() {
            // Restore the root header and publish its one-child block
            // [(MAX, d)] in a single window: a reader pairing the restored
            // MAX header with the old block would conclude `Right` to
            // SENTINEL past every live key.
            let w = self.arena.block_write(self.head);
            head.set_key_next(u64::MAX, SENTINEL);
            w.set_key(0, u64::MAX);
            w.set_child(0, d);
            w.set_count(1);
        } else {
            head.set_key_next(u64::MAX, SENTINEL);
        }
        head.cold.lock.unlock();
        self.stats.depth_increases.fetch_add(1, Ordering::Relaxed);
    }

    /// Algorithm 6: collapse a root whose single child spans everything.
    fn decrease_depth(&self) {
        let head = self.arena.node(self.head);
        head.cold.lock.lock();
        let (hkey, hnext) = head.key_next();
        let level = head.hot.level.load(Ordering::Relaxed);
        if hnext != SENTINEL || level <= 1 {
            head.cold.lock.unlock();
            return;
        }
        let b = head.hot.bottom.load(Ordering::Acquire);
        if b == SENTINEL {
            head.cold.lock.unlock();
            return;
        }
        let bn = self.arena.node(b);
        bn.cold.lock.lock();
        let (bkey, bnext) = bn.key_next();
        let bb = bn.hot.bottom.load(Ordering::Acquire);
        // Collapse only when b is the sole child (key MAX), not terminal.
        if bkey == hkey && bnext == SENTINEL && bb != SENTINEL {
            head.hot.bottom.store(bb, Ordering::Release);
            head.hot.level.store(level - 1, Ordering::Relaxed);
            if self.inner_blocks() {
                // The root adopts b's children, so it adopts b's block (b
                // is locked, its block stable). The root header (MAX,
                // SENTINEL) is unchanged; readers pairing the old
                // [(MAX, b)] block with the new bottom still route through
                // b, which answers from frozen state until retired below.
                let w = self.arena.block_write(self.head);
                match self.arena.block_len(b) {
                    Some(cnt) => {
                        for i in 0..cnt {
                            w.set_key(i, self.arena.block_sep(b, i));
                            w.set_child(i, self.arena.block_child(b, i));
                        }
                        w.set_count(cnt);
                    }
                    None => w.set_count(0),
                }
            }
            bn.cold.mark.store(true, Ordering::Release);
            bn.cold.lock.unlock();
            self.arena.retire(b);
            self.stats.depth_decreases.fetch_add(1, Ordering::Relaxed);
        } else {
            bn.cold.lock.unlock();
        }
        head.cold.lock.unlock();
    }

    // ------------------------------------------------------------------
    // Shared helpers for writers (node + children locked)
    // ------------------------------------------------------------------

    /// Lock and collect the children of locked node `p` (the paper's
    /// `AcquireChildren`): the segment from `p.bottom` up to and including
    /// the first child with key >= p.key. Children cannot be retired while
    /// `p` is locked, so links resolve unconditionally. The next sibling's
    /// hot line is prefetched while the current child's lock is acquired.
    ///
    /// `Err` carries the already-locked prefix when the arity bound
    /// overflows (transiently over-wide segment): the caller must release
    /// those locks and retry the operation.
    fn acquire_children(
        &self,
        pkey: u64,
        pbottom: NodeRef,
        cost: &mut PathCost,
    ) -> Result<ChildVec, ChildVec> {
        let mut out = ChildVec::new();
        let mut d = pbottom;
        while d != SENTINEL {
            cost.derefs += 1;
            let dn = self.arena.node(d);
            dn.cold.lock.lock();
            let (dk, dnext) = dn.key_next();
            cost.prefetches += self.arena.prefetch(dnext) as u64;
            if dk > pkey {
                // Foreign boundary: this node already belongs to the next
                // parent (we are stale-high). Exclude it — CheckNodeKey will
                // lower our key and the operation moves right.
                dn.cold.lock.unlock();
                break;
            }
            if !out.push(d) {
                dn.cold.lock.unlock();
                return Err(out);
            }
            if dk == pkey {
                break;
            }
            d = dnext;
        }
        Ok(out)
    }

    fn release_children(&self, children: &[NodeRef]) {
        for &c in children {
            self.arena.node(c).cold.lock.unlock();
        }
    }

    /// Release children, retiring any that this operation marked (merge /
    /// drop-key victims). Children cannot be marked by other threads while
    /// their parent is locked, so every marked child here is ours.
    fn release_children_retiring(&self, children: &[NodeRef]) {
        for &c in children {
            let n = self.arena.node(c);
            let marked = n.is_marked();
            n.cold.lock.unlock();
            if marked {
                self.arena.retire(c);
            }
        }
    }

    // ------------------------------------------------------------------
    // Fat inner routing blocks — writer-side maintenance
    // ------------------------------------------------------------------
    //
    // A level >= 1 node's block is a *cache* of its child list: up to F
    // `(separator, child)` pairs behind the node's plane seqlock. The
    // maintenance discipline that keeps cached routing linearizable:
    //
    // 1. Separators may go stale-HIGH (a child's key was lowered after
    //    publication) but never stale-LOW: every child-key *raise* and
    //    every range takeover happens under the parent's lock, and the
    //    parent's block is retracted or republished around it. A reader
    //    routed by a stale-high separator lands at-or-left-of the correct
    //    child and recovers by the ordinary rightward walk; a stale-low
    //    separator could route *past* live coverage, which rightward-only
    //    walks cannot undo — that is the one forbidden state.
    // 2. Any header *raise* of a blocked node shares the block's seqlock
    //    window with the matching block rewrite: pairing a raised header
    //    with an older block would turn "all separators < key" into a
    //    false `Right` past live keys. (Lowering is one-sided-safe, but
    //    all header stores go through the window for a uniform proof.)
    // 3. Multi-step terminal mutations that cannot keep (1) true at every
    //    intermediate state first *retract* the block (count = 0): fresh
    //    readers then take the legacy linked child walk — exactly the
    //    fat-leaf protocol, already correct at every intermediate state —
    //    until the epilogue republishes. Readers holding a pre-retract
    //    block copy overlap the writer and route into marked-but-unretired
    //    victims, whose frozen state answers correctly until `retire`
    //    bumps the generation and forces their restart.

    /// Re-derive and publish locked `p`'s routing block from its live child
    /// list, optionally retargeting the packed `(key, next)` header inside
    /// the same seqlock window (discipline point 2 above). With blocks
    /// disabled this degrades to the plain header store.
    ///
    /// `p`'s lock pins the walk: children cannot be unlinked, retired, or
    /// key-raised concurrently (all require this lock); a concurrent
    /// child-local key *lowering* (finger-path `CheckNodeKey`) only makes a
    /// just-written separator stale-high, which routing tolerates.
    fn block_refresh(&self, p: NodeRef, header: Option<(u64, NodeRef)>) {
        let pn = self.arena.node(p);
        if !self.inner_blocks() {
            if let Some((k, nx)) = header {
                pn.set_key_next(k, nx);
            }
            return;
        }
        let w = self.arena.block_write(p);
        if let Some((k, nx)) = header {
            pn.set_key_next(k, nx);
        }
        let (pkey, _) = pn.key_next();
        let cap = self.inner_cap();
        let mut d = pn.hot.bottom.load(Ordering::Acquire);
        let mut n = 0usize;
        let mut over = false;
        while d != SENTINEL {
            let (dk, dnext) = self.arena.node(d).key_next();
            if dk > pkey {
                break; // foreign boundary (stale-high header): not ours
            }
            if n == cap {
                over = true;
                break;
            }
            w.set_key(n, dk);
            w.set_child(n, d);
            n += 1;
            if dk == pkey {
                break;
            }
            d = dnext;
        }
        if over {
            w.set_overflow();
        } else {
            w.set_count(n);
        }
    }

    /// Demote locked `p`'s routing block to *unbuilt* so every fresh reader
    /// takes the legacy linked child walk until [`Self::block_refresh`]
    /// republishes (discipline point 3 above).
    fn block_retract(&self, p: NodeRef) {
        if self.inner_blocks() {
            self.arena.block_write(p).set_count(0);
        }
    }

    /// Store a level >= 1 node's packed header through its block seqlock
    /// window (uniform header/block pairing — discipline point 2; plain
    /// store when blocks are disabled). For key *lowering* and pure `next`
    /// retargets only: raises must republish the block in the same window
    /// via [`Self::block_refresh`].
    fn set_header_windowed(&self, p: NodeRef, k: u64, nx: NodeRef) {
        if self.inner_blocks() {
            let _w = self.arena.block_write(p);
            self.arena.node(p).set_key_next(k, nx);
        } else {
            self.arena.node(p).set_key_next(k, nx);
        }
    }

    /// Build an *unpublished* level >= 1 node's routing block from its
    /// designated (locked, key-stable) children, before any pointer to the
    /// node is stored. Recycled plane slots hold stale bytes, so every
    /// fresh inner node must pass through here (or
    /// [`NodeArena::block_init_unbuilt`]) before publication.
    fn block_init_children(&self, nn: NodeRef, children: &[NodeRef]) {
        if !self.inner_blocks() {
            return;
        }
        if children.is_empty() || children.len() > self.inner_cap() {
            self.arena.block_init_unbuilt(nn);
            return;
        }
        let mut seps = [0u64; MAX_INNER_CAP];
        let mut childs = [SENTINEL; MAX_INNER_CAP];
        for (i, &c) in children.iter().enumerate() {
            seps[i] = self.arena.node(c).key();
            childs[i] = c;
        }
        self.arena.block_init(nn, &seps[..children.len()], &childs[..children.len()]);
    }

    /// Copy locked `src`'s routing block (or its unbuilt/overflow marker)
    /// into unpublished node `dst` — used when a node inherits another's
    /// child list wholesale (root height changes).
    fn block_clone_into(&self, dst: NodeRef, src: NodeRef) {
        if !self.inner_blocks() {
            return;
        }
        match self.arena.block_len(src) {
            Some(cnt) => {
                let mut seps = [0u64; MAX_INNER_CAP];
                let mut childs = [SENTINEL; MAX_INNER_CAP];
                for (i, (s, c)) in seps.iter_mut().zip(childs.iter_mut()).enumerate().take(cnt) {
                    *s = self.arena.block_sep(src, i);
                    *c = self.arena.block_child(src, i);
                }
                self.arena.block_init(dst, &seps[..cnt], &childs[..cnt]);
            }
            None => self.arena.block_init_unbuilt(dst),
        }
    }

    /// Opportunistically build locked `p`'s block if it is currently
    /// unbuilt or overflowed — writers call this on descent path nodes so
    /// blocks reach steady state without waiting for a structural change.
    fn block_build_if_missing(&self, p: NodeRef) {
        if self.inner_blocks() && self.arena.block_len(p).is_none() {
            self.block_refresh(p, None);
        }
    }

    /// Paper's `CheckNodeKey`: lower `p.key` to its last child's key if the
    /// child with the highest key was removed. `p` and children are locked.
    fn check_node_key(&self, p: NodeRef, children: &[NodeRef]) {
        if self.is_head(p) || children.is_empty() {
            return;
        }
        let pn = self.arena.node(p);
        let (pkey, pnext) = pn.key_next();
        if pkey == u64::MAX {
            return; // MAX-spine nodes cover (prev, MAX] by construction
        }
        let last = self.arena.node(*children.last().unwrap());
        let lk = last.key();
        if lk < pkey {
            // header lowering is a pure segment shrink (separators go
            // stale-high at worst) — windowed store only
            self.set_header_windowed(p, lk, pnext);
        }
    }

    /// Algorithm 2 (`AdditionRebalance`): split `p` if it has >=
    /// `split_threshold` children (legacy 5, else the block capacity F).
    /// `p` and `children` are locked. The new sibling takes `p`'s old
    /// `(key, next)` and the upper half of the children; `p` keeps the
    /// lower half and its last kept child's key. The sibling's routing
    /// block is built before publication; `p`'s header retarget and block
    /// shrink share one seqlock window (`block_refresh`).
    fn addition_rebalance(&self, p: NodeRef, children: &[NodeRef]) {
        if children.len() < self.split_threshold() {
            return;
        }
        let pn = self.arena.node(p);
        let (pkey, pnext) = pn.key_next();
        let level = pn.hot.level.load(Ordering::Relaxed);
        let lh = children.len() / 2;
        let nn = self.arena.alloc(pkey, pnext, children[lh], 0, level);
        self.block_init_children(nn, &children[lh..]);
        let c1key = self.arena.node(children[lh - 1]).key();
        self.block_refresh(p, Some((c1key, nn)));
        self.stats.splits.fetch_add(1, Ordering::Relaxed);
    }

    // ------------------------------------------------------------------
    // Finger fast path for terminal mutations
    // ------------------------------------------------------------------

    /// Attempt the leaf-finger fast path for a terminal insert/erase.
    /// `None` = conditions not met (caller runs the full descent); `Some`
    /// carries the operation's result.
    ///
    /// The fast path is confined to states where the mutation is purely
    /// segment-local:
    /// - the recorded leaf resolves (generation), is unmarked and level 1,
    ///   locked like any writer would lock it;
    /// - its (locked) chunks prove coverage:
    ///   `first_chunk.min_key <= key <= leaf.key`;
    /// - an *in-chunk* insert or erase never changes the leaf's arity, so it
    ///   needs no window at all — the common fat-leaf case;
    /// - a chunk split requires `<= INSERT_WINDOW` chunks (after the split
    ///   the leaf holds at most `SPLIT_THRESHOLD`, the same transient bound
    ///   the full descent leaves behind — and the next split-needing insert
    ///   into a leaf that wide falls back to the full descent, whose
    ///   `addition_rebalance` splits it on the way down);
    /// - emptying or underflowing a chunk (unlink / merge-borrow) requires
    ///   `>= ERASE_WINDOW` chunks (after the shrink at least 2 remain — no
    ///   leaf-level boost is ever needed).
    ///
    /// Under those guards the fast path can never split a leaf or underflow
    /// one, so ancestor arities only ever change on full descents and the
    /// paper's rebalance-on-the-way-down discipline is preserved.
    fn finger_write(&self, key: u64, op: FingerOp, cost: &mut PathCost) -> Option<bool> {
        let slot = self.finger_slot();
        let r = slot.refs[0].load(Ordering::Relaxed);
        if r == SENTINEL || r == self.head {
            return None;
        }
        if !(slot.lo[0].load(Ordering::Relaxed) <= key && key <= slot.hi[0].load(Ordering::Relaxed))
        {
            return None;
        }
        self.leaf_write_at(r, key, op, cost)
    }

    /// Attempt a segment-local terminal mutation on candidate leaf `r`
    /// under the fast-path guards documented on
    /// [`DetSkiplist::finger_write`] (resolve + lock + coverage proof +
    /// arity window). Shared by the finger fast path and by the interleaved
    /// engine once its lock-free descent lands on the covering leaf.
    /// `None` = guards not met; the caller runs the full writer descent.
    fn leaf_write_at(&self, r: NodeRef, key: u64, op: FingerOp, cost: &mut PathCost) -> Option<bool> {
        if r == self.head {
            // the head leaf needs the full descent's pending-height check
            return None;
        }
        cost.derefs += 1;
        let n = self.arena.resolve(r)?;
        n.cold.lock.lock();
        if n.is_marked()
            || self.arena.resolve(r).is_none()
            || n.hot.level.load(Ordering::Relaxed) != 1
        {
            n.cold.lock.unlock();
            return None;
        }
        let (nkey, _) = n.key_next();
        let bottom = n.hot.bottom.load(Ordering::Acquire);
        let children = match self.acquire_children(nkey, bottom, cost) {
            Ok(c) => c,
            Err(partial) => {
                self.release_children(&partial);
                n.cold.lock.unlock();
                return None;
            }
        };
        self.check_node_key(r, &children);
        let (nkey, _) = n.key_next(); // may have been lowered
        let covered = !children.is_empty() && {
            // chunk-min coverage proof: the first chunk's smallest key is
            // strictly above the previous leaf's max, so `min <= key <=
            // leaf.key` pins the key inside this leaf's segment
            let c0 = children[0];
            self.arena.chunk_count(c0) > 0 && self.arena.chunk_key(c0, 0) <= key && key <= nkey
        };
        if !covered {
            self.release_children(&children);
            n.cold.lock.unlock();
            return None;
        }
        let out = match op {
            FingerOp::Insert(v) => {
                // in-chunk inserts leave the arity untouched; a chunk split
                // adds one sibling, licensed only inside the insert window
                let t = self.add_terminal(r, &children, key, v, children.len() <= self.insert_window());
                if t != Tri::Retry {
                    // refresh the leaf finger with post-op live bounds
                    let (nk2, _) = n.key_next();
                    self.finger_record(1, r, self.arena.chunk_key(children[0], 0), nk2);
                }
                self.release_children(&children);
                t
            }
            FingerOp::Erase => {
                // children[0] always survives drop_key (first-chunk removal
                // is delete-by-copy; rebuilds mark the right-hand sibling)
                let t = self.drop_key(r, &children, key, children.len() >= self.erase_window());
                if t != Tri::Retry {
                    let (nk2, _) = n.key_next();
                    self.finger_record(1, r, self.arena.chunk_key(children[0], 0), nk2);
                }
                self.release_children_retiring(&children);
                t
            }
        };
        n.cold.lock.unlock();
        match out {
            Tri::True => Some(true),
            Tri::False => Some(false),
            // the op needs a split/unlink/rebuild its window forbids here:
            // decline, and the full writer descent rebalances on the way down
            Tri::Retry => None,
        }
    }

    // ------------------------------------------------------------------
    // Addition (algorithm 1)
    // ------------------------------------------------------------------

    /// Insert `key -> value`. Returns `false` if the key already exists.
    pub fn insert(&self, key: u64, value: u64) -> bool {
        assert!(key <= MAX_KEY, "key {key} reserved for sentinels");
        let mut cost = PathCost::default();
        let inserted = 'result: {
            if self.fingers_on.load(Ordering::Relaxed) {
                cost.finger_attempts += 1;
                if let Some(ok) = self.finger_write(key, FingerOp::Insert(value), &mut cost) {
                    cost.finger_hits += 1;
                    break 'result ok;
                }
            }
            let mut b = Backoff::new();
            loop {
                match self.addition(self.head, key, value, &mut cost) {
                    Tri::True => break 'result true,
                    Tri::False => break 'result false,
                    Tri::Retry => {
                        self.stats.write_retries.fetch_add(1, Ordering::Relaxed);
                        self.increase_depth();
                        b.wait();
                    }
                }
            }
        };
        if inserted {
            self.len.fetch_add(1, Ordering::Relaxed);
        }
        self.flush_cost(&cost);
        inserted
    }

    fn addition(&self, nref: NodeRef, key: u64, value: u64, cost: &mut PathCost) -> Tri {
        if nref == SENTINEL {
            return Tri::Retry; // fell off the structure; restart
        }
        cost.derefs += 1;
        let Some(n) = self.arena.resolve(nref) else {
            return Tri::Retry;
        };
        n.cold.lock.lock();
        if n.is_marked() || self.arena.resolve(nref).is_none() {
            n.cold.lock.unlock();
            return Tri::Retry;
        }
        let (nkey, nnext) = n.key_next();
        if self.is_head(nref) && nnext != SENTINEL {
            n.cold.lock.unlock();
            return Tri::Retry; // height increase pending (alg 3)
        }
        let nbottom = n.hot.bottom.load(Ordering::Acquire);
        let children = match self.acquire_children(nkey, nbottom, cost) {
            Ok(c) => c,
            Err(partial) => {
                self.release_children(&partial);
                n.cold.lock.unlock();
                return Tri::Retry; // over-wide segment: retry after help
            }
        };
        self.check_node_key(nref, &children);
        let (nkey, nnext) = n.key_next(); // may have been lowered

        if nkey < key {
            // Move right.
            self.release_children(&children);
            n.cold.lock.unlock();
            return self.addition(nnext, key, value, cost);
        }

        self.addition_rebalance(nref, &children);
        self.block_build_if_missing(nref);
        let level = n.hot.level.load(Ordering::Relaxed);

        // record the descent entry at this level for the finger cache
        if !self.is_head(nref) && !children.is_empty() {
            self.finger_record(level, nref, self.child_lo(level, children[0]), nkey);
        }

        if level == 1 {
            // Leaf: insert into the covering terminal chunk (paper's
            // AddNode, per-chunk). Full descents always license the split.
            let r = self.add_terminal(nref, &children, key, value, true);
            self.release_children(&children);
            n.cold.lock.unlock();
            return r;
        }

        // Descend into the first child whose key covers `key`.
        let mut target = None;
        for &c in children.iter() {
            if key <= self.arena.node(c).key() {
                target = Some(c);
                break;
            }
        }
        self.release_children(&children);
        n.cold.lock.unlock();
        match target {
            Some(c) => self.addition(c, key, value, cost),
            // Can only happen transiently (concurrent restructure): retry.
            None => Tri::Retry,
        }
    }

    /// The finger/carry lower-bound predictor for a node's first child: at
    /// a leaf the first *chunk's* min key (chunk-min coverage), above it the
    /// first child's key. Caller holds the child's lock (or its parent's).
    #[inline]
    fn child_lo(&self, level: u32, first_child: NodeRef) -> u64 {
        if level == 1 && self.arena.chunk_count(first_child) > 0 {
            self.arena.chunk_key(first_child, 0)
        } else {
            self.arena.node(first_child).key()
        }
    }

    /// Insert `key -> value` into the covering terminal chunk of locked
    /// leaf `p` (whose chunks, also locked, are `children`).
    ///
    /// - Duplicate key: `False`.
    /// - Room in the chunk: shift the arrays inside a seqlock window; an
    ///   append past the last chunk's max raises the packed `(max, next)`
    ///   header inside the same window.
    /// - Chunk full: 1-2-3-4 split *with the new key included* — the high
    ///   half moves to a freshly allocated sibling chunk published by the
    ///   left chunk's in-window header store (both halves hold ≥ (K+1)/2 ≥
    ///   max(1, K/4) keys, so splits never create underfull chunks). Needs
    ///   `allow_split` (full descents pass `true`; the fast paths gate it
    ///   on the leaf's insert window and treat `Retry` as a decline).
    fn add_terminal(
        &self,
        p: NodeRef,
        children: &[NodeRef],
        key: u64,
        value: u64,
        allow_split: bool,
    ) -> Tri {
        let pn = self.arena.node(p);
        let cap = self.arena.leaf_cap();
        if children.is_empty() {
            // empty (head) leaf: the structure's first chunk
            let t = self.arena.alloc_chunk(&[key], &[value], SENTINEL);
            pn.hot.bottom.store(t, Ordering::Release);
            self.block_refresh(p, None);
            self.replica_note(key);
            return Tri::True;
        }
        // target: first chunk whose max covers the key, else the last (an
        // append raises that chunk's max rather than growing the arity)
        let mut ti = children.len() - 1;
        for (j, &c) in children.iter().enumerate() {
            if key <= self.arena.node(c).key() {
                ti = j;
                break;
            }
        }
        let t = children[ti];
        let tn = self.arena.node(t);
        let mut keys = [0u64; MAX_LEAF_CAP];
        let cnt = self.arena.chunk_keys_into(t, &mut keys);
        let pos = simd::rank(&keys[..cnt], key);
        if pos < cnt && keys[pos] == key {
            return Tri::False; // duplicate
        }
        let (_, tnext) = tn.key_next();
        // An append beyond the target chunk's max raises that chunk's key
        // past every separator stored in the leaf's routing block, which a
        // block-routed reader would answer with a false `Right`. Retract
        // the block first (fresh readers take the linked walk), republish
        // after the mutation completes.
        let raising = pos == cnt;
        if raising {
            self.block_retract(p);
        }
        if cnt < cap {
            // in-chunk insert: no arity change, no window needed
            {
                let w = self.arena.chunk_write(t);
                for j in (pos..cnt).rev() {
                    w.set_key(j + 1, w.key(j));
                    w.set_val(j + 1, w.val(j));
                }
                w.set_key(pos, key);
                w.set_val(pos, value);
                w.set_count(cnt + 1);
                if pos == cnt {
                    // append beyond the old max (last chunk only): raise the
                    // routing header atomically with the array it describes
                    tn.set_key_next(key, tnext);
                }
            }
            if raising {
                self.block_refresh(p, None);
                // the chunk's routing max moved: invalidate both the old
                // boundary (stale replica separator) and the new one
                self.replica_note(keys[cnt - 1]);
                self.replica_note(key);
            }
            return Tri::True;
        }
        if !allow_split {
            if raising {
                // nothing was mutated; rebuild the block we retracted
                self.block_refresh(p, None);
            }
            return Tri::Retry; // splits belong to full descents
        }
        // split with the new key included among the K+1
        let mut ks = [0u64; MAX_LEAF_CAP + 1];
        let mut vs = [0u64; MAX_LEAF_CAP + 1];
        for j in 0..cnt {
            ks[j] = keys[j];
            vs[j] = self.arena.chunk_val(t, j);
        }
        let mut j = cnt;
        while j > pos {
            ks[j] = ks[j - 1];
            vs[j] = vs[j - 1];
            j -= 1;
        }
        ks[pos] = key;
        vs[pos] = value;
        let total = cnt + 1;
        let lh = total / 2;
        // the new right chunk is initialized before the left chunk's
        // in-window header store publishes it (release-ordered)
        let nr = self.arena.alloc_chunk(&ks[lh..total], &vs[lh..total], tnext);
        {
            let w = self.arena.chunk_write(t);
            for j in 0..lh {
                w.set_key(j, ks[j]);
                w.set_val(j, vs[j]);
            }
            w.set_count(lh);
            tn.set_key_next(ks[lh - 1], nr);
        }
        // membership grew by one (and `raising` was retracted above):
        // republish the leaf's routing block over the post-split chunks
        self.block_refresh(p, None);
        // new chunk boundary at ks[lh-1]; the right chunk keeps (or, when
        // raising, takes) the high max ks[total-1]
        self.replica_note(ks[lh - 1]);
        self.replica_note(ks[total - 1]);
        Tri::True
    }

    // ------------------------------------------------------------------
    // Find (algorithm 4)
    // ------------------------------------------------------------------

    /// Lookup: returns the value if present.
    pub fn get(&self, key: u64) -> Option<u64> {
        let mut cost = PathCost::default();
        let out = self.get_inner(key, &mut cost);
        self.flush_cost(&cost);
        out
    }

    fn get_inner(&self, key: u64, cost: &mut PathCost) -> Option<u64> {
        // finger fast path: start the lock-free descent at the deepest
        // validated entry of this thread's last descent
        if self.mode == FindMode::LockFree && self.fingers_on.load(Ordering::Relaxed) {
            cost.finger_attempts += 1;
            if let Some((start, seg_lo)) = self.finger_start(key, cost) {
                if let Ok(v) = self.find_lockfree_from(start, seg_lo, key, cost) {
                    // a hit = the op genuinely skipped the full descent
                    cost.finger_hits += 1;
                    return v;
                }
                // the finger raced a restructure mid-traversal: fall back to
                // a full top-down descent (correctness never depended on it).
                // Counted separately from find_retries, whose pre-finger
                // meaning (traversal interference) must stay comparable.
                self.stats.finger_fallbacks.fetch_add(1, Ordering::Relaxed);
            }
        }
        let mut b = Backoff::new();
        loop {
            let r = match self.mode {
                FindMode::LockFree => self.find_lockfree_from(self.head, 0, key, cost),
                FindMode::ReadLocked => self.find_readlocked(key, cost),
            };
            match r {
                Ok(v) => return v,
                Err(()) => {
                    self.stats.find_retries.fetch_add(1, Ordering::Relaxed);
                    // help pending height changes, then retry
                    if self.arena.node(self.head).next() != SENTINEL {
                        self.increase_depth();
                    }
                    b.wait();
                }
            }
        }
    }

    pub fn contains(&self, key: u64) -> bool {
        self.get(key).is_some()
    }

    /// One lock-free traversal attempt from `start` (the head, or a
    /// validated finger entry whose proven segment lower bound is
    /// `seg_lo`). `Err(())` = RETRY.
    ///
    /// While a node is examined, its `next` and `bottom` hot lines are
    /// prefetched — the two dependent misses of the descent overlap instead
    /// of serializing. The descent path is recorded into the per-thread
    /// finger slot as it goes.
    fn find_lockfree_from(
        &self,
        start: NodeRef,
        start_lo: u64,
        key: u64,
        cost: &mut PathCost,
    ) -> Result<Option<u64>, ()> {
        let mut cur = start;
        let mut seg_lo = start_lo; // inclusive lower bound of cur's coverage
        loop {
            if cur == SENTINEL {
                return Ok(None);
            }
            cost.derefs += 1;
            let Some(n) = self.arena.resolve(cur) else {
                return Err(());
            };
            if n.is_marked() {
                return Err(());
            }
            let (nkey, nnext) = n.key_next();
            let bottom = n.hot.bottom.load(Ordering::Acquire);
            // validate the snapshot was taken while `cur` was live
            if self.arena.resolve(cur).is_none() {
                return Err(());
            }
            // overlap the next dependent misses with this node's processing;
            // the paired plane prefetch pulls the first child's data row
            // (terminal chunk on leaf approach, routing block above it)
            cost.prefetches += self.arena.prefetch(nnext) as u64
                + self.arena.prefetch(bottom) as u64
                + self.arena.prefetch_plane(bottom) as u64;
            if self.is_head(cur) && nnext != SENTINEL {
                return Err(()); // height change pending
            }
            if bottom == SENTINEL && !self.is_head(cur) {
                // terminal chunk: branchless in-chunk rank via the seqlock
                // snapshot (simd::rank inside chunk_probe)
                let Some(p) = self.arena.chunk_probe(cur, key) else {
                    return Err(()); // torn snapshot / generation changed
                };
                if key <= p.max {
                    // In-coverage answer (hit or proven miss). Chunk data is
                    // mutable, so the probe window may postdate the mark
                    // check above — unmarked *after* the window proves the
                    // data was live.
                    if n.is_marked() || self.arena.resolve(cur).is_none() {
                        return Err(());
                    }
                    return Ok(p.hit);
                }
                cost.prefetches += self.arena.prefetch_plane(p.next) as u64;
                cur = p.next;
                continue;
            }
            if self.is_head(cur) && bottom == SENTINEL {
                return Ok(None); // empty structure
            }
            if nkey < key {
                seg_lo = nkey.wrapping_add(1);
                cur = nnext;
                continue;
            }
            // remember this level's entry for the next nearby search
            if !self.is_head(cur) {
                self.finger_record(n.hot.level.load(Ordering::Relaxed), cur, seg_lo, nkey);
            }
            // Fat inner nodes: one seqlock-consistent block probe (header +
            // separators + children read in a single window, SIMD rank)
            // replaces the per-child linked walk. `Fallback` (unbuilt /
            // overflowed / disabled) keeps the legacy walk below.
            if self.inner_blocks() {
                match self.arena.block_route(cur, key) {
                    Some(BlockRoute::Descend { child, sep_lo, .. }) => {
                        cost.derefs += 1;
                        cost.prefetches += self.arena.prefetch(child) as u64
                            + self.arena.prefetch_plane(child) as u64;
                        if let Some(s) = sep_lo {
                            // separators are never stale-low, so `s + 1`
                            // only ever narrows the finger's predicted span
                            seg_lo = s.wrapping_add(1);
                        }
                        cur = child;
                        continue;
                    }
                    Some(BlockRoute::Right { nkey, next }) => {
                        // every separator (hence every child) tops out
                        // below `key`: the subtree cannot cover it
                        cost.derefs += 1;
                        seg_lo = nkey.wrapping_add(1);
                        cur = next;
                        continue;
                    }
                    Some(BlockRoute::Fallback { .. }) => {}
                    None => return Err(()), // torn block / generation changed
                }
            }
            // collect children lock-free; stop at first covering child
            let mut d = bottom;
            let mut target = None;
            let mut child_lo = seg_lo;
            loop {
                if d == SENTINEL {
                    break;
                }
                cost.derefs += 1;
                let Some((dk, dn)) = self.arena.read_key_next(d) else {
                    return Err(());
                };
                let dnode = self.arena.node(d);
                if dnode.is_marked() || n.is_marked() {
                    return Err(());
                }
                if key <= dk {
                    target = Some(d);
                    break;
                }
                cost.prefetches += self.arena.prefetch(dn) as u64;
                child_lo = dk.wrapping_add(1);
                if dk >= nkey {
                    break; // boundary child passed without covering `key`
                }
                d = dn;
            }
            match target {
                // Descending into a foreign boundary child (key > nkey,
                // stale-high parent) is correct: the gap (last child, nkey]
                // belongs to the next parent's first subtree.
                Some(t) => {
                    seg_lo = child_lo;
                    cur = t;
                }
                // No cover: every child key < key, so this subtree's max is
                // below `key` — continue right (paper: "the search can
                // continue to the right").
                None => {
                    seg_lo = nkey.wrapping_add(1);
                    cur = nnext;
                }
            }
        }
    }

    /// RWL baseline: hand-over-hand shared locks.
    fn find_readlocked(&self, key: u64, cost: &mut PathCost) -> Result<Option<u64>, ()> {
        let mut cur = self.head;
        let mut held: Option<NodeRef> = None;
        let r = self.find_readlocked_inner(&mut cur, &mut held, key, cost);
        if let Some(h) = held {
            self.arena.node(h).cold.lock.unlock_shared();
        }
        r
    }

    fn find_readlocked_inner(
        &self,
        cur: &mut NodeRef,
        held: &mut Option<NodeRef>,
        key: u64,
        cost: &mut PathCost,
    ) -> Result<Option<u64>, ()> {
        // lock the starting node
        let n0 = self.arena.node(*cur);
        n0.cold.lock.lock_shared();
        *held = Some(*cur);
        loop {
            let curref = (*held).unwrap();
            cost.derefs += 1;
            let n = self.arena.node(curref);
            if n.is_marked() || self.arena.resolve(curref).is_none() {
                return Err(());
            }
            let (nkey, nnext) = n.key_next();
            if self.is_head(curref) && nnext != SENTINEL {
                return Err(());
            }
            let bottom = n.hot.bottom.load(Ordering::Acquire);
            if bottom == SENTINEL && !self.is_head(curref) {
                // terminal chunk: the shared lock blocks chunk writers (they
                // hold the exclusive lock), so no post-window mark re-check
                // is needed here
                let Some(p) = self.arena.chunk_probe(curref, key) else {
                    return Err(());
                };
                if key <= p.max {
                    return Ok(p.hit);
                }
                if !self.step_read(held, p.next)? {
                    return Ok(None);
                }
                continue;
            }
            if self.is_head(curref) && bottom == SENTINEL {
                return Ok(None);
            }
            if nkey < key {
                if !self.step_read(held, nnext)? {
                    return Ok(None);
                }
                continue;
            }
            // walk children under the parent's read lock (children cannot be
            // restructured without the parent's write lock for terminals, and
            // child-level writers lock the child itself — take its read lock
            // before stepping down).
            let mut d = bottom;
            let mut target = None;
            while d != SENTINEL {
                cost.derefs += 1;
                let dn = self.arena.node(d);
                let (dk, dnext) = dn.key_next();
                if key <= dk {
                    target = Some(d);
                    break;
                }
                if dk >= nkey {
                    break;
                }
                d = dnext;
            }
            match target {
                Some(t) => {
                    if !self.step_read(held, t)? {
                        return Ok(None);
                    }
                }
                // no cover: subtree max < key — continue right
                None => {
                    if !self.step_read(held, nnext)? {
                        return Ok(None);
                    }
                }
            }
        }
    }

    /// Move the single shared lock from `held` to `to` (hand-over-hand).
    fn step_read(&self, held: &mut Option<NodeRef>, to: NodeRef) -> Result<bool, ()> {
        if to == SENTINEL {
            if let Some(h) = held.take() {
                self.arena.node(h).cold.lock.unlock_shared();
            }
            return Ok(false);
        }
        let tn = self.arena.node(to);
        tn.cold.lock.lock_shared();
        if let Some(h) = held.take() {
            self.arena.node(h).cold.lock.unlock_shared();
        }
        *held = Some(to);
        if self.arena.resolve(to).is_none() || tn.is_marked() {
            return Err(());
        }
        Ok(true)
    }

    // ------------------------------------------------------------------
    // Deletion (algorithm 5 + the paper's prose)
    // ------------------------------------------------------------------

    /// Remove `key`. Returns `false` if it was not present.
    pub fn erase(&self, key: u64) -> bool {
        let mut cost = PathCost::default();
        let erased = 'result: {
            if self.fingers_on.load(Ordering::Relaxed) {
                cost.finger_attempts += 1;
                if let Some(ok) = self.finger_write(key, FingerOp::Erase, &mut cost) {
                    cost.finger_hits += 1;
                    break 'result ok;
                }
            }
            let mut b = Backoff::new();
            loop {
                match self.deletion(self.head, key, &mut cost) {
                    Tri::True => break 'result true,
                    Tri::False => break 'result false,
                    Tri::Retry => {
                        self.stats.write_retries.fetch_add(1, Ordering::Relaxed);
                        self.increase_depth();
                        self.maybe_decrease_depth();
                        b.wait();
                    }
                }
            }
        };
        if erased {
            self.len.fetch_sub(1, Ordering::Relaxed);
            // opportunistic height collapse (cheap check first) — on the
            // finger fast path too, so heavy nearby-erase phases still shrink
            self.maybe_decrease_depth();
        }
        self.flush_cost(&cost);
        erased
    }

    fn maybe_decrease_depth(&self) {
        let head = self.arena.node(self.head);
        if head.hot.level.load(Ordering::Relaxed) <= 1 {
            return;
        }
        let b = head.hot.bottom.load(Ordering::Acquire);
        if b == SENTINEL {
            return;
        }
        if let Some((bk, bn)) = self.arena.read_key_next(b) {
            if bk == u64::MAX && bn == SENTINEL {
                self.decrease_depth();
            }
        }
    }

    fn deletion(&self, nref: NodeRef, key: u64, cost: &mut PathCost) -> Tri {
        if nref == SENTINEL {
            return Tri::Retry;
        }
        cost.derefs += 1;
        let Some(n) = self.arena.resolve(nref) else {
            return Tri::Retry;
        };
        n.cold.lock.lock();
        if n.is_marked() || self.arena.resolve(nref).is_none() {
            n.cold.lock.unlock();
            return Tri::Retry;
        }
        let (nkey, nnext) = n.key_next();
        if self.is_head(nref) && nnext != SENTINEL {
            n.cold.lock.unlock();
            return Tri::Retry;
        }
        let nbottom = n.hot.bottom.load(Ordering::Acquire);
        let children = match self.acquire_children(nkey, nbottom, cost) {
            Ok(c) => c,
            Err(partial) => {
                self.release_children(&partial);
                n.cold.lock.unlock();
                return Tri::Retry; // over-wide segment: retry after help
            }
        };
        self.check_node_key(nref, &children);
        let (nkey, nnext) = n.key_next();

        if nkey < key {
            self.release_children(&children);
            n.cold.lock.unlock();
            return self.deletion(nnext, key, cost);
        }

        let level = n.hot.level.load(Ordering::Relaxed);
        self.block_build_if_missing(nref);

        // record the descent entry at this level for the finger cache
        if !self.is_head(nref) && !children.is_empty() {
            self.finger_record(level, nref, self.child_lo(level, children[0]), nkey);
        }

        if level == 1 {
            let r = self.drop_key(nref, &children, key, true);
            self.release_children_retiring(&children);
            n.cold.lock.unlock();
            return r;
        }

        // Choose the covering child and (if it needs boosting) a partner.
        let mut idx = None;
        for (i, &c) in children.iter().enumerate() {
            if key <= self.arena.node(c).key() {
                idx = Some(i);
                break;
            }
        }
        let Some(i) = idx else {
            self.release_children(&children);
            n.cold.lock.unlock();
            return Tri::False; // key beyond every child: not present
        };

        let target = children[i];
        let Some(tchildren) = self.count_children(target, cost) else {
            // arity overflow while counting: retry the whole operation
            self.release_children(&children);
            n.cold.lock.unlock();
            return Tri::Retry;
        };
        let mut descend = target;

        if tchildren == 0 {
            // transient/corrupt view; retry
            self.release_children(&children);
            n.cold.lock.unlock();
            return Tri::Retry;
        }
        if tchildren <= self.min_inner() && children.len() >= 2 {
            // Boost via merge/borrow with a sibling (alg 5). Pair is always
            // (left, right) = adjacent children of n; merge removes the
            // RIGHT node so the parent's bottom link never dangles.
            let (li, ri) = if i > 0 { (i - 1, i) } else { (i, i + 1) };
            if ri < children.len() {
                let merged = self.merge_borrow(children[li], children[ri], key, cost);
                // membership/keys below changed: republish n's block over
                // the post-boost child list (the merge victim routes from
                // frozen state until retired at release below)
                self.block_refresh(nref, None);
                descend = merged;
            }
        }

        self.release_children_retiring(&children);
        n.cold.lock.unlock();
        self.deletion(descend, key, cost)
    }

    /// Count the children of locked node `c` (no locks needed: mutating
    /// `c`'s child list requires `c`'s lock, which we hold). `None` on
    /// arity overflow (caller retries).
    fn count_children(&self, c: NodeRef, cost: &mut PathCost) -> Option<usize> {
        self.collect_children(c, cost).map(|v| v.len())
    }

    /// Algorithm 5: merge the pair `(n1, n2)` (both locked children of the
    /// current node; `n2 = n1.next`) and optionally re-split ("borrow") if
    /// the pair's combined arity exceeds `2 * min_inner` (legacy: the
    /// donor side had more than 2 children — identical gate, since legacy
    /// `2 * min_inner == INSERT_WINDOW`). Returns the node now covering
    /// `key`.
    ///
    /// Block discipline: the takeover raises `n1`'s key, so it rides
    /// `block_refresh` (header + block in one window). A reader holding the
    /// parent's pre-refresh block still routes `n1`'s absorbed range to
    /// `n2`, whose frozen children answer correctly until the caller's
    /// release loop retires it.
    fn merge_borrow(&self, n1: NodeRef, n2: NodeRef, key: u64, cost: &mut PathCost) -> NodeRef {
        let n1n = self.arena.node(n1);
        let n2n = self.arena.node(n2);
        let (n1key, n1next) = n1n.key_next();
        debug_assert_eq!(n1next, n2, "pair must be adjacent");
        let (c1, c2) = match (self.collect_children(n1, cost), self.collect_children(n2, cost)) {
            (Some(a), Some(b)) => (a, b),
            // Transiently over-wide sibling: skip the boost. The deletion
            // still descends into the covering child; the next writer pass
            // through this segment rebalances it.
            _ => return if key <= n1key { n1 } else { n2 },
        };
        let floor = self.min_inner();
        let target_left = key <= n1key;
        let need = (target_left && c1.len() <= floor) || (!target_left && c2.len() <= floor);
        if !need {
            return if target_left { n1 } else { n2 };
        }

        // merge: n1 absorbs n2 (atomic (key,next) takeover), n2 retires.
        let (n2key, n2next) = n2n.key_next();
        let level = n1n.hot.level.load(Ordering::Relaxed);
        self.block_refresh(n1, Some((n2key, n2next)));
        n2n.cold.mark.store(true, Ordering::Release);
        self.stats.merges.fetch_add(1, Ordering::Relaxed);

        let merged_len = c1.len() + c2.len();
        let mut result = n1;
        if merged_len > 2 * floor {
            // borrow: re-split so the target side keeps >= min_inner + 1
            // children (the upcoming removal cannot underflow it) and the
            // donor keeps >= min_inner.
            self.stats.borrows.fetch_add(1, Ordering::Relaxed);
            if self.inner_blocks() {
                // generalized F-aware re-split: bias the extra child (odd
                // totals) toward the target side
                let lh = if target_left { merged_len.div_ceil(2) } else { merged_len / 2 };
                let mut all = ChildVec::new();
                if c1.iter().chain(c2.iter()).all(|&c| all.push(c)) {
                    let nn = self.arena.alloc(n2key, n2next, all[lh], 0, level);
                    self.block_init_children(nn, &all[lh..]);
                    let bk = self.arena.node(all[lh - 1]).key();
                    self.block_refresh(n1, Some((bk, nn)));
                    result = if key <= bk { n1 } else { nn };
                }
                // combined list over-wide (cannot happen with both sides
                // within the validator ceiling): stay merged, no re-split
            } else if target_left {
                // legacy: target was n1 (2 children); give it c2[0], new
                // node nn takes c2[1..].
                let nn = self.arena.alloc(n2key, n2next, c2[1], 0, level);
                let bk = self.arena.node(c2[0]).key();
                n1n.set_key_next(bk, nn);
                result = if key <= bk { n1 } else { nn };
            } else {
                // legacy: target was n2 (2 children); nn takes n1's last
                // child plus n2's children.
                let p = c1.len();
                let nn = self.arena.alloc(n2key, n2next, c1[p - 1], 0, level);
                let bk = self.arena.node(c1[p - 2]).key();
                n1n.set_key_next(bk, nn);
                result = if key <= bk { n1 } else { nn };
            }
        }
        // n2 stays locked and marked; the caller's release loop unlocks and
        // retires it (release_children_retiring).
        result
    }

    /// Child refs of locked node `c`, without locking them (mutating `c`'s
    /// child list requires `c`'s lock, which the caller holds). Foreign
    /// boundary nodes (key > c.key) are excluded — see `acquire_children`.
    /// `None` on arity overflow (caller retries or skips the rebalance).
    fn collect_children(&self, c: NodeRef, cost: &mut PathCost) -> Option<ChildVec> {
        let cn = self.arena.node(c);
        let ckey = cn.key();
        let mut out = ChildVec::new();
        let mut d = cn.hot.bottom.load(Ordering::Acquire);
        while d != SENTINEL {
            cost.derefs += 1;
            let (dk, dn) = self.arena.node(d).key_next();
            if dk > ckey {
                break;
            }
            if !out.push(d) {
                return None;
            }
            if dk == ckey {
                break;
            }
            d = dn;
        }
        Some(out)
    }

    /// Remove `key` from the covering terminal chunk of locked leaf `p`
    /// (chunks locked).
    ///
    /// In-chunk removal shifts the arrays left inside a seqlock window;
    /// removing the chunk's max lowers the packed `(max, next)` header in
    /// the same window (and syncs the leaf key if it was the leaf max).
    /// A removal that would empty the chunk unlinks it (predecessor bypass,
    /// delete-by-copy of the successor chunk's full contents when it is the
    /// segment's first chunk, or the head-leaf bottom store); one that would
    /// drop it below `min_chunk_occupancy` triggers [`Self::chunk_rebuild`]
    /// (1-2-3-4 merge/borrow at chunk granularity). Both structural moves
    /// need `allow_shrink` — the shrink decision is taken BEFORE any
    /// mutation, so a declined (`Retry`) op leaves the structure untouched
    /// for the full-descent retry.
    fn drop_key(&self, p: NodeRef, children: &[NodeRef], key: u64, allow_shrink: bool) -> Tri {
        let pn = self.arena.node(p);
        let min_occ = self.min_chunk_occupancy();
        // target: first chunk whose max covers the key
        let mut ti = usize::MAX;
        for (j, &c) in children.iter().enumerate() {
            if key <= self.arena.node(c).key() {
                ti = j;
                break;
            }
        }
        if ti == usize::MAX {
            return Tri::False; // key beyond every chunk
        }
        let t = children[ti];
        let tn = self.arena.node(t);
        let mut keys = [0u64; MAX_LEAF_CAP];
        let cnt = self.arena.chunk_keys_into(t, &mut keys);
        let pos = simd::rank(&keys[..cnt], key);
        if pos >= cnt || keys[pos] != key {
            return Tri::False;
        }
        let (_, tnext) = tn.key_next();
        let newcnt = cnt - 1;
        let needs_shrink = newcnt == 0 || (newcnt < min_occ && children.len() >= 2);
        if needs_shrink && !allow_shrink {
            return Tri::Retry; // structural shrink belongs to full descents
        }

        if newcnt == 0 {
            // the chunk empties: unlink it from the terminal list. Stale
            // block copies still routing to the victim hit its mark and
            // retry; the refresh below re-points fresh readers.
            if ti > 0 {
                // predecessor bypass
                let prn = self.arena.node(children[ti - 1]);
                let (prk, _) = prn.key_next();
                prn.set_key_next(prk, tnext);
                tn.cold.mark.store(true, Ordering::Release);
                // keep p.key in sync if we removed the last chunk
                if ti == children.len() - 1 {
                    let (pk, pnx) = pn.key_next();
                    if pk == key && !self.is_head(p) {
                        self.set_header_windowed(p, prk, pnx);
                    }
                }
            } else if children.len() > 1 {
                // first chunk: delete-by-copy — absorb the successor chunk's
                // full contents so the leaf's bottom link never dangles
                let s = children[1];
                let sn = self.arena.node(s);
                let (sk, snext) = sn.key_next();
                let mut sk_buf = [0u64; MAX_LEAF_CAP];
                let scnt = self.arena.chunk_keys_into(s, &mut sk_buf);
                let w = self.arena.chunk_write(t);
                for j in 0..scnt {
                    w.set_key(j, sk_buf[j]);
                    w.set_val(j, self.arena.chunk_val(s, j));
                }
                w.set_count(scnt);
                tn.set_key_next(sk, snext);
                drop(w);
                sn.cold.mark.store(true, Ordering::Release);
                // `sk` now answers from chunk `t`; the old `s` is dead
                self.replica_note(sk);
            } else {
                // only chunk (possible only at the head leaf)
                pn.hot.bottom.store(tnext, Ordering::Release);
                tn.cold.mark.store(true, Ordering::Release);
            }
            // membership shrank: republish the routing block
            self.block_refresh(p, None);
            self.replica_note(key);
            return Tri::True;
        }

        // in-chunk removal
        {
            let w = self.arena.chunk_write(t);
            for j in pos..newcnt {
                w.set_key(j, w.key(j + 1));
                w.set_val(j, w.val(j + 1));
            }
            w.set_count(newcnt);
            if pos == newcnt {
                // removed the chunk max: lower the routing header
                // atomically with the array it describes
                tn.set_key_next(keys[newcnt - 1], tnext);
            }
        }
        if pos == newcnt {
            // max lowering leaves replica separators stale-high (safe);
            // note it so maintenance re-tightens them
            self.replica_note(key);
        }
        if pos == newcnt && ti == children.len() - 1 {
            // removed the leaf max: sync the leaf key (a lowering — the
            // block separator goes stale-high, which routing tolerates)
            let (pk, pnx) = pn.key_next();
            if pk == key && !self.is_head(p) {
                self.set_header_windowed(p, keys[newcnt - 1], pnx);
            }
        }
        if newcnt < min_occ && children.len() >= 2 {
            let (li, ri) = if ti + 1 < children.len() { (ti, ti + 1) } else { (ti - 1, ti) };
            // the marked right chunk is in `children`, so the caller's
            // release_children_retiring retires it; a resplit's fresh chunk
            // needs no lock here (the leaf lock excludes other writers)
            let _ = self.chunk_rebuild_pair(children[li], children[ri], false);
            // membership changed (merge or resplit): republish the block.
            // The pair's key moves (left raised to a stored separator at
            // worst) stay covered by the pre-refresh block via the marked
            // right chunk's mark-check retry.
            self.block_refresh(p, None);
        }
        Tri::True
    }

    /// 1-2-3-4 merge/borrow at chunk granularity: rebalance the adjacent
    /// locked chunk pair `(l, r)` after one side went underfull. The RIGHT
    /// chunk is always the one marked — a merge absorbs it into the left
    /// chunk, a resplit ("borrow") replaces it with a freshly allocated
    /// chunk — so keys never move leftward *between two live chunks* and
    /// stale lock-free readers fail their generation/mark re-check instead
    /// of missing a key. Returns the fresh chunk on a resplit (locked iff
    /// `lock_fresh`); `r` stays locked and marked for the caller to retire.
    fn chunk_rebuild_pair(&self, l: NodeRef, r: NodeRef, lock_fresh: bool) -> Option<NodeRef> {
        let cap = self.arena.leaf_cap();
        let ln = self.arena.node(l);
        let rn = self.arena.node(r);
        let mut lk = [0u64; MAX_LEAF_CAP];
        let mut rk = [0u64; MAX_LEAF_CAP];
        let lcnt = self.arena.chunk_keys_into(l, &mut lk);
        let rcnt = self.arena.chunk_keys_into(r, &mut rk);
        let total = lcnt + rcnt;
        let (lkey, _) = ln.key_next();
        let (rkey, rnext) = rn.key_next();
        // both chunk boundaries move (merge or resplit): invalidate both
        self.replica_note(lkey);
        self.replica_note(rkey);
        if total <= cap {
            // merge: left absorbs right; the header takeover inside left's
            // window makes the widened coverage and the data atomic
            let w = self.arena.chunk_write(l);
            for j in 0..rcnt {
                w.set_key(lcnt + j, rk[j]);
                w.set_val(lcnt + j, self.arena.chunk_val(r, j));
            }
            w.set_count(total);
            ln.set_key_next(rkey, rnext);
            drop(w);
            rn.cold.mark.store(true, Ordering::Release);
            return None;
        }
        // borrow: re-split the pair evenly. The high half moves to a FRESH
        // chunk (never leftward into a live one); the old right retires.
        let lh = total / 2;
        let mut ks = [0u64; 2 * MAX_LEAF_CAP];
        let mut vs = [0u64; 2 * MAX_LEAF_CAP];
        for j in 0..lcnt {
            ks[j] = lk[j];
            vs[j] = self.arena.chunk_val(l, j);
        }
        for j in 0..rcnt {
            ks[lcnt + j] = rk[j];
            vs[lcnt + j] = self.arena.chunk_val(r, j);
        }
        let nr = self.arena.alloc_chunk(&ks[lh..total], &vs[lh..total], rnext);
        if lock_fresh {
            self.arena.node(nr).cold.lock.lock(); // pre-publication: uncontended
        }
        let w = self.arena.chunk_write(l);
        for j in 0..lh {
            w.set_key(j, ks[j]);
            w.set_val(j, vs[j]);
        }
        w.set_count(lh);
        ln.set_key_next(ks[lh - 1], nr);
        drop(w);
        rn.cold.mark.store(true, Ordering::Release);
        Some(nr)
    }


    // ------------------------------------------------------------------
    // Range search (the paper's motivating skiplist advantage, §IX)
    // ------------------------------------------------------------------

    /// Collect all `(key, value)` with `lo <= key <= hi` (lock-free walk of
    /// the terminal list; retries on interference). The walk prefetches the
    /// next terminal chunk while the current row is copied out.
    pub fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        let mut cost = PathCost::default();
        let out = self.range_inner(lo, hi, &mut cost);
        self.flush_cost(&cost);
        out
    }

    fn range_inner(&self, lo: u64, hi: u64, cost: &mut PathCost) -> Vec<(u64, u64)> {
        let mut b = Backoff::new();
        loop {
            if let Some(start) = self.seek_terminal(lo, cost) {
                if let Some(out) = self.range_walk(start, lo, hi, cost) {
                    return out;
                }
            }
            self.stats.find_retries.fetch_add(1, Ordering::Relaxed);
            b.wait();
        }
    }

    /// Collect `[lo, hi]` rows walking the terminal list from `start`
    /// (`None` = a chunk snapshot tore / recycled — re-seek and retry).
    /// Shared by the top-down range and the replica-seeded range.
    fn range_walk(
        &self,
        start: NodeRef,
        lo: u64,
        hi: u64,
        cost: &mut PathCost,
    ) -> Option<Vec<(u64, u64)>> {
        let mut out = Vec::new();
        let mut cur = start;
        let mut keys = [0u64; MAX_LEAF_CAP];
        let mut vals = [0u64; MAX_LEAF_CAP];
        loop {
            if cur == SENTINEL {
                return Some(out);
            }
            cost.derefs += 1;
            // one seqlock snapshot copies the whole chunk out; a torn
            // read or generation change retries the range
            let (cnt, max, nx) = self.arena.chunk_snapshot(cur, &mut keys, &mut vals)?;
            // pull the next chunk's line while this one is copied out
            cost.prefetches += self.arena.prefetch(nx) as u64;
            for j in 0..cnt {
                let k = keys[j];
                if k > hi {
                    return Some(out);
                }
                if k >= lo {
                    out.push((k, vals[j]));
                }
            }
            if max > hi {
                return Some(out);
            }
            cur = nx;
        }
    }

    /// Find the first terminal node with key >= lo (None = retry).
    fn seek_terminal(&self, lo: u64, cost: &mut PathCost) -> Option<NodeRef> {
        let mut cur = self.head;
        loop {
            if cur == SENTINEL {
                return Some(SENTINEL);
            }
            cost.derefs += 1;
            let n = self.arena.resolve(cur)?;
            if n.is_marked() {
                return None;
            }
            let (nkey, nnext) = n.key_next();
            let bottom = n.hot.bottom.load(Ordering::Acquire);
            if self.arena.resolve(cur).is_none() {
                return None;
            }
            cost.prefetches += self.arena.prefetch(nnext) as u64
                + self.arena.prefetch(bottom) as u64
                + self.arena.prefetch_plane(bottom) as u64;
            if self.is_head(cur) && nnext != SENTINEL {
                return None;
            }
            if bottom == SENTINEL && !self.is_head(cur) {
                // terminal node
                if nkey >= lo {
                    return Some(cur);
                }
                cur = nnext;
                continue;
            }
            if self.is_head(cur) && bottom == SENTINEL {
                return Some(SENTINEL);
            }
            if nkey < lo {
                cur = nnext;
                continue;
            }
            // fat inner nodes: one block probe replaces the child walk
            if self.inner_blocks() {
                match self.arena.block_route(cur, lo) {
                    Some(BlockRoute::Descend { child, .. }) => {
                        cost.derefs += 1;
                        cost.prefetches += self.arena.prefetch(child) as u64
                            + self.arena.prefetch_plane(child) as u64;
                        cur = child;
                        continue;
                    }
                    Some(BlockRoute::Right { next, .. }) => {
                        cost.derefs += 1;
                        cur = next;
                        continue;
                    }
                    Some(BlockRoute::Fallback { .. }) => {}
                    None => return None, // torn block / generation changed
                }
            }
            // descend into covering child
            let mut d = bottom;
            let mut target = None;
            while d != SENTINEL {
                cost.derefs += 1;
                let (dk, dn) = self.arena.read_key_next(d)?;
                if lo <= dk {
                    target = Some(d);
                    break;
                }
                cost.prefetches += self.arena.prefetch(dn) as u64;
                if dk >= nkey {
                    break;
                }
                d = dn;
            }
            match target {
                Some(t) => cur = t,
                None => {
                    // lo beyond this subtree: continue right at this level
                    cur = nnext;
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Fused sorted-batch application (one descent per group of keys)
    // ------------------------------------------------------------------

    /// Apply a key-sorted run of mixed operations with fused descents: one
    /// left-to-right traversal carries the per-level predecessor path
    /// ([`RunCarry`]) forward between consecutive keys, and a whole group of
    /// consecutive keys that land in the same leaf segment is applied under
    /// a single lock acquisition — the per-key O(log n) dependent-miss chain
    /// is paid once per *group* instead of once per op.
    ///
    /// `sink(idx, reply)` is called exactly once per op, in run order —
    /// possibly while leaf locks are held, so it must not call back into
    /// the skiplist (counters/aggregation only).
    ///
    /// Semantics are identical to the equivalent per-key loop (ops apply
    /// strictly left to right against the live structure; duplicate keys in
    /// the run see each other's effects).
    ///
    /// # Why the 1-2-3-4 discipline survives
    ///
    /// Each group starts with a descent that is literally the per-op
    /// writer's walk — `addition`'s split-on-the-way-down for inserts,
    /// `deletion`'s merge/borrow boost for erases — so the *first* op of a
    /// group is licensed exactly like a point op. Subsequent ops of the
    /// group run under the same windows as the finger write fast path:
    /// an insert requires the leaf to hold ≤ 4 children (the post-insert
    /// width ≤ 5 is the same transient a full descent leaves behind, and
    /// the next group's descent splits it on the way down) and an erase of
    /// a resident key requires ≥ 3 (post-erase ≥ 2: no boost ever needed).
    /// When a window closes, the group ends and the next key re-descends —
    /// rebalancing therefore happens **only on descents**, never inside a
    /// leaf group, preserving the rebalance-on-the-way-down invariant.
    ///
    /// # Why the carry is safe
    ///
    /// A carried entry is a hint, exactly like a search finger: before use
    /// it is locked and validated live (generation, unmarked, and the
    /// children lower-bound proof `first_child.key <= key <= node.key`, the
    /// same coverage argument as `finger_start`). A stale entry fails
    /// validation and the run falls back to a shallower level or the head —
    /// it can cost a wasted lock round-trip, never a wrong placement.
    pub fn apply_sorted_run(&self, ops: &[BatchOp], sink: &mut dyn FnMut(usize, BatchReply)) {
        debug_assert!(super::is_sorted_run(ops), "run must be key-sorted");
        if let Some(last) = ops.last() {
            assert!(last.key() <= MAX_KEY, "key {} reserved for sentinels", last.key());
        }
        let mut cost = PathCost::default();
        let mut carry = RunCarry::new();
        let mut i = 0usize;
        let mut erased = false;
        let mut stall = 0u32;
        while i < ops.len() {
            let key = ops[i].key();
            let before = i;
            let mut b = Backoff::new();
            loop {
                let (nref, carried, lvl) = match carry.start_for(key) {
                    Some((l, r)) => (r, true, l),
                    None => (self.head, false, 0),
                };
                match self.run_descent(nref, carried, ops, &mut i, &mut carry, sink, &mut cost, &mut erased)
                {
                    RunStep::Done => break,
                    // stale carried start: retry from a shallower level
                    RunStep::Stale => carry.invalidate_up_to(lvl),
                    RunStep::Retry => {
                        carry.clear();
                        self.stats.write_retries.fetch_add(1, Ordering::Relaxed);
                        self.increase_depth();
                        if erased {
                            self.maybe_decrease_depth();
                        }
                        b.wait();
                    }
                }
            }
            if i == before {
                // A descent that applied nothing (the key moved past a
                // just-split leaf, or a concurrent restructure shrank the
                // target's coverage). The refreshed carry resolves it on
                // the next descent; the stall bound is a defensive back-off
                // against adversarial concurrent churn.
                stall += 1;
                if stall > 16 {
                    carry.clear();
                    b.wait();
                }
            } else {
                stall = 0;
            }
        }
        if erased {
            self.maybe_decrease_depth();
        }
        self.flush_cost(&cost);
    }

    /// One fused-run descent from `nref`: walks down (and right) to the
    /// leaf covering `ops[*i]`, applying the per-op-kind rebalance
    /// discipline on the way, then applies as many consecutive run ops as
    /// the leaf's coverage and arity windows allow. Advances `*i` past every
    /// applied op and records the path into `carry`.
    #[allow(clippy::too_many_arguments)]
    fn run_descent(
        &self,
        nref: NodeRef,
        carried: bool,
        ops: &[BatchOp],
        i: &mut usize,
        carry: &mut RunCarry,
        sink: &mut dyn FnMut(usize, BatchReply),
        cost: &mut PathCost,
        erased: &mut bool,
    ) -> RunStep {
        if nref == SENTINEL {
            return RunStep::Retry;
        }
        let key = ops[*i].key();
        cost.derefs += 1;
        let Some(n) = self.arena.resolve(nref) else {
            return if carried { RunStep::Stale } else { RunStep::Retry };
        };
        n.cold.lock.lock();
        if n.is_marked() || self.arena.resolve(nref).is_none() {
            n.cold.lock.unlock();
            return if carried { RunStep::Stale } else { RunStep::Retry };
        }
        let (nkey, nnext) = n.key_next();
        if self.is_head(nref) && nnext != SENTINEL {
            n.cold.lock.unlock();
            return RunStep::Retry; // height increase pending (alg 3)
        }
        let nbottom = n.hot.bottom.load(Ordering::Acquire);
        let children = match self.acquire_children(nkey, nbottom, cost) {
            Ok(c) => c,
            Err(partial) => {
                self.release_children(&partial);
                n.cold.lock.unlock();
                return RunStep::Retry; // over-wide segment: retry after help
            }
        };
        self.check_node_key(nref, &children);
        let (nkey, nnext) = n.key_next(); // may have been lowered

        let level = n.hot.level.load(Ordering::Relaxed);

        if carried {
            // The carry must prove coverage from below (finger_start's
            // argument): the first child's lower bound — at a leaf the
            // first *chunk's* min key, above it the first child's key —
            // proves the key cannot belong to an earlier subtree.
            let ok = !children.is_empty() && {
                let c0 = children[0];
                if level == 1 {
                    self.arena.chunk_count(c0) > 0 && self.arena.chunk_key(c0, 0) <= key
                } else {
                    self.arena.node(c0).key() <= key
                }
            };
            if !ok {
                self.release_children(&children);
                n.cold.lock.unlock();
                return RunStep::Stale;
            }
        }

        if nkey < key {
            // Merge-join step: the run moved past this node's coverage —
            // carry the level rightward instead of re-descending.
            self.release_children(&children);
            n.cold.lock.unlock();
            return self.run_descent(nnext, false, ops, i, carry, sink, cost, erased);
        }

        if level == 1 {
            let ok = self.run_leaf_group(nref, carried, n, &children, ops, i, carry, sink, erased);
            n.cold.lock.unlock();
            // A carried leaf start that could not legally apply its first
            // op (an erase needing the parent's merge/borrow boost) falls
            // back to a shallower start, which runs the full discipline.
            return if ok { RunStep::Done } else { RunStep::Stale };
        }

        // Inner node: apply the first op's writer discipline on the way
        // down (split for inserts, boost for erases), then descend into the
        // covering child.
        let first_op = ops[*i];
        if matches!(first_op, BatchOp::Insert(..)) {
            self.addition_rebalance(nref, &children);
        }
        self.block_build_if_missing(nref);
        if !self.is_head(nref) && !children.is_empty() {
            carry.record(level, nref, nkey);
            self.finger_record(level, nref, self.arena.node(children[0]).key(), nkey);
        }

        let mut idx = None;
        for (ci, &c) in children.iter().enumerate() {
            if key <= self.arena.node(c).key() {
                idx = Some(ci);
                break;
            }
        }
        let Some(ci) = idx else {
            // No covering child under a key that this node covers: for an
            // erase this is `deletion`'s authoritative "not present"; for a
            // get the same argument answers None; an insert must retry (it
            // needs a segment to land in — transient restructure).
            let out = match first_op {
                BatchOp::Erase(_) => {
                    sink(*i, BatchReply::Applied(false));
                    *i += 1;
                    RunStep::Done
                }
                BatchOp::Get(_) => {
                    sink(*i, BatchReply::Value(None));
                    *i += 1;
                    RunStep::Done
                }
                BatchOp::Insert(..) => RunStep::Retry,
            };
            self.release_children(&children);
            n.cold.lock.unlock();
            return out;
        };

        let target = children[ci];
        let mut descend = target;
        if matches!(first_op, BatchOp::Erase(_)) {
            // Deletion's boost (alg 5): a 1-2-wide covering child merges or
            // borrows from its sibling before we descend into it.
            let Some(tchildren) = self.count_children(target, cost) else {
                self.release_children(&children);
                n.cold.lock.unlock();
                return RunStep::Retry;
            };
            if tchildren == 0 {
                self.release_children(&children);
                n.cold.lock.unlock();
                return RunStep::Retry;
            }
            if tchildren <= self.min_inner() && children.len() >= 2 {
                if carried && children.len() <= self.min_inner() {
                    // Merging two of our children would drop this node
                    // below the resting floor; per-op descents cannot get
                    // here because the level above boosts an at-floor node
                    // before descending into it — a boost the carried
                    // start skipped. Fall back to a shallower start, which
                    // runs the cascade.
                    self.release_children(&children);
                    n.cold.lock.unlock();
                    return RunStep::Stale;
                }
                let (li, ri) = if ci > 0 { (ci - 1, ci) } else { (ci, ci + 1) };
                if ri < children.len() {
                    descend = self.merge_borrow(children[li], children[ri], key, cost);
                    self.block_refresh(nref, None);
                }
            }
            self.release_children_retiring(&children);
        } else {
            self.release_children(&children);
        }
        n.cold.lock.unlock();
        self.run_descent(descend, false, ops, i, carry, sink, cost, erased)
    }

    /// Apply consecutive run ops into locked leaf `nref` (children locked):
    /// every op whose key the leaf covers *and* whose arity window is open
    /// executes under this one lock acquisition. The local [`Seg`] mirrors
    /// the terminal segment as it mutates; terminal nodes created here are
    /// locked before publication (uniform release), terminal nodes removed
    /// here are unlocked and retired on the spot (they left the segment).
    ///
    /// Returns `false` only when a *carried* start could not legally apply
    /// its first op (an erase of a resident key in a ≤ 2-wide segment —
    /// the merge/borrow boost lives on the parent's descent, which a leaf
    /// carry skipped); the caller then retries from a shallower level.
    #[allow(clippy::too_many_arguments)]
    fn run_leaf_group(
        &self,
        nref: NodeRef,
        carried: bool,
        n: NodeView<'_>,
        children: &[NodeRef],
        ops: &[BatchOp],
        i: &mut usize,
        carry: &mut RunCarry,
        sink: &mut dyn FnMut(usize, BatchReply),
        erased: &mut bool,
    ) -> bool {
        let start_i = *i;
        if matches!(ops[*i], BatchOp::Insert(..)) {
            self.addition_rebalance(nref, children);
        }
        // Split the acquired list into this leaf's live segment and the
        // suffix a just-made sibling owns. The suffix stays locked until
        // the end so competing writers keep blocking at the segment heads.
        let (pkey, _) = n.key_next();
        let mut seg = Seg::new();
        let mut seg_end = 0usize;
        for &c in children.iter() {
            if self.arena.node(c).key() <= pkey {
                seg.push(c);
                seg_end += 1;
            } else {
                break;
            }
        }

        let cap = self.arena.leaf_cap();
        let min_occ = self.min_chunk_occupancy();
        let mut first = true;
        // Lazy block retract: demoted to the linked-walk fallback before
        // the group's first mutation (fresh readers then see every
        // intermediate state through the fat-leaf protocol), republished
        // once after the loop.
        let mut retracted = !self.inner_blocks();
        let mut keys = [0u64; MAX_LEAF_CAP];
        while *i < ops.len() {
            let (pk, _) = n.key_next(); // live: erases can lower it
            let key = ops[*i].key();
            if key > pk {
                break; // the run escaped this leaf's coverage
            }
            // target: first segment chunk whose max covers the key
            let mut ci = usize::MAX;
            for j in 0..seg.len() {
                if key <= self.arena.node(seg.get(j)).key() {
                    ci = j;
                    break;
                }
            }
            match ops[*i] {
                BatchOp::Get(k) => {
                    // writer-side read: the chunk lock is held, no snapshot
                    let mut v = None;
                    if ci != usize::MAX {
                        let c = seg.get(ci);
                        let cnt = self.arena.chunk_keys_into(c, &mut keys);
                        let pos = simd::rank(&keys[..cnt], k);
                        if pos < cnt && keys[pos] == k {
                            v = Some(self.arena.chunk_val(c, pos));
                        }
                    }
                    sink(*i, BatchReply::Value(v));
                }
                BatchOp::Insert(k, val) => {
                    if !retracted {
                        self.block_retract(nref);
                        retracted = true;
                    }
                    if seg.len() == 0 {
                        // empty (head) leaf: become the first chunk
                        let t = self.arena.alloc_chunk(&[k], &[val], SENTINEL);
                        self.arena.node(t).cold.lock.lock(); // pre-publication: uncontended
                        n.hot.bottom.store(t, Ordering::Release);
                        seg.insert_at(0, t);
                        self.len.fetch_add(1, Ordering::Relaxed);
                        sink(*i, BatchReply::Applied(true));
                        first = false;
                        *i += 1;
                        continue;
                    }
                    // covering chunk, or the last one (append raises its max)
                    let ti = if ci != usize::MAX { ci } else { seg.len() - 1 };
                    let t = seg.get(ti);
                    let tn = self.arena.node(t);
                    let cnt = self.arena.chunk_keys_into(t, &mut keys);
                    let pos = simd::rank(&keys[..cnt], k);
                    if pos < cnt && keys[pos] == k {
                        sink(*i, BatchReply::Applied(false));
                    } else if cnt < cap {
                        // in-chunk insert: arity untouched, no window gate
                        let (_, tnext) = tn.key_next();
                        let w = self.arena.chunk_write(t);
                        for j in (pos..cnt).rev() {
                            w.set_key(j + 1, w.key(j));
                            w.set_val(j + 1, w.val(j));
                        }
                        w.set_key(pos, k);
                        w.set_val(pos, val);
                        w.set_count(cnt + 1);
                        if pos == cnt {
                            tn.set_key_next(k, tnext); // raise max in-window
                        }
                        drop(w);
                        self.len.fetch_add(1, Ordering::Relaxed);
                        sink(*i, BatchReply::Applied(true));
                    } else {
                        // chunk split grows the arity — window gate: only
                        // descents split leaves, so a non-first split must
                        // leave width <= split_threshold (the post-split
                        // transient a point insert also leaves)
                        if (!first && seg.len() >= self.split_threshold()) || seg.len() + 1 > SEG_CAP
                        {
                            break;
                        }
                        let (_, tnext) = tn.key_next();
                        let mut ks = [0u64; MAX_LEAF_CAP + 1];
                        let mut vs = [0u64; MAX_LEAF_CAP + 1];
                        for j in 0..cnt {
                            ks[j] = keys[j];
                            vs[j] = self.arena.chunk_val(t, j);
                        }
                        let mut j = cnt;
                        while j > pos {
                            ks[j] = ks[j - 1];
                            vs[j] = vs[j - 1];
                            j -= 1;
                        }
                        ks[pos] = k;
                        vs[pos] = val;
                        let total = cnt + 1;
                        let lh = total / 2;
                        let nr = self.arena.alloc_chunk(&ks[lh..total], &vs[lh..total], tnext);
                        self.arena.node(nr).cold.lock.lock(); // pre-publication
                        let w = self.arena.chunk_write(t);
                        for j in 0..lh {
                            w.set_key(j, ks[j]);
                            w.set_val(j, vs[j]);
                        }
                        w.set_count(lh);
                        tn.set_key_next(ks[lh - 1], nr);
                        drop(w);
                        seg.insert_at(ti + 1, nr);
                        self.len.fetch_add(1, Ordering::Relaxed);
                        sink(*i, BatchReply::Applied(true));
                    }
                }
                BatchOp::Erase(k) => {
                    let mut hit = None;
                    if ci != usize::MAX {
                        let c = seg.get(ci);
                        let cnt = self.arena.chunk_keys_into(c, &mut keys);
                        let pos = simd::rank(&keys[..cnt], k);
                        if pos < cnt && keys[pos] == k {
                            hit = Some((pos, cnt));
                        }
                    }
                    let Some((pos, cnt)) = hit else {
                        sink(*i, BatchReply::Applied(false));
                        first = false;
                        *i += 1;
                        continue;
                    };
                    if !retracted {
                        self.block_retract(nref);
                        retracted = true;
                    }
                    let ti = ci;
                    let t = seg.get(ti);
                    let tn = self.arena.node(t);
                    let (_, tnext) = tn.key_next();
                    let newcnt = cnt - 1;
                    let needs_shrink = newcnt == 0 || (newcnt < min_occ && seg.len() >= 2);
                    // window: only descents boost, so a non-first shrink must
                    // leave width >= 2 (no merge/borrow ever needed here).
                    // A carried start skipped the parent's boost entirely,
                    // so even its first shrink is window-gated. In-chunk
                    // removals never change the arity and are never gated.
                    if needs_shrink && (!first || carried) && seg.len() < self.erase_window() {
                        break;
                    }
                    if newcnt == 0 {
                        // the chunk empties: unlink it from the segment
                        if ti > 0 {
                            let pr = seg.get(ti - 1);
                            let prn = self.arena.node(pr);
                            let (prk, _) = prn.key_next();
                            prn.set_key_next(prk, tnext);
                            tn.cold.mark.store(true, Ordering::Release);
                            seg.remove_at(ti);
                            tn.cold.lock.unlock();
                            self.arena.retire(t);
                            if ti == seg.len() {
                                // removed the boundary chunk: sync p.key
                                let (pk2, pnx) = n.key_next();
                                if pk2 == k && !self.is_head(nref) {
                                    self.set_header_windowed(nref, prk, pnx);
                                }
                            }
                        } else if seg.len() > 1 {
                            // first chunk: delete-by-copy — absorb the
                            // successor chunk so the leaf's bottom link
                            // never dangles
                            let s = seg.get(1);
                            let sn = self.arena.node(s);
                            let (sk, snext) = sn.key_next();
                            let mut sk_buf = [0u64; MAX_LEAF_CAP];
                            let scnt = self.arena.chunk_keys_into(s, &mut sk_buf);
                            let w = self.arena.chunk_write(t);
                            for j in 0..scnt {
                                w.set_key(j, sk_buf[j]);
                                w.set_val(j, self.arena.chunk_val(s, j));
                            }
                            w.set_count(scnt);
                            tn.set_key_next(sk, snext);
                            drop(w);
                            sn.cold.mark.store(true, Ordering::Release);
                            seg.remove_at(1);
                            sn.cold.lock.unlock();
                            self.arena.retire(s);
                        } else {
                            // only chunk (head leaf)
                            n.hot.bottom.store(tnext, Ordering::Release);
                            tn.cold.mark.store(true, Ordering::Release);
                            seg.remove_at(0);
                            tn.cold.lock.unlock();
                            self.arena.retire(t);
                        }
                    } else {
                        // in-chunk removal
                        {
                            let w = self.arena.chunk_write(t);
                            for j in pos..newcnt {
                                w.set_key(j, w.key(j + 1));
                                w.set_val(j, w.val(j + 1));
                            }
                            w.set_count(newcnt);
                            if pos == newcnt {
                                tn.set_key_next(keys[newcnt - 1], tnext);
                            }
                        }
                        if pos == newcnt && ti == seg.len() - 1 {
                            // removed the leaf max: sync p.key
                            let (pk2, pnx) = n.key_next();
                            if pk2 == k && !self.is_head(nref) {
                                self.set_header_windowed(nref, keys[newcnt - 1], pnx);
                            }
                        }
                        if newcnt < min_occ && seg.len() >= 2 {
                            let (li, ri) =
                                if ti + 1 < seg.len() { (ti, ti + 1) } else { (ti - 1, ti) };
                            let r = seg.get(ri);
                            let fresh = self.chunk_rebuild_pair(seg.get(li), r, true);
                            seg.remove_at(ri);
                            self.arena.node(r).cold.lock.unlock();
                            self.arena.retire(r);
                            if let Some(nr) = fresh {
                                seg.insert_at(ri, nr); // locked pre-publication
                            }
                        }
                    }
                    self.len.fetch_sub(1, Ordering::Relaxed);
                    *erased = true;
                    sink(*i, BatchReply::Applied(true));
                }
            }
            first = false;
            *i += 1;
        }

        // republish the routing block over the settled segment before any
        // chunk lock releases (the leaf lock alone pins the walk, but the
        // segment is final here either way)
        if retracted && self.inner_blocks() {
            self.block_refresh(nref, None);
        }
        // release: every current segment member (originals still present
        // plus nodes created here), then the split-off suffix
        for j in 0..seg.len() {
            self.arena.node(seg.get(j)).cold.lock.unlock();
        }
        self.release_children(&children[seg_end..]);

        let (pk_end, _) = n.key_next();
        if !self.is_head(nref) && seg.len() > 0 && self.arena.chunk_count(seg.get(0)) > 0 {
            let lo = self.arena.chunk_key(seg.get(0), 0);
            carry.record(1, nref, pk_end);
            self.finger_record(1, nref, lo, pk_end);
        }
        // progress, or a non-carried start (whose zero-progress exits are
        // the benign coverage cases a fresh descent resolves)
        *i > start_i || !carried
    }

    // ------------------------------------------------------------------
    // Interleaved multi-descent engine (memory-level parallelism)
    // ------------------------------------------------------------------

    /// Apply a key-sorted run by advancing up to `width` independent
    /// descents round-robin in a software pipeline: each engine step takes
    /// one pointer step in one lane and issues the prefetches for that
    /// lane's *next* hot lines, so by the time the scheduler returns to the
    /// lane (after one step in each of the other lanes) its miss has been
    /// in flight for `width - 1` steps. The dependent-miss chains of
    /// `width` searches overlap instead of serializing — the
    /// complementary path to [`DetSkiplist::apply_sorted_run`], which wins
    /// when keys cluster; this engine wins when they scatter
    /// (Table XIV, `experiments::t14_mlp`).
    ///
    /// Pipeline invariants:
    /// - The run is split into `width` *contiguous* chunks whose boundaries
    ///   never split an equal-key group, so every key's ops live in one
    ///   lane and apply strictly left to right; cross-lane (cross-key)
    ///   interleaving is indistinguishable from the concurrent callers the
    ///   structure already admits.
    /// - Each lane's descent is exactly one lock-free `Find` (algorithm 4)
    ///   unrolled to one step per scheduler visit — the round-robin only
    ///   changes *when* a step executes, never what it reads, and every
    ///   lane's generation/mark validation chain is self-contained, so
    ///   per-descent linearizability is the point operation's.
    /// - Lanes hold no locks between steps (a parked lane can never block
    ///   another); terminal mutations go through the same segment-local
    ///   leaf write as the finger fast path ([`DetSkiplist::leaf_write_at`],
    ///   lock held only within that call), falling back to the full
    ///   blocking writer descent when its guards fail.
    /// - The engine never *consults* the per-thread finger cache
    ///   (`finger_attempts`/`finger_hits` stay untouched — each lane
    ///   carries its own [`RunCarry`] instead); shared fallback helpers may
    ///   still refresh finger entries as any descent would.
    ///
    /// `sink(idx, reply)` fires exactly once per op, in lane (not run)
    /// order; like the fused path it must not call back into the skiplist.
    /// In [`FindMode::ReadLocked`] the engine degrades to the fused path:
    /// hand-over-hand shared locks cannot be time-sliced across lanes.
    pub fn apply_interleaved(
        &self,
        ops: &[BatchOp],
        width: usize,
        sink: &mut dyn FnMut(usize, BatchReply),
    ) {
        debug_assert!(super::is_sorted_run(ops), "run must be key-sorted");
        let Some(last) = ops.last() else {
            return;
        };
        assert!(last.key() <= MAX_KEY, "key {} reserved for sentinels", last.key());
        if self.mode == FindMode::ReadLocked {
            return self.apply_sorted_run(ops, sink);
        }
        let lanes_n = width.clamp(1, MAX_INTERLEAVE).min(ops.len());
        let mut lanes: Vec<Lane> = Vec::with_capacity(lanes_n);
        let mut start = 0usize;
        for l in 0..lanes_n {
            let mut end =
                if l + 1 == lanes_n { ops.len() } else { ((l + 1) * ops.len()) / lanes_n };
            end = end.max(start);
            // never split an equal-key group across a lane boundary
            while end > start && end < ops.len() && ops[end].key() == ops[end - 1].key() {
                end += 1;
            }
            lanes.push(Lane {
                i: start,
                end,
                cur: SENTINEL,
                started: false,
                retries: 0,
                carry: RunCarry::new(),
            });
            start = end;
        }
        let mut cost = PathCost::default();
        let mut erased = false;
        // warm the shared first hops before the sweep: every lane's first
        // descent begins at the head and immediately needs its child line
        let hb = self.arena.node(self.head).hot.bottom.load(Ordering::Acquire);
        cost.prefetches += self.arena.prefetch_many(&[self.head, hb]);
        let mut active = lanes.iter().filter(|l| l.i < l.end).count();
        while active > 0 {
            for lane in lanes.iter_mut() {
                if lane.i >= lane.end {
                    continue;
                }
                let before = cost.derefs;
                self.interleave_step(ops, lane, sink, &mut cost, &mut erased);
                if active <= 1 {
                    // no other descent in flight: nothing hid these misses
                    cost.stalled += cost.derefs - before;
                }
                if lane.i >= lane.end {
                    active -= 1;
                }
            }
        }
        if erased {
            self.maybe_decrease_depth();
        }
        self.flush_cost(&cost);
    }

    /// Interleaved point lookups: resolve `keys` (any order, duplicates
    /// allowed) with `width` overlapped descents, returning values in
    /// *input* order. Unsorted inputs are routed through a sorting
    /// permutation; the reply permutes back.
    pub fn get_many(&self, keys: &[u64], width: usize) -> Vec<Option<u64>> {
        let mut out = vec![None; keys.len()];
        if keys.is_empty() {
            return out;
        }
        if keys.windows(2).all(|w| w[0] <= w[1]) {
            let ops: Vec<BatchOp> = keys.iter().map(|&k| BatchOp::Get(k)).collect();
            self.apply_interleaved(&ops, width, &mut |i, r| {
                if let BatchReply::Value(v) = r {
                    out[i] = v;
                }
            });
        } else {
            let mut order: Vec<u32> = (0..keys.len() as u32).collect();
            order.sort_by_key(|&i| keys[i as usize]);
            let ops: Vec<BatchOp> =
                order.iter().map(|&i| BatchOp::Get(keys[i as usize])).collect();
            self.apply_interleaved(&ops, width, &mut |i, r| {
                if let BatchReply::Value(v) = r {
                    out[order[i] as usize] = v;
                }
            });
        }
        out
    }

    /// Validate a lane's carried entry as a descent start for `key` — the
    /// lock-free analogue of `finger_start`, with the identical coverage
    /// proof (live generation, unmarked, `first_child.key <= key <=
    /// node.key`); see that method's safety argument.
    fn carry_start(&self, carry: &RunCarry, key: u64, cost: &mut PathCost) -> Option<NodeRef> {
        let mut tried = 0;
        for l in 0..FINGER_LEVELS {
            let r = carry.refs[l];
            if r == SENTINEL || r == self.head || key > carry.hi[l] {
                continue;
            }
            tried += 1;
            cost.derefs += 2;
            if let Some(n) = self.arena.resolve(r) {
                if !n.is_marked() {
                    let (nkey, _) = n.key_next();
                    let bottom = n.hot.bottom.load(Ordering::Acquire);
                    let level = n.hot.level.load(Ordering::Relaxed);
                    if key <= nkey && bottom != SENTINEL {
                        // the proven lower bound: at a leaf the first
                        // chunk's min key, above it the first child's key
                        let blo = if level == 1 {
                            self.arena.chunk_probe(bottom, key).map(|p| p.lo)
                        } else {
                            self.arena.read_key_next(bottom).map(|(bk, _)| bk)
                        };
                        if let Some(blo) = blo {
                            if blo <= key && !n.is_marked() && self.arena.resolve(r).is_some() {
                                return Some(r);
                            }
                        }
                    }
                }
            }
            if tried >= 2 {
                break; // bound the validation cost of a stale carry
            }
        }
        None
    }

    /// One scheduler visit to a lane: start the next op's descent, or take
    /// exactly one pointer step of the in-flight one (an unrolled
    /// `find_lockfree_from` visit — child walks become right-steps at the
    /// child's level, which reaches the same nodes because every level's
    /// list is globally key-sorted and connected across segments).
    fn interleave_step(
        &self,
        ops: &[BatchOp],
        lane: &mut Lane,
        sink: &mut dyn FnMut(usize, BatchReply),
        cost: &mut PathCost,
        erased: &mut bool,
    ) {
        let op = ops[lane.i];
        let key = op.key();
        if !lane.started {
            if lane.retries > LANE_RETRY_LIMIT {
                // interference keeps breaking this descent: resolve the op
                // synchronously (blocking, but guaranteed progress)
                self.interleave_resolve_blocking(op, lane.i, sink, cost, erased);
                lane.i += 1;
                lane.retries = 0;
                lane.carry.clear();
                return;
            }
            lane.cur = self.carry_start(&lane.carry, key, cost).unwrap_or(self.head);
            lane.started = true;
            // warm the start line before this lane's next turn
            cost.prefetches += self.arena.prefetch(lane.cur) as u64;
            return;
        }
        let cur = lane.cur;
        if cur == SENTINEL {
            // walked off a level list's tail
            match op {
                BatchOp::Get(_) => self.lane_done(lane, sink, BatchReply::Value(None)),
                // writes are intercepted at the covering leaf; reaching the
                // tail means the snapshot raced a restructure
                _ => self.lane_fail(lane),
            }
            return;
        }
        cost.derefs += 1;
        let Some(n) = self.arena.resolve(cur) else {
            return self.lane_fail(lane);
        };
        if n.is_marked() {
            return self.lane_fail(lane);
        }
        let (nkey, nnext) = n.key_next();
        let bottom = n.hot.bottom.load(Ordering::Acquire);
        if self.arena.resolve(cur).is_none() {
            return self.lane_fail(lane);
        }
        // the next dependent misses go in flight while the scheduler visits
        // the other lanes — the pipeline's whole point
        cost.prefetches += self.arena.prefetch(nnext) as u64
            + self.arena.prefetch(bottom) as u64
            + self.arena.prefetch_plane(bottom) as u64;
        if self.is_head(cur) && nnext != SENTINEL {
            return self.lane_fail(lane); // height change pending
        }
        if bottom == SENTINEL && !self.is_head(cur) {
            // terminal chunk (only Get descents reach this level)
            match op {
                BatchOp::Get(_) => {
                    let Some(p) = self.arena.chunk_probe(cur, key) else {
                        return self.lane_fail(lane);
                    };
                    if key <= p.max {
                        // in-coverage answer: the probe window may postdate
                        // the mark check above — re-validate liveness
                        if n.is_marked() || self.arena.resolve(cur).is_none() {
                            return self.lane_fail(lane);
                        }
                        return self.lane_done(lane, sink, BatchReply::Value(p.hit));
                    }
                    cost.prefetches += self.arena.prefetch_plane(p.next) as u64;
                    lane.cur = p.next;
                }
                _ => self.lane_fail(lane),
            }
            return;
        }
        if self.is_head(cur) && bottom == SENTINEL {
            // empty structure
            match op {
                BatchOp::Get(_) => self.lane_done(lane, sink, BatchReply::Value(None)),
                _ => {
                    // first insert(s) build the structure: blocking path
                    self.interleave_resolve_blocking(op, lane.i, sink, cost, erased);
                    lane.i += 1;
                    lane.started = false;
                    lane.retries = 0;
                }
            }
            return;
        }
        if nkey < key {
            lane.cur = nnext;
            return;
        }
        // covering node
        let level = n.hot.level.load(Ordering::Relaxed);
        if level == 1 && !matches!(op, BatchOp::Get(_)) {
            // terminal mutation: segment-local leaf write under the finger
            // fast path's guards, else the full blocking writer descent
            let fop = match op {
                BatchOp::Insert(_, v) => FingerOp::Insert(v),
                _ => FingerOp::Erase,
            };
            match self.leaf_write_at(cur, key, fop, cost) {
                Some(applied) => {
                    self.apply_write_effects(&op, applied, erased);
                    self.lane_done(lane, sink, BatchReply::Applied(applied));
                }
                None => {
                    self.interleave_resolve_blocking(op, lane.i, sink, cost, erased);
                    lane.i += 1;
                    lane.started = false;
                    lane.retries = 0;
                }
            }
            return;
        }
        // fat inner node: one block probe replaces the child-level right
        // walk the unrolled descent would otherwise take step by step
        if self.inner_blocks() {
            match self.arena.block_route(cur, key) {
                Some(BlockRoute::Descend { child, .. }) => {
                    cost.derefs += 1;
                    if !self.is_head(cur) {
                        lane.carry.record(level, cur, nkey);
                    }
                    cost.prefetches += self.arena.prefetch(child) as u64
                        + self.arena.prefetch_plane(child) as u64;
                    lane.cur = child;
                    return;
                }
                Some(BlockRoute::Right { next, .. }) => {
                    cost.derefs += 1;
                    lane.cur = next;
                    return;
                }
                Some(BlockRoute::Fallback { .. }) => {}
                None => return self.lane_fail(lane),
            }
        }
        if !self.is_head(cur) {
            lane.carry.record(level, cur, nkey);
        }
        lane.cur = bottom;
    }

    /// A lane's op resolved: deliver the reply and move to the next op
    /// (the carry is kept — lane keys only ascend).
    fn lane_done(&self, lane: &mut Lane, sink: &mut dyn FnMut(usize, BatchReply), reply: BatchReply) {
        sink(lane.i, reply);
        lane.i += 1;
        lane.started = false;
        lane.retries = 0;
    }

    /// A lane's lock-free snapshot raced a restructure: help pending height
    /// changes and restart the op from a fresh descent.
    fn lane_fail(&self, lane: &mut Lane) {
        self.stats.find_retries.fetch_add(1, Ordering::Relaxed);
        if self.arena.node(self.head).next() != SENTINEL {
            self.increase_depth();
        }
        lane.carry.clear();
        lane.started = false;
        lane.retries += 1;
    }

    /// `len` / depth bookkeeping for a write the engine applied directly
    /// (the blocking paths do their own).
    fn apply_write_effects(&self, op: &BatchOp, applied: bool, erased: &mut bool) {
        match *op {
            BatchOp::Insert(..) if applied => {
                self.len.fetch_add(1, Ordering::Relaxed);
            }
            BatchOp::Erase(_) if applied => {
                self.len.fetch_sub(1, Ordering::Relaxed);
                *erased = true;
            }
            _ => {}
        }
    }

    /// Resolve one op synchronously with the ordinary blocking retry loops
    /// (guaranteed progress when a lane exhausts its automaton retries, and
    /// the write path when the leaf fast path declines).
    fn interleave_resolve_blocking(
        &self,
        op: BatchOp,
        idx: usize,
        sink: &mut dyn FnMut(usize, BatchReply),
        cost: &mut PathCost,
        erased: &mut bool,
    ) {
        let mut b = Backoff::new();
        match op {
            BatchOp::Get(key) => {
                let v = loop {
                    match self.find_lockfree_from(self.head, 0, key, cost) {
                        Ok(v) => break v,
                        Err(()) => {
                            self.stats.find_retries.fetch_add(1, Ordering::Relaxed);
                            if self.arena.node(self.head).next() != SENTINEL {
                                self.increase_depth();
                            }
                            b.wait();
                        }
                    }
                };
                sink(idx, BatchReply::Value(v));
            }
            BatchOp::Insert(key, value) => {
                let applied = loop {
                    match self.addition(self.head, key, value, cost) {
                        Tri::True => break true,
                        Tri::False => break false,
                        Tri::Retry => {
                            self.stats.write_retries.fetch_add(1, Ordering::Relaxed);
                            self.increase_depth();
                            b.wait();
                        }
                    }
                };
                if applied {
                    self.len.fetch_add(1, Ordering::Relaxed);
                }
                sink(idx, BatchReply::Applied(applied));
            }
            BatchOp::Erase(key) => {
                let applied = loop {
                    match self.deletion(self.head, key, cost) {
                        Tri::True => break true,
                        Tri::False => break false,
                        Tri::Retry => {
                            self.stats.write_retries.fetch_add(1, Ordering::Relaxed);
                            self.increase_depth();
                            self.maybe_decrease_depth();
                            b.wait();
                        }
                    }
                };
                if applied {
                    self.len.fetch_sub(1, Ordering::Relaxed);
                    *erased = true;
                }
                sink(idx, BatchReply::Applied(applied));
            }
        }
    }

    // ------------------------------------------------------------------
    // Invariant checking (tests; quiescent only)
    // ------------------------------------------------------------------

    /// Verify structural invariants (call only when no writers are active):
    /// per-level sorted keys, parent keys >= child keys, segment partition,
    /// arity bounds, terminal key set. Returns the sorted terminal keys.
    pub fn check_invariants(&self) -> Result<Vec<u64>, String> {
        let head = self.arena.node(self.head);
        if head.next() != SENTINEL {
            return Err("head has a sibling (pending IncreaseDepth)".into());
        }
        // walk down the leftmost spine collecting level heads
        let mut level_heads = vec![self.head];
        let mut cur = self.head;
        loop {
            let b = self.arena.node(cur).hot.bottom.load(Ordering::Acquire);
            if b == SENTINEL {
                break;
            }
            level_heads.push(b);
            cur = b;
        }
        if level_heads.len() < 2 {
            // empty structure
            return Ok(Vec::new());
        }
        // check each non-terminal level; remember solo chunks (a leaf with
        // arity 1 exempts its only chunk from the occupancy floor — the
        // spine / near-empty structure case)
        let leaf_level = level_heads.len() - 2;
        let mut solo_chunks: Vec<NodeRef> = Vec::new();
        for w in 0..level_heads.len() - 1 {
            let mut node = level_heads[w];
            let mut child = level_heads[w + 1];
            let mut prev_key: Option<u64> = None;
            while node != SENTINEL {
                let nn = self.arena.node(node);
                if nn.is_marked() {
                    return Err(format!("marked node reachable at level walk {w}"));
                }
                let (nkey, nnext) = nn.key_next();
                if let Some(pk) = prev_key {
                    if nkey <= pk {
                        return Err(format!("level {w}: keys not increasing ({pk} -> {nkey})"));
                    }
                }
                prev_key = Some(nkey);
                // node's children = segment of the lower level from `child`
                if nn.hot.bottom.load(Ordering::Acquire) != child {
                    return Err(format!("level {w}: segment partition broken at key {nkey}"));
                }
                let first_child = child;
                let mut arity = 0;
                let mut live: Vec<(NodeRef, u64)> = Vec::new();
                loop {
                    if child == SENTINEL {
                        break;
                    }
                    let (ck, cn) = self.arena.node(child).key_next();
                    if ck > nkey {
                        // stale-high parent (lazy CheckNodeKey): the next
                        // parent owns this child — legal quiescent state.
                        break;
                    }
                    arity += 1;
                    live.push((child, ck));
                    child = cn;
                    if ck == nkey {
                        break;
                    }
                }
                let max_arity = self.max_arity();
                if arity > max_arity {
                    return Err(format!("level {w}: node arity {arity} > {max_arity}"));
                }
                // fat-inner routing block: when built it must mirror the
                // live child segment exactly (quiescent writers always
                // refresh in their epilogue) with separators that are never
                // stale-LOW — a low separator routes readers past live
                // coverage, which rightward recovery cannot repair.
                if self.inner_blocks() {
                    if let Some(cnt) = self.arena.block_len(node) {
                        if cnt > self.inner_cap() {
                            return Err(format!(
                                "level {w}: block count {cnt} > inner cap {} (key {nkey})",
                                self.inner_cap()
                            ));
                        }
                        if cnt != live.len() {
                            return Err(format!(
                                "level {w}: block count {cnt} != live arity {} (key {nkey})",
                                live.len()
                            ));
                        }
                        let mut psep: Option<u64> = None;
                        for (i, &(cref, ckey)) in live.iter().enumerate() {
                            let sep = self.arena.block_sep(node, i);
                            let bchild = self.arena.block_child(node, i);
                            if let Some(ps) = psep {
                                if sep <= ps {
                                    return Err(format!(
                                        "level {w}: block seps not increasing ({ps} -> {sep})"
                                    ));
                                }
                            }
                            psep = Some(sep);
                            if bchild != cref {
                                return Err(format!(
                                    "level {w}: block child {i} != live child (key {nkey})"
                                ));
                            }
                            if sep < ckey {
                                return Err(format!(
                                    "level {w}: block sep {sep} stale-LOW vs child key {ckey}"
                                ));
                            }
                        }
                    }
                }
                let is_root_or_spine = node == self.head || nkey == u64::MAX;
                if arity < 2 && !is_root_or_spine && self.len() > 4 {
                    return Err(format!("level {w}: node key {nkey} arity {arity} < 2"));
                }
                if w == leaf_level && arity == 1 {
                    solo_chunks.push(first_child);
                }
                node = nnext;
            }
            if child != SENTINEL {
                return Err(format!("level {w}: lower level has unreachable tail"));
            }
        }
        // collect terminal keys chunk by chunk
        let cap = self.arena.leaf_cap();
        let min_occ = self.min_chunk_occupancy();
        let mut keys = Vec::new();
        let mut buf = [0u64; MAX_LEAF_CAP];
        let mut t = *level_heads.last().unwrap();
        let mut prev: Option<u64> = None;
        let mut chunk_list: Vec<(u64, NodeRef)> = Vec::new();
        while t != SENTINEL {
            let (k, nx) = self.arena.node(t).key_next();
            chunk_list.push((k, t));
            let cnt = self.arena.chunk_keys_into(t, &mut buf);
            if cnt == 0 {
                return Err(format!("empty terminal chunk (header key {k})"));
            }
            if cnt > cap {
                return Err(format!("chunk count {cnt} > leaf cap {cap}"));
            }
            if cnt < min_occ && !solo_chunks.contains(&t) {
                return Err(format!("chunk count {cnt} < min occupancy {min_occ} (key {k})"));
            }
            if buf[cnt - 1] != k {
                return Err(format!("chunk header key {k} != last stored key {}", buf[cnt - 1]));
            }
            for &bk in &buf[..cnt] {
                if let Some(p) = prev {
                    if bk <= p {
                        return Err(format!("terminal keys not increasing ({p} -> {bk})"));
                    }
                }
                prev = Some(bk);
                keys.push(bk);
            }
            t = nx;
        }
        if keys.len() as u64 != self.len() {
            return Err(format!("len {} != terminal count {}", self.len(), keys.len()));
        }
        self.check_replica_invariants(&chunk_list)?;
        Ok(keys)
    }

    /// Replica-plane half of [`DetSkiplist::check_invariants`] (quiescent):
    /// every replica's leaf entries must route into the shared terminal
    /// list. An **exact** replica (rebuilt with no publications since) must
    /// agree entry-for-entry with the live chunk list; a stale one is held
    /// to the safe-stale contract — ascending separators, every child
    /// either dead or a live terminal chunk with `sep >= chunk key`.
    fn check_replica_invariants(&self, chunk_list: &[(u64, NodeRef)]) -> Result<(), String> {
        let Some(set) = self.replicas.get() else { return Ok(()) };
        for (ri, rep) in set.replicas().iter().enumerate() {
            let entries = rep.leaf_entries();
            if rep.is_exact() {
                if entries.len() != chunk_list.len() {
                    return Err(format!(
                        "replica {ri} exact but holds {} entries vs {} live chunks",
                        entries.len(),
                        chunk_list.len()
                    ));
                }
                for (i, (&(sep, child), &(ck, cref))) in
                    entries.iter().zip(chunk_list.iter()).enumerate()
                {
                    if child != cref || sep != ck {
                        return Err(format!(
                            "replica {ri} exact entry {i}: ({sep}, {child:#x}) \
                             != live chunk ({ck}, {cref:#x})"
                        ));
                    }
                }
            } else {
                let mut prev: Option<u64> = None;
                for &(sep, child) in &entries {
                    if let Some(p) = prev {
                        if sep <= p {
                            return Err(format!(
                                "replica {ri}: separators not increasing ({p} -> {sep})"
                            ));
                        }
                    }
                    prev = Some(sep);
                    let Some(n) = self.arena.resolve(child) else { continue };
                    if n.is_marked() {
                        continue; // dead chunk: readers retry off it, fine
                    }
                    // a live child must be in the terminal list; its sep may
                    // sit on either side of the live chunk key (raised maxes
                    // go stale-low, lowered maxes stale-high — both safe)
                    if !chunk_list.iter().any(|&(_, r)| r == child) {
                        return Err(format!(
                            "replica {ri}: live child {child:#x} not in the terminal list"
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeSet;
    use std::sync::Arc;

    fn new_lf() -> DetSkiplist {
        DetSkiplist::with_capacity(FindMode::LockFree, 1 << 14)
    }

    #[test]
    fn empty_structure() {
        let s = new_lf();
        assert_eq!(s.get(1), None);
        assert!(!s.erase(1));
        assert!(s.is_empty());
        assert_eq!(s.check_invariants().unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn single_insert_find() {
        let s = new_lf();
        assert!(s.insert(42, 420));
        assert_eq!(s.get(42), Some(420));
        assert_eq!(s.get(41), None);
        assert_eq!(s.get(43), None);
        assert!(!s.insert(42, 421), "duplicate rejected");
        assert_eq!(s.get(42), Some(420), "duplicate does not overwrite");
        assert_eq!(s.check_invariants().unwrap(), vec![42]);
    }

    #[test]
    fn sorted_bulk_insert_builds_levels() {
        let s = new_lf();
        for k in 0..200u64 {
            assert!(s.insert(k, k * 10));
        }
        for k in 0..200u64 {
            assert_eq!(s.get(k), Some(k * 10), "key {k}");
        }
        assert_eq!(s.get(200), None);
        let st = s.stats();
        assert!(st.splits > 0, "splits must have happened");
        assert!(st.depth_increases > 0, "height must have grown");
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, (0..200).collect::<Vec<_>>());
    }

    #[test]
    fn reverse_and_shuffled_inserts() {
        for seed in 0..3 {
            let s = new_lf();
            let mut keys: Vec<u64> = (0..300).map(|i| i * 7 + 1).collect();
            if seed == 0 {
                keys.reverse();
            } else {
                Rng::new(seed).shuffle(&mut keys);
            }
            for &k in &keys {
                assert!(s.insert(k, k));
            }
            for &k in &keys {
                assert_eq!(s.get(k), Some(k));
            }
            let mut sorted = keys.clone();
            sorted.sort_unstable();
            assert_eq!(s.check_invariants().unwrap(), sorted);
        }
    }

    #[test]
    fn erase_sequential() {
        let s = new_lf();
        for k in 0..100u64 {
            s.insert(k, k);
        }
        // erase evens
        for k in (0..100u64).step_by(2) {
            assert!(s.erase(k), "erase {k}");
        }
        for k in 0..100u64 {
            assert_eq!(s.contains(k), k % 2 == 1, "key {k}");
        }
        assert!(!s.erase(2), "double erase");
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, (0..100).filter(|k| k % 2 == 1).collect::<Vec<_>>());
    }

    #[test]
    fn erase_everything_and_reuse() {
        let s = new_lf();
        for round in 0..3 {
            for k in 0..150u64 {
                assert!(s.insert(k, k + round), "round {round} insert {k}");
            }
            for k in 0..150u64 {
                assert!(s.erase(k), "round {round} erase {k}");
            }
            assert!(s.is_empty(), "round {round}");
            assert_eq!(s.check_invariants().unwrap(), Vec::<u64>::new());
        }
        assert!(s.mem_stats().recycled > 0, "nodes must recycle");
    }

    #[test]
    fn matches_btreeset_oracle_sequential() {
        let s = new_lf();
        let mut oracle = BTreeSet::new();
        let mut rng = Rng::new(7);
        for i in 0..10_000 {
            let k = rng.below(400);
            match rng.below(10) {
                0..=3 => assert_eq!(s.insert(k, k), oracle.insert(k), "op {i} insert {k}"),
                4..=5 => assert_eq!(s.erase(k), oracle.remove(&k), "op {i} erase {k}"),
                _ => assert_eq!(s.contains(k), oracle.contains(&k), "op {i} find {k}"),
            }
        }
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, oracle.into_iter().collect::<Vec<_>>());
    }

    #[test]
    fn matches_oracle_with_fingers_disabled_baseline() {
        // the Table XII baseline path (pure top-down descents) must agree
        // with the oracle exactly like the finger-accelerated default
        let s = new_lf();
        s.set_finger_cache(false);
        assert!(!s.finger_cache_enabled());
        let mut oracle = BTreeSet::new();
        let mut rng = Rng::new(17);
        for _ in 0..5_000 {
            let k = rng.below(300);
            match rng.below(10) {
                0..=3 => assert_eq!(s.insert(k, k), oracle.insert(k)),
                4..=5 => assert_eq!(s.erase(k), oracle.remove(&k)),
                _ => assert_eq!(s.contains(k), oracle.contains(&k)),
            }
        }
        let st = s.stats();
        assert_eq!(st.finger_attempts, 0, "disabled fingers must never be consulted");
        assert_eq!(st.finger_hits, 0);
        assert!(st.node_derefs > 0, "deref accounting is always on");
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, oracle.into_iter().collect::<Vec<_>>());
    }

    #[test]
    fn nearby_keys_hit_the_finger_cache() {
        let s = new_lf();
        // warm a 3-key-per-leaf structure, then hammer one neighbourhood
        for k in 0..600u64 {
            s.insert(k, k);
        }
        let warm = s.stats();
        for _ in 0..50 {
            for k in 300..330u64 {
                assert_eq!(s.get(k), Some(k));
            }
        }
        let st = s.stats();
        let attempts = st.finger_attempts - warm.finger_attempts;
        let hits = st.finger_hits - warm.finger_hits;
        assert_eq!(attempts, 1_500, "every get consults the finger");
        assert!(
            hits as f64 / attempts as f64 > 0.5,
            "repeated nearby gets must mostly hit ({hits}/{attempts})"
        );
        assert!(st.prefetches > 0, "descents must prefetch");
    }

    #[test]
    fn finger_fast_path_writes_preserve_invariants() {
        // repeated nearby insert/erase churn (the finger write fast path)
        // followed by a full structural check
        let s = new_lf();
        for k in 0..400u64 {
            s.insert(k * 2, k);
        }
        let mut rng = Rng::new(5);
        let mut oracle: BTreeSet<u64> = (0..400u64).map(|k| k * 2).collect();
        for _ in 0..20_000 {
            let base = rng.below(40) * 20;
            let k = base + rng.below(20);
            if rng.chance(1, 2) {
                assert_eq!(s.insert(k, k), oracle.insert(k));
            } else {
                assert_eq!(s.erase(k), oracle.remove(&k));
            }
        }
        let st = s.stats();
        assert!(st.finger_hits > 0, "nearby writes must use the fast path");
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, oracle.into_iter().collect::<Vec<_>>());
    }

    #[test]
    fn range_search() {
        let s = new_lf();
        for k in (0..100u64).step_by(5) {
            s.insert(k, k * 2);
        }
        let r = s.range(10, 30);
        assert_eq!(r, vec![(10, 20), (15, 30), (20, 40), (25, 50), (30, 60)]);
        assert_eq!(s.range(101, 200), vec![]);
        assert_eq!(s.range(0, 0), vec![(0, 0)]);
        // range on boundaries not present
        let r = s.range(11, 14);
        assert_eq!(r, vec![]);
    }

    #[test]
    fn childvec_push_signals_overflow() {
        let mut cv = ChildVec::new();
        for i in 0..12u64 {
            assert!(cv.push(i + 1), "push {i} within bound");
        }
        assert_eq!(cv.len(), 12);
        assert!(!cv.push(99), "13th child must signal overflow");
        assert_eq!(cv.len(), 12, "overflowing push must not clobber");
        assert_eq!(cv[11], 12, "contents intact after rejected push");
    }

    #[test]
    fn insert_and_erase_batches() {
        // batch ops come from the OrderedKv capability (sorted default over
        // the native insert/erase)
        use crate::coordinator::OrderedKv;
        let s = new_lf();
        let items: Vec<(u64, u64)> = (0..300u64).rev().map(|k| (k * 2, k)).collect();
        assert_eq!(s.insert_batch(&items), 300);
        assert_eq!(s.insert_batch(&items), 0, "all duplicates");
        assert_eq!(s.len(), 300);
        assert_eq!(s.range(0, 10), vec![(0, 0), (2, 1), (4, 2), (6, 3), (8, 4), (10, 5)]);
        let evens: Vec<u64> = (0..300u64).map(|k| k * 2).collect();
        assert_eq!(s.erase_batch(&evens), 300);
        assert_eq!(s.erase_batch(&evens), 0);
        assert!(s.is_empty());
        s.check_invariants().unwrap();
    }

    #[test]
    fn rwl_mode_basics() {
        let s = DetSkiplist::with_capacity(FindMode::ReadLocked, 1 << 14);
        let mut oracle = BTreeSet::new();
        let mut rng = Rng::new(11);
        for _ in 0..3_000 {
            let k = rng.below(200);
            match rng.below(4) {
                0 => assert_eq!(s.insert(k, k), oracle.insert(k)),
                1 => assert_eq!(s.erase(k), oracle.remove(&k)),
                _ => assert_eq!(s.contains(k), oracle.contains(&k)),
            }
        }
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, oracle.into_iter().collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_disjoint_inserts() {
        let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..2_000u64 {
                    assert!(s.insert(t * 100_000 + i, i));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 8_000);
        for t in 0..4u64 {
            for i in (0..2_000u64).step_by(97) {
                assert_eq!(s.get(t * 100_000 + i), Some(i));
            }
        }
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys.len(), 8_000);
    }

    #[test]
    fn concurrent_interleaved_key_space() {
        // threads insert interleaved (mod-4) keys: heavy same-segment contention
        let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1_500u64 {
                    assert!(s.insert(i * 4 + t, t));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 6_000);
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, (0..6_000).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_readers_and_writers() {
        let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
        for k in 0..1_000u64 {
            s.insert(k * 2, k); // evens pre-inserted
        }
        let mut handles = Vec::new();
        // writers insert odds
        for t in 0..2u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1_000u64 {
                    if i % 2 == t {
                        s.insert(i * 2 + 1, i);
                    }
                }
            }));
        }
        // readers: evens must always be present
        for _ in 0..2 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(3);
                for _ in 0..5_000 {
                    let k = rng.below(1_000) * 2;
                    assert!(s.contains(k), "pre-inserted key {k} lost");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 2_000);
        s.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_erase_and_find() {
        let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
        for k in 0..4_000u64 {
            s.insert(k, k);
        }
        let mut handles = Vec::new();
        // erasers: each removes a disjoint quarter
        for t in 0..2u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for k in 0..4_000u64 {
                    if k % 4 == t {
                        assert!(s.erase(k), "erase {k}");
                    }
                }
            }));
        }
        // readers: keys == 3 (mod 4) never erased
        for _ in 0..2 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(5);
                for _ in 0..4_000 {
                    let k = rng.below(1_000) * 4 + 3;
                    assert!(s.contains(k), "stable key {k} lost");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 2_000);
        let keys = s.check_invariants().unwrap();
        assert!(keys.iter().all(|k| k % 4 >= 2));
    }

    #[test]
    fn concurrent_mixed_workload_then_invariants() {
        let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..2_500 {
                    let k = rng.below(256);
                    match rng.below(10) {
                        0..=4 => {
                            s.insert(k, k * 3);
                        }
                        5..=6 => {
                            s.erase(k);
                        }
                        _ => {
                            if let Some(v) = s.get(k) {
                                assert_eq!(v, k * 3, "value corruption at {k}");
                            }
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let keys = s.check_invariants().unwrap();
        for k in keys {
            assert_eq!(s.get(k), Some(k * 3));
        }
    }

    #[test]
    fn sorted_run_matches_per_key_replay() {
        use crate::skiplist::{BatchOp, BatchReply};
        let mut rng = Rng::new(77);
        for round in 0..10 {
            let fused = new_lf();
            let twin = new_lf();
            for k in 0..200u64 {
                fused.insert(k * 3, k);
                twin.insert(k * 3, k);
            }
            let mut ops = Vec::new();
            for _ in 0..300 {
                let k = rng.below(700);
                ops.push(match rng.below(3) {
                    0 => BatchOp::Insert(k, k ^ 7),
                    1 => BatchOp::Erase(k),
                    _ => BatchOp::Get(k),
                });
            }
            // stable sort: duplicate keys keep their op order
            ops.sort_by_key(|o| o.key());
            let mut got = vec![None; ops.len()];
            fused.apply_sorted_run(&ops, &mut |i, r| got[i] = Some(r));
            for (i, op) in ops.iter().enumerate() {
                let want = match *op {
                    BatchOp::Insert(k, v) => BatchReply::Applied(twin.insert(k, v)),
                    BatchOp::Erase(k) => BatchReply::Applied(twin.erase(k)),
                    BatchOp::Get(k) => BatchReply::Value(twin.get(k)),
                };
                assert_eq!(got[i], Some(want), "round {round} op {i} {op:?}");
            }
            assert_eq!(
                fused.check_invariants().unwrap(),
                twin.check_invariants().unwrap(),
                "round {round}: fused and per-key structures diverged"
            );
        }
    }

    #[test]
    fn sorted_run_handles_empty_singleton_and_duplicates() {
        use crate::skiplist::{BatchOp, BatchReply};
        let s = new_lf();
        s.apply_sorted_run(&[], &mut |_, _| panic!("empty run must not call the sink"));
        let mut got = Vec::new();
        s.apply_sorted_run(&[BatchOp::Insert(9, 90)], &mut |i, r| got.push((i, r)));
        assert_eq!(got, vec![(0, BatchReply::Applied(true))]);
        // duplicate keys in one run see each other's effects, left to right
        let run = [
            BatchOp::Get(5),
            BatchOp::Insert(5, 50),
            BatchOp::Insert(5, 51),
            BatchOp::Get(5),
            BatchOp::Erase(5),
            BatchOp::Get(5),
        ];
        let mut got = vec![None; run.len()];
        s.apply_sorted_run(&run, &mut |i, r| got[i] = Some(r));
        assert_eq!(
            got,
            vec![
                Some(BatchReply::Value(None)),
                Some(BatchReply::Applied(true)),
                Some(BatchReply::Applied(false)),
                Some(BatchReply::Value(Some(50))),
                Some(BatchReply::Applied(true)),
                Some(BatchReply::Value(None)),
            ]
        );
        assert_eq!(s.check_invariants().unwrap(), vec![9]);
    }

    #[test]
    fn sorted_run_bulk_build_and_teardown() {
        use crate::skiplist::BatchOp;
        let s = new_lf();
        let inserts: Vec<BatchOp> = (0..2_000u64).map(|k| BatchOp::Insert(k, k * 2)).collect();
        let mut applied = 0u64;
        s.apply_sorted_run(&inserts, &mut |_, r| {
            if r == crate::skiplist::BatchReply::Applied(true) {
                applied += 1;
            }
        });
        assert_eq!(applied, 2_000);
        assert_eq!(s.len(), 2_000);
        assert_eq!(s.check_invariants().unwrap(), (0..2_000).collect::<Vec<_>>());
        let erases: Vec<BatchOp> = (0..2_000u64).map(BatchOp::Erase).collect();
        let mut erased = 0u64;
        s.apply_sorted_run(&erases, &mut |_, r| {
            if r == crate::skiplist::BatchReply::Applied(true) {
                erased += 1;
            }
        });
        assert_eq!(erased, 2_000);
        assert!(s.is_empty());
        s.check_invariants().unwrap();
    }

    #[test]
    fn sorted_run_cuts_derefs_vs_per_key() {
        use crate::skiplist::BatchOp;
        // same clustered insert+get stream, fused vs per-key, fresh stores
        let keys: Vec<u64> = (0..1_024u64).map(|k| 10_000 + k).collect();
        let fused = new_lf();
        let run: Vec<BatchOp> = keys.iter().map(|&k| BatchOp::Insert(k, k)).collect();
        fused.apply_sorted_run(&run, &mut |_, _| {});
        let run: Vec<BatchOp> = keys.iter().map(|&k| BatchOp::Get(k)).collect();
        fused.apply_sorted_run(&run, &mut |_, _| {});
        let fused_derefs = fused.stats().node_derefs;

        let per_key = new_lf();
        for &k in &keys {
            per_key.insert(k, k);
        }
        for &k in &keys {
            per_key.get(k);
        }
        let per_key_derefs = per_key.stats().node_derefs;
        assert!(
            fused_derefs < per_key_derefs,
            "fused sorted runs must strictly cut derefs ({fused_derefs} vs {per_key_derefs})"
        );
        assert_eq!(
            fused.check_invariants().unwrap(),
            per_key.check_invariants().unwrap()
        );
    }

    #[test]
    fn sorted_run_on_rwl_mode() {
        use crate::skiplist::{BatchOp, BatchReply};
        let s = DetSkiplist::with_capacity(FindMode::ReadLocked, 1 << 14);
        let run: Vec<BatchOp> = (0..500u64).map(|k| BatchOp::Insert(k * 2, k)).collect();
        s.apply_sorted_run(&run, &mut |_, _| {});
        let mut hits = 0;
        let gets: Vec<BatchOp> = (0..1_000u64).map(BatchOp::Get).collect();
        s.apply_sorted_run(&gets, &mut |_, r| {
            if matches!(r, BatchReply::Value(Some(_))) {
                hits += 1;
            }
        });
        assert_eq!(hits, 500);
        assert_eq!(s.len(), 500);
        s.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_sorted_runs_and_point_ops() {
        use crate::skiplist::BatchOp;
        // fused batches on disjoint stripes racing point readers on stable
        // keys: the group locks must serialize exactly like point writers
        let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
        for k in 0..1_000u64 {
            s.insert(k * 10 + 9, k); // stable keys: never touched below
        }
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for round in 0..30u64 {
                    let base = (t * 500 + round * 13 % 400) * 10;
                    let run: Vec<BatchOp> =
                        (0..64u64).map(|j| BatchOp::Insert(base + j * 10 + 1 + t, j)).collect();
                    s.apply_sorted_run(&run, &mut |_, _| {});
                    let run: Vec<BatchOp> =
                        (0..64u64).map(|j| BatchOp::Erase(base + j * 10 + 1 + t)).collect();
                    s.apply_sorted_run(&run, &mut |_, _| {});
                }
            }));
        }
        for _ in 0..2 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(9);
                for _ in 0..5_000 {
                    let k = rng.below(1_000) * 10 + 9;
                    assert!(s.contains(k), "stable key {k} lost under fused churn");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys.iter().filter(|&&k| k % 10 == 9).count(), 1_000);
    }

    #[test]
    fn height_decreases_after_mass_erase() {
        let s = new_lf();
        for k in 0..500u64 {
            s.insert(k, k);
        }
        for k in 0..495u64 {
            s.erase(k);
        }
        // trigger lazy collapses via traffic
        for _ in 0..20 {
            s.get(499);
            s.erase(496);
            s.insert(496, 0);
        }
        assert!(s.stats().depth_decreases > 0, "height should shrink");
        s.check_invariants().unwrap();
    }

    #[test]
    fn get_many_matches_point_gets_any_width() {
        let s = new_lf();
        let mut rng = Rng::new(41);
        for _ in 0..4_000 {
            let k = rng.below(1 << 20);
            s.insert(k, k ^ 0xABCD);
        }
        // scattered, unsorted probe set with hits, misses and duplicates
        let mut keys = Vec::new();
        for _ in 0..1_024 {
            keys.push(rng.below(1 << 20));
        }
        keys.push(keys[0]);
        let expect: Vec<Option<u64>> = keys.iter().map(|&k| s.get(k)).collect();
        for width in [1usize, 3, 8, 64] {
            assert_eq!(s.get_many(&keys, width), expect, "width {width} diverged");
        }
    }

    #[test]
    fn apply_interleaved_mixed_run_matches_oracle() {
        let s = new_lf();
        let mut oracle = BTreeSet::new();
        let mut rng = Rng::new(77);
        for _ in 0..2_000 {
            let k = rng.below(10_000);
            s.insert(k, k);
            oracle.insert(k);
        }
        for round in 0..20u64 {
            let mut ops = Vec::new();
            for _ in 0..256 {
                let k = rng.below(10_000);
                match rng.below(3) {
                    0 => ops.push(BatchOp::Insert(k, k + round)),
                    1 => ops.push(BatchOp::Erase(k)),
                    _ => ops.push(BatchOp::Get(k)),
                }
            }
            ops.sort_by_key(|o| o.key());
            // oracle replies computed per lane chunk semantics = per-key
            // left-to-right (lanes never split an equal-key group, and this
            // run has no cross-chunk key interaction once sorted)
            let mut replies = vec![None; ops.len()];
            s.apply_interleaved(&ops, 8, &mut |i, r| replies[i] = Some(r));
            let mut expected = BTreeSet::new();
            std::mem::swap(&mut expected, &mut oracle);
            for (i, op) in ops.iter().enumerate() {
                let want = match *op {
                    BatchOp::Insert(k, _) => BatchReply::Applied(expected.insert(k)),
                    BatchOp::Erase(k) => BatchReply::Applied(expected.remove(&k)),
                    BatchOp::Get(k) => BatchReply::Value(expected.get(&k).map(|_| k)),
                };
                // Gets see values written by earlier same-key inserts of the
                // same round; only compare presence for Gets
                match (replies[i].unwrap(), want) {
                    (BatchReply::Value(a), BatchReply::Value(b)) => {
                        assert_eq!(a.is_some(), b.is_some(), "round {round} op {i}")
                    }
                    (a, b) => assert_eq!(a, b, "round {round} op {i}"),
                }
            }
            oracle = expected;
        }
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, oracle.iter().copied().collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_never_consults_fingers() {
        let s = new_lf();
        for k in 0..3_000u64 {
            s.insert(k * 7, k);
        }
        let before = s.stats();
        let keys: Vec<u64> = (0..512u64).map(|i| (i * 191) % 21_000).collect();
        let _ = s.get_many(&keys, 8);
        let after = s.stats();
        assert_eq!(
            after.finger_attempts, before.finger_attempts,
            "interleaved descents must bypass the finger cache"
        );
        assert_eq!(after.finger_hits, before.finger_hits);
    }

    #[test]
    fn interleaving_cuts_stalled_derefs() {
        let build = || {
            let s = new_lf();
            for k in 0..20_000u64 {
                s.insert(k * 3, k);
            }
            s
        };
        let keys: Vec<u64> = (0..2_048u64).map(|i| (i * 7_919) % 60_000).collect();
        let stalled = |width: usize| {
            let s = build();
            let b = s.stats().stalled_derefs;
            let _ = s.get_many(&keys, width);
            s.stats().stalled_derefs - b
        };
        let (w1, w8) = (stalled(1), stalled(8));
        assert!(w1 > 0, "width-1 pipeline has nothing to overlap with");
        assert!(w8 * 4 < w1, "width-8 should hide most stalls: {w8} vs {w1}");
    }

    #[test]
    fn arity_windows_are_mutually_consistent() {
        // Pin the named constants to the 1-2-3-4 discipline's values: the
        // validator, the fast-path gates and the rebalancers all read these,
        // so a drift here silently changes the protocol. Update this test
        // only together with a re-derivation of the windows' safety
        // argument (see the constants' doc comments).
        assert_eq!(MAX_ARITY, 7);
        assert_eq!(INSERT_WINDOW, 4);
        assert_eq!(ERASE_WINDOW, 3);
        assert_eq!(SPLIT_THRESHOLD, INSERT_WINDOW + 1);
        // a windowed insert leaves at most SPLIT_THRESHOLD children, which
        // the validator's hard ceiling must tolerate (plus lazy-repair slack)
        assert!(SPLIT_THRESHOLD <= MAX_ARITY);
        // a windowed shrink leaves at least 2 children (no boost needed)
        assert!(ERASE_WINDOW - 1 >= 2);
        // the F-relative windows collapse to the legacy constants when fat
        // inner blocks are off, and keep the same mutual relations at every
        // legal F (quarter-occupancy floor, split fits the block, windows
        // never force a rebalance off the descent path)
        let legacy = DetSkiplist::with_caps_on(
            FindMode::LockFree,
            1 << 10,
            ArenaOptions::default(),
            DEFAULT_LEAF_CAP,
            1, // < 2 disables blocks
        );
        assert!(!legacy.inner_blocks());
        assert_eq!(legacy.split_threshold(), SPLIT_THRESHOLD);
        assert_eq!(legacy.insert_window(), INSERT_WINDOW);
        assert_eq!(legacy.erase_window(), ERASE_WINDOW);
        assert_eq!(legacy.min_inner(), 2);
        assert_eq!(legacy.max_arity(), MAX_ARITY);
        for f in [2usize, 4, 8, 16] {
            let s = DetSkiplist::with_caps_on(
                FindMode::LockFree,
                1 << 10,
                ArenaOptions::default(),
                DEFAULT_LEAF_CAP,
                f,
            );
            assert!(s.inner_blocks());
            assert_eq!(s.split_threshold(), f);
            assert_eq!(s.insert_window(), f - 1);
            assert_eq!(s.min_inner(), (f / 4).max(1));
            assert_eq!(s.erase_window(), s.min_inner() + 1);
            assert_eq!(s.max_arity(), f + 2);
            // a split of an F-wide node leaves two sides >= the floor
            assert!(f / 2 >= s.min_inner());
            assert!(f - f / 2 >= s.min_inner());
            // the smallest borrowable pair (2*floor + 1 children) re-splits
            // with both sides at or above the floor, whichever side is biased
            assert!((2 * s.min_inner() + 1).div_ceil(2) >= s.min_inner());
            assert!((2 * s.min_inner() + 1) / 2 >= s.min_inner());
            // everything fits the acquisition buffers
            assert!(s.max_arity() + 2 <= 24, "ChildVec capacity");
        }
    }

    fn new_lf_k(leaf_cap: usize) -> DetSkiplist {
        DetSkiplist::with_leaf_cap_on(
            FindMode::LockFree,
            1 << 14,
            ArenaOptions::default(),
            leaf_cap,
        )
    }

    #[test]
    fn k1_degenerates_to_single_key_terminals() {
        let s = new_lf_k(1);
        assert_eq!(s.leaf_cap(), 1);
        let mut oracle = BTreeSet::new();
        let mut rng = Rng::new(23);
        for _ in 0..4_000 {
            let k = rng.below(300);
            match rng.below(8) {
                0..=3 => assert_eq!(s.insert(k, k), oracle.insert(k)),
                4..=5 => assert_eq!(s.erase(k), oracle.remove(&k)),
                _ => assert_eq!(s.contains(k), oracle.contains(&k)),
            }
        }
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, oracle.into_iter().collect::<Vec<_>>());
    }

    #[test]
    fn chunk_split_at_capacity_keeps_halves_above_floor() {
        for cap in [8usize, 16, 32] {
            let s = new_lf_k(cap);
            // fill exactly one chunk, then overflow it: the split halves
            // must both satisfy the K/4 floor the validator enforces
            for k in 0..=(cap as u64) {
                assert!(s.insert(k, k * 2), "cap {cap} insert {k}");
                s.check_invariants().unwrap_or_else(|e| panic!("cap {cap} after {k}: {e}"));
            }
            for k in 0..=(cap as u64) {
                assert_eq!(s.get(k), Some(k * 2));
            }
        }
    }

    #[test]
    fn chunk_merge_borrow_on_erase_churn() {
        for cap in [8usize, 16] {
            let s = new_lf_k(cap);
            let n = (cap * 20) as u64;
            for k in 0..n {
                s.insert(k, k);
            }
            // erase a striped 3/4 of the keys: plenty of chunk underflows,
            // so merges and borrows both fire; validate throughout
            for k in 0..n {
                if k % 4 != 3 {
                    assert!(s.erase(k), "cap {cap} erase {k}");
                }
                if k % 16 == 0 {
                    s.check_invariants()
                        .unwrap_or_else(|e| panic!("cap {cap} after erase {k}: {e}"));
                }
            }
            let keys = s.check_invariants().unwrap();
            assert_eq!(keys, (0..n).filter(|k| k % 4 == 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn range_spans_chunk_boundaries() {
        let s = new_lf_k(8);
        for k in 0..200u64 {
            s.insert(k * 2, k);
        }
        // a range fully inside one chunk, one spanning several, one
        // spanning the whole structure
        assert_eq!(s.range(4, 8), vec![(4, 2), (6, 3), (8, 4)]);
        let wide = s.range(31, 333);
        let want: Vec<(u64, u64)> =
            (0..200u64).map(|k| (k * 2, k)).filter(|&(k, _)| (31..=333).contains(&k)).collect();
        assert_eq!(wide, want);
        assert_eq!(s.range(0, u64::MAX - 1).len(), 200);
    }

    #[test]
    fn fused_runs_and_fingers_agree_across_leaf_caps() {
        use crate::skiplist::BatchOp;
        for cap in [1usize, 8, 16] {
            let s = new_lf_k(cap);
            let twin = new_lf_k(cap);
            let mut rng = Rng::new(31 + cap as u64);
            for round in 0..6 {
                let mut ops = Vec::new();
                for _ in 0..400 {
                    let k = rng.below(900);
                    ops.push(match rng.below(3) {
                        0 => BatchOp::Insert(k, k ^ 3),
                        1 => BatchOp::Erase(k),
                        _ => BatchOp::Get(k),
                    });
                }
                ops.sort_by_key(|o| o.key());
                let mut got = vec![None; ops.len()];
                s.apply_sorted_run(&ops, &mut |i, r| got[i] = Some(r));
                for (i, op) in ops.iter().enumerate() {
                    let want = match *op {
                        BatchOp::Insert(k, v) => BatchReply::Applied(twin.insert(k, v)),
                        BatchOp::Erase(k) => BatchReply::Applied(twin.erase(k)),
                        BatchOp::Get(k) => BatchReply::Value(twin.get(k)),
                    };
                    assert_eq!(got[i], Some(want), "cap {cap} round {round} op {i} {op:?}");
                }
                assert_eq!(
                    s.check_invariants().unwrap(),
                    twin.check_invariants().unwrap(),
                    "cap {cap} round {round} diverged"
                );
            }
        }
    }

    fn new_lf_f(leaf_cap: usize, inner_cap: usize) -> DetSkiplist {
        DetSkiplist::with_caps_on(
            FindMode::LockFree,
            1 << 14,
            ArenaOptions::default(),
            leaf_cap,
            inner_cap,
        )
    }

    #[test]
    fn fatinner_oracle_churn_across_caps() {
        use std::collections::BTreeMap;
        for f in [2usize, 4, 8, 16] {
            let s = new_lf_f(DEFAULT_LEAF_CAP, f);
            assert_eq!(s.inner_cap(), f);
            let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
            let mut rng = Rng::new(0xFA7 + f as u64);
            for i in 0..6_000u64 {
                let k = rng.below(1_200);
                match rng.below(8) {
                    0..=3 => {
                        let v = i;
                        let want = !oracle.contains_key(&k);
                        assert_eq!(s.insert(k, v), want, "F {f} insert {k}");
                        oracle.entry(k).or_insert(v);
                    }
                    4..=5 => assert_eq!(s.erase(k), oracle.remove(&k).is_some(), "F {f} erase {k}"),
                    _ => assert_eq!(s.get(k), oracle.get(&k).copied(), "F {f} get {k}"),
                }
                if i % 512 == 0 {
                    s.check_invariants().unwrap_or_else(|e| panic!("F {f} after op {i}: {e}"));
                }
            }
            let keys = s.check_invariants().unwrap();
            assert_eq!(keys, oracle.keys().copied().collect::<Vec<_>>(), "F {f}");
            for (&k, &v) in &oracle {
                assert_eq!(s.get(k), Some(v), "F {f} final get {k}");
            }
        }
    }

    #[test]
    fn fatinner_agrees_with_legacy_routing() {
        // F = 8 against the block-disabled legacy walk on an identical op
        // stream: both the per-op replies and the final key sets must match.
        let fat = new_lf_f(DEFAULT_LEAF_CAP, 8);
        let legacy = new_lf_f(DEFAULT_LEAF_CAP, 1);
        assert!(fat.inner_blocks() && !legacy.inner_blocks());
        let mut rng = Rng::new(0xB10C);
        for i in 0..8_000u64 {
            let k = rng.below(2_000);
            match rng.below(8) {
                0..=3 => assert_eq!(fat.insert(k, k ^ i), legacy.insert(k, k ^ i), "insert {k}"),
                4..=5 => assert_eq!(fat.erase(k), legacy.erase(k), "erase {k}"),
                _ => assert_eq!(fat.get(k), legacy.get(k), "get {k}"),
            }
        }
        assert_eq!(fat.check_invariants().unwrap(), legacy.check_invariants().unwrap());
    }

    #[test]
    fn fatinner_fused_runs_and_interleaved_agree() {
        use crate::skiplist::BatchOp;
        for f in [2usize, 4, 8] {
            let s = new_lf_f(8, f);
            let twin = new_lf_f(8, f);
            let mut rng = Rng::new(77 + f as u64);
            for round in 0..6 {
                let mut ops = Vec::new();
                for _ in 0..400 {
                    let k = rng.below(900);
                    ops.push(match rng.below(3) {
                        0 => BatchOp::Insert(k, k ^ 5),
                        1 => BatchOp::Erase(k),
                        _ => BatchOp::Get(k),
                    });
                }
                ops.sort_by_key(|o| o.key());
                let mut got = vec![None; ops.len()];
                s.apply_sorted_run(&ops, &mut |i, r| got[i] = Some(r));
                for (i, op) in ops.iter().enumerate() {
                    let want = match *op {
                        BatchOp::Insert(k, v) => BatchReply::Applied(twin.insert(k, v)),
                        BatchOp::Erase(k) => BatchReply::Applied(twin.erase(k)),
                        BatchOp::Get(k) => BatchReply::Value(twin.get(k)),
                    };
                    assert_eq!(got[i], Some(want), "F {f} round {round} op {i} {op:?}");
                }
                // scattered (unsorted) batch through the interleaved lanes
                let mut scatter = Vec::new();
                for _ in 0..128 {
                    scatter.push(rng.below(900));
                }
                let got = s.get_many(&scatter, 8);
                for (i, &k) in scatter.iter().enumerate() {
                    assert_eq!(got[i], twin.get(k), "F {f} round {round} scatter {k}");
                }
                assert_eq!(
                    s.check_invariants().unwrap(),
                    twin.check_invariants().unwrap(),
                    "F {f} round {round} diverged"
                );
            }
        }
    }

    #[test]
    fn fatinner_depth_changes_keep_root_block_fresh() {
        // grow far enough for several IncreaseDepth promotions, then erase
        // back down through DecreaseDepth collapses — the root block is
        // rewritten inside both windows, so routing must stay exact
        let s = new_lf_f(2, 2);
        let n = 2_000u64;
        for k in 0..n {
            assert!(s.insert(k, k + 1));
        }
        assert!(s.stats().depth_increases > 0);
        s.check_invariants().unwrap();
        for k in 0..n {
            assert_eq!(s.get(k), Some(k + 1), "post-growth get {k}");
            assert!(s.erase(k), "erase {k}");
            if k % 256 == 0 {
                s.check_invariants().unwrap_or_else(|e| panic!("after erase {k}: {e}"));
            }
        }
        assert!(s.is_empty());
        s.check_invariants().unwrap();
    }

    #[test]
    fn fatinner_block_probe_cuts_index_derefs() {
        // the tentpole's measurable claim, pinned as a unit test: with the
        // same leaf shape, F = 8 routing blocks strictly cut derefs/op for
        // uniform random gets against the F-disabled linked child walk
        let fat = new_lf_f(8, 8);
        let legacy = new_lf_f(8, 1);
        let n = 60_000u64;
        for k in 0..n {
            fat.insert(k, k);
            legacy.insert(k, k);
        }
        let mut rng = Rng::new(0xDE7EF);
        let (mut df, mut dl) = (0u64, 0u64);
        for _ in 0..4_000 {
            let k = rng.below(n);
            let mut c = PathCost::default();
            assert_eq!(fat.find_lockfree_from(fat.head, 0, k, &mut c), Ok(Some(k)));
            df += c.derefs;
            let mut c = PathCost::default();
            assert_eq!(legacy.find_lockfree_from(legacy.head, 0, k, &mut c), Ok(Some(k)));
            dl += c.derefs;
        }
        assert!(
            df < dl,
            "block routing must cut index derefs: fat {df} vs legacy {dl}"
        );
    }

    #[test]
    fn replicas_answer_reads_and_survive_staleness() {
        let s = new_lf();
        for k in 0..4_000u64 {
            s.insert(k * 3 + 1, k);
        }
        assert!(!s.replicas_enabled());
        assert_eq!(s.get_replicated(301).0, Some(100), "pre-enable reads fall through");
        s.enable_replicas(&Topology::virtual_grid(2, 2), 4);
        assert!(s.replicas_enabled());
        // exact replica straight after the quiescent build: on-replica hits
        let before = s.replica_stats();
        for k in 0..4_000u64 {
            let (v, fell_back) = s.get_replicated(k * 3 + 1);
            assert_eq!(v, Some(k), "fresh-replica get {k}");
            assert!(!fell_back, "exact replica must answer key {}", k * 3 + 1);
            assert_eq!(s.get_replicated(k * 3 + 2).0, None, "absent key");
        }
        assert_eq!(s.replica_stats().fallbacks, before.fallbacks);
        s.check_invariants().expect("exact replicas mirror the terminal list");
        // staleness: splits, merges and boundary raises under the replica
        for k in 0..4_000u64 {
            s.insert(k * 3 + 2, k);
            if k % 3 == 0 {
                s.erase(k * 3 + 1);
            }
        }
        assert!(s.replica_stats().records_published > 0, "hooks must publish");
        for k in 0..4_000u64 {
            assert_eq!(s.get_replicated(k * 3 + 2).0, Some(k), "stale-replica get");
            let want = if k % 3 == 0 { None } else { Some(k) };
            assert_eq!(s.get_replicated(k * 3 + 1).0, want, "stale-replica erase view");
        }
        let (rows, _) = s.range_replicated(0, 100);
        assert_eq!(rows, s.range(0, 100), "replicated range agrees while stale");
        s.check_invariants().expect("stale replicas pass the weak invariants");
        // ticks drain the log; a forced rebuild restores exactness
        while !s.replica_tick() {}
        s.replica_rebuild_all();
        s.check_invariants().expect("rebuilt replicas mirror the terminal list");
        let before = s.replica_stats();
        for k in (0..4_000u64).filter(|k| k % 3 != 0) {
            assert_eq!(s.get_replicated(k * 3 + 1).0, Some(k));
        }
        assert_eq!(s.replica_stats().fallbacks, before.fallbacks, "no post-rebuild fallbacks");
    }
}
