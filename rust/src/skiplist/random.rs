//! Lock-free randomized skiplist — the "lkfreeRandomSL" baseline of
//! Table IV / figure 6.
//!
//! The classic Harris/Herlihy–Shavit lock-free skiplist: each node carries a
//! tower of next links; removal marks links top-down (mark bit embedded in
//! the link word) and traversals help unlink marked nodes with CAS.  Nodes
//! come from the unified §V block arena ([`crate::mem::BlockArena`]) with
//! generation-tagged links: a link is `(mark:1 | gen:31 | idx:32)`, so CAS
//! on a recycled node's link fails on the generation — the ABA defense the
//! paper implements with per-node reference counters. Alloc/retire churn
//! runs off the arena's per-thread magazines, and recycle/retire accounting
//! is uniform with the deterministic skiplist's arena (the old inline copy
//! never counted recycled slots).
//!
//! The arena's two-plane layout puts the descent state — `key` and the
//! whole `tower` — in the hot plane and `(value, gen)` in the cold plane,
//! and `find` software-prefetches the successor's hot line while the
//! current node is examined (same rationale as the deterministic list; see
//! `util::prefetch`). Node dereferences and prefetches are counted and
//! surfaced through `mem_stats`-style counters for Table XII.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use crate::mem::arena::ThreadTallies;
use crate::mem::{ArenaNode, ArenaOptions, BlockArena, PoolStats};
use crate::sync::Backoff;
use crate::util::rng::mix64;

use super::{BatchOp, BatchReply};

pub const MAX_LEVEL: usize = 16;

const NIL_IDX: u32 = u32::MAX;
const MARK: u64 = 1 << 63;
const GEN_MASK: u64 = ((1u64 << 31) - 1) << 32;

#[inline(always)]
fn link(gen: u32, idx: u32) -> u64 {
    ((gen as u64 & 0x7FFF_FFFF) << 32) | idx as u64
}

#[inline(always)]
fn link_idx(l: u64) -> u32 {
    l as u32
}

#[inline(always)]
fn link_gen(l: u64) -> u32 {
    ((l & GEN_MASK) >> 32) as u32
}

#[inline(always)]
fn is_marked(l: u64) -> bool {
    l & MARK != 0
}

#[inline(always)]
fn unmarked(l: u64) -> u64 {
    l & !MARK
}

const NIL: u64 = NIL_IDX as u64; // unmarked, gen 0, idx NIL

/// Hot plane: everything a tower descent dereferences.
struct RHot {
    key: AtomicU64,
    /// next links per level; `tower[0]` is the full list.
    tower: [AtomicU64; MAX_LEVEL],
    /// highest valid tower level (inclusive).
    top: AtomicU32,
}

impl RHot {
    fn empty() -> RHot {
        RHot {
            key: AtomicU64::new(0),
            tower: std::array::from_fn(|_| AtomicU64::new(NIL)),
            top: AtomicU32::new(0),
        }
    }
}

/// Cold plane: the payload and the recycle generation.
struct RCold {
    value: AtomicU64,
    gen: AtomicU32,
}

/// Tag type naming the randomized node's hot/cold split.
struct RNode;

impl ArenaNode for RNode {
    type Hot = RHot;
    type Cold = RCold;

    fn vacant_hot() -> RHot {
        RHot::empty()
    }

    fn vacant_cold() -> RCold {
        RCold { value: AtomicU64::new(0), gen: AtomicU32::new(0) }
    }

    fn generation(cold: &RCold) -> &AtomicU32 {
        &cold.gen
    }
}

// Counter indices in the per-thread tally slots (see `mem::arena::ThreadTallies`).
const TALLY_DEREFS: usize = 0;
const TALLY_PREFETCHES: usize = 1;

/// Lock-free randomized skiplist mapping `u64 -> u64`.
pub struct RandomSkiplist {
    arena: BlockArena<RNode>,
    head: Box<RHot>, // virtual -inf node; its tower anchors every level
    len: AtomicU64,
    seed: AtomicU64,
    retries: AtomicU64,
    /// Hashed padded per-thread hot-path counters (Table XII
    /// derefs/prefetches) — per-traversal counting must never bounce a
    /// shared stats line.
    tallies: ThreadTallies<2>,
}

#[derive(Clone, Copy)]
struct FindResult {
    preds: [u64; MAX_LEVEL], // link to pred per level; HEAD_LINK for head
    succs: [u64; MAX_LEVEL],
    found: Option<u64>, // link of the node with the key (level-0 succ)
}

/// Marker for "the head anchors this level" in `preds`.
const HEAD_LINK: u64 = (NIL_IDX as u64) | (1 << 62);

/// Upper bound on the interleaved engine's pipeline width (same rationale
/// as the deterministic list's bound: lane state must stay L1-resident).
const MAX_INTERLEAVE: usize = 32;

/// Automaton restarts per op before the interleaved engine resolves the op
/// with a blocking `get` (guaranteed progress under churn).
const LANE_RETRY_LIMIT: u32 = 8;

/// One in-flight tower descent of [`RandomSkiplist::get_many`]: the lane's
/// slice of the run plus the `(level, pred, curr)` cursor of its unrolled
/// Harris walk.
struct GetLane {
    /// Next op index (into the whole run) this lane resolves.
    i: usize,
    /// Exclusive end of the lane's chunk.
    end: usize,
    lvl: usize,
    pred: u64,
    curr: u64,
    started: bool,
    retries: u32,
}

impl RandomSkiplist {
    pub fn new() -> RandomSkiplist {
        Self::with_capacity(1 << 20)
    }

    pub fn with_capacity(capacity: usize) -> RandomSkiplist {
        Self::with_capacity_on(capacity, ArenaOptions::default())
    }

    /// Like [`RandomSkiplist::with_capacity`] with explicit arena placement
    /// (per-shard skiplists home their arena on the shard's NUMA node).
    pub fn with_capacity_on(capacity: usize, opts: ArenaOptions) -> RandomSkiplist {
        RandomSkiplist {
            arena: BlockArena::for_capacity(capacity, opts),
            head: Box::new(RHot::empty()),
            len: AtomicU64::new(0),
            seed: AtomicU64::new(0x5EED),
            retries: AtomicU64::new(0),
            tallies: ThreadTallies::new(opts.threads_hint),
        }
    }

    /// Flush one traversal's local counts into this thread's padded line.
    #[inline]
    fn flush_tally(&self, derefs: u64, prefetches: u64) {
        let t = self.tallies.slot();
        t.0[TALLY_DEREFS].fetch_add(derefs, Ordering::Relaxed);
        if prefetches > 0 {
            t.0[TALLY_PREFETCHES].fetch_add(prefetches, Ordering::Relaxed);
        }
    }

    #[inline]
    fn raw(&self, idx: u32) -> &RHot {
        self.arena.hot(idx)
    }

    /// Resolve an unmarked link; None on generation mismatch (recycled).
    #[inline]
    fn resolve(&self, l: u64) -> Option<&RHot> {
        let idx = link_idx(l);
        if self.arena.cold(idx).gen.load(Ordering::Acquire) & 0x7FFF_FFFF == link_gen(l) {
            Some(self.raw(idx))
        } else {
            None
        }
    }

    /// Load the tower slot `lvl` of the node behind link `l` (or the head).
    #[inline]
    fn tower(&self, l: u64, lvl: usize) -> &AtomicU64 {
        if l == HEAD_LINK {
            &self.head.tower[lvl]
        } else {
            &self.raw(link_idx(l)).tower[lvl]
        }
    }

    fn alloc(&self, key: u64, value: u64, top: u32) -> u64 {
        let idx = self.arena.alloc_slot();
        let hot = self.raw(idx);
        let cold = self.arena.cold(idx);
        hot.key.store(key, Ordering::Relaxed);
        cold.value.store(value, Ordering::Relaxed);
        hot.top.store(top, Ordering::Relaxed);
        link(cold.gen.load(Ordering::Acquire), idx)
    }

    fn retire(&self, l: u64) {
        // generation bump + recycle accounting live in the unified arena
        self.arena.retire_slot(link_idx(l));
    }

    /// §V arena accounting (allocs/recycled/capacity/locality).
    pub fn mem_stats(&self) -> PoolStats {
        self.arena.stats()
    }

    /// Hot-line dereferences across every traversal (Table XII proxy).
    pub fn deref_count(&self) -> u64 {
        self.tallies.sum(TALLY_DEREFS)
    }

    /// Software prefetches issued by `find`/`range` (Table XII).
    pub fn prefetch_count(&self) -> u64 {
        self.tallies.sum(TALLY_PREFETCHES)
    }

    /// Geometric tower height (p = 1/2), capped at MAX_LEVEL.
    fn random_level(&self) -> u32 {
        let s = self.seed.fetch_add(0x9E37_79B9, Ordering::Relaxed);
        let r = mix64(s);
        ((r.trailing_ones()) as u32).min(MAX_LEVEL as u32 - 1)
    }

    /// Harris find with helping. Err(()) = restart (interference/recycle).
    /// Prefetches the successor's hot line while `curr` is examined, so the
    /// dependent per-hop misses overlap ("Skiplists with Foresight").
    fn find(&self, key: u64) -> Result<FindResult, ()> {
        self.find_hinted(key, None)
    }

    /// [`RandomSkiplist::find`] with tower reuse: each level's walk may
    /// start at the predecessor a previous nearby find recorded instead of
    /// wherever the level above left off (the sorted-run bulk path — for
    /// ascending keys most levels start one or two hops from the target).
    ///
    /// A hint entry is only a *shortcut*, adopted when it still resolves
    /// (generation match — a recycled node can never be adopted, and a live
    /// one's key and tower height are immutable, so `tower[lvl]` is valid:
    /// a node only ever appears in `preds[lvl]` with `top >= lvl`), is
    /// **unmarked at this level**, and its key lies strictly below the
    /// target. Everything after adoption is the ordinary walk with its own
    /// mark/generation checks.
    ///
    /// Safety: for *writes*, a stale predecessor is harmless because
    /// unlinking a node at a level first marks its link word, so any CAS
    /// through it fails on the mark bit and the caller refreshes — a hint
    /// can cost a retry, never a wrong link. For *reads* (the level-0
    /// `found` answer), the mark check is load-bearing: a node is unlinked
    /// only after it is marked, so an unmarked-at-adoption predecessor was
    /// linked at an instant inside this operation, and its successor chain
    /// reflects every insert that completed before the operation began.
    /// (An unlinked node's *frozen* successor pointer can bypass keys
    /// inserted after its unlink — without the mark check, a hint carried
    /// from a previous op could make this op miss a key whose insert
    /// finished before it started: a non-linearizable miss. With the
    /// check, any bypassed insert is concurrent with this op.)
    fn find_hinted(&self, key: u64, hint: Option<&FindResult>) -> Result<FindResult, ()> {
        let mut preds = [HEAD_LINK; MAX_LEVEL];
        let mut succs = [NIL; MAX_LEVEL];
        let mut pred = HEAD_LINK;
        let mut pred_key: Option<u64> = None; // None = head (-inf)
        let mut derefs = 0u64;
        let mut prefetches = 0u64;
        let out = 'walk: {
            for lvl in (0..MAX_LEVEL).rev() {
                if let Some(h) = hint {
                    let cand = h.preds[lvl];
                    if cand != HEAD_LINK && cand != pred {
                        derefs += 1;
                        if let Some(cn) = self.resolve(cand) {
                            let ck = cn.key.load(Ordering::Relaxed);
                            // unmarked at this level = linked at an instant
                            // inside this op (see the safety note above)
                            let live = !is_marked(cn.tower[lvl].load(Ordering::Acquire));
                            // re-validate: key and mark were read while live
                            if self.resolve(cand).is_some()
                                && live
                                && ck < key
                                && pred_key.map_or(true, |pk| ck > pk)
                            {
                                pred = cand;
                                pred_key = Some(ck);
                            }
                        }
                    }
                }
                let mut curr = unmarked(self.tower(pred, lvl).load(Ordering::Acquire));
                loop {
                    if link_idx(curr) == NIL_IDX {
                        break;
                    }
                    derefs += 1;
                    let Some(cn) = self.resolve(curr) else {
                        break 'walk Err(());
                    };
                    let csucc = cn.tower[lvl].load(Ordering::Acquire);
                    // re-validate the node was live when we read its link
                    if self.resolve(curr).is_none() {
                        break 'walk Err(());
                    }
                    // overlap the next hop's miss with this node's checks
                    prefetches += self.arena.prefetch_hot(link_idx(unmarked(csucc))) as u64;
                    if is_marked(csucc) {
                        // help unlink curr at this level
                        if self
                            .tower(pred, lvl)
                            .compare_exchange(curr, unmarked(csucc), Ordering::AcqRel, Ordering::Acquire)
                            .is_err()
                        {
                            break 'walk Err(());
                        }
                        curr = unmarked(csucc);
                        continue;
                    }
                    let ckey = cn.key.load(Ordering::Relaxed);
                    if self.resolve(curr).is_none() {
                        break 'walk Err(());
                    }
                    if ckey < key {
                        pred = curr;
                        pred_key = Some(ckey);
                        curr = unmarked(csucc);
                    } else {
                        break;
                    }
                }
                preds[lvl] = pred;
                succs[lvl] = curr;
            }
            let found = if link_idx(succs[0]) != NIL_IDX {
                let Some(n) = self.resolve(succs[0]) else {
                    break 'walk Err(());
                };
                if n.key.load(Ordering::Relaxed) == key && self.resolve(succs[0]).is_some() {
                    Some(succs[0])
                } else {
                    None
                }
            } else {
                None
            };
            Ok(FindResult { preds, succs, found })
        };
        self.flush_tally(derefs, prefetches);
        out
    }

    /// Insert; false if the key exists.
    pub fn insert(&self, key: u64, value: u64) -> bool {
        self.insert_hinted(key, value, None).0
    }

    /// [`RandomSkiplist::insert`] with a tower hint from a previous nearby
    /// find; returns the result plus the predecessor set for carrying into
    /// the next sorted-run op. The hint is used for the first search only —
    /// any interference retries with a fresh full find.
    fn insert_hinted(
        &self,
        key: u64,
        value: u64,
        hint: Option<&FindResult>,
    ) -> (bool, Option<FindResult>) {
        let top = self.random_level();
        let mut b = Backoff::new();
        let mut hint = hint;
        loop {
            let Ok(f) = self.find_hinted(key, hint.take()) else {
                self.retries.fetch_add(1, Ordering::Relaxed);
                b.wait();
                continue;
            };
            if f.found.is_some() {
                return (false, Some(f));
            }
            let nl = self.alloc(key, value, top);
            let nn = self.raw(link_idx(nl));
            for lvl in 0..=top as usize {
                nn.tower[lvl].store(f.succs[lvl], Ordering::Relaxed);
            }
            // link bottom level (the linearization point)
            if self.tower(f.preds[0], 0)
                .compare_exchange(f.succs[0], nl, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                // undo the allocation and retry
                self.retire(nl);
                self.retries.fetch_add(1, Ordering::Relaxed);
                b.wait();
                continue;
            }
            self.len.fetch_add(1, Ordering::Relaxed);
            // link upper levels (best effort with refresh)
            for lvl in 1..=top as usize {
                loop {
                    let own = nn.tower[lvl].load(Ordering::Acquire);
                    if is_marked(own) {
                        return (true, Some(f)); // concurrently removed; stop linking
                    }
                    if self.tower(f.preds[lvl], lvl)
                        .compare_exchange(f.succs[lvl], nl, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        break;
                    }
                    // refresh preds/succs
                    let Ok(f2) = self.find(key) else {
                        return (true, Some(f)); // node is in (bottom linked); give up on upper levels
                    };
                    if f2.found != Some(nl) {
                        return (true, Some(f2)); // removed meanwhile
                    }
                    let expected = nn.tower[lvl].load(Ordering::Acquire);
                    if is_marked(expected) {
                        return (true, Some(f2));
                    }
                    if nn.tower[lvl]
                        .compare_exchange(expected, f2.succs[lvl], Ordering::AcqRel, Ordering::Acquire)
                        .is_err()
                    {
                        return (true, Some(f2));
                    }
                    // retry CAS with refreshed pred
                    if self.tower(f2.preds[lvl], lvl)
                        .compare_exchange(f2.succs[lvl], nl, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        break;
                    }
                }
            }
            return (true, Some(f));
        }
    }

    /// Remove; false if not present.
    pub fn erase(&self, key: u64) -> bool {
        self.erase_hinted(key, None).0
    }

    /// [`RandomSkiplist::erase`] with a tower hint (see
    /// [`RandomSkiplist::insert_hinted`]); the hint feeds the first search
    /// only.
    fn erase_hinted(&self, key: u64, hint: Option<&FindResult>) -> (bool, Option<FindResult>) {
        let mut b = Backoff::new();
        let mut hint = hint;
        loop {
            let Ok(f) = self.find_hinted(key, hint.take()) else {
                self.retries.fetch_add(1, Ordering::Relaxed);
                b.wait();
                continue;
            };
            let Some(nl) = f.found else {
                return (false, Some(f));
            };
            let Some(n) = self.resolve(nl) else {
                continue;
            };
            let top = n.top.load(Ordering::Relaxed) as usize;
            // mark upper levels
            for lvl in (1..=top).rev() {
                loop {
                    let s = n.tower[lvl].load(Ordering::Acquire);
                    if is_marked(s) {
                        break;
                    }
                    if n.tower[lvl]
                        .compare_exchange(s, s | MARK, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        break;
                    }
                }
                if self.resolve(nl).is_none() {
                    return (false, Some(f)); // recycled under us: someone else removed it
                }
            }
            // mark bottom level — the linearization point
            loop {
                let s = n.tower[0].load(Ordering::Acquire);
                if is_marked(s) {
                    return (false, Some(f)); // another eraser won
                }
                if self.resolve(nl).is_none() {
                    return (false, Some(f));
                }
                if n.tower[0]
                    .compare_exchange(s, s | MARK, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    self.len.fetch_sub(1, Ordering::Relaxed);
                    // physical cleanup, then recycle
                    let _ = self.find(key);
                    self.retire(nl);
                    return (true, Some(f));
                }
            }
        }
    }

    /// Lookup.
    pub fn get(&self, key: u64) -> Option<u64> {
        self.get_hinted(key, None).0
    }

    /// [`RandomSkiplist::get`] with a tower hint (see
    /// [`RandomSkiplist::insert_hinted`]).
    fn get_hinted(&self, key: u64, hint: Option<&FindResult>) -> (Option<u64>, Option<FindResult>) {
        let mut b = Backoff::new();
        let mut hint = hint;
        loop {
            match self.find_hinted(key, hint.take()) {
                Ok(f) => {
                    let Some(l) = f.found else {
                        return (None, Some(f));
                    };
                    if self.resolve(l).is_none() {
                        continue;
                    }
                    let v = self.arena.cold(link_idx(l)).value.load(Ordering::Relaxed);
                    if self.resolve(l).is_none() {
                        continue;
                    }
                    return (Some(v), Some(f));
                }
                Err(()) => {
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    b.wait();
                }
            }
        }
    }

    /// Apply a key-sorted run of mixed operations, reusing each op's tower
    /// predecessors as the next op's search hint — the randomized list's
    /// analogue of the deterministic list's fused path carry. `sink(idx,
    /// reply)` fires once per op in run order; semantics are identical to
    /// the per-key loop (ops apply strictly left to right).
    pub fn apply_sorted_run(&self, ops: &[BatchOp], sink: &mut dyn FnMut(usize, BatchReply)) {
        debug_assert!(super::is_sorted_run(ops), "run must be key-sorted");
        let mut hint: Option<FindResult> = None;
        for (i, op) in ops.iter().enumerate() {
            match *op {
                BatchOp::Insert(k, v) => {
                    let (ok, f) = self.insert_hinted(k, v, hint.as_ref());
                    hint = f;
                    sink(i, BatchReply::Applied(ok));
                }
                BatchOp::Erase(k) => {
                    let (ok, f) = self.erase_hinted(k, hint.as_ref());
                    hint = f;
                    sink(i, BatchReply::Applied(ok));
                }
                BatchOp::Get(k) => {
                    let (v, f) = self.get_hinted(k, hint.as_ref());
                    hint = f;
                    sink(i, BatchReply::Value(v));
                }
            }
        }
    }

    /// Apply a key-sorted run with up to `width` overlapped tower descents
    /// — the randomized list's memory-level-parallelism analogue of
    /// [`crate::skiplist::DetSkiplist::apply_interleaved`]. Each scheduler
    /// visit takes one hop of one lane's Harris walk and issues the
    /// prefetch for that lane's next hot line, so the per-hop dependent
    /// misses of `width` descents overlap.
    ///
    /// Only all-`Get` runs interleave: the write protocol (multi-level CAS
    /// with helping) has no single-hop slice point that preserves its retry
    /// discipline, so mixed runs degrade to the fused
    /// [`RandomSkiplist::apply_sorted_run`]. Lane chunks are contiguous and
    /// never split an equal-key group; replies fire once per op, in lane
    /// (not run) order.
    pub fn apply_interleaved(&self, ops: &[BatchOp], width: usize, sink: &mut dyn FnMut(usize, BatchReply)) {
        debug_assert!(super::is_sorted_run(ops), "run must be key-sorted");
        if ops.is_empty() {
            return;
        }
        if ops.iter().any(|o| !matches!(o, BatchOp::Get(_))) {
            return self.apply_sorted_run(ops, sink);
        }
        let lanes_n = width.clamp(1, MAX_INTERLEAVE).min(ops.len());
        let mut lanes: Vec<GetLane> = Vec::with_capacity(lanes_n);
        let mut start = 0usize;
        for l in 0..lanes_n {
            let mut end =
                if l + 1 == lanes_n { ops.len() } else { ((l + 1) * ops.len()) / lanes_n };
            end = end.max(start);
            while end > start && end < ops.len() && ops[end].key() == ops[end - 1].key() {
                end += 1;
            }
            lanes.push(GetLane {
                i: start,
                end,
                lvl: 0,
                pred: HEAD_LINK,
                curr: NIL,
                started: false,
                retries: 0,
            });
            start = end;
        }
        let mut derefs = 0u64;
        let mut prefetches = 0u64;
        let mut active = lanes.iter().filter(|l| l.i < l.end).count();
        while active > 0 {
            for lane in lanes.iter_mut() {
                if lane.i >= lane.end {
                    continue;
                }
                self.interleave_get_step(ops, lane, sink, &mut derefs, &mut prefetches);
                if lane.i >= lane.end {
                    active -= 1;
                }
            }
        }
        self.flush_tally(derefs, prefetches);
    }

    /// Interleaved point lookups in *input* order (any order, duplicates
    /// allowed); unsorted inputs route through a sorting permutation.
    pub fn get_many(&self, keys: &[u64], width: usize) -> Vec<Option<u64>> {
        let mut out = vec![None; keys.len()];
        if keys.is_empty() {
            return out;
        }
        if keys.windows(2).all(|w| w[0] <= w[1]) {
            let ops: Vec<BatchOp> = keys.iter().map(|&k| BatchOp::Get(k)).collect();
            self.apply_interleaved(&ops, width, &mut |i, r| {
                if let BatchReply::Value(v) = r {
                    out[i] = v;
                }
            });
        } else {
            let mut order: Vec<u32> = (0..keys.len() as u32).collect();
            order.sort_by_key(|&i| keys[i as usize]);
            let ops: Vec<BatchOp> =
                order.iter().map(|&i| BatchOp::Get(keys[i as usize])).collect();
            self.apply_interleaved(&ops, width, &mut |i, r| {
                if let BatchReply::Value(v) = r {
                    out[order[i] as usize] = v;
                }
            });
        }
        out
    }

    /// One scheduler visit to a lane: start the next op's descent from the
    /// head tower, or take one hop of the in-flight Harris walk (with the
    /// same help-unlink and generation re-validation as `find_hinted`).
    fn interleave_get_step(
        &self,
        ops: &[BatchOp],
        lane: &mut GetLane,
        sink: &mut dyn FnMut(usize, BatchReply),
        derefs: &mut u64,
        prefetches: &mut u64,
    ) {
        let key = ops[lane.i].key();
        if !lane.started {
            if lane.retries > LANE_RETRY_LIMIT {
                // interference keeps breaking this walk: resolve blocking
                let v = self.get(key);
                sink(lane.i, BatchReply::Value(v));
                lane.i += 1;
                lane.retries = 0;
                return;
            }
            lane.lvl = MAX_LEVEL - 1;
            lane.pred = HEAD_LINK;
            lane.curr = unmarked(self.head.tower[lane.lvl].load(Ordering::Acquire));
            *prefetches += self.arena.prefetch_hot(link_idx(lane.curr)) as u64;
            lane.started = true;
            return;
        }
        if link_idx(lane.curr) == NIL_IDX {
            if lane.lvl == 0 {
                // walked off the full list: not present
                sink(lane.i, BatchReply::Value(None));
                lane.i += 1;
                lane.started = false;
                lane.retries = 0;
            } else {
                lane.lvl -= 1;
                lane.curr = unmarked(self.tower(lane.pred, lane.lvl).load(Ordering::Acquire));
                *prefetches += self.arena.prefetch_hot(link_idx(lane.curr)) as u64;
            }
            return;
        }
        *derefs += 1;
        let Some(cn) = self.resolve(lane.curr) else {
            return self.get_lane_fail(lane);
        };
        let csucc = cn.tower[lane.lvl].load(Ordering::Acquire);
        // re-validate the node was live when we read its link
        if self.resolve(lane.curr).is_none() {
            return self.get_lane_fail(lane);
        }
        // the next hop's miss goes in flight while other lanes step
        *prefetches += self.arena.prefetch_hot(link_idx(unmarked(csucc))) as u64;
        if is_marked(csucc) {
            // help unlink curr at this level
            if self
                .tower(lane.pred, lane.lvl)
                .compare_exchange(lane.curr, unmarked(csucc), Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                return self.get_lane_fail(lane);
            }
            lane.curr = unmarked(csucc);
            return;
        }
        let ckey = cn.key.load(Ordering::Relaxed);
        if self.resolve(lane.curr).is_none() {
            return self.get_lane_fail(lane);
        }
        if ckey < key {
            lane.pred = lane.curr;
            lane.curr = unmarked(csucc);
            return;
        }
        // first unmarked node with key >= target at this level
        if lane.lvl > 0 {
            lane.lvl -= 1;
            lane.curr = unmarked(self.tower(lane.pred, lane.lvl).load(Ordering::Acquire));
            *prefetches += self.arena.prefetch_hot(link_idx(lane.curr)) as u64;
            return;
        }
        let v = if ckey == key {
            let val = self.arena.cold(link_idx(lane.curr)).value.load(Ordering::Relaxed);
            if self.resolve(lane.curr).is_none() {
                return self.get_lane_fail(lane);
            }
            Some(val)
        } else {
            None
        };
        sink(lane.i, BatchReply::Value(v));
        lane.i += 1;
        lane.started = false;
        lane.retries = 0;
    }

    /// A lane's walk raced an unlink/recycle: restart the op's descent.
    fn get_lane_fail(&self, lane: &mut GetLane) {
        self.retries.fetch_add(1, Ordering::Relaxed);
        lane.started = false;
        lane.retries += 1;
    }

    pub fn contains(&self, key: u64) -> bool {
        self.get(key).is_some()
    }

    pub fn len(&self) -> u64 {
        self.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn retry_count(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Collect all `(key, value)` with `lo <= key <= hi`: tower descent to
    /// the first node >= `lo`, then a lock-free walk of the full-density
    /// level-0 list (marked nodes are skipped; interference retries; the
    /// next hop's hot line is prefetched while the current row is read).
    pub fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        if lo > hi {
            return Vec::new();
        }
        let mut b = Backoff::new();
        'retry: loop {
            let Ok(f) = self.find(lo) else {
                self.retries.fetch_add(1, Ordering::Relaxed);
                b.wait();
                continue 'retry;
            };
            let mut out = Vec::new();
            let mut cur = f.succs[0];
            let mut derefs = 0u64;
            let mut prefetches = 0u64;
            let flush = |derefs: u64, prefetches: u64| self.flush_tally(derefs, prefetches);
            loop {
                if link_idx(cur) == NIL_IDX {
                    flush(derefs, prefetches);
                    return out;
                }
                derefs += 1;
                let Some(n) = self.resolve(cur) else {
                    flush(derefs, prefetches);
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    b.wait();
                    continue 'retry;
                };
                let succ = n.tower[0].load(Ordering::Acquire);
                let k = n.key.load(Ordering::Relaxed);
                let v = self.arena.cold(link_idx(cur)).value.load(Ordering::Relaxed);
                // re-validate: the snapshot above must predate any recycle
                if self.resolve(cur).is_none() {
                    flush(derefs, prefetches);
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    b.wait();
                    continue 'retry;
                }
                prefetches += self.arena.prefetch_hot(link_idx(unmarked(succ))) as u64;
                if k > hi {
                    flush(derefs, prefetches);
                    return out;
                }
                if !is_marked(succ) && k >= lo {
                    out.push((k, v));
                }
                cur = unmarked(succ);
            }
        }
    }

    /// Quiescent structural check: level-0 sorted, towers consistent.
    pub fn check_invariants(&self) -> Result<Vec<u64>, String> {
        let mut keys = Vec::new();
        let mut cur = unmarked(self.head.tower[0].load(Ordering::Acquire));
        let mut prev: Option<u64> = None;
        while link_idx(cur) != NIL_IDX {
            let n = self.resolve(cur).ok_or("stale link in level 0")?;
            let k = n.key.load(Ordering::Relaxed);
            if let Some(p) = prev {
                if k <= p {
                    return Err(format!("level 0 keys not increasing: {p} -> {k}"));
                }
            }
            prev = Some(k);
            keys.push(k);
            cur = unmarked(n.tower[0].load(Ordering::Acquire));
        }
        // every upper-level list must be a subsequence of level 0
        for lvl in 1..MAX_LEVEL {
            let mut cur = unmarked(self.head.tower[lvl].load(Ordering::Acquire));
            let mut prev: Option<u64> = None;
            while link_idx(cur) != NIL_IDX {
                let n = self.resolve(cur).ok_or("stale link in upper level")?;
                let k = n.key.load(Ordering::Relaxed);
                if is_marked(n.tower[lvl].load(Ordering::Acquire)) {
                    return Err(format!("marked node reachable at level {lvl}"));
                }
                if let Some(p) = prev {
                    if k <= p {
                        return Err(format!("level {lvl} keys not increasing"));
                    }
                }
                if keys.binary_search(&k).is_err() {
                    return Err(format!("level {lvl} key {k} missing from level 0"));
                }
                prev = Some(k);
                cur = unmarked(n.tower[lvl].load(Ordering::Acquire));
            }
        }
        if keys.len() as u64 != self.len() {
            return Err(format!("len {} != level-0 count {}", self.len(), keys.len()));
        }
        Ok(keys)
    }
}

impl Default for RandomSkiplist {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeSet;
    use std::sync::Arc;

    #[test]
    fn insert_find_erase_sequential() {
        let s = RandomSkiplist::with_capacity(1 << 12);
        assert!(s.insert(5, 50));
        assert!(s.insert(1, 10));
        assert!(s.insert(9, 90));
        assert!(!s.insert(5, 55), "duplicate");
        assert_eq!(s.get(5), Some(50));
        assert_eq!(s.get(2), None);
        assert!(s.erase(5));
        assert!(!s.erase(5));
        assert_eq!(s.get(5), None);
        assert_eq!(s.len(), 2);
        s.check_invariants().unwrap();
        assert!(s.deref_count() > 0, "traversals must be counted");
    }

    #[test]
    fn matches_btreeset_oracle() {
        let s = RandomSkiplist::with_capacity(1 << 14);
        let mut oracle = BTreeSet::new();
        let mut rng = Rng::new(42);
        for _ in 0..5_000 {
            let k = rng.below(500);
            match rng.below(3) {
                0 => assert_eq!(s.insert(k, k), oracle.insert(k), "insert {k}"),
                1 => assert_eq!(s.erase(k), oracle.remove(&k), "erase {k}"),
                _ => assert_eq!(s.contains(k), oracle.contains(&k), "find {k}"),
            }
        }
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys, oracle.into_iter().collect::<Vec<_>>());
    }

    #[test]
    fn range_and_batches_match_btreemap() {
        use crate::coordinator::OrderedKv;
        use std::collections::BTreeMap;
        let s = RandomSkiplist::with_capacity(1 << 14);
        let mut oracle = BTreeMap::new();
        // k*5 mod 997 is injective for k < 997 (5 coprime to the prime 997)
        let items: Vec<(u64, u64)> = (0..400u64).map(|k| (k * 5 % 997, k)).collect();
        for &(k, v) in &items {
            oracle.insert(k, v);
        }
        assert_eq!(s.insert_batch(&items), 400);
        assert_eq!(s.insert_batch(&items), 0, "all duplicates");
        let got_keys: Vec<u64> = s.range(0, 1_000).iter().map(|&(k, _)| k).collect();
        assert_eq!(got_keys, oracle.keys().copied().collect::<Vec<_>>());
        // windowed ranges are sorted and bounded
        let w = s.range(100, 300);
        assert!(w.windows(2).all(|p| p[0].0 < p[1].0));
        assert!(w.iter().all(|&(k, _)| (100..=300).contains(&k)));
        // batch erase of half the keys
        let evens: Vec<u64> = oracle.keys().copied().filter(|k| k % 2 == 0).collect();
        assert_eq!(s.erase_batch(&evens), evens.len() as u64);
        assert!(s.range(0, 1_000).iter().all(|&(k, _)| k % 2 == 1));
        assert_eq!(s.range(500, 400), vec![]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_disjoint_inserts() {
        let s = Arc::new(RandomSkiplist::with_capacity(1 << 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..2_000u64 {
                    assert!(s.insert(t * 10_000 + i, i));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 8_000);
        let keys = s.check_invariants().unwrap();
        assert_eq!(keys.len(), 8_000);
    }

    #[test]
    fn concurrent_mixed_against_oracle_keys() {
        // concurrent inserts/erases over a small key space; final state must
        // be a subset of the key space with consistent membership
        let s = Arc::new(RandomSkiplist::with_capacity(1 << 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..3_000 {
                    let k = rng.below(128);
                    if rng.chance(1, 2) {
                        s.insert(k, k * 2);
                    } else {
                        s.erase(k);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let keys = s.check_invariants().unwrap();
        for k in keys {
            assert!(k < 128);
            assert_eq!(s.get(k), Some(k * 2));
        }
    }

    #[test]
    fn sorted_run_matches_per_key_replay() {
        use crate::skiplist::{BatchOp, BatchReply};
        let mut rng = Rng::new(31);
        for round in 0..8 {
            let fused = RandomSkiplist::with_capacity(1 << 14);
            let twin = RandomSkiplist::with_capacity(1 << 14);
            for k in 0..150u64 {
                fused.insert(k * 4, k);
                twin.insert(k * 4, k);
            }
            let mut ops = Vec::new();
            for _ in 0..250 {
                let k = rng.below(700);
                ops.push(match rng.below(3) {
                    0 => BatchOp::Insert(k, k ^ 9),
                    1 => BatchOp::Erase(k),
                    _ => BatchOp::Get(k),
                });
            }
            ops.sort_by_key(|o| o.key()); // stable: duplicates keep op order
            let mut got = vec![None; ops.len()];
            fused.apply_sorted_run(&ops, &mut |i, r| got[i] = Some(r));
            for (i, op) in ops.iter().enumerate() {
                let want = match *op {
                    BatchOp::Insert(k, v) => BatchReply::Applied(twin.insert(k, v)),
                    BatchOp::Erase(k) => BatchReply::Applied(twin.erase(k)),
                    BatchOp::Get(k) => BatchReply::Value(twin.get(k)),
                };
                assert_eq!(got[i], Some(want), "round {round} op {i} {op:?}");
            }
            assert_eq!(
                fused.check_invariants().unwrap(),
                twin.check_invariants().unwrap(),
                "round {round}"
            );
        }
    }

    #[test]
    fn tower_reuse_cuts_derefs_on_sorted_runs() {
        use crate::skiplist::BatchOp;
        let keys: Vec<u64> = (0..2_048u64).map(|k| 50_000 + k).collect();
        let fused = RandomSkiplist::with_capacity(1 << 14);
        let run: Vec<BatchOp> = keys.iter().map(|&k| BatchOp::Insert(k, k)).collect();
        fused.apply_sorted_run(&run, &mut |_, _| {});
        let run: Vec<BatchOp> = keys.iter().map(|&k| BatchOp::Get(k)).collect();
        fused.apply_sorted_run(&run, &mut |_, _| {});
        let fused_derefs = fused.deref_count();

        let per_key = RandomSkiplist::with_capacity(1 << 14);
        for &k in &keys {
            per_key.insert(k, k);
        }
        for &k in &keys {
            per_key.get(k);
        }
        let per_key_derefs = per_key.deref_count();
        assert!(
            fused_derefs < per_key_derefs,
            "tower reuse must strictly cut derefs ({fused_derefs} vs {per_key_derefs})"
        );
        assert_eq!(fused.len(), per_key.len());
        fused.check_invariants().unwrap();
    }

    #[test]
    fn recycled_allocs_are_counted() {
        // Regression: the old inline arena's recycled path skipped recycle
        // accounting entirely, so reuse was invisible to stats.
        let s = RandomSkiplist::with_capacity(1 << 12);
        for k in 0..500u64 {
            assert!(s.insert(k, k));
            assert!(s.erase(k));
        }
        let st = s.mem_stats();
        assert_eq!(st.retired, 500);
        assert!(st.recycled > 400, "reuse must be visible: recycled={}", st.recycled);
        assert_eq!(st.retired, st.recycled + st.free_residue + st.overflow, "no lost nodes");
        assert_eq!(st.blocks, 1, "alternating churn must stay in one block");
    }

    #[test]
    fn get_many_matches_point_gets_any_width() {
        let s = RandomSkiplist::with_capacity(1 << 14);
        let mut rng = Rng::new(17);
        for _ in 0..4_000 {
            let k = rng.below(1 << 18);
            s.insert(k, k.wrapping_mul(3));
        }
        let mut keys = Vec::new();
        for _ in 0..1_024 {
            keys.push(rng.below(1 << 18));
        }
        keys.push(keys[0]); // duplicate probe
        let expect: Vec<Option<u64>> = keys.iter().map(|&k| s.get(k)).collect();
        for width in [1usize, 4, 8, 64] {
            assert_eq!(s.get_many(&keys, width), expect, "width {width} diverged");
        }
    }

    #[test]
    fn get_many_under_concurrent_churn() {
        let s = Arc::new(RandomSkiplist::with_capacity(1 << 16));
        // stable keys are never touched by the churners
        for k in 0..2_000u64 {
            s.insert(k * 10 + 5, k);
        }
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t + 100);
                for _ in 0..20_000 {
                    let k = rng.below(2_000) * 10 + t + 1; // never ...5
                    if rng.chance(1, 2) {
                        s.insert(k, k);
                    } else {
                        s.erase(k);
                    }
                }
            }));
        }
        for t in 0..2u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..200 {
                    let keys: Vec<u64> =
                        (0..128).map(|_| rng.below(2_000) * 10 + 5).collect();
                    let got = s.get_many(&keys, 8);
                    for (j, &k) in keys.iter().enumerate() {
                        assert_eq!(got[j], Some(k / 10), "stable key {k} lost");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn random_levels_are_geometricish() {
        let s = RandomSkiplist::new();
        let mut counts = [0u32; MAX_LEVEL];
        for _ in 0..10_000 {
            counts[s.random_level() as usize] += 1;
        }
        assert!(counts[0] > 4_000 && counts[0] < 6_000, "p(level 0) ~ 1/2");
        assert!(counts[1] > 1_800 && counts[1] < 3_200, "p(level 1) ~ 1/4");
    }
}
