//! Skiplists (paper §II, §VI).
//!
//! - [`DetSkiplist`] — the paper's contribution: concurrent deterministic
//!   1-2-3-4 skiplist with lock-free `Find` ([`FindMode::LockFree`],
//!   "lkfreefind") or the RWL baseline ([`FindMode::ReadLocked`], "RWL").
//! - [`RandomSkiplist`] — the lock-free randomized skiplist baseline of
//!   Table IV ("lkfreeRandomSL").
//!
//! Both answer the fused sorted-batch protocol ([`BatchOp`]/[`BatchReply`]):
//! a key-sorted run of mixed operations applied with one left-to-right
//! traversal that carries the search position between consecutive keys —
//! the deterministic list carries its per-level predecessor path
//! (`DetSkiplist::apply_sorted_run`), the randomized list reuses the
//! previous key's tower predecessors (`RandomSkiplist::apply_sorted_run`).

pub mod det;
pub mod node;
pub mod random;
pub mod replica;

pub use det::{DetSkiplist, FindMode, SkiplistStats, MAX_KEY};
pub use node::{DEFAULT_INNER_CAP, DEFAULT_LEAF_CAP, MAX_INNER_CAP, MAX_LEAF_CAP};
pub use random::RandomSkiplist;
pub use replica::ReplicaStats;

/// One element of a key-sorted mixed-operation run — the unit the fused
/// batch descents consume. Runs may contain duplicate keys; ops are applied
/// strictly left to right, so a run behaves exactly like the equivalent
/// per-key loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchOp {
    /// Insert `key -> value` (set semantics: a resident key is not
    /// overwritten and replies `Applied(false)`).
    Insert(u64, u64),
    /// Remove `key`; replies `Applied(present)`.
    Erase(u64),
    /// Look `key` up; replies `Value(..)`.
    Get(u64),
}

impl BatchOp {
    /// The key this op targets (runs are sorted by it).
    #[inline]
    pub fn key(&self) -> u64 {
        match *self {
            BatchOp::Insert(k, _) | BatchOp::Erase(k) | BatchOp::Get(k) => k,
        }
    }
}

/// Per-op outcome of a fused run, delivered through the sink callback with
/// the op's index in the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchReply {
    /// `Insert` / `Erase`: whether the mutation applied.
    Applied(bool),
    /// `Get`: the value, if present.
    Value(Option<u64>),
}

/// `true` when `ops` is a valid key-sorted run (ascending, duplicates
/// allowed) — the precondition of every `apply_sorted_run` implementation.
#[inline]
pub fn is_sorted_run(ops: &[BatchOp]) -> bool {
    ops.windows(2).all(|w| w[0].key() <= w[1].key())
}
