//! Skiplists (paper §II, §VI).
//!
//! - [`DetSkiplist`] — the paper's contribution: concurrent deterministic
//!   1-2-3-4 skiplist with lock-free `Find` ([`FindMode::LockFree`],
//!   "lkfreefind") or the RWL baseline ([`FindMode::ReadLocked`], "RWL").
//! - [`RandomSkiplist`] — the lock-free randomized skiplist baseline of
//!   Table IV ("lkfreeRandomSL").

pub mod det;
pub mod node;
pub mod random;

pub use det::{DetSkiplist, FindMode, SkiplistStats, MAX_KEY};
pub use random::RandomSkiplist;
