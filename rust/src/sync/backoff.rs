//! Contention backoff tuned for oversubscribed cores.
//!
//! The evaluation host runs many more threads than cores (see
//! DESIGN.md §Hardware-Adaptation), so pure spinning deadlocks progress:
//! the lock holder is likely *descheduled*. We spin only a few iterations,
//! then yield to the OS scheduler, then sleep with exponentially growing
//! intervals.

/// Exponential backoff helper. Create one per contended loop.
#[derive(Debug, Default)]
pub struct Backoff {
    step: u32,
}

const SPIN_STEPS: u32 = 4;
const YIELD_STEPS: u32 = 12;

impl Backoff {
    #[inline]
    pub fn new() -> Self {
        Backoff { step: 0 }
    }

    /// Wait once; escalates spin -> yield -> sleep across calls.
    #[inline]
    pub fn wait(&mut self) {
        if self.step < SPIN_STEPS {
            for _ in 0..(1 << self.step) {
                std::hint::spin_loop();
            }
        } else if self.step < YIELD_STEPS {
            std::thread::yield_now();
        } else {
            let exp = (self.step - YIELD_STEPS).min(6);
            std::thread::sleep(std::time::Duration::from_micros(1 << exp));
        }
        self.step = self.step.saturating_add(1);
    }

    /// True once waiting has escalated past pure spinning (used by tests and
    /// adaptive retry loops to decide when to re-validate global state).
    #[inline]
    pub fn is_yielding(&self) -> bool {
        self.step >= SPIN_STEPS
    }

    #[inline]
    pub fn reset(&mut self) {
        self.step = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escalates() {
        let mut b = Backoff::new();
        assert!(!b.is_yielding());
        for _ in 0..SPIN_STEPS {
            b.wait();
        }
        assert!(b.is_yielding());
        b.reset();
        assert!(!b.is_yielding());
    }

    #[test]
    fn wait_many_times_is_bounded() {
        let mut b = Backoff::new();
        let t0 = std::time::Instant::now();
        for _ in 0..YIELD_STEPS + 10 {
            b.wait();
        }
        // sleep growth is capped at 64us per wait
        assert!(t0.elapsed().as_millis() < 2_000);
    }
}
