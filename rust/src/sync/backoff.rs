//! Contention backoff tuned for oversubscribed cores.
//!
//! The evaluation host runs many more threads than cores (see
//! DESIGN.md §Hardware-Adaptation), so pure spinning deadlocks progress:
//! the lock holder is likely *descheduled*. We spin only a few iterations,
//! then yield to the OS scheduler, then park with exponentially growing
//! timeouts — explicitly capped, so one `wait()` call never blocks longer
//! than [`Backoff::MAX_PARK`]. This is the retry primitive every fabric
//! recovery loop leans on (chaos takeover, deadline waits), which is why
//! the progression is observable ([`Backoff::phase`]) and unit-tested.

use std::time::Duration;

/// Where a [`Backoff`] currently sits in its spin → yield → park
/// escalation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Busy-wait with `spin_loop` hints (cheap, latency-optimal while the
    /// peer is running on another core).
    Spin,
    /// `yield_now` to the OS scheduler (the peer is probably descheduled).
    Yield,
    /// `park_timeout` with exponentially growing, capped timeouts (the
    /// wait is long; release the CPU entirely — a future `unpark` can
    /// still end the wait early).
    Park,
}

/// Exponential backoff helper. Create one per contended loop.
#[derive(Debug, Default)]
pub struct Backoff {
    step: u32,
}

const SPIN_STEPS: u32 = 4;
const YIELD_STEPS: u32 = 12;
/// Cap on the park-phase exponent: timeouts grow 1us, 2us, ... and stop
/// doubling at `1 << PARK_CAP_EXP` microseconds.
const PARK_CAP_EXP: u32 = 6;

impl Backoff {
    /// Longest a single [`wait`](Backoff::wait) can block (the park-phase
    /// timeout cap).
    pub const MAX_PARK: Duration = Duration::from_micros(1 << PARK_CAP_EXP);

    #[inline]
    pub fn new() -> Self {
        Backoff { step: 0 }
    }

    /// Wait once; escalates spin -> yield -> park across calls.
    #[inline]
    pub fn wait(&mut self) {
        match self.phase() {
            Phase::Spin => {
                for _ in 0..(1 << self.step) {
                    std::hint::spin_loop();
                }
            }
            Phase::Yield => std::thread::yield_now(),
            Phase::Park => {
                // Capped exponential park. park_timeout may return early
                // (spurious wakeup or a peer's unpark) — both are fine for
                // a backoff: we only promise an upper bound.
                std::thread::park_timeout(self.park_timeout());
            }
        }
        self.step = self.step.saturating_add(1);
    }

    /// Current escalation phase (what the *next* [`wait`](Backoff::wait)
    /// will do).
    #[inline]
    pub fn phase(&self) -> Phase {
        if self.step < SPIN_STEPS {
            Phase::Spin
        } else if self.step < YIELD_STEPS {
            Phase::Yield
        } else {
            Phase::Park
        }
    }

    /// Timeout the next park-phase wait would use (monotone, capped at
    /// [`Backoff::MAX_PARK`]).
    #[inline]
    fn park_timeout(&self) -> Duration {
        let exp = self.step.saturating_sub(YIELD_STEPS).min(PARK_CAP_EXP);
        Duration::from_micros(1 << exp)
    }

    /// True once waiting has escalated past pure spinning (used by tests and
    /// adaptive retry loops to decide when to re-validate global state).
    #[inline]
    pub fn is_yielding(&self) -> bool {
        self.step >= SPIN_STEPS
    }

    #[inline]
    pub fn reset(&mut self) {
        self.step = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escalates_through_all_three_phases_in_order() {
        let mut b = Backoff::new();
        let mut seen = Vec::new();
        for _ in 0..YIELD_STEPS + 4 {
            let p = b.phase();
            if seen.last() != Some(&p) {
                seen.push(p);
            }
            b.wait();
        }
        assert_eq!(seen, [Phase::Spin, Phase::Yield, Phase::Park]);
    }

    #[test]
    fn phase_boundaries_match_constants() {
        let mut b = Backoff::new();
        assert_eq!(b.phase(), Phase::Spin);
        assert!(!b.is_yielding());
        for _ in 0..SPIN_STEPS {
            b.wait();
        }
        assert_eq!(b.phase(), Phase::Yield);
        assert!(b.is_yielding());
        for _ in SPIN_STEPS..YIELD_STEPS {
            b.wait();
        }
        assert_eq!(b.phase(), Phase::Park);
        b.reset();
        assert_eq!(b.phase(), Phase::Spin);
        assert!(!b.is_yielding());
    }

    #[test]
    fn park_timeout_grows_monotonically_and_caps() {
        let mut b = Backoff::new();
        for _ in 0..YIELD_STEPS {
            b.wait();
        }
        let mut prev = Duration::ZERO;
        for _ in 0..PARK_CAP_EXP + 8 {
            let t = b.park_timeout();
            assert!(t >= prev, "timeout must not shrink: {t:?} < {prev:?}");
            assert!(t <= Backoff::MAX_PARK, "timeout must stay capped: {t:?}");
            prev = t;
            b.step = b.step.saturating_add(1); // advance without sleeping
        }
        assert_eq!(prev, Backoff::MAX_PARK, "growth reaches the cap");
    }

    #[test]
    fn wait_many_times_is_bounded() {
        let mut b = Backoff::new();
        let t0 = std::time::Instant::now();
        for _ in 0..YIELD_STEPS + 10 {
            b.wait();
        }
        // park growth is capped at MAX_PARK per wait
        assert!(t0.elapsed().as_millis() < 2_000);
    }

    #[test]
    fn saturates_instead_of_overflowing() {
        let mut b = Backoff { step: u32::MAX - 1 };
        b.wait(); // must not panic on step arithmetic
        assert_eq!(b.phase(), Phase::Park);
    }
}
