//! Node-granularity locks.
//!
//! [`RwSpinLock`] is the per-node / per-slot reader-writer lock used by the
//! skiplist (L- and LL-shaped exclusive acquisitions) and the hash tables
//! (shared `find`, exclusive `insert`/`erase`), standing in for TBB's
//! `spin_rw_mutex`. Writer-preferring so rebalancing cannot be starved by a
//! stream of readers.  Guards are intentionally *not* RAII in the core
//! skiplist code (the paper's `Acquire`/`Release` are explicit and the
//! release order is algorithmic), so raw `lock`/`unlock` are public; RAII
//! wrappers exist for the simpler hash-table use.

use std::sync::atomic::{AtomicU32, Ordering};

use super::backoff::Backoff;

const WRITER: u32 = 1 << 31;
const WRITER_WAIT: u32 = 1 << 30;
const READER_MASK: u32 = WRITER_WAIT - 1;

/// Writer-preferring reader-writer spinlock (4 bytes).
#[derive(Debug, Default)]
pub struct RwSpinLock {
    state: AtomicU32,
}

impl RwSpinLock {
    pub const fn new() -> Self {
        RwSpinLock { state: AtomicU32::new(0) }
    }

    /// Exclusive lock.
    #[inline]
    pub fn lock(&self) {
        let mut b = Backoff::new();
        loop {
            let s = self.state.load(Ordering::Relaxed);
            if s & (WRITER | READER_MASK) == 0 {
                if self
                    .state
                    .compare_exchange_weak(s, (s | WRITER) & !WRITER_WAIT, Ordering::Acquire, Ordering::Relaxed)
                    .is_ok()
                {
                    return;
                }
            } else if s & WRITER_WAIT == 0 {
                // announce a waiting writer so new readers hold off
                let _ = self.state.compare_exchange_weak(
                    s,
                    s | WRITER_WAIT,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                );
            }
            b.wait();
        }
    }

    /// Try exclusive lock.
    #[inline]
    pub fn try_lock(&self) -> bool {
        let s = self.state.load(Ordering::Relaxed);
        s & (WRITER | READER_MASK) == 0
            && self
                .state
                .compare_exchange(s, (s | WRITER) & !WRITER_WAIT, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
    }

    #[inline]
    pub fn unlock(&self) {
        let prev = self.state.fetch_and(!WRITER, Ordering::Release);
        debug_assert!(prev & WRITER != 0, "unlock of unlocked RwSpinLock");
    }

    /// Shared lock.
    #[inline]
    pub fn lock_shared(&self) {
        let mut b = Backoff::new();
        loop {
            let s = self.state.load(Ordering::Relaxed);
            if s & (WRITER | WRITER_WAIT) == 0 {
                if self
                    .state
                    .compare_exchange_weak(s, s + 1, Ordering::Acquire, Ordering::Relaxed)
                    .is_ok()
                {
                    return;
                }
            }
            b.wait();
        }
    }

    #[inline]
    pub fn try_lock_shared(&self) -> bool {
        let s = self.state.load(Ordering::Relaxed);
        s & (WRITER | WRITER_WAIT) == 0
            && self
                .state
                .compare_exchange(s, s + 1, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
    }

    #[inline]
    pub fn unlock_shared(&self) {
        let prev = self.state.fetch_sub(1, Ordering::Release);
        debug_assert!(prev & READER_MASK != 0, "unlock_shared without readers");
    }

    /// RAII exclusive guard.
    #[inline]
    pub fn write(&self) -> WriteGuard<'_> {
        self.lock();
        WriteGuard { lock: self }
    }

    /// RAII shared guard.
    #[inline]
    pub fn read(&self) -> ReadGuard<'_> {
        self.lock_shared();
        ReadGuard { lock: self }
    }

    /// True if currently write-locked (diagnostics only).
    pub fn is_write_locked(&self) -> bool {
        self.state.load(Ordering::Relaxed) & WRITER != 0
    }
}

pub struct WriteGuard<'a> {
    lock: &'a RwSpinLock,
}

impl Drop for WriteGuard<'_> {
    fn drop(&mut self) {
        self.lock.unlock();
    }
}

pub struct ReadGuard<'a> {
    lock: &'a RwSpinLock,
}

impl Drop for ReadGuard<'_> {
    fn drop(&mut self) {
        self.lock.unlock_shared();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn exclusive_mutual_exclusion() {
        let lock = Arc::new(RwSpinLock::new());
        let counter = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let lock = lock.clone();
            let counter = counter.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..5_000 {
                    lock.lock();
                    // non-atomic read-modify-write protected by the lock
                    let v = counter.load(Ordering::Relaxed);
                    counter.store(v + 1, Ordering::Relaxed);
                    lock.unlock();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::Relaxed), 20_000);
    }

    #[test]
    fn readers_are_concurrent_writers_exclusive() {
        let lock = Arc::new(RwSpinLock::new());
        let readers = Arc::new(AtomicU64::new(0));
        let in_writer = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..3 {
            let (lock, readers, in_writer) = (lock.clone(), readers.clone(), in_writer.clone());
            handles.push(std::thread::spawn(move || {
                for _ in 0..2_000 {
                    let _g = lock.read();
                    readers.fetch_add(1, Ordering::Relaxed);
                    assert_eq!(in_writer.load(Ordering::Relaxed), 0);
                    readers.fetch_sub(1, Ordering::Relaxed);
                }
            }));
        }
        for _ in 0..2 {
            let (lock, readers, in_writer) = (lock.clone(), readers.clone(), in_writer.clone());
            handles.push(std::thread::spawn(move || {
                for _ in 0..1_000 {
                    let _g = lock.write();
                    in_writer.store(1, Ordering::Relaxed);
                    assert_eq!(readers.load(Ordering::Relaxed), 0);
                    in_writer.store(0, Ordering::Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn try_lock_fails_under_writer() {
        let lock = RwSpinLock::new();
        lock.lock();
        assert!(!lock.try_lock());
        assert!(!lock.try_lock_shared());
        lock.unlock();
        assert!(lock.try_lock_shared());
        assert!(!lock.try_lock());
        lock.unlock_shared();
        assert!(lock.try_lock());
        lock.unlock();
    }
}
