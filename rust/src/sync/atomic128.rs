//! 128-bit atomic word for the packed `(key, next)` pair.
//!
//! The paper stores a 64-bit key in the upper half and a 64-bit pointer in
//! the lower half of one wide integer so that `Find` can read both with a
//! single atomic load and `Addition`/`Deletion` can update both with a single
//! atomic store — that is what makes the lock-free `Find` sound.
//!
//! On x86_64 this is implemented with `lock cmpxchg16b` (both the load and
//! the store are CAS loops; an aligned SSE load is *not* guaranteed atomic
//! pre-AVX, so we don't use it). Other architectures fall back to a seqlock.

use std::cell::UnsafeCell;

/// A 16-byte-aligned atomic u128.
#[repr(C, align(16))]
pub struct AtomicU128 {
    #[cfg(target_arch = "x86_64")]
    cell: UnsafeCell<u128>,
    #[cfg(not(target_arch = "x86_64"))]
    seq: std::sync::atomic::AtomicU64,
    #[cfg(not(target_arch = "x86_64"))]
    cell: UnsafeCell<u128>,
}

unsafe impl Send for AtomicU128 {}
unsafe impl Sync for AtomicU128 {}

#[cfg(target_arch = "x86_64")]
impl AtomicU128 {
    pub const fn new(v: u128) -> Self {
        AtomicU128 { cell: UnsafeCell::new(v) }
    }

    /// Raw cmpxchg16b: returns the previous value (== `expected` on success).
    #[inline]
    fn cmpxchg16b(&self, expected: u128, new: u128) -> u128 {
        let dst = self.cell.get();
        let (mut lo, mut hi) = (expected as u64, (expected >> 64) as u64);
        let (new_lo, new_hi) = (new as u64, (new >> 64) as u64);
        unsafe {
            // rbx is LLVM-reserved as an asm operand, but the generic `reg`
            // class may still allocate it for other operands — pin every
            // register explicitly and shuttle new_lo through rsi around the
            // cmpxchg16b (restoring rbx with the second xchg).
            std::arch::asm!(
                "xchg rbx, rsi",
                "lock cmpxchg16b [rdi]",
                "xchg rbx, rsi",
                in("rdi") dst,
                inout("rsi") new_lo => _,
                inout("rax") lo,
                inout("rdx") hi,
                in("rcx") new_hi,
                options(nostack),
            );
        }
        (hi as u128) << 64 | lo as u128
    }

    #[inline]
    pub fn load(&self) -> u128 {
        // cmpxchg16b with new == expected never changes memory and returns
        // the current value in rdx:rax.
        self.cmpxchg16b(0, 0)
    }

    #[inline]
    pub fn store(&self, v: u128) {
        let mut cur = self.load();
        loop {
            let prev = self.cmpxchg16b(cur, v);
            if prev == cur {
                return;
            }
            cur = prev;
        }
    }

    /// CAS; returns Ok(prev) on success, Err(actual) on failure.
    #[inline]
    pub fn compare_exchange(&self, expected: u128, new: u128) -> Result<u128, u128> {
        let prev = self.cmpxchg16b(expected, new);
        if prev == expected {
            Ok(prev)
        } else {
            Err(prev)
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
impl AtomicU128 {
    pub const fn new(v: u128) -> Self {
        AtomicU128 {
            seq: std::sync::atomic::AtomicU64::new(0),
            cell: UnsafeCell::new(v),
        }
    }

    // Seqlock fallback: writers serialize on odd seq; readers retry on a
    // seq change. Writers spin-wait for an even seq.
    #[inline]
    pub fn load(&self) -> u128 {
        use std::sync::atomic::Ordering::*;
        loop {
            let s0 = self.seq.load(Acquire);
            if s0 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let v = unsafe { std::ptr::read_volatile(self.cell.get()) };
            std::sync::atomic::fence(Acquire);
            if self.seq.load(Relaxed) == s0 {
                return v;
            }
        }
    }

    #[inline]
    pub fn store(&self, v: u128) {
        use std::sync::atomic::Ordering::*;
        loop {
            let s0 = self.seq.load(Relaxed);
            if s0 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            if self
                .seq
                .compare_exchange_weak(s0, s0 + 1, Acquire, Relaxed)
                .is_ok()
            {
                unsafe { std::ptr::write_volatile(self.cell.get(), v) };
                self.seq.store(s0 + 2, Release);
                return;
            }
        }
    }

    #[inline]
    pub fn compare_exchange(&self, expected: u128, new: u128) -> Result<u128, u128> {
        use std::sync::atomic::Ordering::*;
        loop {
            let s0 = self.seq.load(Relaxed);
            if s0 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            if self
                .seq
                .compare_exchange_weak(s0, s0 + 1, Acquire, Relaxed)
                .is_ok()
            {
                let cur = unsafe { std::ptr::read_volatile(self.cell.get()) };
                let r = if cur == expected {
                    unsafe { std::ptr::write_volatile(self.cell.get(), new) };
                    Ok(cur)
                } else {
                    Err(cur)
                };
                self.seq.store(s0 + 2, Release);
                return r;
            }
        }
    }
}

/// Pack `(key, lo64)` into one u128: key in the upper half, pointer/index in
/// the lower half (the paper's layout: bits 127:64 key, 63:0 next).
#[inline(always)]
pub const fn pack(key: u64, lo: u64) -> u128 {
    (key as u128) << 64 | lo as u128
}

/// Upper half (the key).
#[inline(always)]
pub const fn hi64(v: u128) -> u64 {
    (v >> 64) as u64
}

/// Lower half (the next pointer).
#[inline(always)]
pub const fn lo64(v: u128) -> u64 {
    v as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn pack_unpack() {
        let v = pack(0xDEAD_BEEF_0000_0001, 0x1234_5678_9ABC_DEF0);
        assert_eq!(hi64(v), 0xDEAD_BEEF_0000_0001);
        assert_eq!(lo64(v), 0x1234_5678_9ABC_DEF0);
    }

    #[test]
    fn load_store_roundtrip() {
        let a = AtomicU128::new(7);
        assert_eq!(a.load(), 7);
        a.store(pack(u64::MAX, 42));
        assert_eq!(hi64(a.load()), u64::MAX);
        assert_eq!(lo64(a.load()), 42);
    }

    #[test]
    fn cas_semantics() {
        let a = AtomicU128::new(1);
        assert_eq!(a.compare_exchange(1, 2), Ok(1));
        assert_eq!(a.compare_exchange(1, 3), Err(2));
        assert_eq!(a.load(), 2);
    }

    #[test]
    fn concurrent_torn_write_detection() {
        // Writers alternate between two values whose halves must never mix;
        // readers assert they only ever observe whole values.
        let a = Arc::new(AtomicU128::new(pack(1, 1)));
        let v1 = pack(1, 1);
        let v2 = pack(u64::MAX, u64::MAX);
        let mut handles = Vec::new();
        for w in 0..2 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..20_000 {
                    a.store(if w == 0 { v1 } else { v2 });
                }
            }));
        }
        for _ in 0..2 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..20_000 {
                    let v = a.load();
                    assert!(v == v1 || v == v2, "torn read: {v:#034x}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn concurrent_cas_counter() {
        // 4 threads x 10k CAS-increments over both halves simultaneously.
        let a = Arc::new(AtomicU128::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    let mut cur = a.load();
                    loop {
                        let next = pack(hi64(cur) + 1, lo64(cur) + 1);
                        match a.compare_exchange(cur, next) {
                            Ok(_) => break,
                            Err(actual) => cur = actual,
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load(), pack(40_000, 40_000));
    }
}
