//! Concurrency primitives: the 128-bit atomic `(key, next)` word, node
//! reader-writer spinlocks and oversubscription-aware backoff.

pub mod atomic128;
pub mod backoff;
pub mod lock;

pub use atomic128::{hi64, lo64, pack, AtomicU128};
pub use backoff::{Backoff, Phase};
pub use lock::RwSpinLock;
