//! Memory management (paper §V): one unified block arena with per-thread
//! magazines, generation-validated recycling and NUMA placement accounting.
//!
//! [`BlockArena`] is the single allocator body in the crate; both skiplists,
//! both split-order hash tables and the typed [`NodePool`] façade run on it
//! (DESIGN.md §Unified-mem-layer).

pub mod arena;
pub mod pool;

pub use arena::{
    note_thread_cpu, thread_cpu, ArenaHome, ArenaNode, ArenaOptions, BlockArena, PoolStats,
};
pub use pool::{eq5_average_blocks, NodePool};
