//! Memory management (paper §V): block allocation + lock-free recycling.

pub mod pool;

pub use pool::{eq5_average_blocks, NodePool, PoolStats};
