//! The unified block arena (paper §V) — the **one** node allocator in the
//! crate. Every arena-backed structure (both skiplists, both split-order
//! tables, and the typed [`super::NodePool`] façade) instantiates a
//! [`BlockArena`] instead of carrying its own copy of the block directory /
//! bump / free-list machinery.
//!
//! Layout is the paper's block manager: node memory is allocated in blocks
//! (one heap allocation per `block_size` slots), registered in a
//! preallocated directory, and **never returned to the OS before the arena
//! drops** — the property that keeps stale links dereferenceable while
//! generation counters catch reuse. `alloc_slot` linearizes at the bump
//! fetch-add or at a free-list pop; `retire_slot` linearizes at the
//! generation bump (every existing reference is invalidated there).
//!
//! **Two-plane (hot/cold) layout.** Every block is stored as a *pair* of
//! parallel arrays: a **hot** plane holding the fields a traversal actually
//! reads (for the deterministic skiplist: the packed `(key, next)` word,
//! `bottom` and `level`, packed into one 64-byte line), and a **cold**
//! plane holding control state touched only by writers or validation
//! (lock, mark, generation, value). A descent therefore streams through
//! tightly packed hot lines instead of dragging every node's lock word and
//! value into cache — the locality discipline the B-skiplist line of work
//! (arXiv:2506.13864-style hot/cold splitting) shows is where skiplist
//! throughput actually lives. Each [`ArenaNode`] implementation chooses its
//! own split; single-plane users put everything in `Hot` and only the
//! generation word in `Cold`.
//!
//! On top of §V this adds two things the paper's evaluation motivates:
//!
//! - **Per-thread magazines.** Each thread exchanges slots through a small
//!   thread-local stack (32 slots, spilling half when full) instead of
//!   hammering one shared free list — in steady-state churn the alloc and
//!   retire hot paths touch only a cache-line-padded, effectively
//!   thread-private magazine, not the shared atomics whose remote-access
//!   ping-pong dominates at scale (arXiv:1902.06891, arXiv:2606.13321).
//!   Magazines hash threads onto a padded power-of-two array sized to 2x
//!   the expected thread count (`ArenaOptions::threads_hint`; the sharded
//!   store passes its worker count, so the paper's 128-thread sweep stays
//!   collision-free), and the protocol stays correct (a magazine is a
//!   mutex-guarded stack) even if two threads do collide.
//! - **Placement accounting.** An arena can be *homed* on a (virtual) NUMA
//!   node ([`ArenaHome`]); every alloc then records whether the calling
//!   thread's pinned CPU lives on the home node, giving the per-shard
//!   locality-hit-rate the §VI sharding argument predicts.
//!
//! The shared free list is sized to the arena's **full node capacity** and
//! pushed with a bounded-retry `try_push`: the previous per-structure
//! copies used a fixed 4096×64-slot blocking queue, so a mass-erase phase
//! larger than the queue spun forever inside `retire`. A quiescent mass
//! erase can no longer fill the list; under concurrency a straggler can
//! transiently pin a drained queue block and make the final retry fail, in
//! which case the slot is dropped and counted in `overflow` — a bounded,
//! observable leak instead of the old unbounded spin (see the `mem_churn`
//! regression tests).

use std::cell::Cell;
use std::sync::atomic::{AtomicPtr, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::numa::Topology;
use crate::queue::{ConcurrentQueue, LfQueue};
use crate::sync::Backoff;
use crate::util::fail;
use crate::util::prefetch::prefetch_read;

/// Slots cached per magazine before spilling to the shared free list.
const MAG_SLOTS: usize = 32;
/// How many slots a full magazine spills (the oldest half; the newest —
/// cache-hot — half stays with the thread).
const MAG_SPILL: usize = MAG_SLOTS / 2;

/// Magazine array size: 2x the expected thread count (collisions then stay
/// rare even with hashed thread slots), power of two for mask indexing,
/// floored so small configs still spread test threads out. `threads_hint`
/// 0 means "size from the host" — note the engine oversubscribes a small
/// host with up to 128 virtual workers, which is why `ShardedStore` passes
/// its real thread count instead of relying on the host default.
/// (Also reused by the skiplist's per-thread search-finger array, which
/// hashes threads onto padded slots with exactly the same policy.)
pub(crate) fn magazine_count(threads_hint: usize) -> usize {
    let threads = if threads_hint > 0 {
        threads_hint
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    (threads * 2).clamp(32, 512).next_power_of_two()
}

static NEXT_THREAD_SLOT: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Small dense id per OS thread (assigned on first arena use).
    static THREAD_SLOT: usize = NEXT_THREAD_SLOT.fetch_add(1, Ordering::Relaxed);
    /// Virtual CPU the thread was pinned to (`usize::MAX` = never pinned).
    static THREAD_CPU: Cell<usize> = const { Cell::new(usize::MAX) };
}

/// Dense per-OS-thread id; the magazine AND search-finger arrays hash on it.
#[inline]
pub(crate) fn thread_slot() -> usize {
    THREAD_SLOT.with(|s| *s)
}

/// Record the calling thread's (virtual) CPU for arena locality accounting.
/// `numa::pin_to_cpu` calls this, so pinned workers are tracked for free;
/// unpinned threads (tests, the leader) count as local.
pub fn note_thread_cpu(cpu: usize) {
    THREAD_CPU.with(|c| c.set(cpu));
}

/// One cache-line-padded slot of `K` relaxed counters (padded so
/// hashed-slot neighbours never false-share).
#[repr(align(128))]
pub(crate) struct TallySlot<const K: usize>(pub [AtomicU64; K]);

/// Hashed per-thread counter array — the **one** hot-path instrumentation
/// primitive in the crate (both skiplists count derefs/prefetches/finger
/// traffic through it). Sized exactly like the magazines
/// ([`magazine_count`]), keyed by [`thread_slot`]: per-op counting lands on
/// an effectively thread-private padded line, never a shared stats word
/// that would make the instrumentation the bottleneck it measures.
pub(crate) struct ThreadTallies<const K: usize> {
    slots: Box<[TallySlot<K>]>,
}

impl<const K: usize> ThreadTallies<K> {
    pub(crate) fn new(threads_hint: usize) -> ThreadTallies<K> {
        ThreadTallies {
            slots: (0..magazine_count(threads_hint))
                .map(|_| TallySlot(std::array::from_fn(|_| AtomicU64::new(0))))
                .collect(),
        }
    }

    /// The calling thread's padded counter line.
    #[inline]
    pub(crate) fn slot(&self) -> &TallySlot<K> {
        &self.slots[thread_slot() & (self.slots.len() - 1)]
    }

    /// Sum counter `i` across every thread's slot.
    pub(crate) fn sum(&self, i: usize) -> u64 {
        self.slots.iter().map(|s| s.0[i].load(Ordering::Relaxed)).sum()
    }
}

/// The calling thread's (virtual) CPU as recorded by [`note_thread_cpu`]
/// (`usize::MAX` = unpinned, counted as local everywhere). Public so the
/// NUMA index replicas can charge their derefs to the right node without
/// re-deriving pinning state.
#[inline]
pub fn thread_cpu() -> usize {
    THREAD_CPU.with(|c| c.get())
}

/// A type that can live in a [`BlockArena`] slot, split into a hot plane
/// (fields the traversal fast path reads) and a cold plane (control state:
/// at minimum the recycle generation).
///
/// Both planes are **fully constructed** when their block materializes (via
/// [`ArenaNode::vacant_hot`] / [`ArenaNode::vacant_cold`]) and dropped
/// normally when the arena drops — there is no `MaybeUninit` in the generic
/// layer, so a future node type with a `Drop` impl cannot silently leak
/// (the typed `NodePool` façade keeps the uninitialized-payload model and
/// therefore bounds its payload on `Copy`).
///
/// `Self` is only a *tag* naming the split (implementations are usually
/// empty marker types); the arena stores `Hot` and `Cold` values, never
/// `Self`.
pub trait ArenaNode {
    /// Hot-plane slot: what a descent dereferences.
    type Hot: Send + Sync;
    /// Cold-plane slot: control words (lock/mark/value) plus the generation.
    type Cold: Send + Sync;

    /// A vacant hot slot (links cleared).
    fn vacant_hot() -> Self::Hot;

    /// A vacant cold slot (generation 0).
    fn vacant_cold() -> Self::Cold;

    /// The recycle-generation word; [`BlockArena::retire_slot`] bumps it,
    /// invalidating every reference that embeds the old generation. It
    /// lives in the cold plane so retire/validation traffic never dirties
    /// hot descent lines.
    fn generation(cold: &Self::Cold) -> &AtomicU32;

    /// Called once per plane pair, with the slot's global index, when its
    /// block materializes (before any other thread can observe the slot).
    fn on_materialize(_hot: &mut Self::Hot, _cold: &mut Self::Cold, _idx: u32) {}
}

/// Home placement of an arena on the (virtual) NUMA grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArenaHome {
    pub node: usize,
    pub numa_nodes: usize,
    pub cpus_per_node: usize,
}

impl ArenaHome {
    /// Home an arena on `node` of `topo` (eq. 7 picks `node` per shard).
    pub fn on(node: usize, topo: &Topology) -> ArenaHome {
        ArenaHome {
            node,
            numa_nodes: topo.numa_nodes,
            cpus_per_node: topo.cpus_per_node.max(1),
        }
    }

    #[inline]
    fn is_local(&self, cpu: usize) -> bool {
        cpu == usize::MAX || (cpu / self.cpus_per_node) % self.numa_nodes == self.node
    }
}

/// Arena construction options.
#[derive(Clone, Copy, Debug)]
pub struct ArenaOptions {
    /// Placement for locality accounting; `None` = untracked (all local).
    pub home: Option<ArenaHome>,
    /// Per-thread magazine cache on the alloc/retire paths. When `false`
    /// the arena runs the pre-unification path — shared free list plus
    /// shared relaxed counters, no magazine mutex anywhere — so the `t10`
    /// ablation measures the real baseline.
    pub magazines: bool,
    /// Expected worker-thread count; sizes the magazine array (2x, power
    /// of two, min 32). 0 = derive from the host's parallelism.
    pub threads_hint: usize,
    /// Width (in `u64` words) of the optional third **leaf plane**: a
    /// variable-stride parallel array of `AtomicU64` words per slot, used
    /// by the fat-leaf skiplist for contiguous multi-key terminal chunks.
    /// 0 (the default) allocates no leaf plane.
    pub leaf_words: usize,
}

impl Default for ArenaOptions {
    fn default() -> Self {
        ArenaOptions { home: None, magazines: true, threads_hint: 0, leaf_words: 0 }
    }
}

impl ArenaOptions {
    /// Options for a shard arena homed on `node` of `topo`, serving up to
    /// `threads` workers.
    pub fn placed(node: usize, topo: &Topology, threads: usize) -> ArenaOptions {
        ArenaOptions {
            home: Some(ArenaHome::on(node, topo)),
            magazines: true,
            threads_hint: threads,
            leaf_words: 0,
        }
    }

    /// Magazine-less configuration (shared free list + shared counters
    /// only — the pre-unification behaviour, kept for the `t10` ablation).
    pub fn without_magazines() -> ArenaOptions {
        ArenaOptions { home: None, magazines: false, threads_hint: 0, leaf_words: 0 }
    }

    /// Same options with a `words`-wide leaf plane per slot (builder-style;
    /// see [`ArenaOptions::leaf_words`]).
    pub fn with_leaf_words(mut self, words: usize) -> ArenaOptions {
        self.leaf_words = words;
        self
    }
}

/// Allocation statistics for the §V analysis (eq. 5 behaviour), aggregated
/// across shards/structures with [`PoolStats::merge`].
#[derive(Debug, Default, Clone)]
pub struct PoolStats {
    /// Total `alloc` calls served.
    pub allocs: u64,
    /// `alloc`s served from recycled slots (magazine or shared free list).
    pub recycled: u64,
    /// `retire` calls.
    pub retired: u64,
    /// Blocks currently materialized.
    pub blocks: u64,
    /// `block_size * blocks` — footprint in nodes.
    pub capacity: u64,
    /// Arenas contributing to this snapshot (1 per [`BlockArena`]).
    pub arenas: u64,
    /// Subset of `recycled` served straight from the thread magazine.
    pub magazine_hits: u64,
    /// Retired-but-not-yet-recycled slots parked in magazines or the shared
    /// free list. At quiescence `retired == recycled + free_residue + overflow`.
    pub free_residue: u64,
    /// Retired slots leaked because the shared free list was full (bounded
    /// footprint cost instead of the old unbounded spin in `retire`).
    pub overflow: u64,
    /// Allocs from threads on the arena's home NUMA node.
    pub local_allocs: u64,
    /// Allocs from threads on a remote node.
    pub remote_allocs: u64,
}

impl PoolStats {
    /// Accumulate `other` (per-shard / per-table aggregation).
    pub fn merge(&mut self, other: &PoolStats) {
        self.allocs += other.allocs;
        self.recycled += other.recycled;
        self.retired += other.retired;
        self.blocks += other.blocks;
        self.capacity += other.capacity;
        self.arenas += other.arenas;
        self.magazine_hits += other.magazine_hits;
        self.free_residue += other.free_residue;
        self.overflow += other.overflow;
        self.local_allocs += other.local_allocs;
        self.remote_allocs += other.remote_allocs;
    }

    /// Fraction of allocs served from recycled slots.
    pub fn recycle_rate(&self) -> f64 {
        if self.allocs == 0 {
            0.0
        } else {
            self.recycled as f64 / self.allocs as f64
        }
    }

    /// Fraction of allocs served without touching shared state.
    pub fn magazine_hit_rate(&self) -> f64 {
        if self.allocs == 0 {
            0.0
        } else {
            self.magazine_hits as f64 / self.allocs as f64
        }
    }

    /// Fraction of (tracked) allocs issued from the arena's home node;
    /// 1.0 when placement is untracked.
    pub fn locality_hit_rate(&self) -> f64 {
        let total = self.local_allocs + self.remote_allocs;
        if total == 0 {
            1.0
        } else {
            self.local_allocs as f64 / total as f64
        }
    }
}

/// One magazine: a mutex-guarded slot stack plus the owning threads'
/// counters (the mutex is effectively thread-private, so counting under it
/// adds no shared-atomic traffic to the hot path).
struct MagStack {
    buf: [u32; MAG_SLOTS],
    len: usize,
    allocs: u64,
    mag_hits: u64,
    recycled: u64,
    retired: u64,
    overflow: u64,
    local: u64,
    remote: u64,
}

impl MagStack {
    fn new() -> MagStack {
        MagStack {
            buf: [0; MAG_SLOTS],
            len: 0,
            allocs: 0,
            mag_hits: 0,
            recycled: 0,
            retired: 0,
            overflow: 0,
            local: 0,
            remote: 0,
        }
    }

    #[inline]
    fn pop(&mut self) -> Option<u32> {
        if self.len == 0 {
            return None;
        }
        self.len -= 1;
        Some(self.buf[self.len])
    }

    #[inline]
    fn push(&mut self, idx: u32) -> bool {
        if self.len < MAG_SLOTS {
            self.buf[self.len] = idx;
            self.len += 1;
            true
        } else {
            false
        }
    }

    /// Remove and return the oldest half of a full magazine.
    fn take_spill(&mut self) -> [u32; MAG_SPILL] {
        debug_assert_eq!(self.len, MAG_SLOTS);
        let mut out = [0u32; MAG_SPILL];
        out.copy_from_slice(&self.buf[..MAG_SPILL]);
        self.buf.copy_within(MAG_SPILL.., 0);
        self.len -= MAG_SPILL;
        out
    }
}

#[repr(align(128))]
struct Magazine(Mutex<MagStack>);

/// Counters for the magazine-less ablation path (`magazines: false`):
/// shared relaxed atomics, exactly like the pre-unification allocators, so
/// the `t10` with/without comparison measures the real baseline.
#[derive(Default)]
struct SharedCounters {
    allocs: AtomicU64,
    recycled: AtomicU64,
    retired: AtomicU64,
    overflow: AtomicU64,
    local: AtomicU64,
    remote: AtomicU64,
}

/// One block's plane pointers (hot array + cold array + optional leaf
/// word array, allocated and freed together).
struct BlockPlanes<N: ArenaNode> {
    hot: AtomicPtr<N::Hot>,
    cold: AtomicPtr<N::Cold>,
    /// Variable-stride leaf plane: `block_size * leaf_words` words, or
    /// null when the arena was built with `leaf_words == 0`.
    leaf: AtomicPtr<AtomicU64>,
}

/// The unified §V block arena: index-addressed two-plane slots of `N`,
/// generation validation, magazine-cached recycling, placement accounting.
pub struct BlockArena<N: ArenaNode> {
    dir: Box<[BlockPlanes<N>]>, // one plane pair per block
    count: AtomicUsize,
    grow: Mutex<()>,
    bump: AtomicUsize,
    block_size: usize,
    /// Shared free list, sized to the arena's full node capacity.
    free: LfQueue,
    /// Power-of-two magazine array (see [`magazine_count`]).
    mags: Box<[Magazine]>,
    magazines: bool,
    /// Per-slot width of the leaf plane in `u64` words (0 = no leaf plane).
    leaf_words: usize,
    /// Ablation-path counters (used only when `magazines` is false).
    shared: SharedCounters,
    home: Option<ArenaHome>,
}

// The directory owns raw plane pointers; ArenaNode already requires
// Send + Sync for both plane slot types.
unsafe impl<N: ArenaNode> Send for BlockArena<N> {}
unsafe impl<N: ArenaNode> Sync for BlockArena<N> {}

impl<N: ArenaNode> BlockArena<N> {
    /// Arena with `block_size` slots per block, at most `max_blocks` blocks
    /// (directory preallocated, blocks lazy), default options.
    pub fn new(block_size: usize, max_blocks: usize) -> BlockArena<N> {
        Self::with_options(block_size, max_blocks, ArenaOptions::default())
    }

    /// The §V sizing policy for a structure expecting up to `capacity`
    /// live nodes: 8192-slot blocks (or one capacity-sized block when
    /// smaller), two blocks of slack. Lives here so every structure shares
    /// one policy instead of copy-pasting the arithmetic.
    pub fn for_capacity(capacity: usize, opts: ArenaOptions) -> BlockArena<N> {
        let block = 8192.min(capacity.max(16));
        let blocks = capacity.div_ceil(block) + 2;
        Self::with_options(block, blocks, opts)
    }

    pub fn with_options(block_size: usize, max_blocks: usize, opts: ArenaOptions) -> BlockArena<N> {
        assert!(block_size >= 1 && max_blocks >= 1);
        let nodes = block_size * max_blocks;
        // Free list sized to hold every slot the arena can ever retire
        // (+2 blocks of slack); pushes never block (see retire_slot).
        let qblock = nodes.clamp(2, 4096);
        let qblocks = (nodes / qblock + 2).max(2);
        BlockArena {
            dir: (0..max_blocks)
                .map(|_| BlockPlanes {
                    hot: AtomicPtr::new(std::ptr::null_mut()),
                    cold: AtomicPtr::new(std::ptr::null_mut()),
                    leaf: AtomicPtr::new(std::ptr::null_mut()),
                })
                .collect(),
            count: AtomicUsize::new(0),
            grow: Mutex::new(()),
            bump: AtomicUsize::new(0),
            block_size,
            free: LfQueue::with_config(qblock, qblocks, true),
            mags: (0..magazine_count(opts.threads_hint))
                .map(|_| Magazine(Mutex::new(MagStack::new())))
                .collect(),
            magazines: opts.magazines,
            leaf_words: opts.leaf_words,
            shared: SharedCounters::default(),
            home: opts.home,
        }
    }

    /// Per-slot leaf plane width in words (0 = no leaf plane).
    #[inline]
    pub fn leaf_words(&self) -> usize {
        self.leaf_words
    }

    /// The `leaf_words`-word leaf-plane slot for `idx`. Panics (via the
    /// unreachable null deref guard below) if the arena has no leaf plane —
    /// callers gate on [`BlockArena::leaf_words`].
    #[inline]
    pub fn leaf(&self, idx: u32) -> &[AtomicU64] {
        debug_assert!(self.leaf_words > 0, "arena has no leaf plane");
        let b = idx as usize / self.block_size;
        let s = idx as usize % self.block_size;
        debug_assert!(b < self.count.load(Ordering::Acquire));
        let base = self.dir[b].leaf.load(Ordering::Acquire);
        unsafe { std::slice::from_raw_parts(base.add(s * self.leaf_words), self.leaf_words) }
    }

    #[inline]
    fn mag(&self) -> &Mutex<MagStack> {
        &self.mags[thread_slot() & (self.mags.len() - 1)].0
    }

    /// Hot-plane slot reference. The caller must hold a live index
    /// (allocated and not recycled past its generation window).
    #[inline]
    pub fn hot(&self, idx: u32) -> &N::Hot {
        let b = idx as usize / self.block_size;
        let s = idx as usize % self.block_size;
        debug_assert!(b < self.count.load(Ordering::Acquire));
        unsafe { &*self.dir[b].hot.load(Ordering::Acquire).add(s) }
    }

    /// Cold-plane slot reference (lock/mark/generation/value words).
    #[inline]
    pub fn cold(&self, idx: u32) -> &N::Cold {
        let b = idx as usize / self.block_size;
        let s = idx as usize % self.block_size;
        debug_assert!(b < self.count.load(Ordering::Acquire));
        unsafe { &*self.dir[b].cold.load(Ordering::Acquire).add(s) }
    }

    /// Raw hot-plane slot pointer with whole-block provenance (the
    /// `NodePool` façade projects its payload field through this).
    #[inline]
    pub fn hot_ptr(&self, idx: u32) -> *mut N::Hot {
        let b = idx as usize / self.block_size;
        let s = idx as usize % self.block_size;
        debug_assert!(b < self.count.load(Ordering::Acquire));
        unsafe { self.dir[b].hot.load(Ordering::Acquire).add(s) }
    }

    /// Issue a software prefetch for `idx`'s hot line. Returns whether a
    /// prefetch was actually issued (no-op `false` when the slot's block is
    /// not materialized — a torn/stale/NIL index must never turn into
    /// out-of-bounds pointer arithmetic — so callers can keep honest
    /// prefetch counts).
    #[inline]
    pub fn prefetch_hot(&self, idx: u32) -> bool {
        let b = idx as usize / self.block_size;
        if b < self.count.load(Ordering::Acquire) {
            let p = self.dir[b].hot.load(Ordering::Acquire);
            prefetch_read(unsafe { p.add(idx as usize % self.block_size) });
            true
        } else {
            false
        }
    }

    /// Issue a software prefetch for `idx`'s leaf/chunk-plane row (the
    /// first line of its `leaf_words` slot — key arrays start there). Same
    /// bounds discipline as [`BlockArena::prefetch_hot`]: returns `false`
    /// without touching memory when the arena has no leaf plane or the
    /// slot's block is not materialized, so a torn/stale index never turns
    /// into out-of-bounds pointer arithmetic and callers can keep honest
    /// prefetch counts.
    #[inline]
    pub fn prefetch_leaf(&self, idx: u32) -> bool {
        if self.leaf_words == 0 {
            return false;
        }
        let b = idx as usize / self.block_size;
        if b < self.count.load(Ordering::Acquire) {
            let p = self.dir[b].leaf.load(Ordering::Acquire);
            if p.is_null() {
                return false;
            }
            prefetch_read(unsafe { p.add(idx as usize % self.block_size * self.leaf_words) });
            true
        } else {
            false
        }
    }

    /// Batched [`BlockArena::prefetch_hot`]: issue one prefetch per index
    /// back to back, so the whole set's misses go in flight together before
    /// any of the lines is dereferenced (the interleaved engines warm every
    /// lane's first hop this way). Returns how many were actually issued.
    pub fn prefetch_hot_many(&self, idxs: &[u32]) -> u64 {
        let mut issued = 0u64;
        for &idx in idxs {
            issued += self.prefetch_hot(idx) as u64;
        }
        issued
    }

    /// Allocate one slot: thread magazine, then shared free list, then bump.
    /// Concurrent calls always receive distinct indices.
    pub fn alloc_slot(&self) -> u32 {
        let is_local = self.home.map(|h| h.is_local(thread_cpu()));
        if !self.magazines {
            // Ablation baseline: shared free list + shared relaxed counters,
            // no magazine mutex anywhere (the pre-unification hot path).
            self.shared.allocs.fetch_add(1, Ordering::Relaxed);
            match is_local {
                Some(true) => {
                    self.shared.local.fetch_add(1, Ordering::Relaxed);
                }
                Some(false) => {
                    self.shared.remote.fetch_add(1, Ordering::Relaxed);
                }
                None => {}
            }
            if let Some(idx) = self.free.pop() {
                self.shared.recycled.fetch_add(1, Ordering::Relaxed);
                return idx as u32;
            }
            return self.bump_alloc();
        }
        let mut st = self.mag().lock().unwrap();
        st.allocs += 1;
        match is_local {
            Some(true) => st.local += 1,
            Some(false) => st.remote += 1,
            None => {}
        }
        if let Some(idx) = st.pop() {
            st.mag_hits += 1;
            st.recycled += 1;
            return idx;
        }
        // Magazine dry: refill a batch from the shared free list so the
        // next MAG_SPILL allocs stay on the fast path. Failpoint
        // "arena.refill" (chaos tests) models transient free-list
        // exhaustion by skipping the refill; the alloc falls through to
        // the bump path, so it is correctness-preserving — slots are
        // still distinct, only recycling is deferred.
        if !fail::should_fail("arena.refill") {
            if let Some(first) = self.free.pop() {
                st.recycled += 1;
                for _ in 0..MAG_SPILL {
                    match self.free.pop() {
                        Some(i) => {
                            let ok = st.push(i as u32);
                            debug_assert!(ok);
                        }
                        None => break,
                    }
                }
                return first as u32;
            }
        }
        drop(st);
        self.bump_alloc()
    }

    /// Bump-allocate a fresh slot, materializing its block if needed.
    fn bump_alloc(&self) -> u32 {
        let idx = self.bump.fetch_add(1, Ordering::AcqRel);
        let b = idx / self.block_size;
        assert!(
            b < self.dir.len(),
            "BlockArena exhausted: {} blocks of {} slots",
            self.dir.len(),
            self.block_size
        );
        while b >= self.count.load(Ordering::Acquire) {
            let _g = self.grow.lock().unwrap();
            let cur = self.count.load(Ordering::Acquire);
            if cur <= b {
                for nb in cur..=b {
                    let mut hot: Box<[N::Hot]> =
                        (0..self.block_size).map(|_| N::vacant_hot()).collect();
                    let mut cold: Box<[N::Cold]> =
                        (0..self.block_size).map(|_| N::vacant_cold()).collect();
                    for (s, (h, c)) in hot.iter_mut().zip(cold.iter_mut()).enumerate() {
                        N::on_materialize(h, c, (nb * self.block_size + s) as u32);
                    }
                    self.dir[nb].hot.store(Box::into_raw(hot) as *mut N::Hot, Ordering::Release);
                    self.dir[nb]
                        .cold
                        .store(Box::into_raw(cold) as *mut N::Cold, Ordering::Release);
                    if self.leaf_words > 0 {
                        let leaf: Box<[AtomicU64]> = (0..self.block_size * self.leaf_words)
                            .map(|_| AtomicU64::new(0))
                            .collect();
                        self.dir[nb]
                            .leaf
                            .store(Box::into_raw(leaf) as *mut AtomicU64, Ordering::Release);
                    }
                }
                self.count.store(b + 1, Ordering::Release);
            }
        }
        idx as u32
    }

    /// Retire a slot: bump its generation (every reference embedding the
    /// old generation is invalid from here) and park the index for reuse.
    /// Never blocks: a full shared free list leaks the slot and counts it
    /// in `overflow` instead of spinning (the old copies deadlocked here).
    pub fn retire_slot(&self, idx: u32) {
        N::generation(self.cold(idx)).fetch_add(1, Ordering::AcqRel);
        if !self.magazines {
            self.shared.retired.fetch_add(1, Ordering::Relaxed);
            if !self.push_free(idx) {
                self.shared.overflow.fetch_add(1, Ordering::Relaxed);
            }
            return;
        }
        let mag = self.mag();
        let mut st = mag.lock().unwrap();
        st.retired += 1;
        if st.push(idx) {
            return;
        }
        let spill = st.take_spill();
        let ok = st.push(idx);
        debug_assert!(ok);
        drop(st);
        let mut dropped = 0;
        for i in spill {
            if !self.push_free(i) {
                dropped += 1;
            }
        }
        if dropped > 0 {
            mag.lock().unwrap().overflow += dropped;
        }
    }

    /// Park a retired slot on the shared free list. The list holds the
    /// arena's full capacity, so failure only happens when a pop straggler
    /// transiently pins a drained queue block at the directory's edge — a
    /// short retry rides that window out; the rare final failure drops the
    /// slot (caller counts it in `overflow`) rather than blocking.
    fn push_free(&self, idx: u32) -> bool {
        let mut backoff = Backoff::new();
        for _ in 0..4 {
            if self.free.try_push(idx as u64).is_ok() {
                return true;
            }
            backoff.wait();
        }
        false
    }

    /// Slots currently materialized (footprint in nodes).
    pub fn capacity(&self) -> u64 {
        self.count.load(Ordering::Acquire) as u64 * self.block_size as u64
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn stats(&self) -> PoolStats {
        let blocks = self.count.load(Ordering::Acquire) as u64;
        let qs = self.free.stats();
        let mut out = PoolStats {
            blocks,
            capacity: blocks * self.block_size as u64,
            arenas: 1,
            free_residue: qs.pushes.saturating_sub(qs.pops),
            allocs: self.shared.allocs.load(Ordering::Relaxed),
            recycled: self.shared.recycled.load(Ordering::Relaxed),
            retired: self.shared.retired.load(Ordering::Relaxed),
            overflow: self.shared.overflow.load(Ordering::Relaxed),
            local_allocs: self.shared.local.load(Ordering::Relaxed),
            remote_allocs: self.shared.remote.load(Ordering::Relaxed),
            ..PoolStats::default()
        };
        for m in self.mags.iter() {
            let st = m.0.lock().unwrap();
            out.allocs += st.allocs;
            out.recycled += st.recycled;
            out.retired += st.retired;
            out.magazine_hits += st.mag_hits;
            out.free_residue += st.len as u64;
            out.overflow += st.overflow;
            out.local_allocs += st.local;
            out.remote_allocs += st.remote;
        }
        out
    }
}

impl<N: ArenaNode> Drop for BlockArena<N> {
    fn drop(&mut self) {
        // Every slot of a materialized block is a fully constructed plane
        // value (see ArenaNode::vacant_hot/vacant_cold), so dropping the
        // boxed slices runs slot drops correctly even for node types that
        // own resources.
        let n = self.count.load(Ordering::Acquire);
        for i in 0..n {
            let h = self.dir[i].hot.load(Ordering::Acquire);
            if !h.is_null() {
                let slice = std::ptr::slice_from_raw_parts_mut(h, self.block_size);
                drop(unsafe { Box::from_raw(slice) });
            }
            let c = self.dir[i].cold.load(Ordering::Acquire);
            if !c.is_null() {
                let slice = std::ptr::slice_from_raw_parts_mut(c, self.block_size);
                drop(unsafe { Box::from_raw(slice) });
            }
            let l = self.dir[i].leaf.load(Ordering::Acquire);
            if !l.is_null() {
                let slice =
                    std::ptr::slice_from_raw_parts_mut(l, self.block_size * self.leaf_words);
                drop(unsafe { Box::from_raw(slice) });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    struct Slot;

    struct SlotHot {
        idx: AtomicU32,
        payload: AtomicU64,
    }

    struct SlotCold {
        gen: AtomicU32,
    }

    impl ArenaNode for Slot {
        type Hot = SlotHot;
        type Cold = SlotCold;
        fn vacant_hot() -> SlotHot {
            SlotHot { idx: AtomicU32::new(0), payload: AtomicU64::new(0) }
        }
        fn vacant_cold() -> SlotCold {
            SlotCold { gen: AtomicU32::new(0) }
        }
        fn generation(cold: &SlotCold) -> &AtomicU32 {
            &cold.gen
        }
        fn on_materialize(hot: &mut SlotHot, _cold: &mut SlotCold, idx: u32) {
            hot.idx.store(idx, Ordering::Relaxed);
        }
    }

    #[test]
    fn bump_then_magazine_reuse() {
        let a: BlockArena<Slot> = BlockArena::new(4, 16);
        let i1 = a.alloc_slot();
        assert_eq!(a.hot(i1).idx.load(Ordering::Relaxed), i1);
        a.retire_slot(i1);
        let i2 = a.alloc_slot();
        assert_eq!(i1, i2, "magazine must hand the slot back");
        let st = a.stats();
        assert_eq!(st.allocs, 2);
        assert_eq!(st.recycled, 1);
        assert_eq!(st.magazine_hits, 1);
        assert_eq!(st.retired, 1);
        assert_eq!(st.blocks, 1, "alternating alloc/retire stays in one block");
    }

    #[test]
    fn generation_bumps_on_retire() {
        let a: BlockArena<Slot> = BlockArena::new(4, 16);
        let i = a.alloc_slot();
        let g0 = a.cold(i).gen.load(Ordering::Acquire);
        a.retire_slot(i);
        assert_eq!(a.cold(i).gen.load(Ordering::Acquire), g0 + 1);
    }

    #[test]
    fn planes_are_parallel_and_prefetchable() {
        let a: BlockArena<Slot> = BlockArena::new(8, 8);
        let idxs: Vec<u32> = (0..20).map(|_| a.alloc_slot()).collect();
        for &i in &idxs {
            assert_eq!(a.hot(i).idx.load(Ordering::Relaxed), i, "hot plane indexed per slot");
            a.hot(i).payload.store(i as u64 * 3, Ordering::Relaxed);
            // the cold plane exists for the same index and carries the gen
            assert_eq!(a.cold(i).gen.load(Ordering::Relaxed), 0);
            // prefetching any live index is harmless and reported issued
            assert!(a.prefetch_hot(i));
        }
        // out of range: must be a guarded no-op and report not-issued
        assert!(!a.prefetch_hot(u32::MAX));
        for &i in &idxs {
            assert_eq!(a.hot(i).payload.load(Ordering::Relaxed), i as u64 * 3);
        }
        // no leaf plane on a default arena: leaf prefetch is a guarded no-op
        assert!(!a.prefetch_leaf(idxs[0]));
        let b: BlockArena<Slot> =
            BlockArena::with_options(8, 8, ArenaOptions::default().with_leaf_words(4));
        let j = b.alloc_slot();
        assert!(b.prefetch_leaf(j), "materialized leaf row prefetches");
        assert!(!b.prefetch_leaf(u32::MAX), "out of range stays a no-op");
    }

    #[test]
    fn spill_moves_overflowing_retires_to_shared_free_list() {
        let a: BlockArena<Slot> = BlockArena::new(64, 16);
        let idxs: Vec<u32> = (0..3 * MAG_SLOTS as u32).map(|_| a.alloc_slot()).collect();
        for i in idxs {
            a.retire_slot(i);
        }
        let st = a.stats();
        assert_eq!(st.retired, 3 * MAG_SLOTS as u64);
        assert_eq!(st.overflow, 0);
        // nothing lost: everything retired is parked for reuse
        assert_eq!(st.free_residue, st.retired - st.recycled);
        // and the arena serves it all back before bumping new slots
        let cap = a.capacity();
        for _ in 0..3 * MAG_SLOTS {
            a.alloc_slot();
        }
        assert_eq!(a.capacity(), cap, "reuse must not grow the footprint");
    }

    #[test]
    fn without_magazines_recycles_through_shared_list_only() {
        let a: BlockArena<Slot> =
            BlockArena::with_options(8, 8, ArenaOptions::without_magazines());
        let i = a.alloc_slot();
        a.retire_slot(i);
        let j = a.alloc_slot();
        assert_eq!(i, j);
        let st = a.stats();
        assert_eq!(st.magazine_hits, 0);
        assert_eq!(st.recycled, 1);
    }

    #[test]
    fn leaf_plane_is_parallel_contiguous_and_survives_reuse() {
        let words = 6;
        let a: BlockArena<Slot> =
            BlockArena::with_options(8, 8, ArenaOptions::default().with_leaf_words(words));
        assert_eq!(a.leaf_words(), words);
        let i1 = a.alloc_slot();
        let i2 = a.alloc_slot();
        let l1 = a.leaf(i1);
        let l2 = a.leaf(i2);
        assert_eq!(l1.len(), words);
        // zero-initialized on materialization
        assert!(l1.iter().all(|w| w.load(Ordering::Relaxed) == 0));
        // dense packing: consecutive slots are exactly `words` words apart
        let p1 = l1.as_ptr() as usize;
        let p2 = l2.as_ptr() as usize;
        assert_eq!(p2 - p1, (i2 - i1) as usize * words * 8);
        for (j, w) in l1.iter().enumerate() {
            w.store(100 + j as u64, Ordering::Relaxed);
        }
        // slot reuse hands back the same leaf words (contents NOT reset —
        // the structure layer reinitializes, exactly like hot/cold fields)
        a.retire_slot(i1);
        let i3 = a.alloc_slot();
        assert_eq!(i3, i1);
        assert_eq!(a.leaf(i3)[3].load(Ordering::Relaxed), 103);
        // default arenas have no leaf plane
        let b: BlockArena<Slot> = BlockArena::new(8, 8);
        assert_eq!(b.leaf_words(), 0);
    }

    #[test]
    fn concurrent_allocs_are_unique() {
        let a: Arc<BlockArena<Slot>> = Arc::new(BlockArena::new(16, 256));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                (0..500).map(|_| a.alloc_slot()).collect::<Vec<_>>()
            }));
        }
        let mut seen = HashSet::new();
        for h in handles {
            for idx in h.join().unwrap() {
                assert!(seen.insert(idx), "duplicate slot {idx}");
            }
        }
        assert_eq!(seen.len(), 2000);
    }

    #[test]
    fn concurrent_churn_keeps_footprint_small_and_loses_nothing() {
        let a: Arc<BlockArena<Slot>> = Arc::new(BlockArena::new(16, 4096));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..2_000 {
                    let i = a.alloc_slot();
                    a.hot(i).payload.store(42, Ordering::Relaxed);
                    a.retire_slot(i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let st = a.stats();
        assert_eq!(st.allocs, 8_000);
        assert_eq!(st.retired, 8_000);
        assert_eq!(st.retired, st.recycled + st.free_residue + st.overflow);
        assert!(st.magazine_hits > 7_000, "churn must run off the magazines");
        assert!(st.capacity < 8_000, "recycling keeps the footprint tiny");
    }

    #[test]
    fn locality_accounting_tracks_home_node() {
        let topo = Topology::virtual_grid(2, 2);
        let a: BlockArena<Slot> =
            BlockArena::with_options(8, 8, ArenaOptions::placed(1, &topo, 4));
        // an unpinned thread counts as local (reset: the test-runner thread
        // may have been pinned by an earlier test)
        note_thread_cpu(usize::MAX);
        a.alloc_slot();
        note_thread_cpu(0); // node 0: remote for a home-1 arena
        a.alloc_slot();
        note_thread_cpu(2); // node 1: local
        a.alloc_slot();
        note_thread_cpu(usize::MAX);
        let st = a.stats();
        assert_eq!(st.local_allocs, 2);
        assert_eq!(st.remote_allocs, 1);
        assert!((st.locality_hit_rate() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn stats_merge_sums_and_rates_degrade_gracefully() {
        let mut a = PoolStats { allocs: 10, recycled: 5, magazine_hits: 4, arenas: 1, ..PoolStats::default() };
        let b = PoolStats { allocs: 10, recycled: 1, arenas: 2, ..PoolStats::default() };
        a.merge(&b);
        assert_eq!(a.allocs, 20);
        assert_eq!(a.arenas, 3);
        assert!((a.recycle_rate() - 0.3).abs() < 1e-9);
        assert!((a.magazine_hit_rate() - 0.2).abs() < 1e-9);
        assert_eq!(PoolStats::default().locality_hit_rate(), 1.0);
    }
}
