//! Typed pointer-handing façade over the unified [`BlockArena`] (paper §V).
//!
//! [`NodePool<T>`] keeps the historical address-based API (`alloc` returns a
//! stable `*mut MaybeUninit<T>`, `retire` takes it back) but owns **no**
//! allocator body of its own — blocks, bump index, magazines and the
//! recycle free list all live in [`BlockArena`]. Node memory is never
//! returned to the OS before the pool drops, which is what keeps stale
//! pointers dereferenceable for lock-free traversals.
//!
//! Under the arena's two-plane layout the pool's **hot plane is the payload
//! itself** (plus the slot index needed to take a pointer back) and the
//! cold plane is just the recycle generation — so payload traffic never
//! shares a line with allocator control words.
//!
//! Payloads are bounded `T: Copy`: a pool slot stores `MaybeUninit<T>` and
//! the pool cannot know which slots were initialized, so it never runs `T`
//! drops. The `Copy` bound turns the old "nodes need no drop" comment into
//! a compile-time guarantee — a future `T: Drop` user fails to build
//! instead of silently leaking. (Structures whose nodes are always fully
//! constructed use [`BlockArena`] directly and *do* get slot drops.)
//!
//! Linearization points (per §V): `alloc` linearizes at the bump-index
//! fetch-add or at the free-list/magazine pop; `retire` linearizes at the
//! generation bump. Concurrent `alloc`s therefore always receive unique
//! locations.

use std::cell::UnsafeCell;
use std::marker::PhantomData;
use std::mem::MaybeUninit;
use std::sync::atomic::AtomicU32;

use super::arena::{ArenaNode, ArenaOptions, BlockArena, PoolStats};

/// One hot-plane pool slot: the payload cell first (`repr(C)`), so a
/// payload pointer is also a slot pointer and `retire` can recover the slot
/// index without a reverse lookup.
#[repr(C)]
pub struct PoolSlotHot<T> {
    cell: UnsafeCell<MaybeUninit<T>>,
    idx: u32,
}

unsafe impl<T: Send> Send for PoolSlotHot<T> {}
unsafe impl<T: Send> Sync for PoolSlotHot<T> {}

/// Cold-plane pool slot: the recycle generation only.
pub struct PoolSlotCold {
    gen: AtomicU32,
}

/// Tag type naming the pool's hot/cold split (never instantiated).
pub struct PoolSlot<T>(PhantomData<fn() -> T>);

impl<T: Copy + Send> ArenaNode for PoolSlot<T> {
    type Hot = PoolSlotHot<T>;
    type Cold = PoolSlotCold;

    fn vacant_hot() -> PoolSlotHot<T> {
        PoolSlotHot { cell: UnsafeCell::new(MaybeUninit::uninit()), idx: 0 }
    }

    fn vacant_cold() -> PoolSlotCold {
        PoolSlotCold { gen: AtomicU32::new(0) }
    }

    fn generation(cold: &PoolSlotCold) -> &AtomicU32 {
        &cold.gen
    }

    fn on_materialize(hot: &mut PoolSlotHot<T>, _cold: &mut PoolSlotCold, idx: u32) {
        hot.idx = idx;
    }
}

/// Concurrent block-pool allocator for POD nodes of type `T`.
pub struct NodePool<T: Copy + Send> {
    arena: BlockArena<PoolSlot<T>>,
}

impl<T: Copy + Send> NodePool<T> {
    /// Pool with `block_size` nodes per block and room for `max_blocks`
    /// blocks (directory is preallocated; blocks themselves are lazy).
    pub fn new(block_size: usize, max_blocks: usize) -> NodePool<T> {
        Self::with_options(block_size, max_blocks, ArenaOptions::default())
    }

    pub fn with_options(block_size: usize, max_blocks: usize, opts: ArenaOptions) -> NodePool<T> {
        NodePool { arena: BlockArena::with_options(block_size, max_blocks, opts) }
    }

    /// Allocate one node slot, preferring recycled nodes. The returned
    /// pointer is valid until the pool is dropped.
    pub fn alloc(&self) -> *mut MaybeUninit<T> {
        let idx = self.arena.alloc_slot();
        let slot = self.arena.hot_ptr(idx);
        // Raw field projection keeps whole-block provenance, so the pointer
        // can be cast back to its PoolSlotHot in `retire`.
        unsafe { std::ptr::addr_of_mut!((*slot).cell) as *mut MaybeUninit<T> }
    }

    /// Return a node to the pool. The caller must guarantee no new
    /// operation will dereference `p` expecting the old value (generation
    /// counters catch reuse). Never blocks, even under mass erase: the
    /// unified arena parks overflow instead of spinning.
    pub fn retire(&self, p: *mut MaybeUninit<T>) {
        // `cell` is the first field of the repr(C) hot slot.
        let idx = unsafe { (*(p as *const PoolSlotHot<T>)).idx };
        self.arena.retire_slot(idx);
    }

    pub fn stats(&self) -> PoolStats {
        self.arena.stats()
    }

    pub fn block_size(&self) -> usize {
        self.arena.block_size()
    }
}

/// Average blocks in use for a uniformly random valid new/delete sequence —
/// the closed form of paper §V eq. (5). Used by tests and `exp t10` to
/// validate the arena's accounting.
pub fn eq5_average_blocks(n: u64, c: u64) -> f64 {
    // sum_{k=1..N} sum_{i=0..k} ceil((k-i)/C)   /   sum_{i=1..N} i
    let mut num = 0f64;
    for k in 1..=n {
        for i in 0..=k {
            num += ((k - i) as f64 / c as f64).ceil();
        }
    }
    let den = (n * (n + 1) / 2) as f64;
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn unique_addresses_sequential() {
        let pool: NodePool<u64> = NodePool::new(8, 64);
        let mut seen = HashSet::new();
        for _ in 0..100 {
            assert!(seen.insert(pool.alloc() as usize));
        }
        assert_eq!(pool.stats().blocks, 100u64.div_ceil(8));
    }

    #[test]
    fn recycling_reuses_addresses() {
        let pool: NodePool<u64> = NodePool::new(8, 64);
        let p1 = pool.alloc();
        pool.retire(p1);
        let p2 = pool.alloc();
        assert_eq!(p1, p2);
        let st = pool.stats();
        assert_eq!(st.recycled, 1);
        assert_eq!(st.retired, 1);
        assert_eq!(st.magazine_hits, 1, "reuse must come from the magazine");
    }

    #[test]
    fn alternating_new_delete_uses_one_block() {
        // §V: "the number of blocks allocated is 1 when new and delete
        // alternate".
        let pool: NodePool<u64> = NodePool::new(4, 64);
        for _ in 0..100 {
            let p = pool.alloc();
            pool.retire(p);
        }
        assert_eq!(pool.stats().blocks, 1);
    }

    #[test]
    fn all_news_first_hits_ceiling() {
        // §V: maximum blocks = ceil(N / C) when all news precede deletes.
        let pool: NodePool<u64> = NodePool::new(4, 64);
        let ps: Vec<_> = (0..30).map(|_| pool.alloc()).collect();
        assert_eq!(pool.stats().blocks, 30u64.div_ceil(4));
        for p in ps {
            pool.retire(p);
        }
        let st = pool.stats();
        assert_eq!(st.retired, st.recycled + st.free_residue + st.overflow, "no lost nodes");
    }

    #[test]
    fn concurrent_allocs_are_unique() {
        let pool: Arc<NodePool<u64>> = Arc::new(NodePool::new(16, 256));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            handles.push(std::thread::spawn(move || {
                (0..500).map(|_| pool.alloc() as usize).collect::<Vec<_>>()
            }));
        }
        let mut seen = HashSet::new();
        for h in handles {
            for addr in h.join().unwrap() {
                assert!(seen.insert(addr), "duplicate address {addr:#x}");
            }
        }
        assert_eq!(seen.len(), 2000);
    }

    #[test]
    fn concurrent_alloc_retire_cycles() {
        let pool: Arc<NodePool<u64>> = Arc::new(NodePool::new(16, 4096));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..2_000 {
                    let p = pool.alloc();
                    unsafe { (*p).write(42) };
                    pool.retire(p);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let st = pool.stats();
        assert_eq!(st.allocs, 8_000);
        assert!(st.recycled > 0);
        // recycling keeps the footprint tiny vs 8000 nodes
        assert!(st.capacity < 8_000);
    }

    #[test]
    fn eq5_sanity() {
        // For C=1, every outstanding entity is its own block; the average
        // over all (k news, i deletes) prefixes is (k-i)/1 averaged == ~N/3.
        let avg = eq5_average_blocks(30, 1);
        assert!(avg > 8.0 && avg < 12.0, "avg={avg}");
        // Larger blocks => fewer blocks on average, lower-bounded well below.
        assert!(eq5_average_blocks(30, 8) < avg / 4.0);
    }
}
