//! Block memory manager with recycling (paper §V).
//!
//! A [`NodePool<T>`] allocates node memory in blocks (one `malloc` per
//! `block_size` nodes instead of one per node), hands out stable raw
//! pointers, and recycles deleted nodes through a concurrent lock-free queue.
//! Node memory is **never returned to the OS before the pool drops** — the
//! property that makes the lock-free `Find` traversals of the skiplist and
//! the split-order lists memory-safe (a stale pointer always points at node
//! memory, and generation counters catch reuse).
//!
//! Linearization points (per §V): `alloc` linearizes at the bump-index
//! fetch-add or at the recycle-queue `pop`; `retire` linearizes at the
//! recycle-queue `push`. Concurrent `alloc`s therefore always receive unique
//! locations.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::queue::{ConcurrentQueue, LfQueue};
use crate::sync::Backoff;

/// Allocation statistics for the §V analysis (eq. 5 behaviour).
#[derive(Debug, Default, Clone)]
pub struct PoolStats {
    /// Total `alloc` calls served.
    pub allocs: u64,
    /// `alloc`s served from recycled nodes.
    pub recycled: u64,
    /// `retire` calls.
    pub retired: u64,
    /// Blocks currently allocated.
    pub blocks: u64,
    /// `block_size * blocks` — capacity in nodes.
    pub capacity: u64,
}

struct Blocks<T> {
    dir: Box<[AtomicPtr<UnsafeCell<MaybeUninit<T>>>]>,
    count: AtomicUsize,
    grow: Mutex<()>,
}

/// Concurrent block-pool allocator for nodes of type `T`.
pub struct NodePool<T> {
    blocks: Blocks<T>,
    /// Global bump index: block = idx / block_size, slot = idx % block_size.
    bump: AtomicUsize,
    block_size: usize,
    /// Recycled node addresses.
    free: LfQueue,
    allocs: AtomicU64,
    recycled: AtomicU64,
    retired: AtomicU64,
}

unsafe impl<T: Send> Send for NodePool<T> {}
unsafe impl<T: Send + Sync> Sync for NodePool<T> {}

impl<T> NodePool<T> {
    /// Pool with `block_size` nodes per block and room for `max_blocks`
    /// blocks (directory is preallocated; blocks themselves are lazy).
    pub fn new(block_size: usize, max_blocks: usize) -> NodePool<T> {
        assert!(block_size >= 1 && max_blocks >= 1);
        NodePool {
            blocks: Blocks {
                dir: (0..max_blocks).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect(),
                count: AtomicUsize::new(0),
                grow: Mutex::new(()),
            },
            bump: AtomicUsize::new(0),
            block_size,
            free: LfQueue::with_config(4096, max_blocks.max(64), true),
            allocs: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
            retired: AtomicU64::new(0),
        }
    }

    /// Allocate one node slot, preferring recycled nodes. The returned
    /// pointer is valid until the pool is dropped.
    pub fn alloc(&self) -> *mut MaybeUninit<T> {
        self.allocs.fetch_add(1, Ordering::Relaxed);
        if let Some(addr) = self.free.pop() {
            self.recycled.fetch_add(1, Ordering::Relaxed);
            return addr as *mut MaybeUninit<T>;
        }
        let idx = self.bump.fetch_add(1, Ordering::AcqRel);
        let (b, s) = (idx / self.block_size, idx % self.block_size);
        assert!(
            b < self.blocks.dir.len(),
            "NodePool exhausted: {} blocks of {} nodes",
            self.blocks.dir.len(),
            self.block_size
        );
        let mut backoff = Backoff::new();
        loop {
            if b < self.blocks.count.load(Ordering::Acquire) {
                let base = self.blocks.dir[b].load(Ordering::Acquire);
                return unsafe { (*base.add(s)).get() };
            }
            // Need to materialize block b (once, under the grow lock).
            {
                let _g = self.blocks.grow.lock().unwrap();
                let cur = self.blocks.count.load(Ordering::Acquire);
                if cur <= b {
                    for nb in cur..=b {
                        let block: Box<[UnsafeCell<MaybeUninit<T>>]> = (0..self.block_size)
                            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
                            .collect();
                        let ptr = Box::into_raw(block) as *mut UnsafeCell<MaybeUninit<T>>;
                        self.blocks.dir[nb].store(ptr, Ordering::Release);
                    }
                    self.blocks.count.store(b + 1, Ordering::Release);
                }
            }
            backoff.wait();
        }
    }

    /// Return a node to the pool. The caller must guarantee no new
    /// operation will dereference `p` expecting the old value (generation
    /// counters in the node types enforce this).
    pub fn retire(&self, p: *mut MaybeUninit<T>) {
        self.retired.fetch_add(1, Ordering::Relaxed);
        self.free.push(p as u64);
    }

    pub fn stats(&self) -> PoolStats {
        let blocks = self.blocks.count.load(Ordering::Acquire) as u64;
        PoolStats {
            allocs: self.allocs.load(Ordering::Relaxed),
            recycled: self.recycled.load(Ordering::Relaxed),
            retired: self.retired.load(Ordering::Relaxed),
            blocks,
            capacity: blocks * self.block_size as u64,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }
}

impl<T> Drop for NodePool<T> {
    fn drop(&mut self) {
        // Nodes of `T` handed out by this pool are PODs in this codebase
        // (atomics/integers) and need no drop; free the raw blocks.
        let n = self.blocks.count.load(Ordering::Acquire);
        for i in 0..n {
            let p = self.blocks.dir[i].load(Ordering::Acquire);
            if !p.is_null() {
                let slice = std::ptr::slice_from_raw_parts_mut(p, self.block_size);
                drop(unsafe { Box::from_raw(slice) });
            }
        }
    }
}

/// Average blocks in use for a uniformly random valid new/delete sequence —
/// the closed form of paper §V eq. (5). Used by tests to validate the pool's
/// accounting and by DESIGN.md discussion.
pub fn eq5_average_blocks(n: u64, c: u64) -> f64 {
    // sum_{k=1..N} sum_{i=0..k} ceil((k-i)/C)   /   sum_{i=1..N} i
    let mut num = 0f64;
    for k in 1..=n {
        for i in 0..=k {
            num += ((k - i) as f64 / c as f64).ceil();
        }
    }
    let den = (n * (n + 1) / 2) as f64;
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn unique_addresses_sequential() {
        let pool: NodePool<u64> = NodePool::new(8, 64);
        let mut seen = HashSet::new();
        for _ in 0..100 {
            assert!(seen.insert(pool.alloc() as usize));
        }
        assert_eq!(pool.stats().blocks, 100u64.div_ceil(8));
    }

    #[test]
    fn recycling_reuses_addresses() {
        let pool: NodePool<u64> = NodePool::new(8, 64);
        let p1 = pool.alloc();
        pool.retire(p1);
        let p2 = pool.alloc();
        assert_eq!(p1, p2);
        let st = pool.stats();
        assert_eq!(st.recycled, 1);
        assert_eq!(st.retired, 1);
    }

    #[test]
    fn alternating_new_delete_uses_one_block() {
        // §V: "the number of blocks allocated is 1 when new and delete
        // alternate".
        let pool: NodePool<u64> = NodePool::new(4, 64);
        for _ in 0..100 {
            let p = pool.alloc();
            pool.retire(p);
        }
        assert_eq!(pool.stats().blocks, 1);
    }

    #[test]
    fn all_news_first_hits_ceiling() {
        // §V: maximum blocks = ceil(N / C) when all news precede deletes.
        let pool: NodePool<u64> = NodePool::new(4, 64);
        let ps: Vec<_> = (0..30).map(|_| pool.alloc()).collect();
        assert_eq!(pool.stats().blocks, 30u64.div_ceil(4));
        for p in ps {
            pool.retire(p);
        }
    }

    #[test]
    fn concurrent_allocs_are_unique() {
        let pool: Arc<NodePool<u64>> = Arc::new(NodePool::new(16, 256));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            handles.push(std::thread::spawn(move || {
                (0..500).map(|_| pool.alloc() as usize).collect::<Vec<_>>()
            }));
        }
        let mut seen = HashSet::new();
        for h in handles {
            for addr in h.join().unwrap() {
                assert!(seen.insert(addr), "duplicate address {addr:#x}");
            }
        }
        assert_eq!(seen.len(), 2000);
    }

    #[test]
    fn concurrent_alloc_retire_cycles() {
        let pool: Arc<NodePool<u64>> = Arc::new(NodePool::new(16, 4096));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..2_000 {
                    let p = pool.alloc();
                    unsafe { (*p).write(42) };
                    pool.retire(p);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let st = pool.stats();
        assert_eq!(st.allocs, 8_000);
        assert!(st.recycled > 0);
        // recycling keeps the footprint tiny vs 8000 nodes
        assert!(st.capacity < 8_000);
    }

    #[test]
    fn eq5_sanity() {
        // For C=1, every outstanding entity is its own block; the average
        // over all (k news, i deletes) prefixes is (k-i)/1 averaged == ~N/3.
        let avg = eq5_average_blocks(30, 1);
        assert!(avg > 8.0 && avg < 12.0, "avg={avg}");
        // Larger blocks => fewer blocks on average, lower-bounded well below.
        assert!(eq5_average_blocks(30, 8) < avg / 4.0);
    }
}
