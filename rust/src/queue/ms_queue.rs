//! Michael–Scott linked lock-free queue with a coarse-locked free list —
//! the "boost-like" baseline of §III — generic over the payload type.
//!
//! Boost's `lockfree::queue` follows Michael & Scott [17]: each push/pop is two
//! CAS operations over list pointers, and node memory management takes a
//! coarse lock. The paper attributes its poor cache behaviour to exactly
//! this shape; we reproduce it as a baseline. ABA on recycled nodes is
//! prevented with tagged pointers in a 128-bit CAS word `(tag, ptr)`.
//!
//! ## Generic payloads
//!
//! The winning head CAS is unique per `(ptr, tag)` pair, so exactly one
//! pop ever consumes a node's value: it moves the `MaybeUninit<T>` out
//! *after* the CAS and then publishes the node's `taken` flag. The pop
//! that later unlinks that node waits for `taken` before handing it to
//! the free list, so a re-allocating pusher can never write the slot
//! while the consumer's read is still in flight — value ownership
//! transfers exactly once with no unsynchronized access. (The brief
//! recycle wait mirrors the baseline's deliberately *blocking* memory
//! management: the free list itself takes a coarse lock.)

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::sync::{hi64, lo64, pack, AtomicU128, Backoff};
use crate::util::fail;

use super::traits::ConcurrentQueue;

struct MsNode<T> {
    value: UnsafeCell<MaybeUninit<T>>,
    /// True once this node's value has been moved out (or never existed —
    /// the initial dummy). The unlinking pop spins on it before recycling,
    /// which makes the consumer's post-CAS `value` read race-free.
    taken: AtomicBool,
    /// Tagged next: (tag << 64) | ptr.
    next: AtomicU128,
}

/// Arena that owns node memory for the queue's lifetime (addresses stable,
/// nothing freed until drop), grown and recycled under a coarse lock —
/// deliberately mirroring boost's blocking memory management.
struct NodeArena<T> {
    blocks: Mutex<ArenaInner<T>>,
}

struct ArenaInner<T> {
    blocks: Vec<Box<[MsNode<T>]>>,
    free: Vec<*mut MsNode<T>>,
    bump: usize,
    block_size: usize,
}

unsafe impl<T: Send> Send for NodeArena<T> {}
unsafe impl<T: Send> Sync for NodeArena<T> {}

impl<T> NodeArena<T> {
    fn new(block_size: usize) -> NodeArena<T> {
        NodeArena {
            blocks: Mutex::new(ArenaInner {
                blocks: Vec::new(),
                free: Vec::new(),
                bump: 0,
                block_size,
            }),
        }
    }

    fn alloc(&self) -> *mut MsNode<T> {
        let mut inner = self.blocks.lock().unwrap();
        if let Some(p) = inner.free.pop() {
            return p;
        }
        if inner.blocks.is_empty() || inner.bump == inner.block_size {
            let size = inner.block_size;
            let block: Box<[MsNode<T>]> = (0..size)
                .map(|_| MsNode {
                    value: UnsafeCell::new(MaybeUninit::uninit()),
                    taken: AtomicBool::new(true), // no value until a push writes one
                    next: AtomicU128::new(0),
                })
                .collect();
            inner.blocks.push(block);
            inner.bump = 0;
        }
        let i = inner.bump;
        inner.bump += 1;
        let last = inner.blocks.last_mut().unwrap();
        &mut last[i] as *mut MsNode<T>
    }

    fn free(&self, p: *mut MsNode<T>) {
        self.blocks.lock().unwrap().free.push(p);
    }
}

/// Michael–Scott queue ("boost-like"), `u64` payloads by default.
pub struct MsQueue<T: Send = u64> {
    head: AtomicU128, // (tag, ptr) — dummy-node convention
    tail: AtomicU128,
    arena: NodeArena<T>,
}

unsafe impl<T: Send> Send for MsQueue<T> {}
unsafe impl<T: Send> Sync for MsQueue<T> {}

impl<T: Send> MsQueue<T> {
    pub fn new() -> MsQueue<T> {
        Self::with_block_size(8192)
    }

    pub fn with_block_size(block_size: usize) -> MsQueue<T> {
        let arena = NodeArena::new(block_size);
        let dummy = arena.alloc();
        unsafe { (*dummy).next.store(0) };
        MsQueue {
            head: AtomicU128::new(pack(0, dummy as u64)),
            tail: AtomicU128::new(pack(0, dummy as u64)),
            arena,
        }
    }
}

impl<T: Send> Default for MsQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send> Drop for MsQueue<T> {
    fn drop(&mut self) {
        if !std::mem::needs_drop::<T>() {
            return; // arena Boxes free the raw memory
        }
        // Live values sit strictly after the dummy: the dummy's own value
        // was consumed when it became dummy (or never written, for the
        // initial one). Nodes on the free list are off this chain.
        let mut p = lo64(self.head.load()) as *mut MsNode<T>;
        loop {
            let next = lo64(unsafe { (*p).next.load() }) as *mut MsNode<T>;
            if next.is_null() {
                break;
            }
            unsafe { (*(*next).value.get()).assume_init_drop() };
            p = next;
        }
    }
}

impl<T: Send> ConcurrentQueue<T> for MsQueue<T> {
    fn push(&self, v: T) {
        let node = self.arena.alloc();
        unsafe {
            // Exclusive owner until linked: the node came off the free list
            // only after its previous consumer published `taken`.
            (*node).value.get().write(MaybeUninit::new(v));
            (*node).taken.store(false, Ordering::Relaxed);
            // bump our own tag so a recycled node's next CAS can't ABA
            let old = (*node).next.load();
            (*node).next.store(pack(hi64(old) + 1, 0));
        }
        let mut b = Backoff::new();
        loop {
            let tail = self.tail.load();
            let tail_ptr = lo64(tail) as *mut MsNode<T>;
            let next = unsafe { (*tail_ptr).next.load() };
            if tail != self.tail.load() {
                continue;
            }
            if lo64(next) == 0 {
                // try to link node at the end
                if unsafe { (*tail_ptr).next.compare_exchange(next, pack(hi64(next) + 1, node as u64)) }
                    .is_ok()
                {
                    let _ = self
                        .tail
                        .compare_exchange(tail, pack(hi64(tail) + 1, node as u64));
                    return;
                }
            } else {
                // help swing tail
                let _ = self
                    .tail
                    .compare_exchange(tail, pack(hi64(tail) + 1, lo64(next)));
            }
            b.wait();
        }
    }

    fn try_push(&self, v: T) -> Result<(), T> {
        self.push(v);
        Ok(())
    }

    fn pop(&self) -> Option<T> {
        let mut b = Backoff::new();
        loop {
            let head = self.head.load();
            let tail = self.tail.load();
            let head_ptr = lo64(head) as *mut MsNode<T>;
            let next = unsafe { (*head_ptr).next.load() };
            if head != self.head.load() {
                continue;
            }
            if lo64(head) == lo64(tail) {
                if lo64(next) == 0 {
                    return None; // empty
                }
                // tail lagging: help
                let _ = self
                    .tail
                    .compare_exchange(tail, pack(hi64(tail) + 1, lo64(next)));
            } else {
                let next_ptr = lo64(next) as *mut MsNode<T>;
                if self
                    .head
                    .compare_exchange(head, pack(hi64(head) + 1, lo64(next)))
                    .is_ok()
                {
                    // Unique consumer of next_ptr's value (the tag CAS wins
                    // at most once per (ptr, tag)): read it, then publish
                    // `taken` so the pop that later unlinks next_ptr can
                    // recycle it (see module docs).
                    let v = unsafe { (*next_ptr).value.get().read().assume_init() };
                    // Failpoint "msq.taken.delay" (chaos tests): widen the
                    // window between the value read and the `taken` publish
                    // so the recycler's rendezvous spin below is actually
                    // exercised under contention.
                    fail::point("msq.taken.delay");
                    unsafe { (*next_ptr).taken.store(true, Ordering::Release) };
                    // Recycle the outgoing dummy only after its own value
                    // read (by the pop that made it dummy) has completed.
                    let mut spin = Backoff::new();
                    while !unsafe { (*head_ptr).taken.load(Ordering::Acquire) } {
                        spin.wait();
                    }
                    self.arena.free(head_ptr);
                    return Some(v);
                }
            }
            b.wait();
        }
    }

    fn name(&self) -> &'static str {
        "ms-boostlike"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = MsQueue::with_block_size(16);
        for i in 0..100u64 {
            q.push(i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn node_recycling_under_lock() {
        let q = MsQueue::with_block_size(4);
        for round in 0..50u64 {
            for i in 0..10 {
                q.push(round * 10 + i);
            }
            for i in 0..10 {
                assert_eq!(q.pop(), Some(round * 10 + i));
            }
        }
        // With recycling, 500 pushes fit comfortably in a few 4-node blocks.
        assert!(q.arena.blocks.lock().unwrap().blocks.len() < 20);
    }

    #[test]
    fn boxed_payloads_roundtrip() {
        let q: MsQueue<Box<u64>> = MsQueue::with_block_size(4);
        for i in 0..30u64 {
            q.push(Box::new(i));
        }
        for i in 0..30u64 {
            assert_eq!(q.pop().as_deref(), Some(&i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q = Arc::new(MsQueue::new());
        let n = 4u64;
        let per = 4_000u64;
        let mut handles = Vec::new();
        for p in 0..n {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    q.push(p << 32 | i);
                }
            }));
        }
        let got = Arc::new(Mutex::new(HashSet::new()));
        for _ in 0..n {
            let q = q.clone();
            let got = got.clone();
            handles.push(std::thread::spawn(move || {
                let mut local = Vec::new();
                let mut empties = 0;
                loop {
                    match q.pop() {
                        Some(v) => {
                            local.push(v);
                            empties = 0;
                        }
                        None => {
                            empties += 1;
                            if empties > 10_000 {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
                let mut g = got.lock().unwrap();
                for v in local {
                    assert!(g.insert(v), "duplicate {v}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        while let Some(v) = q.pop() {
            assert!(got.lock().unwrap().insert(v));
        }
        assert_eq!(got.lock().unwrap().len() as u64, n * per);
    }
}
