//! Michael–Scott linked lock-free queue with a coarse-locked free list —
//! the "boost-like" baseline of §III.
//!
//! Boost's `lockfree::queue` follows Michael & Scott [17]: each push/pop is two
//! CAS operations over list pointers, and node memory management takes a
//! coarse lock. The paper attributes its poor cache behaviour to exactly
//! this shape; we reproduce it as a baseline. ABA on recycled nodes is
//! prevented with tagged pointers in a 128-bit CAS word `(tag, ptr)`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::sync::{hi64, lo64, pack, AtomicU128, Backoff};

use super::traits::ConcurrentQueue;

struct MsNode {
    value: AtomicU64,
    /// Tagged next: (tag << 64) | ptr.
    next: AtomicU128,
}

/// Arena that owns node memory for the queue's lifetime (addresses stable,
/// nothing freed until drop), grown and recycled under a coarse lock —
/// deliberately mirroring boost's blocking memory management.
struct NodeArena {
    blocks: Mutex<ArenaInner>,
}

struct ArenaInner {
    blocks: Vec<Box<[MsNode]>>,
    free: Vec<*mut MsNode>,
    bump: usize,
    block_size: usize,
}

unsafe impl Send for NodeArena {}
unsafe impl Sync for NodeArena {}

impl NodeArena {
    fn new(block_size: usize) -> NodeArena {
        NodeArena {
            blocks: Mutex::new(ArenaInner {
                blocks: Vec::new(),
                free: Vec::new(),
                bump: 0,
                block_size,
            }),
        }
    }

    fn alloc(&self) -> *mut MsNode {
        let mut inner = self.blocks.lock().unwrap();
        if let Some(p) = inner.free.pop() {
            return p;
        }
        if inner.blocks.is_empty() || inner.bump == inner.block_size {
            let size = inner.block_size;
            let block: Box<[MsNode]> = (0..size)
                .map(|_| MsNode { value: AtomicU64::new(0), next: AtomicU128::new(0) })
                .collect();
            inner.blocks.push(block);
            inner.bump = 0;
        }
        let i = inner.bump;
        inner.bump += 1;
        let last = inner.blocks.last_mut().unwrap();
        &mut last[i] as *mut MsNode
    }

    fn free(&self, p: *mut MsNode) {
        self.blocks.lock().unwrap().free.push(p);
    }
}

/// Michael–Scott queue ("boost-like").
pub struct MsQueue {
    head: AtomicU128, // (tag, ptr) — dummy-node convention
    tail: AtomicU128,
    arena: NodeArena,
}

unsafe impl Send for MsQueue {}
unsafe impl Sync for MsQueue {}

impl MsQueue {
    pub fn new() -> MsQueue {
        Self::with_block_size(8192)
    }

    pub fn with_block_size(block_size: usize) -> MsQueue {
        let arena = NodeArena::new(block_size);
        let dummy = arena.alloc();
        unsafe { (*dummy).next.store(0) };
        MsQueue {
            head: AtomicU128::new(pack(0, dummy as u64)),
            tail: AtomicU128::new(pack(0, dummy as u64)),
            arena,
        }
    }
}

impl Default for MsQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl ConcurrentQueue for MsQueue {
    fn push(&self, v: u64) {
        let node = self.arena.alloc();
        unsafe {
            (*node).value.store(v, Ordering::Relaxed);
            // bump our own tag so a recycled node's next CAS can't ABA
            let old = (*node).next.load();
            (*node).next.store(pack(hi64(old) + 1, 0));
        }
        let mut b = Backoff::new();
        loop {
            let tail = self.tail.load();
            let tail_ptr = lo64(tail) as *mut MsNode;
            let next = unsafe { (*tail_ptr).next.load() };
            if tail != self.tail.load() {
                continue;
            }
            if lo64(next) == 0 {
                // try to link node at the end
                if unsafe { (*tail_ptr).next.compare_exchange(next, pack(hi64(next) + 1, node as u64)) }
                    .is_ok()
                {
                    let _ = self
                        .tail
                        .compare_exchange(tail, pack(hi64(tail) + 1, node as u64));
                    return;
                }
            } else {
                // help swing tail
                let _ = self
                    .tail
                    .compare_exchange(tail, pack(hi64(tail) + 1, lo64(next)));
            }
            b.wait();
        }
    }

    fn try_push(&self, v: u64) -> bool {
        self.push(v);
        true
    }

    fn pop(&self) -> Option<u64> {
        let mut b = Backoff::new();
        loop {
            let head = self.head.load();
            let tail = self.tail.load();
            let head_ptr = lo64(head) as *mut MsNode;
            let next = unsafe { (*head_ptr).next.load() };
            if head != self.head.load() {
                continue;
            }
            if lo64(head) == lo64(tail) {
                if lo64(next) == 0 {
                    return None; // empty
                }
                // tail lagging: help
                let _ = self
                    .tail
                    .compare_exchange(tail, pack(hi64(tail) + 1, lo64(next)));
            } else {
                let next_ptr = lo64(next) as *mut MsNode;
                let v = unsafe { (*next_ptr).value.load(Ordering::Relaxed) };
                if self
                    .head
                    .compare_exchange(head, pack(hi64(head) + 1, lo64(next)))
                    .is_ok()
                {
                    self.arena.free(head_ptr);
                    return Some(v);
                }
            }
            b.wait();
        }
    }

    fn name(&self) -> &'static str {
        "ms-boostlike"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = MsQueue::with_block_size(16);
        for i in 0..100 {
            q.push(i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn node_recycling_under_lock() {
        let q = MsQueue::with_block_size(4);
        for round in 0..50 {
            for i in 0..10 {
                q.push(round * 10 + i);
            }
            for i in 0..10 {
                assert_eq!(q.pop(), Some(round * 10 + i));
            }
        }
        // With recycling, 500 pushes fit comfortably in a few 4-node blocks.
        assert!(q.arena.blocks.lock().unwrap().blocks.len() < 20);
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q = Arc::new(MsQueue::new());
        let n = 4u64;
        let per = 4_000u64;
        let mut handles = Vec::new();
        for p in 0..n {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    q.push(p << 32 | i);
                }
            }));
        }
        let got = Arc::new(Mutex::new(HashSet::new()));
        for _ in 0..n {
            let q = q.clone();
            let got = got.clone();
            handles.push(std::thread::spawn(move || {
                let mut local = Vec::new();
                let mut empties = 0;
                loop {
                    match q.pop() {
                        Some(v) => {
                            local.push(v);
                            empties = 0;
                        }
                        None => {
                            empties += 1;
                            if empties > 10_000 {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
                let mut g = got.lock().unwrap();
                for v in local {
                    assert!(g.insert(v), "duplicate {v}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        while let Some(v) = q.pop() {
            assert!(got.lock().unwrap().insert(v));
        }
        assert_eq!(got.lock().unwrap().len() as u64, n * per);
    }
}
