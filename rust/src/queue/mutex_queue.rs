//! Coarse-locked queue: the simplest correct baseline (a `VecDeque` under a
//! mutex). Used as a sanity oracle in tests and as the "coarse locks on the
//! queue" anti-pattern the paper calls out in §III.

use std::collections::VecDeque;
use std::sync::Mutex;

use super::traits::ConcurrentQueue;

pub struct MutexQueue<T: Send = u64> {
    inner: Mutex<VecDeque<T>>,
}

impl<T: Send> MutexQueue<T> {
    pub fn new() -> MutexQueue<T> {
        MutexQueue { inner: Mutex::new(VecDeque::new()) }
    }
}

impl<T: Send> Default for MutexQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send> ConcurrentQueue<T> for MutexQueue<T> {
    fn push(&self, v: T) {
        self.inner.lock().unwrap().push_back(v);
    }

    fn try_push(&self, v: T) -> Result<(), T> {
        self.push(v);
        Ok(())
    }

    fn pop(&self) -> Option<T> {
        self.inner.lock().unwrap().pop_front()
    }

    fn name(&self) -> &'static str {
        "mutex"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo() {
        let q = MutexQueue::new();
        q.push(1u64);
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }
}
