//! Coarse-locked queue: the simplest correct baseline (a `VecDeque` under a
//! mutex). Used as a sanity oracle in tests and as the "coarse locks on the
//! queue" anti-pattern the paper calls out in §III.

use std::collections::VecDeque;
use std::sync::Mutex;

use super::traits::ConcurrentQueue;

pub struct MutexQueue {
    inner: Mutex<VecDeque<u64>>,
}

impl MutexQueue {
    pub fn new() -> MutexQueue {
        MutexQueue { inner: Mutex::new(VecDeque::new()) }
    }
}

impl Default for MutexQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl ConcurrentQueue for MutexQueue {
    fn push(&self, v: u64) {
        self.inner.lock().unwrap().push_back(v);
    }

    fn try_push(&self, v: u64) -> bool {
        self.push(v);
        true
    }

    fn pop(&self) -> Option<u64> {
        self.inner.lock().unwrap().pop_front()
    }

    fn name(&self) -> &'static str {
        "mutex"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo() {
        let q = MutexQueue::new();
        q.push(1);
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }
}
