//! Unbounded lock-free queue over an array of recycled blocks
//! (paper §III, algorithms 7–10), generic over the payload type.
//!
//! Layout: the queue is a linked chain of fixed-size *blocks*; each block is
//! an array of `(data, fe)` slots.  `front`/`rear` are plain integers bumped
//! with fetch-add (no CAS retry loops on the hot path — the LCRQ insight),
//! and the `fe` ("full/empty") flag array signals completion of the data
//! write so pops never read half-written slots.  `wclosed`/`rclosed` retire a
//! block for writing/reading; retired blocks return to a pool and are
//! recycled (the paper's memory-management contribution vs. stock LCRQ).
//!
//! The payload is any `T: Send` (the paper's experiments use the bare `u64`
//! default; the delegation fabric ships typed op envelopes). Slots hold
//! `MaybeUninit<T>` guarded by the `fe` protocol below, which hands each
//! written value to exactly one owner: the consuming pop, the pusher taking
//! it back off a killed slot, or the queue's `Drop` for values still in
//! flight — so non-`Copy` payloads are dropped exactly once.
//!
//! ## fe slot protocol
//!
//! ```text
//!   0 EMPTY    --push: fetch_add(+1)-->  1 FULL   --pop: CAS(1,3)-->  3 CONSUMED
//!   0 EMPTY    --pop:  CAS(0,2)------->  2 KILLED (push fetch_add sees prev!=0,
//!                                          takes its value back and retries)
//! ```
//!
//! A pop that overtakes `rear` (the paper's "front gets ahead of rear") kills
//! the slot instead of blocking, and the push that later claims that index
//! observes `prev != 0` from its fetch-add and retries on a fresh slot — the
//! exchange of "signals necessary for validating pushes and pops" of §III.
//!
//! ## Safe recycling (epoch/pin)
//!
//! The paper recycles with per-node reference counters against ABA; we use
//! the equivalent (block `epoch` counter + `pins` count, both SeqCst):
//! an operation pins a block then re-validates its epoch; a recycler bumps
//! the epoch then requires `pins == 0`. The store-load pairing guarantees at
//! least one side observes the other, so a block is never reset under an
//! active operation. Block *memory* is never freed before queue drop, so
//! stale pointers are always safe to dereference. A block is only recycled
//! once fully drained, so recycling never touches a live payload.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sync::Backoff;
use crate::util::fail;

use super::traits::ConcurrentQueue;

const NONE: usize = usize::MAX;

const FE_EMPTY: u32 = 0;
const FE_FULL: u32 = 1;
const FE_KILLED: u32 = 2;
const FE_CONSUMED: u32 = 3;

struct Block<T> {
    front: AtomicUsize,
    rear: AtomicUsize,
    next: AtomicUsize,
    wclosed: AtomicBool,
    rclosed: AtomicBool,
    /// Recycle generation; bumped first by the recycler (SeqCst).
    epoch: AtomicU64,
    /// Active operations pinning this block (SeqCst).
    pins: AtomicU64,
    data: Box<[UnsafeCell<MaybeUninit<T>>]>,
    fe: Box<[AtomicU32]>,
}

impl<T> Block<T> {
    fn new(size: usize) -> Block<T> {
        Block {
            front: AtomicUsize::new(0),
            rear: AtomicUsize::new(0),
            next: AtomicUsize::new(NONE),
            wclosed: AtomicBool::new(false),
            rclosed: AtomicBool::new(false),
            epoch: AtomicU64::new(0),
            pins: AtomicU64::new(0),
            data: (0..size).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect(),
            fe: (0..size).map(|_| AtomicU32::new(FE_EMPTY)).collect(),
        }
    }

    /// Reset for reuse. Caller holds the pool lock and has already bumped
    /// `epoch` and verified `pins == 0`. The block is drained (every claimed
    /// slot consumed or killed), so no slot holds a live payload.
    fn reset(&self) {
        self.front.store(0, Ordering::Relaxed);
        self.rear.store(0, Ordering::Relaxed);
        self.next.store(NONE, Ordering::Relaxed);
        self.wclosed.store(false, Ordering::Relaxed);
        self.rclosed.store(false, Ordering::Relaxed);
        for f in self.fe.iter() {
            f.store(FE_EMPTY, Ordering::Relaxed);
        }
    }
}

/// Counters for the §IV analysis (allocation/recycle behaviour).
#[derive(Debug, Default, Clone)]
pub struct QueueStats {
    pub pushes: u64,
    pub pops: u64,
    pub blocks_allocated: u64,
    pub blocks_recycled: u64,
    pub push_retries: u64,
    pub pop_retries: u64,
    pub slots_killed: u64,
}

impl QueueStats {
    /// Elements still enqueued in this snapshot. Never underflows: `stats()`
    /// samples `pops` before `pushes`, so the snapshot over-approximates the
    /// true depth by at most the pushes that landed between the two loads.
    pub fn depth(&self) -> u64 {
        self.pushes.saturating_sub(self.pops)
    }
}

#[derive(Default)]
struct AtomicStats {
    pushes: AtomicU64,
    pops: AtomicU64,
    blocks_allocated: AtomicU64,
    blocks_recycled: AtomicU64,
    push_retries: AtomicU64,
    pop_retries: AtomicU64,
    slots_killed: AtomicU64,
}

/// The paper's unbounded lock-free queue ("lkfree" in Table I), generic over
/// its payload (`u64` by default, matching the paper's experiments).
pub struct LfQueue<T: Send = u64> {
    /// Stable directory of blocks; a slot is written once (block addresses
    /// never move or free until drop).
    slots: Box<[AtomicPtr<Block<T>>]>,
    /// Number of `slots` entries ever populated.
    allocated: AtomicUsize,
    /// Most recent active block (paper's `cn`).
    cn: AtomicUsize,
    /// Least recent active block (paper's `listhead`).
    listhead: AtomicUsize,
    /// Retired block ids awaiting reuse (slow path only).
    free: Mutex<Vec<usize>>,
    block_size: usize,
    recycle: bool,
    stats: AtomicStats,
}

unsafe impl<T: Send> Send for LfQueue<T> {}
unsafe impl<T: Send> Sync for LfQueue<T> {}

impl<T: Send> LfQueue<T> {
    /// Default configuration: the paper's 8192-slot blocks, recycling on.
    pub fn new() -> LfQueue<T> {
        Self::with_config(8192, 4096, true)
    }

    /// `block_size` slots per block, at most `max_blocks` blocks live at
    /// once; `recycle=false` reproduces the TBB/LCRQ behaviour of always
    /// allocating fresh segments (see `tbb_like`).
    pub fn with_config(block_size: usize, max_blocks: usize, recycle: bool) -> LfQueue<T> {
        assert!(block_size >= 2 && max_blocks >= 2);
        let q = LfQueue {
            slots: (0..max_blocks).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect(),
            allocated: AtomicUsize::new(0),
            cn: AtomicUsize::new(0),
            listhead: AtomicUsize::new(0),
            free: Mutex::new(Vec::new()),
            block_size,
            recycle,
            stats: AtomicStats::default(),
        };
        let first = q.alloc_block().expect("initial block");
        debug_assert_eq!(first, 0);
        q
    }

    #[inline]
    fn block(&self, id: usize) -> &Block<T> {
        debug_assert!(id < self.allocated.load(Ordering::Acquire));
        unsafe { &*self.slots[id].load(Ordering::Acquire) }
    }

    /// Allocate a block id: recycled if possible, else a fresh slot.
    /// Returns None when the directory is exhausted.
    fn alloc_block(&self) -> Option<usize> {
        if self.recycle {
            let mut free = self.free.lock().unwrap();
            // Find a retired block no operation is still pinned to.
            for i in 0..free.len() {
                let id = free[i];
                let blk = self.block(id);
                // Bump epoch FIRST (SeqCst): new pinners will re-validate and
                // retreat; then require no pre-existing pinner.
                blk.epoch.fetch_add(1, Ordering::SeqCst);
                if blk.pins.load(Ordering::SeqCst) == 0 {
                    free.swap_remove(i);
                    blk.reset();
                    self.stats.blocks_recycled.fetch_add(1, Ordering::Relaxed);
                    return Some(id);
                }
                // A straggler is mid-operation: leave it for later; the epoch
                // bump is harmless (it only forces re-validation).
            }
        }
        let id = self.allocated.fetch_add(1, Ordering::AcqRel);
        if id >= self.slots.len() {
            self.allocated.fetch_sub(1, Ordering::AcqRel);
            return None;
        }
        let b = Box::into_raw(Box::new(Block::new(self.block_size)));
        self.slots[id].store(b, Ordering::Release);
        self.stats.blocks_allocated.fetch_add(1, Ordering::Relaxed);
        Some(id)
    }

    /// Paper's AddNode (alg. 8): link a fresh block after `n`.
    fn add_node(&self, n: usize) -> bool {
        let blk = self.block(n);
        if blk.next.load(Ordering::Acquire) != NONE {
            return true; // someone else already linked
        }
        let Some(e) = self.alloc_block() else {
            return false;
        };
        if blk
            .next
            .compare_exchange(NONE, e, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            // Lost the race; return e to the pool.
            if self.recycle {
                self.free.lock().unwrap().push(e);
            }
            // (without recycling the block simply stays allocated-but-unused)
        }
        true
    }

    /// Paper's DeleteNode (alg. 10): unlink a drained head block and retire it.
    fn delete_node(&self, n: usize) {
        let blk = self.block(n);
        if !(blk.rclosed.load(Ordering::Acquire) && blk.wclosed.load(Ordering::Acquire)) {
            return;
        }
        if n == self.cn.load(Ordering::Acquire) {
            return;
        }
        let next = blk.next.load(Ordering::Acquire);
        if next == NONE {
            return;
        }
        if self
            .listhead
            .compare_exchange(n, next, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
            && self.recycle
        {
            self.free.lock().unwrap().push(n);
        }
    }

    /// Pin a block for use. Returns false if the block was recycled since
    /// `seen_epoch` was read; the caller must unpin and retry from the queue
    /// anchors either way (the pin count is incremented unconditionally so
    /// pin/unpin always pair up exactly once).
    #[inline]
    fn pin(&self, blk: &Block<T>, seen_epoch: u64) -> bool {
        blk.pins.fetch_add(1, Ordering::SeqCst);
        blk.epoch.load(Ordering::SeqCst) == seen_epoch
    }

    #[inline]
    fn unpin(&self, blk: &Block<T>) {
        blk.pins.fetch_sub(1, Ordering::SeqCst);
    }

    /// Paper's Push (alg. 7). Returns the value back only if the directory
    /// is exhausted and recycling cannot reclaim (try_push semantics).
    fn push_inner(&self, mut v: T, block_on_full: bool) -> Result<(), T> {
        let mut b = Backoff::new();
        loop {
            let n = self.cn.load(Ordering::Acquire);
            let blk = self.block(n);
            let epoch = blk.epoch.load(Ordering::SeqCst);
            if !self.pin(blk, epoch) || self.cn.load(Ordering::Acquire) != n {
                // pinned a stale/recycled block; release before retrying
                self.unpin(blk);
                self.stats.push_retries.fetch_add(1, Ordering::Relaxed);
                b.wait();
                continue;
            }

            if !blk.wclosed.load(Ordering::Acquire) {
                let p = blk.rear.fetch_add(1, Ordering::AcqRel);
                if p < self.block_size {
                    unsafe { (*blk.data[p].get()).write(v) };
                    let prev = blk.fe[p].fetch_add(1, Ordering::AcqRel);
                    if prev == FE_EMPTY {
                        self.unpin(blk);
                        self.stats.pushes.fetch_add(1, Ordering::Relaxed);
                        return Ok(());
                    }
                    // Slot was killed by an overtaking pop (KILLED -> CONSUMED
                    // via our fetch_add): the killer already moved on, so the
                    // value we just wrote belongs to us alone — take it back
                    // and retry elsewhere.
                    debug_assert_eq!(prev, FE_KILLED);
                    v = unsafe { (*blk.data[p].get()).assume_init_read() };
                    self.stats.push_retries.fetch_add(1, Ordering::Relaxed);
                    self.unpin(blk);
                    continue;
                }
                blk.wclosed.store(true, Ordering::Release);
            }

            // Block write-closed: advance to / create the next block.
            let nn = blk.next.load(Ordering::Acquire);
            if nn != NONE {
                let _ = self
                    .cn
                    .compare_exchange(n, nn, Ordering::AcqRel, Ordering::Acquire);
                self.unpin(blk);
            } else {
                let ok = self.add_node(n);
                self.unpin(blk);
                if !ok {
                    if !block_on_full {
                        return Err(v);
                    }
                    b.wait(); // wait for consumers to retire blocks
                }
            }
        }
    }

    /// Paper's Pop (alg. 9).
    fn pop_inner(&self) -> Option<T> {
        let mut b = Backoff::new();
        loop {
            let n = self.listhead.load(Ordering::Acquire);
            let blk = self.block(n);
            let epoch = blk.epoch.load(Ordering::SeqCst);
            if !self.pin(blk, epoch) || self.listhead.load(Ordering::Acquire) != n {
                self.unpin(blk);
                self.stats.pop_retries.fetch_add(1, Ordering::Relaxed);
                b.wait();
                continue;
            }

            if blk.rclosed.load(Ordering::Acquire) {
                if blk.next.load(Ordering::Acquire) == NONE {
                    // Drained tail block with no successor: queue empty.
                    self.unpin(blk);
                    return None;
                }
                self.delete_node(n);
                self.unpin(blk);
                continue;
            }

            let f = blk.front.load(Ordering::Acquire);
            let r = blk.rear.load(Ordering::Acquire);
            let limit = r.min(self.block_size);

            if f >= limit {
                if f >= self.block_size || blk.wclosed.load(Ordering::Acquire) {
                    // Drained (every claimed slot was consumed or killed).
                    // f >= size implies rear >= size, so no push will ever
                    // write this block again: safe to write-close it too
                    // (delete_node requires both flags).
                    blk.wclosed.store(true, Ordering::Release);
                    blk.rclosed.store(true, Ordering::Release);
                    self.delete_node(n);
                    self.unpin(blk);
                    continue;
                }
                // Queue currently empty.
                self.unpin(blk);
                return None;
            }

            let p = blk.front.fetch_add(1, Ordering::AcqRel);
            if p >= self.block_size {
                blk.wclosed.store(true, Ordering::Release);
                blk.rclosed.store(true, Ordering::Release);
                self.delete_node(n);
                self.unpin(blk);
                continue;
            }

            // If a push already claimed this index (p < r), give it a short
            // grace period to finish its data write before killing the slot.
            // Failpoint "queue.pop.kill" (chaos tests) skips the grace
            // period, forcing the EMPTY->KILLED race so the pusher's
            // take-back path runs deterministically.
            let claimed_by_push = p < r && !fail::should_fail("queue.pop.kill");
            let mut spin = Backoff::new();
            loop {
                match blk.fe[p].load(Ordering::Acquire) {
                    FE_FULL => {
                        // Unique consumer for index p: CAS cannot fail, and
                        // the Acquire pairs with the push's AcqRel fetch_add,
                        // so the payload write is visible before we move it.
                        let prev = blk.fe[p].swap(FE_CONSUMED, Ordering::AcqRel);
                        debug_assert_eq!(prev, FE_FULL);
                        let v = unsafe { (*blk.data[p].get()).assume_init_read() };
                        self.unpin(blk);
                        self.stats.pops.fetch_add(1, Ordering::Relaxed);
                        return Some(v);
                    }
                    FE_EMPTY => {
                        if claimed_by_push && !spin.is_yielding() {
                            spin.wait();
                            continue;
                        }
                        if blk.fe[p]
                            .compare_exchange(
                                FE_EMPTY,
                                FE_KILLED,
                                Ordering::AcqRel,
                                Ordering::Acquire,
                            )
                            .is_ok()
                        {
                            self.stats.slots_killed.fetch_add(1, Ordering::Relaxed);
                            break; // retry pop on the next index
                        }
                        // CAS failed => push just completed => consume it.
                    }
                    other => unreachable!("pop claimed slot in state {other}"),
                }
            }
            self.unpin(blk);
            self.stats.pop_retries.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn stats(&self) -> QueueStats {
        // `pops` is sampled before `pushes` so `pushes - pops` (the depth
        // estimate used by RouterFabric::pending and OpFabric) can never
        // underflow: pops only grow, so a later `pushes` load is >= the
        // pushes that produced the sampled pops.
        let pops = self.stats.pops.load(Ordering::Relaxed);
        let pushes = self.stats.pushes.load(Ordering::Relaxed);
        QueueStats {
            pushes,
            pops,
            blocks_allocated: self.stats.blocks_allocated.load(Ordering::Relaxed),
            blocks_recycled: self.stats.blocks_recycled.load(Ordering::Relaxed),
            push_retries: self.stats.push_retries.load(Ordering::Relaxed),
            pop_retries: self.stats.pop_retries.load(Ordering::Relaxed),
            slots_killed: self.stats.slots_killed.load(Ordering::Relaxed),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }
}

impl<T: Send> Default for LfQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send> Drop for LfQueue<T> {
    fn drop(&mut self) {
        let n = self.allocated.load(Ordering::Acquire);
        for i in 0..n {
            let p = self.slots[i].load(Ordering::Acquire);
            if p.is_null() {
                continue;
            }
            if std::mem::needs_drop::<T>() {
                // Values still in flight live exactly in the FULL slots:
                // CONSUMED/KILLED slots had their value moved out (or never
                // written), EMPTY slots were never written.
                let blk = unsafe { &*p };
                for (s, fe) in blk.fe.iter().enumerate() {
                    if fe.load(Ordering::Acquire) == FE_FULL {
                        unsafe { (*blk.data[s].get()).assume_init_drop() };
                    }
                }
            }
            drop(unsafe { Box::from_raw(p) });
        }
    }
}

impl<T: Send> ConcurrentQueue<T> for LfQueue<T> {
    fn push(&self, v: T) {
        if self.push_inner(v, true).is_err() {
            unreachable!("blocking push cannot fail");
        }
    }

    fn try_push(&self, v: T) -> Result<(), T> {
        // Failpoint "queue.try_push" (chaos tests): report a spurious full
        // queue without touching any slot — the caller's backpressure path
        // must retry or fall back, never lose `v`.
        if fail::should_fail("queue.try_push") {
            return Err(v);
        }
        self.push_inner(v, false)
    }

    fn pop(&self) -> Option<T> {
        self.pop_inner()
    }

    fn name(&self) -> &'static str {
        if self.recycle {
            "lkfree"
        } else {
            "lcrq-norecycle"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = LfQueue::with_config(8, 16, true);
        for i in 0..100u64 {
            q.push(i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn crosses_many_blocks_and_recycles() {
        let q = LfQueue::with_config(4, 8, true);
        // 25 rounds of fill/drain across 4-slot blocks with only 8 block ids:
        // impossible without recycling.
        for round in 0..25u64 {
            for i in 0..16 {
                q.push(round * 100 + i);
            }
            for i in 0..16 {
                assert_eq!(q.pop(), Some(round * 100 + i));
            }
        }
        let st = q.stats();
        assert!(st.blocks_recycled > 0, "expected recycling: {st:?}");
        assert!(st.blocks_allocated <= 8);
    }

    #[test]
    fn boxed_payloads_roundtrip_fifo() {
        // Non-Copy payloads move through the generic slots intact.
        let q: LfQueue<Box<u64>> = LfQueue::with_config(4, 8, true);
        for i in 0..40u64 {
            q.push(Box::new(i));
        }
        for i in 0..40u64 {
            assert_eq!(q.pop().as_deref(), Some(&i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q = Arc::new(LfQueue::with_config(64, 64, true));
        let producers = 4;
        let consumers = 4;
        let per = 5_000u64;
        let mut handles = Vec::new();
        for p in 0..producers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    q.push((p as u64) << 32 | i);
                }
            }));
        }
        let got = Arc::new(Mutex::new(Vec::new()));
        for _ in 0..consumers {
            let q = q.clone();
            let got = got.clone();
            handles.push(std::thread::spawn(move || {
                let mut local = Vec::new();
                let mut empties = 0;
                while (local.len() as u64) < producers as u64 * per {
                    match q.pop() {
                        Some(v) => {
                            local.push(v);
                            empties = 0;
                        }
                        None => {
                            empties += 1;
                            if empties > 10_000 {
                                break; // producers done & queue drained
                            }
                            std::thread::yield_now();
                        }
                    }
                }
                got.lock().unwrap().extend(local);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // drain leftovers
        while let Some(v) = q.pop() {
            got.lock().unwrap().push(v);
        }
        let got = got.lock().unwrap();
        assert_eq!(got.len() as u64, producers as u64 * per);
        let set: HashSet<u64> = got.iter().copied().collect();
        assert_eq!(set.len(), got.len(), "duplicated element");
        for p in 0..producers as u64 {
            for i in 0..per {
                assert!(set.contains(&(p << 32 | i)));
            }
        }
    }

    #[test]
    fn per_producer_order_is_fifo() {
        // Single producer, single consumer: strict FIFO.
        let q = Arc::new(LfQueue::with_config(16, 32, true));
        let qp = q.clone();
        let h = std::thread::spawn(move || {
            for i in 0..20_000u64 {
                qp.push(i);
            }
        });
        let mut expect = 0u64;
        while expect < 20_000 {
            if let Some(v) = q.pop() {
                assert_eq!(v, expect);
                expect += 1;
            }
        }
        h.join().unwrap();
    }

    #[test]
    fn try_push_fails_when_exhausted_without_consumers() {
        let q = LfQueue::with_config(2, 2, false);
        let mut pushed = 0;
        while q.try_push(1u64).is_ok() {
            pushed += 1;
            assert!(pushed < 100);
        }
        assert!(pushed >= 2);
    }

    #[test]
    fn try_push_returns_the_value_on_failure() {
        let q: LfQueue<Box<u64>> = LfQueue::with_config(2, 2, false);
        loop {
            match q.try_push(Box::new(7)) {
                Ok(()) => {}
                Err(v) => {
                    assert_eq!(*v, 7, "rejected payload comes back intact");
                    break;
                }
            }
        }
    }

    #[test]
    fn block_accounting_upper_bound() {
        // §III analysis: blocks in use <= ceil(n1 / C).
        let c = 16;
        let q = LfQueue::with_config(c, 128, true);
        let n1 = 1000u64;
        for i in 0..n1 {
            q.push(i);
        }
        let st = q.stats();
        assert!(st.blocks_allocated as u64 <= n1.div_ceil(c as u64) + 1);
    }
}
