//! Common interface over all queue implementations so benchmarks, the
//! router and the delegation fabric can swap them.

/// A multi-producer multi-consumer queue of `T` payloads.
///
/// `u64` is the default payload — the native element of the paper's
/// experiments (keys / node pointers). The delegation fabric instantiates
/// the same implementations with typed op envelopes; implementations own a
/// pushed value until it is popped (or returned by a failed `try_push`) and
/// drop any still-enqueued values exactly once when the queue drops.
pub trait ConcurrentQueue<T: Send = u64>: Send + Sync {
    /// Enqueue, blocking (with backoff) if the implementation is at capacity.
    fn push(&self, v: T);

    /// Try to enqueue; hands the value back if the queue is at capacity
    /// right now (so non-`Copy` payloads are never silently lost).
    fn try_push(&self, v: T) -> Result<(), T>;

    /// Dequeue; `None` if the queue is observed empty.
    fn pop(&self) -> Option<T>;

    /// Implementation name for reports.
    fn name(&self) -> &'static str;
}
