//! Common interface over all queue implementations so benchmarks and the
//! router can swap them.

/// A multi-producer multi-consumer queue of `u64` payloads.
///
/// `u64` is the native payload of the paper's experiments (keys / node
/// pointers); richer types go through an arena index.
pub trait ConcurrentQueue: Send + Sync {
    /// Enqueue, blocking (with backoff) if the implementation is at capacity.
    fn push(&self, v: u64);

    /// Try to enqueue; `false` if the queue is at capacity right now.
    fn try_push(&self, v: u64) -> bool;

    /// Dequeue; `None` if the queue is observed empty.
    fn pop(&self) -> Option<u64>;

    /// Implementation name for reports.
    fn name(&self) -> &'static str;
}
