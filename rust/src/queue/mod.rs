//! Concurrent queues (paper §III–IV), generic over the payload.
//!
//! - [`LfQueue`] — the paper's contribution: array-block lock-free queue
//!   with pooled, recycled blocks (algorithms 7–10).
//! - [`TbbLikeQueue`] — TBB baseline: same LCRQ family, no recycling.
//! - [`MsQueue`] — boost baseline: Michael–Scott linked queue, coarse-locked
//!   free list.
//! - [`MutexQueue`] — coarse-lock oracle.
//!
//! Every implementation takes a `T: Send` payload type parameter defaulting
//! to `u64` (the paper's native element), so existing word-transport users
//! are unchanged while the delegation fabric ([`crate::coordinator`]) ships
//! typed op envelopes over the same queues. Non-`Copy` payloads are dropped
//! exactly once across push/pop/queue-drop (see `tests/queue_payloads.rs`).

pub mod lcrq;
pub mod ms_queue;
pub mod mutex_queue;
pub mod tbb_like;
pub mod traits;

pub use lcrq::{LfQueue, QueueStats};
pub use ms_queue::MsQueue;
pub use mutex_queue::MutexQueue;
pub use tbb_like::TbbLikeQueue;
pub use traits::ConcurrentQueue;

/// The paper's original `u64`-payload queue (keys / node pointers) — the
/// transport word lane of the coordinator's router fabric.
pub type WordQueue = LfQueue<u64>;
