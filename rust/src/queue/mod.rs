//! Concurrent queues (paper §III–IV).
//!
//! - [`LfQueue`] — the paper's contribution: array-block lock-free queue
//!   with pooled, recycled blocks (algorithms 7–10).
//! - [`TbbLikeQueue`] — TBB baseline: same LCRQ family, no recycling.
//! - [`MsQueue`] — boost baseline: Michael–Scott linked queue, coarse-locked
//!   free list.
//! - [`MutexQueue`] — coarse-lock oracle.

pub mod lcrq;
pub mod ms_queue;
pub mod mutex_queue;
pub mod tbb_like;
pub mod traits;

pub use lcrq::{LfQueue, QueueStats};
pub use ms_queue::MsQueue;
pub use mutex_queue::MutexQueue;
pub use tbb_like::TbbLikeQueue;
pub use traits::ConcurrentQueue;
