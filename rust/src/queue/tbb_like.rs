//! TBB-like concurrent queue baseline.
//!
//! The paper (§IV) notes TBB's `concurrent_queue` follows the LCRQ shape —
//! a linked list of array micro-queues with fetch-add cursors — but **does
//! not recycle memory**: segments are malloc'd as needed and retired
//! segments are freed later.  We reproduce that as [`LfQueue`] configured
//! with `recycle = false` plus TBB's trademark up-front segment reservation
//! ("TBB allocates large segments of memory before running queries", §VIII).

use super::lcrq::{LfQueue, QueueStats};
use super::traits::ConcurrentQueue;

pub struct TbbLikeQueue<T: Send = u64> {
    inner: LfQueue<T>,
}

impl<T: Send> TbbLikeQueue<T> {
    /// Paper's block size (8192) with a generous segment directory, matching
    /// TBB's eager reservation behaviour.
    pub fn new() -> TbbLikeQueue<T> {
        Self::with_config(8192, 1 << 16)
    }

    pub fn with_config(block_size: usize, max_blocks: usize) -> TbbLikeQueue<T> {
        TbbLikeQueue { inner: LfQueue::with_config(block_size, max_blocks, false) }
    }

    pub fn stats(&self) -> QueueStats {
        self.inner.stats()
    }
}

impl<T: Send> Default for TbbLikeQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send> ConcurrentQueue<T> for TbbLikeQueue<T> {
    fn push(&self, v: T) {
        self.inner.push(v)
    }

    fn try_push(&self, v: T) -> Result<(), T> {
        self.inner.try_push(v)
    }

    fn pop(&self) -> Option<T> {
        self.inner.pop()
    }

    fn name(&self) -> &'static str {
        "tbb-like"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_fifo() {
        let q = TbbLikeQueue::with_config(8, 64);
        for i in 0..50u64 {
            q.push(i);
        }
        for i in 0..50 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn never_recycles() {
        let q = TbbLikeQueue::with_config(4, 1024);
        for round in 0..20u64 {
            for i in 0..8 {
                q.push(round * 8 + i);
            }
            for _ in 0..8 {
                q.pop().unwrap();
            }
        }
        let st = q.stats();
        assert_eq!(st.blocks_recycled, 0);
        // fresh segments accumulate instead
        assert!(st.blocks_allocated > 20);
    }
}
