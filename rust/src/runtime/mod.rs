//! PJRT runtime: load the AOT-compiled L2/L1 routing pipeline and run it
//! from rust (python never executes at request time).
//!
//! Artifacts are HLO **text** (`artifacts/*.hlo.txt`) produced by
//! `python/compile/aot.py` — text, not serialized protos, because jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The PJRT engine needs the local `xla` bindings, which are not present in
//! every build environment — it is compiled only under the **`aot` cargo
//! feature**, and enabling that feature additionally requires adding the
//! `xla` path dependency to `rust/Cargo.toml` (see the comment there; a
//! missing path dep would break even default builds, so it is not
//! pre-declared). Without the feature, [`RouteEngine::load`] reports the
//! artifacts as unavailable and [`KeyRouter::auto`] falls back to
//! [`native_route`], the bit-exact rust implementation of the same
//! splitmix64 pipeline, so every experiment runs identically either way.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so a [`RouteEngine`] must be
//! created and used on one thread. That matches the paper's methodology —
//! "we filled the queues first before performing operations on the data
//! structures": the coordinator generates + routes batches on the leader
//! thread, workers drain per-thread queues.
//!
//! [`RouteEngine::self_check`] cross-validates a loaded artifact against
//! the native mixer at startup, so artifact drift is caught before any
//! experiment runs.

use crate::hashtable::hash::{hash_key, shard_of};
use crate::util::rng::mix64;

/// Number of shard bits baked into the kernels (8 NUMA shards).
pub const SHARD_BITS: u32 = 3;

/// A routed batch: for each generated key, its hash, NUMA shard and slot.
#[derive(Debug, Clone, Default)]
pub struct RoutedBatch {
    pub keys: Vec<u64>,
    pub hashes: Vec<u64>,
    pub shards: Vec<u64>,
    pub slots: Vec<u64>,
}

#[cfg_attr(not(feature = "aot"), allow(dead_code))]
impl RoutedBatch {
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    fn append(&mut self, other: &mut RoutedBatch) {
        self.keys.append(&mut other.keys);
        self.hashes.append(&mut other.hashes);
        self.shards.append(&mut other.shards);
        self.slots.append(&mut other.slots);
    }

    fn truncate(&mut self, n: usize) {
        self.keys.truncate(n);
        self.hashes.truncate(n);
        self.shards.truncate(n);
        self.slots.truncate(n);
    }
}

/// Bit-exact rust implementation of the `route` kernel
/// (`python/compile/kernels/route.py`): the no-artifact fallback and the
/// self-check oracle.
pub fn native_route(base: u64, m: u64, n: usize) -> RoutedBatch {
    assert!(m.is_power_of_two());
    let mut out = RoutedBatch {
        keys: Vec::with_capacity(n),
        hashes: Vec::with_capacity(n),
        shards: Vec::with_capacity(n),
        slots: Vec::with_capacity(n),
    };
    for i in 0..n as u64 {
        let key = mix64(base.wrapping_add(i));
        let h = hash_key(key);
        out.keys.push(key);
        out.hashes.push(h);
        out.shards.push(shard_of(key, SHARD_BITS) as u64);
        out.slots.push(h & (m - 1));
    }
    out
}

#[cfg(feature = "aot")]
mod aot_engine {
    use anyhow::{bail, Context, Result};

    use super::{native_route, RoutedBatch};

    /// One compiled batch-size variant of the routing pipeline.
    struct CompiledRoute {
        batch: usize,
        exe: xla::PjRtLoadedExecutable,
    }

    /// The AOT routing engine: PJRT CPU client + compiled `route_batch_<N>`
    /// executables. Not `Send` — create and use on the leader thread.
    pub struct RouteEngine {
        _client: xla::PjRtClient,
        /// sorted descending by batch size
        variants: Vec<CompiledRoute>,
        pub dispatches: std::cell::Cell<u64>,
    }

    impl RouteEngine {
        /// Load every `route_batch_*.hlo.txt` under `artifacts_dir`.
        pub fn load(artifacts_dir: &str) -> Result<RouteEngine> {
            let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
            let mut variants = Vec::new();
            for entry in std::fs::read_dir(artifacts_dir)
                .with_context(|| format!("artifacts dir {artifacts_dir} (run `make artifacts`)"))?
            {
                let path = entry?.path();
                let name = path.file_name().unwrap_or_default().to_string_lossy().to_string();
                if let Some(rest) = name.strip_prefix("route_batch_") {
                    if let Some(bs) = rest.strip_suffix(".hlo.txt") {
                        let batch: usize = bs.parse().context("batch size in artifact name")?;
                        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                            .with_context(|| format!("parse {name}"))?;
                        let comp = xla::XlaComputation::from_proto(&proto);
                        let exe = client.compile(&comp).with_context(|| format!("compile {name}"))?;
                        variants.push(CompiledRoute { batch, exe });
                    }
                }
            }
            if variants.is_empty() {
                bail!("no route_batch_*.hlo.txt artifacts in {artifacts_dir}");
            }
            variants.sort_by(|a, b| b.batch.cmp(&a.batch));
            let engine =
                RouteEngine { _client: client, variants, dispatches: std::cell::Cell::new(0) };
            engine.self_check().context("artifact self-check vs native mixer")?;
            Ok(engine)
        }

        /// Batch sizes available (descending).
        pub fn batch_sizes(&self) -> Vec<usize> {
            self.variants.iter().map(|v| v.batch).collect()
        }

        fn run_variant(&self, v: &CompiledRoute, base: u64, m: u64) -> Result<RoutedBatch> {
            let base_l = xla::Literal::vec1(&[base]);
            let m_l = xla::Literal::vec1(&[m]);
            let result = v.exe.execute::<xla::Literal>(&[base_l, m_l])?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            if parts.len() != 4 {
                bail!("route artifact returned {} outputs, want 4", parts.len());
            }
            let mut it = parts.into_iter();
            let keys = it.next().unwrap().to_vec::<u64>()?;
            let hashes = it.next().unwrap().to_vec::<u64>()?;
            let shards = it.next().unwrap().to_vec::<u64>()?;
            let slots = it.next().unwrap().to_vec::<u64>()?;
            self.dispatches.set(self.dispatches.get() + 1);
            Ok(RoutedBatch { keys, hashes, shards, slots })
        }

        /// Route `n` keys starting at counter `base` for a table of `m`
        /// slots. Runs as few compiled dispatches as possible (largest
        /// variants first), padding the tail with the smallest variant and
        /// truncating.
        pub fn route(&self, base: u64, m: u64, n: usize) -> Result<RoutedBatch> {
            assert!(m.is_power_of_two());
            let mut out = RoutedBatch::default();
            let mut off = 0usize;
            for v in &self.variants {
                while n - off >= v.batch {
                    let mut b = self.run_variant(v, base.wrapping_add(off as u64), m)?;
                    out.append(&mut b);
                    off += v.batch;
                }
            }
            if off < n {
                // tail: run the smallest variant once and truncate
                let v = self.variants.last().unwrap();
                let mut b = self.run_variant(v, base.wrapping_add(off as u64), m)?;
                b.truncate(n - off);
                out.append(&mut b);
            }
            Ok(out)
        }

        /// Cross-check the artifact against the rust mixer on a probe batch.
        pub fn self_check(&self) -> Result<()> {
            let v = self.variants.last().unwrap();
            let got = self.run_variant(v, 0, 8192)?;
            let want = native_route(0, 8192, v.batch);
            if got.keys != want.keys || got.hashes != want.hashes {
                bail!("artifact drift: AOT route != native splitmix64");
            }
            if got.shards != want.shards || got.slots != want.slots {
                bail!("artifact drift: AOT shard/slot routing != native");
            }
            Ok(())
        }
    }
}

#[cfg(not(feature = "aot"))]
mod aot_engine {
    use anyhow::{bail, Result};

    use super::RoutedBatch;

    /// API-compatible stand-in for the PJRT engine in builds without the
    /// `aot` feature: `load` always fails, so [`super::KeyRouter::auto`]
    /// falls back to the bit-exact native router. The other methods exist
    /// only so AOT-gated callers typecheck; they are unreachable because no
    /// stub engine can ever be constructed.
    pub struct RouteEngine {
        _priv: (),
        pub dispatches: std::cell::Cell<u64>,
    }

    impl RouteEngine {
        pub fn load(artifacts_dir: &str) -> Result<RouteEngine> {
            bail!(
                "AOT engine disabled: rebuild with `--features aot` after wiring the \
                 local xla bindings into rust/Cargo.toml, to load artifacts from \
                 {artifacts_dir}"
            )
        }

        pub fn batch_sizes(&self) -> Vec<usize> {
            Vec::new()
        }

        pub fn route(&self, _base: u64, _m: u64, _n: usize) -> Result<RoutedBatch> {
            bail!("AOT engine disabled (build without the `aot` feature)")
        }

        pub fn self_check(&self) -> Result<()> {
            bail!("AOT engine disabled (build without the `aot` feature)")
        }
    }
}

pub use aot_engine::RouteEngine;

/// Key router: AOT engine when artifacts are present (and the `aot` feature
/// is compiled in), else the bit-exact native path. Both produce identical
/// batches.
pub enum KeyRouter {
    Aot(RouteEngine),
    Native,
}

impl KeyRouter {
    /// Prefer AOT artifacts from `dir`; fall back to native with a notice.
    pub fn auto(dir: &str) -> KeyRouter {
        match RouteEngine::load(dir) {
            Ok(e) => KeyRouter::Aot(e),
            Err(err) => {
                eprintln!("[cdskl] AOT artifacts unavailable ({err:#}); using native router");
                KeyRouter::Native
            }
        }
    }

    pub fn route(&self, base: u64, m: u64, n: usize) -> RoutedBatch {
        match self {
            KeyRouter::Aot(e) => e.route(base, m, n).expect("AOT route"),
            KeyRouter::Native => native_route(base, m, n),
        }
    }

    pub fn is_aot(&self) -> bool {
        matches!(self, KeyRouter::Aot(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::GOLDEN;

    #[test]
    fn native_route_matches_golden() {
        let b = native_route(0, 8192, 5);
        assert_eq!(b.keys, GOLDEN.to_vec());
        for i in 0..5 {
            assert_eq!(b.hashes[i], mix64(b.keys[i]));
            assert_eq!(b.shards[i], b.keys[i] >> 61);
            assert_eq!(b.slots[i], b.hashes[i] & 8191);
        }
    }

    #[test]
    fn native_route_shard_range() {
        let b = native_route(12345, 1024, 10_000);
        assert!(b.shards.iter().all(|&s| s < 8));
        assert!(b.slots.iter().all(|&s| s < 1024));
        assert_eq!(b.len(), 10_000);
    }

    #[test]
    fn native_router_enum() {
        let r = KeyRouter::Native;
        assert!(!r.is_aot());
        let b = r.route(7, 256, 100);
        assert_eq!(b.len(), 100);
        assert_eq!(b.keys[0], mix64(7));
    }

    #[cfg(not(feature = "aot"))]
    #[test]
    fn auto_falls_back_to_native_without_aot_feature() {
        let r = KeyRouter::auto("artifacts");
        assert!(!r.is_aot(), "stub engine must never load");
        assert_eq!(r.route(3, 64, 10).keys, native_route(3, 64, 10).keys);
    }

    // AOT tests live in rust/tests/aot_roundtrip.rs (they need artifacts).
}
