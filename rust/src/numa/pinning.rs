//! Thread pinning (the paper pins thread i to CPU i).
//!
//! On the real Milan node this is `sched_setaffinity`; on the single-CPU
//! container every pin degenerates to CPU 0 and becomes a no-op — the
//! virtual topology still records which *virtual* CPU a thread owns.

/// Pin the calling thread to `cpu` (mod the host's CPU count).
/// Returns true when an affinity call actually succeeded.
/// Also records the *virtual* CPU for the mem layer, so per-shard arenas
/// can account local vs remote allocations against their home node.
pub fn pin_to_cpu(cpu: usize) -> bool {
    crate::mem::note_thread_cpu(cpu);
    let host_cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let target = cpu % host_cpus;
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_SET(target, &mut set);
        libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set) == 0
    }
}

/// Virtual CPU id for a worker thread (identity, like the paper).
pub fn cpu_of_thread(thread_id: usize) -> usize {
    thread_id
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_to_existing_cpu_succeeds() {
        assert!(pin_to_cpu(0));
    }

    #[test]
    fn pin_wraps_past_host_cpus() {
        // virtual CPU 127 must map onto some host CPU without failing
        assert!(pin_to_cpu(127));
    }

    #[test]
    fn identity_mapping() {
        assert_eq!(cpu_of_thread(5), 5);
    }
}
