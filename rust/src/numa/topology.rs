//! NUMA topology: detected from sysfs when real, virtual otherwise.
//!
//! The paper's testbed is an AMD Milan node with 8 NUMA nodes x 16 CPUs.
//! This container exposes a single CPU, so the default topology is a
//! **virtual** Milan-like 8x16 grid: thread pinning becomes a no-op, but
//! shard placement, per-node memory pools and locality accounting behave
//! exactly as they would on the real machine (DESIGN.md §Hardware-Adaptation).

/// A machine topology (real or virtual).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    pub numa_nodes: usize,
    pub cpus_per_node: usize,
    /// True when the grid reflects actual hardware rather than simulation.
    pub detected: bool,
}

impl Topology {
    /// The paper's AMD Milan layout: 8 NUMA nodes x 16 CPUs.
    pub fn milan_virtual() -> Topology {
        Topology { numa_nodes: 8, cpus_per_node: 16, detected: false }
    }

    /// Custom virtual topology.
    pub fn virtual_grid(numa_nodes: usize, cpus_per_node: usize) -> Topology {
        assert!(numa_nodes >= 1 && cpus_per_node >= 1);
        Topology { numa_nodes, cpus_per_node, detected: false }
    }

    /// Detect from sysfs; falls back to the virtual Milan grid when the
    /// host has no multi-node NUMA (as in this container). The `CDSKL_NODES`
    /// environment variable overrides both: `CDSKL_NODES=4` gives a virtual
    /// 4-node grid with the Milan per-node CPU count, `CDSKL_NODES=4x8`
    /// also sets CPUs per node — letting single-socket CI exercise every
    /// replica/shard-placement configuration deterministically.
    pub fn detect() -> Topology {
        if let Some(t) = Self::from_env() {
            return t;
        }
        let nodes = Self::sysfs_node_count().unwrap_or(1);
        let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        if nodes > 1 {
            Topology { numa_nodes: nodes, cpus_per_node: cpus.div_ceil(nodes), detected: true }
        } else {
            Topology::milan_virtual()
        }
    }

    /// Parse the `CDSKL_NODES` override (`"N"` or `"NxC"`); `None` when
    /// unset, empty, or malformed (malformed values are ignored rather
    /// than panicking — detection must never take a process down).
    fn from_env() -> Option<Topology> {
        let raw = std::env::var("CDSKL_NODES").ok()?;
        Self::parse_override(&raw)
    }

    /// `"N"` → N virtual nodes x Milan's 16 CPUs; `"NxC"` → N nodes x C
    /// CPUs each. Zero or unparsable fields reject the override.
    pub fn parse_override(raw: &str) -> Option<Topology> {
        let raw = raw.trim();
        if raw.is_empty() {
            return None;
        }
        let (n, c) = match raw.split_once(['x', 'X']) {
            Some((n, c)) => (n.trim().parse().ok()?, c.trim().parse().ok()?),
            None => (raw.parse().ok()?, Topology::milan_virtual().cpus_per_node),
        };
        if n == 0 || c == 0 {
            return None;
        }
        Some(Topology::virtual_grid(n, c))
    }

    fn sysfs_node_count() -> Option<usize> {
        let entries = std::fs::read_dir("/sys/devices/system/node").ok()?;
        let n = entries
            .filter_map(|e| e.ok())
            .filter(|e| {
                let name = e.file_name();
                let name = name.to_string_lossy();
                name.starts_with("node") && name[4..].chars().all(|c| c.is_ascii_digit())
            })
            .count();
        (n >= 1).then_some(n)
    }

    pub fn total_cpus(&self) -> usize {
        self.numa_nodes * self.cpus_per_node
    }

    /// NUMA node of a CPU id (CPUs are numbered node-major, like the
    /// paper's Milan: CPUs 0-15 on node 0, 16-31 on node 1, ...).
    pub fn node_of_cpu(&self, cpu: usize) -> usize {
        (cpu / self.cpus_per_node) % self.numa_nodes
    }

    /// Number of NUMA nodes engaged by `threads` threads pinned in id order
    /// — the paper's eq. (6): n_u = ceil(T / n_cpu).
    pub fn nodes_in_use(&self, threads: usize) -> usize {
        threads.div_ceil(self.cpus_per_node).min(self.numa_nodes).max(1)
    }

    /// Home NUMA node of shard `i` — the paper's eq. (7):
    /// n_{s_i} = S_i mod n_u.
    pub fn shard_home(&self, shard: usize, threads: usize) -> usize {
        shard % self.nodes_in_use(threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn milan_shape() {
        let t = Topology::milan_virtual();
        assert_eq!(t.total_cpus(), 128);
        assert_eq!(t.node_of_cpu(0), 0);
        assert_eq!(t.node_of_cpu(15), 0);
        assert_eq!(t.node_of_cpu(16), 1);
        assert_eq!(t.node_of_cpu(127), 7);
    }

    #[test]
    fn eq6_nodes_in_use() {
        let t = Topology::milan_virtual();
        assert_eq!(t.nodes_in_use(4), 1);
        assert_eq!(t.nodes_in_use(16), 1);
        assert_eq!(t.nodes_in_use(17), 2);
        assert_eq!(t.nodes_in_use(32), 2);
        assert_eq!(t.nodes_in_use(128), 8);
        assert_eq!(t.nodes_in_use(1_000), 8);
    }

    #[test]
    fn eq7_shard_home_odd_even_example() {
        // Paper: T=32, n_cpu=16 -> n_u=2; even shards on node 0, odd on 1.
        let t = Topology::milan_virtual();
        for s in 0..8 {
            assert_eq!(t.shard_home(s, 32), s % 2);
        }
        // T=128 -> n_u=8: shard i lives on node i.
        for s in 0..8 {
            assert_eq!(t.shard_home(s, 128), s);
        }
    }

    #[test]
    fn detect_never_panics() {
        let t = Topology::detect();
        assert!(t.numa_nodes >= 1);
        assert!(t.cpus_per_node >= 1);
    }

    #[test]
    fn env_override_parsing() {
        // bare node count: Milan CPUs per node
        let t = Topology::parse_override("4").unwrap();
        assert_eq!((t.numa_nodes, t.cpus_per_node), (4, 16));
        assert!(!t.detected);
        // NxC form, either case, whitespace tolerated
        let t = Topology::parse_override("2x4").unwrap();
        assert_eq!((t.numa_nodes, t.cpus_per_node), (2, 4));
        let t = Topology::parse_override(" 3X8 ").unwrap();
        assert_eq!((t.numa_nodes, t.cpus_per_node), (3, 8));
        // malformed / zero values are rejected, not panicked on
        for bad in ["", "0", "4x0", "0x4", "ax2", "2xb", "x", "4x", "x4"] {
            assert!(Topology::parse_override(bad).is_none(), "{bad:?} accepted");
        }
    }

    #[test]
    fn env_override_pins_node_assignment() {
        // 2 nodes x 4 CPUs: node of CPU c is (c/4) % 2, shards alternate
        // once both nodes are engaged (>= 5 threads).
        let t = Topology::parse_override("2x4").unwrap();
        assert_eq!(t.total_cpus(), 8);
        for (cpu, node) in [(0, 0), (3, 0), (4, 1), (7, 1), (8, 0)] {
            assert_eq!(t.node_of_cpu(cpu), node, "cpu {cpu}");
        }
        assert_eq!(t.nodes_in_use(4), 1);
        assert_eq!(t.nodes_in_use(5), 2);
        for s in 0..8 {
            assert_eq!(t.shard_home(s, 8), s % 2);
            assert_eq!(t.shard_home(s, 4), 0);
        }
    }
}
