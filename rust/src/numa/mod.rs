//! The (virtual) NUMA layer: topology + eqs (6)-(7) shard placement,
//! thread pinning, locality accounting and latency injection
//! (paper §I, §VI; DESIGN.md §Hardware-Adaptation).

pub mod locality;
pub mod pinning;
pub mod topology;

pub use locality::{LocalityStats, LatencyModel, LATENCY};
pub use pinning::pin_to_cpu;
pub use topology::Topology;
