//! Locality accounting and optional remote-latency injection.
//!
//! On the real Milan machine the paper measures wall-time effects of remote
//! NUMA accesses; on this single-CPU container we measure the *cause*
//! directly — counts of local vs remote (virtual-)node accesses — and can
//! optionally inject a calibrated delay per remote access to recover the
//! wall-time shape (Milan remote/local latency ratio is ~2.3x; we default
//! to ~200ns extra per remote access when enabled).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Per-structure locality counters.
#[derive(Debug, Default)]
pub struct LocalityStats {
    pub local: AtomicU64,
    pub remote: AtomicU64,
}

impl LocalityStats {
    pub fn new() -> LocalityStats {
        LocalityStats::default()
    }

    #[inline]
    pub fn record(&self, local: bool) {
        if local {
            self.local.fetch_add(1, Ordering::Relaxed);
        } else {
            self.remote.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> (u64, u64) {
        (self.local.load(Ordering::Relaxed), self.remote.load(Ordering::Relaxed))
    }

    pub fn remote_fraction(&self) -> f64 {
        let (l, r) = self.snapshot();
        if l + r == 0 {
            0.0
        } else {
            r as f64 / (l + r) as f64
        }
    }
}

/// Global switch + magnitude for remote-access delay injection.
pub struct LatencyModel {
    enabled: AtomicBool,
    remote_extra_ns: AtomicU64,
}

impl LatencyModel {
    pub const fn new() -> LatencyModel {
        LatencyModel { enabled: AtomicBool::new(false), remote_extra_ns: AtomicU64::new(200) }
    }

    pub fn enable(&self, extra_ns: u64) {
        self.remote_extra_ns.store(extra_ns, Ordering::Relaxed);
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Charge one remote access: spin for the configured delay.
    #[inline]
    pub fn charge_remote(&self) {
        if !self.is_enabled() {
            return;
        }
        let ns = self.remote_extra_ns.load(Ordering::Relaxed);
        let t0 = std::time::Instant::now();
        while (t0.elapsed().as_nanos() as u64) < ns {
            std::hint::spin_loop();
        }
    }
}

impl Default for LatencyModel {
    fn default() -> Self {
        Self::new()
    }
}

/// Process-wide model used by the coordinator.
pub static LATENCY: LatencyModel = LatencyModel::new();

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let s = LocalityStats::new();
        s.record(true);
        s.record(true);
        s.record(false);
        assert_eq!(s.snapshot(), (2, 1));
        assert!((s.remote_fraction() - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_fraction_is_zero() {
        assert_eq!(LocalityStats::new().remote_fraction(), 0.0);
    }

    #[test]
    fn injection_delays_when_enabled() {
        let m = LatencyModel::new();
        assert!(!m.is_enabled());
        m.charge_remote(); // no-op
        m.enable(50_000); // 50us so the test is robust
        let t0 = std::time::Instant::now();
        m.charge_remote();
        assert!(t0.elapsed().as_nanos() >= 50_000);
        m.disable();
        let t0 = std::time::Instant::now();
        m.charge_remote();
        assert!(t0.elapsed().as_micros() < 50);
    }
}
