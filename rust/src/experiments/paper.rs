//! The paper's reported numbers, embedded so reports can show
//! paper-vs-measured side by side (EXPERIMENTS.md). All values are seconds
//! for the whole workload, averaged over 5 repetitions, on a 128-CPU
//! 8-NUMA-node AMD Milan (NCSA Delta). Thread sweep: 4..128.

pub const THREADS: [u64; 6] = [4, 8, 16, 32, 64, 128];

/// Table I: queue performance, 100m ops (tbb, lkfree).
pub const T1_100M: [(f64, f64); 6] = [
    (2.525576, 3.23806),
    (1.468532, 2.033946),
    (1.672976, 2.378378),
    (0.7895414, 1.286334),
    (0.4291294, 0.6874498),
    (0.2574812, 0.3819218),
];

/// Table I: queue performance, 1b ops (tbb, lkfree).
pub const T1_1B: [(f64, f64); 6] = [
    (14.9945, 20.19996),
    (9.728728, 12.46478),
    (15.65188, 13.7761),
    (7.565792, 7.139884),
    (3.532416, 3.800926),
    (3.279696, 2.18968),
];

/// Table II: skiplist 10m ops, workload 1 (RWlocks, lkfreefind).
pub const T2_10M: [(f64, f64); 6] = [
    (16.3483, 13.70978),
    (9.237172, 7.842358),
    (11.7282, 8.181222),
    (6.77715, 5.31692),
    (4.614454, 4.869106),
    (4.248924, 3.739122),
];

/// Table III: skiplist 100m ops — (RWL IF, lkfree IF, RWL IFE, lkfree IFE).
pub const T3_100M: [(f64, f64, f64, f64); 6] = [
    (195.069, 138.496, 207.9766, 136.8524),
    (104.2194, 75.27658, 102.8858, 75.15104),
    (103.9242, 71.53346, 101.54936, 88.02024),
    (80.00542, 45.49626, 60.25536, 56.98748),
    (54.5701, 37.90108, 41.77146, 47.41808),
    (40.8587, 34.28502, 39.33168, 32.7872),
];

/// Table IV: deterministic (lkfreefind) vs lockfree random skiplist, 100m.
pub const T4_100M: [(f64, f64); 6] = [
    (138.496, 43.7999),
    (75.27658, 23.00286),
    (71.53346, 17.16074),
    (45.49626, 8.108614),
    (37.90108, 4.343792),
    (34.28502, 2.863776),
];

/// Table V: fixed vs two-level hash tables — (fixed10m, twolevel10m,
/// fixed100m, twolevel100m). NOTE: the published table is partially
/// corrupted; rows below reconstruct the readable cells.
pub const T5: [(f64, f64, f64, f64); 6] = [
    (1.8080762, 1.8143984, 21.56307, 12.077078),
    (1.4035088, 0.9598364, 12.79544, 6.297646),
    (1.4310018, 0.5916096, 10.666476, 3.901922),
    (0.6556778, 0.404464, 5.624658, 2.081128),
    (0.3043472, 0.3143486, 2.946662, 1.433568),
    (0.19882468, f64::NAN, f64::NAN, 1.392154),
];

/// Table VI: cache overheads of one-level vs two-level split-order, 10m.
pub const T6_10M: [(f64, f64); 6] = [
    (4.1893104, 1.8829426),
    (4.384854, 0.9649104),
    (8.3696894, 0.4804762),
    (4.0107974, 0.242256),
    (2.2309622, 0.1543608),
    (1.18745908, 0.11367386),
];

/// Table VII: three hash tables, 100m — (tbb, SPO, BinLists).
pub const T7_100M: [(f64, f64, f64); 6] = [
    (7.87826, 13.57318, 12.09342),
    (4.877724, 7.092238, 6.04725),
    (4.44002, 4.032536, 5.567374),
    (2.234972, 1.890784, 2.556356),
    (1.360036, 1.124712, 1.265442),
    (0.8601906, 0.7902118, 0.6457664),
];

/// Table VIII: three hash tables, 1b — (tbb, SPO, BinLists).
pub const T8_1B: [(f64, f64, f64); 6] = [
    (94.07204, 165.8882, 213.8314),
    (55.35936, 84.47286, 109.2326),
    (48.3085, 44.83896, 65.62332),
    (24.04664, 22.69882, 31.12086),
    (11.55592, 11.0454, 15.21968),
    (6.001542, 5.177758, 7.701186),
];

/// Shape expectations the reproduction asserts (who wins where).
pub mod shapes {
    /// Table IV: the randomized skiplist beats the deterministic one, by a
    /// factor growing with thread count (3.1x at 4t, ~12x at 128t).
    pub const T4_RANDOM_WINS: bool = true;
    /// Table V: two-level beats fixed for the large workload at every
    /// thread count.
    pub const T5_TWOLEVEL_WINS_LARGE: bool = true;
    /// Table VI: two-level split-order dominates the flat table's cache
    /// behaviour (up to ~17x at 16 threads).
    pub const T6_TWOLEVEL_SPO_WINS: bool = true;
}
