//! Experiment harness: one runner per paper table/figure, producing
//! markdown tables with paper-vs-measured columns (EXPERIMENTS.md).
//!
//! Workloads are scaled to the single-CPU container by default (`scale`
//! divides the paper's op counts); pass `--full` / `scale = 1` on real
//! hardware to run the original sizes.

pub mod batch;
pub mod cache;
pub mod chaos;
pub mod fatinner;
pub mod fatleaf;
pub mod hier;
pub mod mem;
pub mod mlp;
pub mod paper;
pub mod queues;
pub mod replica;

pub use self::batch::t13_batch;
pub use self::cache::t12_cache;
pub use self::chaos::t17_chaos;
pub use self::fatinner::t16_fatinner;
pub use self::fatleaf::t15_fatleaf;
pub use self::hier::t11_hier;
pub use self::mem::t10_mem;
pub use self::mlp::t14_mlp;
pub use self::replica::t18_replica;

use std::sync::Arc;

use crate::coordinator::{run_with_mode, ExecMode, RunMetrics, ShardedStore, StoreKind};
use crate::hashtable::{ConcurrentMap, SpoHashMap, TwoLevelSpoHashMap};
use crate::numa::Topology;
use crate::runtime::KeyRouter;
use crate::util::bench::Table;
use crate::util::stats::Summary;
use crate::workload::{OpMix, WorkloadSpec};

use queues::{run_queue_workload, QueueImpl};

/// Experiment configuration shared by every table runner.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    pub threads: Vec<u64>,
    pub reps: usize,
    /// Divide the paper's op counts by this (paper sizes / single CPU).
    pub scale: u64,
    pub topology: Topology,
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            threads: paper::THREADS.to_vec(),
            reps: 2,
            scale: 100, // 100m -> 1m, 10m -> 100k, 1b -> 10m
            topology: Topology::milan_virtual(),
            seed: 0xC0DE,
        }
    }
}

impl ExpConfig {
    fn ops(&self, paper_ops: u64) -> u64 {
        (paper_ops / self.scale).max(10_000)
    }
}

fn store_run(
    cfg: &ExpConfig,
    kind: StoreKind,
    mix: OpMix,
    total_ops: u64,
    threads: usize,
    router: &KeyRouter,
) -> (Summary, RunMetrics) {
    store_run_with_mode(cfg, kind, mix, total_ops, threads, router, ExecMode::Direct, 64)
}

/// One measured workload run per rep in the given [`ExecMode`] (Table XI
/// compares Direct against Delegated; every older table runs Direct).
#[allow(clippy::too_many_arguments)]
pub(crate) fn store_run_with_mode(
    cfg: &ExpConfig,
    kind: StoreKind,
    mix: OpMix,
    total_ops: u64,
    threads: usize,
    router: &KeyRouter,
    mode: ExecMode,
    range_window: u64,
) -> (Summary, RunMetrics) {
    let mut samples = Vec::with_capacity(cfg.reps);
    let mut last = RunMetrics::default();
    for rep in 0..cfg.reps {
        let store = Arc::new(ShardedStore::new(
            kind,
            8,
            (total_ops as usize / 4).max(1 << 14),
            cfg.topology.clone(),
            threads,
        ));
        let spec = WorkloadSpec::new("exp", total_ops, mix, (total_ops / 2).max(1 << 14))
            .with_range_window(range_window);
        let m = run_with_mode(&store, &spec, threads, router, cfg.seed + rep as u64, mode);
        samples.push(m.drain_seconds);
        last = m;
    }
    (Summary::of(&samples), last)
}

/// Table I / fig 3: queues, tbb vs lkfree, two workload sizes.
pub fn t1_queues(cfg: &ExpConfig) -> Vec<Table> {
    let small = cfg.ops(100_000_000);
    let big = cfg.ops(1_000_000_000);
    let mut out = Vec::new();
    for (label, ops, paper_rows) in [
        ("Table I — queues, 100m-class workload", small, &paper::T1_100M),
        ("Table I — queues, 1b-class workload", big, &paper::T1_1B),
    ] {
        let mut t = Table::new(
            &format!("{label} ({ops} ops, scale 1/{})", cfg.scale),
            "#threads",
            &["tbb(s)", "lkfree(s)", "paper tbb(s)", "paper lkfree(s)"],
        );
        for (i, &th) in cfg.threads.iter().enumerate() {
            let mut tbb = Vec::new();
            let mut lk = Vec::new();
            for r in 0..cfg.reps {
                tbb.push(run_queue_workload(QueueImpl::TbbLike, th as usize, ops, &cfg.topology, cfg.seed + r as u64));
                lk.push(run_queue_workload(QueueImpl::Lkfree, th as usize, ops, &cfg.topology, cfg.seed + r as u64));
            }
            let (p_tbb, p_lk) = paper_rows.get(i).copied().unwrap_or((f64::NAN, f64::NAN));
            t.push_row(th, vec![Summary::of(&tbb).mean, Summary::of(&lk).mean, p_tbb, p_lk]);
        }
        out.push(t);
    }
    out
}

/// Table II / fig 4: skiplist workload 1, 10m-class, RWL vs lockfree find.
pub fn t2_skiplist_w1(cfg: &ExpConfig, router: &KeyRouter) -> Table {
    let ops = cfg.ops(10_000_000);
    let mut t = Table::new(
        &format!("Table II — skiplist w1 ({ops} ops, scale 1/{})", cfg.scale),
        "#threads",
        &["RWlocks(s)", "lkfreefind(s)", "paper RWL(s)", "paper lkfree(s)"],
    );
    for (i, &th) in cfg.threads.iter().enumerate() {
        let (rwl, _) = store_run(cfg, StoreKind::DetSkiplistRwl, OpMix::W1, ops, th as usize, router);
        let (lf, _) = store_run(cfg, StoreKind::DetSkiplistLf, OpMix::W1, ops, th as usize, router);
        let (p_rwl, p_lf) = paper::T2_10M.get(i).copied().unwrap_or((f64::NAN, f64::NAN));
        t.push_row(th, vec![rwl.mean, lf.mean, p_rwl, p_lf]);
    }
    t
}

/// Table III / fig 5: skiplist 100m-class, workloads IF and IFE.
pub fn t3_skiplist_w2(cfg: &ExpConfig, router: &KeyRouter) -> Table {
    let ops = cfg.ops(100_000_000);
    let mut t = Table::new(
        &format!("Table III — skiplist w1/w2 ({ops} ops, scale 1/{})", cfg.scale),
        "#threads",
        &["RWL(IF)", "lkfree(IF)", "RWL(IFE)", "lkfree(IFE)", "paper RWL(IF)", "paper lkfree(IF)", "paper RWL(IFE)", "paper lkfree(IFE)"],
    );
    for (i, &th) in cfg.threads.iter().enumerate() {
        let (a, _) = store_run(cfg, StoreKind::DetSkiplistRwl, OpMix::W1, ops, th as usize, router);
        let (b, _) = store_run(cfg, StoreKind::DetSkiplistLf, OpMix::W1, ops, th as usize, router);
        let (c, _) = store_run(cfg, StoreKind::DetSkiplistRwl, OpMix::W2, ops, th as usize, router);
        let (d, _) = store_run(cfg, StoreKind::DetSkiplistLf, OpMix::W2, ops, th as usize, router);
        let (p1, p2, p3, p4) = paper::T3_100M
            .get(i)
            .copied()
            .unwrap_or((f64::NAN, f64::NAN, f64::NAN, f64::NAN));
        t.push_row(th, vec![a.mean, b.mean, c.mean, d.mean, p1, p2, p3, p4]);
    }
    t
}

/// Table IV / fig 6: deterministic vs randomized skiplist.
pub fn t4_random_vs_det(cfg: &ExpConfig, router: &KeyRouter) -> Table {
    let ops = cfg.ops(100_000_000);
    let mut t = Table::new(
        &format!("Table IV — lkfreefind vs lkfreeRandomSL ({ops} ops, scale 1/{})", cfg.scale),
        "#threads",
        &["lkfreefind(s)", "lkfreeRandomSL(s)", "paper det(s)", "paper random(s)"],
    );
    for (i, &th) in cfg.threads.iter().enumerate() {
        let (det, _) = store_run(cfg, StoreKind::DetSkiplistLf, OpMix::W1, ops, th as usize, router);
        let (rnd, _) = store_run(cfg, StoreKind::RandomSkiplist, OpMix::W1, ops, th as usize, router);
        let (p_det, p_rnd) = paper::T4_100M.get(i).copied().unwrap_or((f64::NAN, f64::NAN));
        t.push_row(th, vec![det.mean, rnd.mean, p_det, p_rnd]);
    }
    t
}

/// Table V / fig 7: fixed vs two-level hash tables, two sizes.
pub fn t5_hash_fixed_twolevel(cfg: &ExpConfig, router: &KeyRouter) -> Table {
    let small = cfg.ops(10_000_000);
    let big = cfg.ops(100_000_000);
    let mut t = Table::new(
        &format!("Table V — fixed vs two-level hash ({small}/{big} ops, scale 1/{})", cfg.scale),
        "#threads",
        &["fixed-sm", "twolevel-sm", "fixed-lg", "twolevel-lg", "paper fixed10m", "paper twolevel10m", "paper fixed100m", "paper twolevel100m"],
    );
    for (i, &th) in cfg.threads.iter().enumerate() {
        let (a, _) = store_run(cfg, StoreKind::HashFixed, OpMix::HASH, small, th as usize, router);
        let (b, _) = store_run(cfg, StoreKind::HashTwoLevel, OpMix::HASH, small, th as usize, router);
        let (c, _) = store_run(cfg, StoreKind::HashFixed, OpMix::HASH, big, th as usize, router);
        let (d, _) = store_run(cfg, StoreKind::HashTwoLevel, OpMix::HASH, big, th as usize, router);
        let (p1, p2, p3, p4) =
            paper::T5.get(i).copied().unwrap_or((f64::NAN, f64::NAN, f64::NAN, f64::NAN));
        t.push_row(th, vec![a.mean, b.mean, c.mean, d.mean, p1, p2, p3, p4]);
    }
    t
}

/// Table VI / fig 8: cache behaviour of one- vs two-level split-order.
/// Reported columns: wall seconds plus the cache-miss proxy (walk steps +
/// parent-chain hops per op — see DESIGN.md §Hardware-Adaptation).
pub fn t6_spo_cache(cfg: &ExpConfig) -> Table {
    let ops = cfg.ops(10_000_000);
    let mut t = Table::new(
        &format!("Table VI — split-order cache behaviour ({ops} ops, scale 1/{})", cfg.scale),
        "#threads",
        &["spo(s)", "2lvl-spo(s)", "spo miss-proxy/op", "2lvl miss-proxy/op", "paper spo(s)", "paper 2lvl(s)"],
    );
    for (i, &th) in cfg.threads.iter().enumerate() {
        let mut secs = [Vec::new(), Vec::new()];
        let mut proxy = [0f64, 0f64];
        // Seeds scale with the workload, preserving the paper's ratio
        // (seed 8192 for 10m ops); flat and hierarchical get the same total
        // seed slots so the difference is purely structural.
        let flat_seed = ((ops / 1024).next_power_of_two() as usize).clamp(16, 8192);
        let fanout = 64.min(flat_seed / 4).max(2);
        let seed2 = (flat_seed / fanout).max(4);
        for r in 0..cfg.reps {
            let flat = SpoHashMap::with_config(flat_seed, 16, 1 << 18, ops as usize + (1 << 14));
            secs[0].push(hammer_map(&flat, th as usize, ops, cfg.seed + r as u64));
            // miss proxy = distance-weighted lazy-init slot chasing per op
            // (far-apart parent slots are the flat table's cache killer)
            proxy[0] = flat.stats().init_parent_hops as f64 / ops as f64;
            let two = TwoLevelSpoHashMap::with_config(fanout, seed2, 16, 1 << 14, (ops as usize / fanout).max(1 << 12));
            secs[1].push(hammer_map(&two, th as usize, ops, cfg.seed + r as u64));
            proxy[1] = two.stats().init_parent_hops as f64 / ops as f64;
        }
        let (p1, p2) = paper::T6_10M.get(i).copied().unwrap_or((f64::NAN, f64::NAN));
        t.push_row(
            th,
            vec![Summary::of(&secs[0]).mean, Summary::of(&secs[1]).mean, proxy[0], proxy[1], p1, p2],
        );
    }
    t
}

/// Tables VII-VIII / fig 9: tbb vs SPO vs BinLists, two sizes.
pub fn t78_hash_compare(cfg: &ExpConfig, router: &KeyRouter) -> Vec<Table> {
    let mut out = Vec::new();
    for (label, paper_ops, paper_rows) in [
        ("Table VII — three hash tables, 100m-class", 100_000_000u64, &paper::T7_100M),
        ("Table VIII — three hash tables, 1b-class", 1_000_000_000u64, &paper::T8_1B),
    ] {
        let ops = cfg.ops(paper_ops);
        let mut t = Table::new(
            &format!("{label} ({ops} ops, scale 1/{})", cfg.scale),
            "#threads",
            &["tbb(s)", "SPO(s)", "BinLists(s)", "paper tbb", "paper SPO", "paper BinLists"],
        );
        for (i, &th) in cfg.threads.iter().enumerate() {
            let (a, _) = store_run(cfg, StoreKind::HashTbbLike, OpMix::HASH, ops, th as usize, router);
            let (b, _) = store_run(cfg, StoreKind::HashTwoLevelSpo, OpMix::HASH, ops, th as usize, router);
            let (c, _) = store_run(cfg, StoreKind::HashTwoLevel, OpMix::HASH, ops, th as usize, router);
            let (p1, p2, p3) =
                paper_rows.get(i).copied().unwrap_or((f64::NAN, f64::NAN, f64::NAN));
            t.push_row(th, vec![a.mean, b.mean, c.mean, p1, p2, p3]);
        }
        out.push(t);
    }
    out
}

/// Table IX (beyond the paper, §IX motivation): range throughput of the
/// mixed point/range workload (`OpMix::RANGE`, window 64) on the sharded
/// stores. Skiplists answer scans off the terminal linked list; the
/// hierarchical split-order table pays a full sorted snapshot per scan —
/// the structural gap the paper's §IX argues for.
pub fn t9_range(cfg: &ExpConfig, router: &KeyRouter) -> Table {
    let ops = cfg.ops(10_000_000);
    let mut t = Table::new(
        &format!("Table IX (new) — mixed point/range workload ({ops} ops, window 64, scale 1/{})", cfg.scale),
        "#threads",
        &["det-lf(s)", "random(s)", "2lvl-spo(s)", "det rows/scan", "det Mops/s"],
    );
    for &th in cfg.threads.iter() {
        let (det, dm) =
            store_run(cfg, StoreKind::DetSkiplistLf, OpMix::RANGE, ops, th as usize, router);
        let (rnd, _) =
            store_run(cfg, StoreKind::RandomSkiplist, OpMix::RANGE, ops, th as usize, router);
        let (spo, _) =
            store_run(cfg, StoreKind::HashTwoLevelSpo, OpMix::RANGE, ops, th as usize, router);
        let rows_per_scan =
            if dm.ranges == 0 { 0.0 } else { dm.range_rows as f64 / dm.ranges as f64 };
        t.push_row(th, vec![det.mean, rnd.mean, spo.mean, rows_per_scan, dm.throughput_mops()]);
    }
    t
}

/// Drive a bare map with threads doing 50/50 insert/find (T6 helper; no
/// router fabric so the split-order stats isolate table behaviour).
pub fn hammer_map<M: ConcurrentMap>(map: &M, threads: usize, ops: u64, seed: u64) -> f64 {
    use std::sync::Barrier;
    use std::time::Instant;
    let barrier = Arc::new(Barrier::new(threads + 1));
    let per = ops / threads as u64;
    std::thread::scope(|scope| {
        for t in 0..threads {
            let barrier = barrier.clone();
            let map = &*map;
            scope.spawn(move || {
                crate::numa::pin_to_cpu(t);
                let mut rng = crate::util::rng::Rng::new(seed ^ (t as u64) << 40);
                barrier.wait();
                for _ in 0..per {
                    let k = rng.below(per * threads as u64 / 2 + 1);
                    if rng.chance(1, 2) {
                        map.insert(k, k);
                    } else {
                        let _ = map.get(k);
                    }
                }
            });
        }
        let t0 = Instant::now(); // before the barrier: see engine.rs timing note
        barrier.wait();
        // scope join happens at block end
        drop(barrier);
        t0
    })
    .elapsed()
    .as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            threads: vec![2, 4],
            reps: 1,
            scale: 10_000,
            topology: Topology::virtual_grid(2, 2),
            seed: 1,
        }
    }

    #[test]
    fn t1_produces_tables() {
        let tabs = t1_queues(&tiny_cfg());
        assert_eq!(tabs.len(), 2);
        assert_eq!(tabs[0].rows.len(), 2);
        assert!(tabs[0].rows[0].1[0] > 0.0);
    }

    #[test]
    fn t2_t4_run() {
        let cfg = tiny_cfg();
        let r = KeyRouter::Native;
        let t2 = t2_skiplist_w1(&cfg, &r);
        assert_eq!(t2.rows.len(), 2);
        let t4 = t4_random_vs_det(&cfg, &r);
        assert!(t4.rows[0].1[0] > 0.0 && t4.rows[0].1[1] > 0.0);
    }

    #[test]
    fn t6_proxy_shows_two_level_wins() {
        let cfg = tiny_cfg();
        let t = t6_spo_cache(&cfg);
        // cache-miss proxy per op: two-level must not be worse
        for (_, row) in &t.rows {
            assert!(row[3] <= row[2] * 1.5, "2lvl proxy {} vs flat {}", row[3], row[2]);
        }
    }

    #[test]
    fn t9_range_runs_and_scans_rows() {
        let cfg = tiny_cfg();
        let t = t9_range(&cfg, &KeyRouter::Native);
        assert_eq!(t.rows.len(), 2);
        for (_, row) in &t.rows {
            assert!(row[0] > 0.0 && row[1] > 0.0 && row[2] > 0.0, "all stores must run");
            assert!(row[3] >= 0.0, "rows/scan is a count");
        }
    }

    #[test]
    fn hammer_map_runs() {
        let m = crate::hashtable::FixedHashMap::new(64);
        let secs = hammer_map(&m, 2, 5_000, 3);
        assert!(secs > 0.0);
        assert!(m.len() > 0);
    }
}
