//! Table XII (beyond the paper): the cache-conscious search path —
//! hot/cold node split, descent prefetching and per-thread search fingers
//! — measured end to end through the engine.
//!
//! Methodology (EXPERIMENTS.md §Table XII): a repeated-nearby-key workload
//! (`OpMix::W2` with a 64-key moving hot window, the zipf-ish working set
//! the fingers exploit) runs on the deterministic skiplist store twice per
//! mode — once with fingers disabled (the pure top-down baseline) and once
//! enabled — in both [`ExecMode::Direct`] and [`ExecMode::Delegated`].
//! Reported per run: hot-line node dereferences per op (the cache-cost
//! proxy), the finger hit rate, prefetches per op and throughput.
//!
//! The run self-asserts the PR's acceptance bar: finger hit rate > 50% and
//! *strictly fewer* node dereferences per op than the baseline, in both
//! execution modes.

use std::sync::Arc;

use crate::coordinator::{run_with_opts, ExecMode, RunOptions, ShardedStore, StoreKind};
use crate::runtime::KeyRouter;
use crate::util::bench::Table;
use crate::workload::{OpMix, WorkloadSpec};

use super::ExpConfig;

/// Width of the moving hot key window (keys per locality neighbourhood).
pub const T12_HOT_SPAN: u64 = 64;
/// Ops per hot window before the neighbourhood moves.
pub const T12_HOT_PHASE: u64 = 2048;
/// Bounded key space: small enough that finds hit resident keys, large
/// enough that the per-shard structures grow real height to descend.
pub const T12_KEY_SPACE: u64 = 4096;

struct CacheRun {
    derefs_per_op: f64,
    hit_rate: f64,
    prefetch_per_op: f64,
    mops: f64,
}

/// One measured cell, averaged over `cfg.reps` fresh-store runs (every rep
/// rebuilds the store so counters and resident sets start clean).
fn run_cache(
    cfg: &ExpConfig,
    ops: u64,
    threads: usize,
    router: &KeyRouter,
    mode: ExecMode,
    fingers: bool,
) -> CacheRun {
    let reps = cfg.reps.max(1);
    let mut acc = CacheRun { derefs_per_op: 0.0, hit_rate: 0.0, prefetch_per_op: 0.0, mops: 0.0 };
    for rep in 0..reps {
        let store = Arc::new(ShardedStore::new(
            StoreKind::DetSkiplistLf,
            8,
            (ops as usize / 4).max(1 << 14),
            cfg.topology.clone(),
            threads,
        ));
        store.set_finger_cache(fingers);
        let spec = WorkloadSpec::new("cache", ops, OpMix::W2, T12_KEY_SPACE)
            .with_hot_span(T12_HOT_SPAN, T12_HOT_PHASE);
        // Owner-side combining executes pooled ops through the fused
        // sorted-run path, which never consults the finger cache — Table
        // XIII measures that strategy; this table isolates the point-op
        // descent, so delegated runs pin per-envelope execution.
        let m = run_with_opts(
            &store,
            &spec,
            threads,
            router,
            cfg.seed + rep as u64,
            RunOptions { mode, combining: false, ..RunOptions::default() },
        );
        let st = store.stats();
        let done = m.ops().max(1);
        acc.derefs_per_op += st.node_derefs as f64 / done as f64;
        acc.hit_rate += st.finger_hit_rate();
        acc.prefetch_per_op += st.prefetches as f64 / done as f64;
        acc.mops += m.throughput_mops();
    }
    let n = reps as f64;
    CacheRun {
        derefs_per_op: acc.derefs_per_op / n,
        hit_rate: acc.hit_rate / n,
        prefetch_per_op: acc.prefetch_per_op / n,
        mops: acc.mops / n,
    }
}

/// Table XII: baseline (fingers off) vs finger-accelerated derefs/op, hit
/// rate and prefetch distance, per thread count, in Direct and Delegated
/// modes. Panics if the acceptance bar is missed (hit rate <= 50% or no
/// strict deref reduction) — the same role the locality assert plays in
/// Table XI.
pub fn t12_cache(cfg: &ExpConfig, router: &KeyRouter) -> Table {
    let ops = cfg.ops(10_000_000);
    let mut t = Table::new(
        &format!(
            "Table XII (new) — cache-conscious search path ({ops} ops, mix W2, \
             hot window {T12_HOT_SPAN}x{T12_HOT_PHASE}, key space {T12_KEY_SPACE}, \
             scale 1/{})",
            cfg.scale
        ),
        "#threads",
        &[
            "dir base d/op",
            "dir finger d/op",
            "dir hit%",
            "del base d/op",
            "del finger d/op",
            "del hit%",
            "dir pf/op",
            "dir Mops/s",
            "del Mops/s",
        ],
    );
    for &th in cfg.threads.iter() {
        let mut cols = [0f64; 9];
        for (mi, mode) in [ExecMode::Direct, ExecMode::Delegated].into_iter().enumerate() {
            let base = run_cache(cfg, ops, th as usize, router, mode, false);
            let fing = run_cache(cfg, ops, th as usize, router, mode, true);
            assert!(
                fing.hit_rate > 0.5,
                "{} mode, {th} threads: finger hit rate {:.1}% must exceed 50% \
                 under the repeated-nearby-key workload",
                mode.name(),
                fing.hit_rate * 100.0
            );
            assert!(
                fing.derefs_per_op < base.derefs_per_op,
                "{} mode, {th} threads: fingers must strictly cut derefs/op \
                 (finger {:.2} vs baseline {:.2})",
                mode.name(),
                fing.derefs_per_op,
                base.derefs_per_op
            );
            cols[mi * 3] = base.derefs_per_op;
            cols[mi * 3 + 1] = fing.derefs_per_op;
            cols[mi * 3 + 2] = fing.hit_rate * 100.0;
            cols[7 + mi] = fing.mops;
            if mi == 0 {
                cols[6] = fing.prefetch_per_op;
            }
        }
        t.push_row(th, cols.to_vec());
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    #[test]
    fn t12_cache_asserts_hit_rate_and_deref_cut() {
        let cfg = ExpConfig {
            threads: vec![4],
            reps: 1,
            scale: 10_000,
            topology: Topology::virtual_grid(2, 2),
            seed: 9,
        };
        // t12 self-asserts (hit rate > 50%, strict deref reduction in both
        // modes); reaching the shape checks below means the bar held
        let t = t12_cache(&cfg, &KeyRouter::Native);
        assert_eq!(t.rows.len(), 1);
        let row = &t.rows[0].1;
        assert!(row[0] > 0.0 && row[3] > 0.0, "baselines must count derefs");
        assert!(row[1] < row[0], "direct: finger derefs strictly below baseline");
        assert!(row[4] < row[3], "delegated: finger derefs strictly below baseline");
        assert!(row[2] > 50.0 && row[5] > 50.0, "hit rates above 50%");
        assert!(row[6] > 0.0, "prefetches must be issued");
    }
}
