//! Table XI (beyond the paper, §VI–VII proposal): the hierarchical
//! delegation engine vs direct execution, across every store kind.
//!
//! Methodology (EXPERIMENTS.md §Table XI): the `OpMix::HIER` workload (all
//! four op kinds, 10% range scans) with a prefix-spanning range window, so
//! direct workers must dereference two shards per scan while delegated
//! callers ship each half to its owner. The run asserts the paper's
//! locality claim — `remote_accesses == 0` in delegated mode — and reports
//! the fabric health metrics (batch occupancy, handoff latency).

use crate::coordinator::{ExecMode, StoreKind};
use crate::runtime::KeyRouter;
use crate::util::bench::Table;
use crate::workload::OpMix;

use super::{store_run_with_mode, ExpConfig};

/// The eight store kinds, in the row order of the table.
pub const T11_KINDS: [StoreKind; 8] = [
    StoreKind::DetSkiplistLf,
    StoreKind::DetSkiplistRwl,
    StoreKind::RandomSkiplist,
    StoreKind::HashFixed,
    StoreKind::HashTwoLevel,
    StoreKind::HashSpo,
    StoreKind::HashTwoLevelSpo,
    StoreKind::HashTbbLike,
];

/// A range window of one full prefix segment: every scan that does not
/// start in the last segment spans into the next shard — the cross-shard
/// dereference the delegation engine eliminates.
pub const T11_WINDOW: u64 = 1 << 61;

/// Table XI: Direct vs Delegated over all 8 [`StoreKind`]s at the largest
/// configured thread count. Rows are keyed by kind index (see
/// [`T11_KINDS`]); the title spells out the mapping. Panics if any
/// delegated run reports a remote access — the paper's locality assertion.
pub fn t11_hier(cfg: &ExpConfig, router: &KeyRouter) -> Table {
    let ops = cfg.ops(10_000_000);
    let th = *cfg.threads.last().unwrap_or(&8) as usize;
    let mut t = Table::new(
        &format!(
            "Table XI (new) — direct vs delegated execution ({ops} ops, {th} threads, \
             mix HIER, window 2^61, scale 1/{}) | rows: 0=det-lf 1=det-rwl 2=random \
             3=fixed 4=twolevel 5=spo 6=2lvl-spo 7=tbb",
            cfg.scale
        ),
        "#kind",
        &["direct(s)", "delegated(s)", "dir-remote", "del-remote", "batch-occ", "handoff-us"],
    );
    for (i, kind) in T11_KINDS.into_iter().enumerate() {
        let (d, dm) = store_run_with_mode(
            cfg,
            kind,
            OpMix::HIER,
            ops,
            th,
            router,
            ExecMode::Direct,
            T11_WINDOW,
        );
        let (g, gm) = store_run_with_mode(
            cfg,
            kind,
            OpMix::HIER,
            ops,
            th,
            router,
            ExecMode::Delegated,
            T11_WINDOW,
        );
        assert_eq!(
            gm.remote_accesses, 0,
            "{kind:?}: delegated execution must be NUMA-local (paper §VI-VII)"
        );
        assert_eq!(
            gm.fabric.executed, gm.fabric.submitted,
            "{kind:?}: the fabric must quiesce"
        );
        t.push_row(
            i as u64,
            vec![
                d.mean,
                g.mean,
                dm.remote_accesses as f64,
                gm.remote_accesses as f64,
                gm.fabric.batch_occupancy(),
                gm.fabric.avg_handoff_us(),
            ],
        );
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    #[test]
    fn t11_hier_runs_all_kinds_and_asserts_locality() {
        let cfg = ExpConfig {
            threads: vec![4],
            reps: 1,
            scale: 10_000,
            topology: Topology::virtual_grid(2, 2),
            seed: 5,
        };
        let t = t11_hier(&cfg, &KeyRouter::Native);
        assert_eq!(t.rows.len(), 8, "one row per store kind");
        for (kind, row) in &t.rows {
            assert!(row[0] > 0.0 && row[1] > 0.0, "kind {kind}: both modes must run");
            assert_eq!(row[3], 0.0, "kind {kind}: delegated remote accesses");
            assert!(row[4] >= 1.0, "kind {kind}: batches carry at least one op");
        }
        // the direct column must show the remote dereferences the delegated
        // mode eliminates (2 engaged nodes => adjacent shards alternate)
        assert!(
            t.rows.iter().any(|(_, row)| row[2] > 0.0),
            "direct cross-shard scans must register as remote"
        );
    }
}
