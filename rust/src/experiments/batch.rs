//! Table XIII (beyond the paper): fused sorted-batch descents + owner-side
//! operation combining, measured end to end.
//!
//! Methodology (EXPERIMENTS.md §Table XIII): the `OpMix::BULK` stream
//! (40/40/20 insert/find/erase) is applied in arrival batches of `B` ops,
//! swept over batch size × clustering:
//!
//! - **Direct** — batches are applied straight to the sharded store, once
//!   through the per-key loop (`insert`/`get`/`erase` per element, the old
//!   path) and once through the fused batch ops
//!   (`insert_batch`/`get_batch`/`erase_batch`, which ride
//!   `apply_sorted_run`). Clustered arrivals (`with_clustered_runs`: each
//!   batch is an ascending same-shard key run) are the shape the §VII
//!   batching proposal assumes; the uniform column keeps the fused path
//!   honest on unclustered input.
//! - **Delegated** — the same stream runs through the engine's delegation
//!   fabric with envelope batch `B`, once with owner-side combining off
//!   (per-envelope execution, the per-key baseline) and once on (drains
//!   merge caller batches into per-shard sorted runs).
//!
//! Cost proxy: skiplist hot-line node dereferences per op (the same
//! counter Table XII uses). The run **self-asserts the acceptance bar**:
//! at batch ≥ 16, fused execution does strictly fewer derefs/op than the
//! per-key baseline in both modes, and the combiner merges ≥ 2 caller
//! batches per combining drain under the BULK mix.

use std::sync::Arc;

use crate::coordinator::{run_with_opts, ExecMode, RunOptions, ShardedStore, StoreKind};
use crate::runtime::KeyRouter;
use crate::util::bench::Table;
use crate::util::rng::mix64;
use crate::workload::{OpKind, OpMix, WorkloadSpec};

use super::ExpConfig;

/// Bounded key space: small enough that finds/erases hit resident keys,
/// large enough for real descent height.
pub const T13_KEY_SPACE: u64 = 1 << 14;

/// The arrival-batch sizes swept (rows of the table).
pub const T13_BATCHES: [u64; 4] = [4, 16, 64, 256];

fn spec_for(ops: u64, batch: u64, clustered: bool, salt: u64) -> WorkloadSpec {
    let s = WorkloadSpec::new("batch", ops, OpMix::BULK, T13_KEY_SPACE);
    if clustered {
        // one arrival batch == one ascending same-shard key run; the salt
        // decorrelates the (position-derived) run bases across seeds/reps
        s.with_clustered_runs(batch, 1).with_run_salt(salt)
    } else {
        s
    }
}

/// Decode the deterministic op stream the spec produces (the leader-side
/// fill, without the queue fabric — the Direct half measures pure
/// application cost).
fn gen_stream(spec: &WorkloadSpec, seed: u64) -> Vec<(OpKind, u64)> {
    (0..spec.total_ops)
        .map(|c| WorkloadSpec::decode(spec.encode(mix64(seed.wrapping_add(c)), c)))
        .collect()
}

/// Apply the stream in arrival batches of `batch` ops directly to a fresh
/// store; returns node derefs per op. `fused` selects the batch ops vs the
/// per-key loop — both see identical sub-batches (split by op kind), so
/// the only difference is the application path.
fn run_direct(cfg: &ExpConfig, ops: u64, batch: u64, clustered: bool, fused: bool) -> f64 {
    let store = ShardedStore::new(
        StoreKind::DetSkiplistLf,
        8,
        (ops as usize / 4).max(1 << 14),
        cfg.topology.clone(),
        1,
    );
    let spec = spec_for(ops, batch, clustered, cfg.seed);
    let stream = gen_stream(&spec, cfg.seed);
    let mut ins: Vec<(u64, u64)> = Vec::with_capacity(batch as usize);
    let mut gets: Vec<u64> = Vec::with_capacity(batch as usize);
    let mut ers: Vec<u64> = Vec::with_capacity(batch as usize);
    let before = store.stats().node_derefs;
    for chunk in stream.chunks(batch as usize) {
        ins.clear();
        gets.clear();
        ers.clear();
        for &(op, k) in chunk {
            match op {
                OpKind::Insert => ins.push((k, k ^ 0xDA7A)),
                OpKind::Find => gets.push(k),
                OpKind::Erase => ers.push(k),
                OpKind::Range => unreachable!("BULK has no range ops"),
            }
        }
        if fused {
            store.insert_batch(&ins);
            let _ = store.get_batch(&gets);
            store.erase_batch(&ers);
        } else {
            for &(k, v) in &ins {
                store.insert(k, v);
            }
            for &k in &gets {
                let _ = store.get(k);
            }
            for &k in &ers {
                store.erase(k);
            }
        }
    }
    (store.stats().node_derefs - before) as f64 / stream.len().max(1) as f64
}

struct DelRun {
    derefs_per_op: f64,
    mops: f64,
    batches_per_drain: f64,
    combined_drains: u64,
    coalesced_finds: u64,
}

/// One engine run through the delegation fabric with envelope batch
/// `batch` and owner-side combining on/off; averaged over `cfg.reps`.
fn run_delegated(
    cfg: &ExpConfig,
    ops: u64,
    batch: u64,
    threads: usize,
    router: &KeyRouter,
    combining: bool,
) -> DelRun {
    let reps = cfg.reps.max(1);
    let mut acc = DelRun {
        derefs_per_op: 0.0,
        mops: 0.0,
        batches_per_drain: 0.0,
        combined_drains: 0,
        coalesced_finds: 0,
    };
    for rep in 0..reps {
        let store = Arc::new(ShardedStore::new(
            StoreKind::DetSkiplistLf,
            8,
            (ops as usize / 4).max(1 << 14),
            cfg.topology.clone(),
            threads,
        ));
        let spec = spec_for(ops, batch, true, cfg.seed + rep as u64);
        let m = run_with_opts(
            &store,
            &spec,
            threads,
            router,
            cfg.seed + rep as u64,
            RunOptions { mode: ExecMode::Delegated, batch_n: batch as usize, combining, ..RunOptions::default() },
        );
        assert_eq!(m.remote_accesses, 0, "delegated execution must stay NUMA-local");
        assert_eq!(m.fabric.executed, m.fabric.submitted, "the fabric must quiesce");
        let st = store.stats();
        acc.derefs_per_op += st.node_derefs as f64 / m.ops().max(1) as f64;
        acc.mops += m.throughput_mops();
        acc.batches_per_drain += m.fabric.combined_batches_per_drain();
        acc.combined_drains += m.fabric.combined_drains;
        acc.coalesced_finds += m.fabric.coalesced_finds;
    }
    let n = reps as f64;
    DelRun {
        derefs_per_op: acc.derefs_per_op / n,
        mops: acc.mops / n,
        batches_per_drain: acc.batches_per_drain / n,
        combined_drains: acc.combined_drains,
        coalesced_finds: acc.coalesced_finds,
    }
}

/// Table XIII: per-key vs fused application cost over batch size ×
/// clustering, Direct and Delegated. Panics if the acceptance bar is
/// missed (no strict deref cut at batch ≥ 16 in either mode, or the
/// combiner fails to merge ≥ 2 caller batches per drain).
pub fn t13_batch(cfg: &ExpConfig, router: &KeyRouter) -> Table {
    let ops = cfg.ops(10_000_000);
    let th = *cfg.threads.last().unwrap_or(&8) as usize;
    let mut t = Table::new(
        &format!(
            "Table XIII (new) — fused sorted-batch descents + combining ({ops} ops, mix BULK, \
             key space {T13_KEY_SPACE}, {th} threads delegated, scale 1/{})",
            cfg.scale
        ),
        "#batch",
        &[
            "dir perkey d/op",
            "dir fused d/op",
            "dir fused-uni d/op",
            "del perkey d/op",
            "del fused d/op",
            "batches/drain",
            "coalesced",
            "del Mops/s",
        ],
    );
    for &batch in T13_BATCHES.iter() {
        let dir_pk = run_direct(cfg, ops, batch, true, false);
        let dir_fused = run_direct(cfg, ops, batch, true, true);
        let dir_fused_uni = run_direct(cfg, ops, batch, false, true);
        let del_pk = run_delegated(cfg, ops, batch, th, router, false);
        let del_fused = run_delegated(cfg, ops, batch, th, router, true);
        if batch >= 16 {
            assert!(
                dir_fused < dir_pk,
                "direct: fused batch {batch} must strictly cut derefs/op \
                 (fused {dir_fused:.2} vs per-key {dir_pk:.2})"
            );
            assert!(
                del_fused.derefs_per_op < del_pk.derefs_per_op,
                "delegated: combining at batch {batch} must strictly cut derefs/op \
                 (fused {:.2} vs per-key {:.2})",
                del_fused.derefs_per_op,
                del_pk.derefs_per_op
            );
            assert!(
                del_fused.combined_drains > 0 && del_fused.batches_per_drain >= 2.0,
                "the combiner must merge >= 2 caller batches per drain under BULK \
                 (got {:.2} over {} drains)",
                del_fused.batches_per_drain,
                del_fused.combined_drains
            );
        }
        t.push_row(
            batch,
            vec![
                dir_pk,
                dir_fused,
                dir_fused_uni,
                del_pk.derefs_per_op,
                del_fused.derefs_per_op,
                del_fused.batches_per_drain,
                del_fused.coalesced_finds as f64,
                del_fused.mops,
            ],
        );
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    #[test]
    fn t13_batch_asserts_deref_cut_and_combining() {
        let cfg = ExpConfig {
            threads: vec![4],
            reps: 1,
            scale: 10_000,
            topology: Topology::virtual_grid(2, 2),
            seed: 13,
        };
        // t13 self-asserts (strict deref cut at batch >= 16 in both modes,
        // >= 2 caller batches per combining drain); reaching the shape
        // checks below means the bar held
        let t = t13_batch(&cfg, &KeyRouter::Native);
        assert_eq!(t.rows.len(), T13_BATCHES.len());
        for (batch, row) in &t.rows {
            assert!(row[0] > 0.0 && row[3] > 0.0, "batch {batch}: baselines count derefs");
            if *batch >= 16 {
                assert!(row[1] < row[0], "batch {batch}: direct fused strictly below per-key");
                assert!(row[4] < row[3], "batch {batch}: delegated fused strictly below per-key");
                assert!(row[5] >= 2.0, "batch {batch}: >= 2 batches per combining drain");
            }
        }
    }
}
