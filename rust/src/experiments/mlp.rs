//! Table XIV (beyond the paper): memory-level-parallel interleaved
//! descents for scattered point batches.
//!
//! Methodology (EXPERIMENTS.md §Table XIV): a resident set far beyond LLC
//! (≥ 2^20 keys) is bulk-built through the fused sorted-run path, then a
//! scattered (uniform-random, unsorted) probe stream is executed at
//! several interleave widths:
//!
//! - **Direct** — `DetSkiplist::get_many` applies each arrival batch
//!   through the interleaved engine at width `k`; width 1 is the same
//!   engine serialized to one lane (one full dependent-miss chain per
//!   probe group — the baseline "Skiplists with Foresight" identifies as
//!   the real throughput ceiling).
//! - **Delegated** — the same probes travel the delegation fabric as
//!   `Find` envelopes into a deep owner queue; the combining drain merges
//!   them into per-prefix runs, classifies them scattered, and executes
//!   through `apply_interleaved` at the pinned width
//!   (`OpFabric::set_interleave_width`).
//!
//! Cost proxies: throughput and **stalled derefs/op** — hot-line
//! dereferences the engine performed with no other descent in flight
//! (`SkiplistStats::stalled_derefs`). Width 1 serializes every chain, so
//! all its engine derefs are stalled; at width ≥ 8 only the drain tail
//! is. The run **self-asserts the acceptance bar**: at width ≥ 8 the
//! interleaved path delivers strictly fewer stalled derefs/op than width
//! 1 in both modes (counter-deterministic, asserted always), strictly
//! higher throughput in both modes (timing — asserted in optimized
//! builds at full resident size, where the beyond-LLC precondition
//! holds), and the combiner's per-drain fuse-vs-interleave dispatch is
//! exercised both ways (`fused_runs > 0 && interleaved_runs > 0`) in the
//! mixed clustered+scattered run.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{DelegatedOp, OpFabric, ShardedStore, StoreKind};
use crate::mem::ArenaOptions;
use crate::runtime::KeyRouter;
use crate::skiplist::{BatchOp, DetSkiplist, FindMode};
use crate::util::bench::Table;
use crate::util::rng::mix64;

use super::ExpConfig;

/// Resident keys in the full-size run: beyond any LLC, so a width-1 probe
/// really pays its dependent-miss chain.
pub const T14_RESIDENT: u64 = 1 << 20;

/// Interleave widths swept (rows of the table); the self-asserts compare
/// the width-1 and width-8 rows.
pub const T14_WIDTHS: [usize; 4] = [1, 4, 8, 16];

/// Arrival-batch size for the Direct probe stream (matches the delegated
/// combiner's typical pooled-window population).
const T14_BATCH: usize = 1024;

/// Spread resident keys across the key space: an odd stride keeps sorted
/// build order while making random probe neighbours land far apart (no
/// accidental clustering).
#[inline]
fn key_of(i: u64) -> u64 {
    i * 1021 + 17
}

/// Scattered probe stream: uniform-random resident keys in arrival order.
fn probes(n: u64, resident: u64, seed: u64) -> Vec<u64> {
    (0..n).map(|j| key_of(mix64(seed.wrapping_add(j)) % resident)).collect()
}

/// Bulk-build `resident` keys through the fused sorted-run path (the PR-5
/// bulk-load shape; orders of magnitude faster than point inserts and
/// leaves clean split-balanced segments).
fn build_skiplist(resident: u64) -> DetSkiplist {
    let sl = DetSkiplist::with_capacity_on(
        FindMode::LockFree,
        resident as usize + (1 << 12),
        ArenaOptions::default(),
    );
    let mut i = 0u64;
    while i < resident {
        let end = (i + 8192).min(resident);
        let run: Vec<BatchOp> = (i..end).map(|k| BatchOp::Insert(key_of(k), k)).collect();
        sl.apply_sorted_run(&run, &mut |_, _| {});
        i = end;
    }
    sl
}

struct ModeRun {
    mops: f64,
    stalled_per_op: f64,
}

/// Direct half: `get_many` over arrival batches at `width`, best-of-reps
/// throughput; stalled derefs are counter-deterministic (single thread),
/// taken from the last rep.
fn run_direct(cfg: &ExpConfig, resident: u64, probe_n: u64, width: usize) -> ModeRun {
    let sl = build_skiplist(resident);
    let stream = probes(probe_n, resident, cfg.seed);
    let mut best_mops = 0.0f64;
    let mut stalled_per_op = 0.0;
    for _rep in 0..cfg.reps.max(1) {
        let before = sl.stats().stalled_derefs;
        let t0 = Instant::now();
        let mut hits = 0u64;
        for chunk in stream.chunks(T14_BATCH) {
            for v in sl.get_many(chunk, width) {
                hits += v.is_some() as u64;
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(hits, stream.len() as u64, "every probe targets a resident key");
        best_mops = best_mops.max(stream.len() as f64 / secs / 1e6);
        stalled_per_op = (sl.stats().stalled_derefs - before) as f64 / stream.len() as f64;
    }
    ModeRun { mops: best_mops, stalled_per_op }
}

struct DelRun {
    mops: f64,
    stalled_per_op: f64,
    interleaved_runs: u64,
}

/// Delegated half: stage the whole scattered probe stream as `Find`
/// envelopes into one owner's queue (deep queue ⇒ every drain window
/// merges ≥ 2 caller batches), pin the combiner's interleave width, then
/// time the owner-side drain. Best-of-reps throughput; the stalled
/// counter is deterministic for a single draining owner.
fn run_delegated(cfg: &ExpConfig, resident: u64, probe_n: u64, width: usize) -> DelRun {
    let mut best_mops = 0.0f64;
    let mut stalled_per_op = 0.0;
    let mut interleaved_runs = 0;
    for rep in 0..cfg.reps.max(1) {
        let store = Arc::new(ShardedStore::new(
            StoreKind::DetSkiplistLf,
            1,
            resident as usize + (1 << 12),
            cfg.topology.clone(),
            1,
        ));
        let items: Vec<(u64, u64)> = (0..resident).map(|k| (key_of(k), k)).collect();
        assert_eq!(store.insert_batch(&items), resident);
        let blocks = ((probe_n as usize / 64) / 256 + 4).next_power_of_two().max(16);
        let fabric = OpFabric::new(1, 1, 1, cfg.topology.clone(), blocks, 64);
        fabric.set_interleave_width(width);
        let mut caller = fabric.caller(1, None);
        for &key in &probes(probe_n, resident, cfg.seed + rep as u64) {
            caller.delegate(DelegatedOp::Find { key }, &store);
        }
        caller.finish(&store);
        let before = store.stats().stalled_derefs;
        let t0 = Instant::now();
        while fabric.drain(0, &store, usize::MAX) > 0 {}
        let secs = t0.elapsed().as_secs_f64();
        assert!(fabric.all_quiet(), "drain must quiesce the fabric");
        let st = fabric.stats();
        assert_eq!(st.executed, st.submitted, "combined execution must balance");
        assert_eq!(fabric.slot_totals(1).hits, probe_n, "every probe hits");
        assert!(
            st.interleaved_runs > 0,
            "scattered probe windows must take the interleaved path"
        );
        best_mops = best_mops.max(probe_n as f64 / secs / 1e6);
        stalled_per_op = (store.stats().stalled_derefs - before) as f64 / probe_n as f64;
        interleaved_runs = st.interleaved_runs;
    }
    DelRun { mops: best_mops, stalled_per_op, interleaved_runs }
}

/// Mixed run: one caller streams clustered finds (consecutive keys in
/// prefix 0), another scattered finds (8192-stride keys in prefix 1), into
/// the same owner. Per drain the combiner must dispatch the dense prefix-0
/// slices to the fused path and the sparse prefix-1 slices to the
/// interleaved engine — both counters strictly positive.
fn run_mixed(cfg: &ExpConfig) -> (u64, u64) {
    let store = Arc::new(ShardedStore::new(
        StoreKind::DetSkiplistLf,
        1,
        1 << 14,
        cfg.topology.clone(),
        1,
    ));
    let clustered: Vec<u64> = (0..512u64).map(|i| i + 3).collect();
    let scattered: Vec<u64> = (0..512u64).map(|i| 1u64 << 61 | i * 8192).collect();
    let mut seed: Vec<(u64, u64)> = clustered.iter().map(|&k| (k, k)).collect();
    seed.extend(scattered.iter().map(|&k| (k, k)));
    store.insert_batch(&seed);
    let fabric = OpFabric::new(1, 2, 1, cfg.topology.clone(), 16, 64);
    let mut c1 = fabric.caller(1, None);
    let mut c2 = fabric.caller(2, None);
    for i in 0..512usize {
        c1.delegate(DelegatedOp::Find { key: clustered[i] }, &store);
        c2.delegate(DelegatedOp::Find { key: scattered[i] }, &store);
    }
    c1.finish(&store);
    c2.finish(&store);
    while fabric.drain(0, &store, usize::MAX) > 0 {}
    assert!(fabric.all_quiet());
    let st = fabric.stats();
    assert_eq!(st.executed, st.submitted);
    assert!(
        st.fused_runs > 0 && st.interleaved_runs > 0,
        "the mixed window must exercise both dispatch arms \
         (fused {}, interleaved {})",
        st.fused_runs,
        st.interleaved_runs
    );
    (st.fused_runs, st.interleaved_runs)
}

/// Table XIV with an explicit resident-set size (the public entry point
/// pins it to [`T14_RESIDENT`]; tests shrink it). Timing asserts are
/// enforced only in optimized builds at the full beyond-LLC size — the
/// stalled-deref and dispatch asserts are counter-deterministic and hold
/// at any size.
pub fn t14_mlp_with(cfg: &ExpConfig, resident: u64) -> Table {
    let probe_n = cfg.ops(100_000_000);
    // Timing asserts need the beyond-LLC resident set AND enough probes to
    // integrate over scheduler noise; counter asserts hold unconditionally.
    let strict_timing = !cfg!(debug_assertions) && resident >= T14_RESIDENT && probe_n >= 100_000;
    let (fused, interleaved) = run_mixed(cfg);
    let mut t = Table::new(
        &format!(
            "Table XIV (new) — MLP interleaved descents ({resident} resident keys, \
             {probe_n} scattered probes, batch {T14_BATCH}, scale 1/{}; mixed window \
             dispatched {fused} fused + {interleaved} interleaved runs)",
            cfg.scale
        ),
        "#width",
        &["dir Mops/s", "dir stalled/op", "del Mops/s", "del stalled/op", "del runs"],
    );
    let mut dir_w1: Option<ModeRun> = None;
    let mut del_w1: Option<DelRun> = None;
    for &w in T14_WIDTHS.iter() {
        let dir = run_direct(cfg, resident, probe_n, w);
        let del = run_delegated(cfg, resident, probe_n, w);
        if w == 1 {
            assert!(
                dir.stalled_per_op > 0.0,
                "width 1 serializes every chain: its engine derefs are all stalled"
            );
            assert!(del.stalled_per_op > 0.0);
        }
        if w >= 8 {
            let d1 = dir_w1.as_ref().expect("width sweep starts at 1");
            let g1 = del_w1.as_ref().expect("width sweep starts at 1");
            assert!(
                dir.stalled_per_op < d1.stalled_per_op,
                "direct: width {w} must strictly cut stalled derefs/op \
                 ({:.3} vs {:.3} at width 1)",
                dir.stalled_per_op,
                d1.stalled_per_op
            );
            assert!(
                del.stalled_per_op < g1.stalled_per_op,
                "delegated: width {w} must strictly cut stalled derefs/op \
                 ({:.3} vs {:.3} at width 1)",
                del.stalled_per_op,
                g1.stalled_per_op
            );
            if strict_timing {
                assert!(
                    dir.mops > d1.mops,
                    "direct: interleaving at width {w} must beat width 1 \
                     ({:.3} vs {:.3} Mops/s)",
                    dir.mops,
                    d1.mops
                );
                assert!(
                    del.mops > g1.mops,
                    "delegated: interleaving at width {w} must beat width 1 \
                     ({:.3} vs {:.3} Mops/s)",
                    del.mops,
                    g1.mops
                );
            }
        }
        t.push_row(
            w as u64,
            vec![
                dir.mops,
                dir.stalled_per_op,
                del.mops,
                del.stalled_per_op,
                del.interleaved_runs as f64,
            ],
        );
        if w == 1 {
            dir_w1 = Some(dir);
            del_w1 = Some(del);
        }
    }
    t
}

/// Table XIV entry point (`exp t14`): full beyond-LLC resident set.
pub fn t14_mlp(cfg: &ExpConfig, _router: &KeyRouter) -> Table {
    t14_mlp_with(cfg, T14_RESIDENT)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            threads: vec![1],
            reps: 1,
            scale: 10_000,
            topology: Topology::virtual_grid(2, 2),
            seed: 14,
        }
    }

    #[test]
    fn t14_mlp_small_resident_holds_counter_bar() {
        // shrunk resident set: the counter asserts inside t14_mlp_with
        // (stalled-deref cut, interleaved dispatch, quiescence balance,
        // mixed fused+interleaved) must all hold; timing asserts are
        // size-gated off
        let t = t14_mlp_with(&tiny_cfg(), 1 << 15);
        assert_eq!(t.rows.len(), T14_WIDTHS.len());
        for (w, row) in &t.rows {
            assert!(row[0] > 0.0 && row[2] > 0.0, "width {w}: throughput measured");
            assert!(row[1] >= 0.0 && row[3] >= 0.0);
        }
        // width-1 rows carry the serialized-stall signature
        let w1 = &t.rows[0];
        let w8 = t.rows.iter().find(|(w, _)| *w == 8).expect("width 8 row");
        assert!(w8.1[1] < w1.1[1], "direct stalled/op strictly falls by width 8");
        assert!(w8.1[3] < w1.1[3], "delegated stalled/op strictly falls by width 8");
    }
}
