//! Table XV (beyond the paper): fat-leaf terminal chunks — contiguous
//! multi-key leaves with SIMD intra-leaf search.
//!
//! Methodology (EXPERIMENTS.md §Table XV): a resident set far beyond LLC
//! (≥ 2^20 keys) is bulk-built through the fused sorted-run path at every
//! swept leaf capacity K ∈ {1, 8, 16, 32}, then a scattered
//! (uniform-random, unsorted) point-probe stream is executed two ways:
//!
//! - **Direct** — plain `DetSkiplist::get` point descents, one per probe.
//! - **Delegated** — the same probes travel the delegation fabric as
//!   `Find` envelopes into a deep owner queue and execute through the
//!   combiner's per-drain dispatch (scattered windows → the interleaved
//!   engine, with the gap threshold itself leaf-relative via
//!   `KvStore::cluster_gap`).
//!
//! Cost proxies: throughput and **node derefs/op** (`SkiplistStats::
//! node_derefs` — hot-line dereferences, the Table XII cache proxy). A
//! K-wide chunk replaces up to K single-key terminals with one header
//! probe plus an in-chunk rank, so the tower above shrinks by ~log₂K
//! levels and the descent touches strictly fewer lines. The run
//! **self-asserts the acceptance bar**: at K ≥ 8 the fat-leaf list
//! delivers strictly fewer node derefs/op than K = 1 in both modes
//! (counter-deterministic, asserted always), and at every K all eight
//! [`StoreKind`] builds agree with a `BTreeMap` oracle over a mixed
//! insert/get/erase/range churn (the leaf capacity must be behaviourally
//! invisible).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{DelegatedOp, KvStore, OpFabric, OrderedKv, ShardedStore, StoreKind};
use crate::mem::ArenaOptions;
use crate::runtime::KeyRouter;
use crate::skiplist::{BatchOp, DetSkiplist, FindMode};
use crate::util::bench::{RowTag, Table};
use crate::util::rng::mix64;

use super::ExpConfig;

/// Resident keys in the full-size run: beyond any LLC, so the descent's
/// dependent misses dominate and the deref cut is what the wall clock sees.
pub const T15_RESIDENT: u64 = 1 << 20;

/// Leaf capacities swept (rows of the table); the self-asserts compare
/// every K ≥ 8 row against the K = 1 row (the pre-fat-leaf layout).
pub const T15_CAPS: [usize; 4] = [1, 8, 16, 32];

/// Spread resident keys across the key space: an odd stride keeps sorted
/// build order while making random probe neighbours land far apart.
#[inline]
fn key_of(i: u64) -> u64 {
    i * 1021 + 17
}

/// Scattered probe stream: uniform-random resident keys in arrival order.
fn probes(n: u64, resident: u64, seed: u64) -> Vec<u64> {
    (0..n).map(|j| key_of(mix64(seed.wrapping_add(j)) % resident)).collect()
}

/// Bulk-build `resident` keys at leaf capacity `cap` through the fused
/// sorted-run path.
fn build_skiplist(resident: u64, cap: usize) -> DetSkiplist {
    let sl = DetSkiplist::with_leaf_cap_on(
        FindMode::LockFree,
        resident as usize + (1 << 12),
        ArenaOptions::default(),
        cap,
    );
    let mut i = 0u64;
    while i < resident {
        let end = (i + 8192).min(resident);
        let run: Vec<BatchOp> = (i..end).map(|k| BatchOp::Insert(key_of(k), k)).collect();
        sl.apply_sorted_run(&run, &mut |_, _| {});
        i = end;
    }
    sl
}

struct ModeRun {
    mops: f64,
    derefs_per_op: f64,
}

/// Direct half: point `get` descents over the scattered stream,
/// best-of-reps throughput; node derefs are counter-deterministic (single
/// thread), taken from the last rep.
fn run_direct(cfg: &ExpConfig, resident: u64, probe_n: u64, cap: usize) -> ModeRun {
    let sl = build_skiplist(resident, cap);
    let stream = probes(probe_n, resident, cfg.seed);
    let mut best_mops = 0.0f64;
    let mut derefs_per_op = 0.0;
    for _rep in 0..cfg.reps.max(1) {
        let before = sl.stats().node_derefs;
        let t0 = Instant::now();
        let mut hits = 0u64;
        for &key in &stream {
            hits += sl.get(key).is_some() as u64;
        }
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(hits, stream.len() as u64, "every probe targets a resident key");
        best_mops = best_mops.max(stream.len() as f64 / secs / 1e6);
        derefs_per_op = (sl.stats().node_derefs - before) as f64 / stream.len() as f64;
    }
    ModeRun { mops: best_mops, derefs_per_op }
}

/// Delegated half: stage the scattered probe stream as `Find` envelopes
/// into one owner's queue, then time the combining drain (scattered
/// windows route through the interleaved engine; the dispatch threshold is
/// the shard's leaf-relative `cluster_gap`). Best-of-reps throughput; the
/// deref counter is deterministic for a single draining owner.
fn run_delegated(cfg: &ExpConfig, resident: u64, probe_n: u64, cap: usize) -> ModeRun {
    let mut best_mops = 0.0f64;
    let mut derefs_per_op = 0.0;
    for rep in 0..cfg.reps.max(1) {
        let store = Arc::new(ShardedStore::with_leaf_cap(
            StoreKind::DetSkiplistLf,
            1,
            resident as usize + (1 << 12),
            cfg.topology.clone(),
            1,
            Some(cap),
        ));
        let items: Vec<(u64, u64)> = (0..resident).map(|k| (key_of(k), k)).collect();
        assert_eq!(store.insert_batch(&items), resident);
        let blocks = ((probe_n as usize / 64) / 256 + 4).next_power_of_two().max(16);
        let fabric = OpFabric::new(1, 1, 1, cfg.topology.clone(), blocks, 64);
        let mut caller = fabric.caller(1, None);
        for &key in &probes(probe_n, resident, cfg.seed + rep as u64) {
            caller.delegate(DelegatedOp::Find { key }, &store);
        }
        caller.finish(&store);
        let before = store.stats().node_derefs;
        let t0 = Instant::now();
        while fabric.drain(0, &store, usize::MAX) > 0 {}
        let secs = t0.elapsed().as_secs_f64();
        assert!(fabric.all_quiet(), "drain must quiesce the fabric");
        let st = fabric.stats();
        assert_eq!(st.executed, st.submitted, "combined execution must balance");
        assert_eq!(fabric.slot_totals(1).hits, probe_n, "every probe hits");
        best_mops = best_mops.max(probe_n as f64 / secs / 1e6);
        derefs_per_op = (store.stats().node_derefs - before) as f64 / probe_n as f64;
    }
    ModeRun { mops: best_mops, derefs_per_op }
}

/// Oracle suite: every [`StoreKind`] built at leaf capacity `cap` must
/// track a `BTreeMap` through a mixed insert/get/erase churn plus ordered
/// range scans — K may change the layout, never the answers. Returns how
/// many kinds passed (asserts internally, so always all of them).
fn oracle_all_kinds(cfg: &ExpConfig, cap: usize, churn: u64) -> u64 {
    let mut passed = 0u64;
    for kind in super::hier::T11_KINDS {
        let s = kind.build_placed_leaf(1 << 14, ArenaOptions::default(), Some(cap));
        let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
        for i in 0..churn {
            let r = mix64(cfg.seed ^ (cap as u64) << 32 ^ i);
            // keep the key space tight so inserts, erases and re-inserts
            // collide often (chunk split/merge churn at every K)
            let key = r % (churn / 2 + 1) + 1;
            match r >> 61 {
                0..=2 => {
                    // set semantics: a resident key keeps its old value and
                    // the insert reports false — mirror that in the oracle
                    let v = r >> 8;
                    let fresh = !oracle.contains_key(&key);
                    if fresh {
                        oracle.insert(key, v);
                    }
                    assert_eq!(
                        s.insert(key, v),
                        fresh,
                        "{kind:?} K={cap}: insert({key}) disagreed at op {i}"
                    );
                }
                3..=4 => {
                    assert_eq!(
                        s.erase(key),
                        oracle.remove(&key).is_some(),
                        "{kind:?} K={cap}: erase({key}) disagreed at op {i}"
                    );
                }
                _ => {
                    assert_eq!(
                        s.get(key),
                        oracle.get(&key).copied(),
                        "{kind:?} K={cap}: get({key}) disagreed at op {i}"
                    );
                }
            }
        }
        // ordered sweep: the full final contents in key order
        let want: Vec<(u64, u64)> = oracle.iter().map(|(&k, &v)| (k, v)).collect();
        assert_eq!(
            s.range(0, u64::MAX - 2),
            want,
            "{kind:?} K={cap}: final range sweep disagreed"
        );
        assert_eq!(s.len(), want.len() as u64, "{kind:?} K={cap}: len disagreed");
        passed += 1;
    }
    passed
}

/// Table XV with an explicit resident-set size (the public entry point
/// pins it to [`T15_RESIDENT`]; tests shrink it). The deref asserts are
/// counter-deterministic and hold at any size; timing is reported, not
/// asserted (the deref cut is the structural claim).
pub fn t15_fatleaf_with(cfg: &ExpConfig, resident: u64) -> Table {
    let probe_n = cfg.ops(100_000_000);
    let churn = cfg.ops(10_000_000).min(20_000);
    let mut t = Table::new(
        &format!(
            "Table XV (new) — fat-leaf chunks ({resident} resident keys, {probe_n} \
             scattered probes, churn {churn}/kind, scale 1/{})",
            cfg.scale
        ),
        "#leaf_cap",
        &["dir Mops/s", "dir derefs/op", "del Mops/s", "del derefs/op", "oracle kinds"],
    );
    let mut dir_k1: Option<ModeRun> = None;
    let mut del_k1: Option<ModeRun> = None;
    for &cap in T15_CAPS.iter() {
        let kinds = oracle_all_kinds(cfg, cap, churn);
        assert_eq!(kinds, 8, "all store kinds must pass the oracle at K = {cap}");
        let dir = run_direct(cfg, resident, probe_n, cap);
        let del = run_delegated(cfg, resident, probe_n, cap);
        assert!(dir.derefs_per_op > 0.0 && del.derefs_per_op > 0.0);
        if cap >= 8 {
            let d1 = dir_k1.as_ref().expect("cap sweep starts at 1");
            let g1 = del_k1.as_ref().expect("cap sweep starts at 1");
            assert!(
                dir.derefs_per_op < d1.derefs_per_op,
                "direct: K = {cap} must strictly cut node derefs/op \
                 ({:.3} vs {:.3} at K = 1)",
                dir.derefs_per_op,
                d1.derefs_per_op
            );
            assert!(
                del.derefs_per_op < g1.derefs_per_op,
                "delegated: K = {cap} must strictly cut node derefs/op \
                 ({:.3} vs {:.3} at K = 1)",
                del.derefs_per_op,
                g1.derefs_per_op
            );
        }
        t.push_row_tagged(
            cap as u64,
            vec![dir.mops, dir.derefs_per_op, del.mops, del.derefs_per_op, kinds as f64],
            RowTag { leaf_cap: cap, ..RowTag::default() },
        );
        if cap == 1 {
            dir_k1 = Some(dir);
            del_k1 = Some(del);
        }
    }
    t
}

/// Table XV entry point (`exp t15`): full beyond-LLC resident set.
pub fn t15_fatleaf(cfg: &ExpConfig, _router: &KeyRouter) -> Table {
    t15_fatleaf_with(cfg, T15_RESIDENT)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            threads: vec![1],
            reps: 1,
            scale: 10_000,
            topology: Topology::virtual_grid(2, 2),
            seed: 15,
        }
    }

    #[test]
    fn t15_fatleaf_small_resident_holds_counter_bar() {
        // shrunk resident set: the counter asserts inside t15_fatleaf_with
        // (strict deref cut at K ≥ 8 in both modes, 8/8 oracle kinds at
        // every K) must all hold; timing is reported only
        let t = t15_fatleaf_with(&tiny_cfg(), 1 << 15);
        assert_eq!(t.rows.len(), T15_CAPS.len());
        for (k, row) in &t.rows {
            assert!(row[0] > 0.0 && row[2] > 0.0, "K {k}: throughput measured");
            assert_eq!(row[4], 8.0, "K {k}: all kinds oracle-checked");
        }
        let k1 = &t.rows[0];
        let k16 = t.rows.iter().find(|(k, _)| *k == 16).expect("K 16 row");
        assert!(k16.1[1] < k1.1[1], "direct derefs/op strictly fall by K 16");
        assert!(k16.1[3] < k1.1[3], "delegated derefs/op strictly fall by K 16");
    }
}
