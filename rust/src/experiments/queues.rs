//! Experiment T1 (Table I / figure 3): concurrent queue throughput,
//! tbb-like vs the paper's lkfree queue.
//!
//! Methodology (§IV): a vector of queues, one per thread; threads pinned in
//! id order; pushes go to a random queue within the thread's NUMA region,
//! pops come from the thread's local queue; ~50/50 mix; block size 8192.

use std::sync::{Arc, Barrier};
use std::time::Instant;

use crate::numa::{pin_to_cpu, Topology};
use crate::queue::{ConcurrentQueue, LfQueue, TbbLikeQueue};
use crate::util::rng::Rng;

/// Which queue implementation to benchmark.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueImpl {
    Lkfree,
    TbbLike,
    MsBoostLike,
    Mutex,
}

impl QueueImpl {
    pub fn build(self, blocks: usize) -> Box<dyn ConcurrentQueue> {
        match self {
            QueueImpl::Lkfree => Box::new(LfQueue::<u64>::with_config(8192, blocks, true)),
            QueueImpl::TbbLike => {
                Box::new(TbbLikeQueue::<u64>::with_config(8192, blocks.max(1 << 12)))
            }
            QueueImpl::MsBoostLike => Box::new(crate::queue::MsQueue::<u64>::new()),
            QueueImpl::Mutex => Box::new(crate::queue::MutexQueue::<u64>::new()),
        }
    }
}

/// Run `total_ops` (~50% push / 50% pop) over `threads` queues.
/// Returns wall seconds for the whole workload.
pub fn run_queue_workload(
    imp: QueueImpl,
    threads: usize,
    total_ops: u64,
    topology: &Topology,
    seed: u64,
) -> f64 {
    let blocks = ((total_ops as usize / threads) / 8192 + 4).next_power_of_two().max(64);
    let queues: Arc<Vec<Box<dyn ConcurrentQueue>>> =
        Arc::new((0..threads).map(|_| imp.build(blocks)).collect());
    let barrier = Arc::new(Barrier::new(threads + 1));
    let per_thread = total_ops / threads as u64;
    let mut handles = Vec::with_capacity(threads);
    for t in 0..threads {
        let queues = queues.clone();
        let barrier = barrier.clone();
        let topo = topology.clone();
        handles.push(std::thread::spawn(move || {
            pin_to_cpu(t);
            // threads in this NUMA region (for push targets)
            let node = topo.node_of_cpu(t);
            let region: Vec<usize> =
                (0..queues.len()).filter(|&u| topo.node_of_cpu(u) == node).collect();
            let mut rng = Rng::new(seed ^ (t as u64) << 32);
            barrier.wait();
            for i in 0..per_thread {
                if rng.chance(1, 2) {
                    let target = region[rng.below(region.len() as u64) as usize];
                    queues[target].push(i);
                } else {
                    let _ = queues[t].pop();
                }
            }
        }));
    }
    let t0 = Instant::now(); // before the barrier: see engine.rs timing note
    barrier.wait();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_impls_complete() {
        let topo = Topology::virtual_grid(2, 2);
        for imp in [QueueImpl::Lkfree, QueueImpl::TbbLike] {
            let secs = run_queue_workload(imp, 4, 20_000, &topo, 7);
            assert!(secs > 0.0 && secs < 60.0, "{imp:?} took {secs}");
        }
    }

    #[test]
    fn baselines_complete() {
        let topo = Topology::virtual_grid(1, 2);
        for imp in [QueueImpl::MsBoostLike, QueueImpl::Mutex] {
            let secs = run_queue_workload(imp, 2, 10_000, &topo, 9);
            assert!(secs > 0.0);
        }
    }
}
