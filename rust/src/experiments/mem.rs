//! Table X (new, §V): memory behaviour of the unified block arena under
//! churn — footprint vs the eq. (5) prediction, recycle rate, and the
//! per-thread magazine ablation.
//!
//! Two tables:
//!
//! - **Xa** validates eq. (5) directly: Monte-Carlo samples of the paper's
//!   model (k uniformly random news, i ≤ k deletes, uniformly random valid
//!   interleaving) run against a raw [`NodePool`], measuring materialized
//!   blocks. Measured/predicted sits near 1 (empirically ~0.7-1.0:
//!   interleaved deletes keep the live-set peak — what block
//!   materialization tracks — below the prefix average the closed form
//!   sums). Single-threaded block counts are magazine-invariant (bump only
//!   advances when no slot is parked anywhere), so the magazine ablation
//!   is measured only by the multithreaded Xb.
//! - **Xb** measures the structures: a multithreaded churn workload
//!   (random insert-or-erase per step, per-thread key ranges) on every
//!   arena-backed structure, reporting wall time with/without magazines,
//!   recycle and magazine-hit rates, and footprint vs the eq. 5 node
//!   prediction (per arena, floored at one block — every §V manager holds
//!   at least the block it materialized). The acceptance bar is
//!   footprint <= 2x prediction.

use std::sync::Arc;

use crate::coordinator::KvStore;
use crate::hashtable::{SpoHashMap, TwoLevelSpoHashMap};
use crate::mem::{eq5_average_blocks, ArenaOptions, NodePool, PoolStats};
use crate::skiplist::{DetSkiplist, FindMode, RandomSkiplist};
use crate::util::bench::Table;
use crate::util::rng::Rng;

use super::ExpConfig;

/// eq. (5) average blocks, scaled linearly past the exact-sum cutoff (the
/// closed form is O(N^2) to evaluate; its large-N behaviour is ~N/(3C), so
/// linear extrapolation from the cutoff is accurate).
pub fn eq5_blocks_extrapolated(n: u64, c: u64) -> f64 {
    const CUTOFF: u64 = 2048;
    if n == 0 {
        return 0.0;
    }
    if n <= CUTOFF {
        eq5_average_blocks(n, c)
    } else {
        eq5_average_blocks(CUTOFF, c) * (n as f64 / CUTOFF as f64)
    }
}

/// Footprint prediction in **nodes** for an aggregated [`PoolStats`]
/// snapshot: eq. (5) applied per arena (allocs split evenly), floored at
/// one block per arena, times the block size.
pub fn eq5_nodes_prediction(st: &PoolStats) -> f64 {
    if st.blocks == 0 || st.arenas == 0 {
        return 0.0;
    }
    let c = (st.capacity / st.blocks).max(1);
    let per_arena = st.allocs / st.arenas;
    st.arenas as f64 * eq5_blocks_extrapolated(per_arena, c).max(1.0) * c as f64
}

/// One Monte-Carlo sample of the §V model: `k` news and `i` deletes in a
/// uniformly random valid interleaving against a fresh pool; returns the
/// blocks materialized at the end (monotone, so this is the peak).
fn eq5_sample(rng: &mut Rng, n: u64, c: u64) -> u64 {
    let k = rng.below(n) + 1;
    let i = rng.below(k + 1);
    let pool: NodePool<u64> = NodePool::new(c as usize, (n / c + 8) as usize);
    let mut live = Vec::with_capacity(k as usize);
    let (mut news, mut dels) = (k, i);
    while news + dels > 0 {
        // choose uniformly among the remaining moves, subject to validity
        let do_new = dels == 0 || live.is_empty() || rng.below(news + dels) < news;
        if do_new {
            live.push(pool.alloc() as usize);
            news -= 1;
        } else {
            let at = rng.below(live.len() as u64) as usize;
            let p = live.swap_remove(at);
            pool.retire(p as *mut _);
            dels -= 1;
        }
    }
    pool.stats().blocks
}

/// Multithreaded churn against one arena-backed structure: each thread owns
/// a key range and at every step inserts a fresh random key or erases a
/// random live one. Returns (wall seconds, final §V stats).
fn churn(store: Arc<dyn KvStore>, threads: usize, steps_per_thread: u64, seed: u64) -> (f64, PoolStats) {
    use std::sync::Barrier;
    use std::time::Instant;
    let barrier = Arc::new(Barrier::new(threads + 1));
    let mut handles = Vec::new();
    for t in 0..threads {
        let store = store.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            crate::numa::pin_to_cpu(t);
            let mut rng = Rng::new(seed ^ (t as u64) << 40);
            let base = (t as u64) << 32;
            let span = 1u64 << 32;
            let mut live: Vec<u64> = Vec::new();
            barrier.wait();
            for _ in 0..steps_per_thread {
                if live.is_empty() || rng.chance(1, 2) {
                    let k = base + rng.below(span);
                    if store.insert(k, k) {
                        live.push(k);
                    }
                } else {
                    let at = rng.below(live.len() as u64) as usize;
                    let k = live.swap_remove(at);
                    store.erase(k);
                }
            }
        }));
    }
    let t0 = Instant::now(); // before the barrier: see engine.rs timing note
    barrier.wait();
    for h in handles {
        h.join().unwrap();
    }
    (t0.elapsed().as_secs_f64(), store.mem_stats())
}

/// Build churn target `kind_idx` (0=det, 1=random, 2=spo, 3=2lvl-spo) with
/// arena capacity `cap` and the given magazine setting.
fn build_churn_store(kind_idx: usize, cap: usize, magazines: bool) -> Arc<dyn KvStore> {
    let opts = if magazines { ArenaOptions::default() } else { ArenaOptions::without_magazines() };
    match kind_idx {
        0 => Arc::new(DetSkiplist::with_capacity_on(FindMode::LockFree, cap, opts)),
        1 => Arc::new(RandomSkiplist::with_capacity_on(cap, opts)),
        2 => Arc::new(SpoHashMap::with_config_on(256, 16, 1 << 17, cap, opts)),
        3 => Arc::new(TwoLevelSpoHashMap::with_config_on(8, 32, 16, 1 << 14, (cap / 8).max(64), opts)),
        _ => unreachable!(),
    }
}

pub const T10_KINDS: [&str; 4] = ["det-lf", "random", "spo", "2lvl-spo"];

/// Table X (new, §V): arena churn behaviour. See module docs.
pub fn t10_mem(cfg: &ExpConfig) -> Vec<Table> {
    let mut out = Vec::new();

    // ---- Xa: eq. (5) Monte-Carlo validation on the raw pool ----
    let n = (cfg.ops(10_000_000) / 100).clamp(64, 1024);
    let samples = (cfg.reps as u64 * 150).max(50);
    let mut ta = Table::new(
        &format!("Table Xa (new) — §V eq. 5 validation, N={n}, {samples} samples (rows keyed by block size C)"),
        "C",
        &["avg blocks", "eq5 prediction", "measured/pred"],
    );
    for c in [4u64, 16, 64] {
        let mut rng = Rng::new(cfg.seed ^ c);
        let mut sum = 0u64;
        for _ in 0..samples {
            sum += eq5_sample(&mut rng, n, c);
        }
        let avg = sum as f64 / samples as f64;
        let pred = eq5_average_blocks(n, c);
        ta.push_row(c, vec![avg, pred, avg / pred.max(1e-9)]);
    }
    out.push(ta);

    // ---- Xb: structure churn, with/without magazines ----
    let ops = cfg.ops(10_000_000);
    let threads = cfg.threads.first().copied().unwrap_or(4) as usize;
    let steps = (ops / threads as u64).max(1);
    let cap = (ops as usize).max(1 << 12);
    let mut tb = Table::new(
        &format!(
            "Table Xb (new) — churn workload, {ops} ops x{threads} threads, scale 1/{} (rows: 0={} 1={} 2={} 3={})",
            cfg.scale, T10_KINDS[0], T10_KINDS[1], T10_KINDS[2], T10_KINDS[3]
        ),
        "kind",
        &["mag(s)", "nomag(s)", "recycle%", "mag-hit%", "capacity(nodes)", "eq5 pred(nodes)", "cap/pred"],
    );
    for kind_idx in 0..4 {
        let mut secs = [0f64; 2];
        let mut stats = PoolStats::default();
        for (slot, mag) in [(0usize, true), (1, false)] {
            let mut acc = Vec::new();
            for r in 0..cfg.reps {
                let store = build_churn_store(kind_idx, cap, mag);
                let (s, st) = churn(store, threads, steps, cfg.seed + r as u64);
                acc.push(s);
                if mag {
                    stats = st;
                }
            }
            secs[slot] = acc.iter().sum::<f64>() / acc.len() as f64;
        }
        let pred = eq5_nodes_prediction(&stats);
        tb.push_row(
            kind_idx as u64,
            vec![
                secs[0],
                secs[1],
                100.0 * stats.recycle_rate(),
                100.0 * stats.magazine_hit_rate(),
                stats.capacity as f64,
                pred,
                stats.capacity as f64 / pred.max(1.0),
            ],
        );
    }
    out.push(tb);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            threads: vec![2],
            reps: 1,
            scale: 10_000,
            topology: Topology::virtual_grid(2, 2),
            seed: 9,
        }
    }

    #[test]
    fn t10_footprint_within_2x_of_eq5() {
        let tabs = t10_mem(&tiny_cfg());
        assert_eq!(tabs.len(), 2);
        // Xa: the measured model average must track the closed form
        for (c, row) in &tabs[0].rows {
            assert!(row[1] > 0.0, "C={c}: prediction must be positive");
            assert!(
                row[2] > 0.3 && row[2] < 2.0,
                "C={c}: measured/pred ratio {} out of range",
                row[2]
            );
        }
        // Xb: every structure's churn footprint is within 2x of eq. 5,
        // recycling is visible, and magazines serve the hot path
        for (kind, row) in &tabs[1].rows {
            let name = T10_KINDS[*kind as usize];
            assert!(row[0] > 0.0 && row[1] > 0.0, "{name}: wall times");
            assert!(row[2] > 0.0, "{name}: recycle% must be visible");
            assert!(row[3] > 0.0, "{name}: magazine hits must be visible");
            assert!(row[4] > 0.0, "{name}: capacity");
            assert!(row[6] <= 2.0, "{name}: footprint {}x eq5 prediction", row[6]);
        }
    }

    #[test]
    fn eq5_extrapolation_is_continuous_and_linear() {
        let exact = eq5_average_blocks(2048, 16);
        assert!((eq5_blocks_extrapolated(2048, 16) - exact).abs() < 1e-9);
        let double = eq5_blocks_extrapolated(4096, 16);
        assert!((double / exact - 2.0).abs() < 1e-9, "linear extrapolation");
        assert_eq!(eq5_blocks_extrapolated(0, 16), 0.0);
    }
}
