//! Table XVIII (beyond the paper): NUMA-replicated index layers — each
//! engaged node keeps a full local replica of the skiplist's routing
//! levels over the single shared terminal fat-leaf list, as a third
//! execution mode next to Direct and Delegated.
//!
//! Methodology (EXPERIMENTS.md §Table XVIII): the same point workload is
//! drained three ways at three read/write mixes (95/5, 70/30, 50/50):
//!
//! - **Direct** — workers descend the primary index in place, touching
//!   whichever shard owns the key.
//! - **Delegated** — ops travel the fabric as envelopes to owner threads
//!   (no remote derefs, one cross-thread hop per non-inline envelope).
//! - **Replicated** — writes go direct; reads descend the caller's
//!   node-local index replica (`skiplist::replica`) into the shared
//!   terminals, validating the landing live. No delegation hop, no
//!   remote index-plane deref — staleness costs a bounded local repair
//!   walk instead.
//!
//! Cost proxy per drained op: primary hot-line derefs (`SkiplistStats::
//! node_derefs`) plus the replica plane's own derefs (index blocks,
//! terminal probes, repair-walk hops) plus one hop per non-inline
//! delegated envelope — the cross-thread transfer a local replica
//! descent never pays. The run **self-asserts the acceptance bar**:
//! Replicated reads perform zero remote index-plane derefs at every mix
//! (counter-deterministic), Replicated beats Delegated on derefs+hops
//! per op at the 95/5 read-heavy mix, and all eight [`StoreKind`]s
//! answer identically under Direct and Replicated drains of the same
//! seeded workload (the replica plane must be behaviourally invisible).

use std::sync::Arc;

use crate::coordinator::{run_with_mode, ExecMode, RunMetrics, ShardedStore, StoreKind};
use crate::runtime::KeyRouter;
use crate::util::bench::{RowTag, Table};
use crate::workload::{OpMix, WorkloadSpec};

use super::ExpConfig;

/// The three read/write mixes swept; the tuple's first field is the read
/// permille and the row-key base (rows are keyed `permille + mode index`).
pub const T18_MIXES: [(u64, OpMix); 3] =
    [(950, OpMix::READ95), (700, OpMix::READ70), (500, OpMix::READ50)];

/// The three execution modes compared per mix, in row-key-offset order.
pub const T18_MODES: [ExecMode; 3] =
    [ExecMode::Direct, ExecMode::Delegated, ExecMode::Replicated];

struct ModeRun {
    /// Best-of-reps drain seconds.
    secs: f64,
    /// Last rep's metrics (per-key op order is routing-deterministic, so
    /// the counters repeat across reps of the same seed).
    m: RunMetrics,
    /// Whole-run primary hot-line derefs (fill + drain). The fill phase
    /// is identical in every mode, so cross-mode comparisons of this
    /// counter isolate the drain-side difference.
    node_derefs: u64,
}

impl ModeRun {
    fn drained(&self) -> u64 {
        (self.m.inserts + self.m.finds + self.m.erases + self.m.ranges).max(1)
    }

    /// Drain-cost proxy per op: primary derefs, plus the replica plane's
    /// own line touches (index blocks + terminal probes + walk hops) in
    /// Replicated mode, plus one hop per non-inline envelope in Delegated
    /// mode. Zero-valued terms vanish in the modes that lack them.
    fn cost_per_op(&self) -> f64 {
        let r = &self.m.replica;
        let hops = self.m.fabric.submitted.saturating_sub(self.m.fabric.inline_ops);
        (self.node_derefs + r.index_derefs + r.terminal_probes + r.walk_hops + hops) as f64
            / self.drained() as f64
    }
}

/// One measured fill+drain in the given mode over the det-lf sharded
/// store (the only kind with a real replica plane; every other kind is
/// covered by the oracle suite below).
fn run_mode(
    cfg: &ExpConfig,
    mix: OpMix,
    ops: u64,
    threads: usize,
    router: &KeyRouter,
    mode: ExecMode,
) -> ModeRun {
    let mut secs = f64::INFINITY;
    let mut last: Option<(RunMetrics, u64)> = None;
    for rep in 0..cfg.reps.max(1) {
        let store = Arc::new(ShardedStore::new(
            StoreKind::DetSkiplistLf,
            8,
            (ops as usize / 4).max(1 << 14),
            cfg.topology.clone(),
            threads,
        ));
        let spec =
            WorkloadSpec::new("t18", ops, mix, (ops / 2).max(1 << 14)).with_range_window(64);
        let m = run_with_mode(&store, &spec, threads, router, cfg.seed + rep as u64, mode);
        secs = secs.min(m.drain_seconds);
        last = Some((m, store.stats().node_derefs));
    }
    let (m, node_derefs) = last.expect("reps >= 1");
    ModeRun { secs, m, node_derefs }
}

/// Same-seed Direct vs Replicated agreement across every [`StoreKind`]:
/// per-key op order is pinned by the router in both modes, so final
/// length, find hit counts and the full ordered sweep must match exactly
/// — lazily-synced replicas may be stale, never wrong. Returns how many
/// kinds passed (asserts internally, so always all of them).
fn oracle_all_kinds(cfg: &ExpConfig, ops: u64, threads: usize, router: &KeyRouter) -> u64 {
    let mut passed = 0u64;
    for kind in super::hier::T11_KINDS {
        // write-heavy mix: maximum invalidation-log and repair churn
        let spec = WorkloadSpec::new("t18-oracle", ops, OpMix::READ50, (ops / 2).max(1 << 12))
            .with_range_window(64);
        let build = || {
            Arc::new(ShardedStore::new(
                kind,
                8,
                (ops as usize / 4).max(1 << 14),
                cfg.topology.clone(),
                threads,
            ))
        };
        let dir = build();
        let md = run_with_mode(&dir, &spec, threads, router, cfg.seed ^ 0x18, ExecMode::Direct);
        let rep = build();
        let mr = run_with_mode(&rep, &spec, threads, router, cfg.seed ^ 0x18, ExecMode::Replicated);
        assert_eq!(md.final_len, mr.final_len, "{kind:?}: final_len disagreed across modes");
        assert_eq!(md.found, mr.found, "{kind:?}: find hits disagreed across modes");
        assert_eq!(
            dir.range(0, u64::MAX - 2),
            rep.range(0, u64::MAX - 2),
            "{kind:?}: final ordered sweep disagreed across modes"
        );
        passed += 1;
    }
    passed
}

/// Table XVIII with an explicit drained-op count (the public entry point
/// scales the paper-class 10m workload; tests shrink it). The counter
/// asserts hold at any size; timing is reported, not asserted.
pub fn t18_replica_with(cfg: &ExpConfig, router: &KeyRouter, ops: u64) -> Table {
    let th = *cfg.threads.last().unwrap_or(&8) as usize;
    let oracle_ops = (ops / 5).clamp(5_000, 50_000);
    let kinds = oracle_all_kinds(cfg, oracle_ops, th, router);
    assert_eq!(kinds, 8, "every store kind must agree across Direct/Replicated");
    let mut t = Table::new(
        &format!(
            "Table XVIII (new) — replicated index layers: direct vs delegated vs \
             replicated ({ops} ops, {th} threads, oracle churn {oracle_ops}/kind, \
             scale 1/{}) | rows: read-permille + mode (+0=direct +1=delegated \
             +2=replicated)",
            cfg.scale
        ),
        "#mix+mode",
        &["drain(s)", "Mops/s", "derefs+hops/op", "remote-idx/op", "fallback-rate", "oracle kinds"],
    );
    for (pm, mix) in T18_MIXES {
        let mut runs: Vec<(ExecMode, ModeRun)> = Vec::new();
        for (i, &mode) in T18_MODES.iter().enumerate() {
            let r = run_mode(cfg, mix, ops, th, router, mode);
            let rs = &r.m.replica;
            let (remote_per_op, fallback, oracle) = if mode == ExecMode::Replicated {
                // acceptance (a): the replica plane answered reads, it did
                // so node-locally, and not purely by falling back
                assert!(rs.lookups > 0, "read {pm}: replicated run must use the replica plane");
                assert_eq!(
                    rs.remote_index_derefs, 0,
                    "read {pm}: replicated reads must never deref a remote index line"
                );
                assert!(
                    rs.fallbacks < rs.lookups,
                    "read {pm}: some reads must resolve on-replica \
                     ({} fallbacks of {} lookups)",
                    rs.fallbacks,
                    rs.lookups
                );
                (
                    rs.remote_index_derefs as f64 / r.drained() as f64,
                    rs.fallback_rate(),
                    kinds as f64,
                )
            } else {
                (f64::NAN, f64::NAN, f64::NAN)
            };
            t.push_row_tagged(
                pm + i as u64,
                vec![
                    r.secs,
                    r.drained() as f64 / r.secs / 1e6,
                    r.cost_per_op(),
                    remote_per_op,
                    fallback,
                    oracle,
                ],
                RowTag::mode(mode.name()),
            );
            runs.push((mode, r));
        }
        // acceptance (b): at the read-heavy mix the node-local replica
        // descent must beat delegation's full descent plus per-envelope
        // hop on the combined derefs+hops cost
        if pm == 950 {
            let del = &runs.iter().find(|(m, _)| *m == ExecMode::Delegated).unwrap().1;
            let rep = &runs.iter().find(|(m, _)| *m == ExecMode::Replicated).unwrap().1;
            assert!(
                rep.cost_per_op() < del.cost_per_op(),
                "95/5: replicated must strictly beat delegated on derefs+hops/op \
                 ({:.3} vs {:.3})",
                rep.cost_per_op(),
                del.cost_per_op()
            );
        }
    }
    t
}

/// Table XVIII entry point (`exp t18`): paper-class 10m-op workload.
pub fn t18_replica(cfg: &ExpConfig, router: &KeyRouter) -> Table {
    t18_replica_with(cfg, router, cfg.ops(10_000_000))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            threads: vec![2, 4],
            reps: 1,
            scale: 10_000,
            topology: Topology::virtual_grid(2, 2),
            seed: 18,
        }
    }

    #[test]
    fn t18_replica_tiny_holds_counter_bar() {
        // shrunk workload: every self-assert inside t18_replica_with
        // (remote-idx == 0, replicated < delegated at 95/5, 8/8 oracle
        // kinds) must hold; timing is reported only
        let t = t18_replica_with(&tiny_cfg(), &KeyRouter::Native, 1 << 13);
        assert_eq!(t.rows.len(), T18_MIXES.len() * T18_MODES.len());
        assert_eq!(t.tags.len(), t.rows.len());
        for (i, (k, row)) in t.rows.iter().enumerate() {
            assert!(row[0] > 0.0 && row[1] > 0.0, "row {k}: throughput measured");
            let mode = T18_MODES[i % 3];
            assert_eq!(t.tags[i].mode, mode.name(), "row {k}: mode tag");
            if mode == ExecMode::Replicated {
                assert_eq!(row[3], 0.0, "row {k}: zero remote index derefs/op");
                assert!(row[4] >= 0.0 && row[4] < 1.0, "row {k}: fallback rate sane");
                assert_eq!(row[5], 8.0, "row {k}: all kinds oracle-checked");
            } else {
                assert!(row[3].is_nan() && row[4].is_nan() && row[5].is_nan());
            }
        }
        let rep95 = t.rows.iter().find(|(k, _)| *k == 952).expect("replicated 95/5 row");
        let del95 = t.rows.iter().find(|(k, _)| *k == 951).expect("delegated 95/5 row");
        assert!(rep95.1[2] < del95.1[2], "replicated derefs+hops/op beats delegated at 95/5");
    }
}
