//! Table XVII (beyond the paper, robustness): the self-healing delegation
//! fabric under injected faults.
//!
//! Methodology (EXPERIMENTS.md §Table XVII): the same delegated workload is
//! run four times — unfaulted baseline, an injected owner kill (an owner
//! thread "dies" at an envelope boundary and a survivor adopts its queue
//! and shards), a slow owner (seeded delays at drain entry and settle), and
//! a queue-full storm (spurious `try_push` rejections plus transient arena
//! free-list exhaustion). Each row reports throughput, the measured
//! first-death→first-takeover recovery latency, and the fault counters, and
//! the runner *self-asserts* recovery: the run completes (never panics),
//! quiescence balances (`executed + errored == submitted`), the final store
//! state agrees with an unfaulted Direct-mode reference run of the same
//! spec (insert/find mix, so final membership is order-independent), and a
//! sync caller on a wedged fabric receives a typed [`FabricError`] instead
//! of a panic.
//!
//! Built with `--features failpoints` the fault rows inject real faults;
//! without it the failpoint sites are no-ops and every row degenerates to
//! the baseline (the table still runs, so the bench matrix does not fork).

use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{
    run_with_opts, DelegatedOp, ExecMode, FabricError, OpFabric, RunMetrics, RunOptions,
    ShardedStore, StoreKind,
};
use crate::numa::Topology;
use crate::runtime::KeyRouter;
use crate::util::bench::Table;
use crate::workload::{OpMix, WorkloadSpec};

use super::ExpConfig;

/// Fault scenarios, in table-row order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// No faults installed (the recovery-overhead reference).
    Baseline,
    /// One owner killed at an envelope boundary early in the drain.
    OwnerKill,
    /// Seeded delays at owner drain entry and completion settle.
    SlowOwner,
    /// Spurious queue-full rejections + transient arena refill exhaustion.
    QueueFullStorm,
}

pub const T17_SCENARIOS: [Scenario; 4] =
    [Scenario::Baseline, Scenario::OwnerKill, Scenario::SlowOwner, Scenario::QueueFullStorm];

impl Scenario {
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Baseline => "baseline",
            Scenario::OwnerKill => "owner-kill",
            Scenario::SlowOwner => "slow-owner",
            Scenario::QueueFullStorm => "qfull-storm",
        }
    }
}

/// One delegated run of `spec`-shaped HASH traffic under `scenario`'s fault
/// plan, returning the metrics and the final store for oracle comparison.
fn chaos_run(
    cfg: &ExpConfig,
    ops: u64,
    threads: usize,
    router: &KeyRouter,
    rep: u64,
    scenario: Scenario,
    mode: ExecMode,
) -> (RunMetrics, Arc<ShardedStore>) {
    let store = Arc::new(ShardedStore::new(
        StoreKind::DetSkiplistLf,
        8,
        (ops as usize / 4).max(1 << 14),
        cfg.topology.clone(),
        threads,
    ));
    let spec = WorkloadSpec::new("chaos", ops, OpMix::HASH, (ops / 2).max(1 << 14));
    // Generous deadline: nothing should time out — recovery is supposed to
    // be takeover (heartbeats arm at deadline/4), not caller abandonment.
    let opts = RunOptions {
        mode,
        op_timeout: Some(Duration::from_secs(10)),
        ..RunOptions::default()
    };
    // The fault plan lives exactly as long as the run. With the feature off
    // this block vanishes and `scenario` only selects the row label.
    #[cfg(feature = "failpoints")]
    let _guard = {
        use crate::util::fail::FaultPlan;
        let seed = cfg.seed ^ rep;
        match (mode, scenario) {
            (ExecMode::Direct, _) | (_, Scenario::Baseline) => None,
            (_, Scenario::OwnerKill) => {
                // One kill, early: the site is hit once per drain window,
                // so the 25th hit lands while queues are still deep.
                Some(FaultPlan::new(seed).kill_nth("fabric.owner.kill", 25).install())
            }
            (_, Scenario::SlowOwner) => Some(
                FaultPlan::new(seed)
                    .delay_prob("fabric.owner.slow", 1, 16, 100_000)
                    .delay_prob("fabric.settle", 1, 8, 20_000)
                    .install(),
            ),
            (_, Scenario::QueueFullStorm) => Some(
                FaultPlan::new(seed)
                    .fail_prob("queue.try_push", 1, 8)
                    .fail_prob("arena.refill", 1, 4)
                    .install(),
            ),
        }
    };
    let m = run_with_opts(&store, &spec, threads, router, cfg.seed + rep, opts);
    let _ = (rep, scenario);
    (m, store)
}

/// A sync caller on a fabric whose owner never drains (and is then declared
/// dead) must get a typed [`FabricError`] back — never a panic, never an
/// infinite spin. Feature-independent: this exercises the deadline and
/// dead-owner paths directly, no failpoints needed.
fn assert_sync_caller_sees_typed_error() {
    let fabric = OpFabric::new(2, 1, 4, Topology::virtual_grid(1, 2), 16, 4);
    fabric.set_op_timeout(Some(Duration::from_millis(20)));
    let store = ShardedStore::new(StoreKind::DetSkiplistLf, 4, 1 << 14, Topology::virtual_grid(1, 2), 2);
    let mut caller = fabric.caller(2, None);
    // Route to an owner that never drains; the call must come back typed.
    let r = caller.call(DelegatedOp::Insert { key: 7, value: 7 }, &store);
    assert!(
        matches!(r, Err(FabricError::Timeout) | Err(FabricError::OwnerDead)),
        "wedged sync call must surface a typed error, got {r:?}"
    );
    caller.finish(&store);
}

/// Table XVII: fabric robustness under injected faults. Rows are keyed by
/// scenario index (see [`T17_SCENARIOS`]); `balance` is
/// `submitted - executed - errored` and must be 0 in every row.
pub fn t17_chaos(cfg: &ExpConfig, router: &KeyRouter) -> Table {
    let ops = cfg.ops(10_000_000);
    let th = *cfg.threads.last().unwrap_or(&8) as usize;
    assert_sync_caller_sees_typed_error();
    let mut t = Table::new(
        &format!(
            "Table XVII (new) — fabric chaos: injected faults + self-healing \
             ({ops} ops, {th} threads, mix HASH, scale 1/{}, failpoints {}) \
             | rows: 0=baseline 1=owner-kill 2=slow-owner 3=qfull-storm",
            cfg.scale,
            if cfg!(feature = "failpoints") { "on" } else { "off" },
        ),
        "#scenario",
        &["Mops/s", "recovery-us", "deaths", "adopted", "fallback", "errored", "balance"],
    );
    // Unfaulted Direct-mode reference of the same op stream (the *last*
    // rep's seed, matching the store each scenario keeps for comparison):
    // the membership oracle every scenario's final state must match.
    let rep_ref = cfg.reps.saturating_sub(1) as u64;
    let (_, oracle) =
        chaos_run(cfg, ops, th, router, rep_ref, Scenario::Baseline, ExecMode::Direct);
    let oracle_rows = oracle.range(0, u64::MAX - 2);
    for (i, sc) in T17_SCENARIOS.into_iter().enumerate() {
        let mut mops = Vec::with_capacity(cfg.reps);
        let mut last = RunMetrics::default();
        let mut last_store = None;
        for rep in 0..cfg.reps {
            let (m, store) =
                chaos_run(cfg, ops, th, router, rep as u64, sc, ExecMode::Delegated);
            mops.push(m.throughput_mops());
            last = m;
            last_store = Some(store);
        }
        let f = &last.fabric;
        let balance = f.submitted as i64 - f.executed as i64 - f.errored as i64;
        // -- self-asserted recovery (acceptance criteria) --
        assert_eq!(balance, 0, "{sc:?}: quiescence must balance: {f:?}");
        assert!(
            last.throughput_mops() > 0.0,
            "{sc:?}: post-takeover throughput must be > 0"
        );
        assert_eq!(
            last.ops(),
            ops,
            "{sc:?}: zero lost acks — every op drains exactly once"
        );
        if rep_oracle_applies(sc) {
            // Insert/find membership is order-independent, so even a run
            // that lost an owner mid-way must land on the oracle state.
            assert_eq!(
                last_store.unwrap().range(0, u64::MAX - 2),
                oracle_rows,
                "{sc:?}: post-recovery store must agree with the unfaulted oracle"
            );
        }
        if cfg!(feature = "failpoints") {
            match sc {
                Scenario::OwnerKill => {
                    assert!(f.owner_deaths >= 1, "kill scenario must record a death");
                    assert!(f.recovery_ns > 0, "takeover must be timestamped");
                    assert_eq!(f.errored, 0, "a clean kill loses nothing");
                }
                Scenario::QueueFullStorm => {
                    assert!(
                        f.backpressure > 0 || f.direct_fallback > 0,
                        "storm must exercise the backpressure/fallback path"
                    );
                }
                _ => {}
            }
        }
        let mean_mops = mops.iter().sum::<f64>() / mops.len().max(1) as f64;
        t.push_row(
            i as u64,
            vec![
                mean_mops,
                f.recovery_ns as f64 / 1000.0,
                f.owner_deaths as f64,
                f.shards_adopted as f64,
                f.direct_fallback as f64,
                f.errored as f64,
                balance as f64,
            ],
        );
    }
    t
}

/// The membership oracle holds for every scenario (clean kills re-execute
/// at envelope boundaries; delays and spurious fulls only reorder). Kept as
/// a named predicate so a future unclean-death scenario (quarantine drops
/// work by design, `errored > 0`) can opt out explicitly.
fn rep_oracle_applies(_sc: Scenario) -> bool {
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t17_chaos_runs_and_self_asserts() {
        let cfg = ExpConfig {
            threads: vec![4],
            reps: 1,
            scale: 10_000,
            topology: Topology::virtual_grid(2, 2),
            seed: 9,
        };
        let t = t17_chaos(&cfg, &KeyRouter::Native);
        assert_eq!(t.rows.len(), 4, "one row per scenario");
        for (sc, row) in &t.rows {
            assert!(row[0] > 0.0, "scenario {sc}: throughput");
            assert_eq!(row[6], 0.0, "scenario {sc}: balance");
        }
        #[cfg(feature = "failpoints")]
        {
            let kill = &t.rows[1].1;
            assert!(kill[2] >= 1.0, "owner-kill row must record a death");
            assert!(kill[1] > 0.0, "owner-kill row must measure recovery latency");
        }
    }
}
