//! Fixed-slot MWMR hash table with a BST per slot (§VII variant 1,
//! "BinLists"/"fixed" in Tables V/VII/VIII).
//!
//! A constant power-of-two number of slots; each slot is a reader-writer
//! lock protecting an unbalanced BST keyed by H(k). Scales with slot count
//! but degrades for large workloads as per-slot trees deepen — exactly the
//! behaviour Table V demonstrates against the two-level variant.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sync::RwSpinLock;

use super::bst::Bst;
use super::hash::{hash_key, slot_of, unhash_key};
use super::traits::ConcurrentMap;

struct Slot {
    lock: RwSpinLock,
    tree: std::cell::UnsafeCell<Bst>,
}

unsafe impl Send for Slot {}
unsafe impl Sync for Slot {}

/// Fixed-size table: `m` slots, BST collision chains.
pub struct FixedHashMap {
    slots: Box<[Slot]>,
    len: AtomicU64,
}

impl FixedHashMap {
    /// `m` must be a power of two (the paper uses 8192).
    pub fn new(m: usize) -> FixedHashMap {
        assert!(m.is_power_of_two());
        FixedHashMap {
            slots: (0..m)
                .map(|_| Slot { lock: RwSpinLock::new(), tree: std::cell::UnsafeCell::new(Bst::new()) })
                .collect(),
            len: AtomicU64::new(0),
        }
    }

    #[inline]
    fn slot(&self, key: u64) -> (&Slot, u64) {
        let h = hash_key(key);
        (&self.slots[slot_of(h, self.slots.len())], h)
    }

    /// Max BST depth across slots (collision metric for Table V).
    pub fn max_depth(&self) -> usize {
        self.slots
            .iter()
            .map(|s| {
                let _g = s.lock.read();
                unsafe { &*s.tree.get() }.depth()
            })
            .max()
            .unwrap_or(0)
    }

    /// Per-slot load vector (load-balance check: ~N/M per slot, §VIII).
    pub fn slot_loads(&self) -> Vec<usize> {
        self.slots
            .iter()
            .map(|s| {
                let _g = s.lock.read();
                unsafe { &*s.tree.get() }.len()
            })
            .collect()
    }

    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }
}

impl ConcurrentMap for FixedHashMap {
    fn insert(&self, key: u64, value: u64) -> bool {
        let (s, h) = self.slot(key);
        let _g = s.lock.write();
        // the BST is keyed by the scrambled hash to stay shallow; ties on
        // full 64-bit H(k) are impossible for distinct keys (bijection)
        let ok = unsafe { &mut *s.tree.get() }.insert(h, value);
        if ok {
            self.len.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }

    fn get(&self, key: u64) -> Option<u64> {
        let (s, h) = self.slot(key);
        let _g = s.lock.read();
        unsafe { &*s.tree.get() }.get(h)
    }

    fn erase(&self, key: u64) -> bool {
        let (s, h) = self.slot(key);
        let _g = s.lock.write();
        let ok = unsafe { &mut *s.tree.get() }.erase(h);
        if ok {
            self.len.fetch_sub(1, Ordering::Relaxed);
        }
        ok
    }

    fn len(&self) -> u64 {
        self.len.load(Ordering::Relaxed)
    }

    fn for_each(&self, f: &mut dyn FnMut(u64, u64)) {
        for s in self.slots.iter() {
            let _g = s.lock.read();
            for (h, v) in unsafe { &*s.tree.get() }.entries() {
                f(unhash_key(h), v);
            }
        }
    }

    fn name(&self) -> &'static str {
        "fixed-binlist"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    #[test]
    fn basic() {
        let m = FixedHashMap::new(16);
        assert!(m.insert(1, 10));
        assert!(!m.insert(1, 11));
        assert_eq!(m.get(1), Some(10));
        assert!(m.erase(1));
        assert_eq!(m.get(1), None);
        assert_eq!(m.len(), 0);
    }

    #[test]
    fn oracle_sequential() {
        let m = FixedHashMap::new(64);
        let mut oracle = BTreeMap::new();
        let mut rng = Rng::new(3);
        for _ in 0..20_000 {
            let k = rng.below(1_000);
            match rng.below(3) {
                0 => {
                    let fresh = !oracle.contains_key(&k);
                    assert_eq!(m.insert(k, k + 1), fresh);
                    oracle.entry(k).or_insert(k + 1);
                }
                1 => assert_eq!(m.erase(k), oracle.remove(&k).is_some()),
                _ => assert_eq!(m.get(k), oracle.get(&k).copied()),
            }
        }
        assert_eq!(m.len() as usize, oracle.len());
    }

    #[test]
    fn concurrent_disjoint() {
        let m = Arc::new(FixedHashMap::new(256));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..3_000u64 {
                    assert!(m.insert(t * 1_000_000 + i, i));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.len(), 12_000);
        for t in 0..4u64 {
            assert_eq!(m.get(t * 1_000_000 + 7), Some(7));
        }
    }

    #[test]
    fn for_each_reports_original_keys() {
        let m = FixedHashMap::new(16);
        for k in 0..500u64 {
            m.insert(k * 11, k);
        }
        let mut got = Vec::new();
        m.for_each(&mut |k, v| got.push((k, v)));
        got.sort_unstable();
        let want: Vec<(u64, u64)> = (0..500u64).map(|k| (k * 11, k)).collect();
        assert_eq!(got, want, "hash inversion must recover original keys");
    }

    #[test]
    fn slots_are_load_balanced() {
        let m = FixedHashMap::new(64);
        let n = 64 * 100;
        for k in 0..n as u64 {
            m.insert(k, k);
        }
        let loads = m.slot_loads();
        let mean = 100.0;
        for (i, &l) in loads.iter().enumerate() {
            assert!(
                (l as f64 - mean).abs() < 6.0 * mean.sqrt(),
                "slot {i} load {l} far from mean {mean}"
            );
        }
    }

    #[test]
    fn concurrent_mixed_same_keys() {
        let m = Arc::new(FixedHashMap::new(32));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t + 50);
                for _ in 0..5_000 {
                    let k = rng.below(100);
                    match rng.below(3) {
                        0 => {
                            m.insert(k, k * 7);
                        }
                        1 => {
                            m.erase(k);
                        }
                        _ => {
                            if let Some(v) = m.get(k) {
                                assert_eq!(v, k * 7);
                            }
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // len consistent with actual contents
        let total: usize = m.slot_loads().iter().sum();
        assert_eq!(total as u64, m.len());
    }
}
