//! TBB-like concurrent hash map baseline (`tbb::concurrent_hash_map`
//! analog for Tables VII-VIII).
//!
//! Per the paper: "The TBB implementation is similar to a two-level
//! split-order table with expansion and shrinking. Unlike the split-order
//! algorithm, rehashing traverses all entries in a slot, removes and adds
//! them to new slots" — i.e. chained buckets with per-bucket RW locks and a
//! **migrating** rehash under a table-wide exclusive lock; and "TBB
//! allocates large segments of memory before running hash table queries",
//! which we mirror with a generous initial bucket reservation.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::sync::RwSpinLock;

use super::hash::{hash_key, slot_of, unhash_key};
use super::traits::ConcurrentMap;

struct Bucket {
    lock: RwSpinLock,
    chain: UnsafeCell<Vec<(u64, u64)>>, // (hash, value)
}

unsafe impl Send for Bucket {}
unsafe impl Sync for Bucket {}

fn make_buckets(n: usize) -> Box<[Bucket]> {
    (0..n)
        .map(|_| Bucket { lock: RwSpinLock::new(), chain: UnsafeCell::new(Vec::new()) })
        .collect()
}

/// Chained-bucket map with migrating rehash.
pub struct TbbLikeHashMap {
    table_lock: RwSpinLock,
    buckets: UnsafeCell<Box<[Bucket]>>,
    len: AtomicU64,
    max_load: usize,
    rehashes: AtomicUsize,
}

unsafe impl Send for TbbLikeHashMap {}
unsafe impl Sync for TbbLikeHashMap {}

impl TbbLikeHashMap {
    /// TBB-style eager reservation (large initial table).
    pub fn new() -> TbbLikeHashMap {
        Self::with_config(1 << 16, 4)
    }

    pub fn with_config(initial_buckets: usize, max_load: usize) -> TbbLikeHashMap {
        assert!(initial_buckets.is_power_of_two());
        TbbLikeHashMap {
            table_lock: RwSpinLock::new(),
            buckets: UnsafeCell::new(make_buckets(initial_buckets)),
            len: AtomicU64::new(0),
            max_load,
            rehashes: AtomicUsize::new(0),
        }
    }

    pub fn rehash_count(&self) -> usize {
        self.rehashes.load(Ordering::Relaxed)
    }

    pub fn bucket_count(&self) -> usize {
        let _g = self.table_lock.read();
        unsafe { &*self.buckets.get() }.len()
    }

    /// Migrating rehash: table-wide exclusive lock, every entry moved.
    fn maybe_rehash(&self) {
        let need = {
            let _g = self.table_lock.read();
            let b = unsafe { &*self.buckets.get() };
            (self.len.load(Ordering::Relaxed) as usize) > b.len() * self.max_load
        };
        if !need {
            return;
        }
        let _g = self.table_lock.write();
        let b = unsafe { &mut *self.buckets.get() };
        if (self.len.load(Ordering::Relaxed) as usize) <= b.len() * self.max_load {
            return; // raced
        }
        let fresh = make_buckets(b.len() * 2);
        for bucket in b.iter() {
            for &(h, v) in unsafe { &*bucket.chain.get() }.iter() {
                let idx = slot_of(h, fresh.len());
                unsafe { &mut *fresh[idx].chain.get() }.push((h, v));
            }
        }
        *b = fresh;
        self.rehashes.fetch_add(1, Ordering::Relaxed);
    }
}

impl Default for TbbLikeHashMap {
    fn default() -> Self {
        Self::new()
    }
}

impl ConcurrentMap for TbbLikeHashMap {
    fn insert(&self, key: u64, value: u64) -> bool {
        let h = hash_key(key);
        let ok = {
            let _g = self.table_lock.read();
            let b = unsafe { &*self.buckets.get() };
            let bucket = &b[slot_of(h, b.len())];
            let _bg = bucket.lock.write();
            let chain = unsafe { &mut *bucket.chain.get() };
            if chain.iter().any(|&(eh, _)| eh == h) {
                false
            } else {
                chain.push((h, value));
                true
            }
        };
        if ok {
            self.len.fetch_add(1, Ordering::Relaxed);
            self.maybe_rehash();
        }
        ok
    }

    fn get(&self, key: u64) -> Option<u64> {
        let h = hash_key(key);
        let _g = self.table_lock.read();
        let b = unsafe { &*self.buckets.get() };
        let bucket = &b[slot_of(h, b.len())];
        let _bg = bucket.lock.read();
        unsafe { &*bucket.chain.get() }
            .iter()
            .find(|&&(eh, _)| eh == h)
            .map(|&(_, v)| v)
    }

    fn erase(&self, key: u64) -> bool {
        let h = hash_key(key);
        let ok = {
            let _g = self.table_lock.read();
            let b = unsafe { &*self.buckets.get() };
            let bucket = &b[slot_of(h, b.len())];
            let _bg = bucket.lock.write();
            let chain = unsafe { &mut *bucket.chain.get() };
            if let Some(pos) = chain.iter().position(|&(eh, _)| eh == h) {
                chain.swap_remove(pos);
                true
            } else {
                false
            }
        };
        if ok {
            self.len.fetch_sub(1, Ordering::Relaxed);
        }
        ok
    }

    fn len(&self) -> u64 {
        self.len.load(Ordering::Relaxed)
    }

    fn for_each(&self, f: &mut dyn FnMut(u64, u64)) {
        let _g = self.table_lock.read();
        let b = unsafe { &*self.buckets.get() };
        for bucket in b.iter() {
            let _bg = bucket.lock.read();
            for &(h, v) in unsafe { &*bucket.chain.get() }.iter() {
                f(unhash_key(h), v);
            }
        }
    }

    fn name(&self) -> &'static str {
        "tbb-like"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    #[test]
    fn basic() {
        let m = TbbLikeHashMap::with_config(8, 2);
        assert!(m.insert(1, 10));
        assert!(!m.insert(1, 11));
        assert_eq!(m.get(1), Some(10));
        assert!(m.erase(1));
        assert_eq!(m.get(1), None);
    }

    #[test]
    fn rehash_preserves_contents() {
        let m = TbbLikeHashMap::with_config(4, 2);
        for k in 0..1_000u64 {
            assert!(m.insert(k, k * 2));
        }
        assert!(m.rehash_count() > 0, "must rehash under load");
        assert!(m.bucket_count() > 4);
        for k in 0..1_000u64 {
            assert_eq!(m.get(k), Some(k * 2));
        }
    }

    #[test]
    fn oracle_sequential() {
        let m = TbbLikeHashMap::with_config(16, 2);
        let mut oracle = BTreeMap::new();
        let mut rng = Rng::new(37);
        for _ in 0..20_000 {
            let k = rng.below(600);
            match rng.below(3) {
                0 => {
                    let fresh = !oracle.contains_key(&k);
                    assert_eq!(m.insert(k, k + 3), fresh);
                    oracle.entry(k).or_insert(k + 3);
                }
                1 => assert_eq!(m.erase(k), oracle.remove(&k).is_some()),
                _ => assert_eq!(m.get(k), oracle.get(&k).copied()),
            }
        }
        assert_eq!(m.len() as usize, oracle.len());
    }

    #[test]
    fn concurrent_through_rehash() {
        let m = Arc::new(TbbLikeHashMap::with_config(4, 2));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..2_000u64 {
                    let k = t * 1_000_000 + i;
                    assert!(m.insert(k, k));
                    assert_eq!(m.get(k), Some(k));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.len(), 8_000);
        assert!(m.rehash_count() > 0);
    }
}
