//! Two-level MWMR hash table with BSTs at the second level (§VII variant 2,
//! "twolevel" in Table V).
//!
//! Level 1: `m1` slots, each with a reader-writer lock taken **shared** by
//! every operation (exclusive only while expanding/shrinking the slot's
//! second level). Level 2: a nested table of `m2` slots (1 until the slot
//! grows past the expansion threshold, then `m2_max`), each with its own RW
//! lock and BST. The two levels consume different bit ranges of H(k): the
//! low `log2(m1)` bits, then the next `log2(m2)` bits.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::sync::RwSpinLock;

use super::bst::Bst;
use super::hash::{hash_key, slot_of, unhash_key};
use super::traits::ConcurrentMap;

/// Expansion threshold: a slot grows its second level when it holds more
/// than this many entries (the paper uses 10).
pub const EXPAND_THRESHOLD: usize = 10;

struct L2Slot {
    lock: RwSpinLock,
    tree: std::cell::UnsafeCell<Bst>,
}

unsafe impl Send for L2Slot {}
unsafe impl Sync for L2Slot {}

struct L1Slot {
    lock: RwSpinLock,
    /// 1 or `m2_max` L2 slots; swapped under the exclusive L1 lock.
    inner: std::cell::UnsafeCell<Box<[L2Slot]>>,
    entries: AtomicUsize,
}

unsafe impl Send for L1Slot {}
unsafe impl Sync for L1Slot {}

fn make_l2(n: usize) -> Box<[L2Slot]> {
    (0..n)
        .map(|_| L2Slot { lock: RwSpinLock::new(), tree: std::cell::UnsafeCell::new(Bst::new()) })
        .collect()
}

/// Two-level table: `m1` first-level slots, `m2_max` second-level slots
/// after expansion.
pub struct TwoLevelHashMap {
    slots: Box<[L1Slot]>,
    m2_max: usize,
    len: AtomicU64,
    expansions: AtomicU64,
    shrinks: AtomicU64,
}

impl TwoLevelHashMap {
    /// The paper's configuration: 8192 L1 slots, 2048 L2 slots.
    pub fn new(m1: usize, m2_max: usize) -> TwoLevelHashMap {
        assert!(m1.is_power_of_two() && m2_max.is_power_of_two());
        TwoLevelHashMap {
            slots: (0..m1)
                .map(|_| L1Slot {
                    lock: RwSpinLock::new(),
                    inner: std::cell::UnsafeCell::new(make_l2(1)),
                    entries: AtomicUsize::new(0),
                })
                .collect(),
            m2_max,
            len: AtomicU64::new(0),
            expansions: AtomicU64::new(0),
            shrinks: AtomicU64::new(0),
        }
    }

    #[inline]
    fn l1(&self, h: u64) -> &L1Slot {
        &self.slots[slot_of(h, self.slots.len())]
    }

    /// Second-level slot index: the next log2(m2) bits above the L1 bits.
    #[inline]
    fn l2_index(&self, h: u64, m2: usize) -> usize {
        let shift = self.slots.len().trailing_zeros();
        slot_of(h >> shift, m2)
    }

    /// Grow (or shrink) the slot's second level; caller holds NO locks.
    fn resize_slot(&self, s: &L1Slot, grow: bool) {
        let _g = s.lock.write();
        let inner = unsafe { &mut *s.inner.get() };
        let cur = inner.len();
        let target = if grow { self.m2_max } else { 1 };
        if cur == target {
            return; // raced with another resizer
        }
        // re-check the trigger under the exclusive lock
        let entries = s.entries.load(Ordering::Relaxed);
        if grow && entries <= EXPAND_THRESHOLD {
            return;
        }
        if !grow && entries > EXPAND_THRESHOLD {
            return;
        }
        let fresh = make_l2(target);
        for l2 in inner.iter() {
            let tree = unsafe { &*l2.tree.get() };
            for h in tree.keys() {
                let v = tree.get(h).unwrap();
                let idx = self.l2_index(h, target);
                unsafe { &mut *fresh[idx].tree.get() }.insert(h, v);
            }
        }
        *inner = fresh;
        if grow {
            self.expansions.fetch_add(1, Ordering::Relaxed);
        } else {
            self.shrinks.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn expansion_count(&self) -> u64 {
        self.expansions.load(Ordering::Relaxed)
    }

    pub fn shrink_count(&self) -> u64 {
        self.shrinks.load(Ordering::Relaxed)
    }

    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    /// Max BST depth over all L2 trees (Table V collision metric).
    pub fn max_depth(&self) -> usize {
        let mut max = 0;
        for s in self.slots.iter() {
            let _g = s.lock.read();
            let inner = unsafe { &*s.inner.get() };
            for l2 in inner.iter() {
                let _g2 = l2.lock.read();
                max = max.max(unsafe { &*l2.tree.get() }.depth());
            }
        }
        max
    }
}

impl ConcurrentMap for TwoLevelHashMap {
    fn insert(&self, key: u64, value: u64) -> bool {
        let h = hash_key(key);
        let s = self.l1(h);
        let ok = {
            let _g = s.lock.read(); // shared at level 1 (paper's design)
            let inner = unsafe { &*s.inner.get() };
            let l2 = &inner[self.l2_index(h, inner.len())];
            let _g2 = l2.lock.write(); // exclusive at level 2
            unsafe { &mut *l2.tree.get() }.insert(h, value)
        };
        if ok {
            self.len.fetch_add(1, Ordering::Relaxed);
            let e = s.entries.fetch_add(1, Ordering::Relaxed) + 1;
            if e > EXPAND_THRESHOLD {
                let grown = {
                    let _g = s.lock.read();
                    unsafe { &*s.inner.get() }.len() == self.m2_max
                };
                if !grown {
                    self.resize_slot(s, true);
                }
            }
        }
        ok
    }

    fn get(&self, key: u64) -> Option<u64> {
        let h = hash_key(key);
        let s = self.l1(h);
        let _g = s.lock.read();
        let inner = unsafe { &*s.inner.get() };
        let l2 = &inner[self.l2_index(h, inner.len())];
        let _g2 = l2.lock.read();
        unsafe { &*l2.tree.get() }.get(h)
    }

    fn erase(&self, key: u64) -> bool {
        let h = hash_key(key);
        let s = self.l1(h);
        let ok = {
            let _g = s.lock.read();
            let inner = unsafe { &*s.inner.get() };
            let l2 = &inner[self.l2_index(h, inner.len())];
            let _g2 = l2.lock.write();
            unsafe { &mut *l2.tree.get() }.erase(h)
        };
        if ok {
            self.len.fetch_sub(1, Ordering::Relaxed);
            let e = s.entries.fetch_sub(1, Ordering::Relaxed) - 1;
            if e <= EXPAND_THRESHOLD {
                let grown = {
                    let _g = s.lock.read();
                    unsafe { &*s.inner.get() }.len() > 1
                };
                if grown {
                    self.resize_slot(s, false);
                }
            }
        }
        ok
    }

    fn len(&self) -> u64 {
        self.len.load(Ordering::Relaxed)
    }

    fn for_each(&self, f: &mut dyn FnMut(u64, u64)) {
        for s in self.slots.iter() {
            let _g = s.lock.read();
            let inner = unsafe { &*s.inner.get() };
            for l2 in inner.iter() {
                let _g2 = l2.lock.read();
                for (h, v) in unsafe { &*l2.tree.get() }.entries() {
                    f(unhash_key(h), v);
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "twolevel-binlist"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    #[test]
    fn basic() {
        let m = TwoLevelHashMap::new(16, 8);
        assert!(m.insert(1, 10));
        assert!(!m.insert(1, 11));
        assert_eq!(m.get(1), Some(10));
        assert!(m.erase(1));
        assert!(!m.erase(1));
        assert_eq!(m.len(), 0);
    }

    #[test]
    fn expansion_triggers_and_preserves_contents() {
        // a single L1 slot (m1 = 1) forces everything through one slot
        let m = TwoLevelHashMap::new(1, 64);
        for k in 0..100u64 {
            assert!(m.insert(k, k * 2));
        }
        assert!(m.expansion_count() >= 1, "slot must expand past threshold");
        for k in 0..100u64 {
            assert_eq!(m.get(k), Some(k * 2), "key {k} lost in expansion");
        }
    }

    #[test]
    fn shrink_after_mass_erase() {
        let m = TwoLevelHashMap::new(1, 64);
        for k in 0..100u64 {
            m.insert(k, k);
        }
        for k in 0..95u64 {
            m.erase(k);
        }
        assert!(m.shrink_count() >= 1, "slot must shrink below threshold");
        for k in 95..100u64 {
            assert_eq!(m.get(k), Some(k));
        }
    }

    #[test]
    fn oracle_sequential() {
        let m = TwoLevelHashMap::new(8, 16);
        let mut oracle = BTreeMap::new();
        let mut rng = Rng::new(17);
        for _ in 0..20_000 {
            let k = rng.below(500);
            match rng.below(3) {
                0 => {
                    let fresh = !oracle.contains_key(&k);
                    assert_eq!(m.insert(k, k + 9), fresh);
                    oracle.entry(k).or_insert(k + 9);
                }
                1 => assert_eq!(m.erase(k), oracle.remove(&k).is_some()),
                _ => assert_eq!(m.get(k), oracle.get(&k).copied()),
            }
        }
        assert_eq!(m.len() as usize, oracle.len());
    }

    #[test]
    fn concurrent_through_expansion() {
        let m = Arc::new(TwoLevelHashMap::new(2, 32));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..2_000u64 {
                    let k = t * 1_000_000 + i;
                    assert!(m.insert(k, k));
                    assert_eq!(m.get(k), Some(k), "read-own-write {k}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.len(), 8_000);
        assert!(m.expansion_count() > 0);
        for t in 0..4u64 {
            for i in (0..2_000u64).step_by(111) {
                assert_eq!(m.get(t * 1_000_000 + i), Some(t * 1_000_000 + i));
            }
        }
    }

    #[test]
    fn two_level_is_shallower_than_fixed() {
        use super::super::fixed::FixedHashMap;
        let fixed = FixedHashMap::new(16);
        let two = TwoLevelHashMap::new(16, 256);
        for k in 0..20_000u64 {
            fixed.insert(k, k);
            two.insert(k, k);
        }
        assert!(
            two.max_depth() < fixed.max_depth(),
            "two-level {} !< fixed {}",
            two.max_depth(),
            fixed.max_depth()
        );
    }
}
