//! The repo-wide key scrambler H(k) (paper §VIII eq. 8).
//!
//! Bit-exact with the L1 Pallas kernel and the jnp oracle — see
//! `util::rng::mix64`; this module just re-exports it under the hash-table
//! vocabulary and adds slot/shard helpers.

pub use crate::util::rng::{mix64, unmix64, GOLDEN};

/// H(k): scramble a 64-bit key (the `boost::hash` stand-in).
#[inline(always)]
pub fn hash_key(k: u64) -> u64 {
    mix64(k)
}

/// Inverse of [`hash_key`] (mix64 is a bijection): recovers the original
/// key from a stored hash. The BST-backed tables key their trees by H(k)
/// only; the ordered-map snapshot fallback inverts the hash to report the
/// caller's keys.
#[inline(always)]
pub fn unhash_key(h: u64) -> u64 {
    unmix64(h)
}

/// Slot for a hash in a power-of-two table of `m` slots (eq. 8 with the
/// modulo reduced to the low bits, exactly as the paper does).
#[inline(always)]
pub fn slot_of(h: u64, m: usize) -> usize {
    debug_assert!(m.is_power_of_two());
    (h & (m as u64 - 1)) as usize
}

/// NUMA shard for a key: the top `bits` MSBs (paper §VI uses bits 63-61).
#[inline(always)]
pub fn shard_of(key: u64, bits: u32) -> usize {
    if bits == 0 {
        0
    } else {
        (key >> (64 - bits)) as usize
    }
}

/// Reverse the bits of a 64-bit word (split-order list order, §VIII).
#[inline(always)]
pub fn reverse_bits(x: u64) -> u64 {
    x.reverse_bits()
}

/// Split-order "regular" key: reversed hash with the MSB set so dummy nodes
/// (reversed slot indices, MSB clear) sort strictly before regular nodes of
/// the same slot (Shalev & Shavit).
#[inline(always)]
pub fn so_regular_key(h: u64) -> u64 {
    reverse_bits(h | (1u64 << 63))
}

/// Split-order dummy key for a slot index.
#[inline(always)]
pub fn so_dummy_key(slot: u64) -> u64 {
    reverse_bits(slot)
}

/// Parent slot in the split-order recursive initialization: clear the
/// highest set bit.
#[inline(always)]
pub fn so_parent(slot: usize) -> usize {
    if slot == 0 {
        0
    } else {
        slot & !(1usize << (usize::BITS - 1 - slot.leading_zeros()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_matches_kernel() {
        for (i, want) in GOLDEN.iter().enumerate() {
            assert_eq!(hash_key(i as u64), *want);
        }
    }

    #[test]
    fn unhash_inverts_hash() {
        for k in (0..100_000u64).step_by(7) {
            assert_eq!(unhash_key(hash_key(k)), k);
        }
    }

    #[test]
    fn slot_is_low_bits() {
        assert_eq!(slot_of(0xABCD, 256), 0xCD);
        assert_eq!(slot_of(u64::MAX, 8192), 8191);
    }

    #[test]
    fn shard_is_high_bits() {
        assert_eq!(shard_of(0, 3), 0);
        assert_eq!(shard_of(u64::MAX, 3), 7);
        assert_eq!(shard_of(1u64 << 61, 3), 1);
        assert_eq!(shard_of(123, 0), 0);
    }

    #[test]
    fn dummy_sorts_before_regulars_of_slot() {
        // slot 3 in a 8-slot table: dummy key < any regular key whose low
        // bits are 3.
        let d = so_dummy_key(3);
        for h in [3u64, 11, 19, 0xFFF3, u64::MAX & !4] {
            if h & 7 == 3 {
                assert!(d < so_regular_key(h), "h={h:#x}");
            }
        }
    }

    #[test]
    fn so_parent_clears_top_bit() {
        assert_eq!(so_parent(1), 0);
        assert_eq!(so_parent(5), 1);
        assert_eq!(so_parent(12), 4);
        assert_eq!(so_parent(1024 + 17), 17);
        assert_eq!(so_parent(0), 0);
    }

    #[test]
    fn regular_keys_order_by_reversed_hash() {
        // within a slot, regular keys are ordered by bit-reversed hash
        let a = so_regular_key(0b0001);
        let b = so_regular_key(0b1001);
        assert!(a < b || a > b); // total order, no equality for distinct h
        assert_ne!(a, b);
    }
}
