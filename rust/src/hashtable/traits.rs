//! Common interface over the MWMR hash-table variants.

/// A concurrent multi-writer multi-reader map `u64 -> u64`.
pub trait ConcurrentMap: Send + Sync {
    /// Insert; `false` if the key already exists (no overwrite, matching the
    /// skiplist's set-style semantics used in the paper's workloads).
    fn insert(&self, key: u64, value: u64) -> bool;

    /// Lookup.
    fn get(&self, key: u64) -> Option<u64>;

    /// Remove; `false` if not present.
    fn erase(&self, key: u64) -> bool;

    /// Number of entries.
    fn len(&self) -> u64;

    /// Visit every `(key, value)` pair with the *original* key (tables that
    /// store only H(k) invert the hash or report a stashed key). The walk is
    /// quiescent-consistent: pairs untouched for its duration are reported
    /// exactly once; concurrent inserts/erases may or may not be seen. This
    /// is the snapshot primitive behind the ordered-map (`range`) fallback.
    fn for_each(&self, f: &mut dyn FnMut(u64, u64));

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn contains(&self, key: u64) -> bool {
        self.get(key).is_some()
    }

    /// Implementation name for reports.
    fn name(&self) -> &'static str;
}
