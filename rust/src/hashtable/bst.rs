//! Per-slot binary search tree (collision resolution for the fixed and
//! two-level tables, §VII items 1-2).
//!
//! The tree itself is sequential: every access happens under the owning
//! slot's reader-writer lock (shared for `find`, exclusive for
//! `insert`/`erase`), exactly the paper's design. Nodes live in flat
//! arenas with an internal free list so slot-local memory stays in a
//! few blocks (the §V locality argument).
//!
//! The node is split hot/cold like the skiplist planes: the **hot** array
//! holds `(key, left, right)` — 16 bytes, four descent nodes per cache
//! line — and the **cold** array holds the values, touched only on a hit.
//! A miss-heavy lookup mix therefore streams through 4x denser lines than
//! the old interleaved `(key, value, left, right)` layout.

/// Hot plane: the descent triple. 16 bytes → 4 nodes per 64-byte line.
#[derive(Clone, Copy, Debug)]
struct BstHot {
    key: u64,
    left: u32,
    right: u32,
}

const NIL: u32 = u32::MAX;

/// Unbalanced BST keyed by the *scrambled* hash (insertion order of
/// scrambled keys is effectively random, keeping expected depth O(log n)).
#[derive(Debug, Default)]
pub struct Bst {
    hot: Vec<BstHot>,
    /// Cold plane, parallel to `hot`: the payloads.
    val: Vec<u64>,
    free: Vec<u32>,
    root: u32,
    len: usize,
}

impl Bst {
    pub fn new() -> Bst {
        Bst { hot: Vec::new(), val: Vec::new(), free: Vec::new(), root: NIL, len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn alloc(&mut self, key: u64, value: u64) -> u32 {
        let n = BstHot { key, left: NIL, right: NIL };
        if let Some(i) = self.free.pop() {
            self.hot[i as usize] = n;
            self.val[i as usize] = value;
            i
        } else {
            self.hot.push(n);
            self.val.push(value);
            (self.hot.len() - 1) as u32
        }
    }

    /// Insert; false on duplicate.
    pub fn insert(&mut self, key: u64, value: u64) -> bool {
        if self.root == NIL {
            self.root = self.alloc(key, value);
            self.len = 1;
            return true;
        }
        let mut cur = self.root;
        loop {
            let n = self.hot[cur as usize];
            if key == n.key {
                return false;
            }
            let next = if key < n.key { n.left } else { n.right };
            if next == NIL {
                let fresh = self.alloc(key, value);
                let n = &mut self.hot[cur as usize];
                if key < n.key {
                    n.left = fresh;
                } else {
                    n.right = fresh;
                }
                self.len += 1;
                return true;
            }
            cur = next;
        }
    }

    pub fn get(&self, key: u64) -> Option<u64> {
        let mut cur = self.root;
        while cur != NIL {
            let n = &self.hot[cur as usize];
            if key == n.key {
                return Some(self.val[cur as usize]);
            }
            cur = if key < n.key { n.left } else { n.right };
        }
        None
    }

    /// Remove; false if absent. Standard BST delete (successor splice).
    pub fn erase(&mut self, key: u64) -> bool {
        let mut parent = NIL;
        let mut cur = self.root;
        while cur != NIL {
            let n = self.hot[cur as usize];
            if key == n.key {
                break;
            }
            parent = cur;
            cur = if key < n.key { n.left } else { n.right };
        }
        if cur == NIL {
            return false;
        }
        let n = self.hot[cur as usize];
        let replacement = if n.left == NIL {
            n.right
        } else if n.right == NIL {
            n.left
        } else {
            // splice in-order successor (leftmost of right subtree)
            let mut sp = cur;
            let mut s = n.right;
            while self.hot[s as usize].left != NIL {
                sp = s;
                s = self.hot[s as usize].left;
            }
            let succ = self.hot[s as usize];
            self.hot[cur as usize].key = succ.key;
            self.val[cur as usize] = self.val[s as usize];
            // remove s (has no left child)
            if sp == cur {
                self.hot[sp as usize].right = succ.right;
            } else {
                self.hot[sp as usize].left = succ.right;
            }
            self.free.push(s);
            self.len -= 1;
            return true;
        };
        if parent == NIL {
            self.root = replacement;
        } else if self.hot[parent as usize].left == cur {
            self.hot[parent as usize].left = replacement;
        } else {
            self.hot[parent as usize].right = replacement;
        }
        self.free.push(cur);
        self.len -= 1;
        true
    }

    /// Maximum depth (collision-chain cost metric for Table V analysis).
    pub fn depth(&self) -> usize {
        fn rec(nodes: &[BstHot], cur: u32) -> usize {
            if cur == NIL {
                0
            } else {
                let n = &nodes[cur as usize];
                1 + rec(nodes, n.left).max(rec(nodes, n.right))
            }
        }
        rec(&self.hot, self.root)
    }

    /// In-order `(key, value)` pairs (the snapshot primitive behind the
    /// hash tables' ordered-map fallback).
    pub fn entries(&self) -> Vec<(u64, u64)> {
        fn rec(nodes: &[BstHot], vals: &[u64], cur: u32, out: &mut Vec<(u64, u64)>) {
            if cur != NIL {
                let n = &nodes[cur as usize];
                rec(nodes, vals, n.left, out);
                out.push((n.key, vals[cur as usize]));
                rec(nodes, vals, n.right, out);
            }
        }
        let mut out = Vec::with_capacity(self.len);
        rec(&self.hot, &self.val, self.root, &mut out);
        out
    }

    /// In-order keys (test helper).
    pub fn keys(&self) -> Vec<u64> {
        fn rec(nodes: &[BstHot], cur: u32, out: &mut Vec<u64>) {
            if cur != NIL {
                let n = &nodes[cur as usize];
                rec(nodes, n.left, out);
                out.push(n.key);
                rec(nodes, n.right, out);
            }
        }
        let mut out = Vec::with_capacity(self.len);
        rec(&self.hot, self.root, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    #[test]
    fn basic_ops() {
        let mut t = Bst::new();
        assert!(t.insert(5, 50));
        assert!(t.insert(3, 30));
        assert!(t.insert(8, 80));
        assert!(!t.insert(5, 55));
        assert_eq!(t.get(3), Some(30));
        assert_eq!(t.get(4), None);
        assert!(t.erase(3));
        assert!(!t.erase(3));
        assert_eq!(t.len(), 2);
        assert_eq!(t.keys(), vec![5, 8]);
    }

    #[test]
    fn hot_plane_is_16_bytes() {
        // four descent nodes per cache line — the point of the split
        assert_eq!(std::mem::size_of::<BstHot>(), 16);
    }

    #[test]
    fn erase_two_children_and_root() {
        let mut t = Bst::new();
        for k in [50u64, 30, 70, 20, 40, 60, 80] {
            t.insert(k, k);
        }
        assert!(t.erase(50)); // root with two children
        assert!(t.erase(30)); // internal with two children
        assert_eq!(t.keys(), vec![20, 40, 60, 70, 80]);
        for k in [20u64, 40, 60, 70, 80] {
            assert_eq!(t.get(k), Some(k));
        }
    }

    #[test]
    fn matches_btreemap_oracle() {
        let mut t = Bst::new();
        let mut oracle = BTreeMap::new();
        let mut rng = Rng::new(13);
        for _ in 0..20_000 {
            let k = rng.below(300);
            match rng.below(3) {
                0 => {
                    let e = oracle.contains_key(&k);
                    assert_eq!(t.insert(k, k * 2), !e);
                    oracle.entry(k).or_insert(k * 2);
                }
                1 => assert_eq!(t.erase(k), oracle.remove(&k).is_some()),
                _ => assert_eq!(t.get(k), oracle.get(&k).copied()),
            }
            assert_eq!(t.len(), oracle.len());
        }
        assert_eq!(t.keys(), oracle.keys().copied().collect::<Vec<_>>());
    }

    #[test]
    fn node_reuse_via_freelist() {
        let mut t = Bst::new();
        for k in 0..100u64 {
            t.insert(k, k);
        }
        for k in 0..100u64 {
            t.erase(k);
        }
        let cap = t.hot.len();
        for k in 0..100u64 {
            t.insert(k, k);
        }
        assert_eq!(t.hot.len(), cap, "freed nodes must be reused");
        assert_eq!(t.hot.len(), t.val.len(), "planes stay parallel");
    }
}
