//! Concurrent MWMR hash tables (paper §VII-VIII).
//!
//! Variants, in the paper's order:
//! 1. [`FixedHashMap`] — fixed slots, BST per slot ("BinLists").
//! 2. [`TwoLevelHashMap`] — two-level with BSTs and threshold expansion.
//! 3. [`SpoHashMap`] — split-order list table (RW locks, lazy slot init,
//!    migration-free resize).
//! 4. [`TwoLevelSpoHashMap`] — hierarchical split-order (the winner).
//! Baseline: [`TbbLikeHashMap`] — chained buckets + migrating rehash.

pub mod bst;
pub mod fixed;
pub mod hash;
pub mod splitorder;
pub mod tbb_like;
pub mod traits;
pub mod twolevel;
pub mod twolevel_spo;

pub use fixed::FixedHashMap;
pub use splitorder::{SpoHashMap, SpoStats};
pub use tbb_like::TbbLikeHashMap;
pub use traits::ConcurrentMap;
pub use twolevel::TwoLevelHashMap;
pub use twolevel_spo::TwoLevelSpoHashMap;
