//! Hierarchical (two-level) split-order hash table (§VII variant 4,
//! "twolevelspo" in Table VI / "SPO" winner of Tables VII-VIII).
//!
//! The first level is a fixed fan-out of small split-order tables; each
//! second-level table resizes independently with a small seed, so the lazy
//! slot-initialization parent chains stay short and *local* — the paper's
//! fix for the cache behaviour of the flat split-order table. Each
//! second-level table also gets its own node arena (the paper gives each
//! first-level slot its own memory manager).

use crate::mem::{ArenaOptions, PoolStats};

use super::hash::hash_key;
use super::splitorder::{SpoHashMap, SpoStats};
use super::traits::ConcurrentMap;

/// Two-level split-order table.
pub struct TwoLevelSpoHashMap {
    tables: Box<[SpoHashMap]>,
    shift: u32,
}

impl TwoLevelSpoHashMap {
    /// The paper's configuration: 256 first-level tables, seed 64 each.
    pub fn new() -> TwoLevelSpoHashMap {
        Self::with_config(256, 64, 16, 1 << 14, 1 << 16)
    }

    /// `fanout` first-level tables (power of two); each second-level table
    /// has `seed` slots, `max_collisions`, and its own arena.
    pub fn with_config(
        fanout: usize,
        seed: usize,
        max_collisions: usize,
        max_slots: usize,
        capacity_per_table: usize,
    ) -> TwoLevelSpoHashMap {
        Self::with_config_on(fanout, seed, max_collisions, max_slots, capacity_per_table, ArenaOptions::default())
    }

    /// Like [`TwoLevelSpoHashMap::with_config`] with explicit arena
    /// placement: every second-level table's arena is homed on the same
    /// (shard) NUMA node.
    pub fn with_config_on(
        fanout: usize,
        seed: usize,
        max_collisions: usize,
        max_slots: usize,
        capacity_per_table: usize,
        opts: ArenaOptions,
    ) -> TwoLevelSpoHashMap {
        assert!(fanout.is_power_of_two());
        // Each sub-table sees only ~1/fanout of the shard's traffic, so an
        // explicit thread hint is diluted before reaching the sub-arenas
        // (the floor in `magazine_count` keeps collisions rare for the
        // diluted stream) — a full-size magazine array per sub-table would
        // multiply mostly-idle padded mutexes across fanout x shards. The
        // 0 = "derive from host" sentinel is preserved untouched.
        let sub_opts = ArenaOptions {
            threads_hint: if opts.threads_hint == 0 {
                0
            } else {
                opts.threads_hint.div_ceil(fanout).max(2)
            },
            ..opts
        };
        TwoLevelSpoHashMap {
            tables: (0..fanout)
                .map(|_| SpoHashMap::with_config_on(seed, max_collisions, max_slots, capacity_per_table, sub_opts))
                .collect(),
            // route on high hash bits so second-level tables (which consume
            // low bits) see independent distributions
            shift: 64 - fanout.trailing_zeros(),
        }
    }

    #[inline]
    fn table(&self, h: u64) -> &SpoHashMap {
        &self.tables[(h >> self.shift) as usize]
    }

    /// Aggregated cache-proxy stats across all second-level tables.
    pub fn stats(&self) -> SpoStats {
        let mut out = SpoStats::default();
        for t in self.tables.iter() {
            let s = t.stats();
            out.init_parent_hops += s.init_parent_hops;
            out.walk_steps += s.walk_steps;
            out.resizes += s.resizes;
        }
        out
    }

    pub fn fanout(&self) -> usize {
        self.tables.len()
    }

    /// §V arena accounting summed over every second-level table's arena.
    pub fn mem_stats(&self) -> PoolStats {
        let mut out = PoolStats::default();
        for t in self.tables.iter() {
            out.merge(&t.mem_stats());
        }
        out
    }
}

impl Default for TwoLevelSpoHashMap {
    fn default() -> Self {
        Self::new()
    }
}

impl ConcurrentMap for TwoLevelSpoHashMap {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.table(hash_key(key)).insert(key, value)
    }

    fn get(&self, key: u64) -> Option<u64> {
        self.table(hash_key(key)).get(key)
    }

    fn erase(&self, key: u64) -> bool {
        self.table(hash_key(key)).erase(key)
    }

    fn len(&self) -> u64 {
        self.tables.iter().map(|t| t.len()).sum()
    }

    fn for_each(&self, f: &mut dyn FnMut(u64, u64)) {
        for t in self.tables.iter() {
            t.for_each(&mut *f);
        }
    }

    fn name(&self) -> &'static str {
        "twolevel-spo"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    fn small() -> TwoLevelSpoHashMap {
        TwoLevelSpoHashMap::with_config(8, 4, 4, 1 << 10, 1 << 14)
    }

    #[test]
    fn basic() {
        let m = small();
        assert!(m.insert(1, 10));
        assert!(!m.insert(1, 11));
        assert_eq!(m.get(1), Some(10));
        assert!(m.erase(1));
        assert_eq!(m.get(1), None);
        assert_eq!(m.len(), 0);
    }

    #[test]
    fn oracle_sequential() {
        let m = small();
        let mut oracle = BTreeMap::new();
        let mut rng = Rng::new(31);
        for _ in 0..20_000 {
            let k = rng.below(700);
            match rng.below(3) {
                0 => {
                    let fresh = !oracle.contains_key(&k);
                    assert_eq!(m.insert(k, k + 2), fresh);
                    oracle.entry(k).or_insert(k + 2);
                }
                1 => assert_eq!(m.erase(k), oracle.remove(&k).is_some()),
                _ => assert_eq!(m.get(k), oracle.get(&k).copied()),
            }
        }
        assert_eq!(m.len() as usize, oracle.len());
    }

    #[test]
    fn for_each_covers_every_table() {
        let m = small();
        for k in 0..2_000u64 {
            m.insert(k, k ^ 5);
        }
        let mut got = Vec::new();
        m.for_each(&mut |k, v| got.push((k, v)));
        got.sort_unstable();
        assert_eq!(got.len(), 2_000);
        assert!(got.iter().enumerate().all(|(i, &(k, v))| k == i as u64 && v == k ^ 5));
    }

    #[test]
    fn concurrent_inserts() {
        let m = Arc::new(small());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..2_000u64 {
                    let k = t * 1_000_000 + i;
                    assert!(m.insert(k, k));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.len(), 8_000);
    }

    #[test]
    fn shorter_parent_chains_than_flat_spo() {
        // Table VI's mechanism: same workload, flat vs hierarchical; the
        // hierarchical table must do fewer parent-chain hops per entry.
        let flat = SpoHashMap::with_config(4, 2, 1 << 12, 1 << 16);
        let two = TwoLevelSpoHashMap::with_config(16, 4, 2, 1 << 10, 1 << 14);
        for k in 0..8_000u64 {
            flat.insert(k, k);
            two.insert(k, k);
        }
        let f = flat.stats();
        let t = two.stats();
        assert!(
            t.walk_steps < f.walk_steps,
            "two-level walk {} !< flat walk {}",
            t.walk_steps,
            f.walk_steps
        );
    }
}
